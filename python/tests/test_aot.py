"""AOT path: every artifact entry lowers to parseable HLO text and the
manifest matches what was written."""

import json
import os

import pytest

from compile import aot


def test_build_entries_cover_all_kinds():
    entries = aot.build_entries([64], batch=8)
    kinds = {e["kind"] for e in entries}
    assert kinds == {
        "cbe_encode", "cbe_project", "lsh_encode",
        "bilinear_encode", "opt_encode_b", "opt_hg",
    }


def test_lowering_produces_hlo_text():
    entries = aot.build_entries([32], batch=8)
    for e in entries:
        text = aot.to_hlo_text(e["fn"], *e["specs"])
        assert "HloModule" in text, e["name"]
        # the CBE graphs must contain real FFT ops (the paper's speedup)
        if e["kind"].startswith(("cbe", "opt")):
            assert "fft(" in text, f"{e['name']} lost its FFT"


def test_manifest_roundtrip(tmp_path):
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--dims", "16", "--batch", "4"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.load(open(tmp_path / "manifest.json"))
    assert len(manifest["artifacts"]) == 6
    for a in manifest["artifacts"]:
        p = tmp_path / a["path"]
        assert p.exists() and p.stat().st_size > 0
        assert a["inputs"], a
