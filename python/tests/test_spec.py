"""Projection-spec grammar: parity with the rust parser, clear rejects."""

import pytest

from compile.spec import canonical_spec, parse_proj_spec


def test_accepts_the_grammar():
    assert parse_proj_spec("circ") == ("circ", 1)
    assert parse_proj_spec("circulant") == ("circ", 1)
    assert parse_proj_spec("stacked") == ("stacked", None)
    assert parse_proj_spec("stacked:3") == ("stacked", 3)
    assert parse_proj_spec("downsampled") == ("downsampled", 1)
    assert parse_proj_spec("ds") == ("downsampled", 1)
    assert parse_proj_spec("  circ  ") == ("circ", 1)


def test_canonical_round_trip():
    for spec in ["circ", "stacked", "stacked:4", "downsampled"]:
        assert canonical_spec(*parse_proj_spec(spec)) == spec


@pytest.mark.parametrize("bad", [
    "", "bogus", "circ:2", "stacked:", "stacked:0", "stacked:x",
    "stacked:2:3", "downsampled:4", "stacked:-1",
])
def test_rejects_malformed_with_a_clear_message(bad):
    with pytest.raises(ValueError) as exc:
        parse_proj_spec(bad)
    msg = str(exc.value)
    assert "projection" in msg or "block count" in msg, msg


def test_unknown_spec_names_the_grammar():
    with pytest.raises(ValueError, match=r"circ \| stacked\[:B\] \| downsampled"):
        parse_proj_spec("butterfly")
