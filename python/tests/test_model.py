"""L2 correctness: the jax graphs vs direct dense-math references, plus the
structural identities the paper relies on (circulant ↔ FFT equivalence)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref


def circ(r):
    """Materialize circ(r) per eq. (3): first column r, each column a
    downward rotation of the previous — R[i, j] = r[(i − j) mod d]."""
    d = len(r)
    return np.stack([np.roll(r, j) for j in range(d)], axis=1)


@settings(max_examples=20, deadline=None)
@given(d=st.integers(2, 48), seed=st.integers(0, 2**31 - 1))
def test_cbe_project_equals_dense_circulant(d, seed):
    rng = np.random.default_rng(seed)
    b = 8
    x = rng.standard_normal((b, d)).astype(np.float32)
    r = rng.standard_normal(d).astype(np.float32)
    signs = np.where(rng.random(d) < 0.5, 1.0, -1.0).astype(np.float32)
    got = np.asarray(model.cbe_project(x, r, signs))
    R = circ(r)
    want = (x * signs) @ R.T
    assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@settings(max_examples=15, deadline=None)
@given(d=st.integers(2, 40), seed=st.integers(0, 2**31 - 1))
def test_cbe_encode_matches_ref(d, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((8, d)).astype(np.float32)
    r = rng.standard_normal(d).astype(np.float32)
    signs = np.where(rng.random(d) < 0.5, 1.0, -1.0).astype(np.float32)
    got = np.asarray(model.cbe_encode(x, r, signs))
    want = np.asarray(ref.cbe_encode_ref(x, r, signs))
    y = (x * signs) @ circ(r).T
    mask = np.abs(y) > 1e-4  # ignore near-zero sign races
    assert np.array_equal(got[mask], want[mask])


def test_bilinear_encode_matches_dense():
    rng = np.random.default_rng(7)
    b, d1, d2, k1, k2 = 8, 4, 6, 2, 4
    z = rng.standard_normal((b, d1, d2)).astype(np.float32)
    r1 = rng.standard_normal((d1, k1)).astype(np.float32)
    r2 = rng.standard_normal((d2, k2)).astype(np.float32)
    got = np.asarray(model.bilinear_encode(z, r1, r2))
    want = np.sign(np.einsum("bij,ik,jl->bkl", z, r1, r2)).reshape(b, k1 * k2)
    want[want == 0] = 1
    y = np.einsum("bij,ik,jl->bkl", z, r1, r2).reshape(b, k1 * k2)
    mask = np.abs(y) > 1e-4
    assert np.array_equal(got[mask], want[mask])


def test_opt_hg_matches_paper_formulas():
    rng = np.random.default_rng(11)
    b, d = 16, 24
    x = rng.standard_normal((b, d)).astype(np.float32)
    codes = np.where(rng.random((b, d)) < 0.5, 1.0, -1.0).astype(np.float32)
    m, h, g = (np.asarray(v) for v in model.opt_hg(x, codes))
    xf = np.fft.fft(x, axis=-1)
    bf = np.fft.fft(codes, axis=-1)
    m_want = np.sum(np.abs(xf) ** 2, axis=0)
    h_want = -2 * np.sum(xf.real * bf.real + xf.imag * bf.imag, axis=0)
    g_want = 2 * np.sum(xf.imag * bf.real - xf.real * bf.imag, axis=0)
    assert_allclose(m, m_want, rtol=1e-3)
    assert_allclose(h, h_want, rtol=1e-3, atol=1e-2)
    assert_allclose(g, g_want, rtol=1e-3, atol=1e-2)


def test_opt_encode_b_is_unflipped_cbe():
    rng = np.random.default_rng(13)
    b, d = 8, 20
    x = rng.standard_normal((b, d)).astype(np.float32)
    r = rng.standard_normal(d).astype(np.float32)
    ones = np.ones(d, np.float32)
    got = np.asarray(model.opt_encode_b(x, r))
    want = np.asarray(model.cbe_encode(x, r, ones))
    assert np.array_equal(got, want)
