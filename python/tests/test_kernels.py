"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import circulant as kernels
from compile.kernels import ref


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ------------------------------------------------------- spectral_hadamard

@settings(max_examples=25, deadline=None)
@given(
    b_blocks=st.integers(1, 4),
    block_b=st.sampled_from([1, 2, 8]),
    d=st.integers(2, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_spectral_hadamard_matches_ref(b_blocks, block_b, d, seed):
    rng = np.random.default_rng(seed)
    b = b_blocks * block_b
    x_re, x_im = rand(rng, b, d), rand(rng, b, d)
    r_re, r_im = rand(rng, d), rand(rng, d)
    got_re, got_im = kernels.spectral_hadamard(
        x_re, x_im, r_re, r_im, block_b=block_b)
    want_re, want_im = ref.spectral_hadamard_ref(x_re, x_im, r_re, r_im)
    assert_allclose(got_re, want_re, rtol=1e-5, atol=1e-5)
    assert_allclose(got_im, want_im, rtol=1e-5, atol=1e-5)


def test_spectral_hadamard_shrinks_block_to_divisor():
    rng = np.random.default_rng(0)
    # b=3 is not divisible by the requested block of 2; the kernel falls
    # back to the largest divisor (1) instead of failing.
    got_re, got_im = kernels.spectral_hadamard(
        rand(rng, 3, 8), rand(rng, 3, 8), rand(rng, 8), rand(rng, 8),
        block_b=2)
    assert got_re.shape == (3, 8) and got_im.shape == (3, 8)


# ------------------------------------------------------------ sign_matmul

@settings(max_examples=25, deadline=None)
@given(
    b_blocks=st.integers(1, 3),
    k_blocks=st.integers(1, 3),
    block_b=st.sampled_from([1, 4]),
    block_k=st.sampled_from([2, 8]),
    d=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_sign_matmul_matches_ref(b_blocks, k_blocks, block_b, block_k, d, seed):
    rng = np.random.default_rng(seed)
    b, k = b_blocks * block_b, k_blocks * block_k
    x, w = rand(rng, b, d), rand(rng, k, d)
    got = kernels.sign_matmul(x, w, block_b=block_b, block_k=block_k)
    want = ref.sign_matmul_ref(x, w)
    # ±1 outputs: any disagreement is a sign flip at a near-zero projection;
    # require bitwise equality except where |y| < tol.
    y = x @ w.T
    mask = np.abs(y) > 1e-4
    assert np.array_equal(np.asarray(got)[mask], np.asarray(want)[mask])
    assert set(np.unique(got)).issubset({-1.0, 1.0})


def test_sign_matmul_zero_is_positive():
    x = np.zeros((4, 8), np.float32)
    w = np.zeros((8, 8), np.float32)
    got = kernels.sign_matmul(x, w, block_b=4, block_k=8)
    assert np.all(np.asarray(got) == 1.0)
