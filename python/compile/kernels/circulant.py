"""L1 Pallas kernels for the CBE hot paths.

Two kernels:

* ``spectral_hadamard`` — the frequency-domain complex Hadamard product
  at the center of eq. (10). Tiled over batch rows; each grid step holds
  one (block_b × D) tile of the four real planes in VMEM.
* ``sign_matmul`` — blocked projection + binarization used by the LSH and
  bilinear baselines (and the B-update of §4.1): sign(X·Wᵀ).

Both run with ``interpret=True``: the CPU PJRT client cannot execute
Mosaic (real-TPU) custom calls, so kernels lower to plain HLO. TPU
considerations (VMEM footprint, MXU tiling) are documented in
DESIGN.md §Hardware-Adaptation and estimated in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------- spectral

def _spectral_hadamard_kernel(x_re_ref, x_im_ref, r_re_ref, r_im_ref,
                              y_re_ref, y_im_ref):
    """One batch tile: complex multiply of spectra, elementwise on VPU."""
    xr = x_re_ref[...]
    xi = x_im_ref[...]
    rr = r_re_ref[...]
    ri = r_im_ref[...]
    y_re_ref[...] = xr * rr[None, :] - xi * ri[None, :]
    y_im_ref[...] = xr * ri[None, :] + xi * rr[None, :]


@functools.partial(jax.jit, static_argnames=("block_b",))
def spectral_hadamard(x_re, x_im, r_re, r_im, block_b: int = 8):
    """Batched complex Hadamard product via Pallas.

    x_re, x_im: [B, D]; r_re, r_im: [D] → (y_re, y_im): [B, D].
    block_b is shrunk to a divisor of B when needed.
    """
    b, d = x_re.shape
    block_b = _largest_divisor_leq(b, block_b)
    grid = (b // block_b,)
    row_spec = pl.BlockSpec((block_b, d), lambda i: (i, 0))
    filt_spec = pl.BlockSpec((d,), lambda i: (0,))
    out_shape = jax.ShapeDtypeStruct((b, d), x_re.dtype)
    y_re, y_im = pl.pallas_call(
        _spectral_hadamard_kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, filt_spec, filt_spec],
        out_specs=[row_spec, row_spec],
        out_shape=[out_shape, out_shape],
        interpret=True,
    )(x_re, x_im, r_re, r_im)
    return y_re, y_im


# ---------------------------------------------------------------- matmul

def _sign_matmul_kernel(x_ref, w_ref, o_ref):
    """One (block_b × block_k) output tile: full-depth matmul + sign."""
    x = x_ref[...]          # [bb, D]
    w = w_ref[...]          # [bk, D]
    y = jnp.dot(x, w.T, preferred_element_type=jnp.float32)
    o_ref[...] = jnp.where(y >= 0, 1.0, -1.0).astype(jnp.float32)


def _largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of n that is ≤ cap (≥ 1)."""
    best = 1
    f = 1
    while f * f <= n:
        if n % f == 0:
            if f <= cap:
                best = max(best, f)
            if n // f <= cap:
                best = max(best, n // f)
        f += 1
    return best


@functools.partial(jax.jit, static_argnames=("block_b", "block_k"))
def sign_matmul(x, w, block_b: int = 8, block_k: int = 128):
    """sign(X · Wᵀ) via Pallas. x: [B, D], w: [K, D] → [B, K] of ±1.

    Grid tiles the output; the D (depth) axis stays whole per tile — the
    paper's d fits VMEM for the AOT shapes we ship (see DESIGN.md).
    Block sizes are shrunk to divisors of the actual shape when needed.
    """
    b, d = x.shape
    k, d2 = w.shape
    assert d == d2, "depth mismatch"
    block_b = _largest_divisor_leq(b, block_b)
    block_k = _largest_divisor_leq(k, block_k)
    grid = (b // block_b, k // block_k)
    return pl.pallas_call(
        _sign_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_k), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=True,
    )(x, w)
