"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact reference here; pytest
pins the kernels to these functions (the CORE correctness signal for L1).
"""

import jax.numpy as jnp


def spectral_hadamard_ref(x_re, x_im, r_re, r_im):
    """Complex Hadamard product of a batch of spectra with one filter
    spectrum: Y = X ∘ R, split into real/imag planes.

    x_re, x_im: [B, D] — real/imag parts of FFT(x_i) rows.
    r_re, r_im: [D]    — real/imag parts of FFT(r).
    Returns (y_re, y_im): [B, D].
    """
    y_re = x_re * r_re[None, :] - x_im * r_im[None, :]
    y_im = x_re * r_im[None, :] + x_im * r_re[None, :]
    return y_re, y_im


def sign_matmul_ref(x, w):
    """sign(X @ Wᵀ) with the paper's convention sign(0) = +1.

    x: [B, D], w: [K, D]. Returns [B, K] of ±1 (f32).
    """
    y = x @ w.T
    return jnp.where(y >= 0, 1.0, -1.0).astype(jnp.float32)


def cbe_encode_ref(x, r, signs):
    """Full-precision reference of the CBE encode pipeline (eq. 10):
    sign(IFFT(FFT(r) ∘ FFT(D·x))). x: [B, D]; r, signs: [D]."""
    xf = jnp.fft.fft(x * signs[None, :], axis=-1)
    rf = jnp.fft.fft(r)
    y = jnp.fft.ifft(xf * rf[None, :], axis=-1).real
    return jnp.where(y >= 0, 1.0, -1.0).astype(jnp.float32)
