"""Projection-spec grammar shared with the rust CLI.

Mirrors ``ProjectionSpec::from_spec`` in ``rust/src/projections/mod.rs``:
``circ | stacked[:B] | downsampled`` (aliases ``circulant``, ``ds``).
Kept free of jax imports so argument validation never needs the heavy
runtime — the AOT bridge parses specs before touching the compiler.
"""


def parse_proj_spec(spec):
    """Parse a projection spec into ``(variant, blocks)``.

    ``variant`` is one of ``"circ" | "stacked" | "downsampled"``;
    ``blocks`` is the stacked block count, or ``None`` when the spec
    leaves it to be auto-sized as ceil(k/d) (plain ``stacked``). Raises
    ``ValueError`` on anything outside the grammar, naming the grammar
    in the message like the rust parser does.
    """
    parts = str(spec).strip().split(":")
    head = parts[0]
    if head in ("circ", "circulant"):
        if len(parts) != 1:
            raise ValueError(f"wrong arity in projection spec '{spec}'")
        return ("circ", 1)
    if head == "stacked":
        if len(parts) == 1:
            return ("stacked", None)
        if len(parts) != 2:
            raise ValueError(f"wrong arity in projection spec '{spec}'")
        try:
            blocks = int(parts[1], 10)
        except ValueError:
            raise ValueError(
                f"bad number '{parts[1]}' in projection spec '{spec}'"
            ) from None
        if blocks < 1:
            raise ValueError(f"block count must be >= 1 in '{spec}'")
        return ("stacked", blocks)
    if head in ("downsampled", "ds"):
        if len(parts) != 1:
            raise ValueError(f"wrong arity in projection spec '{spec}'")
        return ("downsampled", 1)
    raise ValueError(
        f"unknown projection '{head}' (want circ | stacked[:B] | downsampled)"
    )


def canonical_spec(variant, blocks):
    """Round-trip partner of :func:`parse_proj_spec`."""
    if variant == "stacked":
        return "stacked" if blocks is None else f"stacked:{blocks}"
    return variant
