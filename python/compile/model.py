"""L2: the paper's compute graphs in JAX, calling the L1 Pallas kernels.

These functions are AOT-lowered by ``aot.py`` into HLO-text artifacts that
the Rust runtime loads — python never runs on the request path.

Graphs:

* ``cbe_encode``       — eq. (10): sign(IFFT(FFT(r) ∘ FFT(D·x))).
* ``cbe_project``      — same without binarization (for the asymmetric
                         classification protocol of Table 3).
* ``lsh_encode``       — sign(X·Wᵀ), the full-projection baseline.
* ``bilinear_encode``  — sign(R1ᵀ·Z·R2), the bilinear baseline.
* ``opt_encode_b``     — §4.1 B-update: codes of pre-flipped data.
* ``opt_hg``           — §4.1 frequency-domain h, g accumulators (the
                         O(n·d log d) heavy lifting of each iteration; the
                         O(d) per-bin closed-form solve stays in Rust).
"""

import jax.numpy as jnp

from compile.kernels import circulant as kernels


def _split_fft(x, axis=-1):
    f = jnp.fft.fft(x, axis=axis)
    return f.real.astype(jnp.float32), f.imag.astype(jnp.float32)


def cbe_project(x, r, signs):
    """Circulant projection R·D·x for a batch. x: [B,D]; r, signs: [D].

    Returns the full-precision projections [B, D] (f32).
    """
    x_re, x_im = _split_fft(x * signs[None, :])
    r_re, r_im = _split_fft(r)
    y_re, y_im = kernels.spectral_hadamard(x_re, x_im, r_re, r_im)
    y = jnp.fft.ifft(y_re + 1j * y_im, axis=-1).real
    return y.astype(jnp.float32)


def cbe_encode(x, r, signs):
    """k=d-bit CBE codes as ±1 f32 [B, D] (Rust slices the first k)."""
    y = cbe_project(x, r, signs)
    return jnp.where(y >= 0, 1.0, -1.0).astype(jnp.float32)


def lsh_encode(x, w):
    """LSH baseline: sign(X·Wᵀ). x: [B,D], w: [K,D] → ±1 [B,K]."""
    return kernels.sign_matmul(x, w)


def bilinear_encode(z, r1, r2):
    """Bilinear baseline: sign(R1ᵀ·Z·R2) flattened to [B, k1·k2].

    z: [B, d1, d2]; r1: [d1, k1]; r2: [d2, k2].
    The second-stage projection + sign runs through the Pallas sign_matmul
    kernel (depth = d2 after the first contraction).
    """
    b, d1, d2 = z.shape
    k1 = r1.shape[1]
    k2 = r2.shape[1]
    t = jnp.einsum("bij,ik->bkj", z, r1)          # [B, k1, d2]
    t2 = t.reshape(b * k1, d2)                    # rows to project
    y = kernels.sign_matmul(t2, r2.T)             # sign(T·R2): [B·k1, k2]
    return y.reshape(b, k1 * k2)


def opt_encode_b(x, r):
    """§4.1 B-update on pre-flipped data (D already applied): sign(X·Rᵀ)
    computed via FFT. Returns ±1 f32 [B, D]; Rust zeroes columns ≥ k."""
    ones = jnp.ones((x.shape[1],), jnp.float32)
    return cbe_encode(x, r, ones)


def opt_hg(x, b):
    """§4.1 frequency-domain accumulators for a batch:

    h = −2 Σ_i Re(x̃_i)∘Re(b̃_i) + Im(x̃_i)∘Im(b̃_i)
    g = +2 Σ_i Im(x̃_i)∘Re(b̃_i) − Re(x̃_i)∘Im(b̃_i)
    m =    Σ_i |x̃_i|²            (per-bin energies)

    x, b: [B, D] (b holds the current binary codes, zero-padded past k).
    Returns (m, h, g): [D] each. Rust sums across batches and runs the
    closed-form per-bin solve.
    """
    x_re, x_im = _split_fft(x)
    b_re, b_im = _split_fft(b)
    # The products are elementwise over [B, D] — route them through the
    # spectral_hadamard kernel with the conjugate trick:
    # conj(b̃)∘x̃ = (br·xr + bi·xi) + i(br·xi − bi·xr), so
    # h = −2 Σ Re(conj(b̃)∘x̃), g = +2 Σ Im(conj(b̃)∘x̃).
    m = jnp.sum(x_re * x_re + x_im * x_im, axis=0)
    prod_re = b_re * x_re + b_im * x_im
    prod_im = b_re * x_im - b_im * x_re
    h = -2.0 * jnp.sum(prod_re, axis=0)
    g = 2.0 * jnp.sum(prod_im, axis=0)
    return m.astype(jnp.float32), h.astype(jnp.float32), g.astype(jnp.float32)
