"""AOT bridge: lower the L2 graphs to HLO text + a JSON manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot --out-dir ../artifacts [--dims 512,2048]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.spec import canonical_spec, parse_proj_spec


def to_hlo_text(fn, *specs):
    """Lower a jax function at the given ShapeDtypeStructs to HLO text."""
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def near_square(d):
    best = (1, d)
    f = 1
    while f * f <= d:
        if d % f == 0:
            best = (f, d // f)
        f += 1
    return best


def build_entries(dims, batch):
    """The artifact set: one entry per (graph, shape signature)."""
    entries = []
    for d in dims:
        entries.append({
            "name": f"cbe_encode_d{d}_b{batch}",
            "fn": model.cbe_encode,
            "specs": [f32(batch, d), f32(d), f32(d)],
            "kind": "cbe_encode", "d": d, "batch": batch,
        })
        entries.append({
            "name": f"cbe_project_d{d}_b{batch}",
            "fn": model.cbe_project,
            "specs": [f32(batch, d), f32(d), f32(d)],
            "kind": "cbe_project", "d": d, "batch": batch,
        })
        k = min(d, 256)
        entries.append({
            "name": f"lsh_encode_d{d}_k{k}_b{batch}",
            "fn": model.lsh_encode,
            "specs": [f32(batch, d), f32(k, d)],
            "kind": "lsh_encode", "d": d, "k": k, "batch": batch,
        })
        d1, d2 = near_square(d)
        k1, k2 = near_square(k)
        entries.append({
            "name": f"bilinear_encode_d{d}_k{k}_b{batch}",
            "fn": model.bilinear_encode,
            "specs": [f32(batch, d1, d2), f32(d1, k1), f32(d2, k2)],
            "kind": "bilinear_encode", "d": d, "k": k, "batch": batch,
            "d1": d1, "d2": d2, "k1": k1, "k2": k2,
        })
        entries.append({
            "name": f"opt_encode_b_d{d}_b{batch}",
            "fn": model.opt_encode_b,
            "specs": [f32(batch, d), f32(d)],
            "kind": "opt_encode_b", "d": d, "batch": batch,
        })
        entries.append({
            "name": f"opt_hg_d{d}_b{batch}",
            "fn": model.opt_hg,
            "specs": [f32(batch, d), f32(batch, d)],
            "kind": "opt_hg", "d": d, "batch": batch,
        })
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--dims", default="512,2048",
                    help="comma-separated feature dims to compile")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--proj", default=os.environ.get("CBE_PROJ", "circ"),
                    help="projection spec (circ | stacked[:B] | downsampled); "
                         "defaults to $CBE_PROJ")
    args = ap.parse_args()

    # Validate the spec before any compiler work so a typo fails fast
    # with the grammar in the message (the rust CLI parses identically).
    variant, blocks = parse_proj_spec(args.proj)

    dims = [int(t) for t in args.dims.split(",") if t]
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "artifacts": [],
        "projection": {
            "spec": canonical_spec(variant, blocks),
            "variant": variant,
            "blocks": blocks,
        },
    }
    for e in build_entries(dims, args.batch):
        text = to_hlo_text(e["fn"], *e["specs"])
        path = f"{e['name']}.hlo.txt"
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        meta = {k: v for k, v in e.items() if k not in ("fn", "specs")}
        meta["path"] = path
        meta["inputs"] = [list(s.shape) for s in e["specs"]]
        manifest["artifacts"].append(meta)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
