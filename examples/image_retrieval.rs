//! Domain example: the paper's motivating workload — large-scale image
//! retrieval with long binary codes. Compares all five high-dim methods at
//! a fixed time budget (the paper's Figure 2/3/4 first-row regime) on a
//! synthetic Flickr-like corpus, then prints a ranked leaderboard.
//!
//! Run: `cargo run --release --example image_retrieval`

use cbe::experiments::recall_sweep::{run, Corpus, SweepConfig};

fn main() {
    let mut cfg = SweepConfig::quick(Corpus::Flickr, 2048);
    cfg.n = 4000;
    cfg.n_train = 800;
    cfg.n_queries = 80;
    cfg.bits = vec![512];
    println!("running fixed-time + fixed-bits retrieval comparison (d=2048, k=512)…");
    let result = run(&cfg);
    println!("{}", result.report);

    // Leaderboard at fixed time (the paper's headline regime).
    let mut ranked: Vec<_> = result
        .entries
        .iter()
        .filter(|e| e.regime == "fixed-time" || e.method.starts_with("CBE"))
        .collect();
    ranked.sort_by(|a, b| b.auc.partial_cmp(&a.auc).unwrap());
    println!("fixed-time leaderboard (AUC):");
    for (i, e) in ranked.iter().enumerate() {
        println!(
            "  {}. {:<14} bits={:<5} auc={:.3}",
            i + 1,
            e.method,
            e.bits,
            e.auc
        );
    }
}
