//! Quickstart: train CBE-opt on synthetic data, encode, retrieve.
//!
//! Run: `cargo run --release --example quickstart`

use cbe::bits::BinaryIndex;
use cbe::data::{gather, generate, train_query_split, SynthConfig};
use cbe::encoders::{BinaryEncoder, CbeRand, CbeTrainer};
use cbe::eval::{recall_auc, recall_curve};
use cbe::fft::Planner;
use cbe::groundtruth::exact_knn;
use cbe::opt::TimeFreqConfig;
use cbe::projections::ProjectionSpec;

fn main() -> anyhow::Result<()> {
    let d = 1024; // feature dimension
    let k = 256; // code bits
    let n = 3000;

    println!("== CBE quickstart: d={d}, k={k}, n={n} ==");

    // 1. Data: ℓ2-normalized synthetic image-like features.
    let ds = generate(&SynthConfig::flickr(n, d, 1));
    let (db_idx, q_idx) = train_query_split(n, 50, 2);
    let db = gather(&ds.x, &db_idx);
    let queries = gather(&ds.x, &q_idx);
    let train = gather(&ds.x, &db_idx[..500]);

    // 2. Train CBE-opt (time–frequency alternating optimization, §4).
    let mut cfg = TimeFreqConfig::new(k);
    cfg.iters = 6;
    let planner = Planner::new();
    let enc = CbeTrainer::new(cfg).seed(3).planner(planner.clone()).train(&train);
    println!(
        "trained CBE-opt in {:.0} ms on {} threads; objective {:.1} → {:.1}",
        enc.report.total_ms,
        enc.report.threads,
        enc.objective_trace[1],
        enc.objective_trace.last().unwrap()
    );

    // 3. Encode database + queries, build the Hamming index.
    let index = BinaryIndex::new(enc.encode_batch(&db));
    let q_codes = enc.encode_batch(&queries);

    // 4. Evaluate recall@R against exact ℓ2 ground truth.
    let gt = exact_knn(&db, &queries, 10);
    let curve = recall_curve(&index, &q_codes, &gt, 100);
    println!(
        "CBE-opt : recall@10={:.3} recall@100={:.3} AUC={:.3}",
        curve[9],
        curve[99],
        recall_auc(&curve)
    );

    // 5. Compare with CBE-rand (no training, same speed).
    let rand = CbeRand::new(d, k, 4, planner.clone())?;
    let curve_r = recall_curve(
        &BinaryIndex::new(rand.encode_batch(&db)),
        &rand.encode_batch(&queries),
        &gt,
        100,
    );
    println!(
        "CBE-rand: recall@10={:.3} recall@100={:.3} AUC={:.3}",
        curve_r[9],
        curve_r[99],
        recall_auc(&curve_r)
    );

    // 6. Long codes: k > d via stacked circulant blocks (spec grammar
    //    `circ | stacked[:B] | downsampled`; one FFT per block).
    let k_long = 2 * d;
    let long = CbeRand::with_spec(&ProjectionSpec::Stacked { blocks: None }, d, k_long, 4, planner)?;
    let curve_l = recall_curve(
        &BinaryIndex::new(long.encode_batch(&db)),
        &long.encode_batch(&queries),
        &gt,
        100,
    );
    println!(
        "{} (k={k_long}, {} blocks): recall@10={:.3} recall@100={:.3} AUC={:.3}",
        long.name(),
        long.model.block_count(),
        curve_l[9],
        curve_l[99],
        recall_auc(&curve_l)
    );
    Ok(())
}
