//! The paper's closing claim: "the full potential of the method is
//! unleashed for ultra-high dimensional data (d ~ 100M), for which no other
//! methods are applicable." This example encodes d = 2^20 (1M) vectors —
//! where the full-projection matrix alone would need 4 TB — with CBE's
//! O(d) memory, and extrapolates the d ~ 100M cost from measured scaling.
//!
//! Run: `cargo run --release --example ultra_high_dim`

use cbe::fft::Planner;
use cbe::projections::CirculantProjection;
use cbe::util::rng::Pcg64;
use cbe::util::timer::time_ms;

fn main() {
    let planner = Planner::new();
    let mut rng = Pcg64::new(1);

    println!("== ultra-high-dimensional CBE (paper §7 claim) ==");
    let mut last: Option<(usize, f64)> = None;
    for exp in [16usize, 18, 20] {
        let d = 1usize << exp;
        let proj = CirculantProjection::random(d, &mut rng, planner.clone());
        let x = rng.normal_vec(d);
        // warm the plan cache, then measure
        let _ = proj.project(&x);
        let (_, ms) = time_ms(|| {
            std::hint::black_box(proj.encode(std::hint::black_box(&x), 1024));
        });
        let dense_gb = (d as f64).powi(2) * 4.0 / 1e9;
        println!(
            "d = 2^{exp} ({d:>8}): encode {ms:>9.1} ms | CBE memory {:>7.1} MB | dense matrix would be {:>10.1} GB",
            d as f64 * 4.0 * 3.0 / 1e6,
            dense_gb
        );
        last = Some((d, ms));
    }
    // Extrapolate to d ~ 100M (2^27) via d log d scaling.
    if let Some((d0, ms0)) = last {
        let d1 = 1usize << 27;
        let scale = (d1 as f64 * (d1 as f64).log2()) / (d0 as f64 * (d0 as f64).log2());
        println!(
            "extrapolated d = 2^27 (~134M): ≈ {:.1} s per encode — feasible; any O(d²) method needs ~72 PB for its matrix",
            ms0 * scale / 1e3
        );
    }
}
