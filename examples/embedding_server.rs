//! End-to-end serving driver (the mandated e2e validation): train CBE-opt,
//! start the EmbeddingService (dynamic batching over the parallel native
//! batch-encode engine), index a corpus via the bulk `encode_corpus`
//! path, serve batched encode+search traffic, and report
//! latency/throughput + recall. Results are recorded in
//! EXPERIMENTS.md §E2E.
//!
//! Run: `cargo run --release --example embedding_server`
//! (a compiled-artifact manifest under `artifacts/` is optional — when
//! present its routed batch dimension sizes the dynamic batches).

use cbe::bits::BitCode;
use cbe::coordinator::{BatcherConfig, EmbeddingService, RetrainConfig, ServiceConfig};
use cbe::data::{gather, generate, train_query_split, SynthConfig};
use cbe::encoders::CbeTrainer;
use cbe::eval::{recall_auc, recall_curve};
use cbe::fft::Planner;
use cbe::groundtruth::exact_knn;
use cbe::index::IndexBackend;
use cbe::opt::TimeFreqConfig;
use cbe::projections::ProjectionSpec;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let d = 2048;
    let bits = 512;
    let n_db = 4000;
    let n_queries = 200;
    let artifacts = PathBuf::from("artifacts");
    // Retrieval backend is config:
    //   CBE_INDEX=linear|mih[:m]|mih-sampled[:m]|sharded:<s>[:m]
    // (default auto → routed by corpus size; mih-sampled decorrelates
    // adjacent CBE bits before bucketing).
    let backend = IndexBackend::from_spec(
        &std::env::var("CBE_INDEX").unwrap_or_else(|_| "auto".to_string()),
    )
    .map_err(|e| anyhow::anyhow!("CBE_INDEX: {e}"))?;
    // Projection variant is config too:
    //   CBE_PROJ=circ|stacked[:B]|downsampled
    // (stacked serves bits > d across B circulant blocks; downsampled
    // decorrelates bits < d via sparse row selection).
    let proj = ProjectionSpec::from_spec(
        &std::env::var("CBE_PROJ").unwrap_or_else(|_| "circ".to_string()),
    )
    .map_err(|e| anyhow::anyhow!("CBE_PROJ: {e}"))?;

    println!(
        "== embedding server e2e: d={d} bits={bits} db={n_db} index={} proj={} ==",
        backend.spec(),
        proj.spec()
    );

    // Data + training (build phase; python is NOT involved at runtime).
    let ds = generate(&SynthConfig::imagenet(n_db + n_queries, d, 11));
    let (db_idx, q_idx) = train_query_split(n_db + n_queries, n_queries, 12);
    let db_rows = gather(&ds.x, &db_idx);
    let queries = gather(&ds.x, &q_idx);
    let train = gather(&ds.x, &db_idx[..800]);

    let mut tf = TimeFreqConfig::new(bits);
    tf.iters = 5;
    // CBE_CACHE_BUDGET=<bytes>: cap the trainer's resident spectrum cache
    // (0 / unset = unlimited); oversized training sets stream in tiles.
    // Applies to both the initial training run and live retrains.
    let tf_cache_budget: usize = std::env::var("CBE_CACHE_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    tf.cache_budget = tf_cache_budget;
    let enc = CbeTrainer::new(tf)
        .seed(13)
        .planner(Planner::new())
        .train_model(&proj, &train, None)
        .map_err(|e| anyhow::anyhow!("train: {e}"))?;
    println!(
        "CBE-opt trained in {:.1}s ({} threads, spectrum cache {:.1} MiB)",
        enc.report.total_ms / 1e3,
        enc.report.threads,
        enc.report.cache_bytes as f64 / (1 << 20) as f64
    );

    // Start the service over the registered native model.
    let svc = EmbeddingService::start_with_model(
        &artifacts,
        ServiceConfig {
            d,
            bits,
            batcher: BatcherConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(2),
            },
            index: backend,
            retrain: RetrainConfig {
                cache_budget: tf_cache_budget,
                ..RetrainConfig::default()
            },
            // 0 → CBE_QUEUE_DEPTH env, else the 1024 default.
            queue_depth: 0,
            // Auto → CBE_MMAP env, else mapped where supported.
            load_mode: cbe::index::LoadMode::Auto,
            proj,
        },
        enc.model,
    )?;

    // Index the corpus through the bulk path (borrowed rows, parallel
    // batch encode, no per-request round-trip).
    let rows: Vec<Vec<f32>> = (0..db_rows.rows).map(|i| db_rows.row(i).to_vec()).collect();
    let t0 = Instant::now();
    let index = svc.build_index(&rows)?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "indexed {} vectors in {:.2}s ({:.0} vec/s through encode_corpus, backend {})",
        index.len(),
        dt,
        index.len() as f64 / dt,
        index.backend_name()
    );

    // Serve query traffic: concurrent async submits (exercises batching).
    let t0 = Instant::now();
    let handles: Vec<_> = (0..queries.rows)
        .map(|i| svc.encode_async(queries.row(i).to_vec()).unwrap())
        .collect();
    let mut q_codes = BitCode::new(queries.rows, bits);
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.recv()?;
        q_codes.set_row_from_signs(i, &resp.signs);
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "encoded {} queries in {:.3}s → {:.0} qps",
        queries.rows,
        dt,
        queries.rows as f64 / dt
    );

    // Retrieval quality vs exact ground truth.
    let gt = exact_knn(&db_rows, &queries, 10);
    let curve = recall_curve(&index, &q_codes, &gt, 100);
    println!(
        "recall@10={:.3} recall@100={:.3} AUC={:.3}",
        curve[9],
        curve[99],
        recall_auc(&curve)
    );

    // CBE_RETRAIN=1: re-learn the model from the corpus reservoir and
    // hot-swap it with the service live — queries keep flowing while the
    // trainer runs, and the swap never touches an in-flight batch.
    if std::env::var("CBE_RETRAIN").is_ok_and(|v| v == "1") {
        let pending = svc.retrain()?;
        // Keep serving while the background trainer works.
        let mut served = 0usize;
        let outcome = loop {
            match pending.try_recv() {
                Ok(result) => break result.map_err(|e| anyhow::anyhow!("retrain: {e}"))?,
                Err(std::sync::mpsc::TryRecvError::Empty) => {
                    let resp = svc.encode(queries.row(served % queries.rows).to_vec())?;
                    assert_eq!(resp.signs.len(), bits);
                    served += 1;
                }
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    anyhow::bail!("service dropped retrain reply");
                }
            }
        };
        println!(
            "retrained live: model v{} on {} sampled rows in {:.1} ms \
             ({} threads); served {served} queries during training",
            outcome.version,
            outcome.rows_used,
            outcome.report.total_ms,
            outcome.report.threads
        );
        let t0 = Instant::now();
        let index = svc.build_index(&rows)?;
        let resp = svc.encode(queries.row(0).to_vec())?;
        let q0 = BitCode::from_signs(&resp.signs, 1, bits);
        let hits = index.search(q0.code(0), 10);
        println!(
            "post-swap: reindexed {} vectors in {:.2}s; top hit dist {}",
            index.len(),
            t0.elapsed().as_secs_f64(),
            hits.first().map(|h| h.dist).unwrap_or(0)
        );
    }
    println!("service metrics: {}", svc.metrics.summary(32));
    // CBE_STATS=1: print the structured stats snapshot as the final
    // stdout line (machine-readable — CI pipes it into a JSON parser).
    if std::env::var("CBE_STATS").is_ok_and(|v| v == "1") {
        println!("{}", svc.stats()?.to_json());
    }
    Ok(())
}
