//! End-to-end serving driver (the mandated e2e validation): train CBE-opt,
//! start the EmbeddingService (dynamic batching over the parallel native
//! batch-encode engine), index a corpus via the bulk `encode_corpus`
//! path, serve batched encode+search traffic, and report
//! latency/throughput + recall. Results are recorded in
//! EXPERIMENTS.md §E2E.
//!
//! Run: `cargo run --release --example embedding_server`
//! (a compiled-artifact manifest under `artifacts/` is optional — when
//! present its routed batch dimension sizes the dynamic batches).

use cbe::bits::BitCode;
use cbe::coordinator::{BatcherConfig, EmbeddingService, ServiceConfig};
use cbe::data::{gather, generate, train_query_split, SynthConfig};
use cbe::encoders::CbeOpt;
use cbe::eval::{recall_auc, recall_curve};
use cbe::fft::Planner;
use cbe::groundtruth::exact_knn;
use cbe::index::IndexBackend;
use cbe::opt::TimeFreqConfig;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let d = 2048;
    let bits = 512;
    let n_db = 4000;
    let n_queries = 200;
    let artifacts = PathBuf::from("artifacts");
    // Retrieval backend is config:
    //   CBE_INDEX=linear|mih[:m]|mih-sampled[:m]|sharded:<s>[:m]
    // (default auto → routed by corpus size; mih-sampled decorrelates
    // adjacent CBE bits before bucketing).
    let backend = IndexBackend::from_spec(
        &std::env::var("CBE_INDEX").unwrap_or_else(|_| "auto".to_string()),
    )
    .map_err(|e| anyhow::anyhow!("CBE_INDEX: {e}"))?;

    println!(
        "== embedding server e2e: d={d} bits={bits} db={n_db} index={} ==",
        backend.spec()
    );

    // Data + training (build phase; python is NOT involved at runtime).
    let ds = generate(&SynthConfig::imagenet(n_db + n_queries, d, 11));
    let (db_idx, q_idx) = train_query_split(n_db + n_queries, n_queries, 12);
    let db_rows = gather(&ds.x, &db_idx);
    let queries = gather(&ds.x, &q_idx);
    let train = gather(&ds.x, &db_idx[..800]);

    let t0 = Instant::now();
    let mut tf = TimeFreqConfig::new(bits);
    tf.iters = 5;
    let enc = CbeOpt::train(&train, tf, 13, Planner::new(), None);
    println!("CBE-opt trained in {:.1}s", t0.elapsed().as_secs_f64());

    // Start the service over the shared native projection.
    let svc = EmbeddingService::start(
        &artifacts,
        ServiceConfig {
            d,
            bits,
            batcher: BatcherConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(2),
            },
            index: backend,
        },
        enc.proj.r.clone(),
        enc.proj.signs.clone(),
    )?;

    // Index the corpus through the bulk path (borrowed rows, parallel
    // batch encode, no per-request round-trip).
    let rows: Vec<Vec<f32>> = (0..db_rows.rows).map(|i| db_rows.row(i).to_vec()).collect();
    let t0 = Instant::now();
    let index = svc.build_index(&rows)?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "indexed {} vectors in {:.2}s ({:.0} vec/s through encode_corpus, backend {})",
        index.len(),
        dt,
        index.len() as f64 / dt,
        index.backend_name()
    );

    // Serve query traffic: concurrent async submits (exercises batching).
    let t0 = Instant::now();
    let handles: Vec<_> = (0..queries.rows)
        .map(|i| svc.encode_async(queries.row(i).to_vec()).unwrap())
        .collect();
    let mut q_codes = BitCode::new(queries.rows, bits);
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.recv()?;
        q_codes.set_row_from_signs(i, &resp.signs);
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "encoded {} queries in {:.3}s → {:.0} qps",
        queries.rows,
        dt,
        queries.rows as f64 / dt
    );

    // Retrieval quality vs exact ground truth.
    let gt = exact_knn(&db_rows, &queries, 10);
    let curve = recall_curve(&index, &q_codes, &gt, 100);
    println!(
        "recall@10={:.3} recall@100={:.3} AUC={:.3}",
        curve[9],
        curve[99],
        recall_auc(&curve)
    );
    println!("service metrics: {}", svc.metrics.summary(32));
    Ok(())
}
