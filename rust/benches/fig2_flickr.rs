//! Bench: Figure 2 — recall on synth-Flickr, fixed-time and fixed-bits.

use cbe::experiments::recall_sweep::{run, Corpus, SweepConfig};

fn main() {
    let full = std::env::var("CBE_BENCH_FULL").is_ok();
    let mut cfg = SweepConfig::quick(Corpus::Flickr, if full { 25600 } else { 1024 });
    if full {
        cfg.n = 20_000;
        cfg.n_train = 2_000;
        cfg.n_queries = 500;
    }
    let r = run(&cfg);
    println!("{}", r.report);
}
