//! Bench: Figure 3 — recall on synth-ImageNet-25600 analogue.

use cbe::experiments::recall_sweep::{run, Corpus, SweepConfig};

fn main() {
    let full = std::env::var("CBE_BENCH_FULL").is_ok();
    let cfg = SweepConfig::quick(Corpus::ImageNet, if full { 25600 } else { 1024 });
    let r = run(&cfg);
    println!("{}", r.report);
}
