//! Bench: Table 2 — projection time vs d for full/bilinear/circulant.
//! Run with `cargo bench --bench table2_timing` (add CBE_BENCH_FULL=1 for
//! the paper-scale dims up to 2^20).

use cbe::experiments::table2_timing::{run, DEFAULT_MEM_BUDGET};

fn main() {
    let full = std::env::var("CBE_BENCH_FULL").is_ok();
    let dims: Vec<usize> = if full {
        vec![1 << 13, 1 << 15, 1 << 17, 1 << 20]
    } else {
        vec![1 << 10, 1 << 12, 1 << 14, 1 << 16]
    };
    let r = run(&dims, DEFAULT_MEM_BUDGET, 7);
    println!("{}", r.report);
    // Shape assertions (the reproduction contract).
    let last = r.rows.last().unwrap();
    assert!(last.circulant_ms < last.bilinear_ms,
        "circulant must beat bilinear at d={}", last.d);
}
