//! Bench: serving-path throughput/latency of the coordinator (batched PJRT
//! encode). Not a paper table — the L3 perf target of DESIGN.md §Perf.

use cbe::coordinator::{BatcherConfig, EmbeddingService, ServiceConfig};
use cbe::util::rng::Pcg64;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn main() {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("skipping coordinator bench: run `make artifacts` first");
        return;
    }
    let d = 512;
    let mut rng = Pcg64::new(1);
    for max_batch in [1usize, 8, 32] {
        let svc = EmbeddingService::start(
            &dir,
            ServiceConfig {
                d,
                bits: 256,
                batcher: BatcherConfig {
                    max_batch,
                    max_wait: Duration::from_millis(1),
                },
            },
            rng.normal_vec(d),
            rng.sign_vec(d),
        )
        .unwrap();
        let n = 512;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n)
            .map(|_| svc.encode_async(rng.normal_vec(d)).unwrap())
            .collect();
        for h in handles {
            h.recv().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "max_batch={max_batch:<3} {n} reqs in {:.3}s → {:>8.0} enc/s | {}",
            dt,
            n as f64 / dt,
            svc.metrics.summary(max_batch)
        );
    }
}
