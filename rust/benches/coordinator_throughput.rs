//! Bench: serving-path throughput of the coordinator, in two parts.
//!
//! 1. Batched PJRT encode latency/QPS (needs `make artifacts`; skipped
//!    otherwise) — the L3 perf target of DESIGN.md §Perf.
//! 2. Retrieval QPS: linear scan vs MIH vs sharded MIH over packed codes
//!    at n ∈ {10⁴, 10⁵, 10⁶}, 256-bit — written to `BENCH_index.json`.
//!    Cap the sweep with `CBE_BENCH_MAX_N=100000` on small machines.
//!
//! The retrieval corpus is *clustered* (cluster centers + per-bit flip
//! noise), because that is the regime real embedding codes live in;
//! uniform random codes are the degenerate case where every point is
//! equidistant and no Hamming index — ours or anyone's — can help.

use cbe::bits::BitCode;
use cbe::coordinator::{BatcherConfig, EmbeddingService, ServiceConfig};
use cbe::index::{build_index, IndexAny, IndexBackend};
use cbe::util::json::Json;
use cbe::util::rng::Pcg64;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Flip each of `bits` bits with probability `p` (geometric skip-sampling,
/// so cost scales with the number of flips, not the number of bits).
fn flip_bits(rng: &mut Pcg64, words: &mut [u64], bits: usize, p: f64) {
    let mut i = 0usize;
    loop {
        let u = rng.next_f64();
        let skip = (u.max(1e-300).ln() / (1.0 - p).ln()).floor() as usize;
        i = i.saturating_add(skip);
        if i >= bits {
            return;
        }
        words[i / 64] ^= 1u64 << (i % 64);
        i += 1;
    }
}

/// Clustered corpus: `centers` random codes, each row a center with
/// per-bit flip noise `p` — neighbor structure like real embeddings.
fn clustered_codes(rng: &mut Pcg64, n: usize, bits: usize, centers: usize, p: f64) -> BitCode {
    let wpc = bits.div_ceil(64);
    let pad = wpc * 64 - bits;
    let mask = if pad == 0 { u64::MAX } else { u64::MAX >> pad };
    let center_words: Vec<u64> = (0..centers * wpc)
        .map(|j| {
            let w = rng.next_u64();
            if (j + 1) % wpc == 0 {
                w & mask
            } else {
                w
            }
        })
        .collect();
    let mut codes = BitCode::new(n, bits);
    for row in 0..n {
        let c = rng.below(centers as u64) as usize;
        let words = &mut codes.data[row * wpc..(row + 1) * wpc];
        words.copy_from_slice(&center_words[c * wpc..(c + 1) * wpc]);
        flip_bits(rng, words, bits, p);
    }
    codes
}

/// Queries = perturbed database rows, so every query has true neighbors.
fn perturbed_queries(rng: &mut Pcg64, db: &BitCode, nq: usize, p: f64) -> BitCode {
    let wpc = db.words_per_code;
    let mut queries = BitCode::new(nq, db.bits);
    for qi in 0..nq {
        let src = rng.below(db.n as u64) as usize;
        let words = &mut queries.data[qi * wpc..(qi + 1) * wpc];
        words.copy_from_slice(db.code(src));
        flip_bits(rng, words, db.bits, p);
    }
    queries
}

fn bench_index_backends() {
    let bits = 256;
    let k = 10;
    let nq = 200;
    let flip = 0.05;
    let max_n: usize = std::env::var("CBE_BENCH_MAX_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_000_000);
    let shards = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .max(2);

    println!("== retrieval backends: bits={bits} k={k} queries={nq} shards={shards} ==");
    let mut results: Vec<Json> = Vec::new();
    for n in [10_000usize, 100_000, 1_000_000] {
        if n > max_n {
            println!("n={n}: skipped (CBE_BENCH_MAX_N={max_n})");
            continue;
        }
        let mut rng = Pcg64::new(0xbeec + n as u64);
        let db = clustered_codes(&mut rng, n, bits, (n / 1000).max(16), flip);
        let queries = perturbed_queries(&mut rng, &db, nq, flip);

        let backends = [
            IndexBackend::Linear,
            IndexBackend::Mih { m: None },
            IndexBackend::ShardedMih { shards, m: None },
        ];
        let mut reference: Option<Vec<Vec<cbe::bits::index::Hit>>> = None;
        for backend in backends {
            let t0 = Instant::now();
            let index: IndexAny = build_index(db.clone(), &backend);
            let build_ms = t0.elapsed().as_secs_f64() * 1e3;

            // Warm caches/allocators, then time the full batch.
            std::hint::black_box(index.search_batch(&queries, k));
            let t0 = Instant::now();
            let hits = index.search_batch(&queries, k);
            let dt = t0.elapsed().as_secs_f64();
            let qps = nq as f64 / dt;

            // Every backend is exact: identical hits or the bench is void.
            match &reference {
                None => reference = Some(hits),
                Some(r) => assert_eq!(&hits, r, "backend {} diverged", backend.spec()),
            }

            println!(
                "n={n:<8} backend={:<12} build={build_ms:>9.1} ms  qps={qps:>9.0}",
                backend.spec()
            );
            results.push(Json::obj(vec![
                ("n", Json::num(n as f64)),
                ("backend", Json::str(&backend.spec())),
                ("build_ms", Json::num(build_ms)),
                ("batch_s", Json::num(dt)),
                ("qps", Json::num(qps)),
            ]));
        }
    }
    let doc = Json::obj(vec![
        ("bits", Json::num(bits as f64)),
        ("k", Json::num(k as f64)),
        ("queries", Json::num(nq as f64)),
        ("flip_prob", Json::num(flip)),
        ("shards", Json::num(shards as f64)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write("BENCH_index.json", format!("{doc}\n")).expect("write BENCH_index.json");
    println!("wrote BENCH_index.json");
}

fn bench_pjrt_encode() {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("skipping coordinator encode bench: run `make artifacts` first");
        return;
    }
    let d = 512;
    let mut rng = Pcg64::new(1);
    for max_batch in [1usize, 8, 32] {
        let svc = EmbeddingService::start(
            &dir,
            ServiceConfig {
                d,
                bits: 256,
                batcher: BatcherConfig {
                    max_batch,
                    max_wait: Duration::from_millis(1),
                },
                index: IndexBackend::Auto,
            },
            rng.normal_vec(d),
            rng.sign_vec(d),
        )
        .unwrap();
        let n = 512;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n)
            .map(|_| svc.encode_async(rng.normal_vec(d)).unwrap())
            .collect();
        for h in handles {
            h.recv().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "max_batch={max_batch:<3} {n} reqs in {:.3}s → {:>8.0} enc/s | {}",
            dt,
            n as f64 / dt,
            svc.metrics.summary(max_batch)
        );
    }
}

fn main() {
    bench_index_backends();
    bench_pjrt_encode();
}
