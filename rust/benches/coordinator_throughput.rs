//! Bench: serving-path throughput of the coordinator, in two parts.
//!
//! 1. Batched serving-path encode latency/QPS through the native
//!    parallel batch engine — the L3 perf target of DESIGN.md §Perf
//!    (per-projection encode cost lives in `encode_throughput`).
//! 2. Retrieval QPS: linear scan vs MIH (contiguous and bit-sampled
//!    substrings) vs sharded MIH over packed codes at n ∈ {10⁴, 10⁵, 10⁶},
//!    256-bit — the `results` array of `BENCH_index.json` (the
//!    sampled-vs-contiguous series is the `mih` vs `mih-sampled` rows).
//!    Cap the sweep with `CBE_BENCH_MAX_N=100000` on small machines.
//! 3. Bucket-store engines: the same key→postings workload through the
//!    legacy `HashMap<u64, Vec<u32>>` layout and the flat open-addressing
//!    arena `SubstringTable` — the `bucket_store` array of
//!    `BENCH_index.json` (arena-vs-hashmap series). Set
//!    `CBE_BENCH_ENFORCE=1` to hard-fail if the arena store probes slower
//!    than the hashmap (left off in CI: shared runners are too noisy for
//!    perf asserts).
//! 4. Observability overhead: one encode+search workload run with stage
//!    recording enabled vs disabled (`cbe::obs::set_enabled`, flipped
//!    in-process), best-of-N per mode — `BENCH_obs.json`. The overhead
//!    contract is ≤3%; `CBE_BENCH_ENFORCE=1` hard-fails past it.
//! 5. Kernel A/B: the linear-scan and MIH search paths at 512-bit codes
//!    (8 words per code — wide enough that the AVX2 popcount kernels
//!    engage) with the SIMD gate forced off vs on
//!    (`cbe::simd::set_enabled`), hits asserted identical — the
//!    `kernel_ab` array of `BENCH_index.json`. `CBE_BENCH_ENFORCE=1`
//!    hard-fails if the simd arm is slower.
//!
//! The retrieval corpus is *clustered* (cluster centers + per-bit flip
//! noise), because that is the regime real embedding codes live in;
//! uniform random codes are the degenerate case where every point is
//! equidistant and no Hamming index — ours or anyone's — can help.

use cbe::bits::BitCode;
use cbe::coordinator::{BatcherConfig, EmbeddingService, ServiceConfig};
use cbe::index::substring::{extract_bits, BuildFastHash, KeySource, SubstringTable};
use cbe::index::{build_index, IndexAny, IndexBackend};
use cbe::util::json::Json;
use cbe::util::rng::Pcg64;
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Flip each of `bits` bits with probability `p` (geometric skip-sampling,
/// so cost scales with the number of flips, not the number of bits).
fn flip_bits(rng: &mut Pcg64, words: &mut [u64], bits: usize, p: f64) {
    let mut i = 0usize;
    loop {
        let u = rng.next_f64();
        let skip = (u.max(1e-300).ln() / (1.0 - p).ln()).floor() as usize;
        i = i.saturating_add(skip);
        if i >= bits {
            return;
        }
        words[i / 64] ^= 1u64 << (i % 64);
        i += 1;
    }
}

/// Clustered corpus: `centers` random codes, each row a center with
/// per-bit flip noise `p` — neighbor structure like real embeddings.
fn clustered_codes(rng: &mut Pcg64, n: usize, bits: usize, centers: usize, p: f64) -> BitCode {
    let wpc = bits.div_ceil(64);
    let pad = wpc * 64 - bits;
    let mask = if pad == 0 { u64::MAX } else { u64::MAX >> pad };
    let center_words: Vec<u64> = (0..centers * wpc)
        .map(|j| {
            let w = rng.next_u64();
            if (j + 1) % wpc == 0 {
                w & mask
            } else {
                w
            }
        })
        .collect();
    let mut codes = BitCode::new(n, bits);
    for row in 0..n {
        let c = rng.below(centers as u64) as usize;
        let words = &mut codes.data[row * wpc..(row + 1) * wpc];
        words.copy_from_slice(&center_words[c * wpc..(c + 1) * wpc]);
        flip_bits(rng, words, bits, p);
    }
    codes
}

/// Queries = perturbed database rows, so every query has true neighbors.
fn perturbed_queries(rng: &mut Pcg64, db: &BitCode, nq: usize, p: f64) -> BitCode {
    let wpc = db.words_per_code;
    let mut queries = BitCode::new(nq, db.bits);
    for qi in 0..nq {
        let src = rng.below(db.n as u64) as usize;
        let words = &mut queries.data[qi * wpc..(qi + 1) * wpc];
        words.copy_from_slice(db.code(src));
        flip_bits(rng, words, db.bits, p);
    }
    queries
}

fn bench_index_backends() {
    let bits = 256;
    let k = 10;
    let nq = 200;
    let flip = 0.05;
    let max_n: usize = std::env::var("CBE_BENCH_MAX_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_000_000);
    let shards = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .max(2);

    println!("== retrieval backends: bits={bits} k={k} queries={nq} shards={shards} ==");
    let mut results: Vec<Json> = Vec::new();
    for n in [10_000usize, 100_000, 1_000_000] {
        if n > max_n {
            println!("n={n}: skipped (CBE_BENCH_MAX_N={max_n})");
            continue;
        }
        let mut rng = Pcg64::new(0xbeec + n as u64);
        let db = clustered_codes(&mut rng, n, bits, (n / 1000).max(16), flip);
        let queries = perturbed_queries(&mut rng, &db, nq, flip);

        let backends = [
            IndexBackend::Linear,
            IndexBackend::Mih { m: None },
            IndexBackend::MihSampled { m: None },
            IndexBackend::ShardedMih { shards, m: None },
        ];
        let mut reference: Option<Vec<Vec<cbe::bits::index::Hit>>> = None;
        for backend in backends {
            let t0 = Instant::now();
            let index: IndexAny = build_index(db.clone(), &backend);
            let build_ms = t0.elapsed().as_secs_f64() * 1e3;

            // Warm caches/allocators, then time the full batch.
            std::hint::black_box(index.search_batch(&queries, k));
            let t0 = Instant::now();
            let hits = index.search_batch(&queries, k);
            let dt = t0.elapsed().as_secs_f64();
            let qps = nq as f64 / dt;

            // Every backend is exact: identical hits or the bench is void.
            match &reference {
                None => reference = Some(hits),
                Some(r) => assert_eq!(&hits, r, "backend {} diverged", backend.spec()),
            }

            println!(
                "n={n:<8} backend={:<12} build={build_ms:>9.1} ms  qps={qps:>9.0}",
                backend.spec()
            );
            results.push(Json::obj(vec![
                ("n", Json::num(n as f64)),
                ("backend", Json::str(&backend.spec())),
                ("build_ms", Json::num(build_ms)),
                ("batch_s", Json::num(dt)),
                ("qps", Json::num(qps)),
            ]));
        }
    }
    let bucket_store = bench_bucket_store(max_n);
    let kernel_ab = bench_kernel_ab(max_n);
    let doc = Json::obj(vec![
        ("bits", Json::num(bits as f64)),
        ("k", Json::num(k as f64)),
        ("queries", Json::num(nq as f64)),
        ("flip_prob", Json::num(flip)),
        ("shards", Json::num(shards as f64)),
        ("results", Json::Arr(results)),
        ("bucket_store", Json::Arr(bucket_store)),
        ("kernel_ab", Json::Arr(kernel_ab)),
    ]);
    std::fs::write("BENCH_index.json", format!("{doc}\n")).expect("write BENCH_index.json");
    println!("wrote BENCH_index.json");
}

/// One timed probe workload, shared by both stores so the protocol
/// (warm-up, rounds, checksum rule) cannot diverge between them: walk all
/// query keys `rounds` times, summing every posting in every hit bucket.
/// Returns (lookups per second, checksum).
fn probe_rounds<'a>(
    rounds: usize,
    qkeys: &[u64],
    lookup: impl Fn(u64) -> Option<&'a [u32]>,
) -> (f64, u64) {
    let one = |acc: u64| {
        let mut sum = acc;
        for &key in qkeys {
            if let Some(bucket) = lookup(key) {
                for &slot in bucket {
                    sum = sum.wrapping_add(u64::from(slot) + 1);
                }
            }
        }
        sum
    };
    std::hint::black_box(one(0)); // warm caches
    let t0 = Instant::now();
    let mut sum = 0u64;
    for _ in 0..rounds {
        sum = one(sum);
    }
    let lps = (rounds * qkeys.len()) as f64 / t0.elapsed().as_secs_f64();
    (lps, sum)
}

/// Storage-engine microbench: identical (key → postings) build + probe
/// workloads through the legacy `HashMap<u64, Vec<u32>>` bucket layout and
/// the flat open-addressing arena [`SubstringTable`], over one 32-bit
/// substring of the clustered corpus. Checksums must match — both engines
/// must visit exactly the same postings — or the comparison is void.
fn bench_bucket_store(max_n: usize) -> Vec<Json> {
    let bits = 256;
    let span_len = 32;
    let flip = 0.05;
    println!("== bucket stores: hashmap vs arena, {span_len}-bit keys ==");
    let mut out: Vec<Json> = Vec::new();
    for n in [10_000usize, 100_000, 1_000_000] {
        if n > max_n {
            println!("n={n}: skipped (CBE_BENCH_MAX_N={max_n})");
            continue;
        }
        let mut rng = Pcg64::new(0x570e + n as u64);
        let db = clustered_codes(&mut rng, n, bits, (n / 1000).max(16), flip);
        let queries = perturbed_queries(&mut rng, &db, 2000, flip);
        let qkeys: Vec<u64> = (0..queries.n)
            .map(|i| extract_bits(queries.code(i), 0, span_len))
            .collect();
        // Enough probe rounds that the slower store still runs >~100ms.
        let rounds = (2_000_000 / qkeys.len()).max(1);

        let t0 = Instant::now();
        let mut hm: HashMap<u64, Vec<u32>, BuildFastHash> = HashMap::default();
        for row in 0..db.n {
            hm.entry(extract_bits(db.code(row), 0, span_len))
                .or_default()
                .push(row as u32);
        }
        let hm_build_ms = t0.elapsed().as_secs_f64() * 1e3;
        let (hm_lps, hm_sum) = probe_rounds(rounds, &qkeys, |key| hm.get(&key).map(Vec::as_slice));

        let t0 = Instant::now();
        let table = SubstringTable::build(
            KeySource::Span {
                start: 0,
                len: span_len,
            },
            &db,
        );
        let ar_build_ms = t0.elapsed().as_secs_f64() * 1e3;
        let (ar_lps, ar_sum) = probe_rounds(rounds, &qkeys, |key| table.bucket(key));

        assert_eq!(hm_sum, ar_sum, "stores visited different postings");
        println!(
            "n={n:<8} store=hashmap      build={hm_build_ms:>9.1} ms  lookups/s={hm_lps:>12.0}"
        );
        println!(
            "n={n:<8} store=arena        build={ar_build_ms:>9.1} ms  lookups/s={ar_lps:>12.0}"
        );
        if ar_lps < hm_lps {
            println!(
                "WARNING: arena store probed {:.1}% slower than hashmap at n={n}",
                (1.0 - ar_lps / hm_lps) * 100.0
            );
            let enforce = std::env::var("CBE_BENCH_ENFORCE").is_ok_and(|v| v == "1");
            assert!(
                !enforce,
                "arena store regressed vs hashmap (CBE_BENCH_ENFORCE=1)"
            );
        }
        for (store, build_ms, lps) in [
            ("hashmap", hm_build_ms, hm_lps),
            ("arena", ar_build_ms, ar_lps),
        ] {
            out.push(Json::obj(vec![
                ("n", Json::num(n as f64)),
                ("store", Json::str(store)),
                ("build_ms", Json::num(build_ms)),
                ("lookups_per_s", Json::num(lps)),
            ]));
        }
    }
    out
}

/// Kernel A/B over the retrieval hot loops: 512-bit codes (8 words per
/// code) through the linear scan (`hamming_to_all` bulk kernel) and MIH
/// (per-candidate `hamming_words` re-rank), SIMD gate forced off vs on.
/// Interleaved best-of-3 per backend; hits must be identical — the
/// popcount kernels are bit-exact, so divergence is a bug, not noise.
fn bench_kernel_ab(max_n: usize) -> Vec<Json> {
    let mut out: Vec<Json> = Vec::new();
    if !cbe::simd::available() {
        println!("== kernel A/B: skipped (SIMD kernels unavailable on this host/build) ==");
        return out;
    }
    let bits = 512;
    let k = 10;
    let nq = 200;
    let flip = 0.05;
    let n = 10_000usize;
    if n > max_n {
        println!("== kernel A/B: skipped (CBE_BENCH_MAX_N={max_n}) ==");
        return out;
    }
    println!("== search kernels: scalar vs simd popcount, bits={bits} n={n} ==");
    let mut rng = Pcg64::new(0x51d + n as u64);
    let db = clustered_codes(&mut rng, n, bits, (n / 1000).max(16), flip);
    let queries = perturbed_queries(&mut rng, &db, nq, flip);
    for backend in [IndexBackend::Linear, IndexBackend::Mih { m: None }] {
        let index: IndexAny = build_index(db.clone(), &backend);
        std::hint::black_box(index.search_batch(&queries, k)); // warm
        let mut best = [f64::INFINITY; 2]; // [scalar, simd]
        let mut hits_by_mode: Vec<Vec<Vec<cbe::bits::index::Hit>>> = Vec::new();
        for round in 0..3 {
            for (mode, on) in [(0usize, false), (1usize, true)] {
                cbe::simd::set_enabled(on);
                let t0 = Instant::now();
                let hits = index.search_batch(&queries, k);
                best[mode] = best[mode].min(t0.elapsed().as_secs_f64());
                if round == 0 {
                    hits_by_mode.push(hits);
                }
            }
        }
        assert_eq!(
            hits_by_mode[0],
            hits_by_mode[1],
            "kernel A/B hits diverged for backend {}",
            backend.spec()
        );
        let (scalar_qps, simd_qps) = (nq as f64 / best[0], nq as f64 / best[1]);
        println!(
            "backend={:<8} scalar={scalar_qps:>9.0} qps  simd={simd_qps:>9.0} qps  ratio={:>5.2}x",
            backend.spec(),
            simd_qps / scalar_qps
        );
        if simd_qps < scalar_qps {
            println!(
                "WARNING: simd search {:.1}% slower than scalar for backend {}",
                (1.0 - simd_qps / scalar_qps) * 100.0,
                backend.spec()
            );
            let enforce = std::env::var("CBE_BENCH_ENFORCE").is_ok_and(|v| v == "1");
            assert!(
                !enforce,
                "simd search regressed vs scalar (CBE_BENCH_ENFORCE=1)"
            );
        }
        for (kernel, qps, dt) in [("scalar", scalar_qps, best[0]), ("simd", simd_qps, best[1])] {
            out.push(Json::obj(vec![
                ("n", Json::num(n as f64)),
                ("bits", Json::num(bits as f64)),
                ("backend", Json::str(&backend.spec())),
                ("kernel", Json::str(kernel)),
                ("batch_s", Json::num(dt)),
                ("qps", Json::num(qps)),
            ]));
        }
    }
    // Leave the gate the way the environment asked for it.
    let env_on = !matches!(
        std::env::var("CBE_SIMD").ok().as_deref(),
        Some("0") | Some("false") | Some("off")
    );
    cbe::simd::set_enabled(env_on);
    out
}

fn bench_service_encode() {
    // Native parallel batch encode: no compiled artifacts required (a
    // manifest, when present, only sizes the batches).
    let dir = PathBuf::from("artifacts");
    let d = 512;
    let mut rng = Pcg64::new(1);
    for max_batch in [1usize, 8, 32] {
        let svc = EmbeddingService::start(
            &dir,
            ServiceConfig {
                d,
                bits: 256,
                batcher: BatcherConfig {
                    max_batch,
                    max_wait: Duration::from_millis(1),
                },
                index: IndexBackend::Auto,
                retrain: cbe::coordinator::RetrainConfig::default(),
                queue_depth: 0,
                load_mode: cbe::index::LoadMode::Auto,
                proj: cbe::projections::ProjectionSpec::Circ,
            },
            rng.normal_vec(d),
            rng.sign_vec(d),
        )
        .unwrap();
        let n = 512;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n)
            .map(|_| svc.encode_async(rng.normal_vec(d)).unwrap())
            .collect();
        for h in handles {
            h.recv().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "max_batch={max_batch:<3} {n} reqs in {:.3}s → {:>8.0} enc/s | {}",
            dt,
            n as f64 / dt,
            svc.metrics.summary(max_batch)
        );
    }
}

/// Observability overhead A/B: the identical serve workload (async encode
/// fan-in + MIH search) with the obs recorder enabled vs disabled, flipped
/// in-process via `set_enabled` so the two modes share one service, one
/// index and one warmed allocator. Best-of-`ROUNDS` per mode absorbs
/// scheduler noise; the JSON records both throughputs and the relative
/// overhead against the 3% contract.
fn bench_obs() {
    const ROUNDS: usize = 3;
    let dir = PathBuf::from("artifacts");
    let d = 512;
    let bits = 256;
    let n_db = 2048;
    let n_requests = 512;
    let n_queries = 64;

    println!(
        "== obs overhead: d={d} bits={bits} db={n_db} reqs={n_requests} queries={n_queries} =="
    );
    let mut rng = Pcg64::new(0x0b5e);
    let svc = EmbeddingService::start(
        &dir,
        ServiceConfig {
            d,
            bits,
            batcher: BatcherConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(1),
            },
            // Explicit MIH so the probe/dedup/re-rank path is exercised
            // whatever the auto router would pick at this corpus size.
            index: IndexBackend::Mih { m: None },
            retrain: cbe::coordinator::RetrainConfig::default(),
            queue_depth: 0,
            load_mode: cbe::index::LoadMode::Auto,
            proj: cbe::projections::ProjectionSpec::Circ,
        },
        rng.normal_vec(d),
        rng.sign_vec(d),
    )
    .unwrap();
    let rows: Vec<Vec<f32>> = (0..n_db).map(|_| rng.normal_vec(d)).collect();
    let index = svc.build_index(&rows).unwrap();

    let run_once = |rng: &mut Pcg64| -> f64 {
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n_requests)
            .map(|_| svc.encode_async(rng.normal_vec(d)).unwrap())
            .collect();
        for h in handles {
            h.recv().unwrap();
        }
        for qi in 0..n_queries {
            std::hint::black_box(svc.search(&index, rows[qi].clone(), 10).unwrap());
        }
        t0.elapsed().as_secs_f64()
    };

    // Warm-up: plan cache, scratch pools, allocator, branch predictors.
    std::hint::black_box(run_once(&mut rng));

    // Interleave modes across rounds so drift hits both equally.
    let mut best = [f64::INFINITY; 2]; // [obs off, obs on]
    for _ in 0..ROUNDS {
        for (mode, on) in [(0usize, false), (1usize, true)] {
            cbe::obs::set_enabled(on);
            let dt = run_once(&mut rng);
            best[mode] = best[mode].min(dt);
        }
    }
    // Leave the gate the way the environment asked for it.
    let env_on = !matches!(
        std::env::var("CBE_OBS").ok().as_deref(),
        Some("0") | Some("false") | Some("off")
    );
    cbe::obs::set_enabled(env_on);

    let ops = (n_requests + n_queries) as f64;
    let qps_off = ops / best[0];
    let qps_on = ops / best[1];
    let overhead_pct = (best[1] / best[0] - 1.0) * 100.0;
    println!(
        "obs off: {qps_off:>8.0} ops/s | obs on: {qps_on:>8.0} ops/s | overhead {overhead_pct:+.2}%"
    );

    let doc = Json::obj(vec![
        ("d", Json::num(d as f64)),
        ("bits", Json::num(bits as f64)),
        ("db", Json::num(n_db as f64)),
        ("requests", Json::num(n_requests as f64)),
        ("search_queries", Json::num(n_queries as f64)),
        ("rounds", Json::num(ROUNDS as f64)),
        ("qps_obs_off", Json::num(qps_off)),
        ("qps_obs_on", Json::num(qps_on)),
        ("overhead_pct", Json::num(overhead_pct)),
        ("threshold_pct", Json::num(3.0)),
    ]);
    std::fs::write("BENCH_obs.json", format!("{doc}\n")).expect("write BENCH_obs.json");
    println!("wrote BENCH_obs.json");

    if overhead_pct > 3.0 {
        println!("WARNING: observability overhead {overhead_pct:.2}% exceeds the 3% contract");
        let enforce = std::env::var("CBE_BENCH_ENFORCE").is_ok_and(|v| v == "1");
        assert!(
            !enforce,
            "observability overhead {overhead_pct:.2}% > 3% (CBE_BENCH_ENFORCE=1)"
        );
    }
}

fn main() {
    bench_index_backends();
    bench_service_encode();
    bench_obs();
}
