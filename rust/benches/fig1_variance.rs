//! Bench: Figure 1 — analytical vs circulant Hamming-distance variance.

use cbe::experiments::fig1_variance::run;

fn main() {
    let full = std::env::var("CBE_BENCH_FULL").is_ok();
    let (pairs, reps, d) = if full { (40, 200, 256) } else { (10, 60, 128) };
    let r = run(
        d,
        &[8, 16, 32, 64, 128],
        &[0.2, 0.5, 0.9, 1.2, std::f64::consts::FRAC_PI_2],
        pairs,
        reps,
        42,
    );
    println!("{}", r.report);
    println!("max |circulant − analytical| gap: {:.5}", r.max_gap);
}
