//! Bench: the spectrum-cached trainer vs the old per-row-FFT serial
//! loop — CBE-opt training throughput at d ∈ {256, 1024}. Three arms:
//!
//! * `legacy`   — `opt::timefreq::reference::run`, the pre-refactor
//!   serial trainer (recomputes every row FFT in every iteration);
//! * `serial`   — the spectrum-cached trainer pinned to 1 thread
//!   (isolates the cache win from the threading win);
//! * `parallel` — the spectrum-cached trainer on all cores.
//!
//! Throughput is row-iterations per second (rows × iters / wall time,
//! cache build included), the unit that matches the trainer's
//! O(n·d log d)-per-iteration cost. The serial and parallel arms must
//! produce bit-identical r (the deterministic-flag contract) or the
//! bench aborts. Emits `BENCH_train.json`.
//!
//! Env knobs, mirroring `encode_throughput`:
//! * `CBE_BENCH_MAX_D=256` caps the dim sweep (CI-sized machines);
//! * `CBE_BENCH_TRAIN_N=128` overrides training rows per arm;
//! * `CBE_BENCH_TRAIN_ITERS=3` overrides iterations;
//! * `CBE_BENCH_ENFORCE=1` turns the parallel-slower-than-legacy
//!   warning into a hard failure (left off in CI: shared runners are
//!   too noisy for perf asserts).

use cbe::fft::Planner;
use cbe::linalg::Mat;
use cbe::opt::timefreq::reference;
use cbe::opt::{TimeFreqConfig, TimeFreqOptimizer};
use cbe::util::json::Json;
use cbe::util::rng::Pcg64;
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let max_d = env_usize("CBE_BENCH_MAX_D", 1024);
    let iters = env_usize("CBE_BENCH_TRAIN_ITERS", 5);
    println!("== CBE-opt trainer: legacy per-row-FFT vs spectrum-cached ({cores} cores) ==");

    let mut results: Vec<Json> = Vec::new();
    for d in [256usize, 1024] {
        if d > max_d {
            println!("d={d}: skipped (CBE_BENCH_MAX_D={max_d})");
            continue;
        }
        let n = env_usize("CBE_BENCH_TRAIN_N", 512);
        let k = d / 2;
        let mut rng = Pcg64::new(0x7a11 + d as u64);
        let mut x = Mat::randn(n, d, &mut rng);
        for i in 0..n {
            cbe::util::l2_normalize(x.row_mut(i));
        }
        let r0 = rng.normal_vec(d);
        let planner = Planner::new();
        let mut cfg = TimeFreqConfig::new(k);
        cfg.iters = iters;
        cfg.deterministic = true;
        // Warm the plan cache so no arm pays first-use twiddle builds.
        let _ = planner.plan(d);

        // Legacy arm: the old serial trainer, per-row FFTs everywhere.
        let t0 = Instant::now();
        let (_r_legacy, _) = reference::run(&planner, d, &cfg, &x, &r0, None);
        let dt_legacy = t0.elapsed().as_secs_f64();

        // Serial arm: spectrum cache, 1 thread.
        cfg.threads = 1;
        let mut opt = TimeFreqOptimizer::new(d, cfg.clone(), planner.clone());
        let t0 = Instant::now();
        let r_serial = opt.run(&x, &r0, None);
        let dt_serial = t0.elapsed().as_secs_f64();

        // Parallel arm: spectrum cache, all cores.
        cfg.threads = cores;
        let mut opt = TimeFreqOptimizer::new(d, cfg, planner.clone());
        let t0 = Instant::now();
        let r_parallel = opt.run(&x, &r0, None);
        let dt_parallel = t0.elapsed().as_secs_f64();

        for (i, (a, b)) in r_parallel.iter().zip(&r_serial).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "parallel trainer diverged from serial at d={d}, r[{i}]"
            );
        }

        let row_iters = (n * iters) as f64;
        let qps = |dt: f64| row_iters / dt;
        println!(
            "d={d:<5} k={k:<4} n={n:<5} iters={iters}  \
             legacy={:>9.0} row-it/s  serial={:>9.0} ({:.2}x)  \
             parallel={:>9.0} ({:.2}x)",
            qps(dt_legacy),
            qps(dt_serial),
            dt_legacy / dt_serial,
            qps(dt_parallel),
            dt_legacy / dt_parallel,
        );
        if dt_parallel >= dt_legacy && cores >= 2 {
            println!(
                "WARNING: spectrum-cached parallel trainer {:.1}% slower than legacy at d={d}",
                (dt_parallel / dt_legacy - 1.0) * 100.0
            );
            let enforce = std::env::var("CBE_BENCH_ENFORCE").is_ok_and(|v| v == "1");
            assert!(
                !enforce,
                "parallel trainer regressed vs the old per-row-FFT path (CBE_BENCH_ENFORCE=1)"
            );
        }

        for (mode, threads, dt) in [
            ("legacy", 1usize, dt_legacy),
            ("serial", 1, dt_serial),
            ("parallel", cores, dt_parallel),
        ] {
            results.push(Json::obj(vec![
                ("d", Json::num(d as f64)),
                ("k", Json::num(k as f64)),
                ("n", Json::num(n as f64)),
                ("iters", Json::num(iters as f64)),
                ("mode", Json::str(mode)),
                ("threads", Json::num(threads as f64)),
                ("train_s", Json::num(dt)),
                ("row_iters_per_s", Json::num(qps(dt))),
                ("speedup_vs_legacy", Json::num(dt_legacy / dt)),
            ]));
        }
    }

    let doc = Json::obj(vec![
        ("cores", Json::num(cores as f64)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write("BENCH_train.json", format!("{doc}\n")).expect("write BENCH_train.json");
    println!("wrote BENCH_train.json");
}
