//! Bench: the half-spectrum trainer vs the layouts it replaced — CBE-opt
//! training throughput at d ∈ {256, 1024}. Four arms:
//!
//! * `legacy`   — `opt::timefreq::reference::run`, the pre-cache serial
//!   trainer (recomputes every row FFT in every iteration);
//! * `full`     — `opt::timefreq::reference::run_full_cache`, the PR-4
//!   layout: spectra cached once as **full** d-point complex rows
//!   (16·n·d bytes), full-size per-iteration transforms;
//! * `serial`   — the half-spectrum trainer pinned to 1 thread
//!   (isolates the half-size FFT + half-cache win from the threading
//!   win);
//! * `parallel` — the half-spectrum trainer on all cores.
//!
//! Throughput is row-iterations per second (rows × iters / wall time,
//! cache build included), the unit that matches the trainer's
//! O(n·d log d)-per-iteration cost; the full-vs-half comparison is also
//! reported **per iteration** (cache build excluded) since that is what
//! the half-size transforms halve. The serial and parallel arms must
//! produce bit-identical r (the deterministic-flag contract) or the
//! bench aborts. Emits `BENCH_train.json`, including `cache_bytes` per
//! arm so the memory halving is recorded alongside the speed.
//!
//! Env knobs, mirroring `encode_throughput`:
//! * `CBE_BENCH_MAX_D=256` caps the dim sweep (CI-sized machines);
//! * `CBE_BENCH_TRAIN_N=128` overrides training rows per arm;
//! * `CBE_BENCH_TRAIN_ITERS=3` overrides iterations;
//! * `CBE_BENCH_ENFORCE=1` turns regressions into hard failures: the
//!   half-spectrum cache must stay ≤ 0.55× the full layout (exact,
//!   deterministic), the half-spectrum per-iteration time must not
//!   exceed the full-spectrum arm's ×1.15 (expected ratio ~0.55–0.6),
//!   and the parallel arm must stay under ×1.25 of legacy (expected
//!   ≤ ~0.5). The timing gates **re-measure the offending pair once
//!   before failing**: a shared-runner stall doesn't reproduce, a real
//!   regression does — which is what makes them safe to enforce in CI.

use cbe::fft::Planner;
use cbe::linalg::Mat;
use cbe::opt::timefreq::reference;
use cbe::opt::{TimeFreqConfig, TimeFreqOptimizer};
use cbe::util::json::Json;
use cbe::util::rng::Pcg64;
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let max_d = env_usize("CBE_BENCH_MAX_D", 1024);
    let iters = env_usize("CBE_BENCH_TRAIN_ITERS", 5);
    let enforce = std::env::var("CBE_BENCH_ENFORCE").is_ok_and(|v| v == "1");
    println!("== CBE-opt trainer: legacy / full-spectrum cache / half-spectrum ({cores} cores) ==");

    let mut results: Vec<Json> = Vec::new();
    for d in [256usize, 1024] {
        if d > max_d {
            println!("d={d}: skipped (CBE_BENCH_MAX_D={max_d})");
            continue;
        }
        let n = env_usize("CBE_BENCH_TRAIN_N", 512);
        let k = d / 2;
        let mut rng = Pcg64::new(0x7a11 + d as u64);
        let mut x = Mat::randn(n, d, &mut rng);
        for i in 0..n {
            cbe::util::l2_normalize(x.row_mut(i));
        }
        let r0 = rng.normal_vec(d);
        let planner = Planner::new();
        let mut cfg = TimeFreqConfig::new(k);
        cfg.iters = iters;
        cfg.deterministic = true;
        let mut cfg_serial = cfg.clone();
        cfg_serial.threads = 1;
        let mut cfg_par = cfg.clone();
        cfg_par.threads = cores;
        // Warm the plan caches so no arm pays first-use twiddle builds.
        let _ = planner.plan(d);
        let _ = planner.plan(d / 2);

        let per_iter = |secs: f64| secs / iters.max(1) as f64;
        // One measurement per arm, repeatable for the retry gates below.
        let measure_legacy = || {
            let t0 = Instant::now();
            let _ = reference::run(&planner, d, &cfg, &x, &r0, None);
            t0.elapsed().as_secs_f64()
        };
        let measure_full = || {
            let t0 = Instant::now();
            let (_r, _trace, iter_s, bytes) = reference::run_full_cache(&planner, d, &cfg, &x, &r0);
            (
                t0.elapsed().as_secs_f64(),
                per_iter(iter_s.iter().sum::<f64>()),
                bytes,
            )
        };
        let measure_half = |arm_cfg: &TimeFreqConfig| {
            let mut opt = TimeFreqOptimizer::new(d, arm_cfg.clone(), planner.clone());
            let t0 = Instant::now();
            let r = opt.run(&x, &r0, None);
            let dt = t0.elapsed().as_secs_f64();
            let it = per_iter(opt.report.iter_ms.iter().sum::<f64>() / 1e3);
            (dt, it, opt.report.cache_bytes, r)
        };

        let mut dt_legacy = measure_legacy();
        let (dt_full, mut full_iter, full_cache_bytes) = measure_full();
        let (dt_serial, mut half_iter, half_cache_bytes, r_serial) = measure_half(&cfg_serial);
        let (mut dt_parallel, par_iter, _, r_parallel) = measure_half(&cfg_par);

        for (i, (a, b)) in r_parallel.iter().zip(&r_serial).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "parallel trainer diverged from serial at d={d}, r[{i}]"
            );
        }

        // Timing gates re-measure the offending pair once before
        // judging: a noisy-neighbor stall on a shared runner doesn't
        // reproduce, a real regression does.
        if half_iter > full_iter * 1.15 {
            let (_, full2, _) = measure_full();
            let (_, half2, _, _) = measure_half(&cfg_serial);
            full_iter = full_iter.min(full2);
            half_iter = half_iter.min(half2);
        }
        if dt_parallel >= dt_legacy && cores >= 2 {
            dt_legacy = dt_legacy.min(measure_legacy());
            let (dtp2, _, _, _) = measure_half(&cfg_par);
            dt_parallel = dt_parallel.min(dtp2);
        }

        let row_iters = (n * iters) as f64;
        let qps = |dt: f64| row_iters / dt;
        let cache_ratio = half_cache_bytes as f64 / full_cache_bytes as f64;
        let iter_speedup = full_iter / half_iter;
        println!(
            "d={d:<5} k={k:<4} n={n:<5} iters={iters}  \
             legacy={:>9.0} row-it/s  full={:>9.0} ({:.2}x)  \
             serial={:>9.0} ({:.2}x)  parallel={:>9.0} ({:.2}x)",
            qps(dt_legacy),
            qps(dt_full),
            dt_legacy / dt_full,
            qps(dt_serial),
            dt_legacy / dt_serial,
            qps(dt_parallel),
            dt_legacy / dt_parallel,
        );
        println!(
            "        half vs full: cache {half_cache_bytes} B vs {full_cache_bytes} B \
             ({:.2}x), per-iter {:.1} ms vs {:.1} ms ({iter_speedup:.2}x)",
            cache_ratio,
            half_iter * 1e3,
            full_iter * 1e3,
        );

        // Memory is deterministic: the half layout must stay ≤ 0.55×.
        if cache_ratio > 0.55 {
            println!("WARNING: half-spectrum cache ratio {cache_ratio:.3} exceeds 0.55");
            assert!(!enforce, "cache_bytes regression (CBE_BENCH_ENFORCE=1)");
        }
        // Throughput: the half path must not be slower per iteration
        // than the full layout it replaced (target ≥ 1.3×; the 1.15
        // margin is noise headroom, not an accepted regression).
        if half_iter > full_iter * 1.15 {
            println!(
                "WARNING: half-spectrum per-iteration {:.1} ms slower than full-spectrum {:.1} ms",
                half_iter * 1e3,
                full_iter * 1e3
            );
            assert!(
                !enforce,
                "half-spectrum trainer regressed vs full (CBE_BENCH_ENFORCE=1)"
            );
        } else if iter_speedup < 1.3 {
            println!(
                "note: half-vs-full per-iteration speedup {iter_speedup:.2}x below the 1.3x target"
            );
        }
        if dt_parallel >= dt_legacy && cores >= 2 {
            println!(
                "WARNING: half-spectrum parallel trainer {:.1}% slower than legacy at d={d}",
                (dt_parallel / dt_legacy - 1.0) * 100.0
            );
            assert!(
                !enforce || dt_parallel <= dt_legacy * 1.25,
                "parallel trainer regressed vs the old per-row-FFT path (CBE_BENCH_ENFORCE=1)"
            );
        }

        for (mode, threads, dt, iter_avg, cache_bytes) in [
            ("legacy", 1usize, dt_legacy, per_iter(dt_legacy), 0usize),
            ("full", 1, dt_full, full_iter, full_cache_bytes),
            ("serial", 1, dt_serial, half_iter, half_cache_bytes),
            ("parallel", cores, dt_parallel, par_iter, half_cache_bytes),
        ] {
            results.push(Json::obj(vec![
                ("d", Json::num(d as f64)),
                ("k", Json::num(k as f64)),
                ("n", Json::num(n as f64)),
                ("iters", Json::num(iters as f64)),
                ("mode", Json::str(mode)),
                ("threads", Json::num(threads as f64)),
                ("train_s", Json::num(dt)),
                ("iter_s_avg", Json::num(iter_avg)),
                ("cache_bytes", Json::num(cache_bytes as f64)),
                ("row_iters_per_s", Json::num(qps(dt))),
                ("speedup_vs_legacy", Json::num(dt_legacy / dt)),
            ]));
        }
    }

    let doc = Json::obj(vec![
        ("cores", Json::num(cores as f64)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write("BENCH_train.json", format!("{doc}\n")).expect("write BENCH_train.json");
    println!("wrote BENCH_train.json");
}
