//! Bench: the persistence tier — snapshot save/load bandwidth, WAL
//! append and replay rates, and the claim that justifies the tier's
//! existence: loading a checksummed snapshot must beat re-encoding the
//! corpus and rebuilding the index from raw vectors. Emits
//! `BENCH_persist.json`.
//!
//! Arms, all over the same n×256-bit MIH index:
//! * `rebuild` — projection batch-encode of the raw vectors + index
//!   build (what a process without a snapshot has to do at startup);
//! * `save` — checksummed snapshot write (temp + fsync + rename);
//! * `load` — the same snapshot through both backings, heap
//!   (read+copy) and zero-copy mmap, each timed to *first query*
//!   (open + one search — the cold-start number the mapped tier exists
//!   to shrink), with the hit lists asserted identical;
//! * `crc` — the slicing-by-8 checksum kernel A/B'd against the
//!   byte-wise reference over the real snapshot bytes (the verify pass
//!   dominates a mapped load);
//! * `wal` — insert appends through the write-ahead log (fsync
//!   batched to the end, so the rate is the encode/append path, not the
//!   disk's fsync latency), then a reopen that replays every record.
//!
//! Env knobs:
//! * `CBE_BENCH_MAX_N=10000` shrinks the corpus (CI-sized machines);
//! * `CBE_BENCH_ENFORCE=1` hard-fails if load is not strictly faster
//!   than rebuild, or if the mapped load does not beat the heap load to
//!   first query (left off on shared runners; the recovery smoke turns
//!   it on because the gaps are structural, not a few percent).

use cbe::bits::BitCode;
use cbe::fft::Planner;
use cbe::index::persist::faults::FaultPlan;
use cbe::index::persist::{self, PersistOptions, PersistentIndex, SnapshotStamp};
use cbe::index::{build_index_with_ids, IndexBackend};
use cbe::projections::{CirculantProjection, ScratchPool};
use cbe::util::json::Json;
use cbe::util::rng::Pcg64;
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn dir_bytes(dir: &std::path::Path) -> u64 {
    std::fs::read_dir(dir)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter_map(|e| e.metadata().ok())
                .map(|m| m.len())
                .sum()
        })
        .unwrap_or(0)
}

fn main() {
    let n = 100_000usize.min(env_usize("CBE_BENCH_MAX_N", 100_000));
    let d = 256usize;
    let bits = 256usize;
    let dir = std::env::temp_dir().join(format!("cbe_bench_persist_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    println!("== persistence tier: snapshot load vs rebuild at n={n}, {bits} bits ==");

    let mut rng = Pcg64::new(0x9e51);
    let proj = CirculantProjection::random(d, &mut rng, Planner::new());
    let flat: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d)).collect();
    let rows: Vec<&[f32]> = flat.iter().map(|r| r.as_slice()).collect();

    // Rebuild arm: what startup costs without a snapshot. Warm the plan
    // caches and thread pool on a small slice first so the measured run
    // is the steady state, mirroring the encode bench.
    let mut pool = ScratchPool::new();
    let warm = 64.min(n);
    let mut warm_codes = BitCode::new(warm, bits);
    proj.encode_batch_into(&rows[..warm], bits, &mut warm_codes, &mut pool);
    let t0 = Instant::now();
    let mut codes = BitCode::new(n, bits);
    proj.encode_batch_into(&rows, bits, &mut codes, &mut pool);
    let index = build_index_with_ids(
        codes,
        (0..n as u32).collect(),
        &IndexBackend::Mih { m: None },
    );
    let rebuild_s = t0.elapsed().as_secs_f64();
    println!(
        "rebuild: encode+build {n} rows in {:.1} ms ({:.0} rows/s)",
        rebuild_s * 1e3,
        n as f64 / rebuild_s
    );

    // Save arm.
    let t0 = Instant::now();
    persist::save(&dir, &index, &SnapshotStamp::none()).expect("save snapshot");
    let save_s = t0.elapsed().as_secs_f64();
    let snapshot_bytes = dir_bytes(&dir);
    let mb = snapshot_bytes as f64 / (1 << 20) as f64;
    println!(
        "save:    {mb:.1} MiB in {:.1} ms ({:.0} MiB/s)",
        save_s * 1e3,
        mb / save_s
    );

    let enforce = std::env::var("CBE_BENCH_ENFORCE").is_ok_and(|v| v == "1");

    // Load arms: read + CRC-validate + reconstruct, through both
    // backings. The save above just wrote the file, so both arms run
    // against a warm page cache — the measured delta is the copy and
    // allocation the heap path pays, which is exactly the cost the
    // mapped path deletes. Each arm is timed to first query, and the
    // hit lists must match: the backing is invisible to results.
    let q: Vec<u64> = (0..bits / 64).map(|_| rng.next_u64()).collect();
    let t0 = Instant::now();
    let (heap_idx, heap_report) =
        persist::load_with_mode(&dir, persist::LoadMode::Heap).expect("heap load");
    let heap_load_s = t0.elapsed().as_secs_f64();
    let heap_hits = heap_idx.search(&q, 10);
    let heap_ttfq_s = t0.elapsed().as_secs_f64();
    assert_eq!(heap_idx.len(), n, "heap load dropped rows");
    assert_eq!(heap_report.path.name(), "heap");
    drop(heap_idx);
    println!(
        "load:    heap {mb:.1} MiB in {:.1} ms ({:.0} MiB/s); first query at {:.1} ms",
        heap_load_s * 1e3,
        mb / heap_load_s,
        heap_ttfq_s * 1e3
    );

    let t0 = Instant::now();
    let (loaded, mmap_report) =
        persist::load_with_mode(&dir, persist::LoadMode::Mmap).expect("mmap load");
    let load_s = t0.elapsed().as_secs_f64();
    let mmap_hits = loaded.search(&q, 10);
    let ttfq_s = t0.elapsed().as_secs_f64();
    assert_eq!(loaded.len(), n, "mmap load dropped rows");
    assert_eq!(mmap_hits, heap_hits, "hit lists differ between mmap and heap loads");
    let speedup = rebuild_s / load_s;
    println!(
        "load:    {} {mb:.1} MiB in {:.1} ms ({:.0} MiB/s, {} bytes mapped); \
         first query at {:.1} ms — {speedup:.1}x faster than rebuild, \
         {:.1}x faster than heap to first query",
        mmap_report.path.name(),
        load_s * 1e3,
        mb / load_s,
        mmap_report.mapped_bytes,
        ttfq_s * 1e3,
        heap_ttfq_s / ttfq_s
    );
    if load_s >= rebuild_s {
        println!(
            "WARNING: loading the snapshot was not faster than rebuilding \
             (load {:.1} ms vs rebuild {:.1} ms)",
            load_s * 1e3,
            rebuild_s * 1e3
        );
        assert!(!enforce, "snapshot load regressed vs rebuild (CBE_BENCH_ENFORCE=1)");
    }
    if mmap_report.path.name() == "mmap" && ttfq_s >= heap_ttfq_s {
        println!(
            "WARNING: mapped load did not beat heap to first query \
             ({:.1} ms vs {:.1} ms)",
            ttfq_s * 1e3,
            heap_ttfq_s * 1e3
        );
        assert!(!enforce, "mmap time-to-first-query regressed vs heap (CBE_BENCH_ENFORCE=1)");
    }

    // CRC A/B: the sliced kernel vs the byte-wise reference, over the
    // actual snapshot bytes it checksums in production.
    let snap_bytes = std::fs::read(dir.join("current.snap")).expect("read snapshot file");
    let t0 = Instant::now();
    let sliced = persist::crc32_sliced(std::hint::black_box(&snap_bytes));
    let crc_sliced_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let bytewise = persist::crc32_bytewise(std::hint::black_box(&snap_bytes));
    let crc_bytewise_s = t0.elapsed().as_secs_f64();
    assert_eq!(sliced, bytewise, "CRC kernels disagree");
    let smb = snap_bytes.len() as f64 / (1 << 20) as f64;
    println!(
        "crc:     slicing-by-8 {:.0} MiB/s vs byte-wise {:.0} MiB/s ({:.1}x)",
        smb / crc_sliced_s,
        smb / crc_bytewise_s,
        crc_bytewise_s / crc_sliced_s
    );

    // WAL arm: append churn through the log (fsync deferred to the final
    // flush so the measured rate is the append path), then replay it all
    // on a reopen.
    let wal_n = 20_000usize.min(n.max(1));
    let opts = PersistOptions {
        sync_on_append: false,
        compact_threshold: 0,
        faults: FaultPlan::none(),
        load_mode: persist::LoadMode::Auto,
    };
    let (mut pidx, _) = PersistentIndex::open(&dir, opts.clone()).expect("open for churn");
    let mut wal_rng = Pcg64::new(0x3a1);
    let churn: Vec<[u64; 4]> = (0..wal_n)
        .map(|_| {
            [
                wal_rng.next_u64(),
                wal_rng.next_u64(),
                wal_rng.next_u64(),
                wal_rng.next_u64(),
            ]
        })
        .collect();
    let t0 = Instant::now();
    for (i, code) in churn.iter().enumerate() {
        pidx.insert((n + i) as u32, code).expect("wal insert");
    }
    pidx.flush().expect("wal flush");
    let append_s = t0.elapsed().as_secs_f64();
    drop(pidx);
    println!(
        "wal:     {wal_n} appends in {:.1} ms ({:.0} appends/s, one deferred fsync)",
        append_s * 1e3,
        wal_n as f64 / append_s
    );
    let t0 = Instant::now();
    let (replayed, report) = PersistentIndex::open(&dir, opts).expect("replay wal");
    let replay_s = t0.elapsed().as_secs_f64();
    assert_eq!(report.wal_records_replayed, wal_n as u64, "replay lost records");
    assert_eq!(replayed.len(), n + wal_n);
    drop(replayed);
    println!(
        "replay:  {wal_n} records in {:.1} ms ({:.0} records/s, snapshot load included)",
        replay_s * 1e3,
        wal_n as f64 / replay_s
    );

    let doc = Json::obj(vec![
        ("n", Json::num(n as f64)),
        ("bits", Json::num(bits as f64)),
        ("backend", Json::str(index.backend_name())),
        ("snapshot_bytes", Json::num(snapshot_bytes as f64)),
        ("rebuild_s", Json::num(rebuild_s)),
        ("save_s", Json::num(save_s)),
        ("save_mib_s", Json::num(mb / save_s)),
        ("load_s", Json::num(load_s)),
        ("load_mib_s", Json::num(mb / load_s)),
        ("load_speedup_vs_rebuild", Json::num(speedup)),
        ("load_path", Json::str(mmap_report.path.name())),
        ("mapped_bytes", Json::num(mmap_report.mapped_bytes as f64)),
        ("load_heap_s", Json::num(heap_load_s)),
        ("ttfq_mmap_s", Json::num(ttfq_s)),
        ("ttfq_heap_s", Json::num(heap_ttfq_s)),
        ("ttfq_speedup_mmap_vs_heap", Json::num(heap_ttfq_s / ttfq_s)),
        ("crc_sliced_mib_s", Json::num(smb / crc_sliced_s)),
        ("crc_bytewise_mib_s", Json::num(smb / crc_bytewise_s)),
        ("wal_appends", Json::num(wal_n as f64)),
        ("wal_append_s", Json::num(append_s)),
        ("wal_appends_per_s", Json::num(wal_n as f64 / append_s)),
        ("wal_replay_s", Json::num(replay_s)),
        ("wal_replays_per_s", Json::num(wal_n as f64 / replay_s)),
    ]);
    std::fs::write("BENCH_persist.json", format!("{doc}\n")).expect("write BENCH_persist.json");
    println!("wrote BENCH_persist.json");
    let _ = std::fs::remove_dir_all(&dir);
}
