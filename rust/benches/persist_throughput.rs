//! Bench: the persistence tier — snapshot save/load bandwidth, WAL
//! append and replay rates, and the claim that justifies the tier's
//! existence: loading a checksummed snapshot must beat re-encoding the
//! corpus and rebuilding the index from raw vectors. Emits
//! `BENCH_persist.json`.
//!
//! Arms, all over the same n×256-bit MIH index:
//! * `rebuild` — projection batch-encode of the raw vectors + index
//!   build (what a process without a snapshot has to do at startup);
//! * `save` — checksummed snapshot write (temp + fsync + rename);
//! * `load` — snapshot read, CRC validation, and index reconstruction;
//! * `wal` — insert appends through the write-ahead log (fsync
//!   batched to the end, so the rate is the encode/append path, not the
//!   disk's fsync latency), then a reopen that replays every record.
//!
//! Env knobs:
//! * `CBE_BENCH_MAX_N=10000` shrinks the corpus (CI-sized machines);
//! * `CBE_BENCH_ENFORCE=1` hard-fails if load is not strictly faster
//!   than rebuild (left off on shared runners; the recovery smoke turns
//!   it on because the gap is an order of magnitude, not a few percent).

use cbe::bits::BitCode;
use cbe::fft::Planner;
use cbe::index::persist::faults::FaultPlan;
use cbe::index::persist::{self, PersistOptions, PersistentIndex, SnapshotStamp};
use cbe::index::{build_index_with_ids, IndexBackend};
use cbe::projections::{CirculantProjection, ScratchPool};
use cbe::util::json::Json;
use cbe::util::rng::Pcg64;
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn dir_bytes(dir: &std::path::Path) -> u64 {
    std::fs::read_dir(dir)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter_map(|e| e.metadata().ok())
                .map(|m| m.len())
                .sum()
        })
        .unwrap_or(0)
}

fn main() {
    let n = 100_000usize.min(env_usize("CBE_BENCH_MAX_N", 100_000));
    let d = 256usize;
    let bits = 256usize;
    let dir = std::env::temp_dir().join(format!("cbe_bench_persist_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    println!("== persistence tier: snapshot load vs rebuild at n={n}, {bits} bits ==");

    let mut rng = Pcg64::new(0x9e51);
    let proj = CirculantProjection::random(d, &mut rng, Planner::new());
    let flat: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d)).collect();
    let rows: Vec<&[f32]> = flat.iter().map(|r| r.as_slice()).collect();

    // Rebuild arm: what startup costs without a snapshot. Warm the plan
    // caches and thread pool on a small slice first so the measured run
    // is the steady state, mirroring the encode bench.
    let mut pool = ScratchPool::new();
    let warm = 64.min(n);
    let mut warm_codes = BitCode::new(warm, bits);
    proj.encode_batch_into(&rows[..warm], bits, &mut warm_codes, &mut pool);
    let t0 = Instant::now();
    let mut codes = BitCode::new(n, bits);
    proj.encode_batch_into(&rows, bits, &mut codes, &mut pool);
    let index = build_index_with_ids(
        codes,
        (0..n as u32).collect(),
        &IndexBackend::Mih { m: None },
    );
    let rebuild_s = t0.elapsed().as_secs_f64();
    println!(
        "rebuild: encode+build {n} rows in {:.1} ms ({:.0} rows/s)",
        rebuild_s * 1e3,
        n as f64 / rebuild_s
    );

    // Save arm.
    let t0 = Instant::now();
    persist::save(&dir, &index, &SnapshotStamp::none()).expect("save snapshot");
    let save_s = t0.elapsed().as_secs_f64();
    let snapshot_bytes = dir_bytes(&dir);
    let mb = snapshot_bytes as f64 / (1 << 20) as f64;
    println!(
        "save:    {mb:.1} MiB in {:.1} ms ({:.0} MiB/s)",
        save_s * 1e3,
        mb / save_s
    );

    // Load arm: read + CRC-validate + reconstruct.
    let t0 = Instant::now();
    let (loaded, _report) = persist::load(&dir).expect("load snapshot");
    let load_s = t0.elapsed().as_secs_f64();
    assert_eq!(loaded.len(), n, "load dropped rows");
    let speedup = rebuild_s / load_s;
    println!(
        "load:    {mb:.1} MiB in {:.1} ms ({:.0} MiB/s) — {speedup:.1}x faster than rebuild",
        load_s * 1e3,
        mb / load_s
    );
    if load_s >= rebuild_s {
        println!(
            "WARNING: loading the snapshot was not faster than rebuilding \
             (load {:.1} ms vs rebuild {:.1} ms)",
            load_s * 1e3,
            rebuild_s * 1e3
        );
        let enforce = std::env::var("CBE_BENCH_ENFORCE").is_ok_and(|v| v == "1");
        assert!(!enforce, "snapshot load regressed vs rebuild (CBE_BENCH_ENFORCE=1)");
    }

    // WAL arm: append churn through the log (fsync deferred to the final
    // flush so the measured rate is the append path), then replay it all
    // on a reopen.
    let wal_n = 20_000usize.min(n.max(1));
    let opts = PersistOptions {
        sync_on_append: false,
        compact_threshold: 0,
        faults: FaultPlan::none(),
    };
    let (mut pidx, _) = PersistentIndex::open(&dir, opts.clone()).expect("open for churn");
    let mut wal_rng = Pcg64::new(0x3a1);
    let churn: Vec<[u64; 4]> = (0..wal_n)
        .map(|_| {
            [
                wal_rng.next_u64(),
                wal_rng.next_u64(),
                wal_rng.next_u64(),
                wal_rng.next_u64(),
            ]
        })
        .collect();
    let t0 = Instant::now();
    for (i, code) in churn.iter().enumerate() {
        pidx.insert((n + i) as u32, code).expect("wal insert");
    }
    pidx.flush().expect("wal flush");
    let append_s = t0.elapsed().as_secs_f64();
    drop(pidx);
    println!(
        "wal:     {wal_n} appends in {:.1} ms ({:.0} appends/s, one deferred fsync)",
        append_s * 1e3,
        wal_n as f64 / append_s
    );
    let t0 = Instant::now();
    let (replayed, report) = PersistentIndex::open(&dir, opts).expect("replay wal");
    let replay_s = t0.elapsed().as_secs_f64();
    assert_eq!(report.wal_records_replayed, wal_n as u64, "replay lost records");
    assert_eq!(replayed.len(), n + wal_n);
    drop(replayed);
    println!(
        "replay:  {wal_n} records in {:.1} ms ({:.0} records/s, snapshot load included)",
        replay_s * 1e3,
        wal_n as f64 / replay_s
    );

    let doc = Json::obj(vec![
        ("n", Json::num(n as f64)),
        ("bits", Json::num(bits as f64)),
        ("backend", Json::str(index.backend_name())),
        ("snapshot_bytes", Json::num(snapshot_bytes as f64)),
        ("rebuild_s", Json::num(rebuild_s)),
        ("save_s", Json::num(save_s)),
        ("save_mib_s", Json::num(mb / save_s)),
        ("load_s", Json::num(load_s)),
        ("load_mib_s", Json::num(mb / load_s)),
        ("load_speedup_vs_rebuild", Json::num(speedup)),
        ("wal_appends", Json::num(wal_n as f64)),
        ("wal_append_s", Json::num(append_s)),
        ("wal_appends_per_s", Json::num(wal_n as f64 / append_s)),
        ("wal_replay_s", Json::num(replay_s)),
        ("wal_replays_per_s", Json::num(wal_n as f64 / replay_s)),
    ]);
    std::fs::write("BENCH_persist.json", format!("{doc}\n")).expect("write BENCH_persist.json");
    println!("wrote BENCH_persist.json");
    let _ = std::fs::remove_dir_all(&dir);
}
