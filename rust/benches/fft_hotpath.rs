//! Perf-pass microbench: the circulant encode hot path (L3's dominant
//! cost). Reports ms/encode for power-of-two (radix-2) and paper-native
//! (25600, Bluestein) sizes, through the allocation-free scratch API.
//! Used for the EXPERIMENTS.md §Perf log. Batch-vs-serial throughput
//! lives in `encode_throughput`.

use cbe::bench::Bench;
use cbe::fft::Planner;
use cbe::projections::{CirculantProjection, EncodeScratch};
use cbe::util::rng::Pcg64;

fn main() {
    let planner = Planner::new();
    let mut rng = Pcg64::new(1);
    let mut bench = Bench::new(3, 15);
    let mut scratch = EncodeScratch::new();
    for d in [4096usize, 65536, 25600] {
        let proj = CirculantProjection::random(d, &mut rng, planner.clone());
        let x = rng.normal_vec(d);
        let mut out = vec![0f32; 256];
        proj.encode_into(&x, &mut out, &mut scratch); // warm plan cache
        bench.run(&format!("encode d={d}"), || {
            proj.encode_into(std::hint::black_box(&x), &mut out, &mut scratch);
            std::hint::black_box(&out);
        });
    }
    println!("{}", bench.report("fft hot path"));
}
