//! Perf-pass microbench: the circulant encode hot path (L3's dominant
//! cost). Reports ms/encode for power-of-two (radix-2) and paper-native
//! (25600, Bluestein) sizes. Used for the EXPERIMENTS.md §Perf log.

use cbe::bench::Bench;
use cbe::fft::Planner;
use cbe::projections::CirculantProjection;
use cbe::util::rng::Pcg64;

fn main() {
    let planner = Planner::new();
    let mut rng = Pcg64::new(1);
    let mut bench = Bench::new(3, 15);
    for d in [4096usize, 65536, 25600] {
        let proj = CirculantProjection::random(d, &mut rng, planner.clone());
        let x = rng.normal_vec(d);
        let _ = proj.project(&x); // warm plan cache
        bench.run(&format!("encode d={d}"), || {
            std::hint::black_box(proj.encode(std::hint::black_box(&x), 256));
        });
    }
    println!("{}", bench.report("fft hot path"));
}
