//! Bench: Figure 5 — low-dimensional comparison vs ITQ/SH/SKLSH/AQBC.

use cbe::experiments::fig5_lowdim::{run, Fig5Config};

fn main() {
    let full = std::env::var("CBE_BENCH_FULL").is_ok();
    let mut cfg = Fig5Config::quick(if full { 2048 } else { 512 });
    if full {
        cfg.n = 10_000;
        cfg.n_train = 1_000;
        cfg.n_queries = 200;
        cfg.bits = vec![64, 128, 256, 512];
    }
    let r = run(&cfg);
    println!("{}", r.report);
}
