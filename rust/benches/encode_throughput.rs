//! Bench: the parallel batch-encode engine vs the serial per-vector
//! path — encode QPS at d ∈ {256, 1024, 25600} (two radix-2 sizes plus
//! the paper's non-power-of-two Bluestein dimension), 1 thread vs all
//! cores. Emits `BENCH_encode.json`.
//!
//! The serial arm is the honest hot-loop baseline: `encode_into` with a
//! reused [`EncodeScratch`] + `set_row_from_signs` (no per-call
//! allocation), not the allocating convenience wrappers. The batch arm
//! is `encode_batch_into` (scoped-thread fan-out, direct sign→bit
//! packing). Both arms must produce identical packed codes or the bench
//! aborts — the speedup is only meaningful if the outputs agree.
//!
//! When the SIMD gate can open ([`cbe::simd::available`]) a third pair of
//! arms A/Bs the kernel layer itself: the batch engine with the AVX2
//! kernels forced off vs on (`mode` = `batch-scalar` / `batch-simd`,
//! interleaved best-of-3 rounds, packed codes asserted identical — the
//! kernels are bit-exact, so any divergence is a bug, not noise).
//!
//! Env knobs, mirroring `coordinator_throughput`:
//! * `CBE_BENCH_MAX_D=1024` caps the dim sweep (CI-sized machines);
//! * `CBE_BENCH_ENCODE_ROWS=64` overrides rows per measured round;
//! * `CBE_BENCH_ENFORCE=1` turns the batch-slower-than-serial warning
//!   into a hard failure, and likewise simd-slower-than-scalar (left off
//!   in CI: shared runners are too noisy for perf asserts). It also arms
//!   the projection-variant gates: stacked k=2d must encode in < 2.2× the
//!   k=d circulant time (two blocks ≈ two FFTs, the rest is shared), and
//!   downsampled k=d/4 must beat the full circulant (it prunes the
//!   binarization, never adds work).

use cbe::bits::BitCode;
use cbe::fft::Planner;
use cbe::projections::{CbeModel, CirculantProjection, EncodeScratch, ProjectionSpec, ScratchPool};
use cbe::util::json::Json;
use cbe::util::rng::Pcg64;
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let k = 256usize;
    let max_d = env_usize("CBE_BENCH_MAX_D", 25_600);
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    println!("== encode engine: serial per-vector vs batch-parallel ({cores} cores) ==");

    let mut results: Vec<Json> = Vec::new();
    for d in [256usize, 1024, 25_600] {
        if d > max_d {
            println!("d={d}: skipped (CBE_BENCH_MAX_D={max_d})");
            continue;
        }
        let default_rows = if d >= 25_600 { 64 } else { 1024 };
        let n = env_usize("CBE_BENCH_ENCODE_ROWS", default_rows);
        let k_eff = k.min(d);
        let mut rng = Pcg64::new(0xe2c + d as u64);
        let proj = CirculantProjection::random(d, &mut rng, Planner::new());
        let flat: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d)).collect();
        let rows: Vec<&[f32]> = flat.iter().map(|r| r.as_slice()).collect();

        // Serial arm: one thread, reused scratch, ±1 signs intermediate
        // then pack — the per-vector serving path before this engine.
        let mut serial_codes = BitCode::new(n, k_eff);
        let mut scratch = EncodeScratch::new();
        let mut signs = vec![0f32; k_eff];
        proj.encode_into(rows[0], &mut signs, &mut scratch); // warm plans
        let t0 = Instant::now();
        for (i, row) in rows.iter().enumerate() {
            proj.encode_into(row, &mut signs, &mut scratch);
            serial_codes.set_row_from_signs(i, &signs);
        }
        let dt_serial = t0.elapsed().as_secs_f64();
        let serial_qps = n as f64 / dt_serial;

        // Batch arm: all cores, warm round first (pool + plan caches).
        let mut batch_codes = BitCode::new(n, k_eff);
        let mut pool = ScratchPool::new();
        proj.encode_batch_into(&rows, k_eff, &mut batch_codes, &mut pool);
        let t0 = Instant::now();
        proj.encode_batch_into(&rows, k_eff, &mut batch_codes, &mut pool);
        let dt_batch = t0.elapsed().as_secs_f64();
        let batch_qps = n as f64 / dt_batch;

        assert_eq!(
            batch_codes,
            serial_codes,
            "batch path diverged from per-vector at d={d}"
        );

        let speedup = batch_qps / serial_qps;
        println!(
            "d={d:<6} k={k_eff:<4} rows={n:<5} serial={serial_qps:>9.0} qps  \
             batch={batch_qps:>9.0} qps  speedup={speedup:>5.2}x"
        );
        if speedup < 1.0 && cores >= 2 {
            println!(
                "WARNING: batch path {:.1}% slower than serial at d={d}",
                (1.0 - speedup) * 100.0
            );
            let enforce = std::env::var("CBE_BENCH_ENFORCE").is_ok_and(|v| v == "1");
            assert!(
                !enforce,
                "batch encode regressed vs serial (CBE_BENCH_ENFORCE=1)"
            );
        }

        let mut arms = vec![
            ("serial", 1usize, serial_qps, dt_serial),
            ("batch", cores, batch_qps, dt_batch),
        ];

        // Kernel A/B: the same batch engine with the AVX2 kernels forced
        // off vs on. Interleaved best-of-3 so drift hits both arms alike;
        // packed codes must be identical (bit-exact contract).
        if cbe::simd::available() {
            let mut scalar_codes = BitCode::new(n, k_eff);
            let mut simd_codes = BitCode::new(n, k_eff);
            let mut best = [f64::INFINITY; 2];
            for _ in 0..3 {
                cbe::simd::set_enabled(false);
                let t0 = Instant::now();
                proj.encode_batch_into(&rows, k_eff, &mut scalar_codes, &mut pool);
                best[0] = best[0].min(t0.elapsed().as_secs_f64());
                cbe::simd::set_enabled(true);
                let t0 = Instant::now();
                proj.encode_batch_into(&rows, k_eff, &mut simd_codes, &mut pool);
                best[1] = best[1].min(t0.elapsed().as_secs_f64());
            }
            // Restore whatever the environment asked for before the
            // forced A/B (mirrors the obs bench's env restore).
            let env_on = !matches!(
                std::env::var("CBE_SIMD").ok().as_deref(),
                Some("0") | Some("false") | Some("off")
            );
            cbe::simd::set_enabled(env_on);
            assert_eq!(
                simd_codes, scalar_codes,
                "simd batch codes diverged from scalar at d={d}"
            );
            let (scalar_qps, simd_qps) = (n as f64 / best[0], n as f64 / best[1]);
            println!(
                "d={d:<6} kernel A/B: scalar={scalar_qps:>9.0} qps  \
                 simd={simd_qps:>9.0} qps  ratio={:>5.2}x",
                simd_qps / scalar_qps
            );
            if simd_qps < scalar_qps {
                println!(
                    "WARNING: simd kernels {:.1}% slower than scalar at d={d}",
                    (1.0 - simd_qps / scalar_qps) * 100.0
                );
                let enforce = std::env::var("CBE_BENCH_ENFORCE").is_ok_and(|v| v == "1");
                assert!(
                    !enforce,
                    "simd encode regressed vs scalar (CBE_BENCH_ENFORCE=1)"
                );
            }
            arms.push(("batch-scalar", cores, scalar_qps, best[0]));
            arms.push(("batch-simd", cores, simd_qps, best[1]));
        }

        for (mode, threads, qps, batch_s) in arms {
            results.push(Json::obj(vec![
                ("d", Json::num(d as f64)),
                ("k", Json::num(k_eff as f64)),
                ("rows", Json::num(n as f64)),
                ("mode", Json::str(mode)),
                ("threads", Json::num(threads as f64)),
                ("batch_s", Json::num(batch_s)),
                ("qps", Json::num(qps)),
                ("speedup_vs_serial", Json::num(qps / serial_qps)),
            ]));
        }
    }

    // ---- projection-variant arms: stacked k=2d and downsampled k=d/4 ----
    // One mid-size dimension (CI friendly); best-of-3 per arm so the
    // ratio gates compare like with like.
    {
        let d = 1024usize.min(max_d).max(64);
        let n = env_usize("CBE_BENCH_ENCODE_ROWS", 512);
        let mut rng = Pcg64::new(0xface);
        let flat: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d)).collect();
        let rows: Vec<&[f32]> = flat.iter().map(|r| r.as_slice()).collect();
        let arms: [(&str, ProjectionSpec, usize); 3] = [
            ("variant-circ", ProjectionSpec::Circ, d),
            ("variant-stacked-2d", ProjectionSpec::Stacked { blocks: Some(2) }, 2 * d),
            ("variant-downsampled-d4", ProjectionSpec::Downsampled, d / 4),
        ];
        let mut timings = Vec::new();
        for (mode, spec, k) in arms {
            let model = CbeModel::random(&spec, d, k, 0xe2c, Planner::new())
                .expect("variant arm shapes are valid");
            let mut codes = BitCode::new(n, k);
            let mut pool = ScratchPool::new();
            model.encode_batch_into(&rows, k, &mut codes, &mut pool); // warm
            let mut best = f64::INFINITY;
            for _ in 0..3 {
                let t0 = Instant::now();
                model.encode_batch_into(&rows, k, &mut codes, &mut pool);
                best = best.min(t0.elapsed().as_secs_f64());
            }
            let qps = n as f64 / best;
            println!("d={d:<6} k={k:<4} mode={mode:<22} {qps:>9.0} qps");
            results.push(Json::obj(vec![
                ("d", Json::num(d as f64)),
                ("k", Json::num(k as f64)),
                ("rows", Json::num(n as f64)),
                ("mode", Json::str(mode)),
                ("threads", Json::num(cores as f64)),
                ("batch_s", Json::num(best)),
                ("qps", Json::num(qps)),
            ]));
            timings.push(best);
        }
        let (circ_s, stacked_s, ds_s) = (timings[0], timings[1], timings[2]);
        println!(
            "variants: stacked-2d/circ={:.2}x (gate < 2.2x), downsampled/circ={:.2}x (gate <= 1x)",
            stacked_s / circ_s,
            ds_s / circ_s
        );
        if std::env::var("CBE_BENCH_ENFORCE").is_ok_and(|v| v == "1") {
            assert!(
                stacked_s < 2.2 * circ_s,
                "stacked k=2d encode took {:.2}x the k=d circulant (gate 2.2x)",
                stacked_s / circ_s
            );
            assert!(
                ds_s <= circ_s,
                "downsampled k=d/4 ({ds_s:.4}s) should beat the full circulant ({circ_s:.4}s)"
            );
        }
    }

    let doc = Json::obj(vec![
        ("cores", Json::num(cores as f64)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write("BENCH_encode.json", format!("{doc}\n")).expect("write BENCH_encode.json");
    println!("wrote BENCH_encode.json");
}
