//! Bench: Table 3 — classification accuracy on binary-coded features.

use cbe::experiments::table3_classify::{run, Table3Config};

fn main() {
    let full = std::env::var("CBE_BENCH_FULL").is_ok();
    let mut cfg = Table3Config::quick(if full { 2560 } else { 256 });
    if full {
        cfg.classes = 50;
        cfg.per_class_train = 100;
        cfg.per_class_test = 50;
    }
    let r = run(&cfg);
    println!("{}", r.report);
}
