//! Bench: ablations — λ robustness, iteration count, sign-flip diagonal.

fn main() {
    let full = std::env::var("CBE_BENCH_FULL").is_ok();
    let r = cbe::experiments::ablations::run(if full { 2048 } else { 256 }, 5);
    println!("{}", r.report);
}
