//! Bench: §6 — semi-supervised CBE AUC delta.

use cbe::experiments::semi_supervised::{run, Sec6Config};

fn main() {
    let full = std::env::var("CBE_BENCH_FULL").is_ok();
    let mut cfg = Sec6Config::quick(if full { 2560 } else { 256 });
    if full {
        cfg.n = 10_000;
        cfg.n_train = 1_000;
        cfg.n_pairs = 2_000;
    }
    let r = run(&cfg);
    println!("{}", r.report);
}
