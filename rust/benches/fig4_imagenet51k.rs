//! Bench: Figure 4 — recall on synth-ImageNet-51200 analogue (d doubled
//! relative to fig3; non-power-of-two to exercise the Bluestein path).

use cbe::experiments::recall_sweep::{run, Corpus, SweepConfig};

fn main() {
    let full = std::env::var("CBE_BENCH_FULL").is_ok();
    let cfg = SweepConfig::quick(Corpus::ImageNet, if full { 51200 } else { 2560 });
    let r = run(&cfg);
    println!("{}", r.report);
}
