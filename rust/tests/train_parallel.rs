//! Integration properties of the half-spectrum-cached parallel trainer:
//!
//! * **Determinism** — under `TimeFreqConfig::deterministic`, parallel
//!   training is bit-for-bit identical to the serial path (threads = 1)
//!   for every shape class the optimizer special-cases: even d (Nyquist
//!   bin), odd d (full-size fallback, no Nyquist), k < d (zeroed B
//!   columns), and §6 semi-supervised pairs.
//! * **Half-spectrum fidelity** — models trained by the half-spectrum
//!   engine emit *identical binary codes* to the full-spectrum
//!   `opt::timefreq::reference` oracle on a held-out probe set, at 1, 4
//!   and 8 threads (the engines differ in FFT rounding, so r agrees to
//!   ulps, but the codes — the product the serving path ships — must
//!   not move).
//! * **Memory budget** — a `cache_budget` small enough to force tiling
//!   changes resident memory, not one output bit.
//! * **Monotone objective** — the per-iteration trace still descends
//!   (from iteration 1; trace[0] mixes the random init's binarization
//!   error) when training runs parallel.
//! * **Cache correctness** — `objective` reading the shared
//!   [`SpectrumCache`] equals the old per-row-re-FFT evaluation on the
//!   same r.

use cbe::encoders::CbeTrainer;
use cbe::fft::Planner;
use cbe::linalg::Mat;
use cbe::opt::timefreq::{reference, DETERMINISTIC_BLOCK};
use cbe::opt::{PairSet, SpectrumCache, TimeFreqConfig, TimeFreqOptimizer};
use cbe::projections::CirculantProjection;
use cbe::proptest_lite::forall;
use cbe::util::rng::Pcg64;

fn make_data(n: usize, d: usize, rng: &mut Pcg64) -> Mat {
    let mut x = Mat::randn(n, d, rng);
    for i in 0..n {
        cbe::util::l2_normalize(x.row_mut(i));
    }
    x
}

fn make_pairs(n: usize, count: usize, rng: &mut Pcg64) -> PairSet {
    let mut ps = PairSet::default();
    for t in 0..count {
        let i = rng.below(n as u64) as usize;
        let j = (i + 1 + rng.below((n - 1) as u64) as usize) % n;
        if t % 2 == 0 {
            ps.similar.push((i, j));
        } else {
            ps.dissimilar.push((i, j));
        }
    }
    ps
}

/// Train twice — serial and at `threads` workers — and require bitwise
/// identical learned r and objective trace.
fn assert_parity(
    d: usize,
    k: usize,
    n: usize,
    threads: usize,
    pairs: Option<&PairSet>,
    seed: u64,
) {
    let mut rng = Pcg64::new(seed);
    let x = make_data(n, d, &mut rng);
    let r0 = rng.normal_vec(d);
    let planner = Planner::new();

    let mut cfg = TimeFreqConfig::new(k);
    cfg.iters = 3;
    cfg.mu = if pairs.is_some() { 0.7 } else { 0.0 };
    cfg.deterministic = true;

    cfg.threads = 1;
    let mut serial = TimeFreqOptimizer::new(d, cfg.clone(), planner.clone());
    let r_serial = serial.run(&x, &r0, pairs);

    cfg.threads = threads;
    let mut parallel = TimeFreqOptimizer::new(d, cfg, planner);
    let r_parallel = parallel.run(&x, &r0, pairs);

    // The report records the fan-out actually used: one worker per
    // reduction block at most.
    let nblocks = n.div_ceil(DETERMINISTIC_BLOCK).max(1);
    assert_eq!(parallel.report.threads, threads.min(nblocks));
    for (i, (a, b)) in r_parallel.iter().zip(&r_serial).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "d={d} k={k} n={n} threads={threads}: r[{i}] {a} != {b}"
        );
    }
    for (a, b) in parallel
        .objective_trace
        .iter()
        .zip(&serial.objective_trace)
    {
        assert_eq!(a.to_bits(), b.to_bits(), "trace diverged");
    }
}

/// Train with the half-spectrum engine at `threads` workers and with the
/// full-spectrum reference oracle; the two learned models must emit
/// identical k-bit codes on a held-out probe set.
fn assert_codes_match_reference(
    d: usize,
    k: usize,
    n: usize,
    threads: usize,
    pairs: Option<&PairSet>,
    seed: u64,
) {
    let mut rng = Pcg64::new(seed);
    let x = make_data(n, d, &mut rng);
    let r0 = rng.normal_vec(d);
    let probe: Vec<Vec<f32>> = (0..12).map(|_| rng.normal_vec(d)).collect();
    let planner = Planner::new();

    let mut cfg = TimeFreqConfig::new(k);
    cfg.iters = 3;
    cfg.mu = if pairs.is_some() { 0.7 } else { 0.0 };
    cfg.deterministic = true;

    let (r_ref, _) = reference::run(&planner, d, &cfg, &x, &r0, pairs);
    cfg.threads = threads;
    let mut opt = TimeFreqOptimizer::new(d, cfg, planner.clone());
    let r_half = opt.run(&x, &r0, pairs);

    let signs = vec![1f32; d];
    let p_ref = CirculantProjection::new(r_ref, signs.clone(), planner.clone());
    let p_half = CirculantProjection::new(r_half, signs, planner);
    for (t, q) in probe.iter().enumerate() {
        assert_eq!(
            p_half.encode(q, k),
            p_ref.encode(q, k),
            "d={d} k={k} n={n} threads={threads} probe {t}"
        );
    }
}

#[test]
fn parallel_equals_serial_even_d() {
    assert_parity(32, 32, 170, 4, None, 1);
}

#[test]
fn parallel_equals_serial_odd_d() {
    assert_parity(27, 27, 150, 4, None, 2);
}

#[test]
fn parallel_equals_serial_k_less_than_d() {
    assert_parity(30, 9, 160, 4, None, 3);
}

#[test]
fn parallel_equals_serial_semi_supervised() {
    let mut rng = Pcg64::new(4);
    let n = 140;
    let pairs = make_pairs(n, 60, &mut rng);
    assert_parity(24, 24, n, 4, Some(&pairs), 5);
}

#[test]
fn parallel_equals_serial_property_sweep() {
    // Random shapes, random thread counts — including thread counts that
    // don't divide the block count and exceed the row count.
    forall("parallel trainer ≡ serial trainer", 12, |g| {
        let d = g.usize_in(4, 40);
        let k = g.usize_in(1, d);
        let n = g.usize_in(2, 200);
        let threads = g.usize_in(2, 8);
        assert_parity(d, k, n, threads, None, 1000 + n as u64);
    });
}

#[test]
fn half_spectrum_codes_match_reference_even_d() {
    for threads in [1usize, 4, 8] {
        assert_codes_match_reference(32, 32, 120, threads, None, 40 + threads as u64);
    }
}

#[test]
fn half_spectrum_codes_match_reference_odd_d() {
    for threads in [1usize, 4, 8] {
        assert_codes_match_reference(27, 27, 110, threads, None, 50 + threads as u64);
    }
}

#[test]
fn half_spectrum_codes_match_reference_k_less_than_d() {
    for threads in [1usize, 4, 8] {
        assert_codes_match_reference(30, 9, 130, threads, None, 60 + threads as u64);
    }
}

#[test]
fn half_spectrum_codes_match_reference_semi_supervised() {
    let mut rng = Pcg64::new(70);
    let n = 120;
    let pairs = make_pairs(n, 40, &mut rng);
    for threads in [1usize, 4, 8] {
        assert_codes_match_reference(24, 24, n, threads, Some(&pairs), 71 + threads as u64);
    }
}

#[test]
fn budget_tiled_training_matches_cached_end_to_end() {
    // The CbeTrainer pipeline under a memory budget small enough to
    // force tiling must produce the same model — same r bits, same
    // probe codes — as the unbounded run, at any thread count.
    let d = 26;
    let n = 180; // several DETERMINISTIC_BLOCK tiles
    let mut rng = Pcg64::new(81);
    let x = make_data(n, d, &mut rng);
    let probe: Vec<Vec<f32>> = (0..10).map(|_| rng.normal_vec(d)).collect();

    let mut cfg = TimeFreqConfig::new(d);
    cfg.iters = 3;
    cfg.threads = 4;
    let full = CbeTrainer::new(cfg.clone()).seed(9).train(&x);
    assert_eq!(full.report.tile_rows, 0);

    cfg.cache_budget = 80 * (d / 2 + 1) * 16; // fits ~80 of the 180 rows
    let tiled = CbeTrainer::new(cfg).seed(9).train(&x);
    assert_eq!(tiled.report.tile_rows, DETERMINISTIC_BLOCK);
    assert!(tiled.report.cache_bytes < full.report.cache_bytes);
    assert!(tiled.report.cache_bytes <= 80 * (d / 2 + 1) * 16);

    let full_p = full.model.as_circulant().unwrap();
    let tiled_p = tiled.model.as_circulant().unwrap();
    for (a, b) in full_p.r.iter().zip(&tiled_p.r) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    for p in &probe {
        assert_eq!(full_p.encode(p, d), tiled_p.encode(p, d));
    }
}

#[test]
fn parallel_objective_stays_monotone() {
    let d = 30;
    let n = 180;
    let mut rng = Pcg64::new(6);
    let x = make_data(n, d, &mut rng);
    let r0 = rng.normal_vec(d);
    let mut cfg = TimeFreqConfig::new(d);
    cfg.iters = 8;
    cfg.threads = 4;
    let planner = Planner::new();
    let mut opt = TimeFreqOptimizer::new(d, cfg, planner.clone());
    let cache = SpectrumCache::build(&x, &planner, 4);
    let o0 = opt.objective(&cache, &r0);
    let r = opt.run_cached(&cache, &r0, None);
    assert!(opt.objective(&cache, &r) < o0);
    for w in opt.objective_trace[1..].windows(2) {
        assert!(w[1] <= w[0] + 1e-6, "trace not monotone: {w:?}");
    }
}

#[test]
fn cached_objective_equals_old_path_property() {
    forall("cache objective ≡ per-row-FFT objective", 15, |g| {
        let d = g.usize_in(2, 48);
        let k = g.usize_in(1, d);
        let n = g.usize_in(1, 120);
        let x = make_data(n, d, g.rng());
        let r = g.normal_vec(d);
        let planner = Planner::new();
        let cfg = TimeFreqConfig::new(k);
        let opt = TimeFreqOptimizer::new(d, cfg.clone(), planner.clone());
        let cache = SpectrumCache::build(&x, &planner, 3);
        let cached = opt.objective(&cache, &r);
        let legacy = reference::objective(&planner, d, &cfg, &x, &r);
        assert!(
            (cached - legacy).abs() <= 1e-9 * legacy.abs().max(1.0),
            "d={d} k={k} n={n}: {cached} vs {legacy}"
        );
    });
}

#[test]
fn trained_encoder_is_thread_count_invariant_end_to_end() {
    // The whole CbeTrainer pipeline (sign flips, init, training, model
    // build) must give the same *codes* whether it trained serial or
    // parallel.
    let d = 28;
    let n = 130;
    let mut rng = Pcg64::new(7);
    let x = make_data(n, d, &mut rng);
    let probe: Vec<Vec<f32>> = (0..10).map(|_| rng.normal_vec(d)).collect();

    let mut cfg = TimeFreqConfig::new(d);
    cfg.iters = 3;
    cfg.deterministic = true;
    cfg.threads = 1;
    let serial = CbeTrainer::new(cfg.clone()).seed(9).train(&x);
    cfg.threads = 4;
    let parallel = CbeTrainer::new(cfg).seed(9).train(&x);

    let serial_p = serial.model.as_circulant().unwrap();
    let parallel_p = parallel.model.as_circulant().unwrap();
    for p in &probe {
        assert_eq!(serial_p.encode(p, d), parallel_p.encode(p, d));
    }
    assert_eq!(
        serial.report.objective_trace,
        parallel.report.objective_trace
    );
}
