//! Property tests: the MIH subsystem is *exact* — [`MihIndex`] (both
//! substring schemes) and [`ShardedIndex`] return hit-for-hit the same
//! results as the linear-scan [`BinaryIndex`] on random corpora, including
//! distance ties, k > n, empty corpora, and after interleaved
//! insert/remove churn against the arena-backed bucket store.

use cbe::bits::{BinaryIndex, BitCode};
use cbe::index::{MihIndex, ShardedIndex};
use cbe::proptest_lite::{forall, Gen};

fn random_codes(g: &mut Gen, n: usize, bits: usize) -> BitCode {
    BitCode::from_signs(&g.sign_vec(n * bits), n, bits)
}

#[test]
fn prop_mih_matches_linear_scan() {
    forall("MihIndex == BinaryIndex on random corpora", 60, |g| {
        let bits = g.usize_in(2, 200);
        let n = g.usize_in(0, 250);
        let db = random_codes(g, n, bits);
        let m = if g.bool() {
            None
        } else {
            Some(g.usize_in(1, bits.min(8)))
        };
        let mih = MihIndex::build(db.clone(), m);
        let linear = BinaryIndex::new(db);
        // k sweeps through 0, sensible, and > n.
        let k = g.usize_in(0, n + 5);
        let q = random_codes(g, 1, bits);
        assert_eq!(
            mih.search(q.code(0), k),
            linear.search(q.code(0), k),
            "bits={bits} n={n} m={m:?} k={k}"
        );
    });
}

#[test]
fn prop_mih_sampled_matches_linear_scan() {
    forall("mih-sampled == BinaryIndex on random corpora", 60, |g| {
        let bits = g.usize_in(2, 200);
        let n = g.usize_in(0, 250);
        let db = random_codes(g, n, bits);
        let m = if g.bool() {
            None
        } else {
            Some(g.usize_in(1, bits.min(8)))
        };
        let mih = MihIndex::build_sampled(db.clone(), m);
        let linear = BinaryIndex::new(db);
        let k = g.usize_in(0, n + 5);
        let q = random_codes(g, 1, bits);
        assert_eq!(
            mih.search(q.code(0), k),
            linear.search(q.code(0), k),
            "bits={bits} n={n} m={m:?} k={k}"
        );
    });
}

#[test]
fn prop_mih_matches_linear_under_heavy_ties() {
    // Tiny codes over larger corpora force many duplicate codes and
    // distance ties; selection must break ties identically (by id) in
    // both substring schemes.
    forall("MihIndex tie-breaking matches linear scan", 60, |g| {
        let bits = g.usize_in(2, 10);
        let n = g.usize_in(20, 300);
        let db = random_codes(g, n, bits);
        let m = Some(g.usize_in(1, bits.min(3)));
        let mih = MihIndex::build(db.clone(), m);
        let sampled = MihIndex::build_sampled(db.clone(), m);
        let linear = BinaryIndex::new(db);
        let k = g.usize_in(1, 25);
        let q = random_codes(g, 1, bits);
        let want = linear.search(q.code(0), k);
        assert_eq!(mih.search(q.code(0), k), want, "contiguous, m={m:?}");
        assert_eq!(sampled.search(q.code(0), k), want, "sampled, m={m:?}");
    });
}

#[test]
fn prop_sharded_matches_linear_scan() {
    forall("ShardedIndex == BinaryIndex on random corpora", 50, |g| {
        let bits = g.usize_in(2, 160);
        let n = g.usize_in(0, 250);
        let shards = g.usize_in(1, 6);
        let db = random_codes(g, n, bits);
        let sharded = ShardedIndex::build(db.clone(), shards, None);
        let linear = BinaryIndex::new(db);
        let k = g.usize_in(0, n + 5);
        let q = random_codes(g, 1, bits);
        assert_eq!(
            sharded.search(q.code(0), k),
            linear.search(q.code(0), k),
            "bits={bits} n={n} shards={shards} k={k}"
        );
        // Batch path (query-parallel) must agree with single-query path.
        let queries = random_codes(g, 10, bits);
        let batch = sharded.search_batch(&queries, k);
        for qi in 0..queries.n {
            assert_eq!(batch[qi], linear.search(queries.code(qi), k));
        }
    });
}

/// Mirror model: a plain (id, code) list. After any interleaving of
/// inserts and removes, a fresh BinaryIndex over the mirror is the ground
/// truth the incremental indexes must match. Ids are assigned in
/// ascending order so linear-scan tie-breaking (insertion order) equals
/// id order, the documented contract of the MIH backends.
struct Mirror {
    bits: usize,
    rows: Vec<(u32, Vec<u64>)>,
}

impl Mirror {
    fn to_linear(&self) -> BinaryIndex {
        let mut codes = BitCode::new(self.rows.len(), self.bits);
        let wpc = codes.words_per_code;
        let mut ids = Vec::with_capacity(self.rows.len());
        for (i, (id, words)) in self.rows.iter().enumerate() {
            codes.data[i * wpc..(i + 1) * wpc].copy_from_slice(words);
            ids.push(*id);
        }
        BinaryIndex::with_ids(codes, ids)
    }
}

#[test]
fn prop_incremental_churn_stays_exact() {
    forall("insert/remove churn keeps MIH backends exact", 40, |g| {
        let bits = g.usize_in(2, 120);
        let n0 = g.usize_in(0, 80);
        let db = random_codes(g, n0, bits);
        let shards = g.usize_in(1, 4);

        let mut mih = MihIndex::build(db.clone(), None);
        let mut sampled = MihIndex::build_sampled(db.clone(), None);
        let mut sharded = ShardedIndex::build(db.clone(), shards, None);
        let mut mirror = Mirror {
            bits,
            rows: (0..n0)
                .map(|i| (i as u32, db.code(i).to_vec()))
                .collect(),
        };

        let mut next_id = n0 as u32;
        let ops = g.usize_in(1, 60);
        for _ in 0..ops {
            let remove = g.bool() && !mirror.rows.is_empty();
            if remove {
                let victim = g.usize_in(0, mirror.rows.len() - 1);
                let id = mirror.rows[victim].0;
                mirror.rows.remove(victim);
                assert!(mih.remove(id));
                assert!(sampled.remove(id));
                assert!(sharded.remove(id));
                assert!(!mih.remove(id), "double remove must report absence");
            } else {
                let code = random_codes(g, 1, bits);
                mih.insert(next_id, code.code(0));
                sampled.insert(next_id, code.code(0));
                sharded.insert(next_id, code.code(0));
                mirror.rows.push((next_id, code.code(0).to_vec()));
                next_id += 1;
            }
        }

        let linear = mirror.to_linear();
        assert_eq!(mih.len(), linear.len());
        assert_eq!(sampled.len(), linear.len());
        assert_eq!(sharded.len(), linear.len());
        let k = g.usize_in(0, mirror.rows.len() + 3);
        let q = random_codes(g, 1, bits);
        let want = linear.search(q.code(0), k);
        assert_eq!(mih.search(q.code(0), k), want, "MihIndex after churn");
        assert_eq!(
            sampled.search(q.code(0), k),
            want,
            "sampled MihIndex after churn"
        );
        assert_eq!(
            sharded.search(q.code(0), k),
            want,
            "ShardedIndex after churn"
        );
    });
}

#[test]
fn prop_arena_survives_heavy_bucket_churn() {
    // Wave churn aimed at the flat bucket store: repeatedly insert a wave
    // of codes and remove the oldest wave, keeping the live count steady
    // so MihIndex's own storage compaction rarely fires and the churn
    // lands on the per-table postings arena (bucket relocation, tombstoned
    // keys, arena compaction). Tiny keyspaces (small bits, small m) force
    // deep buckets that relocate many times.
    forall("postings arena stays exact under wave churn", 25, |g| {
        let bits = g.usize_in(2, 24);
        let m = Some(g.usize_in(1, bits.min(3)));
        let n0 = g.usize_in(30, 60);
        let db = random_codes(g, n0, bits);
        let mut mih = MihIndex::build(db.clone(), m);
        let mut sampled = MihIndex::build_sampled(db.clone(), m);
        let mut mirror = Mirror {
            bits,
            rows: (0..n0)
                .map(|i| (i as u32, db.code(i).to_vec()))
                .collect(),
        };
        let mut next_id = n0 as u32;
        let waves = g.usize_in(3, 8);
        let wave = g.usize_in(10, 30);
        for _ in 0..waves {
            for _ in 0..wave {
                let code = random_codes(g, 1, bits);
                mih.insert(next_id, code.code(0));
                sampled.insert(next_id, code.code(0));
                mirror.rows.push((next_id, code.code(0).to_vec()));
                next_id += 1;
            }
            for _ in 0..wave {
                let id = mirror.rows.remove(0).0;
                assert!(mih.remove(id));
                assert!(sampled.remove(id));
            }
            // Spot-check mid-churn, not only at the end.
            let linear = mirror.to_linear();
            let q = random_codes(g, 1, bits);
            let k = g.usize_in(1, 12);
            let want = linear.search(q.code(0), k);
            assert_eq!(mih.search(q.code(0), k), want, "contiguous mid-churn");
            assert_eq!(sampled.search(q.code(0), k), want, "sampled mid-churn");
        }
        // Physical code storage must not have grown without bound either:
        // MihIndex compaction keeps tombstones under half of storage.
        assert!(
            mih.storage_slots() <= 2 * mih.len().max(64),
            "storage={} live={}",
            mih.storage_slots(),
            mih.len()
        );
    });
}

#[test]
fn prop_removed_then_reinserted_ids_resolve_to_new_code() {
    // Remove an id and insert a different code under the same id: searches
    // must see only the new code (the tombstoned slot stays dead).
    forall("id reuse after remove", 40, |g| {
        let bits = g.usize_in(8, 64);
        let n = g.usize_in(2, 40);
        let db = random_codes(g, n, bits);
        let mut mih = MihIndex::build(db.clone(), None);
        let victim = g.usize_in(0, n - 1) as u32;
        assert!(mih.remove(victim));
        let fresh = random_codes(g, 1, bits);
        mih.insert(victim, fresh.code(0));
        let hits = mih.search(fresh.code(0), 1);
        assert_eq!(hits[0].dist, 0);
        // And the old code is only reachable if some live row equals it.
        let old_hits = mih.search(db.code(victim as usize), n);
        for h in &old_hits {
            if h.id == victim {
                // distance must be measured against the *new* code
                let d = cbe::bits::hamming::hamming_words(
                    db.code(victim as usize),
                    fresh.code(0),
                );
                assert_eq!(h.dist, d);
            }
        }
    });
}
