//! Equivalence properties of the parallel batch-encode engine: for every
//! FFT route (realpack half path for even d, Bluestein full-complex for
//! odd d) and every k ≤ d, `encode_batch_into` must be **bit-exactly**
//! the composition of per-vector `encode_into` (≡ `encode_signs`) with
//! `BitCode::set_row_from_signs` — at any batch size and thread count.
//! Thread-safety of the substrate itself is compile-time asserted in
//! `projections::circulant` (`CirculantProjection`/`Plan`: Send + Sync).

use cbe::bits::BitCode;
use cbe::encoders::{BinaryEncoder, CbeRand};
use cbe::fft::Planner;
use cbe::linalg::Mat;
use cbe::projections::{CbeModel, CirculantProjection, EncodeScratch, ProjectionSpec, ScratchPool};
use cbe::proptest_lite::forall;
use cbe::util::rng::Pcg64;

/// Per-vector reference path: encode_into + set_row_from_signs.
fn per_vector_codes(proj: &CirculantProjection, rows: &[&[f32]], k: usize) -> BitCode {
    let mut bc = BitCode::new(rows.len(), k);
    let mut scratch = EncodeScratch::new();
    let mut signs = vec![0f32; k];
    for (i, row) in rows.iter().enumerate() {
        proj.encode_into(row, &mut signs, &mut scratch);
        bc.set_row_from_signs(i, &signs);
    }
    bc
}

fn batch_codes(proj: &CirculantProjection, rows: &[&[f32]], k: usize) -> BitCode {
    let mut bc = BitCode::new(rows.len(), k);
    let mut pool = ScratchPool::new();
    proj.encode_batch_into(rows, k, &mut bc, &mut pool);
    bc
}

/// Fresh seed per case (keeps cases independent of generator state).
fn seed_from(g: &mut cbe::proptest_lite::Gen) -> u64 {
    g.rng().next_u64()
}

fn check_equivalence(d: usize, k: usize, n: usize, seed: u64) {
    let planner = Planner::new();
    let mut rng = Pcg64::new(seed);
    let proj = CirculantProjection::random(d, &mut rng, planner);
    let flat: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d)).collect();
    let rows: Vec<&[f32]> = flat.iter().map(|r| r.as_slice()).collect();
    let batch = batch_codes(&proj, &rows, k);
    let reference = per_vector_codes(&proj, &rows, k);
    assert_eq!(batch, reference, "d={d} k={k} n={n} seed={seed}");
}

#[test]
fn prop_even_d_realpack_path_bit_exact() {
    forall("batch == per-vector (even d, realpack)", 25, |g| {
        let d = 2 * g.usize_in(1, 64);
        let k = g.usize_in(1, d);
        let n = g.usize_in(1, 20);
        let seed = seed_from(g);
        check_equivalence(d, k, n, seed);
    });
}

#[test]
fn prop_odd_d_bluestein_path_bit_exact() {
    forall("batch == per-vector (odd d, Bluestein)", 25, |g| {
        let d = 2 * g.usize_in(1, 64) + 1;
        let k = g.usize_in(1, d);
        let n = g.usize_in(1, 20);
        let seed = seed_from(g);
        check_equivalence(d, k, n, seed);
    });
}

#[test]
fn prop_k_lt_d_prefix_packed() {
    // k < d: the packed batch rows are exactly the k-bit prefix of the
    // full-d per-vector codes.
    forall("batch k<d is the packed prefix", 20, |g| {
        let d = g.usize_in(8, 96);
        let k = g.usize_in(1, d - 1);
        let planner = Planner::new();
        let proj = CirculantProjection::random(d, g.rng(), planner);
        let x = g.normal_vec(d);
        let rows = [x.as_slice()];
        let short = batch_codes(&proj, &rows, k);
        let full = proj.encode(&x, d);
        let mut prefix = BitCode::new(1, k);
        prefix.set_row_from_signs(0, &full[..k]);
        assert_eq!(short, prefix, "d={d} k={k}");
    });
}

#[test]
fn large_batch_spans_threads_bit_exact() {
    // Enough rows × d to clear the fan-out cutover: the scoped-thread
    // path must agree with the serial reference on every row.
    for (d, n) in [(256usize, 200usize), (100, 300), (33, 600)] {
        check_equivalence(d, d.min(128), n, 0xabc + d as u64);
    }
}

#[test]
fn trait_batch_override_matches_default() {
    // CbeRand overrides BinaryEncoder::encode_batch with the parallel
    // engine; the trait's default serial loop is the reference.
    let mut rng = Pcg64::new(77);
    for (d, k, n) in [(64usize, 64usize, 40usize), (50, 17, 25), (21, 21, 30)] {
        let enc = CbeRand::new(d, k, 1000 + d as u64, Planner::new()).unwrap();
        let x = Mat::randn(n, d, &mut rng);
        let batch = enc.encode_batch(&x);
        let mut reference = BitCode::new(n, k);
        for i in 0..n {
            reference.set_row_from_signs(i, &enc.encode_signs(x.row(i)));
        }
        assert_eq!(batch, reference, "d={d} k={k} n={n}");
    }
}

#[test]
fn empty_and_singleton_batches() {
    let planner = Planner::new();
    let mut rng = Pcg64::new(5);
    let proj = CirculantProjection::random(16, &mut rng, planner);
    let mut empty = BitCode::new(0, 8);
    proj.encode_batch_into(&[], 8, &mut empty, &mut ScratchPool::new());
    assert_eq!(empty.n, 0);
    let x = rng.normal_vec(16);
    let rows = [x.as_slice()];
    assert_eq!(
        batch_codes(&proj, &rows, 8),
        per_vector_codes(&proj, &rows, 8)
    );
}

// ---------------------------------------------------------------------------
// Arbitrary code lengths: stacked (k > d) and downsampled (k < d) variants
// must satisfy the same batch ≡ serial contract, and the packed rows must
// keep their padding bits zero at every ragged k.
// ---------------------------------------------------------------------------

fn model_batch(model: &CbeModel, rows: &[&[f32]], k: usize) -> BitCode {
    let mut bc = BitCode::new(rows.len(), k);
    model.encode_batch_into(rows, k, &mut bc, &mut ScratchPool::new());
    bc
}

/// Serial reference through the sign-vector path (`encode` unpacks the
/// per-vector packed bits back to ±1, `set_row_from_signs` repacks).
fn model_serial(model: &CbeModel, rows: &[&[f32]], k: usize) -> BitCode {
    let mut bc = BitCode::new(rows.len(), k);
    for (i, row) in rows.iter().enumerate() {
        bc.set_row_from_signs(i, &model.encode(row, k));
    }
    bc
}

#[test]
fn ragged_code_lengths_batch_equals_serial_and_padding_zero() {
    // Satellite grid from the issue: word-boundary straddlers (63/64/65),
    // the exact-d seam, one past it, and deep multi-block territory —
    // across both FFT routes (even d realpack, odd d Bluestein).
    let planner = Planner::new();
    for d in [96usize, 97] {
        for k in [63usize, 64, 65, d, d + 1, 2 * d, 3 * d + 17] {
            let mut specs = vec![ProjectionSpec::Stacked { blocks: None }];
            if k <= d {
                specs.push(ProjectionSpec::Downsampled);
            }
            for spec in &specs {
                let mut rng = Pcg64::new(0x5eed ^ (d as u64) ^ ((k as u64) << 20));
                let model = CbeModel::random_with(spec, d, k, &mut rng, planner.clone())
                    .expect("grid is within each variant's capacity");
                let flat: Vec<Vec<f32>> = (0..17).map(|_| rng.normal_vec(d)).collect();
                let rows: Vec<&[f32]> = flat.iter().map(|r| r.as_slice()).collect();
                let batch = model_batch(&model, &rows, k);
                assert!(
                    batch.padding_is_zero(),
                    "padding dirty: spec={} d={d} k={k}",
                    spec.spec()
                );
                assert_eq!(
                    batch,
                    model_serial(&model, &rows, k),
                    "spec={} d={d} k={k}",
                    spec.spec()
                );
            }
        }
    }
}

#[test]
fn prop_stacked_any_k_batch_bit_exact() {
    forall("stacked batch == serial at arbitrary k", 15, |g| {
        let d = g.usize_in(4, 80);
        let k = g.usize_in(1, 3 * d);
        let n = g.usize_in(1, 12);
        let seed = seed_from(g);
        let mut rng = Pcg64::new(seed);
        let model = CbeModel::random_with(
            &ProjectionSpec::Stacked { blocks: None },
            d,
            k,
            &mut rng,
            Planner::new(),
        )
        .unwrap();
        let flat: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d)).collect();
        let rows: Vec<&[f32]> = flat.iter().map(|r| r.as_slice()).collect();
        let batch = model_batch(&model, &rows, k);
        assert!(batch.padding_is_zero(), "d={d} k={k} n={n} seed={seed}");
        assert_eq!(batch, model_serial(&model, &rows, k), "d={d} k={k} n={n} seed={seed}");
    });
}

#[test]
fn prop_downsampled_k_batch_bit_exact() {
    forall("downsampled batch == serial at k < d", 15, |g| {
        let d = g.usize_in(4, 96);
        let k = g.usize_in(1, d);
        let n = g.usize_in(1, 12);
        let seed = seed_from(g);
        let mut rng = Pcg64::new(seed);
        let model = CbeModel::random_with(
            &ProjectionSpec::Downsampled,
            d,
            k,
            &mut rng,
            Planner::new(),
        )
        .unwrap();
        let flat: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d)).collect();
        let rows: Vec<&[f32]> = flat.iter().map(|r| r.as_slice()).collect();
        let batch = model_batch(&model, &rows, k);
        assert!(batch.padding_is_zero(), "d={d} k={k} n={n} seed={seed}");
        assert_eq!(batch, model_serial(&model, &rows, k), "d={d} k={k} n={n} seed={seed}");
    });
}
