//! Differential suite for the SIMD kernel layer: every vectorized path
//! against its scalar oracle, then end-to-end.
//!
//! The contract under test is two-tier (ARCHITECTURE.md §SIMD kernels):
//! integer popcount paths are **bit-exact** by construction, and the
//! FFT-side kernels are written to preserve the scalar operation order —
//! so *both* tiers assert `assert_eq!` here, not a tolerance, and the
//! final packed sign bits are code-identical end-to-end.
//!
//! On hosts without AVX2 (or under `--no-default-features`) the gate
//! never opens, both arms of every A/B run the scalar path, and the
//! properties hold trivially — CI runs this suite in both build flavors.
//!
//! Tests that flip the kernel switch serialize behind one mutex
//! ([`with_kernel`]): `cbe::simd::set_enabled` is process-global state
//! and the test harness runs threads in parallel. The explicit
//! `*_scalar` oracles need no gating, so each A/B holds the lock only
//! around its dispatched arm.

use cbe::bits::hamming::{
    hamming_to_all, hamming_to_all_scalar, hamming_words, hamming_words_scalar,
};
use cbe::bits::BitCode;
use cbe::fft::radix2::{fft_inplace_tw, fft_inplace_tw_scalar, make_twiddles, make_twiddles_inv};
use cbe::fft::realpack::{
    spectral_corr_accum, spectral_energy_accum, spectral_mul, RealPackPlan, RealPackScratch,
};
use cbe::fft::{cmul_in_place, C64, Dir, FftScratch, Plan, Planner, RealFft};
use cbe::index::{build_index, IndexBackend};
use cbe::projections::{CirculantProjection, EncodeScratch, ScratchPool};
use cbe::proptest_lite::forall;
use cbe::util::rng::Pcg64;
use std::sync::Mutex;

/// Serializes every test that touches the process-global kernel switch.
static GATE: Mutex<()> = Mutex::new(());

/// Run `f` with the kernel switch forced to `on`, restoring the default
/// (enabled) afterwards even if `f` panics. Holds [`GATE`] throughout so
/// parallel test threads can't observe each other's switch state.
fn with_kernel<R>(on: bool, f: impl FnOnce() -> R) -> R {
    let _guard = GATE.lock().unwrap_or_else(|e| e.into_inner());
    struct Restore;
    impl Drop for Restore {
        fn drop(&mut self) {
            cbe::simd::set_enabled(true);
        }
    }
    let _restore = Restore;
    cbe::simd::set_enabled(on);
    f()
}

fn complex_buf(vals: &[f64]) -> Vec<C64> {
    vals.chunks_exact(2).map(|p| C64::new(p[0], p[1])).collect()
}

#[test]
fn gate_switch_controls_active() {
    with_kernel(false, || assert!(!cbe::simd::active()));
    with_kernel(true, || {
        assert_eq!(cbe::simd::active(), cbe::simd::available());
        let want = if cbe::simd::available() { "avx2" } else { "scalar" };
        assert_eq!(cbe::simd::kernel_name(), want);
    });
}

#[test]
fn radix2_butterflies_bit_exact() {
    forall("radix2 simd == scalar (bit-exact)", 40, |g| {
        let n = g.pow2_in(2, 2048);
        let buf = complex_buf(&g.f64_slice(2 * n, -4.0, 4.0));
        for tw in [make_twiddles(n), make_twiddles_inv(n)] {
            let mut simd = buf.clone();
            with_kernel(true, || fft_inplace_tw(&mut simd, &tw));
            let mut scalar = buf.clone();
            fft_inplace_tw_scalar(&mut scalar, &tw);
            assert_eq!(simd, scalar, "n={n}");
        }
    });
}

#[test]
fn plan_transforms_bit_exact_both_directions() {
    // Radix-2 and Bluestein sizes, forward and inverse; the Bluestein
    // chain (chirp pre/post scalar, convolution FFTs dispatched) stays
    // exact because each dispatched stage is.
    let mut rng = Pcg64::new(907);
    for n in [4usize, 8, 33, 64, 100, 256, 777, 1000] {
        let plan = Plan::new(n);
        let buf: Vec<C64> = (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        for dir in [Dir::Forward, Dir::Inverse] {
            let simd = with_kernel(true, || {
                let mut b = buf.clone();
                plan.transform_with(&mut b, dir, &mut FftScratch::new());
                b
            });
            let scalar = with_kernel(false, || {
                let mut b = buf.clone();
                plan.transform_with(&mut b, dir, &mut FftScratch::new());
                b
            });
            assert_eq!(simd, scalar, "n={n} dir={dir:?}");
        }
        // Forward→inverse round-trip: compositions of bit-exact stages
        // are bit-exact too.
        let round = |on: bool| {
            with_kernel(on, || {
                let mut b = buf.clone();
                let mut s = FftScratch::new();
                plan.transform_with(&mut b, Dir::Forward, &mut s);
                plan.transform_with(&mut b, Dir::Inverse, &mut s);
                b
            })
        };
        assert_eq!(round(true), round(false), "round-trip n={n}");
    }
}

#[test]
fn realpack_pipeline_bit_exact() {
    forall("realpack rfft/irfft simd == scalar", 25, |g| {
        let d = 2 * g.usize_in(1, 200); // even: the packed fast path
        let planner = Planner::new();
        let plan = RealPackPlan::new(d, &planner);
        let x = g.normal_vec(d);
        let pre = g.sign_vec(d);
        let run = |on: bool| {
            with_kernel(on, || {
                let mut scratch = RealPackScratch::new();
                let mut half = vec![C64::ZERO; d / 2 + 1];
                plan.rfft(&x, Some(&pre), &mut half, &mut scratch);
                let mut back32 = vec![0f32; d];
                plan.irfft(&half, &mut back32, &mut scratch);
                let mut back64 = vec![0f64; d];
                plan.irfft_f64(&half, &mut back64, &mut scratch);
                (half, back32, back64)
            })
        };
        assert_eq!(run(true), run(false), "d={d}");
    });
}

#[test]
fn realfft_any_length_bit_exact() {
    // Odd lengths route through the full-complex (possibly Bluestein)
    // arm; even through the packed arm — both must be kernel-invariant.
    let mut rng = Pcg64::new(911);
    for d in [2usize, 7, 16, 21, 64, 100, 135, 777] {
        let planner = Planner::new();
        let rf = RealFft::new(d, &planner);
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let run = |on: bool| {
            with_kernel(on, || {
                let mut scratch = RealPackScratch::new();
                let mut half = vec![C64::ZERO; rf.half_len()];
                rf.rfft(&x, &mut half, &mut scratch);
                let mut back = vec![0f32; d];
                rf.irfft(&half, &mut back, &mut scratch);
                (half, back)
            })
        };
        assert_eq!(run(true), run(false), "d={d}");
    }
}

#[test]
fn spectral_kernels_bit_exact() {
    forall("spectral kernels simd == scalar", 30, |g| {
        let n = g.usize_in(0, 130);
        let a = complex_buf(&g.f64_slice(2 * n, -3.0, 3.0));
        let b = complex_buf(&g.f64_slice(2 * n, -3.0, 3.0));
        let h0 = g.f64_slice(n, -1.0, 1.0);
        let g0 = g.f64_slice(n, -1.0, 1.0);
        let run = |on: bool| {
            with_kernel(on, || {
                let mut prod = vec![C64::ZERO; n];
                spectral_mul(&a, &b, &mut prod);
                let mut inplace = a.clone();
                cmul_in_place(&mut inplace, &b);
                let mut energy = h0.clone();
                spectral_energy_accum(&a, &mut energy);
                let mut hacc = h0.clone();
                let mut gacc = g0.clone();
                spectral_corr_accum(&a, &b, &mut hacc, &mut gacc);
                (prod, inplace, energy, hacc, gacc)
            })
        };
        let simd = run(true);
        let scalar = run(false);
        assert_eq!(simd, scalar, "n={n}");
        // The in-place and out-of-place products agree with each other.
        assert_eq!(simd.0, simd.1, "n={n}");
    });
}

#[test]
fn hamming_kernels_bit_exact() {
    forall("hamming simd == scalar", 60, |g| {
        let wpc = g.usize_in(1, 9);
        // Ragged widths hit the tail-word masking; exact multiples the
        // no-padding case. Both must agree with the scalar oracle.
        let bits = if g.bool() {
            g.usize_in((wpc - 1) * 64 + 1, wpc * 64 - 1)
        } else {
            wpc * 64
        };
        let n = g.usize_in(0, 33);
        let db = BitCode::from_signs(&g.sign_vec(n * bits), n, bits);
        let qc = BitCode::from_signs(&g.sign_vec(bits), 1, bits);
        let q = qc.code(0);
        let mut scalar_out = vec![0u32; n];
        hamming_to_all_scalar(q, &db, &mut scalar_out);
        with_kernel(true, || {
            let mut out = vec![0u32; n];
            hamming_to_all(q, &db, &mut out);
            assert_eq!(out, scalar_out, "wpc={wpc} bits={bits} n={n}");
            for i in 0..n {
                assert_eq!(
                    hamming_words(q, db.code(i)),
                    hamming_words_scalar(q, db.code(i)),
                    "wpc={wpc} bits={bits} row={i}"
                );
            }
        });
    });
}

#[test]
fn padding_bits_stay_zero_under_churn() {
    // The invariant the popcount kernels count whole words against:
    // every BitCode writer leaves tail-word padding bits zero.
    forall("padding stays zero", 15, |g| {
        let d = 2 * g.usize_in(8, 60);
        let k = g.usize_in(1, d);
        let n = g.usize_in(1, 10);
        let planner = Planner::new();
        let proj = CirculantProjection::random(d, g.rng(), planner);
        let flat: Vec<Vec<f32>> = (0..n).map(|_| g.normal_vec(d)).collect();
        let rows: Vec<&[f32]> = flat.iter().map(|r| r.as_slice()).collect();

        let mut bc = BitCode::from_signs(&g.sign_vec(n * k), n, k);
        assert!(bc.padding_is_zero(), "after from_signs k={k}");
        // Dirty the buffer via a smaller reshape, then grow back: reset
        // must rezero everything including padding.
        bc.reset(n.div_ceil(2));
        bc.reset(n);
        assert!(bc.padding_is_zero(), "after reset churn k={k}");

        let mut scratch = EncodeScratch::new();
        for (i, row) in rows.iter().enumerate() {
            let base = i * bc.words_per_code;
            let window = &mut bc.data[base..base + bc.words_per_code];
            proj.encode_bits_into(row, k, window, &mut scratch);
        }
        assert!(bc.padding_is_zero(), "after encode_bits_into k={k}");

        let mut batch = BitCode::new(n, k);
        proj.encode_batch_into(&rows, k, &mut batch, &mut ScratchPool::new());
        assert!(batch.padding_is_zero(), "after encode_batch_into k={k}");
        assert_eq!(batch.data, bc.data, "batch == per-row d={d} k={k}");
    });
}

#[test]
fn distances_unaffected_by_masked_padding() {
    // Bit-level oracle: the popcount kernels (either side of the gate)
    // must count exactly the logical bits — zero padding contributes
    // nothing regardless of word math.
    forall("padding-masked distances", 30, |g| {
        let bits = g.usize_in(1, 300);
        let n = g.usize_in(1, 12);
        let db = BitCode::from_signs(&g.sign_vec(n * bits), n, bits);
        let qc = BitCode::from_signs(&g.sign_vec(bits), 1, bits);
        assert!(db.padding_is_zero() && qc.padding_is_zero());
        let bit = |c: &BitCode, i: usize, b: usize| c.code(i)[b / 64] >> (b % 64) & 1;
        let oracle: Vec<u32> = (0..n)
            .map(|i| (0..bits).filter(|&b| bit(&db, i, b) != bit(&qc, 0, b)).count() as u32)
            .collect();
        for on in [false, true] {
            with_kernel(on, || {
                let mut out = vec![0u32; n];
                hamming_to_all(qc.code(0), &db, &mut out);
                assert_eq!(out, oracle, "bits={bits} n={n} simd={on}");
            });
        }
    });
}

#[test]
fn end_to_end_codes_and_hits_identical() {
    // The acceptance property: encode → index → search produces
    // code-identical packed bits and hit-identical results whichever
    // kernel set runs. d covers the packed-even, pow2, and odd/Bluestein
    // encode paths.
    for d in [256usize, 512, 777] {
        let k = d.min(256);
        let n = 300;
        let n_q = 32;
        let planner = Planner::new();
        let mut rng = Pcg64::new(4242 + d as u64);
        let proj = CirculantProjection::random(d, &mut rng, planner);
        let corpus: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d)).collect();
        // Queries near corpus rows so searches have neighbor structure.
        let queries: Vec<Vec<f32>> = (0..n_q)
            .map(|qi| {
                let base = &corpus[qi * 7 % n];
                base.iter().map(|v| v + 0.1 * rng.normal() as f32).collect()
            })
            .collect();
        let run = |on: bool| {
            with_kernel(on, || {
                let mut pool = ScratchPool::new();
                let rows: Vec<&[f32]> = corpus.iter().map(|r| r.as_slice()).collect();
                let mut codes = BitCode::new(n, k);
                proj.encode_batch_into(&rows, k, &mut codes, &mut pool);
                let qrows: Vec<&[f32]> = queries.iter().map(|r| r.as_slice()).collect();
                let mut qcodes = BitCode::new(n_q, k);
                proj.encode_batch_into(&qrows, k, &mut qcodes, &mut pool);
                let index = build_index(codes.clone(), &IndexBackend::Mih { m: None });
                let hits = index.search_batch(&qcodes, 10);
                (codes, qcodes, hits)
            })
        };
        let (codes_s, qcodes_s, hits_s) = run(true);
        let (codes_c, qcodes_c, hits_c) = run(false);
        assert_eq!(codes_s, codes_c, "corpus codes differ at d={d}");
        assert_eq!(qcodes_s, qcodes_c, "query codes differ at d={d}");
        assert_eq!(hits_s, hits_c, "search hits differ at d={d}");
    }
}
