//! Differential suite for the projection-variant refactor: the k == d
//! compatibility contract. A one-block stacked model must be **bit
//! identical** to the plain [`CirculantProjection`] it generalizes —
//! same codes, same index hits, same snapshot fingerprints — whether the
//! models are drawn from a shared seed or built from shared parameters,
//! and whether they are exercised natively or through the full
//! EmbeddingService. Anything less would make `stacked:1` a silent
//! model change instead of a refactor.

use cbe::bits::BitCode;
use cbe::coordinator::{BatcherConfig, EmbeddingService, RetrainConfig, ServiceConfig};
use cbe::fft::Planner;
use cbe::index::{build_index, IndexBackend};
use cbe::projections::{
    CbeModel, CirculantProjection, ProjectionSpec, ScratchPool, StackedCirculant,
};
use cbe::util::rng::Pcg64;
use std::path::PathBuf;
use std::time::Duration;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn batch(model: &CbeModel, rows: &[&[f32]], k: usize) -> BitCode {
    let mut bc = BitCode::new(rows.len(), k);
    model.encode_batch_into(rows, k, &mut bc, &mut ScratchPool::new());
    bc
}

#[test]
fn same_seed_stacked_1_equals_circulant_codes_and_fingerprint() {
    // Both FFT routes (even d realpack, odd d Bluestein), word-boundary
    // straddling k values included.
    let planner = Planner::new();
    for d in [64usize, 97, 128] {
        let circ = CbeModel::random(&ProjectionSpec::Circ, d, d, 0xD1FF ^ d as u64, planner.clone())
            .unwrap();
        let st1 = CbeModel::random(
            &ProjectionSpec::Stacked { blocks: Some(1) },
            d,
            d,
            0xD1FF ^ d as u64,
            planner.clone(),
        )
        .unwrap();
        assert_eq!(
            circ.fingerprint(),
            st1.fingerprint(),
            "d={d}: stacked:1 fingerprint must equal the plain circulant's"
        );
        let mut rng = Pcg64::new(7 + d as u64);
        let flat: Vec<Vec<f32>> = (0..23).map(|_| rng.normal_vec(d)).collect();
        let rows: Vec<&[f32]> = flat.iter().map(|r| r.as_slice()).collect();
        for k in [1usize, 63.min(d), 64.min(d), 65.min(d), d] {
            for row in &rows {
                assert_eq!(circ.encode(row, k), st1.encode(row, k), "d={d} k={k}");
            }
            assert_eq!(batch(&circ, &rows, k), batch(&st1, &rows, k), "d={d} k={k} (batch)");
        }
    }
}

#[test]
fn shared_parameters_stacked_1_equals_circulant() {
    // Construct both variants from the SAME (r, signs) — no rng in the
    // loop, so any divergence is in the encode path itself.
    let d = 100;
    let planner = Planner::new();
    let mut rng = Pcg64::new(41);
    let r = rng.normal_vec(d);
    let signs = rng.sign_vec(d);
    let circ = CbeModel::circulant(r.clone(), signs.clone(), planner.clone());
    let block = CirculantProjection::new(r, signs, planner);
    let st1 = CbeModel::Stacked(StackedCirculant::new(vec![block]).unwrap());
    assert_eq!(circ.fingerprint(), st1.fingerprint());
    for i in 0..12 {
        let x = rng.normal_vec(d);
        assert_eq!(circ.encode(&x, d), st1.encode(&x, d), "vector {i}");
        assert_eq!(circ.encode(&x, 37), st1.encode(&x, 37), "vector {i} (k=37)");
    }
}

#[test]
fn index_hits_are_identical_between_circ_and_stacked_1() {
    let d = 96;
    let k = d;
    let planner = Planner::new();
    let circ = CbeModel::random(&ProjectionSpec::Circ, d, k, 0xCAB, planner.clone()).unwrap();
    let st1 = CbeModel::random(&ProjectionSpec::Stacked { blocks: Some(1) }, d, k, 0xCAB, planner)
        .unwrap();
    let mut rng = Pcg64::new(43);
    let db: Vec<Vec<f32>> = (0..80).map(|_| rng.normal_vec(d)).collect();
    let db_rows: Vec<&[f32]> = db.iter().map(|r| r.as_slice()).collect();
    let queries: Vec<Vec<f32>> = (0..10).map(|_| rng.normal_vec(d)).collect();
    let q_rows: Vec<&[f32]> = queries.iter().map(|r| r.as_slice()).collect();
    for backend in [IndexBackend::Linear, IndexBackend::Mih { m: Some(2) }] {
        let ic = build_index(batch(&circ, &db_rows, k), &backend);
        let is = build_index(batch(&st1, &db_rows, k), &backend);
        let qc = batch(&circ, &q_rows, k);
        let qs = batch(&st1, &q_rows, k);
        for qi in 0..q_rows.len() {
            assert_eq!(
                ic.search(qc.code(qi), 5),
                is.search(qs.code(qi), 5),
                "query {qi} diverged on {}",
                backend.spec()
            );
        }
    }
}

#[test]
fn service_level_stacked_1_serves_the_circulant_bits() {
    // The full serving stack: `start` with raw (r, signs) vs
    // `start_with_model` with the one-block stacked wrapper of the same
    // parameters. Served signs and snapshot fingerprints must agree.
    let d = 128;
    let bits = 64;
    let mut rng = Pcg64::new(0x5e5);
    let r = rng.normal_vec(d);
    let signs = rng.sign_vec(d);
    let cfg = |proj: ProjectionSpec| ServiceConfig {
        d,
        bits,
        batcher: BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(1),
        },
        index: IndexBackend::Auto,
        retrain: RetrainConfig::default(),
        queue_depth: 0,
        load_mode: cbe::index::LoadMode::Auto,
        proj,
    };
    let svc_circ = EmbeddingService::start(
        &artifacts_dir(),
        cfg(ProjectionSpec::Circ),
        r.clone(),
        signs.clone(),
    )
    .unwrap();
    let block = CirculantProjection::new(r, signs, Planner::new());
    let model = CbeModel::Stacked(StackedCirculant::new(vec![block]).unwrap());
    let svc_stacked = EmbeddingService::start_with_model(
        &artifacts_dir(),
        cfg(ProjectionSpec::Stacked { blocks: Some(1) }),
        model,
    )
    .unwrap();

    assert_eq!(
        svc_circ.model_fingerprint(),
        svc_stacked.model_fingerprint(),
        "snapshot stamps would go stale across the refactor seam"
    );
    for _ in 0..8 {
        let x = rng.normal_vec(d);
        let a = svc_circ.encode(x.clone()).unwrap();
        let b = svc_stacked.encode(x).unwrap();
        assert_eq!(a.signs, b.signs);
    }
    // The stats snapshot names each variant honestly even when the bits
    // are identical.
    assert_eq!(svc_circ.stats().unwrap().projection.variant, "circ");
    assert_eq!(svc_stacked.stats().unwrap().projection.variant, "stacked");
}

#[test]
fn start_refuses_non_circ_specs() {
    let d = 32;
    let mut rng = Pcg64::new(9);
    let err = EmbeddingService::start(
        &artifacts_dir(),
        ServiceConfig {
            d,
            bits: 16,
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
            index: IndexBackend::Auto,
            retrain: RetrainConfig::default(),
            queue_depth: 0,
            load_mode: cbe::index::LoadMode::Auto,
            proj: ProjectionSpec::Downsampled,
        },
        rng.normal_vec(d),
        rng.sign_vec(d),
    )
    .err()
    .expect("start must reject non-circ specs");
    assert!(err.to_string().contains("start_with_model"), "got: {err}");
}

#[test]
fn downsampled_service_end_to_end() {
    // k ≪ d through the whole serving stack: encode, index, search.
    let d = 128;
    let bits = 24;
    let model = CbeModel::random(&ProjectionSpec::Downsampled, d, bits, 77, Planner::new())
        .unwrap();
    let fp = model.fingerprint();
    let svc = EmbeddingService::start_with_model(
        &artifacts_dir(),
        ServiceConfig {
            d,
            bits,
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            index: IndexBackend::Linear,
            retrain: RetrainConfig::default(),
            queue_depth: 0,
            load_mode: cbe::index::LoadMode::Auto,
            proj: ProjectionSpec::Downsampled,
        },
        model,
    )
    .unwrap();
    assert_eq!(svc.model_fingerprint(), fp);
    let mut rng = Pcg64::new(78);
    let rows: Vec<Vec<f32>> = (0..40).map(|_| rng.normal_vec(d)).collect();
    let index = svc.build_index(&rows).unwrap();
    for qi in [0usize, 17, 39] {
        let hits = svc.search(&index, rows[qi].clone(), 3).unwrap();
        assert_eq!(hits[0].id, qi as u32, "row must retrieve itself first");
        assert_eq!(hits[0].dist, 0);
    }
    let snap = svc.stats().unwrap();
    assert_eq!(snap.projection.spec, "downsampled");
    assert_eq!(snap.projection.bits, bits);
    assert_eq!(snap.projection.blocks, 1);
}
