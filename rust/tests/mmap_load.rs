//! Integration: the zero-copy (mmap) snapshot backing is *invisible* —
//! an index loaded through the mapped path must answer every query
//! identically to one loaded through the portable heap path, across
//! every backend, word-per-code parity, and tombstone density; and
//! churn after a mapped load must promote the mapped stores to owned
//! copies without changing a single result.
//!
//! (On targets without mmap support `LoadMode::Mmap` silently degrades
//! to the heap path, so these tests still run — the differential just
//! becomes heap-vs-heap and the mapped-specific assertions are gated on
//! `Mmap::supported()`.)

use cbe::bits::BitCode;
use cbe::index::persist::mmap::Mmap;
use cbe::index::persist::{self, LoadMode, SnapshotStamp};
use cbe::index::{build_index_with_ids, IndexAny, IndexBackend};
use cbe::obs::{self, Counter};
use cbe::util::rng::Pcg64;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "cbe_mmap_load_{tag}_{}_{}",
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn backends() -> Vec<(&'static str, IndexBackend)> {
    vec![
        ("linear", IndexBackend::Linear),
        ("mih", IndexBackend::Mih { m: Some(2) }),
        ("mih_sampled", IndexBackend::MihSampled { m: Some(2) }),
        ("sharded", IndexBackend::ShardedMih { shards: 3, m: Some(2) }),
    ]
}

fn build(backend: &IndexBackend, n: usize, bits: usize, seed: u64) -> IndexAny {
    let mut rng = Pcg64::new(seed);
    let codes = BitCode::from_signs(&rng.sign_vec(n * bits), n, bits);
    build_index_with_ids(codes, (0..n as u32).collect(), backend)
}

/// Load `dir` through both backings and assert they are byte-for-byte
/// equivalent to a caller: same row count, same hits for every query.
fn assert_backings_agree(dir: &Path, queries: &BitCode, k: usize, tag: &str) -> IndexAny {
    let (heap, heap_report) = persist::load_with_mode(dir, LoadMode::Heap)
        .unwrap_or_else(|e| panic!("{tag}: heap load: {e}"));
    assert_eq!(heap_report.path.name(), "heap", "{tag}");
    assert_eq!(heap_report.mapped_bytes, 0, "{tag}: heap load mapped bytes");
    let (mapped, mmap_report) = persist::load_with_mode(dir, LoadMode::Mmap)
        .unwrap_or_else(|e| panic!("{tag}: mmap load: {e}"));
    if Mmap::supported() {
        assert_eq!(mmap_report.path.name(), "mmap", "{tag}: expected the mapped path");
        assert!(mmap_report.mapped_bytes > 0, "{tag}: nothing was mapped");
    }
    assert_eq!(heap.len(), mapped.len(), "{tag}: row counts diverge");
    for qi in 0..queries.n {
        assert_eq!(
            heap.search(queries.code(qi), k),
            mapped.search(queries.code(qi), k),
            "{tag}: query {qi} diverged between heap and mmap loads"
        );
    }
    assert_eq!(
        heap.search_batch(queries, k),
        mapped.search_batch(queries, k),
        "{tag}: batch search diverged"
    );
    mapped
}

#[test]
fn mapped_and_heap_loads_agree_across_backends_and_widths() {
    // 128 bits → 2 words per code (even, no padding); 160 bits → 3
    // words with 32 padding bits (odd, padding load-bearing).
    for bits in [128usize, 160] {
        for (tag, backend) in backends() {
            let n = 80;
            let index = build(&backend, n, bits, 0xA11C + bits as u64);
            let dir = temp_dir(&format!("agree_{tag}_{bits}"));
            persist::save(&dir, &index, &SnapshotStamp::none()).unwrap();
            let mut rng = Pcg64::new(0xBEEF);
            let queries = BitCode::from_signs(&rng.sign_vec(12 * bits), 12, bits);
            let mapped = assert_backings_agree(&dir, &queries, 5, &format!("{tag}/{bits}"));
            // And both agree with the in-memory original.
            for qi in 0..queries.n {
                assert_eq!(
                    mapped.search(queries.code(qi), 5),
                    index.search(queries.code(qi), 5),
                    "{tag}/{bits}: mapped load diverged from the saved index"
                );
            }
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

#[test]
fn tombstone_heavy_snapshots_agree_after_compacting_save() {
    // Remove two thirds of the rows before saving: the snapshot writer
    // compacts tombstones and remaps postings, so the mapped arena the
    // loader adopts has a very different shape from the live index's.
    let bits = 160;
    let n = 90;
    for (tag, backend) in backends() {
        if matches!(backend, IndexBackend::Linear) {
            continue; // linear has no tombstones
        }
        let mut index = build(&backend, n, bits, 0xD00D);
        for id in 0..60u32 {
            assert!(index.remove(id).unwrap(), "{tag}: remove {id}");
        }
        let dir = temp_dir(&format!("tomb_{tag}"));
        persist::save(&dir, &index, &SnapshotStamp::none()).unwrap();
        let mut rng = Pcg64::new(0xCAFE);
        let queries = BitCode::from_signs(&rng.sign_vec(10 * bits), 10, bits);
        let mapped = assert_backings_agree(&dir, &queries, 7, tag);
        assert_eq!(mapped.len(), 30, "{tag}: compaction changed the row count");
        for qi in 0..queries.n {
            assert_eq!(
                mapped.search(queries.code(qi), 7),
                index.search(queries.code(qi), 7),
                "{tag}: query {qi} diverged from the pre-save index"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Deterministic churn code for 160-bit rows: word 2 keeps its top 32
/// bits zero (the padding contract).
fn code_for(id: u32) -> [u64; 3] {
    [
        u64::from(id).wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1,
        u64::from(id).rotate_left(17) ^ 0x5555_5555_5555_5555,
        u64::from(id) & 0xFFFF_FFFF,
    ]
}

#[test]
fn churn_after_mapped_load_promotes_and_matches_heap_churn() {
    obs::set_enabled(true);
    let bits = 160;
    let n = 40;
    for (tag, backend) in [
        ("mih", IndexBackend::Mih { m: Some(2) }),
        ("sharded", IndexBackend::ShardedMih { shards: 3, m: Some(2) }),
    ] {
        let index = build(&backend, n, bits, 0xF00D);
        let dir = temp_dir(&format!("churn_{tag}"));
        persist::save(&dir, &index, &SnapshotStamp::none()).unwrap();

        let (mut heap, _) = persist::load_with_mode(&dir, LoadMode::Heap).unwrap();
        let (mut mapped, _) = persist::load_with_mode(&dir, LoadMode::Mmap).unwrap();

        // Identical churn through both handles. The first mutation of
        // the mapped index must promote its stores (copy-on-write) —
        // visible as a bump of the PromoteOwned counter — and from
        // there on the two must stay indistinguishable.
        let before = obs::global().counter(Counter::PromoteOwned);
        for id in 100..120u32 {
            heap.insert(id, &code_for(id)).unwrap();
            mapped.insert(id, &code_for(id)).unwrap();
        }
        for id in [3u32, 7, 11, 102] {
            assert_eq!(heap.remove(id).unwrap(), mapped.remove(id).unwrap(), "{tag}");
        }
        if Mmap::supported() {
            assert!(
                obs::global().counter(Counter::PromoteOwned) > before,
                "{tag}: churn on a mapped index never promoted to owned"
            );
        }

        assert_eq!(heap.len(), mapped.len(), "{tag}: row counts diverge after churn");
        let mut rng = Pcg64::new(0x1DEA);
        let queries = BitCode::from_signs(&rng.sign_vec(10 * bits), 10, bits);
        for qi in 0..queries.n {
            assert_eq!(
                heap.search(queries.code(qi), 6),
                mapped.search(queries.code(qi), 6),
                "{tag}: query {qi} diverged after post-load churn"
            );
        }

        // A promoted index must survive a fresh save/load roundtrip.
        let dir2 = temp_dir(&format!("churn_resave_{tag}"));
        persist::save(&dir2, &mapped, &SnapshotStamp::none()).unwrap();
        let remapped = assert_backings_agree(&dir2, &queries, 6, &format!("{tag}/resave"));
        assert_eq!(remapped.len(), mapped.len(), "{tag}: resave lost rows");
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&dir2);
    }
}

#[test]
fn variant_codes_round_trip_identically_through_both_backings() {
    // Codes produced by the non-trivial projection variants (stacked at a
    // ragged k > d, downsampled at k ≪ d) must survive the snapshot
    // round-trip bit-exactly on both backings, with the model fingerprint
    // (which covers every block and the selection plan) stamped in.
    use cbe::fft::Planner;
    use cbe::projections::{CbeModel, ProjectionSpec, ScratchPool};

    let d = 96;
    for (tag, spec, k) in [
        ("stacked", ProjectionSpec::Stacked { blocks: None }, 2 * d + 5),
        ("downsampled", ProjectionSpec::Downsampled, 29),
    ] {
        let mut rng = Pcg64::new(0x60D ^ k as u64);
        let model = CbeModel::random_with(&spec, d, k, &mut rng, Planner::new())
            .unwrap_or_else(|e| panic!("{tag}: {e}"));
        let n = 70;
        let encode = |rows: usize, rng: &mut Pcg64| {
            let flat: Vec<Vec<f32>> = (0..rows).map(|_| rng.normal_vec(d)).collect();
            let refs: Vec<&[f32]> = flat.iter().map(|r| r.as_slice()).collect();
            let mut bc = BitCode::new(rows, k);
            model.encode_batch_into(&refs, k, &mut bc, &mut ScratchPool::new());
            bc
        };
        let codes = encode(n, &mut rng);
        assert!(codes.padding_is_zero(), "{tag}: dirty padding at k={k}");
        let queries = encode(8, &mut rng);

        for (btag, backend) in backends() {
            let index = build_index_with_ids(codes.clone(), (0..n as u32).collect(), &backend);
            let dir = temp_dir(&format!("variant_{tag}_{btag}"));
            let stamp = SnapshotStamp {
                model_version: Some(1),
                fingerprint: model.fingerprint(),
            };
            persist::save(&dir, &index, &stamp).unwrap();
            let mapped = assert_backings_agree(&dir, &queries, 5, &format!("{tag}/{btag}"));
            for qi in 0..queries.n {
                assert_eq!(
                    mapped.search(queries.code(qi), 5),
                    index.search(queries.code(qi), 5),
                    "{tag}/{btag}: query {qi} diverged from the saved index"
                );
            }
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}
