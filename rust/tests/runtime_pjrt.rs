//! Integration: the PJRT runtime executes the AOT artifacts and agrees
//! with the native Rust implementations — the cross-layer correctness
//! contract of the whole three-layer architecture.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use cbe::fft::Planner;
use cbe::projections::CirculantProjection;
use cbe::runtime::Engine;
use cbe::util::rng::Pcg64;
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn cbe_encode_pjrt_matches_native() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let meta = engine.find("cbe_encode", 512).expect("d=512 artifact");
    let (b, d) = (meta.batch, meta.d);

    let mut rng = Pcg64::new(7);
    let x: Vec<f32> = rng.normal_vec(b * d);
    let r = rng.normal_vec(d);
    let signs = rng.sign_vec(d);

    let outs = engine
        .execute(
            &meta.name,
            &[(&x, &[b, d]), (&r, &[d]), (&signs, &[d])],
        )
        .unwrap();
    let codes = &outs[0];
    assert_eq!(codes.len(), b * d);

    // Native path must agree bit-for-bit except at near-zero projections.
    let proj = CirculantProjection::new(r, signs, Planner::new());
    let mut mismatches = 0usize;
    let mut checked = 0usize;
    for i in 0..b {
        let row = &x[i * d..(i + 1) * d];
        let y = proj.project(row);
        let native = proj.encode(row, d);
        for j in 0..d {
            if y[j].abs() > 1e-3 {
                checked += 1;
                if native[j] != codes[i * d + j] {
                    mismatches += 1;
                }
            }
        }
    }
    assert!(checked > b * d / 2);
    assert_eq!(
        mismatches, 0,
        "PJRT and native disagree on {mismatches}/{checked} stable bits"
    );
}

#[test]
fn cbe_project_pjrt_matches_native_values() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let meta = engine.find("cbe_project", 512).expect("artifact");
    let (b, d) = (meta.batch, meta.d);
    let mut rng = Pcg64::new(8);
    let x: Vec<f32> = rng.normal_vec(b * d);
    let r = rng.normal_vec(d);
    let signs = rng.sign_vec(d);
    let outs = engine
        .execute(&meta.name, &[(&x, &[b, d]), (&r, &[d]), (&signs, &[d])])
        .unwrap();
    let proj = CirculantProjection::new(r, signs, Planner::new());
    let mut max_err = 0f32;
    for i in 0..b {
        let y = proj.project(&x[i * d..(i + 1) * d]);
        for j in 0..d {
            max_err = max_err.max((y[j] - outs[0][i * d + j]).abs());
        }
    }
    assert!(max_err < 2e-2, "max |native - pjrt| = {max_err}");
}

#[test]
fn opt_hg_pjrt_matches_native_accumulators() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let meta = engine.find("opt_hg", 512).expect("artifact");
    let (b, d) = (meta.batch, meta.d);
    let mut rng = Pcg64::new(9);
    let x: Vec<f32> = rng.normal_vec(b * d);
    let codes: Vec<f32> = rng.sign_vec(b * d);
    let outs = engine
        .execute(&meta.name, &[(&x, &[b, d]), (&codes, &[b, d])])
        .unwrap();
    assert_eq!(outs.len(), 3, "m, h, g");
    // Native reference via the fft substrate.
    let planner = Planner::new();
    let mut m = vec![0f64; d];
    let mut h = vec![0f64; d];
    let mut g = vec![0f64; d];
    for i in 0..b {
        let xf = cbe::fft::real::rfft_full(&planner, &x[i * d..(i + 1) * d]);
        let bf = cbe::fft::real::rfft_full(&planner, &codes[i * d..(i + 1) * d]);
        for l in 0..d {
            m[l] += xf[l].norm_sqr();
            h[l] -= 2.0 * (xf[l].re * bf[l].re + xf[l].im * bf[l].im);
            g[l] += 2.0 * (xf[l].im * bf[l].re - xf[l].re * bf[l].im);
        }
    }
    for l in 0..d {
        let scale = 1.0 + m[l].abs();
        assert!((outs[0][l] as f64 - m[l]).abs() / scale < 1e-3, "m[{l}]");
        let scale = 1.0 + h[l].abs();
        assert!((outs[1][l] as f64 - h[l]).abs() / scale < 1e-2, "h[{l}]");
        let scale = 1.0 + g[l].abs();
        assert!((outs[2][l] as f64 - g[l]).abs() / scale < 1e-2, "g[{l}]");
    }
}

#[test]
fn engine_caches_compiled_executables() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let meta = engine.find("cbe_encode", 512).unwrap();
    engine.load(&meta.name).unwrap();
    engine.load(&meta.name).unwrap();
    assert_eq!(engine.loaded_count(), 1);
}

#[test]
fn lsh_encode_pjrt_matches_native() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let meta = engine.find("lsh_encode", 512).expect("artifact");
    let (b, d) = (meta.batch, meta.d);
    let k = meta.k.unwrap();
    let mut rng = Pcg64::new(10);
    let x: Vec<f32> = rng.normal_vec(b * d);
    let w: Vec<f32> = rng.normal_vec(k * d);
    let outs = engine
        .execute(&meta.name, &[(&x, &[b, d]), (&w, &[k, d])])
        .unwrap();
    let codes = &outs[0];
    let wmat = cbe::linalg::Mat::from_vec(k, d, w);
    let proj = cbe::projections::FullProjection::from_mat(wmat);
    for i in 0..b {
        let y = proj.project(&x[i * d..(i + 1) * d]);
        let native = proj.encode(&x[i * d..(i + 1) * d]);
        for j in 0..k {
            if y[j].abs() > 1e-3 {
                assert_eq!(native[j], codes[i * k + j], "row {i} bit {j}");
            }
        }
    }
}
