//! Integration: the crash-safe persistence tier under fault injection.
//!
//! The central property is the *recovery matrix*: a churn workload is
//! dry-run once with no faults to enumerate every durability syscall it
//! makes (each write, fsync, rename, and directory fsync is one op on
//! the deterministic `FaultClock`), then the exact same workload is
//! replayed killing the writer at every single op `0..n`. Whatever
//! boundary the crash lands on, reopening the directory must yield
//! either the precise pre-crash index (acknowledged ops only, plus at
//! most the one in-flight op whose log record became durable before the
//! crash) or a typed `CbeError` — never a panic, never silently wrong
//! results. Torn-write and bit-flip variants cover the two ways real
//! storage lies beyond clean crashes.

use cbe::bits::BitCode;
use cbe::coordinator::{BatcherConfig, EmbeddingService, RetrainConfig, ServiceConfig};
use cbe::index::persist::faults::FaultPlan;
use cbe::index::persist::{self, PersistOptions, PersistentIndex, RecoveryState, SnapshotStamp};
use cbe::index::{build_index_with_ids, IndexAny, IndexBackend};
use cbe::proptest_lite::forall;
use cbe::util::rng::Pcg64;
use cbe::CbeError;
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "cbe_recovery_{tag}_{}_{}",
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// 96 bits → 2 words per code with 32 padding bits, so the padding-zero
/// invariant is actually load-bearing through the WAL roundtrip.
const BITS: usize = 96;
const BASE_N: usize = 12;

fn base_index(seed: u64) -> IndexAny {
    let mut rng = Pcg64::new(seed);
    let codes = BitCode::from_signs(&rng.sign_vec(BASE_N * BITS), BASE_N, BITS);
    build_index_with_ids(
        codes,
        (0..BASE_N as u32).collect(),
        &IndexBackend::Mih { m: Some(2) },
    )
}

/// Deterministic code for a churned id; word 1 keeps its top 32 bits
/// zero (the padding contract for 96-bit codes).
fn code_for(id: u32) -> [u64; 2] {
    [
        u64::from(id).wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1,
        u64::from(id) & 0xFFFF_FFFF,
    ]
}

#[derive(Clone, Copy, Debug)]
enum Op {
    Insert(u32),
    Remove(u32),
    Checkpoint,
}

/// Live-id set after the first `acked` ops, starting from the base corpus.
fn expected_ids(ops: &[Op], acked: usize) -> BTreeSet<u32> {
    let mut live: BTreeSet<u32> = (0..BASE_N as u32).collect();
    for op in &ops[..acked] {
        match op {
            Op::Insert(id) => {
                live.insert(*id);
            }
            Op::Remove(id) => {
                live.remove(id);
            }
            Op::Checkpoint => {}
        }
    }
    live
}

/// Ids the index actually holds, over the universe this workload touches.
fn live_ids(index: &IndexAny, ops: &[Op]) -> BTreeSet<u32> {
    let mut universe: BTreeSet<u32> = (0..BASE_N as u32).collect();
    for op in ops {
        if let Op::Insert(id) | Op::Remove(id) = op {
            universe.insert(*id);
        }
    }
    universe.into_iter().filter(|id| index.contains(*id)).collect()
}

struct RunOutcome {
    /// Ops acknowledged (returned Ok) before the first failure.
    acked: usize,
    /// Whether `PersistentIndex::create` itself got to return Ok.
    created: bool,
    result: Result<(), CbeError>,
    /// Fault-clock ops consumed — on a clean dry run, the crash-point
    /// count the matrix must cover.
    total_fault_ops: u64,
}

fn run_workload(dir: &Path, ops: &[Op], plan: FaultPlan, seed: u64) -> RunOutcome {
    let popts = PersistOptions {
        sync_on_append: true,
        compact_threshold: 0,
        faults: plan,
        load_mode: persist::LoadMode::Auto,
    };
    let mut p = match PersistentIndex::create(dir, base_index(seed), SnapshotStamp::none(), popts) {
        Ok(p) => p,
        Err(e) => {
            return RunOutcome {
                acked: 0,
                created: false,
                result: Err(e),
                total_fault_ops: 0,
            }
        }
    };
    let mut acked = 0usize;
    for op in ops {
        let step = match op {
            Op::Insert(id) => p.insert(*id, &code_for(*id)),
            Op::Remove(id) => p.remove(*id).map(|_| ()),
            Op::Checkpoint => p.checkpoint(),
        };
        match step {
            Ok(()) => acked += 1,
            Err(e) => {
                let ops_used = p.fault_ops();
                return RunOutcome {
                    acked,
                    created: true,
                    result: Err(e),
                    total_fault_ops: ops_used,
                };
            }
        }
    }
    let total_fault_ops = p.fault_ops();
    RunOutcome {
        acked,
        created: true,
        result: Ok(()),
        total_fault_ops,
    }
}

fn clean_opts() -> PersistOptions {
    clean_opts_with(persist::LoadMode::Auto)
}

/// Fault-free options pinned to one snapshot backing, so the matrix can
/// interrogate the mapped and heap loaders independently over the same
/// damaged directory. (On targets without mmap support `Mmap` quietly
/// degrades to the heap path — the comparison is then trivially true.)
fn clean_opts_with(load_mode: persist::LoadMode) -> PersistOptions {
    PersistOptions {
        sync_on_append: true,
        compact_threshold: 0,
        faults: FaultPlan::none(),
        load_mode,
    }
}

/// The matrix proper: dry-run to count crash points, then crash (per
/// `make_plan`) at each one and check recovery against the oracle.
fn assert_recovery_matrix(ops: &[Op], seed: u64, tag: &str, make_plan: impl Fn(u64) -> FaultPlan) {
    let dry_dir = temp_dir(&format!("{tag}_dry"));
    let dry = run_workload(&dry_dir, ops, FaultPlan::none(), seed);
    let _ = std::fs::remove_dir_all(&dry_dir);
    assert!(dry.result.is_ok(), "dry run failed: {:?}", dry.result);
    assert_eq!(dry.acked, ops.len());
    assert!(dry.total_fault_ops > 0, "workload consumed no fault ops");

    for crash_op in 0..dry.total_fault_ops {
        let dir = temp_dir(&format!("{tag}_{crash_op}"));
        let run = run_workload(&dir, ops, make_plan(crash_op), seed);
        assert!(
            run.result.is_err(),
            "plan at op {crash_op} never fired (dry run counted {} ops)",
            dry.total_fault_ops
        );
        // The heap loader sees every crash point first (its open also
        // performs any tail repair); the mapped loader must then agree
        // byte-for-byte — same rows or the same typed error. This runs
        // the whole fault matrix against the zero-copy path, not just
        // the happy roundtrip.
        let heap_ids = match PersistentIndex::open(&dir, clean_opts_with(persist::LoadMode::Heap)) {
            Ok((heap_rec, _)) => Some(live_ids(heap_rec.index(), ops)),
            Err(CbeError::CorruptSnapshot { .. }) => None,
            Err(other) => panic!("crash at op {crash_op}: heap loader: unexpected {other}"),
        };
        match PersistentIndex::open(&dir, clean_opts_with(persist::LoadMode::Mmap)) {
            Ok((recovered, _report)) => {
                let got = live_ids(recovered.index(), ops);
                assert_eq!(
                    Some(&got),
                    heap_ids.as_ref(),
                    "crash at op {crash_op}: mapped and heap loaders disagree"
                );
                let at_ack = expected_ids(ops, run.acked);
                let with_inflight = expected_ids(ops, (run.acked + 1).min(ops.len()));
                assert!(
                    got == at_ack || got == with_inflight,
                    "crash at op {crash_op}: recovered ids {got:?} match neither the \
                     acked state {at_ack:?} nor acked+in-flight {with_inflight:?}"
                );
                drop(recovered);
                // Recovery must be idempotent: the second open finds a
                // clean directory (tail repairs stuck) and the same rows.
                let (again, report) = PersistentIndex::open(&dir, clean_opts())
                    .unwrap_or_else(|e| panic!("re-open after recovery at op {crash_op}: {e}"));
                assert_eq!(
                    report.state,
                    RecoveryState::Loaded,
                    "tail repair did not persist after crash at op {crash_op}"
                );
                assert_eq!(live_ids(again.index(), ops), got);
            }
            Err(CbeError::CorruptSnapshot { reason }) => {
                // Only legitimate before the very first snapshot landed:
                // once create() returned Ok, every later crash leaves a
                // loadable directory.
                assert!(
                    !run.created,
                    "crash at op {crash_op} corrupted an already-created index: {reason}"
                );
                assert!(
                    heap_ids.is_none(),
                    "crash at op {crash_op}: heap loader accepted what the mapped loader rejected"
                );
            }
            Err(other) => panic!("crash at op {crash_op}: unexpected error kind {other}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

fn fixed_workload() -> Vec<Op> {
    vec![
        Op::Insert(100),
        Op::Insert(101),
        Op::Remove(3),
        Op::Checkpoint,
        Op::Insert(102),
        Op::Remove(100),
    ]
}

#[test]
fn recovery_matrix_clean_crash_at_every_syscall() {
    assert_recovery_matrix(&fixed_workload(), 71, "crash", FaultPlan::crash_at);
}

#[test]
fn recovery_matrix_torn_writes_at_every_syscall() {
    // 7 bytes is shorter than any WAL record (13 B minimum) and any
    // snapshot section, so every torn write leaves a detectable stub.
    assert_recovery_matrix(&fixed_workload(), 72, "torn", |op| FaultPlan::torn_at(op, 7));
}

#[test]
fn prop_recovery_matrix_random_churn() {
    forall("recovery matrix over random churn", 4, |g| {
        let mut ops = Vec::new();
        let mut next_id = 200u32;
        let mut live: Vec<u32> = (0..BASE_N as u32).collect();
        let n_ops = g.usize_in(3, 8);
        for _ in 0..n_ops {
            match g.usize_in(0, 5) {
                0 | 1 | 2 => {
                    ops.push(Op::Insert(next_id));
                    live.push(next_id);
                    next_id += 1;
                }
                3 | 4 => {
                    let victim = live[g.usize_in(0, live.len() - 1)];
                    live.retain(|&id| id != victim);
                    ops.push(Op::Remove(victim));
                }
                _ => ops.push(Op::Checkpoint),
            }
        }
        let seed = 80 + g.case as u64;
        assert_recovery_matrix(&ops, seed, &format!("prop{}", g.case), FaultPlan::crash_at);
    });
}

#[test]
fn flipped_bits_are_detected_never_believed() {
    // Silent media corruption: flip one bit of each write the workload
    // makes (the op still succeeds). A later open must end in a typed
    // CorruptSnapshot or in a state equal to some acknowledged prefix
    // with the damage *reported* (a flipped WAL record is
    // indistinguishable from a torn tail, and is dropped as one) —
    // never a panic, never unreported garbage.
    let ops = fixed_workload();
    let dry_dir = temp_dir("flip_dry");
    let dry = run_workload(&dry_dir, &ops, FaultPlan::none(), 73);
    let _ = std::fs::remove_dir_all(&dry_dir);
    assert!(dry.result.is_ok());
    let prefix_states: Vec<BTreeSet<u32>> =
        (0..=ops.len()).map(|k| expected_ids(&ops, k)).collect();

    for flip_op in 0..dry.total_fault_ops {
        for bit in [0u64, 13, 101] {
            let dir = temp_dir(&format!("flip_{flip_op}_{bit}"));
            let run = run_workload(&dir, &ops, FaultPlan::flip_at(flip_op, bit), 73);
            assert!(run.result.is_ok(), "a flip must not fail the writer");
            for mode in [persist::LoadMode::Mmap, persist::LoadMode::Heap] {
                match PersistentIndex::open(&dir, clean_opts_with(mode)) {
                    Ok((recovered, _report)) => {
                        let got = live_ids(recovered.index(), &ops);
                        assert!(
                            prefix_states.iter().any(|s| *s == got),
                            "flip at op {flip_op} bit {bit} ({mode:?}): ids {got:?} \
                             match no acked prefix"
                        );
                    }
                    Err(CbeError::CorruptSnapshot { .. }) => {}
                    Err(other) => {
                        panic!("flip at op {flip_op} bit {bit} ({mode:?}): unexpected {other}")
                    }
                }
            }
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

#[test]
fn torn_tail_is_repaired_and_appendable() {
    // Tear a WAL append mid-record, recover (tail reported + truncated),
    // then keep appending through the recovered handle: the log must be
    // clean again, with no garbage burying the new records.
    let dir = temp_dir("tail_repair");
    let ops = [Op::Insert(100), Op::Insert(101)];
    // Dry-run an identical prefix to find the op index of the *second*
    // insert's write, then tear it.
    let probe = temp_dir("tail_repair_probe");
    let mut p = PersistentIndex::create(
        &probe,
        base_index(74),
        SnapshotStamp::none(),
        clean_opts(),
    )
    .unwrap();
    let before_second = {
        p.insert(100, &code_for(100)).unwrap();
        p.fault_ops()
    };
    drop(p);
    let _ = std::fs::remove_dir_all(&probe);

    let run = run_workload(&dir, &ops, FaultPlan::torn_at(before_second, 7), 74);
    assert_eq!(run.acked, 1);
    assert!(run.result.is_err());

    let (mut recovered, report) = PersistentIndex::open(&dir, clean_opts()).unwrap();
    match report.state {
        RecoveryState::LoadedWithTruncatedWalTail { dropped_bytes } => {
            assert_eq!(dropped_bytes, 7, "exactly the torn stub is dropped")
        }
        RecoveryState::Loaded => panic!("torn tail was not reported"),
    }
    assert!(recovered.index().contains(100));
    assert!(!recovered.index().contains(101));

    recovered.insert(101, &code_for(101)).unwrap();
    recovered.insert(102, &code_for(102)).unwrap();
    drop(recovered);
    let (p3, report3) = PersistentIndex::open(&dir, clean_opts()).unwrap();
    assert_eq!(report3.state, RecoveryState::Loaded);
    assert_eq!(report3.wal_records_replayed, 3);
    for id in [100u32, 101, 102] {
        assert!(p3.index().contains(id), "id {id} lost after tail repair");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_snapshot_fuzz_truncations_and_header_damage() {
    let dir = temp_dir("fuzz");
    let index = base_index(75);
    persist::save(&dir, &index, &SnapshotStamp::none()).unwrap();
    let snap_path = dir.join("current.snap");
    let pristine = std::fs::read(&snap_path).unwrap();

    // Every proper prefix must be rejected typed — a snapshot is never
    // partially applied.
    let cuts: Vec<usize> = (0..pristine.len()).step_by(7).chain([pristine.len() - 1]).collect();
    for cut in cuts {
        std::fs::write(&snap_path, &pristine[..cut]).unwrap();
        for mode in [persist::LoadMode::Mmap, persist::LoadMode::Heap] {
            match persist::load_with_mode(&dir, mode) {
                Err(CbeError::CorruptSnapshot { .. }) => {}
                other => panic!(
                    "truncation to {cut} bytes ({mode:?}): expected CorruptSnapshot, got {other:?}"
                ),
            }
        }
    }
    // Header-region damage: wrong magic, version, counts, CRCs. (The
    // prelude's trailing reserved word at bytes 20..24 sits outside the
    // CRC and is deliberately ignorable — forward compatibility — so
    // only the validated 20 bytes are fuzzed.)
    for byte in 0..20.min(pristine.len()) {
        for mask in [0x01u8, 0x80] {
            let mut bad = pristine.clone();
            bad[byte] ^= mask;
            std::fs::write(&snap_path, &bad).unwrap();
            for mode in [persist::LoadMode::Mmap, persist::LoadMode::Heap] {
                match persist::load_with_mode(&dir, mode) {
                    Err(CbeError::CorruptSnapshot { .. }) => {}
                    other => panic!(
                        "header byte {byte} flipped ({mode:?}): expected CorruptSnapshot, \
                         got {other:?}"
                    ),
                }
            }
        }
    }
    // Restored bytes load cleanly again.
    std::fs::write(&snap_path, &pristine).unwrap();
    let (loaded, report) = persist::load(&dir).unwrap();
    assert_eq!(report.state, RecoveryState::Loaded);
    assert_eq!(loaded.len(), BASE_N);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn roundtrip_every_backend_odd_wpc_and_tombstones() {
    // 160 bits → 3 words per code (odd, with padding); remove more than
    // half the rows first so the snapshot writer's compaction-on-save
    // path (tombstone filtering + posting remap) is exercised.
    let bits = 160;
    let n = 60;
    for (tag, backend) in [
        ("linear", IndexBackend::Linear),
        ("mih", IndexBackend::Mih { m: Some(2) }),
        ("mih_sampled", IndexBackend::MihSampled { m: Some(2) }),
        ("sharded", IndexBackend::ShardedMih { shards: 3, m: Some(2) }),
    ] {
        let mut rng = Pcg64::new(76);
        let codes = BitCode::from_signs(&rng.sign_vec(n * bits), n, bits);
        let mut index = build_index_with_ids(codes, (0..n as u32).collect(), &backend);
        if !matches!(backend, IndexBackend::Linear) {
            for id in 0..35u32 {
                assert!(index.remove(id).unwrap(), "{tag}: remove {id}");
            }
        }
        let dir = temp_dir(&format!("roundtrip_{tag}"));
        persist::save(&dir, &index, &SnapshotStamp::none()).unwrap();
        let (loaded, _) = persist::load(&dir).unwrap();
        assert_eq!(loaded.len(), index.len(), "{tag}: row count changed");
        let queries = BitCode::from_signs(&rng.sign_vec(10 * bits), 10, bits);
        for qi in 0..queries.n {
            assert_eq!(
                loaded.search(queries.code(qi), 5),
                index.search(queries.code(qi), 5),
                "{tag}: query {qi} diverged after the roundtrip"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn stale_model_fingerprint_rejected_across_services() {
    // Two services with different projections simulate two processes.
    // The snapshot carries the saving model's parameter fingerprint, so
    // the wrong service refuses it typed instead of serving neighbors
    // from a foreign embedding; an identically-seeded service accepts it
    // and re-stamps it at its own live registry version.
    fn start(seed: u64) -> EmbeddingService {
        let d = 64;
        let mut rng = Pcg64::new(seed);
        EmbeddingService::start(
            &PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            ServiceConfig {
                d,
                bits: 32,
                batcher: BatcherConfig {
                    max_batch: 32,
                    max_wait: std::time::Duration::from_millis(1),
                },
                index: IndexBackend::Mih { m: Some(2) },
                retrain: RetrainConfig::default(),
                queue_depth: 0,
                load_mode: persist::LoadMode::Auto,
                proj: cbe::projections::ProjectionSpec::Circ,
            },
            rng.normal_vec(d),
            rng.sign_vec(d),
        )
        .unwrap()
    }
    let mut rng = Pcg64::new(77);
    let rows: Vec<Vec<f32>> = (0..40).map(|_| rng.normal_vec(64)).collect();

    let saver = start(61);
    let index = saver.build_index(&rows).unwrap();
    let dir = temp_dir("fingerprint");
    saver.save_index(&dir, &index).unwrap();

    let wrong = start(62);
    assert_ne!(wrong.model_fingerprint(), saver.model_fingerprint());
    match wrong.load_index(&dir) {
        Err(CbeError::StaleIndex { .. }) => {}
        other => panic!("foreign-model snapshot accepted: {other:?}"),
    }

    let twin = start(61);
    assert_eq!(twin.model_fingerprint(), saver.model_fingerprint());
    let (loaded, report) = twin.load_index(&dir).unwrap();
    assert_eq!(report.state, RecoveryState::Loaded);
    assert_eq!(loaded.len(), 40);
    // Re-stamped at the twin's live version: searches are accepted and
    // every row still finds itself.
    for qi in [0usize, 17, 39] {
        let hits = twin.search(&loaded, rows[qi].clone(), 3).unwrap();
        assert_eq!(hits[0].id, qi as u32);
        assert_eq!(hits[0].dist, 0);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn auto_compaction_folds_the_wal_and_drops_tombstones_from_disk() {
    cbe::obs::set_enabled(true);
    let dir = temp_dir("compaction");
    let opts = PersistOptions {
        sync_on_append: true,
        compact_threshold: 6,
        faults: FaultPlan::none(),
        load_mode: persist::LoadMode::Auto,
    };
    let mut p =
        PersistentIndex::create(&dir, base_index(78), SnapshotStamp::none(), opts.clone()).unwrap();
    for id in 100..105u32 {
        p.insert(id, &code_for(id)).unwrap();
    }
    assert_eq!(p.generation(), 1);
    assert_eq!(p.wal_records(), 5);
    assert!(p.remove(2).unwrap(), "6th record crosses the threshold");
    assert_eq!(p.generation(), 2, "auto-checkpoint did not fire");
    assert_eq!(p.wal_records(), 0);
    drop(p);
    let (p2, report) = PersistentIndex::open(&dir, opts).unwrap();
    assert_eq!(report.generation, 2);
    assert_eq!(report.wal_records_replayed, 0, "checkpoint folded the log");
    assert_eq!(p2.len(), BASE_N + 5 - 1);
    assert!(!p2.index().contains(2));
    let _ = std::fs::remove_dir_all(&dir);
}
