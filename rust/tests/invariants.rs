//! Cross-module property tests: the paper's structural invariants, driven
//! by proptest_lite across random shapes and seeds.

use cbe::bits::hamming::normalized_hamming;
use cbe::bits::BitCode;
use cbe::fft::{real, Planner};
use cbe::projections::CirculantProjection;
use cbe::proptest_lite::forall;
use cbe::util::l2_normalize;

#[test]
fn prop_circulant_commutes_with_shift() {
    // The defining property of circ(r): shifting the input circularly
    // shifts the projection circularly (R is shift-equivariant).
    forall("circulant shift equivariance", 40, |g| {
        let d = g.usize_in(4, 64);
        let planner = Planner::new();
        let r = g.normal_vec(d);
        let proj = CirculantProjection::new(r, vec![1.0; d], planner);
        let x = g.normal_vec(d);
        let y = proj.project(&x);
        // shift x by s
        let s = g.usize_in(1, d - 1);
        let xs: Vec<f32> = (0..d).map(|i| x[(i + d - s) % d]).collect();
        let ys = proj.project(&xs);
        for i in 0..d {
            let want = y[(i + d - s) % d];
            assert!(
                (ys[i] - want).abs() < 1e-2 * (1.0 + want.abs()),
                "d={d} s={s} i={i}: {} vs {want}",
                ys[i]
            );
        }
    });
}

#[test]
fn prop_projection_linear() {
    forall("circulant projection is linear", 40, |g| {
        let d = g.usize_in(2, 96);
        let planner = Planner::new();
        let proj = CirculantProjection::random(d, g.rng(), planner);
        let x = g.normal_vec(d);
        let yv = proj.project(&x);
        let alpha = g.f32_in(-3.0, 3.0);
        let xs: Vec<f32> = x.iter().map(|v| v * alpha).collect();
        let ys = proj.project(&xs);
        for i in 0..d {
            assert!(
                (ys[i] - alpha * yv[i]).abs() < 1e-2 * (1.0 + yv[i].abs()),
                "i={i}"
            );
        }
    });
}

#[test]
fn prop_spectrum_energy_preserved() {
    // Parseval through the whole real-FFT stack (incl. Bluestein sizes).
    forall("parseval on rfft_full", 60, |g| {
        let d = g.usize_in(2, 200);
        let planner = Planner::new();
        let x = g.normal_vec(d);
        let spec = real::rfft_full(&planner, &x);
        let e_time: f64 = x.iter().map(|v| (*v as f64) * (*v as f64)).sum();
        let e_freq: f64 = spec.iter().map(|c| c.norm_sqr()).sum::<f64>() / d as f64;
        assert!(
            (e_time - e_freq).abs() < 1e-6 * (1.0 + e_time),
            "d={d}: {e_time} vs {e_freq}"
        );
    });
}

#[test]
fn prop_hamming_metric_axioms() {
    forall("normalized hamming is a metric on sign vectors", 100, |g| {
        let bits = g.usize_in(1, 300);
        let a = g.sign_vec(bits);
        let b = g.sign_vec(bits);
        let c = g.sign_vec(bits);
        let dab = normalized_hamming(&a, &b);
        let dba = normalized_hamming(&b, &a);
        assert_eq!(dab, dba);
        assert_eq!(normalized_hamming(&a, &a), 0.0);
        let dac = normalized_hamming(&a, &c);
        let dcb = normalized_hamming(&c, &b);
        assert!(dab <= dac + dcb + 1e-12, "triangle inequality");
        assert!((0.0..=1.0).contains(&dab));
    });
}

#[test]
fn prop_bitcode_pack_preserves_hamming() {
    forall("packed hamming == unpacked hamming", 80, |g| {
        let bits = g.usize_in(1, 260);
        let a = g.sign_vec(bits);
        let b = g.sign_vec(bits);
        let ca = BitCode::from_signs(&a, 1, bits);
        let cb = BitCode::from_signs(&b, 1, bits);
        let packed =
            cbe::bits::hamming::hamming(&ca, 0, &cb, 0) as f64 / bits as f64;
        assert!((packed - normalized_hamming(&a, &b)).abs() < 1e-12);
    });
}

#[test]
fn prop_encode_invariant_to_positive_scaling() {
    // sign(R·D·(αx)) = sign(R·D·x) for α > 0 — codes depend on direction
    // only, the basis of the paper's angle-preservation claims.
    forall("codes scale-invariant", 40, |g| {
        let d = g.usize_in(4, 80);
        let planner = Planner::new();
        let proj = CirculantProjection::random(d, g.rng(), planner);
        let mut x = g.normal_vec(d);
        l2_normalize(&mut x);
        let y = proj.project(&x);
        let alpha = g.f32_in(0.1, 10.0);
        let xs: Vec<f32> = x.iter().map(|v| v * alpha).collect();
        let c1 = proj.encode(&x, d);
        let c2 = proj.encode(&xs, d);
        for j in 0..d {
            if y[j].abs() > 1e-3 {
                assert_eq!(c1[j], c2[j], "bit {j}");
            }
        }
    });
}
