//! Integration: the EmbeddingService end to end — dynamic batching over
//! the parallel native batch-encode path, retrieval, metrics — plus
//! property tests on the coordinator invariants (batching, routing) via
//! proptest_lite. The service no longer needs compiled artifacts (the
//! manifest, when present, only sizes batches), so these run everywhere.

use cbe::coordinator::{BatcherConfig, EmbeddingService, RetrainConfig, ServiceConfig};
use cbe::fft::Planner;
use cbe::index::IndexBackend;
use cbe::projections::{CirculantProjection, ProjectionSpec};
use cbe::proptest_lite::forall;
use cbe::util::rng::Pcg64;
use std::path::PathBuf;
use std::time::Duration;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn service(d: usize, bits: usize, seed: u64) -> (EmbeddingService, Vec<f32>, Vec<f32>) {
    let mut rng = Pcg64::new(seed);
    let r = rng.normal_vec(d);
    let signs = rng.sign_vec(d);
    let svc = EmbeddingService::start(
        &artifacts_dir(),
        ServiceConfig {
            d,
            bits,
            batcher: BatcherConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(1),
            },
            index: IndexBackend::Auto,
            retrain: RetrainConfig::default(),
            queue_depth: 0,
            load_mode: cbe::index::LoadMode::Auto,
            proj: ProjectionSpec::Circ,
        },
        r.clone(),
        signs.clone(),
    )
    .unwrap();
    (svc, r, signs)
}

#[test]
fn served_codes_match_native_encoder() {
    let (svc, r, signs) = service(512, 128, 11);
    let proj = CirculantProjection::new(r, signs, Planner::new());
    let mut rng = Pcg64::new(12);
    for _ in 0..5 {
        let x = rng.normal_vec(512);
        let resp = svc.encode(x.clone()).unwrap();
        assert_eq!(resp.signs.len(), 128);
        let y = proj.project(&x);
        let native = proj.encode(&x, 128);
        for j in 0..128 {
            if y[j].abs() > 1e-3 {
                assert_eq!(resp.signs[j], native[j], "bit {j}");
            }
        }
    }
}

#[test]
fn concurrent_requests_batch_together() {
    let (svc, _, _) = service(512, 64, 13);
    let mut rng = Pcg64::new(14);
    let handles: Vec<_> = (0..96)
        .map(|_| svc.encode_async(rng.normal_vec(512)).unwrap())
        .collect();
    for h in handles {
        let resp = h.recv().unwrap();
        assert_eq!(resp.signs.len(), 64);
        assert!(resp.signs.iter().all(|s| s.abs() == 1.0));
    }
    assert_eq!(svc.metrics.request_count(), 96);
    // 96 requests at max_batch=32 must have used ≥ 3 batches but far
    // fewer than 96 (i.e. batching actually happened).
    let batches = svc.metrics.batch_count();
    assert!(batches >= 3, "batches={batches}");
    assert!(batches < 96, "no batching happened: {batches}");
}

#[test]
fn wrong_dim_rejected() {
    let (svc, _, _) = service(512, 64, 15);
    assert!(svc.encode_async(vec![0.0; 100]).is_err());
}

#[test]
fn index_and_search_roundtrip() {
    let (svc, _, _) = service(512, 256, 16);
    let mut rng = Pcg64::new(17);
    let rows: Vec<Vec<f32>> = (0..64)
        .map(|_| {
            let mut v = rng.normal_vec(512);
            cbe::util::l2_normalize(&mut v);
            v
        })
        .collect();
    let index = svc.build_index(&rows).unwrap();
    assert_eq!(index.len(), 64);
    // Searching with a database row must return itself first (distance 0).
    for qi in [0usize, 10, 63] {
        let hits = svc.search(&index, rows[qi].clone(), 3).unwrap();
        assert_eq!(hits[0].id, qi as u32);
        assert_eq!(hits[0].dist, 0);
    }
}

#[test]
fn encode_corpus_matches_request_path() {
    // d = 100: even → realpack half path with a Bluestein half plan —
    // the gnarliest native route. Bulk codes must equal the per-request
    // serving path bit for bit.
    let (svc, _, _) = service(100, 64, 18);
    let mut rng = Pcg64::new(19);
    let rows: Vec<Vec<f32>> = (0..40).map(|_| rng.normal_vec(100)).collect();
    let codes = svc.encode_corpus(&rows).unwrap();
    assert_eq!(codes.n, 40);
    assert_eq!(codes.bits, 64);
    for (i, row) in rows.iter().enumerate() {
        let resp = svc.encode(row.clone()).unwrap();
        let via_request = cbe::bits::BitCode::from_signs(&resp.signs, 1, 64);
        assert_eq!(codes.code(i), via_request.code(0), "row {i}");
    }
    assert!(svc.encode_corpus(&[vec![0.0; 3]]).is_err());
}

#[test]
fn retrain_hot_swaps_without_dropping_requests() {
    // Index a corpus (fills the retrain reservoir), then race waves of
    // in-flight encode requests against a background Retrain. Contract:
    // no request is dropped, every reply matches exactly one of the two
    // model versions (batch-atomic swap), and post-swap traffic is
    // served by the new model.
    let (svc, _, _) = service(64, 32, 21);
    let mut rng = Pcg64::new(22);
    let rows: Vec<Vec<f32>> = (0..300)
        .map(|_| {
            let mut v = rng.normal_vec(64);
            cbe::util::l2_normalize(&mut v);
            v
        })
        .collect();
    let _ = svc.build_index(&rows).unwrap();
    assert!(svc.corpus_sample_len() >= 2, "reservoir not fed by encode_corpus");
    assert_eq!(svc.model_version(), 0);
    let old_proj = svc.projection();

    let queries: Vec<Vec<f32>> = (0..48).map(|_| rng.normal_vec(64)).collect();
    let pending = svc.retrain().unwrap();
    let mut responses: Vec<(usize, Vec<f32>)> = Vec::new();
    let outcome = loop {
        let handles: Vec<_> = queries
            .iter()
            .map(|q| svc.encode_async(q.clone()).unwrap())
            .collect();
        for (qi, h) in handles.into_iter().enumerate() {
            let resp = h.recv().expect("in-flight request dropped during retrain");
            assert_eq!(resp.signs.len(), 32);
            responses.push((qi, resp.signs));
        }
        match pending.try_recv() {
            Ok(result) => break result.expect("retrain failed"),
            Err(std::sync::mpsc::TryRecvError::Empty) => {}
            Err(e) => panic!("retrain reply lost: {e:?}"),
        }
    };
    assert_eq!(outcome.version, 1);
    assert!(outcome.rows_used >= 2);
    assert!(!outcome.report.objective_trace.is_empty());
    assert_eq!(svc.model_version(), 1);

    let new_proj = svc.projection();
    assert!(!std::sync::Arc::ptr_eq(&old_proj, &new_proj));
    // Snapshot consistency: every reply came from one whole model.
    for (qi, signs) in &responses {
        let old_code = old_proj.encode(&queries[*qi], 32);
        let new_code = new_proj.encode(&queries[*qi], 32);
        assert!(
            *signs == old_code || *signs == new_code,
            "reply for query {qi} matches neither model version"
        );
    }
    // Post-swap requests are served by the new model.
    let resp = svc.encode(queries[0].clone()).unwrap();
    assert_eq!(resp.signs, new_proj.encode(&queries[0], 32));
}

#[test]
fn stale_index_rejected_after_retrain() {
    // The PR-4 rebuild-after-retrain contract, now enforced by code:
    // build_index stamps the registry version its codes were encoded
    // with, and search() against an index whose stamp mismatches the live
    // model fails with CbeError::StaleIndex instead of silently mixing
    // codes from two models.
    let (svc, _, _) = service(64, 32, 31);
    let mut rng = Pcg64::new(32);
    let rows: Vec<Vec<f32>> = (0..64)
        .map(|_| {
            let mut v = rng.normal_vec(64);
            cbe::util::l2_normalize(&mut v);
            v
        })
        .collect();
    let old_index = svc.build_index(&rows).unwrap();
    assert_eq!(old_index.model_version(), Some(0));

    // Pre-retrain the stamped index serves normally.
    let hits = svc.search(&old_index, rows[3].clone(), 3).unwrap();
    assert_eq!(hits[0].id, 3);
    assert_eq!(hits[0].dist, 0);

    svc.retrain_blocking().unwrap();
    assert_eq!(svc.model_version(), 1);

    // Post-retrain, the pre-swap index is refused …
    let err = svc.search(&old_index, rows[3].clone(), 3).unwrap_err();
    assert_eq!(
        err,
        cbe::CbeError::StaleIndex {
            built: 0,
            current: 1
        }
    );
    assert!(err.to_string().contains("stale index"), "{err}");

    // … a rebuilt index carries the new stamp and is accepted …
    let fresh = svc.build_index(&rows).unwrap();
    assert_eq!(fresh.model_version(), Some(1));
    let hits = svc.search(&fresh, rows[3].clone(), 3).unwrap();
    assert_eq!(hits[0].id, 3);
    assert_eq!(hits[0].dist, 0);

    // … and an unversioned index (built outside the service) is not
    // version-checked: its staleness stays the caller's contract.
    let codes = svc.encode_corpus(&rows).unwrap();
    let bare = cbe::index::build_index(codes, &cbe::index::IndexBackend::Linear);
    assert_eq!(bare.model_version(), None);
    svc.search(&bare, rows[0].clone(), 3).unwrap();
}

#[test]
fn retrain_without_corpus_reports_error_and_keeps_model() {
    let (svc, _, _) = service(32, 16, 23);
    let err = svc.retrain_blocking().unwrap_err();
    assert!(format!("{err}").contains("corpus sample"), "{err}");
    assert_eq!(svc.model_version(), 0);
    // Service still serves after the refused retrain.
    let mut rng = Pcg64::new(24);
    let resp = svc.encode(rng.normal_vec(32)).unwrap();
    assert_eq!(resp.signs.len(), 16);
}

#[test]
fn stats_snapshot_reflects_served_workload() {
    // The observability acceptance path end to end: serve a workload
    // (encode + MIH search), retrain, trip a StaleIndex rejection, then
    // assert ControlRequest::Stats reports all of it — counters, per-stage
    // histograms, and a JSON rendering that round-trips.
    cbe::obs::set_enabled(true);
    let mut rng = Pcg64::new(41);
    let svc = EmbeddingService::start(
        &artifacts_dir(),
        ServiceConfig {
            d: 64,
            bits: 32,
            batcher: BatcherConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(1),
            },
            // Explicit MIH: Auto routes a corpus this small to the linear
            // backend, which would leave the probe histogram empty.
            index: IndexBackend::Mih { m: None },
            retrain: RetrainConfig::default(),
            queue_depth: 0,
            load_mode: cbe::index::LoadMode::Auto,
            proj: ProjectionSpec::Circ,
        },
        rng.normal_vec(64),
        rng.sign_vec(64),
    )
    .unwrap();
    let rows: Vec<Vec<f32>> = (0..128)
        .map(|_| {
            let mut v = rng.normal_vec(64);
            cbe::util::l2_normalize(&mut v);
            v
        })
        .collect();
    let index = svc.build_index(&rows).unwrap();
    for qi in 0..8 {
        let hits = svc.search(&index, rows[qi].clone(), 3).unwrap();
        assert_eq!(hits[0].id, qi as u32);
    }
    svc.retrain_blocking().unwrap();
    svc.search(&index, rows[0].clone(), 3)
        .expect_err("stale index must be rejected");

    let snap = svc.stats().unwrap();
    // The live model's identity is stamped into the snapshot.
    assert_eq!(snap.projection.spec, "circ");
    assert_eq!(snap.projection.variant, "circ");
    assert_eq!(snap.projection.blocks, 1);
    assert_eq!(snap.projection.bits, 32);
    // Service-local counters: 8 search-path encodes (bulk indexing and
    // the refused stale search never enter the request channel).
    assert_eq!(snap.requests, 8);
    assert_eq!(snap.retrains, 1);
    assert_eq!(snap.stale_rejections, 1);
    assert_eq!(snap.model_version, 1);
    assert!(snap.batches >= 1);
    assert_eq!(snap.latency.count, 8);
    let l = &snap.latency;
    assert!(l.p50_us <= l.p99_us && l.p99_us <= l.p999_us && l.p999_us <= l.max_us);
    // Per-stage histograms (process-global, so ≥ — other tests in this
    // binary may have contributed too) must be non-empty for the full
    // request + index pipeline.
    for stage in ["queue_wait", "model_resolve", "encode", "pack", "probe", "re_rank"] {
        let s = snap.stage(stage).unwrap_or_else(|| panic!("stage {stage} missing"));
        assert!(s.count > 0, "stage {stage} recorded nothing");
    }
    assert!(snap.probes > 0, "no MIH bucket probes counted");
    assert!(snap.reranked > 0, "no re-rank work counted");
    assert!(snap.plan_cache_hits > 0, "FFT plan cache never hit");

    // The JSON rendering parses and carries the same numbers.
    let text = snap.to_json().to_string();
    let parsed = cbe::util::json::Json::parse(&text).expect("stats JSON must parse");
    assert_eq!(
        parsed.get("retrains").and_then(cbe::util::json::Json::as_f64),
        Some(1.0)
    );
    let encode = parsed
        .get("stages")
        .and_then(|s| s.get("encode"))
        .expect("stages.encode in JSON");
    assert_eq!(
        encode.get("count").and_then(cbe::util::json::Json::as_f64),
        Some(snap.stage("encode").unwrap().count as f64)
    );
}

#[test]
fn overload_sheds_with_typed_error_instead_of_buffering_forever() {
    // Admission control: the request channel is bounded, and a full
    // queue rejects with CbeError::Overloaded instead of growing without
    // limit. Depth 1 + single-request batches + a non-trivial encode
    // keep the event loop busy while a burst of async submits arrives,
    // so some of them must hit the bound.
    let d = 1024;
    let mut rng = Pcg64::new(51);
    let svc = EmbeddingService::start(
        &artifacts_dir(),
        ServiceConfig {
            d,
            bits: 256,
            batcher: BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_millis(0),
            },
            index: IndexBackend::Auto,
            retrain: RetrainConfig::default(),
            queue_depth: 1,
            load_mode: cbe::index::LoadMode::Auto,
            proj: ProjectionSpec::Circ,
        },
        rng.normal_vec(d),
        rng.sign_vec(d),
    )
    .unwrap();
    assert_eq!(svc.queue_depth(), 1);

    let mut accepted = Vec::new();
    let mut shed = 0usize;
    for _ in 0..256 {
        match svc.encode_async(rng.normal_vec(d)) {
            Ok(rx) => accepted.push(rx),
            Err(e) => {
                assert_eq!(e, cbe::CbeError::Overloaded { depth: 1 });
                assert!(e.to_string().contains("overloaded"), "{e}");
                shed += 1;
            }
        }
    }
    assert!(shed > 0, "256 burst submits never overflowed a depth-1 queue");
    assert!(!accepted.is_empty(), "admission control rejected everything");
    // Every accepted request is still served to completion.
    for rx in accepted {
        let resp = rx.recv().expect("accepted request was dropped");
        assert_eq!(resp.signs.len(), 256);
    }
    assert_eq!(svc.metrics.overload_count(), shed as u64);
    let snap = svc.stats().unwrap();
    assert_eq!(snap.overloads, shed as u64);
    // The blocking path surfaces the same typed error when it loses the
    // race (cannot force it deterministically here, so just check the
    // queue drained and the service still serves).
    let resp = svc.encode(rng.normal_vec(d)).unwrap();
    assert_eq!(resp.signs.len(), 256);
}

#[test]
fn queue_depth_resolution_prefers_config() {
    // queue_depth = 0 defers to CBE_QUEUE_DEPTH (unset here) → 1024
    // default; explicit config wins outright.
    let (svc, _, _) = service(64, 32, 52);
    assert_eq!(svc.queue_depth(), 1024);
}

// ---------------------------------------------------------- properties

#[test]
fn prop_batcher_never_exceeds_capacity_and_preserves_order() {
    use cbe::coordinator::request::EncodeRequest;
    use cbe::coordinator::Batcher;
    use std::time::Instant;

    forall("batcher capacity + FIFO", 200, |g| {
        let cap = g.usize_in(1, 16);
        let n = g.usize_in(0, 50);
        let mut b = Batcher::new(BatcherConfig {
            max_batch: cap,
            max_wait: Duration::from_secs(3600),
        });
        for _ in 0..n {
            b.push(EncodeRequest::new(vec![0.0], 1).0);
        }
        let mut drained = 0usize;
        let far_future = Instant::now() + Duration::from_secs(7200);
        while let Some(batch) = b.pop_ready(far_future) {
            assert!(batch.len() <= cap);
            assert!(!batch.is_empty());
            drained += batch.len();
        }
        assert_eq!(drained, n);
        assert!(b.is_empty());
    });
}

#[test]
fn prop_router_total_on_manifest_dims() {
    use cbe::coordinator::Router;
    use cbe::runtime::{ArtifactMeta, Manifest};

    forall("router finds every advertised dim", 100, |g| {
        let n = g.usize_in(1, 8);
        let mut arts = Vec::new();
        for i in 0..n {
            let d = g.pow2_in(8, 4096) + i; // distinct-ish dims
            arts.push(ArtifactMeta {
                name: format!("cbe_encode_d{d}"),
                kind: "cbe_encode".into(),
                d,
                batch: g.usize_in(1, 64),
                k: None,
                inputs: vec![],
                path: PathBuf::new(),
            });
        }
        let m = Manifest { artifacts: arts };
        let router = Router::from_manifest(&m);
        for d in router.dims("cbe_encode") {
            let e = router.route("cbe_encode", d).unwrap();
            assert_eq!(e.d, d);
        }
        assert!(router.route("cbe_encode", 5).is_err());
    });
}
