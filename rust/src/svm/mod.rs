//! Linear SVM substrate for the Table 3 classification experiment.
//!
//! One-vs-rest linear SVMs trained with Pegasos (stochastic subgradient,
//! Shalev-Shwartz et al. 2007). Supports the asymmetric protocol of
//! Sánchez & Perronnin 2011 that the paper uses: train on binarized codes
//! sign(Rx), test on the real-valued projections Rx.

use crate::linalg::Mat;
use crate::util::rng::Pcg64;

/// A trained multiclass (one-vs-rest) linear SVM.
pub struct LinearSvm {
    /// classes × dim weight matrix.
    pub w: Mat,
    pub bias: Vec<f32>,
    pub classes: usize,
}

/// Training hyper-parameters.
#[derive(Clone, Debug)]
pub struct SvmConfig {
    pub lambda: f32,
    pub epochs: usize,
    pub seed: u64,
}

impl Default for SvmConfig {
    fn default() -> Self {
        SvmConfig {
            lambda: 1e-4,
            epochs: 12,
            seed: 0,
        }
    }
}

impl LinearSvm {
    /// Train OVR pegasos on rows of x with integer labels in [0, classes).
    pub fn train(x: &Mat, labels: &[usize], classes: usize, cfg: &SvmConfig) -> LinearSvm {
        assert_eq!(x.rows, labels.len());
        let d = x.cols;
        let n = x.rows;
        let mut w = Mat::zeros(classes, d);
        let mut bias = vec![0f32; classes];
        let mut rng = Pcg64::new(cfg.seed);
        let mut order: Vec<usize> = (0..n).collect();

        for c in 0..classes {
            let mut t = 1usize;
            for _epoch in 0..cfg.epochs {
                rng.shuffle(&mut order);
                for &i in &order {
                    let y = if labels[i] == c { 1.0f32 } else { -1.0 };
                    let eta = 1.0 / (cfg.lambda * t as f32);
                    let row = x.row(i);
                    let wrow = w.row_mut(c);
                    let mut score = bias[c];
                    for j in 0..d {
                        score += wrow[j] * row[j];
                    }
                    // regularization shrink
                    let shrink = 1.0 - eta * cfg.lambda;
                    for v in wrow.iter_mut() {
                        *v *= shrink;
                    }
                    if y * score < 1.0 {
                        for j in 0..d {
                            wrow[j] += eta * y * row[j];
                        }
                        bias[c] += eta * y * 0.1; // damped bias update
                    }
                    t += 1;
                }
            }
        }
        LinearSvm { w, bias, classes }
    }

    /// Predict the class of one row.
    pub fn predict(&self, x: &[f32]) -> usize {
        let mut best = (f32::NEG_INFINITY, 0usize);
        for c in 0..self.classes {
            let row = self.w.row(c);
            let mut s = self.bias[c];
            for j in 0..x.len() {
                s += row[j] * x[j];
            }
            if s > best.0 {
                best = (s, c);
            }
        }
        best.1
    }

    /// Accuracy over rows.
    pub fn accuracy(&self, x: &Mat, labels: &[usize]) -> f64 {
        let correct = (0..x.rows)
            .filter(|&i| self.predict(x.row(i)) == labels[i])
            .count();
        correct as f64 / x.rows as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn separates_two_gaussians() {
        let mut rng = Pcg64::new(77);
        let n = 200;
        let d = 8;
        let mut x = Mat::zeros(n, d);
        let mut labels = vec![0usize; n];
        for i in 0..n {
            let c = i % 2;
            labels[i] = c;
            for j in 0..d {
                let center = if c == 0 { 1.0 } else { -1.0 };
                x[(i, j)] = center + 0.5 * rng.normal() as f32;
            }
        }
        let svm = LinearSvm::train(&x, &labels, 2, &SvmConfig::default());
        assert!(svm.accuracy(&x, &labels) > 0.95);
    }

    #[test]
    fn multiclass_beats_chance() {
        let mut rng = Pcg64::new(78);
        let n = 300;
        let d = 12;
        let classes = 4;
        let mut x = Mat::zeros(n, d);
        let mut labels = vec![0usize; n];
        for i in 0..n {
            let c = i % classes;
            labels[i] = c;
            for j in 0..d {
                let center = if j % classes == c { 2.0 } else { 0.0 };
                x[(i, j)] = center + 0.6 * rng.normal() as f32;
            }
        }
        let svm = LinearSvm::train(&x, &labels, classes, &SvmConfig::default());
        assert!(svm.accuracy(&x, &labels) > 0.8);
    }
}
