//! Retrieval evaluation: recall@R curves (the paper's Figures 2–5 metric)
//! and AUC (the §6 semi-supervised metric).

use crate::bits::BitCode;
use crate::index::AnyIndex;

/// recall@R for R = 1..max_r, averaged over queries: the fraction of the
/// true k nearest neighbors found in the top-R Hamming candidates. Works
/// against any retrieval backend (all are exact, so recall is invariant
/// to the backend choice).
pub fn recall_curve(
    index: &dyn AnyIndex,
    query_codes: &BitCode,
    groundtruth: &[Vec<u32>],
    max_r: usize,
) -> Vec<f64> {
    assert_eq!(query_codes.n, groundtruth.len());
    let mut curve = vec![0f64; max_r];
    let mut counted = 0usize;
    for (qi, gt) in groundtruth.iter().enumerate() {
        if gt.is_empty() {
            continue; // query with no relevant items — undefined recall
        }
        counted += 1;
        let hits = index.search(query_codes.code(qi), max_r);
        let gtset: std::collections::HashSet<u32> = gt.iter().cloned().collect();
        let mut found = 0usize;
        for (rank, h) in hits.iter().enumerate() {
            if gtset.contains(&h.id) {
                found += 1;
            }
            curve[rank] += found as f64 / gt.len() as f64;
        }
        // Tiny index (< max_r hits): remaining ranks keep the final recall.
        let tail = found as f64 / gt.len() as f64;
        for rank in hits.len()..max_r {
            curve[rank] += tail;
        }
    }
    for v in curve.iter_mut() {
        *v /= counted.max(1) as f64;
    }
    curve
}

/// Area under the recall@R curve, normalized to [0, 1] — the scalar used
/// for the §6 comparison ("averaged AUC").
pub fn recall_auc(curve: &[f64]) -> f64 {
    if curve.is_empty() {
        return 0.0;
    }
    curve.iter().sum::<f64>() / curve.len() as f64
}

/// Mean of per-position recall at specific cut points (for table output).
pub fn recall_at(curve: &[f64], points: &[usize]) -> Vec<f64> {
    points
        .iter()
        .map(|p| {
            if *p == 0 || curve.is_empty() {
                0.0
            } else {
                curve[(*p - 1).min(curve.len() - 1)]
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::BinaryIndex;
    use crate::util::rng::Pcg64;

    #[test]
    fn perfect_codes_have_recall_one() {
        // Database of distinct codes; each query IS a database item and its
        // own ground truth → recall@1 = 1.
        let mut rng = Pcg64::new(9);
        let bits = 64;
        let n = 30;
        let signs = rng.sign_vec(n * bits);
        let db = BitCode::from_signs(&signs, n, bits);
        let index = BinaryIndex::new(db.clone());
        let gt: Vec<Vec<u32>> = (0..n).map(|i| vec![i as u32]).collect();
        let curve = recall_curve(&index, &db, &gt, 10);
        assert!(curve[0] > 0.95, "recall@1={}", curve[0]);
        assert!(curve[9] >= curve[0]);
    }

    #[test]
    fn curve_monotone_nondecreasing() {
        let mut rng = Pcg64::new(10);
        let bits = 32;
        let n = 40;
        let db = BitCode::from_signs(&rng.sign_vec(n * bits), n, bits);
        let queries = BitCode::from_signs(&rng.sign_vec(5 * bits), 5, bits);
        let gt: Vec<Vec<u32>> = (0..5).map(|i| vec![i as u32, (i + 1) as u32]).collect();
        let curve = recall_curve(&BinaryIndex::new(db), &queries, &gt, 20);
        for w in curve.windows(2) {
            assert!(w[1] >= w[0] - 1e-12);
        }
        let auc = recall_auc(&curve);
        assert!((0.0..=1.0).contains(&auc));
    }

    #[test]
    fn recall_at_points() {
        let curve = vec![0.1, 0.2, 0.3, 0.4];
        assert_eq!(recall_at(&curve, &[1, 4]), vec![0.1, 0.4]);
    }
}
