//! Circulant projection: R·D·x with R = circ(r), computed via FFT.
//!
//! This is the paper's core operator (eq. 4–10):
//!     h(x) = sign(IFFT(FFT(r) ∘ FFT(D·x)))
//! D is a random ±1 diagonal (random sign flips), required so adversarial
//! inputs (e.g. the all-ones vector, §3) still have their norms preserved.
//!
//! # Threading and scratch ownership
//!
//! [`CirculantProjection`] is immutable per encode (`&self` everywhere) and
//! `Send + Sync` — compile-time asserted below — so one projection is
//! shared across threads. All per-call mutable state lives in a
//! caller-owned [`EncodeScratch`]; [`ScratchPool`] keeps one scratch per
//! worker thread for the batch fan-out. With a reused scratch, nothing on
//! the encode path allocates or locks per vector.
//!
//! [`CirculantProjection::encode_batch_into`] is the throughput entry
//! point: it splits rows across core-capped scoped threads (mirroring
//! `ShardedIndex`'s fan-out) and packs signs **directly** into `BitCode`
//! words — no per-row ±1 f32 intermediate.

use crate::bits::BitCode;
use crate::fft::realpack::{RealPackPlan, RealPackScratch};
use crate::fft::{real, C64, Dir, FftScratch, Plan, Planner};
use crate::util::rng::Pcg64;
use crate::CbeError;
use std::sync::Arc;

// Below a total work (rows × d) of [`crate::tune::min_parallel_work`] —
// calibrated once per process, fixed 2^14 fallback — the scoped-thread
// fan-out costs more than it saves and `encode_batch_into` degrades to a
// serial sweep. The trainer fan-out consults the same threshold.

/// Per-thread mutable state for one projection's encode/project calls.
/// Buffers grow to the projection's d on first use and are reused; keep
/// one per thread (see [`ScratchPool`]) for allocation-free encoding.
#[derive(Default)]
pub struct EncodeScratch {
    /// Full-complex work buffer (odd-d path), len d.
    cplx: Vec<C64>,
    /// Half-spectrum buffer (even-d realpack path), len d/2 + 1.
    spec: Vec<C64>,
    /// Real projection output before binarization, len d.
    vals: Vec<f32>,
    /// Nested real-pack scratch (packed half-size buffer + FFT work).
    rp: RealPackScratch,
    /// FFT work buffer for the full-complex Bluestein path.
    fft: FftScratch,
}

impl EncodeScratch {
    pub fn new() -> EncodeScratch {
        EncodeScratch::default()
    }
}

/// A bag of [`EncodeScratch`]es, one per worker thread of the batch
/// fan-out. Reuse one pool across batches: slots grow to the thread count
/// and the per-slot buffers stay warm.
#[derive(Default)]
pub struct ScratchPool {
    slots: Vec<EncodeScratch>,
}

impl ScratchPool {
    pub fn new() -> ScratchPool {
        ScratchPool::default()
    }

    /// Hand out exactly `n` scratch slots (growing the pool if needed).
    /// Crate-visible so the stacked/downsampled wrappers in this module
    /// tree drive their batch fan-outs through the same warm pool.
    pub(crate) fn slots_mut(&mut self, n: usize) -> &mut [EncodeScratch] {
        if self.slots.len() < n {
            self.slots.resize_with(n, EncodeScratch::new);
        }
        &mut self.slots[..n]
    }
}

/// A circulant projection R = circ(r) with sign-flip diagonal D.
/// Immutable on the encode path and `Send + Sync`: share it behind an
/// `Arc` (or plain `&`) across as many threads as the box has cores.
#[derive(Clone)]
pub struct CirculantProjection {
    pub d: usize,
    /// Defining vector r (first column of R).
    pub r: Vec<f32>,
    /// ±1 sign flips (the diagonal of D).
    pub signs: Vec<f32>,
    /// Cached FFT(r).
    r_spec: Vec<C64>,
    planner: Planner,
    /// Full-complex plan for size d (odd-d path), resolved once.
    full_plan: Arc<Plan>,
    /// Half-size real-FFT fast path (even d): ~1.8× over the full-complex
    /// path on the encode hot loop (perf pass iteration 3).
    half: Option<HalfPath>,
}

/// Even-d fast path state. Clones share the underlying plan cache (the
/// `RealPackPlan` clone is table + `Arc` copies — no twiddle recompute).
#[derive(Clone)]
struct HalfPath {
    plan: RealPackPlan,
    /// FFT(r) half spectrum, len d/2 + 1.
    r_half: Vec<C64>,
}

thread_local! {
    /// Per-thread scratch backing the allocating convenience wrappers
    /// ([`CirculantProjection::project`]/[`CirculantProjection::encode`])
    /// so per-row loops stay allocation-free; the explicit-scratch entry
    /// points never touch it, and it lives outside the shared types, so
    /// nothing here affects `Send`/`Sync`.
    static WRAPPER_SCRATCH: std::cell::RefCell<EncodeScratch> =
        std::cell::RefCell::new(EncodeScratch::new());
}

// Compile-time guarantee that the shared encode substrate stays
// shareable across threads — interior mutability sneaking back into
// these types fails to build right here.
const _: () = {
    #[allow(dead_code)]
    fn assert_send_sync<T: Send + Sync>() {}
    #[allow(dead_code)]
    fn check() {
        assert_send_sync::<CirculantProjection>();
        assert_send_sync::<Plan>();
        assert_send_sync::<Planner>();
        assert_send_sync::<RealPackPlan>();
    }
};

impl CirculantProjection {
    /// Build from an explicit r (and signs).
    pub fn new(r: Vec<f32>, signs: Vec<f32>, planner: Planner) -> CirculantProjection {
        assert_eq!(r.len(), signs.len());
        let d = r.len();
        let r_spec = real::rfft_full(&planner, &r);
        let half = if d >= 2 && d % 2 == 0 {
            let plan = RealPackPlan::new(d, &planner);
            let mut r_half = vec![C64::ZERO; d / 2 + 1];
            plan.rfft(&r, None, &mut r_half, &mut RealPackScratch::new());
            Some(HalfPath { plan, r_half })
        } else {
            None
        };
        let full_plan = planner.plan(d);
        CirculantProjection {
            d,
            r,
            signs,
            r_spec,
            planner,
            full_plan,
            half,
        }
    }

    /// CBE-rand: r ~ N(0,1), signs ~ ±1 uniform.
    pub fn random(d: usize, rng: &mut Pcg64, planner: Planner) -> CirculantProjection {
        let r = rng.normal_vec(d);
        let signs = rng.sign_vec(d);
        CirculantProjection::new(r, signs, planner)
    }

    /// Replace r (e.g. after a learning step), refreshing the cached FFTs.
    pub fn set_r(&mut self, r: Vec<f32>) {
        assert_eq!(r.len(), self.d);
        self.r_spec = real::rfft_full(&self.planner, &r);
        if let Some(h) = &mut self.half {
            let mut scratch = RealPackScratch::new();
            h.plan.rfft(&r, None, &mut h.r_half, &mut scratch);
        }
        self.r = r;
    }

    /// Project one vector: y = R·D·x (full d outputs, no binarization).
    /// Backed by a per-thread scratch, so per-row loops (experiments,
    /// `encode_signs`) don't reallocate buffers every call.
    pub fn project(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; self.d];
        WRAPPER_SCRATCH.with(|s| self.project_into(x, &mut out, &mut s.borrow_mut()));
        out
    }

    /// Allocation-free projection into a caller buffer (hot path; reuse
    /// the scratch across calls).
    pub fn project_into(&self, x: &[f32], out: &mut [f32], scratch: &mut EncodeScratch) {
        assert_eq!(x.len(), self.d);
        assert_eq!(out.len(), self.d);
        if let Some(h) = &self.half {
            let EncodeScratch { spec, rp, .. } = scratch;
            spec.resize(self.d / 2 + 1, C64::ZERO);
            h.plan.rfft(x, Some(&self.signs), spec, rp);
            crate::fft::cmul_in_place(spec, &h.r_half);
            h.plan.irfft(spec, out, rp);
            return;
        }
        self.full_project(x, scratch);
        for (o, c) in out.iter_mut().zip(scratch.cplx.iter()) {
            *o = c.re as f32;
        }
    }

    /// Typed code-length guard: one circulant block produces at most `d`
    /// bits, so any `k > d` is `Err(CbeError::BadCodeLength)`. The config
    /// seams (spec parsing, encoder constructors,
    /// [`crate::coordinator::EmbeddingService`] startup) call this and
    /// surface the error to the operator; the encode entry points below
    /// route their internal invariant through it too, so a violation that
    /// slips past config validation still names k, d and the cap instead
    /// of tripping a bare `assert!(k <= d)`.
    pub fn check_code_length(&self, k: usize) -> Result<(), CbeError> {
        if k <= self.d {
            Ok(())
        } else {
            Err(CbeError::BadCodeLength {
                k,
                d: self.d,
                max: self.d,
            })
        }
    }

    /// Hot-path form of [`CirculantProjection::check_code_length`]: the
    /// caller was supposed to validate at config time, so a violation
    /// here is a bug — but it dies naming the numbers.
    fn require_code_length(&self, k: usize) {
        if let Err(e) = self.check_code_length(k) {
            panic!("{e}");
        }
    }

    /// k-bit binary code: sign of the first k projections (k ≤ d).
    /// Backed by the same per-thread scratch as
    /// [`CirculantProjection::project`].
    pub fn encode(&self, x: &[f32], k: usize) -> Vec<f32> {
        self.require_code_length(k);
        let mut out = vec![0f32; k];
        WRAPPER_SCRATCH.with(|s| self.encode_into(x, &mut out, &mut s.borrow_mut()));
        out
    }

    /// Allocation-free encode into a ±1 buffer of length k (hot path;
    /// reuse the scratch across calls).
    pub fn encode_into(&self, x: &[f32], out: &mut [f32], scratch: &mut EncodeScratch) {
        let k = out.len();
        self.require_code_length(k);
        assert_eq!(x.len(), self.d);
        if let Some(h) = &self.half {
            let vals = self.half_project(h, x, scratch);
            for (o, v) in out.iter_mut().zip(vals.iter()) {
                *o = if *v >= 0.0 { 1.0 } else { -1.0 };
            }
            return;
        }
        self.full_project(x, scratch);
        for (o, c) in out.iter_mut().zip(scratch.cplx.iter()) {
            *o = if c.re >= 0.0 { 1.0 } else { -1.0 };
        }
    }

    /// Encode one vector straight into packed `BitCode` words (bit b set
    /// iff projection b is ≥ 0) — bit-exactly the composition of
    /// [`CirculantProjection::encode_into`] with
    /// [`BitCode::set_row_from_signs`], without the ±1 f32 intermediate.
    /// `words` must hold exactly `k.div_ceil(64)` words (one `BitCode`
    /// row); trailing pad bits are written as zero.
    pub fn encode_bits_into(
        &self,
        x: &[f32],
        k: usize,
        words: &mut [u64],
        scratch: &mut EncodeScratch,
    ) {
        self.require_code_length(k);
        assert_eq!(words.len(), k.div_ceil(64));
        words.fill(0);
        self.or_sign_bits(x, k, 0, words, scratch);
    }

    /// OR the sign bits of projections `0..k` into `words` at bit offset
    /// `bit0`: bit `bit0 + j` of the window is set iff projection j of
    /// this block is ≥ 0. This is the shared packing engine behind
    /// [`CirculantProjection::encode_bits_into`] (offset 0) and the
    /// multi-block [`super::StackedCirculant`], whose block b writes its
    /// sign window at `bit0 = b·d` — windows of adjacent blocks may share
    /// a boundary word, hence OR into caller-zeroed words rather than
    /// overwrite. The sign decision is identical to
    /// [`CirculantProjection::encode_into`]: the half path compares the
    /// same f32 values, the odd-d path compares `c.re` in f64 **before**
    /// the cast (an f64→f32 cast can round a tiny negative to -0.0, which
    /// would flip the `>= 0.0` verdict).
    pub fn or_sign_bits(
        &self,
        x: &[f32],
        k: usize,
        bit0: usize,
        words: &mut [u64],
        scratch: &mut EncodeScratch,
    ) {
        self.require_code_length(k);
        assert_eq!(x.len(), self.d);
        assert!(words.len() * 64 >= bit0 + k, "word window too short");
        if let Some(h) = &self.half {
            let vals = self.half_project(h, x, scratch);
            for (j, v) in vals[..k].iter().enumerate() {
                if *v >= 0.0 {
                    let bit = bit0 + j;
                    words[bit >> 6] |= 1u64 << (bit & 63);
                }
            }
            return;
        }
        self.full_project(x, scratch);
        for (j, c) in scratch.cplx[..k].iter().enumerate() {
            if c.re >= 0.0 {
                let bit = bit0 + j;
                words[bit >> 6] |= 1u64 << (bit & 63);
            }
        }
    }

    /// OR the sign bits of a *selected* subset of projection rows into
    /// `words` at bit offset `bit0`: bit `bit0 + i` is set iff projection
    /// `sel[i]` is ≥ 0. One projection (one FFT round-trip) feeds all
    /// selected bits — this is the engine behind
    /// [`super::DownsampledCirculant`], where `sel` is a seeded sparse
    /// row-selection of k ≪ d rows. Every entry of `sel` must be < d.
    /// Sign decisions match [`CirculantProjection::encode_into`] exactly
    /// (same f32/f64 comparisons as [`CirculantProjection::or_sign_bits`]).
    pub fn or_selected_sign_bits(
        &self,
        x: &[f32],
        sel: &[u32],
        bit0: usize,
        words: &mut [u64],
        scratch: &mut EncodeScratch,
    ) {
        assert_eq!(x.len(), self.d);
        assert!(words.len() * 64 >= bit0 + sel.len(), "word window too short");
        if let Some(h) = &self.half {
            let vals = self.half_project(h, x, scratch);
            for (i, &row) in sel.iter().enumerate() {
                if vals[row as usize] >= 0.0 {
                    let bit = bit0 + i;
                    words[bit >> 6] |= 1u64 << (bit & 63);
                }
            }
            return;
        }
        self.full_project(x, scratch);
        for (i, &row) in sel.iter().enumerate() {
            if scratch.cplx[row as usize].re >= 0.0 {
                let bit = bit0 + i;
                words[bit >> 6] |= 1u64 << (bit & 63);
            }
        }
    }

    /// Batch encode: pack the k-bit codes of `rows` into `out` (row i of
    /// `out` = code of `rows[i]`), fanning out across scoped threads
    /// capped at the core count. Bit-exactly equal to per-vector
    /// [`CirculantProjection::encode_into`] +
    /// [`BitCode::set_row_from_signs`] for every row, at any thread
    /// count. Pass a reused [`ScratchPool`] to keep per-thread buffers
    /// warm across batches.
    pub fn encode_batch_into(
        &self,
        rows: &[&[f32]],
        k: usize,
        out: &mut BitCode,
        pool: &mut ScratchPool,
    ) {
        assert_eq!(out.n, rows.len());
        assert_eq!(out.bits, k);
        self.encode_batch_words(rows, k, &mut out.data, out.words_per_code, pool);
    }

    /// The batch engine over a bare packed-word window: row i of `rows`
    /// is encoded into `words[i*wpc .. (i+1)*wpc]`. This is what lets
    /// [`crate::coordinator::EmbeddingService::encode_corpus`] stream a
    /// large corpus through the fan-out in bounded slabs — each slab
    /// targets a disjoint window of one big `BitCode` without any copy
    /// or stitching step. `wpc` must equal `k.div_ceil(64)` (one
    /// `BitCode` row).
    pub fn encode_batch_words(
        &self,
        rows: &[&[f32]],
        k: usize,
        words: &mut [u64],
        wpc: usize,
        pool: &mut ScratchPool,
    ) {
        self.require_code_length(k);
        assert_eq!(wpc, k.div_ceil(64));
        assert_eq!(words.len(), rows.len() * wpc);
        let n = rows.len();
        if n == 0 {
            return;
        }
        let cores = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        let threads = cores.min(n);
        if threads <= 1 || n * self.d < crate::tune::min_parallel_work() {
            let scratch = &mut pool.slots_mut(1)[0];
            for (row, words) in rows.iter().zip(words.chunks_mut(wpc)) {
                self.encode_bits_into(row, k, words, scratch);
            }
            return;
        }
        // Contiguous row ranges per thread; each worker owns a disjoint
        // &mut window of the packed words, so no synchronization beyond
        // the scope join.
        let chunk = n.div_ceil(threads);
        std::thread::scope(|scope| {
            let mut rest_rows = rows;
            let mut rest_words = words;
            for scratch in pool.slots_mut(threads) {
                if rest_rows.is_empty() {
                    break;
                }
                let take = chunk.min(rest_rows.len());
                let (row_chunk, tail_rows) = rest_rows.split_at(take);
                let (word_chunk, tail_words) = rest_words.split_at_mut(take * wpc);
                rest_rows = tail_rows;
                rest_words = tail_words;
                scope.spawn(move || {
                    for (row, words) in row_chunk.iter().zip(word_chunk.chunks_mut(wpc)) {
                        self.encode_bits_into(row, k, words, scratch);
                    }
                });
            }
        });
    }

    /// Even-d path: project via the half-spectrum plan into
    /// `scratch.vals`; returns the d real projection values.
    fn half_project<'s>(
        &self,
        h: &HalfPath,
        x: &[f32],
        scratch: &'s mut EncodeScratch,
    ) -> &'s [f32] {
        let spec = &mut scratch.spec;
        let vals = &mut scratch.vals;
        let rp = &mut scratch.rp;
        spec.resize(self.d / 2 + 1, C64::ZERO);
        h.plan.rfft(x, Some(&self.signs), spec, rp);
        crate::fft::cmul_in_place(spec, &h.r_half);
        vals.resize(self.d, 0.0);
        h.plan.irfft(spec, vals, rp);
        vals
    }

    /// Odd-d path: full-complex convolution; leaves IFFT(FFT(r)∘FFT(Dx))
    /// in `scratch.cplx` (real parts are the projection values).
    fn full_project(&self, x: &[f32], scratch: &mut EncodeScratch) {
        let EncodeScratch { cplx, fft, .. } = scratch;
        cplx.clear();
        cplx.extend(
            x.iter()
                .zip(&self.signs)
                .map(|(v, s)| C64::new((*v * *s) as f64, 0.0)),
        );
        self.full_plan.transform_with(cplx, Dir::Forward, fft);
        crate::fft::cmul_in_place(cplx, &self.r_spec);
        self.full_plan.transform_with(cplx, Dir::Inverse, fft);
    }

    /// Naive O(d²) oracle: materialize circ(r)·D·x row by row.
    /// Row i of circ(r) is [r_i, r_{i-1}, ..., r_0, r_{d-1}, ..., r_{i+1}]
    /// (indices mod d), i.e. (Rx)_i = Σ_j r_{(i-j) mod d} x_j.
    pub fn project_naive(&self, x: &[f32]) -> Vec<f32> {
        let d = self.d;
        let xs: Vec<f64> = x
            .iter()
            .zip(&self.signs)
            .map(|(v, s)| (*v * *s) as f64)
            .collect();
        let mut y = vec![0f64; d];
        for i in 0..d {
            let mut acc = 0f64;
            for j in 0..d {
                let ridx = (i + d - j) % d;
                acc += self.r[ridx] as f64 * xs[j];
            }
            y[i] = acc;
        }
        y.iter().map(|v| *v as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::forall;

    #[test]
    fn fft_path_matches_naive() {
        forall("circulant fft == naive", 30, |g| {
            let d = g.usize_in(2, 96);
            let planner = Planner::new();
            let proj = CirculantProjection::random(d, g.rng(), planner);
            let x = g.normal_vec(d);
            let fast = proj.project(&x);
            let slow = proj.project_naive(&x);
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "d={d} {a} vs {b}");
            }
        });
    }

    #[test]
    fn convolution_identity() {
        // r = e_0 (delta) makes R = I, so project(x) == D·x.
        let planner = Planner::new();
        let d = 16;
        let mut r = vec![0f32; d];
        r[0] = 1.0;
        let mut rng = Pcg64::new(5);
        let signs = rng.sign_vec(d);
        let proj = CirculantProjection::new(r, signs.clone(), planner);
        let x: Vec<f32> = (0..d).map(|i| i as f32 - 5.0).collect();
        let y = proj.project(&x);
        for i in 0..d {
            assert!((y[i] - x[i] * signs[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn encode_prefix_property() {
        let planner = Planner::new();
        let mut rng = Pcg64::new(6);
        let d = 32;
        let proj = CirculantProjection::random(d, &mut rng, planner);
        let x = rng.normal_vec(d);
        let full = proj.encode(&x, d);
        let k = 10;
        let part = proj.encode(&x, k);
        assert_eq!(part, full[..k].to_vec());
    }

    #[test]
    fn all_ones_attack_handled_by_signs() {
        // §3: without D, circ(r)·1 has all-equal entries (rᵀ1) — degenerate.
        // With D, the projected norm stays healthy.
        let planner = Planner::new();
        let mut rng = Pcg64::new(7);
        let d = 256;
        let proj = CirculantProjection::random(d, &mut rng, planner.clone());
        let ones = vec![1f32; d];
        let y = proj.project(&ones);
        let norm: f64 = y.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        let input_norm = (d as f64).sqrt();
        // E[norm] ≈ sqrt(d)·input_norm/sqrt(d) scale: expect same order.
        assert!(norm > 0.2 * input_norm * (d as f64).sqrt() / 2.0);

        // Without sign flips the output really is constant across entries.
        let no_d = CirculantProjection::new(proj.r.clone(), vec![1f32; d], planner);
        let y2 = no_d.project(&ones);
        let spread = y2
            .iter()
            .map(|v| (*v - y2[0]).abs())
            .fold(0f32, f32::max);
        assert!(spread < 1e-3, "spread={spread}");
    }

    #[test]
    fn clone_shares_plans_and_matches() {
        // Regression: HalfPath::clone used to rebuild its RealPackPlan
        // with a fresh empty Planner, silently dropping the shared plan
        // cache. Clones must produce identical codes (and share tables).
        let planner = Planner::new();
        let mut rng = Pcg64::new(41);
        for d in [64usize, 100, 33] {
            let proj = CirculantProjection::random(d, &mut rng, planner.clone());
            let cloned = proj.clone();
            let x = rng.normal_vec(d);
            assert_eq!(proj.encode(&x, d), cloned.encode(&x, d), "d={d}");
        }
    }

    #[test]
    fn batch_matches_per_vector_bits() {
        forall("batch == per-vector packed bits", 20, |g| {
            let d = g.usize_in(2, 80);
            let k = g.usize_in(1, d);
            let n = g.usize_in(1, 12);
            let planner = Planner::new();
            let proj = CirculantProjection::random(d, g.rng(), planner);
            let flat: Vec<Vec<f32>> = (0..n).map(|_| g.normal_vec(d)).collect();
            let rows: Vec<&[f32]> = flat.iter().map(|r| r.as_slice()).collect();
            let mut batch = BitCode::new(n, k);
            proj.encode_batch_into(&rows, k, &mut batch, &mut ScratchPool::new());
            let mut per_vec = BitCode::new(n, k);
            for (i, row) in rows.iter().enumerate() {
                per_vec.set_row_from_signs(i, &proj.encode(row, k));
            }
            assert_eq!(batch, per_vec, "d={d} k={k} n={n}");
        });
    }

    #[test]
    fn code_length_guard_is_typed_not_a_bare_assert() {
        let planner = Planner::new();
        let mut rng = Pcg64::new(9);
        let proj = CirculantProjection::random(16, &mut rng, planner);
        assert!(proj.check_code_length(16).is_ok());
        assert_eq!(
            proj.check_code_length(17),
            Err(CbeError::BadCodeLength { k: 17, d: 16, max: 16 })
        );
        let msg = proj.check_code_length(17).unwrap_err().to_string();
        assert!(msg.contains("17") && msg.contains("16"), "{msg}");
    }

    #[test]
    fn or_sign_bits_at_any_offset_matches_the_packed_encode() {
        forall("or_sign_bits offset == shifted encode_bits_into", 30, |g| {
            let d = g.usize_in(2, 80);
            let k = g.usize_in(1, d);
            let bit0 = g.usize_in(0, 130);
            let planner = Planner::new();
            let proj = CirculantProjection::random(d, g.rng(), planner);
            let x = g.normal_vec(d);
            let mut direct = vec![0u64; k.div_ceil(64)];
            let mut scratch = EncodeScratch::new();
            proj.encode_bits_into(&x, k, &mut direct, &mut scratch);
            let mut shifted = vec![0u64; (bit0 + k).div_ceil(64)];
            proj.or_sign_bits(&x, k, bit0, &mut shifted, &mut scratch);
            for j in 0..k {
                let a = direct[j >> 6] >> (j & 63) & 1;
                let bit = bit0 + j;
                let b = shifted[bit >> 6] >> (bit & 63) & 1;
                assert_eq!(a, b, "d={d} k={k} bit0={bit0} j={j}");
            }
            // No stray bits outside the window.
            let set: u32 = shifted.iter().map(|w| w.count_ones()).sum();
            let expect: u32 = direct.iter().map(|w| w.count_ones()).sum();
            assert_eq!(set, expect, "d={d} k={k} bit0={bit0}");
        });
    }

    #[test]
    fn selected_sign_bits_match_the_full_code_rows() {
        forall("or_selected_sign_bits == full-code gather", 30, |g| {
            let d = g.usize_in(2, 80);
            let k = g.usize_in(1, d);
            let planner = Planner::new();
            let proj = CirculantProjection::random(d, g.rng(), planner);
            let x = g.normal_vec(d);
            let sel: Vec<u32> = g.rng().sample_indices(d, k).iter().map(|&i| i as u32).collect();
            let mut words = vec![0u64; k.div_ceil(64)];
            let mut scratch = EncodeScratch::new();
            proj.or_selected_sign_bits(&x, &sel, 0, &mut words, &mut scratch);
            let full = proj.encode(&x, d);
            for (i, &row) in sel.iter().enumerate() {
                let got = words[i >> 6] >> (i & 63) & 1;
                let want = u64::from(full[row as usize] >= 0.0);
                assert_eq!(got, want, "d={d} k={k} i={i} row={row}");
            }
        });
    }

    use crate::util::rng::Pcg64;
}
