//! Circulant projection: R·D·x with R = circ(r), computed via FFT.
//!
//! This is the paper's core operator (eq. 4–10):
//!     h(x) = sign(IFFT(FFT(r) ∘ FFT(D·x)))
//! D is a random ±1 diagonal (random sign flips), required so adversarial
//! inputs (e.g. the all-ones vector, §3) still have their norms preserved.

use crate::fft::{real, C64, Planner};
use crate::util::rng::Pcg64;

/// A circulant projection R = circ(r) with sign-flip diagonal D.
#[derive(Clone)]
pub struct CirculantProjection {
    pub d: usize,
    /// Defining vector r (first column of R).
    pub r: Vec<f32>,
    /// ±1 sign flips (the diagonal of D).
    pub signs: Vec<f32>,
    /// Cached FFT(r).
    r_spec: Vec<C64>,
    planner: Planner,
    /// Reusable complex work buffer — a d=2^16 projection would otherwise
    /// pay a 1 MB allocation per call (perf pass, EXPERIMENTS.md §Perf).
    scratch: std::cell::RefCell<Vec<C64>>,
    /// Half-size real-FFT fast path (even d): ~1.8× over the full-complex
    /// path on the encode hot loop (perf pass iteration 3).
    half: Option<HalfPath>,
}

struct HalfPath {
    plan: crate::fft::realpack::RealPackPlan,
    /// FFT(r) half spectrum, len d/2 + 1.
    r_half: Vec<C64>,
    spec_buf: std::cell::RefCell<Vec<C64>>,
    out_buf: std::cell::RefCell<Vec<f32>>,
}

impl Clone for HalfPath {
    fn clone(&self) -> Self {
        HalfPath {
            plan: crate::fft::realpack::RealPackPlan::new(
                self.plan.d,
                Planner::new(),
            ),
            r_half: self.r_half.clone(),
            spec_buf: self.spec_buf.clone(),
            out_buf: self.out_buf.clone(),
        }
    }
}

impl CirculantProjection {
    /// Build from an explicit r (and signs).
    pub fn new(r: Vec<f32>, signs: Vec<f32>, planner: Planner) -> CirculantProjection {
        assert_eq!(r.len(), signs.len());
        let d = r.len();
        let r_spec = real::rfft_full(&planner, &r);
        let half = if d >= 2 && d % 2 == 0 {
            let plan = crate::fft::realpack::RealPackPlan::new(d, planner.clone());
            let mut r_half = vec![C64::ZERO; d / 2 + 1];
            plan.rfft(&r, None, &mut r_half);
            Some(HalfPath {
                plan,
                r_half,
                spec_buf: std::cell::RefCell::new(vec![C64::ZERO; d / 2 + 1]),
                out_buf: std::cell::RefCell::new(vec![0f32; d]),
            })
        } else {
            None
        };
        CirculantProjection {
            d,
            r,
            signs,
            r_spec,
            planner,
            scratch: std::cell::RefCell::new(Vec::new()),
            half,
        }
    }

    /// CBE-rand: r ~ N(0,1), signs ~ ±1 uniform.
    pub fn random(d: usize, rng: &mut Pcg64, planner: Planner) -> CirculantProjection {
        let r = rng.normal_vec(d);
        let signs = rng.sign_vec(d);
        CirculantProjection::new(r, signs, planner)
    }

    /// Replace r (e.g. after a learning step), refreshing the cached FFTs.
    pub fn set_r(&mut self, r: Vec<f32>) {
        assert_eq!(r.len(), self.d);
        self.r_spec = real::rfft_full(&self.planner, &r);
        if let Some(h) = &mut self.half {
            h.plan.rfft(&r, None, &mut h.r_half);
        }
        self.r = r;
    }

    /// Project one vector: y = R·D·x (full d outputs, no binarization).
    pub fn project(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; self.d];
        self.project_into(x, &mut out);
        out
    }

    /// Allocation-free projection into a caller buffer (hot path).
    pub fn project_into(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.d);
        assert_eq!(out.len(), self.d);
        if let Some(h) = &self.half {
            let mut spec = h.spec_buf.borrow_mut();
            h.plan.rfft(x, Some(&self.signs), &mut spec);
            for (s, rs) in spec.iter_mut().zip(&h.r_half) {
                *s = *s * *rs;
            }
            h.plan.irfft(&spec, out);
            return;
        }
        let mut buf = self.scratch.borrow_mut();
        buf.clear();
        buf.extend(
            x.iter()
                .zip(&self.signs)
                .map(|(v, s)| C64::new((*v * *s) as f64, 0.0)),
        );
        self.planner.fft(&mut buf);
        for (b, rs) in buf.iter_mut().zip(&self.r_spec) {
            *b = *b * *rs;
        }
        self.planner.ifft(&mut buf);
        for (o, c) in out.iter_mut().zip(buf.iter()) {
            *o = c.re as f32;
        }
    }

    /// k-bit binary code: sign of the first k projections (k ≤ d).
    pub fn encode(&self, x: &[f32], k: usize) -> Vec<f32> {
        assert!(k <= self.d);
        let mut out = vec![0f32; k];
        self.encode_into(x, &mut out);
        out
    }

    /// Allocation-light encode into a caller buffer of length k.
    pub fn encode_into(&self, x: &[f32], out: &mut [f32]) {
        let k = out.len();
        assert!(k <= self.d);
        assert_eq!(x.len(), self.d);
        if let Some(h) = &self.half {
            let mut spec = h.spec_buf.borrow_mut();
            h.plan.rfft(x, Some(&self.signs), &mut spec);
            for (s, rs) in spec.iter_mut().zip(&h.r_half) {
                *s = *s * *rs;
            }
            let mut full = h.out_buf.borrow_mut();
            h.plan.irfft(&spec, &mut full);
            for (o, v) in out.iter_mut().zip(full.iter()) {
                *o = if *v >= 0.0 { 1.0 } else { -1.0 };
            }
            return;
        }
        let mut buf = self.scratch.borrow_mut();
        buf.clear();
        buf.extend(
            x.iter()
                .zip(&self.signs)
                .map(|(v, s)| C64::new((*v * *s) as f64, 0.0)),
        );
        self.planner.fft(&mut buf);
        for (b, rs) in buf.iter_mut().zip(&self.r_spec) {
            *b = *b * *rs;
        }
        self.planner.ifft(&mut buf);
        for (o, c) in out.iter_mut().zip(buf.iter()) {
            *o = if c.re >= 0.0 { 1.0 } else { -1.0 };
        }
    }

    /// Naive O(d²) oracle: materialize circ(r)·D·x row by row.
    /// Row i of circ(r) is [r_i, r_{i-1}, ..., r_0, r_{d-1}, ..., r_{i+1}]
    /// (indices mod d), i.e. (Rx)_i = Σ_j r_{(i-j) mod d} x_j.
    pub fn project_naive(&self, x: &[f32]) -> Vec<f32> {
        let d = self.d;
        let xs: Vec<f64> = x
            .iter()
            .zip(&self.signs)
            .map(|(v, s)| (*v * *s) as f64)
            .collect();
        let mut y = vec![0f64; d];
        for i in 0..d {
            let mut acc = 0f64;
            for j in 0..d {
                let ridx = (i + d - j) % d;
                acc += self.r[ridx] as f64 * xs[j];
            }
            y[i] = acc;
        }
        y.iter().map(|v| *v as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::forall;

    #[test]
    fn fft_path_matches_naive() {
        forall("circulant fft == naive", 30, |g| {
            let d = g.usize_in(2, 96);
            let planner = Planner::new();
            let proj = CirculantProjection::random(d, g.rng(), planner);
            let x = g.normal_vec(d);
            let fast = proj.project(&x);
            let slow = proj.project_naive(&x);
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "d={d} {a} vs {b}");
            }
        });
    }

    #[test]
    fn convolution_identity() {
        // r = e_0 (delta) makes R = I, so project(x) == D·x.
        let planner = Planner::new();
        let d = 16;
        let mut r = vec![0f32; d];
        r[0] = 1.0;
        let mut rng = Pcg64::new(5);
        let signs = rng.sign_vec(d);
        let proj = CirculantProjection::new(r, signs.clone(), planner);
        let x: Vec<f32> = (0..d).map(|i| i as f32 - 5.0).collect();
        let y = proj.project(&x);
        for i in 0..d {
            assert!((y[i] - x[i] * signs[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn encode_prefix_property() {
        let planner = Planner::new();
        let mut rng = Pcg64::new(6);
        let d = 32;
        let proj = CirculantProjection::random(d, &mut rng, planner);
        let x = rng.normal_vec(d);
        let full = proj.encode(&x, d);
        let k = 10;
        let part = proj.encode(&x, k);
        assert_eq!(part, full[..k].to_vec());
    }

    #[test]
    fn all_ones_attack_handled_by_signs() {
        // §3: without D, circ(r)·1 has all-equal entries (rᵀ1) — degenerate.
        // With D, the projected norm stays healthy.
        let planner = Planner::new();
        let mut rng = Pcg64::new(7);
        let d = 256;
        let proj = CirculantProjection::random(d, &mut rng, planner.clone());
        let ones = vec![1f32; d];
        let y = proj.project(&ones);
        let norm: f64 = y.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        let input_norm = (d as f64).sqrt();
        // E[norm] ≈ sqrt(d)·input_norm/sqrt(d) scale: expect same order.
        assert!(norm > 0.2 * input_norm * (d as f64).sqrt() / 2.0);

        // Without sign flips the output really is constant across entries.
        let no_d = CirculantProjection::new(proj.r.clone(), vec![1f32; d], planner);
        let y2 = no_d.project(&ones);
        let spread = y2
            .iter()
            .map(|v| (*v - y2[0]).abs())
            .fold(0f32, f32::max);
        assert!(spread < 1e-3, "spread={spread}");
    }

    use crate::util::rng::Pcg64;
}
