//! Downsampled circulant: k ≪ d codes from one circulant block plus a
//! seeded sparse row-selection (arXiv:1601.06342) — data-independent, no
//! trainer required.
//!
//! A plain circulant at k < d keeps the *first* k rows of circ(r)·D.
//! Adjacent circulant rows are shifts of one vector, so a contiguous
//! prefix is the most-correlated subset you can pick. The downsampled
//! variant instead selects k rows **uniformly at random without
//! replacement** from all d — decorrelating the kept bits at zero extra
//! encode cost: the FFT round-trip already produces all d projection
//! values, selection is a gather.
//!
//! The selection plan is drawn once from the model seed and stored
//! (sorted, for cache-friendly gathers); it is part of the model's
//! identity, folded into the snapshot fingerprint via
//! [`crate::index::persist::fingerprint_chain`] so an index built under
//! one selection can never be served by another.

use super::circulant::{CirculantProjection, EncodeScratch, ScratchPool};
use crate::bits::BitCode;
use crate::fft::Planner;
use crate::util::rng::Pcg64;
use crate::CbeError;

/// One circulant block + a fixed k-row selection plan. The code length
/// is baked in at construction: `bits()` is the only k this model
/// produces (a shorter request takes a prefix of the selected rows).
#[derive(Clone)]
pub struct DownsampledCirculant {
    block: CirculantProjection,
    /// Selected projection rows, strictly increasing, len = bits().
    sel: Vec<u32>,
}

thread_local! {
    static WRAPPER_SCRATCH: std::cell::RefCell<EncodeScratch> =
        std::cell::RefCell::new(EncodeScratch::new());
}

impl DownsampledCirculant {
    /// Build from an explicit block and selection plan. Entries of `sel`
    /// must be distinct, sorted ascending and < d.
    pub fn new(
        block: CirculantProjection,
        sel: Vec<u32>,
    ) -> Result<DownsampledCirculant, CbeError> {
        let d = block.d;
        if sel.is_empty() || sel.len() > d {
            return Err(CbeError::BadCodeLength {
                k: sel.len(),
                d,
                max: d,
            });
        }
        let ordered = sel.windows(2).all(|w| w[0] < w[1]);
        if !ordered || sel.last().is_some_and(|&i| i as usize >= d) {
            return Err(CbeError::Service(format!(
                "downsampled selection must be strictly increasing row indices < d={d}"
            )));
        }
        Ok(DownsampledCirculant { block, sel })
    }

    /// Seeded model: r ~ N(0,1) and D ~ ±1 drawn exactly like
    /// [`CirculantProjection::random`], then k of the d rows sampled
    /// without replacement from the same stream.
    pub fn random(
        d: usize,
        k: usize,
        rng: &mut Pcg64,
        planner: Planner,
    ) -> Result<DownsampledCirculant, CbeError> {
        if k == 0 || k > d {
            return Err(CbeError::BadCodeLength { k, d, max: d });
        }
        let block = CirculantProjection::random(d, rng, planner);
        let mut sel: Vec<u32> = rng.sample_indices(d, k).iter().map(|&i| i as u32).collect();
        sel.sort_unstable();
        DownsampledCirculant::new(block, sel)
    }

    /// Input dimension.
    pub fn d(&self) -> usize {
        self.block.d
    }

    /// The underlying circulant block.
    pub fn block(&self) -> &CirculantProjection {
        &self.block
    }

    /// The selection plan (strictly increasing row indices).
    pub fn selection(&self) -> &[u32] {
        &self.sel
    }

    /// Code length the selection plan produces.
    pub fn max_bits(&self) -> usize {
        self.sel.len()
    }

    /// Typed code-length guard: requests past the selection length are
    /// `Err(CbeError::BadCodeLength)` (the cap is the plan, not d).
    pub fn check_code_length(&self, k: usize) -> Result<(), CbeError> {
        if k <= self.sel.len() {
            Ok(())
        } else {
            Err(CbeError::BadCodeLength {
                k,
                d: self.block.d,
                max: self.sel.len(),
            })
        }
    }

    fn require_code_length(&self, k: usize) {
        if let Err(e) = self.check_code_length(k) {
            panic!("{e}");
        }
    }

    /// k-bit ±1 code: sign of projection `sel[i]` at position i. One
    /// projection round-trip feeds all k bits.
    pub fn encode(&self, x: &[f32], k: usize) -> Vec<f32> {
        self.require_code_length(k);
        let mut out = vec![0f32; k];
        // Route the ±1 path through the same packed-bit decision as the
        // batch engine: for odd d the sign is taken on the f64 real part
        // *before* the f32 cast (a tiny negative can round to -0.0 and
        // flip a post-cast `>= 0.0`), so deriving signs from the words
        // keeps serial ≡ batch bit-exact by construction.
        WRAPPER_SCRATCH.with(|s| {
            let scratch = &mut s.borrow_mut();
            let mut words = vec![0u64; k.div_ceil(64)];
            self.block
                .or_selected_sign_bits(x, &self.sel[..k], 0, &mut words, scratch);
            for (i, o) in out.iter_mut().enumerate() {
                *o = if words[i >> 6] >> (i & 63) & 1 == 1 {
                    1.0
                } else {
                    -1.0
                };
            }
        });
        out
    }

    /// Encode one vector straight into packed words (one `BitCode` row
    /// of exactly `k.div_ceil(64)` words); pad bits zero.
    pub fn encode_bits_into(
        &self,
        x: &[f32],
        k: usize,
        words: &mut [u64],
        scratch: &mut EncodeScratch,
    ) {
        self.require_code_length(k);
        assert_eq!(words.len(), k.div_ceil(64));
        words.fill(0);
        self.block
            .or_selected_sign_bits(x, &self.sel[..k], 0, words, scratch);
    }

    /// Batch encode into a `BitCode`, mirroring
    /// [`CirculantProjection::encode_batch_into`].
    pub fn encode_batch_into(
        &self,
        rows: &[&[f32]],
        k: usize,
        out: &mut BitCode,
        pool: &mut ScratchPool,
    ) {
        assert_eq!(out.n, rows.len());
        assert_eq!(out.bits, k);
        self.encode_batch_words(rows, k, &mut out.data, out.words_per_code, pool);
    }

    /// The batch engine over a bare packed-word window. The per-row work
    /// is the block's full FFT regardless of k, so the fan-out gates on
    /// n·d like the single-block engine.
    pub fn encode_batch_words(
        &self,
        rows: &[&[f32]],
        k: usize,
        words: &mut [u64],
        wpc: usize,
        pool: &mut ScratchPool,
    ) {
        self.require_code_length(k);
        assert_eq!(wpc, k.div_ceil(64));
        assert_eq!(words.len(), rows.len() * wpc);
        let n = rows.len();
        if n == 0 {
            return;
        }
        let cores = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        let threads = cores.min(n);
        if threads <= 1 || n * self.block.d < crate::tune::min_parallel_work() {
            let scratch = &mut pool.slots_mut(1)[0];
            for (row, words) in rows.iter().zip(words.chunks_mut(wpc)) {
                self.encode_bits_into(row, k, words, scratch);
            }
            return;
        }
        let chunk = n.div_ceil(threads);
        std::thread::scope(|scope| {
            let mut rest_rows = rows;
            let mut rest_words = words;
            for scratch in pool.slots_mut(threads) {
                if rest_rows.is_empty() {
                    break;
                }
                let take = chunk.min(rest_rows.len());
                let (row_chunk, tail_rows) = rest_rows.split_at(take);
                let (word_chunk, tail_words) = rest_words.split_at_mut(take * wpc);
                rest_rows = tail_rows;
                rest_words = tail_words;
                scope.spawn(move || {
                    for (row, words) in row_chunk.iter().zip(word_chunk.chunks_mut(wpc)) {
                        self.encode_bits_into(row, k, words, scratch);
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::forall;

    #[test]
    fn selected_bits_are_the_full_codes_rows() {
        forall("downsampled == gathered full code", 25, |g| {
            let d = g.usize_in(2, 96);
            let k = g.usize_in(1, d);
            let planner = Planner::new();
            let seed = g.rng().next_u64();
            let mut rng_a = Pcg64::new(seed);
            let mut rng_b = Pcg64::new(seed);
            let ds = DownsampledCirculant::random(d, k, &mut rng_a, planner.clone()).unwrap();
            let plain = CirculantProjection::random(d, &mut rng_b, planner);
            let x = g.normal_vec(d);
            let full = plain.encode(&x, d);
            let code = ds.encode(&x, k);
            for (i, &row) in ds.selection().iter().enumerate() {
                assert_eq!(
                    code[i], full[row as usize],
                    "d={d} k={k} i={i} row={row}"
                );
            }
        });
    }

    #[test]
    fn batch_matches_per_vector_and_padding_stays_zero() {
        forall("downsampled batch == serial", 15, |g| {
            let d = g.usize_in(2, 80);
            let k = g.usize_in(1, d);
            let n = g.usize_in(0, 10);
            let planner = Planner::new();
            let ds = DownsampledCirculant::random(d, k, g.rng(), planner).unwrap();
            let flat: Vec<Vec<f32>> = (0..n).map(|_| g.normal_vec(d)).collect();
            let rows: Vec<&[f32]> = flat.iter().map(|r| r.as_slice()).collect();
            let mut batch = BitCode::new(n, k);
            ds.encode_batch_into(&rows, k, &mut batch, &mut ScratchPool::new());
            let mut per_vec = BitCode::new(n, k);
            for (i, row) in rows.iter().enumerate() {
                per_vec.set_row_from_signs(i, &ds.encode(row, k));
            }
            assert_eq!(batch, per_vec, "d={d} k={k} n={n}");
            assert!(batch.padding_is_zero());
        });
    }

    #[test]
    fn selection_is_seed_deterministic_and_sorted() {
        let planner = Planner::new();
        let mut a = Pcg64::new(77);
        let mut b = Pcg64::new(77);
        let x = DownsampledCirculant::random(64, 16, &mut a, planner.clone()).unwrap();
        let y = DownsampledCirculant::random(64, 16, &mut b, planner.clone()).unwrap();
        assert_eq!(x.selection(), y.selection());
        assert!(x.selection().windows(2).all(|w| w[0] < w[1]));
        let mut c = Pcg64::new(78);
        let z = DownsampledCirculant::random(64, 16, &mut c, planner).unwrap();
        assert_ne!(x.selection(), z.selection(), "seed must move the plan");
    }

    #[test]
    fn bad_shapes_are_typed_errors() {
        let planner = Planner::new();
        let mut rng = Pcg64::new(5);
        assert_eq!(
            DownsampledCirculant::random(16, 17, &mut rng, planner.clone()).unwrap_err(),
            CbeError::BadCodeLength { k: 17, d: 16, max: 16 }
        );
        assert!(DownsampledCirculant::random(16, 0, &mut rng, planner.clone()).is_err());
        let ds = DownsampledCirculant::random(16, 4, &mut rng, planner.clone()).unwrap();
        assert_eq!(
            ds.check_code_length(5),
            Err(CbeError::BadCodeLength { k: 5, d: 16, max: 4 })
        );
        // Unsorted or out-of-range plans are rejected.
        let block = CirculantProjection::random(8, &mut rng, planner.clone());
        assert!(DownsampledCirculant::new(block.clone(), vec![3, 1]).is_err());
        assert!(DownsampledCirculant::new(block, vec![7, 8]).is_err());
    }
}
