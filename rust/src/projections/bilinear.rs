//! Bilinear projection (Gong et al. 2013a) — the strongest prior baseline.
//!
//! x ∈ R^d is reshaped to Z ∈ R^{d1×d2} (d = d1·d2) and coded as
//! sign(R1ᵀ Z R2) with R1 ∈ R^{d1×k1}, R2 ∈ R^{d2×k2}. With near-square
//! shapes the cost is O(d^1.5) time and O(d) space.

use crate::linalg::Mat;
use crate::util::rng::Pcg64;

/// Bilinear projection with factor matrices R1 (d1×k1) and R2 (d2×k2).
pub struct BilinearProjection {
    pub d1: usize,
    pub d2: usize,
    pub k1: usize,
    pub k2: usize,
    pub r1: Mat,
    pub r2: Mat,
}

/// Pick a near-square factorization d = d1·d2 (d1 ≤ d2, d1 maximal).
pub fn near_square_factors(d: usize) -> (usize, usize) {
    let mut best = (1, d);
    let mut f = 1usize;
    while f * f <= d {
        if d % f == 0 {
            best = (f, d / f);
        }
        f += 1;
    }
    best
}

impl BilinearProjection {
    /// Random gaussian factors producing k = k1·k2 bits.
    pub fn random(d: usize, k: usize, rng: &mut Pcg64) -> BilinearProjection {
        let (d1, d2) = near_square_factors(d);
        let (k1, k2) = near_square_factors(k);
        // Assign the larger k factor to the larger d factor.
        BilinearProjection {
            d1,
            d2,
            k1,
            k2,
            r1: Mat::randn(d1, k1, rng),
            r2: Mat::randn(d2, k2, rng),
        }
    }

    pub fn bits(&self) -> usize {
        self.k1 * self.k2
    }

    /// Project: vec(R1ᵀ · reshape(x, d1×d2) · R2), length k1·k2.
    pub fn project(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.d1 * self.d2);
        // Z is d1×d2 row-major view of x.
        let z = Mat::from_vec(self.d1, self.d2, x.to_vec());
        // T = R1ᵀ Z → k1×d2
        let t = self.r1.transpose().matmul(&z);
        // Y = T R2 → k1×k2
        let y = t.matmul(&self.r2);
        y.data
    }

    /// sign(project(x)).
    pub fn encode(&self, x: &[f32]) -> Vec<f32> {
        self.project(x)
            .iter()
            .map(|v| if *v >= 0.0 { 1.0 } else { -1.0 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factors_near_square() {
        assert_eq!(near_square_factors(16), (4, 4));
        assert_eq!(near_square_factors(12), (3, 4));
        assert_eq!(near_square_factors(25600), (160, 160));
        assert_eq!(near_square_factors(51200), (200, 256));
        assert_eq!(near_square_factors(7), (1, 7));
    }

    #[test]
    fn matches_explicit_kron() {
        // Bilinear code = sign((R1 ⊗ R2)ᵀ-ish projection); verify against the
        // direct double loop definition y_{ab} = Σ_{ij} R1[i,a] Z[i,j] R2[j,b].
        let mut rng = Pcg64::new(111);
        let p = BilinearProjection::random(12, 6, &mut rng);
        let x = rng.normal_vec(12);
        let y = p.project(&x);
        for a in 0..p.k1 {
            for b in 0..p.k2 {
                let mut acc = 0f64;
                for i in 0..p.d1 {
                    for j in 0..p.d2 {
                        acc += p.r1[(i, a)] as f64
                            * x[i * p.d2 + j] as f64
                            * p.r2[(j, b)] as f64;
                    }
                }
                let got = y[a * p.k2 + b] as f64;
                assert!((acc - got).abs() < 1e-4);
            }
        }
    }
}
