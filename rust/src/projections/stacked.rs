//! Stacked circulant blocks: k > d codes from B = ⌈k/d⌉ independent
//! circulant projections (arXiv:1511.06480).
//!
//! One circulant block caps useful bits at d — after that the rows of
//! circ(r) wrap around and bits repeat sign structure. The follow-up
//! analysis shows the fix is embarrassingly simple: draw B independent
//! (r_b, D_b) pairs and concatenate their sign windows,
//!
//! ```text
//! h(x) = [ sign(R_0 D_0 x) ∥ sign(R_1 D_1 x) ∥ … ∥ sign(R_{B-1} D_{B-1} x) ]
//! ```
//!
//! which keeps the independent-bit variance behavior of Figure 1 while
//! costing B half-spectrum FFT round-trips per vector — still
//! O(k log d), never O(kd).
//!
//! # Bit layout and the k == d contract
//!
//! Block b owns the bit window `[b·d, min((b+1)·d, k))` of the packed
//! code; the final block may be truncated. Windows of adjacent blocks
//! share a boundary word whenever d % 64 ≠ 0, so blocks OR their signs
//! into pre-zeroed words via [`CirculantProjection::or_sign_bits`]
//! rather than overwriting whole words. Block 0 writes at offset 0
//! through exactly the code path `CirculantProjection::encode_bits_into`
//! uses, so a one-block `StackedCirculant` is **bit-identical** to the
//! plain circulant — codes, index hits and snapshot fingerprints — which
//! the differential suite (`rust/tests/projection_variants.rs`) enforces.
//!
//! # Threading
//!
//! [`StackedCirculant::encode_batch_words`] reuses the row fan-out of the
//! single-block engine, but sizes the serial cutover and the thread count
//! by the *total* work n·B·d — rows × blocks — so a short batch of very
//! long codes still clears [`crate::tune::min_parallel_work`]. Blocks of
//! one row are not split across threads: adjacent blocks share boundary
//! words, and a per-(row, block) fan-out would need atomic ORs on the
//! shared words for no measurable win (the FFTs dominate).

use super::circulant::{CirculantProjection, EncodeScratch, ScratchPool};
use crate::bits::BitCode;
use crate::fft::Planner;
use crate::util::rng::Pcg64;
use crate::CbeError;

/// B independent circulant blocks concatenated into one long code.
/// Immutable on the encode path and `Send + Sync`, like the blocks it
/// holds; share behind an `Arc` across threads.
#[derive(Clone)]
pub struct StackedCirculant {
    d: usize,
    blocks: Vec<CirculantProjection>,
}

thread_local! {
    /// Scratch behind the allocating [`StackedCirculant::encode`]
    /// wrapper, mirroring the circulant block's own wrapper scratch.
    static WRAPPER_SCRATCH: std::cell::RefCell<EncodeScratch> =
        std::cell::RefCell::new(EncodeScratch::new());
}

impl StackedCirculant {
    /// Build from explicit blocks. All blocks must share one input
    /// dimension d; at least one block is required.
    pub fn new(blocks: Vec<CirculantProjection>) -> Result<StackedCirculant, CbeError> {
        let d = match blocks.first() {
            Some(b) => b.d,
            None => {
                return Err(CbeError::Service(
                    "stacked circulant needs at least one block".into(),
                ))
            }
        };
        if let Some(b) = blocks.iter().find(|b| b.d != d) {
            return Err(CbeError::Service(format!(
                "stacked circulant blocks disagree on d: {} vs {}",
                d, b.d
            )));
        }
        Ok(StackedCirculant { d, blocks })
    }

    /// CBE-rand stacking: `blocks` independent (r_b ~ N(0,1), D_b ~ ±1)
    /// pairs drawn from one sequential rng stream. Block 0 consumes the
    /// rng exactly like [`CirculantProjection::random`], so a one-block
    /// stack seeded the same way IS the plain circulant model.
    pub fn random(
        d: usize,
        blocks: usize,
        rng: &mut Pcg64,
        planner: Planner,
    ) -> Result<StackedCirculant, CbeError> {
        if blocks == 0 {
            return Err(CbeError::Service(
                "stacked circulant needs at least one block".into(),
            ));
        }
        let blocks = (0..blocks)
            .map(|_| CirculantProjection::random(d, rng, planner.clone()))
            .collect();
        StackedCirculant::new(blocks)
    }

    /// Input dimension (shared by every block).
    pub fn d(&self) -> usize {
        self.d
    }

    /// The blocks, in bit-window order.
    pub fn blocks(&self) -> &[CirculantProjection] {
        &self.blocks
    }

    /// Longest code this model can produce: B·d bits.
    pub fn max_bits(&self) -> usize {
        self.blocks.len() * self.d
    }

    /// Typed code-length guard: `Err(CbeError::BadCodeLength)` past B·d.
    pub fn check_code_length(&self, k: usize) -> Result<(), CbeError> {
        if k <= self.max_bits() {
            Ok(())
        } else {
            Err(CbeError::BadCodeLength {
                k,
                d: self.d,
                max: self.max_bits(),
            })
        }
    }

    fn require_code_length(&self, k: usize) {
        if let Err(e) = self.check_code_length(k) {
            panic!("{e}");
        }
    }

    /// k-bit ±1 code (k ≤ B·d): block b fills `out[b·d .. b·d + take]`
    /// through [`CirculantProjection::encode_into`], so every bit's sign
    /// decision is the block's own single-block decision.
    pub fn encode(&self, x: &[f32], k: usize) -> Vec<f32> {
        self.require_code_length(k);
        let mut out = vec![0f32; k];
        WRAPPER_SCRATCH.with(|s| {
            let scratch = &mut s.borrow_mut();
            for (b, block) in self.blocks.iter().enumerate() {
                let base = b * self.d;
                if base >= k {
                    break;
                }
                let take = self.d.min(k - base);
                block.encode_into(x, &mut out[base..base + take], scratch);
            }
        });
        out
    }

    /// Encode one vector straight into packed words (one `BitCode` row of
    /// exactly `k.div_ceil(64)` words). Bit `b·d + j` is set iff
    /// projection j of block b is ≥ 0; trailing pad bits are zero.
    pub fn encode_bits_into(
        &self,
        x: &[f32],
        k: usize,
        words: &mut [u64],
        scratch: &mut EncodeScratch,
    ) {
        self.require_code_length(k);
        assert_eq!(words.len(), k.div_ceil(64));
        words.fill(0);
        for (b, block) in self.blocks.iter().enumerate() {
            let base = b * self.d;
            if base >= k {
                break;
            }
            let take = self.d.min(k - base);
            block.or_sign_bits(x, take, base, words, scratch);
        }
    }

    /// Batch encode into a `BitCode`, mirroring
    /// [`CirculantProjection::encode_batch_into`].
    pub fn encode_batch_into(
        &self,
        rows: &[&[f32]],
        k: usize,
        out: &mut BitCode,
        pool: &mut ScratchPool,
    ) {
        assert_eq!(out.n, rows.len());
        assert_eq!(out.bits, k);
        self.encode_batch_words(rows, k, &mut out.data, out.words_per_code, pool);
    }

    /// The batch engine over a bare packed-word window (row i into
    /// `words[i·wpc .. (i+1)·wpc]`). Fan-out is by rows, but the serial
    /// cutover and thread count weigh the full rows × blocks work n·B·d,
    /// so long-code batches parallelize even when n alone looks small.
    pub fn encode_batch_words(
        &self,
        rows: &[&[f32]],
        k: usize,
        words: &mut [u64],
        wpc: usize,
        pool: &mut ScratchPool,
    ) {
        self.require_code_length(k);
        assert_eq!(wpc, k.div_ceil(64));
        assert_eq!(words.len(), rows.len() * wpc);
        let n = rows.len();
        if n == 0 {
            return;
        }
        let cores = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        let threads = cores.min(n);
        let work = n * self.d * self.blocks.len();
        if threads <= 1 || work < crate::tune::min_parallel_work() {
            let scratch = &mut pool.slots_mut(1)[0];
            for (row, words) in rows.iter().zip(words.chunks_mut(wpc)) {
                self.encode_bits_into(row, k, words, scratch);
            }
            return;
        }
        let chunk = n.div_ceil(threads);
        std::thread::scope(|scope| {
            let mut rest_rows = rows;
            let mut rest_words = words;
            for scratch in pool.slots_mut(threads) {
                if rest_rows.is_empty() {
                    break;
                }
                let take = chunk.min(rest_rows.len());
                let (row_chunk, tail_rows) = rest_rows.split_at(take);
                let (word_chunk, tail_words) = rest_words.split_at_mut(take * wpc);
                rest_rows = tail_rows;
                rest_words = tail_words;
                scope.spawn(move || {
                    for (row, words) in row_chunk.iter().zip(word_chunk.chunks_mut(wpc)) {
                        self.encode_bits_into(row, k, words, scratch);
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::forall;

    #[test]
    fn one_block_is_bit_identical_to_the_plain_circulant() {
        forall("stacked:1 == circulant", 25, |g| {
            let d = g.usize_in(2, 96);
            let k = g.usize_in(1, d);
            let planner = Planner::new();
            let seed = g.rng().next_u64();
            let mut rng_a = Pcg64::new(seed);
            let mut rng_b = Pcg64::new(seed);
            let plain = CirculantProjection::random(d, &mut rng_a, planner.clone());
            let stacked = StackedCirculant::random(d, 1, &mut rng_b, planner).unwrap();
            let x = g.normal_vec(d);
            assert_eq!(plain.encode(&x, k), stacked.encode(&x, k), "d={d} k={k}");
            let mut wa = vec![0u64; k.div_ceil(64)];
            let mut wb = vec![0u64; k.div_ceil(64)];
            let mut scratch = EncodeScratch::new();
            plain.encode_bits_into(&x, k, &mut wa, &mut scratch);
            stacked.encode_bits_into(&x, k, &mut wb, &mut scratch);
            assert_eq!(wa, wb, "packed words diverged at d={d} k={k}");
        });
    }

    #[test]
    fn each_bit_window_is_its_blocks_own_code() {
        forall("stacked windows == per-block codes", 20, |g| {
            let d = g.usize_in(2, 64);
            let blocks = g.usize_in(1, 4);
            let k = g.usize_in(1, blocks * d);
            let planner = Planner::new();
            let stacked =
                StackedCirculant::random(d, blocks, g.rng(), planner).unwrap();
            let x = g.normal_vec(d);
            let code = stacked.encode(&x, k);
            for (b, block) in stacked.blocks().iter().enumerate() {
                let base = b * d;
                if base >= k {
                    break;
                }
                let take = d.min(k - base);
                assert_eq!(
                    code[base..base + take],
                    block.encode(&x, take),
                    "block {b} window diverged (d={d} blocks={blocks} k={k})"
                );
            }
        });
    }

    #[test]
    fn batch_matches_per_vector_at_ragged_lengths() {
        forall("stacked batch == serial", 15, |g| {
            let d = g.usize_in(2, 48);
            let blocks = g.usize_in(1, 3);
            let k = g.usize_in(1, blocks * d);
            let n = g.usize_in(0, 10);
            let planner = Planner::new();
            let stacked =
                StackedCirculant::random(d, blocks, g.rng(), planner).unwrap();
            let flat: Vec<Vec<f32>> = (0..n).map(|_| g.normal_vec(d)).collect();
            let rows: Vec<&[f32]> = flat.iter().map(|r| r.as_slice()).collect();
            let mut batch = BitCode::new(n, k);
            stacked.encode_batch_into(&rows, k, &mut batch, &mut ScratchPool::new());
            let mut per_vec = BitCode::new(n, k);
            for (i, row) in rows.iter().enumerate() {
                per_vec.set_row_from_signs(i, &stacked.encode(row, k));
            }
            assert_eq!(batch, per_vec, "d={d} blocks={blocks} k={k} n={n}");
            assert!(batch.padding_is_zero());
        });
    }

    #[test]
    fn bad_shapes_are_typed_errors() {
        let planner = Planner::new();
        let mut rng = Pcg64::new(3);
        assert!(StackedCirculant::random(8, 0, &mut rng, planner.clone()).is_err());
        let s = StackedCirculant::random(8, 2, &mut rng, planner.clone()).unwrap();
        assert_eq!(s.max_bits(), 16);
        assert_eq!(
            s.check_code_length(17),
            Err(CbeError::BadCodeLength { k: 17, d: 8, max: 16 })
        );
        let a = CirculantProjection::random(8, &mut rng, planner.clone());
        let b = CirculantProjection::random(6, &mut rng, planner);
        assert!(StackedCirculant::new(vec![a, b]).is_err());
    }
}
