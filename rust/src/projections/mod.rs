//! The three projection families compared throughout the paper (Table 1/2):
//! full (dense, O(d²)), bilinear (O(d^1.5)), circulant (O(d log d)) —
//! plus the circulant *variants* from the follow-up papers that free the
//! code length from the single-block `k ≤ d` cap:
//! [`stacked::StackedCirculant`] (k > d, arXiv:1511.06480) and
//! [`downsampled::DownsampledCirculant`] (k ≪ d, arXiv:1601.06342).
//!
//! The circulant family is the serving hot path; see
//! [`circulant::CirculantProjection`] for the threading model (shared
//! `Send + Sync` projection, caller-owned [`circulant::EncodeScratch`],
//! scoped-thread batch fan-out via
//! [`circulant::CirculantProjection::encode_batch_into`]).
//!
//! # Picking a variant: [`ProjectionSpec`]
//!
//! Serving code selects the variant through a spec string, parsed like
//! [`crate::index::IndexBackend`] backend specs:
//!
//! | spec           | model                         | code length |
//! |----------------|-------------------------------|-------------|
//! | `circ`         | one circulant block           | k ≤ d       |
//! | `stacked[:B]`  | B independent blocks (auto: ⌈k/d⌉) | k ≤ B·d |
//! | `downsampled`  | one block + sparse row-selection | k ≤ d (decorrelated) |
//!
//! [`CbeModel`] is the parsed model all three variants serve behind: the
//! registry, the batch fan-out and the snapshot fingerprint all speak
//! `CbeModel`, so the serving path is variant-agnostic. A `stacked:1`
//! model is bit-identical to `circ` — codes, index hits and fingerprints
//! — enforced by `rust/tests/projection_variants.rs`.

pub mod circulant;
pub mod downsampled;
pub mod full;
pub mod bilinear;
pub mod stacked;

pub use circulant::{CirculantProjection, EncodeScratch, ScratchPool};
pub use downsampled::DownsampledCirculant;
pub use full::FullProjection;
pub use bilinear::BilinearProjection;
pub use stacked::StackedCirculant;

use crate::bits::BitCode;
use crate::fft::Planner;
use crate::util::rng::Pcg64;
use crate::CbeError;

/// Which circulant variant a model should be built as. Parsed from the
/// `circ | stacked[:B] | downsampled` grammar (CLI `--proj`, env
/// `CBE_PROJ`) exactly like [`crate::index::IndexBackend::from_spec`]
/// parses index backends.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum ProjectionSpec {
    /// One circulant block; the paper's core operator. k ≤ d.
    #[default]
    Circ,
    /// B independent circulant blocks concatenated; k ≤ B·d. `None`
    /// sizes B automatically as ⌈k/d⌉ once k is known.
    Stacked { blocks: Option<usize> },
    /// One block + seeded sparse row-selection; k ≤ d, training-free.
    Downsampled,
}

impl ProjectionSpec {
    /// Parse a projection spec: `circ` | `stacked[:B]` | `downsampled`.
    /// See the type-level docs for the exact grammar.
    pub fn from_spec(spec: &str) -> Result<ProjectionSpec, String> {
        let parts: Vec<&str> = spec.trim().split(':').collect();
        let num = |s: &str| {
            s.parse::<usize>()
                .map_err(|_| format!("bad number '{s}' in projection spec '{spec}'"))
        };
        let arity = |want: std::ops::RangeInclusive<usize>| {
            if want.contains(&parts.len()) {
                Ok(())
            } else {
                Err(format!("wrong arity in projection spec '{spec}'"))
            }
        };
        match parts[0] {
            "circ" | "circulant" => {
                arity(1..=1)?;
                Ok(ProjectionSpec::Circ)
            }
            "stacked" => {
                arity(1..=2)?;
                let blocks = if parts.len() > 1 {
                    let b = num(parts[1])?;
                    if b == 0 {
                        return Err(format!("block count must be >= 1 in '{spec}'"));
                    }
                    Some(b)
                } else {
                    None
                };
                Ok(ProjectionSpec::Stacked { blocks })
            }
            "downsampled" | "ds" => {
                arity(1..=1)?;
                Ok(ProjectionSpec::Downsampled)
            }
            other => Err(format!(
                "unknown projection '{other}' (want circ | stacked[:B] | downsampled)"
            )),
        }
    }

    /// Canonical spec string (round-trips through
    /// [`ProjectionSpec::from_spec`]).
    pub fn spec(&self) -> String {
        match self {
            ProjectionSpec::Circ => "circ".to_string(),
            ProjectionSpec::Stacked { blocks: None } => "stacked".to_string(),
            ProjectionSpec::Stacked { blocks: Some(b) } => format!("stacked:{b}"),
            ProjectionSpec::Downsampled => "downsampled".to_string(),
        }
    }

    /// Blocks a model built from this spec will carry for a k-bit code
    /// over d-dim inputs (`Stacked { blocks: None }` auto-sizes ⌈k/d⌉).
    pub fn blocks_for(&self, k: usize, d: usize) -> usize {
        match self {
            ProjectionSpec::Circ | ProjectionSpec::Downsampled => 1,
            ProjectionSpec::Stacked { blocks: Some(b) } => *b,
            ProjectionSpec::Stacked { blocks: None } => k.div_ceil(d).max(1),
        }
    }

    /// Typed validation of a (k, d) request against this spec — the
    /// recoverable replacement for the old `assert!(k <= d)` aborts.
    pub fn validate(&self, k: usize, d: usize) -> Result<(), CbeError> {
        if d == 0 {
            return Err(CbeError::Service("projection needs d >= 1".into()));
        }
        let max = match self {
            ProjectionSpec::Circ | ProjectionSpec::Downsampled => d,
            ProjectionSpec::Stacked { blocks: Some(b) } => b * d,
            // Auto-sized stacking accepts any k ≥ 1.
            ProjectionSpec::Stacked { blocks: None } => usize::MAX,
        };
        if k == 0 || k > max {
            return Err(CbeError::BadCodeLength { k, d, max });
        }
        Ok(())
    }
}

/// A parsed projection model: what [`crate::coordinator::ModelRegistry`]
/// versions, the batch fan-out encodes with, and the snapshot
/// fingerprint identifies. All variants expose one encode surface, so
/// everything downstream of the spec is variant-agnostic.
#[derive(Clone)]
pub enum CbeModel {
    Circ(CirculantProjection),
    Stacked(StackedCirculant),
    Downsampled(DownsampledCirculant),
}

impl CbeModel {
    /// Wrap a plain circulant block (the `circ` spec).
    pub fn circulant(r: Vec<f32>, signs: Vec<f32>, planner: Planner) -> CbeModel {
        CbeModel::Circ(CirculantProjection::new(r, signs, planner))
    }

    /// Seeded random model for `spec`, sized for k-bit codes over d-dim
    /// inputs. For `circ` this draws exactly what
    /// [`CirculantProjection::random`] draws from the same seed, so
    /// spec-built and legacy-built models are interchangeable.
    pub fn random(
        spec: &ProjectionSpec,
        d: usize,
        k: usize,
        seed: u64,
        planner: Planner,
    ) -> Result<CbeModel, CbeError> {
        let mut rng = Pcg64::new(seed);
        CbeModel::random_with(spec, d, k, &mut rng, planner)
    }

    /// [`CbeModel::random`] drawing from a caller-owned rng stream.
    pub fn random_with(
        spec: &ProjectionSpec,
        d: usize,
        k: usize,
        rng: &mut Pcg64,
        planner: Planner,
    ) -> Result<CbeModel, CbeError> {
        spec.validate(k, d)?;
        Ok(match spec {
            ProjectionSpec::Circ => {
                CbeModel::Circ(CirculantProjection::random(d, rng, planner))
            }
            ProjectionSpec::Stacked { .. } => CbeModel::Stacked(StackedCirculant::random(
                d,
                spec.blocks_for(k, d),
                rng,
                planner,
            )?),
            ProjectionSpec::Downsampled => {
                CbeModel::Downsampled(DownsampledCirculant::random(d, k, rng, planner)?)
            }
        })
    }

    /// Input dimension.
    pub fn d(&self) -> usize {
        match self {
            CbeModel::Circ(p) => p.d,
            CbeModel::Stacked(s) => s.d(),
            CbeModel::Downsampled(ds) => ds.d(),
        }
    }

    /// Circulant blocks in the model (1 except for stacked).
    pub fn block_count(&self) -> usize {
        match self {
            CbeModel::Circ(_) | CbeModel::Downsampled(_) => 1,
            CbeModel::Stacked(s) => s.blocks().len(),
        }
    }

    /// Longest code this model can produce.
    pub fn max_bits(&self) -> usize {
        match self {
            CbeModel::Circ(p) => p.d,
            CbeModel::Stacked(s) => s.max_bits(),
            CbeModel::Downsampled(ds) => ds.max_bits(),
        }
    }

    /// Variant name, as shown in stats snapshots.
    pub fn variant(&self) -> &'static str {
        match self {
            CbeModel::Circ(_) => "circ",
            CbeModel::Stacked(_) => "stacked",
            CbeModel::Downsampled(_) => "downsampled",
        }
    }

    /// The canonical spec this model answers to (block count resolved).
    pub fn spec(&self) -> ProjectionSpec {
        match self {
            CbeModel::Circ(_) => ProjectionSpec::Circ,
            CbeModel::Stacked(s) => ProjectionSpec::Stacked {
                blocks: Some(s.blocks().len()),
            },
            CbeModel::Downsampled(_) => ProjectionSpec::Downsampled,
        }
    }

    /// Canonical spec string (`circ`, `stacked:2`, `downsampled`).
    pub fn spec_string(&self) -> String {
        self.spec().spec()
    }

    /// Whether `other` can replace this model under a registry hot-swap:
    /// same variant, same input dimension, same code-length cap. In-flight
    /// indices still get the staleness guard via version stamps; this
    /// check only keeps a swap from changing the *shape* of the service.
    pub fn shape_matches(&self, other: &CbeModel) -> bool {
        self.variant() == other.variant()
            && self.d() == other.d()
            && self.max_bits() == other.max_bits()
    }

    /// Typed code-length guard for this model (see
    /// [`CirculantProjection::check_code_length`]).
    pub fn check_code_length(&self, k: usize) -> Result<(), CbeError> {
        match self {
            CbeModel::Circ(p) => p.check_code_length(k),
            CbeModel::Stacked(s) => s.check_code_length(k),
            CbeModel::Downsampled(ds) => ds.check_code_length(k),
        }
    }

    /// The plain circulant block, when the model is one (`circ` spec) —
    /// the single-block compatibility seam for the trainer and tests.
    pub fn as_circulant(&self) -> Option<&CirculantProjection> {
        match self {
            CbeModel::Circ(p) => Some(p),
            _ => None,
        }
    }

    /// k-bit ±1 code of one vector.
    pub fn encode(&self, x: &[f32], k: usize) -> Vec<f32> {
        match self {
            CbeModel::Circ(p) => p.encode(x, k),
            CbeModel::Stacked(s) => s.encode(x, k),
            CbeModel::Downsampled(ds) => ds.encode(x, k),
        }
    }

    /// Encode one vector straight into packed `BitCode` words.
    pub fn encode_bits_into(
        &self,
        x: &[f32],
        k: usize,
        words: &mut [u64],
        scratch: &mut EncodeScratch,
    ) {
        match self {
            CbeModel::Circ(p) => p.encode_bits_into(x, k, words, scratch),
            CbeModel::Stacked(s) => s.encode_bits_into(x, k, words, scratch),
            CbeModel::Downsampled(ds) => ds.encode_bits_into(x, k, words, scratch),
        }
    }

    /// Batch encode into a `BitCode` (scoped-thread fan-out; see the
    /// variant methods for the work gating).
    pub fn encode_batch_into(
        &self,
        rows: &[&[f32]],
        k: usize,
        out: &mut BitCode,
        pool: &mut ScratchPool,
    ) {
        match self {
            CbeModel::Circ(p) => p.encode_batch_into(rows, k, out, pool),
            CbeModel::Stacked(s) => s.encode_batch_into(rows, k, out, pool),
            CbeModel::Downsampled(ds) => ds.encode_batch_into(rows, k, out, pool),
        }
    }

    /// Batch encode over a bare packed-word window (the slab-streaming
    /// seam of `EmbeddingService::encode_corpus`).
    pub fn encode_batch_words(
        &self,
        rows: &[&[f32]],
        k: usize,
        words: &mut [u64],
        wpc: usize,
        pool: &mut ScratchPool,
    ) {
        match self {
            CbeModel::Circ(p) => p.encode_batch_words(rows, k, words, wpc, pool),
            CbeModel::Stacked(s) => s.encode_batch_words(rows, k, words, wpc, pool),
            CbeModel::Downsampled(ds) => ds.encode_batch_words(rows, k, words, wpc, pool),
        }
    }

    /// Content fingerprint covering **all** blocks and the bit-selection
    /// plan, for the snapshot stale-model guard. A one-block stacked
    /// model hashes to exactly the plain circulant fingerprint of the
    /// same parameters (the k == d compatibility contract); every extra
    /// block is chained in, and the downsampled variant additionally
    /// chains a tag plus its selection plan so it can never collide with
    /// the plain circulant sharing its block. Never 0 (0 = unstamped).
    pub fn fingerprint(&self) -> u64 {
        use crate::index::persist::{fingerprint_chain, model_fingerprint};
        match self {
            CbeModel::Circ(p) => model_fingerprint(&p.r, &p.signs),
            CbeModel::Stacked(s) => {
                let mut it = s.blocks().iter();
                let first = it.next().expect("stacked model has >= 1 block");
                let mut h = model_fingerprint(&first.r, &first.signs);
                for b in it {
                    h = fingerprint_chain(h, model_fingerprint(&b.r, &b.signs));
                }
                h
            }
            CbeModel::Downsampled(ds) => {
                let b = ds.block();
                let mut h = model_fingerprint(&b.r, &b.signs);
                h = fingerprint_chain(h, 0x6473_u64); // "ds" variant tag
                for &row in ds.selection() {
                    h = fingerprint_chain(h, u64::from(row));
                }
                h
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_roundtrip() {
        for s in ["circ", "stacked", "stacked:3", "downsampled"] {
            let parsed = ProjectionSpec::from_spec(s).unwrap();
            assert_eq!(parsed.spec(), s, "canonical form changed for {s}");
            assert_eq!(
                ProjectionSpec::from_spec(&parsed.spec()).unwrap(),
                parsed,
                "{s} does not round-trip"
            );
        }
        // Aliases parse to the same canonical forms.
        assert_eq!(
            ProjectionSpec::from_spec("circulant").unwrap(),
            ProjectionSpec::Circ
        );
        assert_eq!(
            ProjectionSpec::from_spec(" ds ").unwrap(),
            ProjectionSpec::Downsampled
        );
    }

    #[test]
    fn spec_rejects_malformed() {
        for bad in [
            "", "bogus", "circ:2", "stacked:", "stacked:0", "stacked:x",
            "stacked:2:3", "downsampled:4", "stacked:-1",
        ] {
            let err = ProjectionSpec::from_spec(bad).unwrap_err();
            assert!(!err.is_empty(), "'{bad}' should not parse");
        }
        // The unknown-variant message teaches the grammar.
        let err = ProjectionSpec::from_spec("hadamard").unwrap_err();
        assert!(err.contains("stacked[:B]"), "{err}");
    }

    #[test]
    fn validate_is_the_typed_code_length_guard() {
        let circ = ProjectionSpec::Circ;
        assert!(circ.validate(64, 64).is_ok());
        assert_eq!(
            circ.validate(65, 64),
            Err(CbeError::BadCodeLength { k: 65, d: 64, max: 64 })
        );
        let st2 = ProjectionSpec::Stacked { blocks: Some(2) };
        assert!(st2.validate(128, 64).is_ok());
        assert_eq!(
            st2.validate(129, 64),
            Err(CbeError::BadCodeLength { k: 129, d: 64, max: 128 })
        );
        let auto = ProjectionSpec::Stacked { blocks: None };
        assert!(auto.validate(10_000, 64).is_ok());
        assert_eq!(auto.blocks_for(129, 64), 3);
        assert_eq!(auto.blocks_for(64, 64), 1);
        assert!(ProjectionSpec::Downsampled.validate(0, 64).is_err());
    }

    #[test]
    fn model_dispatch_matches_the_underlying_variant() {
        let planner = Planner::new();
        let seed = 99u64;
        let d = 32;
        let model =
            CbeModel::random(&ProjectionSpec::Circ, d, d, seed, planner.clone()).unwrap();
        let plain = CirculantProjection::random(d, &mut Pcg64::new(seed), planner);
        let mut rng = Pcg64::new(1);
        let x = rng.normal_vec(d);
        assert_eq!(model.encode(&x, d), plain.encode(&x, d));
        assert_eq!(model.variant(), "circ");
        assert_eq!(model.spec_string(), "circ");
        assert_eq!(model.block_count(), 1);
        assert_eq!(model.max_bits(), d);
        assert!(model.as_circulant().is_some());
    }

    #[test]
    fn fingerprints_separate_variants_but_not_stacked_1() {
        let planner = Planner::new();
        let d = 24;
        let seed = 7u64;
        let circ =
            CbeModel::random(&ProjectionSpec::Circ, d, d, seed, planner.clone()).unwrap();
        let st1 = CbeModel::random(
            &ProjectionSpec::Stacked { blocks: Some(1) },
            d,
            d,
            seed,
            planner.clone(),
        )
        .unwrap();
        let st2 = CbeModel::random(
            &ProjectionSpec::Stacked { blocks: Some(2) },
            d,
            2 * d,
            seed,
            planner.clone(),
        )
        .unwrap();
        let ds =
            CbeModel::random(&ProjectionSpec::Downsampled, d, d, seed, planner).unwrap();
        // The k == d contract: one stacked block == the plain circulant,
        // fingerprint included.
        assert_eq!(circ.fingerprint(), st1.fingerprint());
        // More blocks, or a selection plan, must move the fingerprint —
        // even though all share block 0's parameters (same seed stream).
        assert_ne!(circ.fingerprint(), st2.fingerprint());
        assert_ne!(circ.fingerprint(), ds.fingerprint());
        assert_ne!(st2.fingerprint(), ds.fingerprint());
        for m in [&circ, &st1, &st2, &ds] {
            assert_ne!(m.fingerprint(), 0);
            assert_eq!(m.fingerprint(), m.fingerprint());
        }
    }

    #[test]
    fn shape_matching_gates_hot_swaps() {
        let planner = Planner::new();
        let circ =
            CbeModel::random(&ProjectionSpec::Circ, 16, 16, 1, planner.clone()).unwrap();
        let circ2 =
            CbeModel::random(&ProjectionSpec::Circ, 16, 16, 2, planner.clone()).unwrap();
        let wider =
            CbeModel::random(&ProjectionSpec::Circ, 32, 32, 1, planner.clone()).unwrap();
        let st2 = CbeModel::random(
            &ProjectionSpec::Stacked { blocks: Some(2) },
            16,
            32,
            1,
            planner,
        )
        .unwrap();
        assert!(circ.shape_matches(&circ2));
        assert!(!circ.shape_matches(&wider));
        assert!(!circ.shape_matches(&st2));
    }
}
