//! The three projection families compared throughout the paper (Table 1/2):
//! full (dense, O(d²)), bilinear (O(d^1.5)), circulant (O(d log d)).

pub mod circulant;
pub mod full;
pub mod bilinear;

pub use circulant::CirculantProjection;
pub use full::FullProjection;
pub use bilinear::BilinearProjection;
