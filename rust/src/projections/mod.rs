//! The three projection families compared throughout the paper (Table 1/2):
//! full (dense, O(d²)), bilinear (O(d^1.5)), circulant (O(d log d)).
//!
//! The circulant family is the serving hot path; see
//! [`circulant::CirculantProjection`] for the threading model (shared
//! `Send + Sync` projection, caller-owned [`circulant::EncodeScratch`],
//! scoped-thread batch fan-out via
//! [`circulant::CirculantProjection::encode_batch_into`]).

pub mod circulant;
pub mod full;
pub mod bilinear;

pub use circulant::{CirculantProjection, EncodeScratch, ScratchPool};
pub use full::FullProjection;
pub use bilinear::BilinearProjection;
