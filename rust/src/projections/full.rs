//! Full (unstructured) projection — the LSH baseline. O(kd) time, O(kd)
//! space: exactly what the paper is beating.

use crate::linalg::Mat;
use crate::util::rng::Pcg64;

/// Dense k×d gaussian projection.
pub struct FullProjection {
    pub k: usize,
    pub d: usize,
    /// Row-major k×d matrix.
    pub w: Mat,
}

impl FullProjection {
    pub fn random(k: usize, d: usize, rng: &mut Pcg64) -> FullProjection {
        FullProjection {
            k,
            d,
            w: Mat::randn(k, d, rng),
        }
    }

    pub fn from_mat(w: Mat) -> FullProjection {
        FullProjection {
            k: w.rows,
            d: w.cols,
            w,
        }
    }

    /// y = W·x (k outputs).
    pub fn project(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.d);
        let mut y = vec![0f32; self.k];
        for i in 0..self.k {
            let row = self.w.row(i);
            let mut acc = 0f32;
            for j in 0..self.d {
                acc += row[j] * x[j];
            }
            y[i] = acc;
        }
        y
    }

    /// sign(W·x).
    pub fn encode(&self, x: &[f32]) -> Vec<f32> {
        self.project(x)
            .iter()
            .map(|v| if *v >= 0.0 { 1.0 } else { -1.0 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn projection_linear() {
        let mut rng = Pcg64::new(101);
        let p = FullProjection::random(8, 16, &mut rng);
        let x = rng.normal_vec(16);
        let y2: Vec<f32> = x.iter().map(|v| v * 2.0).collect();
        let px = p.project(&x);
        let px2 = p.project(&y2);
        for (a, b) in px.iter().zip(&px2) {
            assert!((b - 2.0 * a).abs() < 1e-4);
        }
    }

    #[test]
    fn encode_signs() {
        let w = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, -1.0]);
        let p = FullProjection::from_mat(w);
        assert_eq!(p.encode(&[3.0, 5.0]), vec![1.0, -1.0]);
    }
}
