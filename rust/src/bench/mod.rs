//! Criterion-lite benchmark harness (no criterion in the vendor set):
//! warmup, timed iterations, mean/std/p50/p99, ASCII reporting, and a
//! `cargo bench` entry style with `harness = false`.

use crate::util::table::Table;
use crate::util::timer::Samples;
use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Samples,
}

impl Measurement {
    pub fn mean_ms(&self) -> f64 {
        self.samples.mean()
    }
}

/// Bench runner with fixed warmup/iteration counts (deterministic wall
/// budget — this repo benches scaling *shapes*, not nanosecond jitter).
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    pub results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: 2,
            iters: 7,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Bench {
        Bench {
            warmup,
            iters,
            results: Vec::new(),
        }
    }

    /// Time `f` (ms per call) with warmup; records and returns the mean.
    pub fn run(&mut self, name: &str, mut f: impl FnMut()) -> f64 {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Samples::default();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let m = Measurement {
            name: name.to_string(),
            samples,
        };
        let mean = m.mean_ms();
        self.results.push(m);
        mean
    }

    /// Render all measurements as a table.
    pub fn report(&self, title: &str) -> String {
        let mut t = Table::new(title, &["bench", "mean ms", "p50 ms", "p99 ms", "std"]);
        for m in &self.results {
            t.row(vec![
                m.name.clone(),
                format!("{:.3}", m.samples.mean()),
                format!("{:.3}", m.samples.percentile(50.0)),
                format!("{:.3}", m.samples.percentile(99.0)),
                format!("{:.3}", m.samples.std()),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_runs() {
        let mut b = Bench::new(1, 3);
        let mean = b.run("noop", || {});
        assert!(mean >= 0.0);
        assert_eq!(b.results.len(), 1);
        assert_eq!(b.results[0].samples.len(), 3);
        assert!(b.report("t").contains("noop"));
    }
}
