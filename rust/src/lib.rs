//! # cbe — Circulant Binary Embedding
//!
//! A production-grade reproduction of *Circulant Binary Embedding*
//! (Yu, Kumar, Gong, Chang — ICML 2014) as a three-layer system:
//!
//! * **L1** Pallas kernels (build-time python, `python/compile/kernels/`)
//! * **L2** JAX compute graphs AOT-lowered to HLO text (`python/compile/`)
//! * **L3** this Rust crate: the coordinator, runtime, retrieval engine,
//!   native reference implementations of every encoder, and the full
//!   experiment harness reproducing every table and figure of the paper.
//!
//! The public API entry points are [`encoders::BinaryEncoder`] (train/encode
//! any of the paper's methods), [`coordinator::EmbeddingService`] (the
//! serving facade: dynamic batching + parallel batch encode + binary
//! retrieval),
//! [`index`] (sub-linear exact Hamming ANN: multi-index hashing, sharded
//! fan-out, backend selection via [`index::IndexBackend`]), and
//! [`experiments`] (one driver per paper table/figure).

pub mod error;
pub mod util;
pub mod proptest_lite;
pub mod tune;
pub mod obs;
pub mod simd;
pub mod fft;
pub mod linalg;
pub mod bits;
pub mod index;
pub mod projections;
pub mod opt;
pub mod encoders;
pub mod data;
pub mod groundtruth;
pub mod eval;
pub mod svm;
pub mod runtime;
pub mod pool;
pub mod coordinator;
pub mod bench;
pub mod experiments;

pub use error::CbeError;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
