//! Figure 5: low-dimensional comparison (Flickr-2048 in the paper) against
//! methods that do not scale to high d: ITQ, SH, SKLSH, AQBC — plus LSH,
//! bilinear and both CBE variants. Fixed-bits regime only (as the paper).

use crate::bits::BinaryIndex;
use crate::data::{gather, generate, train_query_split, SynthConfig};
use crate::encoders::{
    Aqbc, BilinearOpt, BinaryEncoder, CbeRand, CbeTrainer, Itq, Lsh, Sh, Sklsh,
};
use crate::eval::{recall_auc, recall_curve};
use crate::fft::Planner;
use crate::groundtruth::exact_knn;
use crate::opt::TimeFreqConfig;
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct Fig5Config {
    pub d: usize,
    pub n: usize,
    pub n_train: usize,
    pub n_queries: usize,
    pub gt_k: usize,
    pub bits: Vec<usize>,
    pub max_r: usize,
    pub seed: u64,
}

impl Fig5Config {
    pub fn quick(d: usize) -> Fig5Config {
        Fig5Config {
            d,
            n: 2500,
            n_train: 500,
            n_queries: 50,
            gt_k: 10,
            bits: vec![32, 64, 128],
            max_r: 100,
            seed: 512,
        }
    }
}

pub struct Fig5Entry {
    pub method: String,
    pub bits: usize,
    pub auc: f64,
    pub recall_at_100: f64,
}

pub struct Fig5Result {
    pub entries: Vec<Fig5Entry>,
    pub report: String,
}

pub fn run(cfg: &Fig5Config) -> Fig5Result {
    let planner = Planner::new();
    let ds = generate(&SynthConfig::flickr(cfg.n, cfg.d, cfg.seed));
    let (train_idx, query_idx) = train_query_split(cfg.n, cfg.n_queries, cfg.seed + 1);
    let db = gather(&ds.x, &train_idx);
    let queries = gather(&ds.x, &query_idx);
    let train = gather(&ds.x, &train_idx[..cfg.n_train.min(train_idx.len())]);
    let gt = exact_knn(&db, &queries, cfg.gt_k);

    let mut entries = Vec::new();
    for &k in &cfg.bits {
        let mut tf = TimeFreqConfig::new(k);
        tf.iters = 5;
        let cbe_opt = CbeTrainer::new(tf)
            .seed(cfg.seed + 2)
            .planner(planner.clone())
            .train(&train);
        let cbe_rand = CbeRand::new(cfg.d, k, cfg.seed + 3, planner.clone())
            .expect("fig5 keeps k <= d");
        let lsh = Lsh::new(cfg.d, k, cfg.seed + 4);
        let bil_opt = BilinearOpt::train(&train, k, 3, cfg.seed + 5);
        let itq = Itq::train(&train, k.min(train.cols), 8, cfg.seed + 6);
        let sh = Sh::train(&train, k, cfg.seed + 7);
        let sklsh = Sklsh::new(cfg.d, k, 0.7, cfg.seed + 8);
        let aqbc = Aqbc::train(&train, k.min(train.cols), 5, cfg.seed + 9);

        let methods: Vec<&dyn BinaryEncoder> = vec![
            &cbe_opt, &cbe_rand, &lsh, &bil_opt, &itq, &sh, &sklsh, &aqbc,
        ];
        for m in methods {
            let db_codes = m.encode_batch(&db);
            let q_codes = m.encode_batch(&queries);
            let index = BinaryIndex::new(db_codes);
            let curve = recall_curve(&index, &q_codes, &gt, cfg.max_r);
            entries.push(Fig5Entry {
                method: m.name().to_string(),
                bits: k,
                auc: recall_auc(&curve),
                recall_at_100: curve.last().cloned().unwrap_or(0.0),
            });
        }
    }

    let mut t = Table::new(
        &format!("Figure 5 analogue — low-dim (d={}) fixed bits", cfg.d),
        &["method", "bits", "AUC", "recall@100"],
    );
    for e in &entries {
        t.row(vec![
            e.method.clone(),
            format!("{}", e.bits),
            format!("{:.3}", e.auc),
            format!("{:.3}", e.recall_at_100),
        ]);
    }
    Fig5Result {
        entries,
        report: t.render(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_methods_run_and_beat_chance() {
        let mut cfg = Fig5Config::quick(64);
        cfg.n = 500;
        cfg.n_train = 200;
        cfg.n_queries = 20;
        cfg.bits = vec![32];
        cfg.max_r = 50;
        let r = run(&cfg);
        assert_eq!(r.entries.len(), 8);
        for e in &r.entries {
            assert!(e.auc > 0.01, "{}: auc={}", e.method, e.auc);
        }
        // CBE-opt should be competitive: not the worst method.
        let cbe = r.entries.iter().find(|e| e.method == "CBE-opt").unwrap().auc;
        let worst = r
            .entries
            .iter()
            .map(|e| e.auc)
            .fold(f64::INFINITY, f64::min);
        assert!(cbe > worst || (cbe - worst).abs() < 1e-9);
    }
}
