//! Figures 2–4: recall@R retrieval comparison on the three (synthetic
//! stand-in) datasets, in both of the paper's regimes:
//!
//! * **fixed-bits** — every method uses the same k; CBE-rand should track
//!   LSH, CBE-opt should lead, bilinear in between (second rows).
//! * **fixed-time** — every method gets the time budget CBE needs for k
//!   bits; slower methods must use fewer bits (first rows). Budgets are
//!   computed from measured per-vector encode times.

use crate::data::{gather, generate, train_query_split, Dataset, SynthConfig};
use crate::encoders::{BilinearOpt, BilinearRand, BinaryEncoder, CbeRand, CbeTrainer, Lsh};
use crate::eval::{recall_auc, recall_curve};
use crate::fft::Planner;
use crate::groundtruth::exact_knn;
use crate::index::{build_index, IndexBackend};
use crate::linalg::Mat;
use crate::opt::TimeFreqConfig;
use crate::projections::ProjectionSpec;
use crate::util::table::Table;
use crate::util::timer::time_ms;

/// Which dataset of the paper a sweep imitates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Corpus {
    Flickr,   // Fig. 2 (Flickr-25600)
    ImageNet, // Fig. 3 / Fig. 4 (ImageNet-25600 / 51200)
}

/// Sweep configuration (dims scaled down by default; see DESIGN.md).
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub corpus: Corpus,
    pub d: usize,
    pub n: usize,
    pub n_train: usize,
    pub n_queries: usize,
    pub gt_k: usize,
    pub bits: Vec<usize>,
    pub max_r: usize,
    pub opt_iters: usize,
    pub seed: u64,
    /// Retrieval backend for the recall evaluation (any
    /// [`IndexBackend`] spec: `auto | linear | mih[:m] | mih-sampled[:m]
    /// | sharded:<shards>[:m]`). Every backend is exact, so curves are
    /// identical across backends; this exists so the sweep doubles as an
    /// end-to-end exerciser of the index subsystem.
    pub index: IndexBackend,
}

impl SweepConfig {
    pub fn quick(corpus: Corpus, d: usize) -> SweepConfig {
        SweepConfig {
            corpus,
            d,
            n: 3000,
            n_train: 600,
            n_queries: 60,
            gt_k: 10,
            bits: vec![d / 8, d / 4, d / 2],
            max_r: 100,
            opt_iters: 5,
            seed: 20140601,
            index: IndexBackend::Auto,
        }
    }
}

/// Result: per (method, bits) the recall curve and its AUC, plus encode
/// timing used for the fixed-time normalization.
pub struct SweepResult {
    pub entries: Vec<SweepEntry>,
    pub report: String,
}

pub struct SweepEntry {
    pub method: String,
    pub regime: &'static str, // "fixed-bits" | "fixed-time"
    pub bits: usize,
    pub encode_ms_per_vec: f64,
    pub curve: Vec<f64>,
    pub auc: f64,
}

fn dataset(cfg: &SweepConfig) -> Dataset {
    match cfg.corpus {
        Corpus::Flickr => generate(&SynthConfig::flickr(cfg.n, cfg.d, cfg.seed)),
        Corpus::ImageNet => generate(&SynthConfig::imagenet(cfg.n, cfg.d, cfg.seed)),
    }
}

/// Measure per-vector encode time of an encoder (ms).
fn encode_time_ms(enc: &dyn BinaryEncoder, x: &Mat, samples: usize) -> f64 {
    let take = samples.min(x.rows);
    let (_, ms) = time_ms(|| {
        for i in 0..take {
            std::hint::black_box(enc.encode_signs(x.row(i)));
        }
    });
    ms / take as f64
}

/// Evaluate one encoder at one bit budget; returns (curve, auc, ms/vec).
fn eval_encoder(
    enc: &dyn BinaryEncoder,
    db: &Mat,
    queries: &Mat,
    gt: &[Vec<u32>],
    max_r: usize,
    backend: &IndexBackend,
) -> (Vec<f64>, f64, f64) {
    let db_codes = enc.encode_batch(db);
    let q_codes = enc.encode_batch(queries);
    let index = build_index(db_codes, backend);
    let curve = recall_curve(&index, &q_codes, gt, max_r);
    let auc = recall_auc(&curve);
    let ms = encode_time_ms(enc, queries, 16);
    (curve, auc, ms)
}

/// Run the full sweep for one figure.
pub fn run(cfg: &SweepConfig) -> SweepResult {
    let planner = Planner::new();
    let ds = dataset(cfg);
    let (train_idx, query_idx) = train_query_split(cfg.n, cfg.n_queries, cfg.seed + 1);
    let db = gather(&ds.x, &train_idx);
    let queries = gather(&ds.x, &query_idx);
    let train = gather(&ds.x, &train_idx[..cfg.n_train.min(train_idx.len())]);
    let gt = exact_knn(&db, &queries, cfg.gt_k);

    let mut entries: Vec<SweepEntry> = Vec::new();

    for &k in &cfg.bits {
        // ---------------- fixed-bits regime ----------------
        let cbe_rand = CbeRand::new(cfg.d, k, cfg.seed + 2, planner.clone())
            .expect("sweep bit budgets stay within k <= d");
        let mut tf = TimeFreqConfig::new(k);
        tf.iters = cfg.opt_iters;
        let cbe_opt = CbeTrainer::new(tf)
            .seed(cfg.seed + 3)
            .planner(planner.clone())
            .train(&train);
        let lsh = Lsh::new(cfg.d, k, cfg.seed + 4);
        let bil_rand = BilinearRand::new(cfg.d, k, cfg.seed + 5);
        let bil_opt = BilinearOpt::train(&train, k, 3, cfg.seed + 6);

        let methods: Vec<&dyn BinaryEncoder> =
            vec![&cbe_rand, &cbe_opt, &lsh, &bil_rand, &bil_opt];
        let mut cbe_ms = 0.0;
        for m in &methods {
            let (curve, auc, ms) = eval_encoder(*m, &db, &queries, &gt, cfg.max_r, &cfg.index);
            if m.name() == "CBE-rand" {
                cbe_ms = ms;
            }
            entries.push(SweepEntry {
                method: m.name().to_string(),
                regime: "fixed-bits",
                bits: k,
                encode_ms_per_vec: ms,
                curve,
                auc,
            });
        }

        // ---------------- fixed-time regime ----------------
        // Budget = CBE's encode time for k bits. Slower methods get fewer
        // bits: scale k by (cbe_ms / method_ms), floor 8 bits.
        for (name, ms) in entries
            .iter()
            .filter(|e| e.regime == "fixed-bits" && e.bits == k)
            .map(|e| (e.method.clone(), e.encode_ms_per_vec))
            .collect::<Vec<_>>()
        {
            if name.starts_with("CBE") {
                continue; // CBE defines the budget; its fixed-time = fixed-bits
            }
            let scale = (cbe_ms / ms).min(1.0);
            let kk = ((k as f64 * scale) as usize).max(8).min(cfg.d);
            let (curve, auc, ms2) = match name.as_str() {
                "LSH" => {
                    let e = Lsh::new(cfg.d, kk, cfg.seed + 7);
                    eval_encoder(&e, &db, &queries, &gt, cfg.max_r, &cfg.index)
                }
                "Bilinear-rand" => {
                    let e = BilinearRand::new(cfg.d, kk, cfg.seed + 8);
                    eval_encoder(&e, &db, &queries, &gt, cfg.max_r, &cfg.index)
                }
                "Bilinear-opt" => {
                    let e = BilinearOpt::train(&train, kk, 3, cfg.seed + 9);
                    eval_encoder(&e, &db, &queries, &gt, cfg.max_r, &cfg.index)
                }
                _ => continue,
            };
            entries.push(SweepEntry {
                method: name,
                regime: "fixed-time",
                bits: kk,
                encode_ms_per_vec: ms2,
                curve,
                auc,
            });
        }
    }

    // ---------------- long/short-code regime ----------------
    // The paper's circulant projection caps codes at d bits. Stacked
    // blocks lift that cap (k > d), and the downsampled variant serves
    // k ≪ d with a decorrelated sparse bit selection; both share the
    // probe budget (max_r) of the base arms so AUCs are comparable. The
    // circ baseline below draws from the same seed as the stacked arm,
    // so the stacked code's first d bits are exactly the baseline code —
    // any AUC gain is attributable to the extra blocks alone.
    let long_arms: [(ProjectionSpec, usize); 3] = [
        (ProjectionSpec::Circ, cfg.d),
        (ProjectionSpec::Stacked { blocks: None }, 2 * cfg.d),
        (ProjectionSpec::Downsampled, (cfg.d / 8).max(8)),
    ];
    for (spec, k) in long_arms {
        let enc = CbeRand::with_spec(&spec, cfg.d, k, cfg.seed + 10, planner.clone())
            .expect("long-code arms are validated against d");
        let (curve, auc, ms) = eval_encoder(&enc, &db, &queries, &gt, cfg.max_r, &cfg.index);
        entries.push(SweepEntry {
            method: enc.name().to_string(),
            regime: "long-code",
            bits: k,
            encode_ms_per_vec: ms,
            curve,
            auc,
        });
    }

    let title = match cfg.corpus {
        Corpus::Flickr => format!("Figure 2 analogue — recall, synth-Flickr d={}", cfg.d),
        Corpus::ImageNet => format!("Figures 3/4 analogue — recall, synth-ImageNet d={}", cfg.d),
    };
    let mut t = Table::new(
        &title,
        &["regime", "method", "bits", "ms/vec", "recall@10", "recall@100", "AUC"],
    );
    for e in &entries {
        t.row(vec![
            e.regime.to_string(),
            e.method.clone(),
            format!("{}", e.bits),
            format!("{:.3}", e.encode_ms_per_vec),
            format!("{:.3}", e.curve.get(9).cloned().unwrap_or(0.0)),
            format!("{:.3}", e.curve.last().cloned().unwrap_or(0.0)),
            format!("{:.3}", e.auc),
        ]);
    }
    SweepResult {
        entries,
        report: t.render(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SweepConfig {
        SweepConfig {
            corpus: Corpus::ImageNet,
            d: 128,
            n: 400,
            n_train: 150,
            n_queries: 25,
            gt_k: 5,
            bits: vec![64],
            max_r: 50,
            opt_iters: 4,
            seed: 99,
            index: IndexBackend::Auto,
        }
    }

    #[test]
    fn recall_invariant_to_index_backend() {
        // All backends are exact, so the sweep must produce identical
        // curves whichever one serves it.
        let mut base = tiny();
        base.n = 250;
        base.n_train = 100;
        base.n_queries = 12;
        let mut curves: Vec<Vec<f64>> = Vec::new();
        for backend in [
            IndexBackend::Linear,
            IndexBackend::Mih { m: Some(8) },
            IndexBackend::MihSampled { m: Some(8) },
            IndexBackend::ShardedMih { shards: 3, m: None },
        ] {
            let mut cfg = base.clone();
            cfg.index = backend;
            let r = run(&cfg);
            let cbe: Vec<f64> = r
                .entries
                .iter()
                .find(|e| e.method == "CBE-rand" && e.regime == "fixed-bits")
                .unwrap()
                .curve
                .clone();
            curves.push(cbe);
        }
        for (i, c) in curves.iter().enumerate().skip(1) {
            assert_eq!(&curves[0], c, "backend #{i} diverged");
        }
    }

    #[test]
    fn cbe_rand_tracks_lsh_fixed_bits() {
        // The paper's §3/§5 claim: same bits → CBE-rand ≈ LSH.
        let r = run(&tiny());
        let auc = |m: &str| {
            r.entries
                .iter()
                .find(|e| e.method == m && e.regime == "fixed-bits")
                .unwrap()
                .auc
        };
        let cbe = auc("CBE-rand");
        let lsh = auc("LSH");
        assert!(
            (cbe - lsh).abs() < 0.2,
            "CBE-rand {cbe} vs LSH {lsh} should be close"
        );
    }

    #[test]
    fn stacked_long_codes_beat_base_at_fixed_probe_budget() {
        // Acceptance: k > d (stacked) must beat k == d (plain circulant)
        // at the same probe budget. The arms share a seed, so the
        // stacked code extends the baseline code bit-for-bit.
        let r = run(&tiny());
        let auc = |m: &str| {
            r.entries
                .iter()
                .find(|e| e.method == m && e.regime == "long-code")
                .unwrap()
                .auc
        };
        let base = auc("CBE-rand");
        let long_rand = auc("CBE-rand-stacked");
        assert!(
            long_rand > base,
            "stacked 2d AUC {long_rand} should beat circ d AUC {base}"
        );
        let ds = auc("CBE-rand-ds");
        assert!(ds > 0.02, "downsampled arm should beat chance, auc={ds}");
    }

    #[test]
    fn all_methods_better_than_chance() {
        let r = run(&tiny());
        for e in &r.entries {
            assert!(e.auc > 0.02, "{} ({}) auc={}", e.method, e.regime, e.auc);
            // curves monotone
            for w in e.curve.windows(2) {
                assert!(w[1] >= w[0] - 1e-12);
            }
        }
    }
}
