//! §6: semi-supervised CBE. Labeled similar/dissimilar pairs are folded
//! into the optimization (M → M + μA); the paper reports ~2% averaged-AUC
//! improvement on ImageNet-25600. We reproduce the sign and rough size of
//! the effect on the synthetic stand-in.

use crate::bits::BinaryIndex;
use crate::data::{gather, generate, train_query_split, SynthConfig};
use crate::encoders::{BinaryEncoder, CbeOpt, CbeTrainer};
use crate::eval::{recall_auc, recall_curve};
use crate::fft::Planner;
use crate::groundtruth::exact_knn;
use crate::opt::{PairSet, TimeFreqConfig};
use crate::util::rng::Pcg64;
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct Sec6Config {
    pub d: usize,
    pub n: usize,
    pub n_train: usize,
    pub n_queries: usize,
    pub n_pairs: usize,
    pub mu: f64,
    pub k: usize,
    pub seed: u64,
}

impl Sec6Config {
    pub fn quick(d: usize) -> Sec6Config {
        Sec6Config {
            d,
            n: 2000,
            n_train: 400,
            n_queries: 50,
            n_pairs: 600,
            mu: 4.0,
            k: d / 2,
            seed: 606,
        }
    }
}

pub struct Sec6Result {
    pub auc_plain: f64,
    pub auc_semi: f64,
    pub report: String,
}

pub fn run(cfg: &Sec6Config) -> Sec6Result {
    let planner = Planner::new();
    let mut ds = generate(&SynthConfig::imagenet(cfg.n, cfg.d, cfg.seed));
    // Class-irrelevant nuisance energy: real image descriptors carry strong
    // directions (illumination, background) uncorrelated with semantics.
    // The paper's gain comes from supervision suppressing exactly such
    // structure, so the synthetic stand-in must have it: the first d/4
    // dimensions get high-variance class-independent noise.
    {
        let mut nrng = Pcg64::new(cfg.seed ^ 0xbeef);
        let nuisance = cfg.d / 4;
        for i in 0..ds.x.rows {
            let row = ds.x.row_mut(i);
            for v in row.iter_mut().take(nuisance) {
                *v += 2.5 * nrng.normal() as f32 / (nuisance as f32).sqrt();
            }
            crate::util::l2_normalize(row);
        }
    }
    let ds = ds;
    let (train_idx, query_idx) = train_query_split(cfg.n, cfg.n_queries, cfg.seed + 1);
    let db = gather(&ds.x, &train_idx);
    let queries = gather(&ds.x, &query_idx);
    let train_rows = &train_idx[..cfg.n_train.min(train_idx.len())];
    let train = gather(&ds.x, train_rows);
    // Ground truth: the 10 nearest *same-class* database rows. The
    // supervision term teaches class structure, so the §6 metric must be
    // class-aware (plain ℓ2 10-NN would not move with supervision).
    let gt: Vec<Vec<u32>> = {
        let db_labels: Vec<usize> = train_idx.iter().map(|&i| ds.labels[i]).collect();
        let raw = exact_knn(&db, &queries, db.rows.min(400));
        query_idx
            .iter()
            .zip(&raw)
            .map(|(&qi, cands)| {
                cands
                    .iter()
                    .filter(|&&c| db_labels[c as usize] == ds.labels[qi])
                    .take(10)
                    .cloned()
                    .collect()
            })
            .collect()
    };

    // Build supervision from labels of the training subset.
    let labels: Vec<usize> = train_rows.iter().map(|&i| ds.labels[i]).collect();
    let mut rng = Pcg64::new(cfg.seed + 2);
    let mut pairs = PairSet::default();
    let nt = train.rows;
    while pairs.similar.len() < cfg.n_pairs || pairs.dissimilar.len() < cfg.n_pairs {
        let i = rng.below(nt as u64) as usize;
        let j = rng.below(nt as u64) as usize;
        if i == j {
            continue;
        }
        if labels[i] == labels[j] {
            if pairs.similar.len() < cfg.n_pairs {
                pairs.similar.push((i, j));
            }
        } else if pairs.dissimilar.len() < cfg.n_pairs {
            pairs.dissimilar.push((i, j));
        }
    }

    let eval = |enc: &CbeOpt| -> f64 {
        let index = BinaryIndex::new(enc.encode_batch(&db));
        let q = enc.encode_batch(&queries);
        recall_auc(&recall_curve(&index, &q, &gt, 100))
    };

    let mut tf = TimeFreqConfig::new(cfg.k);
    tf.iters = 6;
    let trainer = CbeTrainer::new(tf.clone()).seed(cfg.seed + 3).planner(planner);
    let plain = trainer.train(&train);
    let mut tf_ss = tf;
    tf_ss.mu = cfg.mu;
    let semi = CbeTrainer::new(tf_ss)
        .seed(cfg.seed + 3)
        .planner(trainer.planner.clone())
        .train_with_pairs(&train, Some(&pairs));

    let auc_plain = eval(&plain);
    let auc_semi = eval(&semi);

    let mut t = Table::new(
        &format!("§6 — semi-supervised CBE (d={}, k={}, μ={})", cfg.d, cfg.k, cfg.mu),
        &["variant", "recall AUC"],
    );
    t.row(vec!["CBE-opt".into(), format!("{auc_plain:.4}")]);
    t.row(vec!["CBE-opt + pairs".into(), format!("{auc_semi:.4}")]);
    t.row(vec![
        "Δ (paper: ≈ +2%)".into(),
        format!("{:+.2}%", 100.0 * (auc_semi - auc_plain)),
    ]);
    Sec6Result {
        auc_plain,
        auc_semi,
        report: t.render(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn supervision_does_not_hurt() {
        let mut cfg = Sec6Config::quick(64);
        cfg.n = 600;
        cfg.n_train = 200;
        cfg.n_queries = 25;
        cfg.n_pairs = 120;
        let r = run(&cfg);
        // Effect sizes are noisy at this scale; require "no collapse" and
        // report the delta (the paper's +2% is asserted as shape in the
        // bench at full scale).
        assert!(r.auc_plain > 0.02);
        assert!(r.auc_semi > r.auc_plain - 0.1);
    }
}
