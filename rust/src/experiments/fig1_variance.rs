//! Figure 1: variance of the normalized Hamming distance — analytical
//! independent-bit variance θ(π−θ)/kπ² (eq. 14) vs the sampled variance of
//! circulant bits. The paper's headline observation: the two curves
//! overlap, i.e. circulant bits behave like independent bits.

use crate::bits::hamming::normalized_hamming;
use crate::fft::Planner;
use crate::linalg::qr::random_orthonormal;
use crate::linalg::Mat;
use crate::projections::{CbeModel, ProjectionSpec};
use crate::util::rng::Pcg64;
use crate::util::table::Table;

/// Result rows: (theta, k, analytical variance, circulant sample variance).
pub struct Fig1Result {
    pub rows: Vec<(f64, usize, f64, f64)>,
    pub report: String,
    /// Max |circulant − analytical| across the grid (the overlap claim).
    pub max_gap: f64,
}

/// Place two d-dim unit vectors at exact angle θ: extend the 2-D pair
/// (1,0), (cosθ, sinθ) and apply a random rotation (the paper's footnote 6
/// construction, Gram–Schmidt on random vectors = our Householder QR).
fn pair_at_angle(d: usize, theta: f64, rng: &mut Pcg64) -> (Vec<f32>, Vec<f32>) {
    let q = random_orthonormal(2, rng); // cheap 2×2 mixer for determinism
    let _ = q;
    // Use two random orthonormal directions of R^d from QR of a d×2 matrix.
    let g = Mat::randn(d, 2, rng);
    let (qq, _) = crate::linalg::qr::qr(&g);
    let mut a = vec![0f32; d];
    let mut b = vec![0f32; d];
    let (c, s) = (theta.cos() as f32, theta.sin() as f32);
    for i in 0..d {
        a[i] = qq[(i, 0)];
        b[i] = c * qq[(i, 0)] + s * qq[(i, 1)];
    }
    (a, b)
}

/// Run the Figure-1 simulation. `projections_per_pair` CBE draws per point
/// pair and `pairs` independent pairs per (θ, k) cell (paper: 1000×1000 —
/// scaled down by default, the estimator converges much earlier).
pub fn run(
    d: usize,
    ks: &[usize],
    thetas: &[f64],
    pairs: usize,
    projections_per_pair: usize,
    seed: u64,
) -> Fig1Result {
    let planner = Planner::new();
    let mut rng = Pcg64::new(seed);
    let mut rows = Vec::new();
    let mut max_gap = 0f64;

    for &theta in thetas {
        for &k in ks {
            // k ≤ d uses the paper's single circulant block; k > d rides
            // stacked blocks. The analytical curve θ(π−θ)/kπ² assumes
            // independent bits either way (blocks draw independent r, D).
            let spec = if k <= d {
                ProjectionSpec::Circ
            } else {
                ProjectionSpec::Stacked { blocks: None }
            };
            let analytical = theta * (std::f64::consts::PI - theta)
                / (k as f64 * std::f64::consts::PI * std::f64::consts::PI);
            // Sample variance of H_k over random (pair, projection) draws.
            let mut sum = 0f64;
            let mut sum2 = 0f64;
            let mut count = 0usize;
            for _ in 0..pairs {
                let (a, b) = pair_at_angle(d, theta, &mut rng);
                for _ in 0..projections_per_pair {
                    let proj = CbeModel::random_with(&spec, d, k, &mut rng, planner.clone())
                        .expect("fig1 grid is pre-validated");
                    let ha = proj.encode(&a, k);
                    let hb = proj.encode(&b, k);
                    let h = normalized_hamming(&ha, &hb);
                    sum += h;
                    sum2 += h * h;
                    count += 1;
                }
            }
            let mean = sum / count as f64;
            let var = (sum2 / count as f64 - mean * mean).max(0.0);
            max_gap = max_gap.max((var - analytical).abs());
            rows.push((theta, k, analytical, var));
        }
    }

    let mut t = Table::new(
        "Figure 1 — Var(H_k): independent (analytical) vs circulant (sampled)",
        &["theta", "k", "var independent", "var circulant", "E[H_k] (θ/π)"],
    );
    for (theta, k, ana, var) in &rows {
        t.row(vec![
            format!("{theta:.3}"),
            format!("{k}"),
            format!("{ana:.5}"),
            format!("{var:.5}"),
            format!("{:.3}", theta / std::f64::consts::PI),
        ]);
    }
    Fig1Result {
        rows,
        report: t.render(),
        max_gap,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn circulant_variance_tracks_analytical() {
        // Reduced grid; the overlap claim must hold within noise.
        let r = run(
            64,
            &[16, 64],
            &[std::f64::consts::FRAC_PI_4, std::f64::consts::FRAC_PI_2],
            8,
            60,
            42,
        );
        for (theta, k, ana, var) in &r.rows {
            assert!(
                (var - ana).abs() < 3.0 * ana.max(1e-4),
                "θ={theta} k={k}: analytical {ana} vs circulant {var}"
            );
        }
        // variance shrinks with k (paper: more bits → lower variance)
        let v16: f64 = r.rows.iter().filter(|r| r.1 == 16).map(|r| r.3).sum();
        let v64: f64 = r.rows.iter().filter(|r| r.1 == 64).map(|r| r.3).sum();
        assert!(v64 < v16);
    }

    #[test]
    fn stacked_variance_tracks_analytical_beyond_d() {
        // k > d: eq. 14's independent-bit variance still holds because
        // stacked blocks draw independent (r, D) pairs.
        let r = run(32, &[64], &[std::f64::consts::FRAC_PI_2], 6, 40, 7);
        for (theta, k, ana, var) in &r.rows {
            assert!(
                (var - ana).abs() < 3.0 * ana.max(1e-4),
                "θ={theta} k={k}: analytical {ana} vs stacked {var}"
            );
        }
    }

    #[test]
    fn pair_angle_is_exact() {
        let mut rng = Pcg64::new(3);
        for theta in [0.3f64, 1.0, 1.5] {
            let (a, b) = pair_at_angle(32, theta, &mut rng);
            let got = crate::util::angle(&a, &b) as f64;
            assert!((got - theta).abs() < 1e-3, "want {theta} got {got}");
        }
    }
}
