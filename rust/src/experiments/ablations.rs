//! Ablations for the design choices the paper asserts but does not plot:
//!
//! * **λ robustness** (§5: "performance difference for λ = 0.1, 1, 10 is
//!   within 0.5%") — AUC sweep over λ.
//! * **Sign-flip diagonal D** (§3: required for norm preservation on
//!   adversarial inputs) — projected-norm spread with and without D.
//! * **Optimization iterations** (§4.1: "good solution in 5–10
//!   iterations") — AUC vs iteration count.

use crate::bits::BinaryIndex;
use crate::data::{gather, generate, train_query_split, SynthConfig};
use crate::encoders::{BinaryEncoder, CbeTrainer};
use crate::eval::{recall_auc, recall_curve};
use crate::fft::Planner;
use crate::groundtruth::exact_knn;
use crate::opt::TimeFreqConfig;
use crate::projections::CirculantProjection;
use crate::util::rng::Pcg64;
use crate::util::table::Table;

pub struct AblationResult {
    pub lambda_auc: Vec<(f64, f64)>,
    pub iters_auc: Vec<(usize, f64)>,
    /// (with D spread, without D spread) of projections of the all-ones
    /// vector — the §3 degenerate case.
    pub sign_flip_spread: (f32, f32),
    pub report: String,
}

pub fn run(d: usize, seed: u64) -> AblationResult {
    let planner = Planner::new();
    let n = 1200;
    let ds = generate(&SynthConfig::imagenet(n, d, seed));
    let (train_idx, query_idx) = train_query_split(n, 50, seed + 1);
    let db = gather(&ds.x, &train_idx);
    let queries = gather(&ds.x, &query_idx);
    let train = gather(&ds.x, &train_idx[..300]);
    let gt = exact_knn(&db, &queries, 10);
    let k = d / 2;

    let auc_of = |cfg: TimeFreqConfig| -> f64 {
        let enc = CbeTrainer::new(cfg)
            .seed(seed + 2)
            .planner(planner.clone())
            .train(&train);
        let index = BinaryIndex::new(enc.encode_batch(&db));
        let q = enc.encode_batch(&queries);
        recall_auc(&recall_curve(&index, &q, &gt, 100))
    };

    // λ sweep (paper: within 0.5% for 0.1 / 1 / 10).
    let mut lambda_auc = Vec::new();
    for lambda in [0.1f64, 1.0, 10.0] {
        let mut cfg = TimeFreqConfig::new(k);
        cfg.iters = 6;
        cfg.lambda = lambda;
        lambda_auc.push((lambda, auc_of(cfg)));
    }

    // Iteration sweep (paper: 5–10 iterations suffice).
    let mut iters_auc = Vec::new();
    for iters in [1usize, 3, 5, 10] {
        let mut cfg = TimeFreqConfig::new(k);
        cfg.iters = iters;
        iters_auc.push((iters, auc_of(cfg)));
    }

    // §3 sign-flip ablation on the adversarial all-ones input.
    let mut rng = Pcg64::new(seed + 3);
    let r = rng.normal_vec(d);
    let signs = rng.sign_vec(d);
    let with_d = CirculantProjection::new(r.clone(), signs, planner.clone());
    let without_d = CirculantProjection::new(r, vec![1.0; d], planner);
    let ones = vec![1f32; d];
    let spread = |p: &CirculantProjection| -> f32 {
        let y = p.project(&ones);
        let mean: f32 = y.iter().sum::<f32>() / d as f32;
        (y.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32).sqrt()
    };
    let sign_flip_spread = (spread(&with_d), spread(&without_d));

    let mut t = Table::new(
        &format!("Ablations (d={d}, k={k})"),
        &["ablation", "setting", "value"],
    );
    for (l, a) in &lambda_auc {
        t.row(vec!["λ sweep (AUC)".into(), format!("λ={l}"), format!("{a:.4}")]);
    }
    for (i, a) in &iters_auc {
        t.row(vec!["iterations (AUC)".into(), format!("{i}"), format!("{a:.4}")]);
    }
    t.row(vec![
        "sign flips D (§3)".into(),
        "projection spread of 1-vector, with D".into(),
        format!("{:.4}", sign_flip_spread.0),
    ]);
    t.row(vec![
        "sign flips D (§3)".into(),
        "without D (degenerate: →0)".into(),
        format!("{:.6}", sign_flip_spread.1),
    ]);
    AblationResult {
        lambda_auc,
        iters_auc,
        sign_flip_spread,
        report: t.render(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lambda_robustness_matches_paper() {
        let r = run(96, 5);
        let aucs: Vec<f64> = r.lambda_auc.iter().map(|(_, a)| *a).collect();
        let max = aucs.iter().cloned().fold(f64::MIN, f64::max);
        let min = aucs.iter().cloned().fold(f64::MAX, f64::min);
        // paper: within 0.5% — allow generous noise at this tiny scale
        assert!(max - min < 0.08, "λ sensitivity too high: {aucs:?}");
        // more iterations never catastrophically worse
        let first = r.iters_auc.first().unwrap().1;
        let last = r.iters_auc.last().unwrap().1;
        assert!(last > first - 0.08, "iters 10 ({last}) vs 1 ({first})");
        // D prevents the all-ones degeneracy
        assert!(r.sign_flip_spread.0 > 10.0 * r.sign_flip_spread.1.max(1e-9));
    }
}
