//! One driver per paper table/figure (see DESIGN.md §5 for the index).
//!
//! Every driver prints the same rows/series the paper reports and returns
//! the rendered report so benches/tests can assert on the *shape* of the
//! result (who wins, scaling exponents, crossovers) rather than absolute
//! numbers from the authors' testbed.

pub mod fig1_variance;
pub mod table2_timing;
pub mod recall_sweep;
pub mod fig5_lowdim;
pub mod table3_classify;
pub mod semi_supervised;
pub mod ablations;
