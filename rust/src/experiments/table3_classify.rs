//! Table 3: multiclass classification on binary-coded features.
//!
//! The paper's protocol (Sánchez & Perronnin asymmetric setting): train a
//! linear SVM on the *binarized* projections sign(Rx), evaluate on the
//! *real-valued* projections Rx. Compared: original features, LSH,
//! Bilinear-opt, CBE-opt — all at k = d bits.

use crate::data::{generate, SynthConfig};
use crate::encoders::{BilinearOpt, BinaryEncoder, CbeTrainer, Lsh};
use crate::fft::Planner;
use crate::linalg::Mat;
use crate::opt::TimeFreqConfig;
use crate::svm::{LinearSvm, SvmConfig};
use crate::util::rng::Pcg64;
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct Table3Config {
    pub d: usize,
    pub classes: usize,
    pub per_class_train: usize,
    pub per_class_test: usize,
    pub seed: u64,
}

impl Table3Config {
    pub fn quick(d: usize) -> Table3Config {
        Table3Config {
            d,
            classes: 10,
            per_class_train: 30,
            per_class_test: 15,
            seed: 25600,
        }
    }
}

pub struct Table3Result {
    pub accuracy: Vec<(String, f64)>,
    pub report: String,
}

/// Project every row with an encoder's underlying real-valued projection
/// and ℓ2-normalize the result. Binary codes (±1) and real projections
/// (≈1/√d per coordinate for near-orthogonal R) live on very different
/// scales; normalizing both sides is the paper's footnote-9 rescaling
/// (B ∈ {±1/√d}) applied symmetrically, and keeps the asymmetric
/// train-on-codes / test-on-projections protocol scale-consistent.
fn project_all(rows: &Mat, f: &dyn Fn(&[f32]) -> Vec<f32>) -> Mat {
    let probe = f(rows.row(0));
    let mut out = Mat::zeros(rows.rows, probe.len());
    out.row_mut(0).copy_from_slice(&probe);
    for i in 1..rows.rows {
        let v = f(rows.row(i));
        out.row_mut(i).copy_from_slice(&v);
    }
    for i in 0..out.rows {
        crate::util::l2_normalize(out.row_mut(i));
    }
    out
}

pub fn run(cfg: &Table3Config) -> Table3Result {
    let planner = Planner::new();
    let n = cfg.classes * (cfg.per_class_train + cfg.per_class_test);
    let mut synth = SynthConfig::imagenet(n, cfg.d, cfg.seed);
    synth.clusters = cfg.classes;
    synth.zipf = 0.0; // balanced classes, as the paper samples per class
    let ds = generate(&synth);

    // Per-class balanced split.
    let mut train_idx = Vec::new();
    let mut test_idx = Vec::new();
    let mut counts = vec![0usize; cfg.classes];
    for (i, &c) in ds.labels.iter().enumerate() {
        if counts[c] < cfg.per_class_train {
            train_idx.push(i);
        } else {
            test_idx.push(i);
        }
        counts[c] += 1;
    }
    let xtrain = crate::data::gather(&ds.x, &train_idx);
    let xtest = crate::data::gather(&ds.x, &test_idx);
    let ytrain: Vec<usize> = train_idx.iter().map(|&i| ds.labels[i]).collect();
    let ytest: Vec<usize> = test_idx.iter().map(|&i| ds.labels[i]).collect();

    let svm_cfg = SvmConfig::default();
    let mut results = Vec::new();

    // Original features.
    let svm = LinearSvm::train(&xtrain, &ytrain, cfg.classes, &svm_cfg);
    results.push(("Original".to_string(), svm.accuracy(&xtest, &ytest)));

    // LSH (k = d).
    let lsh = Lsh::new(cfg.d, cfg.d, cfg.seed + 1);
    {
        let tr = project_all(&xtrain, &|x| lsh.encode_signs(x));
        let te = project_all(&xtest, &|x| lsh.proj.project(x));
        let svm = LinearSvm::train(&tr, &ytrain, cfg.classes, &svm_cfg);
        results.push(("LSH".to_string(), svm.accuracy(&te, &ytest)));
    }

    // Bilinear-opt.
    let bil = BilinearOpt::train(&xtrain, cfg.d.min(256), 3, cfg.seed + 2);
    {
        let tr = project_all(&xtrain, &|x| bil.encode_signs(x));
        let te = project_all(&xtest, &|x| bil.proj.project(x));
        let svm = LinearSvm::train(&tr, &ytrain, cfg.classes, &svm_cfg);
        results.push(("Bilinear-opt".to_string(), svm.accuracy(&te, &ytest)));
    }

    // CBE-opt.
    let mut tf = TimeFreqConfig::new(cfg.d);
    tf.iters = 5;
    let cbe = CbeTrainer::new(tf).seed(cfg.seed + 3).planner(planner).train(&xtrain);
    {
        let tr = project_all(&xtrain, &|x| cbe.encode_signs(x));
        let te = project_all(&xtest, &|x| cbe.model.as_circulant().unwrap().project(x));
        let svm = LinearSvm::train(&tr, &ytrain, cfg.classes, &svm_cfg);
        results.push(("CBE-opt".to_string(), svm.accuracy(&te, &ytest)));
    }

    let _ = Pcg64::new(0); // keep rng import honest if protocols change
    let mut t = Table::new(
        &format!(
            "Table 3 analogue — classification accuracy, {} classes, d={}",
            cfg.classes, cfg.d
        ),
        &["features", "accuracy"],
    );
    for (name, acc) in &results {
        t.row(vec![name.clone(), format!("{:.4}", acc)]);
    }
    Table3Result {
        accuracy: results,
        report: t.render(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_codes_retain_separability() {
        let mut cfg = Table3Config::quick(64);
        cfg.classes = 5;
        cfg.per_class_train = 25;
        cfg.per_class_test = 10;
        let r = run(&cfg);
        let get = |m: &str| r.accuracy.iter().find(|(n, _)| n == m).unwrap().1;
        let orig = get("Original");
        let cbe = get("CBE-opt");
        let chance = 1.0 / 5.0;
        assert!(orig > 2.0 * chance, "original={orig}");
        assert!(cbe > 1.5 * chance, "cbe={cbe}");
        // paper's claim: CBE shows no (big) degradation vs original
        assert!(cbe > orig - 0.25, "cbe={cbe} vs orig={orig}");
    }
}
