//! Table 2: projection time (ms) vs dimensionality for full (LSH-style),
//! bilinear and circulant projections, single core, k = d bits — plus a
//! stacked-circulant column at k = 2d (two blocks, two FFTs) showing the
//! long-code regime stays in the O(B·d log d) family.
//!
//! The paper's machine shows ~d² : d√d : 5·d·log d. Absolute numbers differ
//! on this testbed; the *shape* (who wins, the growing gap, the memory
//! wall for full projection) is what the harness reproduces. Configs whose
//! projection matrix would exceed the memory budget are skipped — exactly
//! like the paper's empty cells ("larger than the machine limit of 24GB").

use crate::bench::Bench;
use crate::fft::Planner;
use crate::projections::{
    BilinearProjection, CbeModel, CirculantProjection, FullProjection, ProjectionSpec,
};
use crate::util::rng::Pcg64;
use crate::util::table::{fmt_ms, Table};

/// One row of Table 2.
pub struct TimingRow {
    pub d: usize,
    pub full_ms: Option<f64>,
    pub bilinear_ms: f64,
    pub circulant_ms: f64,
    /// Stacked circulant at k = 2d (two blocks) — the long-code arm.
    pub stacked2_ms: f64,
}

pub struct Table2Result {
    pub rows: Vec<TimingRow>,
    pub report: String,
}

/// Memory budget for the full projection matrix (bytes).
pub const DEFAULT_MEM_BUDGET: usize = 2 << 30; // 2 GiB — container-scale 24GB analogue

/// Run the timing sweep. `dims` are the d values (k = d bits throughout,
/// matching the paper's long-code setting).
pub fn run(dims: &[usize], mem_budget: usize, seed: u64) -> Table2Result {
    let planner = Planner::new();
    let mut rng = Pcg64::new(seed);
    let mut rows = Vec::new();
    let mut bench = Bench::new(1, 5);

    for &d in dims {
        let x = rng.normal_vec(d);

        // Circulant: O(d log d)
        let circ = CirculantProjection::random(d, &mut rng, planner.clone());
        let circulant_ms = bench.run(&format!("circulant d={d}"), || {
            std::hint::black_box(circ.project(std::hint::black_box(&x)));
        });

        // Stacked circulant, k = 2d: two blocks, O(2·d log d).
        let stacked = CbeModel::random_with(
            &ProjectionSpec::Stacked { blocks: Some(2) },
            d,
            2 * d,
            &mut rng,
            planner.clone(),
        )
        .expect("2d bits fit two stacked blocks");
        let stacked2_ms = bench.run(&format!("stacked:2 d={d}"), || {
            std::hint::black_box(stacked.encode(std::hint::black_box(&x), 2 * d));
        });

        // Bilinear: O(d^1.5)
        let bil = BilinearProjection::random(d, d, &mut rng);
        let bilinear_ms = bench.run(&format!("bilinear d={d}"), || {
            std::hint::black_box(bil.project(std::hint::black_box(&x)));
        });

        // Full: O(d²) — skipped above the memory wall like the paper.
        let full_bytes = d.checked_mul(d).and_then(|n| n.checked_mul(4));
        let full_ms = match full_bytes {
            Some(b) if b <= mem_budget => {
                let full = FullProjection::random(d, d, &mut rng);
                Some(bench.run(&format!("full d={d}"), || {
                    std::hint::black_box(full.project(std::hint::black_box(&x)));
                }))
            }
            _ => None,
        };

        rows.push(TimingRow {
            d,
            full_ms,
            bilinear_ms,
            circulant_ms,
            stacked2_ms,
        });
    }

    let mut t = Table::new(
        "Table 2 — projection time (ms), k = d bits, single core",
        &[
            "d",
            "Full proj.",
            "Bilinear proj.",
            "Circulant proj.",
            "Stacked circ. (2d bits)",
        ],
    );
    for r in &rows {
        t.row(vec![
            format!("2^{:.0} ({})", (r.d as f64).log2(), r.d),
            r.full_ms.map(fmt_ms).unwrap_or_else(|| "-".into()),
            fmt_ms(r.bilinear_ms),
            fmt_ms(r.circulant_ms),
            fmt_ms(r.stacked2_ms),
        ]);
    }
    Table2Result {
        rows,
        report: t.render(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn circulant_wins_at_scale() {
        // Shape check at CI-friendly sizes: by d = 2^13 the circulant
        // projection must beat full, and the full/circulant ratio must
        // grow with d (the paper's whole point).
        let r = run(&[1 << 10, 1 << 13], usize::MAX, 7);
        let last = &r.rows[1];
        let full = last.full_ms.unwrap();
        assert!(
            last.circulant_ms < full,
            "circulant {} !< full {}",
            last.circulant_ms,
            full
        );
        let first = &r.rows[0];
        let ratio0 = first.full_ms.unwrap() / first.circulant_ms;
        let ratio1 = full / last.circulant_ms;
        assert!(ratio1 > ratio0, "gap must grow: {ratio0} -> {ratio1}");
        // Long codes stay cheap: twice the bits of the full projection for
        // a fraction of its time at scale.
        assert!(
            last.stacked2_ms < full,
            "stacked 2d {} !< full {}",
            last.stacked2_ms,
            full
        );
    }

    #[test]
    fn memory_wall_skips_full() {
        let r = run(&[256], 1024, 8); // budget too small for 256²×4 bytes
        assert!(r.rows[0].full_ms.is_none());
        assert!(r.report.contains('-'));
    }
}
