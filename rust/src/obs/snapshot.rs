//! [`StatsSnapshot`]: a point-in-time, structured view of service +
//! pipeline statistics, serializable to JSON with the crate's hand-rolled
//! [`crate::util::json::Json`] (the toolchain is offline — no serde).
//!
//! The snapshot merges two sources: per-service counters and the
//! end-to-end latency histogram from [`crate::coordinator::Metrics`]
//! (filled in by `Metrics::snapshot`), and the process-global per-stage
//! recorder ([`super::span::global`]) folded in via
//! [`StatsSnapshot::with_stages`]. It crosses the control-plane channel
//! as a plain struct (`ControlRequest::Stats`) and prints as one JSON
//! object — the schema is documented in ARCHITECTURE.md §Observability.

use super::histogram::Histogram;
use super::span::{Counter, Recorder, Stage};
use crate::util::json::Json;

/// Summary of one latency histogram (µs buckets): count, total time and
/// the p50/p99/p999/max quantiles. Quantiles carry the histogram's
/// +3.125% bucket error; `max_us` is exact.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageStats {
    pub count: u64,
    pub total_ms: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    pub max_us: u64,
}

impl StageStats {
    pub fn from_histogram(h: &Histogram) -> StageStats {
        let (p50, p99, p999, max) = h.percentiles();
        StageStats {
            count: h.count(),
            total_ms: h.sum() as f64 / 1e3,
            p50_us: p50,
            p99_us: p99,
            p999_us: p999,
            max_us: max,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("total_ms", Json::num(self.total_ms)),
            ("p50_us", Json::num(self.p50_us as f64)),
            ("p99_us", Json::num(self.p99_us as f64)),
            ("p999_us", Json::num(self.p999_us as f64)),
            ("max_us", Json::num(self.max_us as f64)),
        ])
    }
}

/// Identity of the live projection model, stamped into every snapshot so
/// scrapes can tell *what* is serving, not just which version counter.
#[derive(Clone, Debug, Default)]
pub struct ProjectionInfo {
    /// Canonical projection spec (`circ`, `stacked:2`, `downsampled`).
    pub spec: String,
    /// Variant name (`circ` | `stacked` | `downsampled`).
    pub variant: &'static str,
    /// Circulant blocks in the model (1 except for stacked).
    pub blocks: usize,
    /// Total bits served per code.
    pub bits: usize,
}

impl ProjectionInfo {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("spec", Json::str(&self.spec)),
            ("variant", Json::str(self.variant)),
            ("blocks", Json::num(self.blocks as f64)),
            ("bits", Json::num(self.bits as f64)),
        ])
    }
}

/// Point-in-time service statistics (see module docs for provenance).
#[derive(Clone, Debug, Default)]
pub struct StatsSnapshot {
    /// Live model version of the answering service.
    pub model_version: u64,
    /// Identity of the live projection model.
    pub projection: ProjectionInfo,
    /// Requests served through the data plane.
    pub requests: u64,
    /// Batches launched.
    pub batches: u64,
    /// Mean occupancy of launched batches (1.0 = always full).
    pub batch_occupancy: f64,
    /// Completed retrain hot-swaps on this service.
    pub retrains: u64,
    /// Searches refused with `CbeError::StaleIndex`.
    pub stale_rejections: u64,
    /// Requests rejected at admission with `CbeError::Overloaded`
    /// (bounded queue full).
    pub overloads: u64,
    /// WAL records durably appended (process-wide).
    pub wal_appends: u64,
    /// WAL records replayed onto snapshots during loads (process-wide).
    pub wal_replays: u64,
    /// WAL compactions into fresh snapshots (process-wide).
    pub wal_compactions: u64,
    /// Completed recovery loads (process-wide).
    pub recoveries: u64,
    /// Snapshot loads through the zero-copy mmap path (process-wide).
    pub mmap_loads: u64,
    /// Snapshot loads through the portable heap path (process-wide).
    pub heap_loads: u64,
    /// Snapshot bytes served straight from mapped sections, summed
    /// across loads (process-wide).
    pub mapped_bytes: u64,
    /// Mapped stores promoted to owned heap copies on first mutation
    /// (process-wide).
    pub promoted_to_owned: u64,
    /// Microseconds spent in the streaming verify pass of snapshot
    /// loads, summed (process-wide).
    pub load_verify_us: u64,
    /// Process-wide MIH bucket lookups.
    pub probes: u64,
    /// Process-wide postings touched before dedup.
    pub candidates: u64,
    /// Process-wide exact Hamming re-rank computations.
    pub reranked: u64,
    /// FFT plan-cache read-path hits (process-wide).
    pub plan_cache_hits: u64,
    /// FFT plan-cache write-path entries (process-wide).
    pub plan_cache_misses: u64,
    /// End-to-end request latency (enqueue → reply), this service.
    pub latency: StageStats,
    /// Per-stage timings from the process-global recorder, keyed by
    /// [`Stage::name`].
    pub stages: Vec<(&'static str, StageStats)>,
}

impl StatsSnapshot {
    /// Fold the per-stage histograms and event counters of `rec`
    /// (normally [`super::span::global`]) into the snapshot.
    pub fn with_stages(mut self, rec: &Recorder) -> StatsSnapshot {
        self.probes = rec.counter(Counter::Probes);
        self.candidates = rec.counter(Counter::Candidates);
        self.reranked = rec.counter(Counter::Reranked);
        self.plan_cache_hits = rec.counter(Counter::PlanHit);
        self.plan_cache_misses = rec.counter(Counter::PlanMiss);
        self.wal_appends = rec.counter(Counter::WalAppend);
        self.wal_replays = rec.counter(Counter::WalReplay);
        self.wal_compactions = rec.counter(Counter::WalCompaction);
        self.recoveries = rec.counter(Counter::Recovery);
        self.mmap_loads = rec.counter(Counter::MmapLoad);
        self.heap_loads = rec.counter(Counter::HeapLoad);
        self.mapped_bytes = rec.counter(Counter::MappedBytes);
        self.promoted_to_owned = rec.counter(Counter::PromoteOwned);
        self.load_verify_us = rec.counter(Counter::LoadVerifyUs);
        self.stages = Stage::ALL
            .iter()
            .map(|&s| (s.name(), StageStats::from_histogram(rec.histogram(s))))
            .collect();
        self
    }

    /// The stats of one stage, by its snake_case name.
    pub fn stage(&self, name: &str) -> Option<StageStats> {
        self.stages.iter().find(|(n, _)| *n == name).map(|(_, s)| *s)
    }

    /// Serialize to one JSON object (schema: ARCHITECTURE.md
    /// §Observability).
    pub fn to_json(&self) -> Json {
        let stages = Json::Obj(
            self.stages
                .iter()
                .map(|(name, s)| (name.to_string(), s.to_json()))
                .collect(),
        );
        Json::obj(vec![
            ("model_version", Json::num(self.model_version as f64)),
            ("projection", self.projection.to_json()),
            ("requests", Json::num(self.requests as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("batch_occupancy", Json::num(self.batch_occupancy)),
            ("retrains", Json::num(self.retrains as f64)),
            ("stale_rejections", Json::num(self.stale_rejections as f64)),
            ("overloads", Json::num(self.overloads as f64)),
            (
                "persist",
                Json::obj(vec![
                    ("wal_appends", Json::num(self.wal_appends as f64)),
                    ("wal_replays", Json::num(self.wal_replays as f64)),
                    ("wal_compactions", Json::num(self.wal_compactions as f64)),
                    ("recoveries", Json::num(self.recoveries as f64)),
                ]),
            ),
            (
                "load",
                Json::obj(vec![
                    // Which path the most recent loads took: counts of
                    // each, not a single enum, because one process can
                    // load several indexes.
                    (
                        "mode",
                        Json::str(if self.mmap_loads > 0 { "mmap" } else { "heap" }),
                    ),
                    ("mmap_loads", Json::num(self.mmap_loads as f64)),
                    ("heap_loads", Json::num(self.heap_loads as f64)),
                    ("mapped_bytes", Json::num(self.mapped_bytes as f64)),
                    ("verify_ms", Json::num(self.load_verify_us as f64 / 1e3)),
                    (
                        "promoted_to_owned",
                        Json::num(self.promoted_to_owned as f64),
                    ),
                ]),
            ),
            (
                "index",
                Json::obj(vec![
                    ("probes", Json::num(self.probes as f64)),
                    ("candidates", Json::num(self.candidates as f64)),
                    ("reranked", Json::num(self.reranked as f64)),
                ]),
            ),
            (
                "fft_plan_cache",
                Json::obj(vec![
                    ("hits", Json::num(self.plan_cache_hits as f64)),
                    ("misses", Json::num(self.plan_cache_misses as f64)),
                ]),
            ),
            ("latency_us", self.latency.to_json()),
            ("stages", stages),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_round_trips_through_json() {
        let rec = Recorder::new();
        rec.record_us(Stage::Encode, 120);
        rec.record_us(Stage::Probe, 40);
        rec.add(Counter::Probes, 6);
        rec.add(Counter::WalAppend, 2);
        rec.add(Counter::Recovery, 1);
        rec.add(Counter::MmapLoad, 1);
        rec.add(Counter::MappedBytes, 4096);
        rec.add(Counter::PromoteOwned, 3);
        rec.add(Counter::LoadVerifyUs, 1500);
        let hist = Histogram::new();
        hist.record(500);
        let snap = StatsSnapshot {
            model_version: 2,
            projection: ProjectionInfo {
                spec: "stacked:2".to_string(),
                variant: "stacked",
                blocks: 2,
                bits: 96,
            },
            requests: 1,
            batches: 1,
            batch_occupancy: 0.5,
            retrains: 2,
            stale_rejections: 1,
            latency: StageStats::from_histogram(&hist),
            ..Default::default()
        }
        .with_stages(&rec);

        assert_eq!(snap.probes, 6);
        assert_eq!(snap.stages.len(), Stage::COUNT);
        assert_eq!(snap.stage("encode").unwrap().count, 1);
        assert!(snap.stage("nope").is_none());

        let text = snap.to_json().to_string();
        let parsed = Json::parse(&text).expect("snapshot JSON must parse");
        assert_eq!(parsed.get("retrains").and_then(Json::as_f64), Some(2.0));
        let proj = parsed.get("projection").expect("projection block present");
        assert_eq!(proj.get("spec").and_then(Json::as_str), Some("stacked:2"));
        assert_eq!(proj.get("variant").and_then(Json::as_str), Some("stacked"));
        assert_eq!(proj.get("blocks").and_then(Json::as_f64), Some(2.0));
        assert_eq!(proj.get("bits").and_then(Json::as_f64), Some(96.0));
        assert_eq!(
            parsed
                .get("index")
                .and_then(|i| i.get("probes"))
                .and_then(Json::as_f64),
            Some(6.0)
        );
        assert_eq!(snap.wal_appends, 2);
        assert_eq!(snap.recoveries, 1);
        assert_eq!(
            parsed
                .get("persist")
                .and_then(|p| p.get("wal_appends"))
                .and_then(Json::as_f64),
            Some(2.0)
        );
        assert_eq!(parsed.get("overloads").and_then(Json::as_f64), Some(0.0));
        let load = parsed.get("load").expect("load block present");
        assert_eq!(load.get("mode").and_then(Json::as_str), Some("mmap"));
        assert_eq!(load.get("mmap_loads").and_then(Json::as_f64), Some(1.0));
        assert_eq!(load.get("heap_loads").and_then(Json::as_f64), Some(0.0));
        assert_eq!(load.get("mapped_bytes").and_then(Json::as_f64), Some(4096.0));
        assert_eq!(load.get("verify_ms").and_then(Json::as_f64), Some(1.5));
        assert_eq!(
            load.get("promoted_to_owned").and_then(Json::as_f64),
            Some(3.0)
        );
        let enc = parsed.get("stages").and_then(|s| s.get("encode")).unwrap();
        assert_eq!(enc.get("count").and_then(Json::as_f64), Some(1.0));
        assert_eq!(
            parsed
                .get("latency_us")
                .and_then(|l| l.get("max_us"))
                .and_then(Json::as_f64),
            Some(500.0)
        );
    }
}
