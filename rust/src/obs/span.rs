//! Per-stage span recorder: where the time goes, per pipeline stage.
//!
//! A [`Recorder`] holds one [`Histogram`] (µs resolution) per [`Stage`]
//! plus a bank of monotonic event [`Counter`]s. Hot paths either open a
//! scoped [`Span`] guard ([`Recorder::start`], recorded on drop) or
//! report an externally measured duration ([`Recorder::record`]); both
//! cost a handful of relaxed atomics. The free functions in
//! [`crate::obs`] (`span`, `record`, `add`) route to the process-global
//! recorder behind the `CBE_OBS` gate, so an instrumented path that is
//! disabled pays one atomic load and nothing else.
//!
//! Stage timings live in one process-global recorder rather than per
//! service because the deepest spans (index probing, trainer phases) run
//! in code that has no service handle — per-service attribution stays in
//! [`crate::coordinator::Metrics`]; the recorder answers "where does the
//! time go in this process".

use super::histogram::Histogram;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// Every instrumented pipeline stage, across the three hot paths:
/// request (`QueueWait → ModelResolve → Encode → Pack`), index
/// (`Probe → CandidateDedup → ReRank`), trainer
/// (`CacheBuild → Sweep → BinSolve`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Request time queued before its batch launched.
    QueueWait,
    /// Resolving the active model from the registry (per batch).
    ModelResolve,
    /// The parallel batch encode (per batch).
    Encode,
    /// Per-request sign extraction + reply scatter (per batch).
    Pack,
    /// MIH key enumeration + bucket fetches (per query).
    Probe,
    /// Generation-stamp candidate dedup (per query).
    CandidateDedup,
    /// Exact Hamming re-rank, sweep-cutover rows included (per query).
    ReRank,
    /// Trainer: building (or streaming) the half-spectrum cache.
    CacheBuild,
    /// Trainer: time-domain sweep (B = sign(XRᵀ), h/g folds).
    Sweep,
    /// Trainer: closed-form per-bin solve + inverse FFT.
    BinSolve,
    /// Persist: reading + validating a snapshot and replaying its WAL
    /// (the whole `persist::load` path, per load).
    SnapshotLoad,
}

impl Stage {
    pub const COUNT: usize = 11;
    pub const ALL: [Stage; Stage::COUNT] = [
        Stage::QueueWait,
        Stage::ModelResolve,
        Stage::Encode,
        Stage::Pack,
        Stage::Probe,
        Stage::CandidateDedup,
        Stage::ReRank,
        Stage::CacheBuild,
        Stage::Sweep,
        Stage::BinSolve,
        Stage::SnapshotLoad,
    ];

    /// Stable snake_case name — the key used in the stats snapshot JSON.
    pub fn name(self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::ModelResolve => "model_resolve",
            Stage::Encode => "encode",
            Stage::Pack => "pack",
            Stage::Probe => "probe",
            Stage::CandidateDedup => "candidate_dedup",
            Stage::ReRank => "re_rank",
            Stage::CacheBuild => "cache_build",
            Stage::Sweep => "sweep",
            Stage::BinSolve => "bin_solve",
            Stage::SnapshotLoad => "snapshot_load",
        }
    }

    #[inline]
    fn idx(self) -> usize {
        self as usize
    }
}

/// Monotonic event counters riding alongside the stage timers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// MIH bucket lookups (keys enumerated).
    Probes,
    /// Postings touched before dedup.
    Candidates,
    /// Exact Hamming distance computations.
    Reranked,
    /// FFT plan-cache read-path hits.
    PlanHit,
    /// FFT plan-cache write-path entries (first build of a length).
    PlanMiss,
    /// WAL records durably appended (insert/remove churn).
    WalAppend,
    /// WAL records replayed onto a snapshot during load.
    WalReplay,
    /// WAL compactions: churn folded into a fresh snapshot, log reset.
    WalCompaction,
    /// Recovery loads completed (any terminal classification).
    Recovery,
    /// Snapshot loads that went through the zero-copy mmap path.
    MmapLoad,
    /// Snapshot loads that took the portable heap (read + copy) path.
    HeapLoad,
    /// Bytes currently served straight from mapped snapshot sections
    /// (accumulated across loads; a gauge in spirit, counter in shape).
    MappedBytes,
    /// Mapped stores promoted to owned heap copies on first mutation.
    PromoteOwned,
    /// Microseconds spent in the streaming CRC/structure verify pass of
    /// snapshot loads (accumulated).
    LoadVerifyUs,
}

impl Counter {
    pub const COUNT: usize = 14;
    pub const ALL: [Counter; Counter::COUNT] = [
        Counter::Probes,
        Counter::Candidates,
        Counter::Reranked,
        Counter::PlanHit,
        Counter::PlanMiss,
        Counter::WalAppend,
        Counter::WalReplay,
        Counter::WalCompaction,
        Counter::Recovery,
        Counter::MmapLoad,
        Counter::HeapLoad,
        Counter::MappedBytes,
        Counter::PromoteOwned,
        Counter::LoadVerifyUs,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::Probes => "probes",
            Counter::Candidates => "candidates",
            Counter::Reranked => "reranked",
            Counter::PlanHit => "plan_hits",
            Counter::PlanMiss => "plan_misses",
            Counter::WalAppend => "wal_appends",
            Counter::WalReplay => "wal_replays",
            Counter::WalCompaction => "wal_compactions",
            Counter::Recovery => "recoveries",
            Counter::MmapLoad => "mmap_loads",
            Counter::HeapLoad => "heap_loads",
            Counter::MappedBytes => "mapped_bytes",
            Counter::PromoteOwned => "promoted_to_owned",
            Counter::LoadVerifyUs => "load_verify_us",
        }
    }

    #[inline]
    fn idx(self) -> usize {
        self as usize
    }
}

/// A bank of per-stage histograms + counters. Construct private ones in
/// tests for exact assertions; production paths share [`global`].
pub struct Recorder {
    cells: [Histogram; Stage::COUNT],
    counters: [AtomicU64; Counter::COUNT],
}

impl Default for Recorder {
    fn default() -> Recorder {
        Recorder::new()
    }
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder {
            cells: std::array::from_fn(|_| Histogram::new()),
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Open a scoped span: the stage is timed from now until the guard
    /// drops.
    #[inline]
    pub fn start(&self, stage: Stage) -> Span<'_> {
        Span {
            rec: self,
            stage,
            t0: Instant::now(),
        }
    }

    /// Record an externally measured duration (µs resolution; sub-µs
    /// spans count but round to 0).
    #[inline]
    pub fn record(&self, stage: Stage, dur: Duration) {
        self.record_us(stage, dur.as_micros() as u64);
    }

    /// Record a duration already expressed in microseconds.
    #[inline]
    pub fn record_us(&self, stage: Stage, us: u64) {
        self.cells[stage.idx()].record(us);
    }

    /// Bump an event counter by `n`.
    #[inline]
    pub fn add(&self, counter: Counter, n: u64) {
        self.counters[counter.idx()].fetch_add(n, Ordering::Relaxed);
    }

    /// Current value of an event counter.
    pub fn counter(&self, counter: Counter) -> u64 {
        self.counters[counter.idx()].load(Ordering::Relaxed)
    }

    /// The stage's latency histogram (µs).
    pub fn histogram(&self, stage: Stage) -> &Histogram {
        &self.cells[stage.idx()]
    }

    /// Total wall time attributed to a stage.
    pub fn total(&self, stage: Stage) -> Duration {
        Duration::from_micros(self.cells[stage.idx()].sum())
    }
}

/// Scoped span guard: records `stage` on drop. Nesting attributes each
/// level to its own stage — the outer span's time *includes* the inner
/// span's (wall-clock attribution, not exclusive self-time).
pub struct Span<'a> {
    rec: &'a Recorder,
    stage: Stage,
    t0: Instant,
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        self.rec.record(self.stage, self.t0.elapsed());
    }
}

static GLOBAL: OnceLock<Recorder> = OnceLock::new();

/// The process-global recorder behind [`crate::obs::span`] /
/// [`crate::obs::record`] / [`crate::obs::add`].
pub fn global() -> &'static Recorder {
    GLOBAL.get_or_init(Recorder::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nested_spans_attribute_to_their_own_stages() {
        let r = Recorder::new();
        {
            let _outer = r.start(Stage::Encode);
            std::thread::sleep(Duration::from_millis(4));
            {
                let _inner = r.start(Stage::Pack);
                std::thread::sleep(Duration::from_millis(4));
            }
        }
        assert_eq!(r.histogram(Stage::Encode).count(), 1);
        assert_eq!(r.histogram(Stage::Pack).count(), 1);
        // Wall-clock attribution: the outer span covers the inner one.
        assert!(r.total(Stage::Encode) >= r.total(Stage::Pack));
        assert!(r.total(Stage::Pack) >= Duration::from_millis(3));
        // Untouched stages stay empty.
        for s in [Stage::Probe, Stage::Sweep, Stage::QueueWait] {
            assert_eq!(r.histogram(s).count(), 0, "{}", s.name());
        }
    }

    #[test]
    fn record_us_feeds_the_stage_histogram() {
        let r = Recorder::new();
        r.record_us(Stage::Probe, 250);
        r.record_us(Stage::Probe, 750);
        assert_eq!(r.histogram(Stage::Probe).count(), 2);
        assert_eq!(r.histogram(Stage::Probe).max(), 750);
        assert_eq!(r.total(Stage::Probe), Duration::from_micros(1000));
    }

    #[test]
    fn counters_accumulate_independently() {
        let r = Recorder::new();
        r.add(Counter::Probes, 3);
        r.add(Counter::Probes, 4);
        r.add(Counter::Reranked, 5);
        assert_eq!(r.counter(Counter::Probes), 7);
        assert_eq!(r.counter(Counter::Reranked), 5);
        assert_eq!(r.counter(Counter::Candidates), 0);
        assert_eq!(r.counter(Counter::PlanHit), 0);
    }

    #[test]
    fn stage_names_are_unique_and_stable() {
        let mut names: Vec<&str> = Stage::ALL.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Stage::COUNT);
        assert_eq!(Stage::QueueWait.name(), "queue_wait");
        assert_eq!(Stage::ReRank.name(), "re_rank");
    }
}
