//! Lock-free log-linear histogram with fixed memory and bounded error.
//!
//! HdrHistogram-style bucketing over `u64` values (the serving paths
//! record microseconds): values below [`Histogram::LINEAR_MAX`] each get
//! their own bucket (exact); above, every power-of-two octave is split
//! into 32 equal sub-buckets, so a bucket's width is at most 1/32 of the
//! values it holds and any reported quantile is within **+3.125%** of the
//! true value (~2 significant digits). The whole range of `u64` fits in
//! 1920 buckets (~15 KiB of `AtomicU64`s) — a histogram never grows,
//! however long the service lives.
//!
//! Recording is three relaxed `fetch_add`s plus one `fetch_max` — no
//! locks, no allocation, safe from any thread. Reads ([`Histogram::p`],
//! [`Histogram::merge`]) walk the buckets without stopping writers; a
//! snapshot taken under concurrent recording is a valid histogram of
//! *some* interleaving, which is all a stats endpoint needs.

use std::sync::atomic::{AtomicU64, Ordering};

/// Sub-buckets per power-of-two octave: the resolution knob. 32 ⇒ bucket
/// width ≤ value/32 ⇒ quantile error ≤ 3.125%.
const SUBBUCKETS: usize = 32;
/// log2([`SUBBUCKETS`]).
const SUB_SHIFT: u32 = 5;
/// Octaves above the exact linear region (exponents 5..=63).
const OCTAVES: usize = 64 - SUB_SHIFT as usize;
/// Total buckets covering all of `u64`.
const NBUCKETS: usize = SUBBUCKETS + OCTAVES * SUBBUCKETS;

/// Lock-free fixed-bucket log-scale histogram (see module docs).
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    /// Exact maximum (`fetch_max`), so `p(1.0)` and `max()` never suffer
    /// bucket quantization.
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    /// Values below this map to their own bucket, exactly.
    pub const LINEAR_MAX: u64 = SUBBUCKETS as u64;

    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Bucket index of a value: identity below [`Histogram::LINEAR_MAX`],
    /// log-linear (octave × sub-bucket) above.
    #[inline]
    fn bucket_of(v: u64) -> usize {
        if v < Self::LINEAR_MAX {
            v as usize
        } else {
            let exp = 63 - v.leading_zeros();
            let sub = (v >> (exp - SUB_SHIFT)) as usize - SUBBUCKETS;
            SUBBUCKETS + (exp - SUB_SHIFT) as usize * SUBBUCKETS + sub
        }
    }

    /// Largest value mapping to bucket `idx` (quantiles report this upper
    /// edge, hence the one-sided +1/32 error bound).
    #[inline]
    fn bucket_high(idx: usize) -> u64 {
        if idx < SUBBUCKETS {
            idx as u64
        } else {
            let oct = ((idx - SUBBUCKETS) / SUBBUCKETS) as u32;
            let sub = ((idx - SUBBUCKETS) % SUBBUCKETS) as u64;
            let width = 1u64 << oct;
            (SUBBUCKETS as u64 + sub) * width + (width - 1)
        }
    }

    /// Record one value. Lock-free; callable from any thread.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Values recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact sum of recorded values (wrapping only past 2⁶⁴).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Exact maximum recorded value (0 when empty).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// The q-quantile (q ∈ [0, 1]): the bucket upper edge at the
    /// ⌈q·count⌉-th smallest record, capped at the exact max — so
    /// `p(0.5)` ≤ true p50 × 1.03125 and `p(1.0)` is exact. Returns 0 on
    /// an empty histogram.
    pub fn p(&self, q: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let target = ((q * count as f64).ceil() as u64).clamp(1, count);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_high(i).min(self.max());
            }
        }
        self.max()
    }

    /// (p50, p99, p999, max) in one pass-per-quantile.
    pub fn percentiles(&self) -> (u64, u64, u64, u64) {
        (self.p(0.50), self.p(0.99), self.p(0.999), self.max())
    }

    /// Fold another histogram into this one (bucket-wise add). Lock-free;
    /// concurrent records on either side land in some valid interleaving.
    pub fn merge(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            let n = theirs.load(Ordering::Relaxed);
            if n > 0 {
                mine.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum.fetch_add(other.sum(), Ordering::Relaxed);
        self.max.fetch_max(other.max(), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn small_values_are_exact() {
        let h = Histogram::new();
        for v in 0..Histogram::LINEAR_MAX {
            h.record(v);
        }
        assert_eq!(h.count(), 32);
        assert_eq!(h.sum(), (0..32).sum::<u64>());
        assert_eq!(h.max(), 31);
        assert_eq!(h.p(1.0), 31);
        // With 32 records, the 16th smallest is value 15 — exact below
        // LINEAR_MAX.
        assert_eq!(h.p(0.5), 15);
    }

    #[test]
    fn bucket_round_trip_is_within_one_thirty_second() {
        let mut rng = Pcg64::new(7);
        for _ in 0..50_000 {
            // Exercise every magnitude: shift a random u64 by 0..=63.
            let v = rng.next_u64() >> rng.below(64);
            let high = Histogram::bucket_high(Histogram::bucket_of(v));
            assert!(high >= v, "v={v} high={high}");
            assert!(high - v <= v / 32, "v={v} high={high}");
        }
        // Extremes.
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(u64::MAX), NBUCKETS - 1);
        assert_eq!(Histogram::bucket_high(NBUCKETS - 1), u64::MAX);
    }

    #[test]
    fn merge_matches_recording_into_one() {
        let mut rng = Pcg64::new(9);
        let (a, b, whole) = (Histogram::new(), Histogram::new(), Histogram::new());
        for i in 0..10_000u64 {
            let v = rng.below(1 << 20);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            whole.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.sum(), whole.sum());
        assert_eq!(a.max(), whole.max());
        for q in [0.5, 0.9, 0.99, 0.999, 1.0] {
            assert_eq!(a.p(q), whole.p(q), "q={q}");
        }
    }

    #[test]
    fn concurrent_recording_is_exact_in_count_and_bounded_in_quantile() {
        // N threads × M records: the totals must be *exact* (no lost
        // updates) and the quantiles within the bucket error bound of a
        // single-threaded sorted reference over the same values.
        let h = Histogram::new();
        let threads = 8u64;
        let per = 20_000usize;
        std::thread::scope(|scope| {
            for t in 0..threads {
                let h = &h;
                scope.spawn(move || {
                    let mut rng = Pcg64::new(100 + t);
                    for _ in 0..per {
                        h.record(rng.below(1_000_000) + 1);
                    }
                });
            }
        });
        let mut reference: Vec<u64> = Vec::with_capacity(threads as usize * per);
        for t in 0..threads {
            let mut rng = Pcg64::new(100 + t);
            for _ in 0..per {
                reference.push(rng.below(1_000_000) + 1);
            }
        }
        reference.sort_unstable();
        assert_eq!(h.count(), reference.len() as u64);
        assert_eq!(h.sum(), reference.iter().sum::<u64>());
        assert_eq!(h.max(), *reference.last().unwrap());
        assert_eq!(h.p(1.0), *reference.last().unwrap());
        for q in [0.5, 0.99, 0.999] {
            let exact = reference[((q * (reference.len() - 1) as f64).round() as usize)
                .min(reference.len() - 1)] as f64;
            let got = h.p(q) as f64;
            // One-sided bucket quantization (+1/32) plus a whisker of
            // rank-definition slack.
            assert!(
                got >= exact * 0.999 && got <= exact * 1.04,
                "q={q}: got {got}, exact {exact}"
            );
        }
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = Histogram::new();
        assert_eq!(h.percentiles(), (0, 0, 0, 0));
        assert_eq!(h.count(), 0);
    }
}
