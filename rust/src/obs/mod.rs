//! Observability substrate: lock-free latency histograms, per-stage span
//! timing, and structured stats snapshots.
//!
//! Three pieces, threaded through every hot path of the crate:
//!
//! * [`Histogram`] — a fixed-bucket log-scale histogram (atomic u64
//!   buckets, ≤3.125% quantile error, mergeable) backing both the
//!   per-service request-latency record in
//!   [`crate::coordinator::Metrics`] and every stage timer here.
//! * [`Stage`] + [`Recorder`] — a near-zero-overhead scoped span
//!   recorder. The request pipeline (`queue-wait → model-resolve →
//!   encode → pack`), the index path (`probe → candidate-dedup →
//!   re-rank`) and the trainer (`cache-build → sweep → bin-solve`) each
//!   report wall time per stage into the process-global [`global`]
//!   recorder, alongside event [`Counter`]s (probe/candidate/re-rank
//!   totals, FFT plan-cache hits).
//! * [`StatsSnapshot`] — a plain struct rendering all of the above (plus
//!   service counters) as one JSON object; exposed as
//!   `ControlRequest::Stats` on the service, `--stats` / `--stats-every`
//!   on the CLI and `CBE_STATS=1` in the embedding_server example.
//!
//! # The gate
//!
//! Stage recording is controlled two ways:
//!
//! * **Runtime**: `CBE_OBS=0` (or `false` / `off`) in the environment
//!   disables recording at startup; [`set_enabled`] overrides either way
//!   at runtime (the obs bench flips it in-process to measure its own
//!   overhead). Default: enabled.
//! * **Compile time**: building with `--no-default-features` (dropping
//!   the `obs` cargo feature) makes [`enabled`] a constant `false`, so
//!   every span/counter site folds away.
//!
//! A disabled site costs one relaxed atomic load (plus one `Once` check);
//! the overhead contract — instrumentation ≤3% of encode+serve
//! throughput — is measured by `cargo bench coordinator_throughput`
//! (`BENCH_obs.json`) and enforceable with `CBE_BENCH_ENFORCE=1`.
//!
//! [`crate::coordinator::Metrics`] request/batch counters and the
//! end-to-end latency histogram are *not* gated: they are the service's
//! always-on operational record, and recording them is already lock-free
//! and allocation-free.

pub mod histogram;
pub mod snapshot;
pub mod span;

pub use histogram::Histogram;
pub use snapshot::{ProjectionInfo, StageStats, StatsSnapshot};
pub use span::{global, Counter, Recorder, Span, Stage};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Once;
use std::time::Duration;

static ENABLED: AtomicBool = AtomicBool::new(true);
static ENV_INIT: Once = Once::new();

/// Whether stage recording is on. Constant `false` without the `obs`
/// cargo feature; otherwise initialized once from `CBE_OBS` (`0` /
/// `false` / `off` disable) and overridable via [`set_enabled`].
#[inline]
pub fn enabled() -> bool {
    if cfg!(not(feature = "obs")) {
        return false;
    }
    ENV_INIT.call_once(|| {
        if let Ok(v) = std::env::var("CBE_OBS") {
            if matches!(v.as_str(), "0" | "false" | "off") {
                ENABLED.store(false, Ordering::Relaxed);
            }
        }
    });
    ENABLED.load(Ordering::Relaxed)
}

/// Flip the runtime gate (wins over `CBE_OBS`; no-op semantically when
/// the `obs` feature is compiled out). The obs bench uses this to compare
/// instrumented vs uninstrumented throughput in one process.
pub fn set_enabled(on: bool) {
    // Consume the env init so a later first call to `enabled()` cannot
    // override this explicit choice.
    ENV_INIT.call_once(|| {});
    ENABLED.store(on, Ordering::Relaxed);
}

/// Open a scoped span on the global recorder; `None` (and nothing else)
/// when recording is disabled.
#[inline]
pub fn span(stage: Stage) -> Option<Span<'static>> {
    if enabled() {
        Some(global().start(stage))
    } else {
        None
    }
}

/// Record an externally measured duration for `stage` on the global
/// recorder (no-op when disabled).
#[inline]
pub fn record(stage: Stage, dur: Duration) {
    if enabled() {
        global().record(stage, dur);
    }
}

/// [`record`], with the duration already in microseconds.
#[inline]
pub fn record_us(stage: Stage, us: u64) {
    if enabled() {
        global().record_us(stage, us);
    }
}

/// Bump a global event counter by `n` (no-op when disabled).
#[inline]
pub fn add(counter: Counter, n: u64) {
    if enabled() {
        global().add(counter, n);
    }
}
