//! Tiny property-testing harness (the offline vendor set has no `proptest`;
//! the python side uses hypothesis, this is the rust counterpart).
//!
//! Seeded, deterministic, with minimal shrinking (halving numeric inputs).
//! Usage (`no_run`: doctest binaries lack the xla rpath in this image):
//! ```no_run
//! use cbe::proptest_lite::{forall, Gen};
//! forall("sum is commutative", 100, |g| {
//!     let a = g.usize_in(0, 1000);
//!     let b = g.usize_in(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Pcg64;

/// A source of random test inputs for one property case.
pub struct Gen {
    rng: Pcg64,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        self.rng.normal_vec(n)
    }
    pub fn sign_vec(&mut self, n: usize) -> Vec<f32> {
        self.rng.sign_vec(n)
    }
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
    /// Power of two in [lo, hi].
    pub fn pow2_in(&mut self, lo: usize, hi: usize) -> usize {
        let lo_exp = lo.next_power_of_two().trailing_zeros();
        let hi_exp = hi.next_power_of_two().trailing_zeros();
        1usize << self.usize_in(lo_exp as usize, hi_exp as usize)
    }
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `cases` random cases of the property; panics (with the failing case
/// number and seed) on the first failure so `cargo test` reports it.
pub fn forall(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    let base_seed = 0xcbe0_0000u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut g = Gen {
            rng: Pcg64::new(seed),
            case,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} (seed {seed})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall("trivial", 50, |g| {
            let n = g.usize_in(1, 100);
            assert!(n >= 1 && n <= 100);
        });
    }

    #[test]
    #[should_panic]
    fn forall_reports_failure() {
        forall("always fails eventually", 50, |g| {
            let n = g.usize_in(0, 10);
            assert!(n < 10, "hit the boundary");
        });
    }

    #[test]
    fn pow2_in_range() {
        forall("pow2", 100, |g| {
            let p = g.pow2_in(4, 256);
            assert!(p.is_power_of_two());
            assert!(p >= 4 && p <= 256);
        });
    }
}
