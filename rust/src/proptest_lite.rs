//! Tiny property-testing harness (the offline vendor set has no `proptest`;
//! the python side uses hypothesis, this is the rust counterpart).
//!
//! Seeded, deterministic, with minimal shrinking (halving numeric inputs).
//! Usage (`no_run`: doctest binaries lack the xla rpath in this image):
//! ```no_run
//! use cbe::proptest_lite::{forall, Gen};
//! forall("sum is commutative", 100, |g| {
//!     let a = g.usize_in(0, 1000);
//!     let b = g.usize_in(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! # Replaying failures
//!
//! Every failure prints the failing case number and seed, plus a ready-made
//! `CBE_PROPTEST_SEED=<seed>` replay hint. Setting that variable makes every
//! [`forall`] in the process run **exactly one case** with that seed instead
//! of its full sweep — a failing property reproduces instantly, and
//! unrelated properties in the same test binary degrade to a harmless
//! single case (the variable is a debugging tool, not a CI mode).

use crate::util::rng::Pcg64;

/// A source of random test inputs for one property case.
pub struct Gen {
    rng: Pcg64,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }
    /// n uniform f64 draws in [lo, hi] — the raw-buffer generator the SIMD
    /// differential properties feed the FFT kernels with.
    pub fn f64_slice(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        self.rng.normal_vec(n)
    }
    pub fn sign_vec(&mut self, n: usize) -> Vec<f32> {
        self.rng.sign_vec(n)
    }
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
    /// Power of two in [lo, hi].
    pub fn pow2_in(&mut self, lo: usize, hi: usize) -> usize {
        let lo_exp = lo.next_power_of_two().trailing_zeros();
        let hi_exp = hi.next_power_of_two().trailing_zeros();
        1usize << self.usize_in(lo_exp as usize, hi_exp as usize)
    }
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `cases` random cases of the property; panics (with the failing case
/// number, seed, and a `CBE_PROPTEST_SEED` replay hint) on the first
/// failure so `cargo test` reports it. When `CBE_PROPTEST_SEED` is set,
/// runs exactly one case with that seed instead (see the module docs).
pub fn forall(name: &str, cases: usize, prop: impl FnMut(&mut Gen)) {
    let override_seed = resolve_seed(std::env::var("CBE_PROPTEST_SEED").ok().as_deref());
    forall_with_seed(name, cases, override_seed, prop);
}

/// Parse a `CBE_PROPTEST_SEED` value. `None`/unparsable → no override
/// (full sweep); unparsable additionally warns on stderr, since the
/// operator was clearly trying to replay something. (Pure, unit-tested.)
pub fn resolve_seed(v: Option<&str>) -> Option<u64> {
    let v = v?;
    match v.trim().parse::<u64>() {
        Ok(seed) => Some(seed),
        Err(_) => {
            eprintln!("cbe: CBE_PROPTEST_SEED='{v}' is not a u64; running the full sweep");
            None
        }
    }
}

/// [`forall`] with the seed override made explicit (the testable core:
/// no environment reads). `Some(seed)` runs a single case with exactly
/// that seed; `None` runs the deterministic `cases`-long sweep.
pub fn forall_with_seed(
    name: &str,
    cases: usize,
    override_seed: Option<u64>,
    mut prop: impl FnMut(&mut Gen),
) {
    let base_seed = 0xcbe0_0000u64;
    let plan: Vec<(usize, u64)> = match override_seed {
        Some(seed) => vec![(0, seed)],
        None => (0..cases)
            .map(|case| (case, base_seed.wrapping_add(case as u64)))
            .collect(),
    };
    for (case, seed) in plan {
        let mut g = Gen {
            rng: Pcg64::new(seed),
            case,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {case} (seed {seed}); \
                 replay with CBE_PROPTEST_SEED={seed}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall("trivial", 50, |g| {
            let n = g.usize_in(1, 100);
            assert!(n >= 1 && n <= 100);
        });
    }

    #[test]
    #[should_panic]
    fn forall_reports_failure() {
        forall("always fails eventually", 50, |g| {
            let n = g.usize_in(0, 10);
            assert!(n < 10, "hit the boundary");
        });
    }

    #[test]
    fn pow2_in_range() {
        forall("pow2", 100, |g| {
            let p = g.pow2_in(4, 256);
            assert!(p.is_power_of_two());
            assert!(p >= 4 && p <= 256);
        });
    }

    #[test]
    fn resolve_seed_parses_and_rejects() {
        assert_eq!(resolve_seed(None), None);
        assert_eq!(resolve_seed(Some("42")), Some(42));
        assert_eq!(resolve_seed(Some(" 42 ")), Some(42));
        assert_eq!(resolve_seed(Some("18446744073709551615")), Some(u64::MAX));
        // Unparsable values warn and fall back to the full sweep.
        assert_eq!(resolve_seed(Some("0xcbe")), None);
        assert_eq!(resolve_seed(Some("")), None);
        assert_eq!(resolve_seed(Some("-1")), None);
    }

    #[test]
    fn seed_override_replays_exact_seed() {
        // With an override the property runs exactly once, seeded with
        // exactly the requested value (same first draw as a raw Pcg64).
        let mut draws = Vec::new();
        forall_with_seed("replay", 50, Some(42), |g| {
            assert_eq!(g.case, 0);
            draws.push(g.rng().next_u64());
        });
        assert_eq!(draws, vec![Pcg64::new(42).next_u64()]);
    }

    #[test]
    fn f64_slice_len_and_bounds() {
        forall("f64_slice", 50, |g| {
            let n = g.usize_in(0, 64);
            let v = g.f64_slice(n, -3.0, 5.0);
            assert_eq!(v.len(), n);
            // Closed-interval bounds (hi is reachable when next_f64
            // returns a value rounding the product up to hi - lo).
            assert!(v.iter().all(|x| (-3.0..=5.0).contains(x)));
        });
    }
}
