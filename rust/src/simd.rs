//! Runtime gate for the SIMD kernel layer.
//!
//! The AVX2 kernels under [`crate::fft`] and [`crate::bits`] are selected
//! per call through one predicate, [`active`], which ANDs four layers
//! (the gating matrix — see ARCHITECTURE.md §SIMD kernels):
//!
//! 1. **`simd` cargo feature** — compiled in by default; building with
//!    `--no-default-features` removes every kernel and turns [`active`]
//!    into a constant `false`, so dispatch sites fold to the scalar path.
//! 2. **target architecture** — the kernels are `x86_64` only; other
//!    targets compile the scalar paths and nothing else.
//! 3. **CPU detection** — `is_x86_feature_detected!("avx2")`, probed once
//!    per process and cached. No AVX2, no dispatch: the binary runs
//!    everywhere the scalar code runs.
//! 4. **runtime switch** — `CBE_SIMD=0` (or `false`/`off`) in the
//!    environment, or [`set_enabled`] in-process (the bench A/B arms and
//!    the differential test suite flip it), mirrors the `obs` gating
//!    pattern: [`set_enabled`] wins over the environment once called.
//!
//! The exactness contract the gate guards is two-tier and test-enforced
//! (`rust/tests/simd_kernels.rs`): integer popcount paths are bit-exact
//! vs scalar by construction; the FFT-side kernels are written to perform
//! the *identical* IEEE-754 operations in the same order as the scalar
//! loops (two complex lanes per `__m256d`, no FMA contraction), so they
//! are bit-exact too, and the packed sign bits of an encode are
//! code-identical whichever side of the gate runs.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Once;

/// Runtime toggle (defaults to on; the env layer may lower it once).
static ENABLED: AtomicBool = AtomicBool::new(true);
/// One-shot `CBE_SIMD` read. [`set_enabled`] consumes it first so an
/// explicit in-process choice is never overridden by a late env read.
static ENV_INIT: Once = Once::new();

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn detect() -> bool {
    static AVX2: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVX2.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
}

#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
fn detect() -> bool {
    false
}

/// Could the SIMD kernels run here at all? True iff the `simd` feature is
/// compiled in, the target is x86-64 and the CPU reports AVX2. Detection
/// is probed once and cached; this never changes within a process.
#[inline]
pub fn available() -> bool {
    detect()
}

/// Does `CBE_SIMD=<v>` disable the kernels? (Pure, for unit tests.)
fn env_disables(v: Option<&str>) -> bool {
    matches!(v, Some("0") | Some("false") | Some("off"))
}

/// Should a dispatch site take the SIMD kernel *now*? [`available`] AND
/// the runtime switch (env-initialized, [`set_enabled`]-overridable).
/// One relaxed atomic load on the hot path.
#[inline]
pub fn active() -> bool {
    if !available() {
        return false;
    }
    ENV_INIT.call_once(|| {
        if env_disables(std::env::var("CBE_SIMD").ok().as_deref()) {
            ENABLED.store(false, Ordering::Relaxed);
        }
    });
    ENABLED.load(Ordering::Relaxed)
}

/// Flip the runtime switch in-process (bench A/B arms, differential
/// tests). Takes precedence over `CBE_SIMD` from this point on. A no-op
/// in effect when [`available`] is false — [`active`] stays false.
pub fn set_enabled(on: bool) {
    // Claim the env read so a later `active()` can't override this call.
    ENV_INIT.call_once(|| {});
    ENABLED.store(on, Ordering::Relaxed);
}

/// Name of the kernel set a dispatch site would pick right now — for
/// bench JSON and logs.
pub fn kernel_name() -> &'static str {
    if active() {
        "avx2"
    } else {
        "scalar"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_values_that_disable() {
        assert!(env_disables(Some("0")));
        assert!(env_disables(Some("false")));
        assert!(env_disables(Some("off")));
        assert!(!env_disables(Some("1")));
        assert!(!env_disables(Some("")));
        assert!(!env_disables(None));
    }

    #[test]
    fn availability_is_stable_and_bounds_active() {
        // Detection is one-shot: two reads agree, and `active` can never
        // exceed `available`. (No `set_enabled` here — unit tests share
        // this process with every other lib test.)
        assert_eq!(available(), available());
        if !available() {
            assert!(!active());
            assert_eq!(kernel_name(), "scalar");
        }
    }

    #[cfg(not(feature = "simd"))]
    #[test]
    fn scalar_build_is_constant_false() {
        assert!(!available());
        assert!(!active());
    }
}
