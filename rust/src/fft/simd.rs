//! AVX2 kernels for the complex/f64 hot loops of the FFT substrate.
//!
//! Lane layout: every `__m256d` holds **two interleaved complex numbers**
//! `[re0, im0, re1, im1]` — [`C64`] is `repr(C)`, so a `&[C64]` slice
//! reinterprets directly as the flat f64 buffer these kernels load from.
//!
//! # Exactness contract (the strict tier)
//!
//! Each kernel performs the *identical* IEEE-754 operations, in the same
//! order, as the scalar loop it replaces: multiplies and adds are
//! element-wise `_mm256_{mul,add,sub,addsub}_pd` (never FMA — a fused
//! multiply-add rounds once where the scalar code rounds twice), complex
//! multiplication reproduces [`C64`]'s `Mul` term order up to the
//! commutativity of IEEE `*`/`+` (which is exact), and sign flips
//! (conjugation, ±i rotation) are sign-bit XORs — exact negation, just
//! like scalar `-x`. The differential suite (`rust/tests/simd_kernels.rs`)
//! asserts **bit equality** against the scalar paths, not a tolerance.
//!
//! Tails: vector bodies step two complexes (or four f64 bins) at a time;
//! every kernel finishes ragged remainders with the scalar statements
//! inline, so any length is accepted and the tail is bit-exact trivially.
//!
//! # Safety
//!
//! Every function is `#[target_feature(enable = "avx2")]` and must only
//! be called when [`crate::simd::active`] returned true (which implies
//! runtime AVX2 detection succeeded). All pointer arithmetic stays inside
//! the passed slices; unaligned loads/stores are used throughout.

use super::C64;
use std::arch::x86_64::*;

/// Complex multiply of two lanes: per complex, `a·w` with [`C64`]'s exact
/// term order — `re = ar·wr − ai·wi`, `im = ai·wr + ar·wi` (the scalar
/// `ar·wi + ai·wr` commuted, which IEEE addition makes bit-identical).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn cmul(a: __m256d, w: __m256d) -> __m256d {
    let wr = _mm256_unpacklo_pd(w, w); // [wr0, wr0, wr1, wr1]
    let wi = _mm256_unpackhi_pd(w, w); // [wi0, wi0, wi1, wi1]
    let t1 = _mm256_mul_pd(a, wr); // [ar·wr, ai·wr, …]
    let swapped = _mm256_permute_pd::<0b0101>(a); // [ai, ar, …]
    let t2 = _mm256_mul_pd(swapped, wi); // [ai·wi, ar·wi, …]
    _mm256_addsub_pd(t1, t2) // [ar·wr − ai·wi, ai·wr + ar·wi, …]
}

/// Sign mask flipping each lane's imaginary part (conjugation / the
/// second half of a ±i rotation): XOR with −0.0 is exact negation.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn conj_mask() -> __m256d {
    _mm256_setr_pd(0.0, -0.0, 0.0, -0.0)
}

/// All butterfly stages of the radix-2 FFT (after bit-reversal), n ≥ 4.
/// The first stage (`len == 2`, twiddle `W⁰ = 1`) runs the scalar
/// butterfly statements; every later stage has an even `half ≥ 2` and
/// processes two butterflies per vector — no intra-stage tail exists.
/// Twiddles are gathered as two 128-bit loads at the strided indices, so
/// arbitrary stage strides reuse the one top-level table exactly like
/// the scalar loop.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn fft_stages(buf: &mut [C64], twiddles: &[C64]) {
    let n = buf.len();
    debug_assert!(n >= 4 && n.is_power_of_two());
    debug_assert_eq!(twiddles.len(), n / 2);
    let w0 = twiddles[0];
    let mut i = 0usize;
    while i < n {
        let a = buf[i];
        let b = buf[i + 1] * w0;
        buf[i] = a + b;
        buf[i + 1] = a - b;
        i += 2;
    }
    let base = buf.as_mut_ptr() as *mut f64;
    let tw = twiddles.as_ptr() as *const f64;
    let mut len = 4usize;
    while len <= n {
        let half = len / 2; // power of two ≥ 2: the k-loop never tails
        let stride = n / len;
        let mut start = 0usize;
        while start < n {
            let lo = base.add(2 * start);
            let hi = base.add(2 * (start + half));
            let mut k = 0usize;
            while k < half {
                let w_lo = _mm_loadu_pd(tw.add(2 * (k * stride)));
                let w_hi = _mm_loadu_pd(tw.add(2 * ((k + 1) * stride)));
                let w = _mm256_set_m128d(w_hi, w_lo);
                let a = _mm256_loadu_pd(lo.add(2 * k));
                let b = cmul(_mm256_loadu_pd(hi.add(2 * k)), w);
                _mm256_storeu_pd(lo.add(2 * k), _mm256_add_pd(a, b));
                _mm256_storeu_pd(hi.add(2 * k), _mm256_sub_pd(a, b));
                k += 2;
            }
            start += len;
        }
        len <<= 1;
    }
}

/// Pointwise in-place complex product `a[i] ← a[i]·b[i]` (the Bluestein
/// convolution and circulant spectral-multiply step).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn cmul_in_place(a: &mut [C64], b: &[C64]) {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let ap = a.as_mut_ptr() as *mut f64;
    let bp = b.as_ptr() as *const f64;
    let mut k = 0usize;
    while k + 2 <= n {
        let va = _mm256_loadu_pd(ap.add(2 * k));
        let vb = _mm256_loadu_pd(bp.add(2 * k));
        _mm256_storeu_pd(ap.add(2 * k), cmul(va, vb));
        k += 2;
    }
    while k < n {
        a[k] = a[k] * b[k];
        k += 1;
    }
}

/// Pointwise out-of-place complex product `out[i] = a[i]·b[i]`
/// ([`super::realpack::spectral_mul`]'s vector body).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn cmul_into(a: &[C64], b: &[C64], out: &mut [C64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    let n = a.len();
    let ap = a.as_ptr() as *const f64;
    let bp = b.as_ptr() as *const f64;
    let op = out.as_mut_ptr() as *mut f64;
    let mut k = 0usize;
    while k + 2 <= n {
        let va = _mm256_loadu_pd(ap.add(2 * k));
        let vb = _mm256_loadu_pd(bp.add(2 * k));
        _mm256_storeu_pd(op.add(2 * k), cmul(va, vb));
        k += 2;
    }
    while k < n {
        out[k] = a[k] * b[k];
        k += 1;
    }
}

/// The k ∈ [1, h) untangle loop of the packed real FFT: reads the
/// half-size spectrum `z` (len h) and the forward twiddles `w_fwd`
/// (len h+1), writes `out[1..h]`. The self-conjugate bins `out[0]` /
/// `out[h]` stay with the caller. Mirrored bins are fetched with one
/// 256-bit load at `h−k−1` and a 128-bit-half swap, so the vector body
/// touches the same elements as two scalar iterations.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn untangle(z: &[C64], w_fwd: &[C64], out: &mut [C64]) {
    let h = z.len();
    debug_assert_eq!(out.len(), h + 1);
    debug_assert_eq!(w_fwd.len(), h + 1);
    let zp = z.as_ptr() as *const f64;
    let wp = w_fwd.as_ptr() as *const f64;
    let op = out.as_mut_ptr() as *mut f64;
    let half = _mm256_set1_pd(0.5);
    let cm = conj_mask();
    let mut k = 1usize;
    while k + 2 <= h {
        let a = _mm256_loadu_pd(zp.add(2 * k)); // z[k], z[k+1]
        let brev = _mm256_loadu_pd(zp.add(2 * (h - k - 1))); // z[h−k−1], z[h−k]
        let b = _mm256_xor_pd(_mm256_permute2f128_pd::<0x01>(brev, brev), cm);
        let fe = _mm256_mul_pd(_mm256_add_pd(a, b), half);
        let fo = _mm256_mul_pd(_mm256_sub_pd(a, b), half);
        // ×(−i): (re, im) → (im, −re) = pair swap + imag sign flip.
        let fo = _mm256_xor_pd(_mm256_permute_pd::<0b0101>(fo), cm);
        let wfo = cmul(fo, _mm256_loadu_pd(wp.add(2 * k)));
        _mm256_storeu_pd(op.add(2 * k), _mm256_add_pd(fe, wfo));
        k += 2;
    }
    while k < h {
        let a = z[k];
        let b = z[h - k].conj();
        let fe = (a + b).scale(0.5);
        let fo = (a - b).scale(0.5);
        let fo = C64::new(fo.im, -fo.re);
        out[k] = fe + w_fwd[k] * fo;
        k += 1;
    }
}

/// The k ∈ [0, h) retangle loop of the packed real inverse FFT: reads
/// the half spectrum `spec` (len h+1) and the inverse twiddles `w_inv`,
/// writes the packed buffer `z` (len h).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn retangle(spec: &[C64], w_inv: &[C64], z: &mut [C64]) {
    let h = z.len();
    debug_assert_eq!(spec.len(), h + 1);
    debug_assert_eq!(w_inv.len(), h + 1);
    let sp = spec.as_ptr() as *const f64;
    let wp = w_inv.as_ptr() as *const f64;
    let zp = z.as_mut_ptr() as *mut f64;
    let half = _mm256_set1_pd(0.5);
    let cm = conj_mask();
    // Mask negating each lane's *real* part: the ×i rotation.
    let im = _mm256_setr_pd(-0.0, 0.0, -0.0, 0.0);
    let mut k = 0usize;
    while k + 2 <= h {
        let a = _mm256_loadu_pd(sp.add(2 * k)); // spec[k], spec[k+1]
        let brev = _mm256_loadu_pd(sp.add(2 * (h - k - 1))); // spec[h−k−1], spec[h−k]
        let b = _mm256_xor_pd(_mm256_permute2f128_pd::<0x01>(brev, brev), cm);
        let fe = _mm256_mul_pd(_mm256_add_pd(a, b), half);
        let w = _mm256_loadu_pd(wp.add(2 * k));
        let fo = _mm256_mul_pd(cmul(_mm256_sub_pd(a, b), w), half);
        // ×i: (re, im) → (−im, re) = pair swap + real sign flip.
        let ifo = _mm256_xor_pd(_mm256_permute_pd::<0b0101>(fo), im);
        _mm256_storeu_pd(zp.add(2 * k), _mm256_add_pd(fe, ifo));
        k += 2;
    }
    while k < h {
        let a = spec[k];
        let b = spec[h - k].conj();
        let fe = (a + b).scale(0.5);
        let fo = (w_inv[k] * (a - b)).scale(0.5);
        let ifo = C64::new(-fo.im, fo.re);
        z[k] = fe + ifo;
        k += 1;
    }
}

/// The rfft input pack: `z[k] = (x[2k]·s[2k], x[2k+1]·s[2k+1])` widened
/// to f64 (four f32 loads + one `cvtps_pd` per vector step; the f32
/// multiply and the widening are both exact-match with the scalar cast).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn pack_real(x: &[f32], pre_scale: Option<&[f32]>, z: &mut [C64]) {
    let h = z.len();
    debug_assert_eq!(x.len(), 2 * h);
    let xp = x.as_ptr();
    let zp = z.as_mut_ptr() as *mut f64;
    match pre_scale {
        Some(s) => {
            debug_assert_eq!(s.len(), 2 * h);
            let sp = s.as_ptr();
            let mut k = 0usize;
            while k + 2 <= h {
                let v = _mm_mul_ps(_mm_loadu_ps(xp.add(2 * k)), _mm_loadu_ps(sp.add(2 * k)));
                _mm256_storeu_pd(zp.add(2 * k), _mm256_cvtps_pd(v));
                k += 2;
            }
            while k < h {
                z[k] = C64::new(
                    (x[2 * k] * s[2 * k]) as f64,
                    (x[2 * k + 1] * s[2 * k + 1]) as f64,
                );
                k += 1;
            }
        }
        None => {
            let mut k = 0usize;
            while k + 2 <= h {
                _mm256_storeu_pd(zp.add(2 * k), _mm256_cvtps_pd(_mm_loadu_ps(xp.add(2 * k))));
                k += 2;
            }
            while k < h {
                z[k] = C64::new(x[2 * k] as f64, x[2 * k + 1] as f64);
                k += 1;
            }
        }
    }
}

/// The irfft output unpack: `out[2k], out[2k+1] = z[k].re, z[k].im` as
/// f32 (`cvtpd_ps` rounds to nearest-even — the same rounding `as f32`
/// performs).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn unpack_f32(z: &[C64], out: &mut [f32]) {
    let h = z.len();
    debug_assert_eq!(out.len(), 2 * h);
    let zp = z.as_ptr() as *const f64;
    let op = out.as_mut_ptr();
    let mut k = 0usize;
    while k + 2 <= h {
        _mm_storeu_ps(op.add(2 * k), _mm256_cvtpd_ps(_mm256_loadu_pd(zp.add(2 * k))));
        k += 2;
    }
    while k < h {
        out[2 * k] = z[k].re as f32;
        out[2 * k + 1] = z[k].im as f32;
        k += 1;
    }
}

/// `acc[l] += |s[l]|²`, four bins per step: square both spectrum lanes,
/// horizontal-add pairs, restore bin order with one 4×64 permute.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn energy_accum(s: &[C64], acc: &mut [f64]) {
    let n = s.len();
    debug_assert_eq!(acc.len(), n);
    let sp = s.as_ptr() as *const f64;
    let ap = acc.as_mut_ptr();
    let mut l = 0usize;
    while l + 4 <= n {
        let v0 = _mm256_loadu_pd(sp.add(2 * l)); // bins l, l+1
        let v1 = _mm256_loadu_pd(sp.add(2 * l + 4)); // bins l+2, l+3
        // hadd → [n_l, n_{l+2}, n_{l+1}, n_{l+3}]; 0xD8 restores order.
        let t = _mm256_hadd_pd(_mm256_mul_pd(v0, v0), _mm256_mul_pd(v1, v1));
        let norms = _mm256_permute4x64_pd::<0b1101_1000>(t);
        let a = _mm256_loadu_pd(ap.add(l));
        _mm256_storeu_pd(ap.add(l), _mm256_add_pd(a, norms));
        l += 4;
    }
    while l < n {
        acc[l] += s[l].norm_sqr();
        l += 1;
    }
}

/// The eq. 17 correlation accumulators, four bins per step:
/// `h[l] −= 2·Re(x·conj(b))`, `g[l] += 2·Im(x·conj(b))`. The complex
/// products land interleaved `[p, q, …]`; unpack + permute deinterleaves
/// them into bin-ordered `p` and `q` vectors.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn corr_accum(x: &[C64], b: &[C64], hacc: &mut [f64], gacc: &mut [f64]) {
    let n = x.len();
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(hacc.len(), n);
    debug_assert_eq!(gacc.len(), n);
    let xp = x.as_ptr() as *const f64;
    let bp = b.as_ptr() as *const f64;
    let hp = hacc.as_mut_ptr();
    let gp = gacc.as_mut_ptr();
    let cm = conj_mask();
    let two = _mm256_set1_pd(2.0);
    let mut l = 0usize;
    while l + 4 <= n {
        let x0 = _mm256_loadu_pd(xp.add(2 * l));
        let x1 = _mm256_loadu_pd(xp.add(2 * l + 4));
        let b0 = _mm256_xor_pd(_mm256_loadu_pd(bp.add(2 * l)), cm);
        let b1 = _mm256_xor_pd(_mm256_loadu_pd(bp.add(2 * l + 4)), cm);
        let c0 = cmul(x0, b0); // [p_l, q_l, p_{l+1}, q_{l+1}]
        let c1 = cmul(x1, b1); // [p_{l+2}, q_{l+2}, p_{l+3}, q_{l+3}]
        let p = _mm256_permute4x64_pd::<0b1101_1000>(_mm256_unpacklo_pd(c0, c1));
        let q = _mm256_permute4x64_pd::<0b1101_1000>(_mm256_unpackhi_pd(c0, c1));
        let hv = _mm256_loadu_pd(hp.add(l));
        _mm256_storeu_pd(hp.add(l), _mm256_sub_pd(hv, _mm256_mul_pd(two, p)));
        let gv = _mm256_loadu_pd(gp.add(l));
        _mm256_storeu_pd(gp.add(l), _mm256_add_pd(gv, _mm256_mul_pd(two, q)));
        l += 4;
    }
    while l < n {
        hacc[l] -= 2.0 * (x[l].re * b[l].re + x[l].im * b[l].im);
        gacc[l] += 2.0 * (x[l].im * b[l].re - x[l].re * b[l].im);
        l += 1;
    }
}
