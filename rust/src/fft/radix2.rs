//! Iterative in-place radix-2 Cooley–Tukey FFT (decimation in time).
//!
//! Twiddles for the largest stage are precomputed once per plan (separate
//! forward and inverse tables — perf pass: the per-butterfly `conj` branch
//! cost ~15% at d = 2^16); smaller stages stride through the same table,
//! so the hot loop does no trig and no branching.

use super::{C64, Dir};

/// Precompute e^{-2πik/n} for k in [0, n/2).
pub fn make_twiddles(n: usize) -> Vec<C64> {
    assert!(n.is_power_of_two());
    let half = (n / 2).max(1);
    (0..half)
        .map(|k| C64::cis(-2.0 * std::f64::consts::PI * k as f64 / n as f64))
        .collect()
}

/// Conjugated (inverse-direction) twiddle table.
pub fn make_twiddles_inv(n: usize) -> Vec<C64> {
    make_twiddles(n).into_iter().map(|c| c.conj()).collect()
}

#[inline]
fn bit_reverse_permute(buf: &mut [C64]) {
    let n = buf.len();
    let shift = (usize::BITS - n.trailing_zeros()) % usize::BITS;
    for i in 0..n {
        let j = i.reverse_bits() >> shift;
        if j > i {
            buf.swap(i, j);
        }
    }
}

/// In-place FFT of a power-of-two buffer using a prebuilt twiddle table
/// (forward table → forward DFT, conjugated table → unnormalized inverse).
///
/// Dispatch point of the SIMD layer: when [`crate::simd::active`] the
/// butterfly stages run as AVX2 two-complex lanes
/// ([`super::simd::fft_stages`]), which perform the identical IEEE-754
/// operations in the same order as [`fft_inplace_tw_scalar`] — the two
/// paths are bit-exact, not merely close (enforced by
/// `rust/tests/simd_kernels.rs`).
pub fn fft_inplace_tw(buf: &mut [C64], twiddles: &[C64]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if buf.len() >= 4 && crate::simd::active() {
        bit_reverse_permute(buf);
        // SAFETY: `active()` implies runtime AVX2 detection succeeded.
        unsafe { super::simd::fft_stages(buf, twiddles) };
        return;
    }
    fft_inplace_tw_scalar(buf, twiddles);
}

/// The scalar butterfly loop — the oracle the SIMD path is compared
/// against, and the only path on non-AVX2 hosts / scalar builds.
pub fn fft_inplace_tw_scalar(buf: &mut [C64], twiddles: &[C64]) {
    let n = buf.len();
    if n <= 1 {
        return;
    }
    debug_assert_eq!(twiddles.len(), n / 2);
    bit_reverse_permute(buf);
    let mut len = 2usize;
    while len <= n {
        let half = len / 2;
        let stride = n / len; // index stride into the top-level twiddle table
        for start in (0..n).step_by(len) {
            let (lo, hi) = buf[start..start + len].split_at_mut(half);
            let mut tw_idx = 0usize;
            for k in 0..half {
                let w = twiddles[tw_idx];
                let a = lo[k];
                let b = hi[k] * w;
                lo[k] = a + b;
                hi[k] = a - b;
                tw_idx += stride;
            }
        }
        len <<= 1;
    }
}

/// Direction-explicit wrapper kept for tests/callers that own no tables.
/// No normalization is applied here.
pub fn fft_inplace(buf: &mut [C64], twiddles: &[C64], dir: Dir) {
    match dir {
        Dir::Forward => fft_inplace_tw(buf, twiddles),
        Dir::Inverse => {
            let inv: Vec<C64> = twiddles.iter().map(|c| c.conj()).collect();
            fft_inplace_tw(buf, &inv);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn impulse_is_flat() {
        let n = 16;
        let tw = make_twiddles(n);
        let mut buf = vec![C64::ZERO; n];
        buf[0] = C64::ONE;
        fft_inplace(&mut buf, &tw, Dir::Forward);
        for v in &buf {
            assert!((v.re - 1.0).abs() < 1e-12 && v.im.abs() < 1e-12);
        }
    }

    #[test]
    fn constant_is_impulse() {
        let n = 8;
        let tw = make_twiddles(n);
        let mut buf = vec![C64::ONE; n];
        fft_inplace(&mut buf, &tw, Dir::Forward);
        assert!((buf[0].re - n as f64).abs() < 1e-12);
        for v in &buf[1..] {
            assert!(v.abs() < 1e-12);
        }
    }

    #[test]
    fn forward_then_conj_inverse_identity() {
        let n = 32;
        let tw = make_twiddles(n);
        let orig: Vec<C64> = (0..n)
            .map(|i| C64::new((i as f64).sin(), (i as f64 * 0.7).cos()))
            .collect();
        let mut buf = orig.clone();
        fft_inplace(&mut buf, &tw, Dir::Forward);
        fft_inplace(&mut buf, &tw, Dir::Inverse);
        for (a, b) in buf.iter().zip(&orig) {
            assert!((*a - b.scale(n as f64)).abs() < 1e-10);
        }
    }

    #[test]
    fn inv_table_equals_dir_inverse() {
        let n = 64;
        let tw = make_twiddles(n);
        let tw_inv = make_twiddles_inv(n);
        let orig: Vec<C64> = (0..n)
            .map(|i| C64::new((i as f64 * 0.3).cos(), (i as f64 * 0.9).sin()))
            .collect();
        let mut a = orig.clone();
        let mut b = orig;
        fft_inplace(&mut a, &tw, Dir::Inverse);
        fft_inplace_tw(&mut b, &tw_inv);
        for (x, y) in a.iter().zip(&b) {
            assert!((*x - *y).abs() < 1e-12);
        }
    }
}
