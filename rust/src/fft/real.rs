//! Real-input FFT wrappers.
//!
//! CBE's signals (data vectors and the circulant parameter r) are real, so
//! their spectra are conjugate-symmetric: F(t)_{d-i} = conj(F(t)_i). The
//! learning step of §4 works directly on the half-spectrum; these helpers
//! convert between real time-domain slices and full complex spectra.
//!
//! These wrappers hold no loops worth vectorizing themselves, but the
//! planner FFTs they call dispatch through the SIMD layer
//! ([`crate::simd`]) like every other transform — the full-spectrum path
//! and the packed path stay bit-identical per kernel choice.

use super::{C64, Planner};

/// Forward FFT of a real signal → full complex spectrum (len n).
pub fn rfft_full(planner: &Planner, x: &[f32]) -> Vec<C64> {
    let mut buf: Vec<C64> = x.iter().map(|v| C64::new(*v as f64, 0.0)).collect();
    planner.fft(&mut buf);
    buf
}

/// Inverse FFT of a conjugate-symmetric spectrum → real signal (len n).
/// The imaginary residue (numerical noise) is dropped.
pub fn irfft_full(planner: &Planner, spec: &[C64]) -> Vec<f32> {
    let mut buf = spec.to_vec();
    planner.ifft(&mut buf);
    buf.iter().map(|c| c.re as f32).collect()
}

/// Enforce exact conjugate symmetry on a spectrum in place (projects onto
/// the set of spectra of real signals): F_0 real, F_{n-i} = conj(F_i).
pub fn symmetrize(spec: &mut [C64]) {
    let n = spec.len();
    if n == 0 {
        return;
    }
    spec[0] = C64::new(spec[0].re, 0.0);
    if n % 2 == 0 {
        spec[n / 2] = C64::new(spec[n / 2].re, 0.0);
    }
    for i in 1..=(n - 1) / 2 {
        let avg = (spec[i] + spec[n - i].conj()).scale(0.5);
        spec[i] = avg;
        spec[n - i] = avg.conj();
    }
}

/// Max deviation from conjugate symmetry (diagnostic / tests).
pub fn symmetry_error(spec: &[C64]) -> f64 {
    let n = spec.len();
    let mut err = spec[0].im.abs();
    if n % 2 == 0 {
        err = err.max(spec[n / 2].im.abs());
    }
    for i in 1..=(n.saturating_sub(1)) / 2 {
        err = err.max((spec[i] - spec[n - i].conj()).abs());
    }
    err
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn real_roundtrip() {
        let planner = Planner::new();
        let mut r = Pcg64::new(21);
        for n in [8usize, 15, 64, 100] {
            let x: Vec<f32> = (0..n).map(|_| r.normal() as f32).collect();
            let spec = rfft_full(&planner, &x);
            assert!(symmetry_error(&spec) < 1e-9, "n={n}");
            let back = irfft_full(&planner, &spec);
            for (a, b) in back.iter().zip(&x) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn symmetrize_idempotent_and_projects() {
        let mut r = Pcg64::new(23);
        for n in [6usize, 7, 16] {
            let mut spec: Vec<C64> = (0..n).map(|_| C64::new(r.normal(), r.normal())).collect();
            symmetrize(&mut spec);
            assert!(symmetry_error(&spec) < 1e-12);
            let snap = spec.clone();
            symmetrize(&mut spec);
            for (a, b) in spec.iter().zip(&snap) {
                assert!((*a - *b).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn symmetric_spectrum_gives_real_signal() {
        let planner = Planner::new();
        let mut r = Pcg64::new(29);
        let n = 32;
        let mut spec: Vec<C64> = (0..n).map(|_| C64::new(r.normal(), r.normal())).collect();
        symmetrize(&mut spec);
        let mut buf = spec.clone();
        planner.ifft(&mut buf);
        for c in &buf {
            assert!(c.im.abs() < 1e-10);
        }
    }
}
