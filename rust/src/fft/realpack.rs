//! Real-input FFT via half-size complex FFT (the "pack two reals into one
//! complex" trick, Numerical Recipes `realft` lineage) — and the
//! half-spectrum substrate the CBE trainer runs on.
//!
//! CBE's signals — data vectors, the circulant parameter r, and the
//! projections — are all real, so their spectra are **conjugate
//! symmetric**: only the ⌊d/2⌋+1 bins `X[0..=d/2]` are independent, the
//! rest mirror as `X[d−l] = conj(X[l])`. Two consequences this module
//! exploits:
//!
//! * every transform in the encode hot path can run at *half size*: a
//!   d-point real FFT costs one (d/2)-point complex FFT plus O(d)
//!   untangling ([`RealPackPlan`]; perf pass iteration 3,
//!   EXPERIMENTS.md §Perf — ~1.8× on the dominant cost), and
//! * every spectrum the training engine stores or sweeps only needs the
//!   independent half — half the bytes, half the bandwidth. [`RealFft`]
//!   is the any-length entry point the trainer builds on, and the
//!   [`spectral_mul`] / [`spectral_energy_accum`] / [`spectral_corr_accum`]
//!   kernels are the per-bin accumulations of §4 phrased on half-spectra.
//!
//! # Conventions and the DC/Nyquist realness contract
//!
//! `rfft` returns the half-spectrum X[0..=h] (h = ⌊d/2⌋, inclusive of the
//! Nyquist bin when d is even). For a **real** signal, X[0] (DC) and —
//! for even d — X[h] (Nyquist) are purely real; `rfft` produces them with
//! exactly zero imaginary part. `irfft` inverts the half-spectrum back to
//! a real signal, including the 1/d scale, and **requires** those bins to
//! be (numerically) real on input: an imaginary part there has no
//! real-signal representation and would be silently corrupted, so debug
//! builds reject it (`debug_assert!`) instead of discarding it. Callers
//! that synthesize spectra (rather than round-tripping `rfft` output)
//! must zero those imaginary parts themselves — the trainer's per-bin
//! solver constructs them real by design.
//!
//! [`RealPackPlan`] and [`RealFft`] are immutable (`Send + Sync`, cheap to
//! clone — plans are `Arc`-shared); all per-transform state lives in the
//! caller-owned [`RealPackScratch`], one per thread.

use super::{C64, Dir, FftScratch, Plan, Planner};
use std::sync::Arc;

/// Bins in the conjugate-symmetric half-spectrum of a d-point real
/// signal: ⌊d/2⌋ + 1.
#[inline]
pub const fn half_len(d: usize) -> usize {
    d / 2 + 1
}

/// The realness contract on the DC / Nyquist bins (see module docs):
/// debug builds reject spectra whose self-conjugate bins carry an
/// imaginary part that `irfft` would otherwise silently corrupt.
#[inline]
fn debug_assert_real_bin(c: C64, what: &str) {
    debug_assert!(
        c.im.abs() <= 1e-6 * (1.0 + c.re.abs()),
        "{what} must be real for a real signal (got {} + {}i)",
        c.re,
        c.im
    );
}

/// Precomputed tables for one even length d. Immutable and shareable
/// across threads; clones share the underlying half-size [`Plan`].
#[derive(Clone)]
pub struct RealPackPlan {
    pub d: usize,
    h: usize,
    /// W_d^k = e^{-2πik/d}, k = 0..h.
    w_fwd: Vec<C64>,
    /// W_d^{-k}, k = 0..h.
    w_inv: Vec<C64>,
    /// Shared half-size complex plan (resolved once, no planner lock on
    /// the hot path).
    half_plan: Arc<Plan>,
}

/// Caller-owned work space for [`RealPackPlan`] / [`RealFft`]: the packed
/// half-size (or, on the odd-length fallback, full-size) complex buffer
/// plus the nested FFT scratch (h itself may be a Bluestein size, e.g.
/// d = 100 → h = 50).
#[derive(Default)]
pub struct RealPackScratch {
    z: Vec<C64>,
    fft: FftScratch,
}

impl RealPackScratch {
    pub fn new() -> RealPackScratch {
        RealPackScratch::default()
    }
}

impl RealPackPlan {
    /// d must be even (callers fall back to [`RealFft::Full`] — or the
    /// full-complex path — if not).
    pub fn new(d: usize, planner: &Planner) -> RealPackPlan {
        assert!(d >= 2 && d % 2 == 0, "RealPackPlan requires even d");
        let h = d / 2;
        let w_fwd: Vec<C64> = (0..=h)
            .map(|k| C64::cis(-2.0 * std::f64::consts::PI * k as f64 / d as f64))
            .collect();
        let w_inv: Vec<C64> = w_fwd.iter().map(|c| c.conj()).collect();
        RealPackPlan {
            d,
            h,
            w_fwd,
            w_inv,
            // Resolve the half-size plan now (not on the first hot call).
            half_plan: planner.plan(h),
        }
    }

    /// Forward real FFT: x (len d, real) → half spectrum (len h+1).
    /// `pre_scale` multiplies inputs on the fly (used for the D sign
    /// flips). The DC and Nyquist outputs are produced with exactly zero
    /// imaginary part (they are self-conjugate bins of a real signal).
    pub fn rfft(
        &self,
        x: &[f32],
        pre_scale: Option<&[f32]>,
        out: &mut [C64],
        scratch: &mut RealPackScratch,
    ) {
        assert_eq!(x.len(), self.d);
        assert_eq!(out.len(), self.h + 1);
        let h = self.h;
        let RealPackScratch { z, fft } = scratch;
        z.resize(h, C64::ZERO);
        pack_real(x, pre_scale, z);
        self.half_plan.transform_with(z, Dir::Forward, fft);
        // The self-conjugate bins (DC + Nyquist) stay scalar so their
        // exactly-zero imaginary parts are produced by construction.
        let zk0 = z[0];
        out[0] = C64::new(zk0.re + zk0.im, 0.0);
        out[h] = C64::new(zk0.re - zk0.im, 0.0);
        untangle(z, &self.w_fwd, out);
    }

    /// Shared retangle + half-size inverse transform behind
    /// [`RealPackPlan::irfft`] / [`RealPackPlan::irfft_f64`]: leaves the
    /// packed time samples in `scratch.z` (re = even indices, im = odd).
    fn inverse_packed(&self, spec: &[C64], scratch: &mut RealPackScratch) {
        assert_eq!(spec.len(), self.h + 1);
        let h = self.h;
        debug_assert_real_bin(spec[0], "irfft: spec[0] (DC)");
        debug_assert_real_bin(spec[h], "irfft: spec[h] (Nyquist)");
        let RealPackScratch { z, fft } = scratch;
        z.resize(h, C64::ZERO);
        retangle(spec, &self.w_inv, z);
        self.half_plan.transform_with(z, Dir::Inverse, fft);
    }

    /// Inverse real FFT: half spectrum (len h+1) → real signal (len d),
    /// including the 1/d normalization. `spec[0]` and `spec[h]` must be
    /// real (see the module-level contract); debug builds assert it.
    pub fn irfft(&self, spec: &[C64], out: &mut [f32], scratch: &mut RealPackScratch) {
        assert_eq!(out.len(), self.d);
        self.inverse_packed(spec, scratch);
        unpack_f32(&scratch.z, out);
    }

    /// [`RealPackPlan::irfft`] at full f64 output precision — the
    /// trainer's time-domain sweep binarizes against the f64 samples, so
    /// rounding through f32 would perturb its objective accounting.
    pub fn irfft_f64(&self, spec: &[C64], out: &mut [f64], scratch: &mut RealPackScratch) {
        assert_eq!(out.len(), self.d);
        self.inverse_packed(spec, scratch);
        for (k, zk) in scratch.z.iter().enumerate() {
            out[2 * k] = zk.re;
            out[2 * k + 1] = zk.im;
        }
    }
}

// ---------------------------------------------------- kernel dispatchers
//
// The pack/untangle/retangle/unpack loops of the packed path, each split
// into a dispatcher (below) and its scalar body. When the
// [`crate::simd`] gate is open the AVX2 kernels in [`super::simd`] run
// instead; they perform the identical IEEE-754 operations in the same
// order, so both sides are bit-exact (enforced by
// `rust/tests/simd_kernels.rs`). The w tables are passed in because the
// dispatchers are free functions shared by the plan methods above.

/// z[k] = (x[2k]·s[2k], x[2k+1]·s[2k+1]) widened to f64 (s optional).
fn pack_real(x: &[f32], pre_scale: Option<&[f32]>, z: &mut [C64]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if z.len() >= 2 && crate::simd::active() {
        // SAFETY: `active()` implies runtime AVX2 detection succeeded.
        unsafe { super::simd::pack_real(x, pre_scale, z) };
        return;
    }
    match pre_scale {
        Some(s) => {
            for (k, zk) in z.iter_mut().enumerate() {
                *zk = C64::new(
                    (x[2 * k] * s[2 * k]) as f64,
                    (x[2 * k + 1] * s[2 * k + 1]) as f64,
                );
            }
        }
        None => {
            for (k, zk) in z.iter_mut().enumerate() {
                *zk = C64::new(x[2 * k] as f64, x[2 * k + 1] as f64);
            }
        }
    }
}

/// Untangle (k ∈ [1, h)): F_even[k] = (Z[k] + Z*[h−k])/2,
/// F_odd[k] = −i (Z[k] − Z*[h−k])/2, X[k] = F_even[k] + W_d^k F_odd[k].
/// The caller writes the self-conjugate bins `out[0]` / `out[h]`.
fn untangle(z: &[C64], w_fwd: &[C64], out: &mut [C64]) {
    let h = z.len();
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if h >= 3 && crate::simd::active() {
        // SAFETY: `active()` implies runtime AVX2 detection succeeded.
        unsafe { super::simd::untangle(z, w_fwd, out) };
        return;
    }
    for k in 1..h {
        let a = z[k];
        let b = z[h - k].conj();
        let fe = (a + b).scale(0.5);
        let fo = (a - b).scale(0.5);
        let fo = C64::new(fo.im, -fo.re); // multiply by -i
        out[k] = fe + w_fwd[k] * fo;
    }
}

/// Retangle (k ∈ [0, h)): F_even[k] = (X[k] + X*[h−k])/2,
/// F_odd[k] = W_d^{−k} (X[k] − X*[h−k])/2, Z[k] = F_even[k] + i F_odd[k].
fn retangle(spec: &[C64], w_inv: &[C64], z: &mut [C64]) {
    let h = z.len();
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if h >= 2 && crate::simd::active() {
        // SAFETY: `active()` implies runtime AVX2 detection succeeded.
        unsafe { super::simd::retangle(spec, w_inv, z) };
        return;
    }
    for (k, zk) in z.iter_mut().enumerate() {
        let a = spec[k];
        let b = spec[h - k].conj();
        let fe = (a + b).scale(0.5);
        let fo = (w_inv[k] * (a - b)).scale(0.5);
        let ifo = C64::new(-fo.im, fo.re); // multiply by i
        *zk = fe + ifo;
    }
}

/// out[2k], out[2k+1] = z[k].re, z[k].im as f32.
fn unpack_f32(z: &[C64], out: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if z.len() >= 2 && crate::simd::active() {
        // SAFETY: `active()` implies runtime AVX2 detection succeeded.
        unsafe { super::simd::unpack_f32(z, out) };
        return;
    }
    for (k, zk) in z.iter().enumerate() {
        out[2 * k] = zk.re as f32;
        out[2 * k + 1] = zk.im as f32;
    }
}

/// Real-FFT plan for **any** length d, producing conjugate-symmetric
/// half-spectra `X[0..=d/2]` (the ⌊d/2⌋+1 independent bins; the mirror
/// half `X[d−l] = conj(X[l])` is never materialized).
///
/// Even d routes through the packed half-size fast path
/// ([`RealPackPlan`]: one (d/2)-point complex FFT per transform); odd d
/// falls back to a full d-point complex transform with the redundant
/// mirror half dropped on output — same half layout and memory, full
/// transform cost. The DC/Nyquist realness contract of the module docs
/// applies to both arms.
///
/// Immutable, `Send + Sync`, cheap to clone (plans are `Arc`-shared);
/// per-transform state lives in a caller-owned [`RealPackScratch`].
#[derive(Clone)]
pub enum RealFft {
    /// Even d: packed half-size fast path.
    Packed(RealPackPlan),
    /// Odd d: full-size complex transform, half-spectrum views.
    Full { d: usize, plan: Arc<Plan> },
}

impl RealFft {
    pub fn new(d: usize, planner: &Planner) -> RealFft {
        assert!(d >= 1);
        if d >= 2 && d % 2 == 0 {
            RealFft::Packed(RealPackPlan::new(d, planner))
        } else {
            RealFft::Full {
                d,
                plan: planner.plan(d),
            }
        }
    }

    /// Signal length.
    pub fn d(&self) -> usize {
        match self {
            RealFft::Packed(p) => p.d,
            RealFft::Full { d, .. } => *d,
        }
    }

    /// Half-spectrum length ⌊d/2⌋ + 1.
    pub fn half_len(&self) -> usize {
        half_len(self.d())
    }

    /// Forward real FFT: x (len d) → half spectrum (len ⌊d/2⌋+1). The DC
    /// bin (and Nyquist, even d) is produced exactly real.
    pub fn rfft(&self, x: &[f32], out: &mut [C64], scratch: &mut RealPackScratch) {
        match self {
            RealFft::Packed(p) => p.rfft(x, None, out, scratch),
            RealFft::Full { d, plan } => {
                assert_eq!(x.len(), *d);
                assert_eq!(out.len(), half_len(*d));
                let RealPackScratch { z, fft } = scratch;
                z.resize(*d, C64::ZERO);
                for (zk, v) in z.iter_mut().zip(x) {
                    *zk = C64::new(*v as f64, 0.0);
                }
                plan.transform_with(z, Dir::Forward, fft);
                out.copy_from_slice(&z[..out.len()]);
                // A real signal's DC bin is Σxᵢ: enforce the exact
                // realness the packed arm produces by construction
                // (Bluestein leaves ~1 ulp of imaginary dirt).
                out[0] = C64::new(out[0].re, 0.0);
            }
        }
    }

    /// Batch helper for cache builds: `rows` is a row-major concatenation
    /// of real rows (len multiple of d), `out` the matching concatenation
    /// of half-spectra (stride [`RealFft::half_len`]).
    pub fn rfft_batch(&self, rows: &[f32], out: &mut [C64], scratch: &mut RealPackScratch) {
        let d = self.d();
        let hl = self.half_len();
        assert_eq!(rows.len() % d, 0, "rows not a multiple of d");
        assert_eq!(out.len(), rows.len() / d * hl, "out/rows length mismatch");
        for (row, spec) in rows.chunks_exact(d).zip(out.chunks_exact_mut(hl)) {
            self.rfft(row, spec, scratch);
        }
    }

    /// Inverse real FFT: half spectrum → real signal (1/d scale
    /// included). Requires real DC/Nyquist bins (module contract).
    pub fn irfft(&self, spec: &[C64], out: &mut [f32], scratch: &mut RealPackScratch) {
        match self {
            RealFft::Packed(p) => p.irfft(spec, out, scratch),
            RealFft::Full { d, plan } => {
                assert_eq!(out.len(), *d);
                Self::full_inverse(*d, plan, spec, scratch);
                for (o, zk) in out.iter_mut().zip(scratch.z.iter()) {
                    *o = zk.re as f32;
                }
            }
        }
    }

    /// [`RealFft::irfft`] at full f64 output precision (see
    /// [`RealPackPlan::irfft_f64`]).
    pub fn irfft_f64(&self, spec: &[C64], out: &mut [f64], scratch: &mut RealPackScratch) {
        match self {
            RealFft::Packed(p) => p.irfft_f64(spec, out, scratch),
            RealFft::Full { d, plan } => {
                assert_eq!(out.len(), *d);
                Self::full_inverse(*d, plan, spec, scratch);
                for (o, zk) in out.iter_mut().zip(scratch.z.iter()) {
                    *o = zk.re;
                }
            }
        }
    }

    /// Odd-length inverse: rebuild the mirror half by conjugate symmetry
    /// and run the full-size inverse transform into `scratch.z`.
    fn full_inverse(d: usize, plan: &Plan, spec: &[C64], scratch: &mut RealPackScratch) {
        assert_eq!(spec.len(), half_len(d));
        debug_assert_real_bin(spec[0], "irfft: spec[0] (DC)");
        let RealPackScratch { z, fft } = scratch;
        z.resize(d, C64::ZERO);
        z[..spec.len()].copy_from_slice(spec);
        for l in 1..spec.len() {
            z[d - l] = spec[l].conj();
        }
        plan.transform_with(z, Dir::Inverse, fft);
    }
}

// The trainer fans one RealFft out across scoped worker threads.
const _: () = {
    #[allow(dead_code)]
    fn assert_send_sync<T: Send + Sync>() {}
    #[allow(dead_code)]
    fn check() {
        assert_send_sync::<RealFft>();
        assert_send_sync::<RealPackPlan>();
    }
};

// ------------------------------------------------- half-spectrum kernels
//
// The per-bin accumulations of the §4 trainer, phrased on half-spectra.
// Conjugate symmetry makes the half layout closed under all of them: the
// product of two conjugate-symmetric spectra is conjugate-symmetric, and
// every mirror bin's contribution to a per-bin reduction equals its
// partner's (|X[d−l]|² = |X[l]|², Re mirrors, Im negates), so the
// trainer folds the factor of 2 into the per-bin solve instead of ever
// touching a mirror bin.

/// out[l] = a[l]·b[l] — the half-spectrum product behind every circulant
/// apply (y = IFFT(F(x) ∘ F(r))). SIMD-dispatched, bit-exact both sides.
#[inline]
pub fn spectral_mul(a: &[C64], b: &[C64], out: &mut [C64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if a.len() >= 2 && crate::simd::active() {
        // SAFETY: `active()` implies runtime AVX2 detection succeeded.
        unsafe { super::simd::cmul_into(a, b, out) };
        return;
    }
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = *x * *y;
    }
}

/// acc[l] += |s[l]|² — the M accumulation of eq. 17 on a half-spectrum
/// (the solver doubles the paired bins; DC/Nyquist count once).
/// SIMD-dispatched, bit-exact both sides.
#[inline]
pub fn spectral_energy_accum(s: &[C64], acc: &mut [f64]) {
    debug_assert_eq!(s.len(), acc.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if s.len() >= 4 && crate::simd::active() {
        // SAFETY: `active()` implies runtime AVX2 detection succeeded.
        unsafe { super::simd::energy_accum(s, acc) };
        return;
    }
    for (a, c) in acc.iter_mut().zip(s) {
        *a += c.norm_sqr();
    }
}

/// The eq. 17 h/g correlation accumulators on half-spectra:
/// h[l] −= 2·Re(x[l]·conj(b[l])), g[l] += 2·Im(x[l]·conj(b[l])).
/// SIMD-dispatched, bit-exact both sides.
#[inline]
pub fn spectral_corr_accum(x: &[C64], b: &[C64], h: &mut [f64], g: &mut [f64]) {
    debug_assert_eq!(x.len(), b.len());
    debug_assert_eq!(x.len(), h.len());
    debug_assert_eq!(x.len(), g.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if x.len() >= 4 && crate::simd::active() {
        // SAFETY: `active()` implies runtime AVX2 detection succeeded.
        unsafe { super::simd::corr_accum(x, b, h, g) };
        return;
    }
    for l in 0..x.len() {
        h[l] -= 2.0 * (x[l].re * b[l].re + x[l].im * b[l].im);
        g[l] += 2.0 * (x[l].im * b[l].re - x[l].re * b[l].im);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::real;
    use crate::util::rng::Pcg64;

    #[test]
    fn half_spectrum_matches_full_fft() {
        let planner = Planner::new();
        let mut rng = Pcg64::new(31);
        let mut scratch = RealPackScratch::new();
        for d in [4usize, 16, 30, 64, 100] {
            let plan = RealPackPlan::new(d, &planner);
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let mut half = vec![C64::ZERO; d / 2 + 1];
            plan.rfft(&x, None, &mut half, &mut scratch);
            let full = real::rfft_full(&planner, &x);
            for k in 0..=d / 2 {
                let err = (half[k] - full[k]).abs();
                assert!(err < 1e-6 * (1.0 + full[k].abs()), "d={d} k={k} err={err}");
            }
        }
    }

    #[test]
    fn roundtrip_real_signal() {
        let planner = Planner::new();
        let mut rng = Pcg64::new(32);
        let mut scratch = RealPackScratch::new();
        for d in [8usize, 20, 64, 256] {
            let plan = RealPackPlan::new(d, &planner);
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let mut half = vec![C64::ZERO; d / 2 + 1];
            plan.rfft(&x, None, &mut half, &mut scratch);
            let mut back = vec![0f32; d];
            plan.irfft(&half, &mut back, &mut scratch);
            for (a, b) in back.iter().zip(&x) {
                assert!((a - b).abs() < 1e-4, "d={d}");
            }
        }
    }

    #[test]
    fn pre_scale_applies_sign_flips() {
        let planner = Planner::new();
        let mut rng = Pcg64::new(33);
        let mut scratch = RealPackScratch::new();
        let d = 32;
        let plan = RealPackPlan::new(d, &planner);
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let s = rng.sign_vec(d);
        let flipped: Vec<f32> = x.iter().zip(&s).map(|(a, b)| a * b).collect();
        let mut h1 = vec![C64::ZERO; d / 2 + 1];
        let mut h2 = vec![C64::ZERO; d / 2 + 1];
        plan.rfft(&x, Some(&s), &mut h1, &mut scratch);
        plan.rfft(&flipped, None, &mut h2, &mut scratch);
        for (a, b) in h1.iter().zip(&h2) {
            assert!((*a - *b).abs() < 1e-9);
        }
    }

    #[test]
    fn clones_share_the_half_plan() {
        let planner = Planner::new();
        let plan = RealPackPlan::new(64, &planner);
        let clone = plan.clone();
        assert!(Arc::ptr_eq(&plan.half_plan, &clone.half_plan));
    }

    // ------------------------------------------------ RealFft (any d)

    #[test]
    fn realfft_matches_full_fft_even_and_odd() {
        let planner = Planner::new();
        let mut rng = Pcg64::new(41);
        let mut scratch = RealPackScratch::new();
        for d in [1usize, 2, 3, 7, 16, 21, 27, 64, 100, 135] {
            let rf = RealFft::new(d, &planner);
            assert_eq!(rf.d(), d);
            assert_eq!(rf.half_len(), d / 2 + 1);
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let mut half = vec![C64::ZERO; rf.half_len()];
            rf.rfft(&x, &mut half, &mut scratch);
            let full = real::rfft_full(&planner, &x);
            for k in 0..half.len() {
                let err = (half[k] - full[k]).abs();
                assert!(err < 1e-6 * (1.0 + full[k].abs()), "d={d} k={k} err={err}");
            }
            // The realness contract on the self-conjugate bins is exact.
            assert_eq!(half[0].im, 0.0, "d={d}: DC bin not exactly real");
            if d % 2 == 0 && d >= 2 {
                assert_eq!(half[d / 2].im, 0.0, "d={d}: Nyquist bin not exactly real");
            }
        }
    }

    #[test]
    fn realfft_roundtrip_f32_and_f64() {
        let planner = Planner::new();
        let mut rng = Pcg64::new(42);
        let mut scratch = RealPackScratch::new();
        for d in [2usize, 5, 20, 27, 64] {
            let rf = RealFft::new(d, &planner);
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let mut half = vec![C64::ZERO; rf.half_len()];
            rf.rfft(&x, &mut half, &mut scratch);
            let mut back32 = vec![0f32; d];
            rf.irfft(&half, &mut back32, &mut scratch);
            let mut back64 = vec![0f64; d];
            rf.irfft_f64(&half, &mut back64, &mut scratch);
            for j in 0..d {
                assert!((back32[j] - x[j]).abs() < 1e-4, "d={d} f32");
                assert!((back64[j] - x[j] as f64).abs() < 1e-9, "d={d} f64");
            }
        }
    }

    #[test]
    fn rfft_batch_equals_per_row() {
        let planner = Planner::new();
        let mut rng = Pcg64::new(43);
        let mut scratch = RealPackScratch::new();
        for d in [12usize, 15] {
            let rf = RealFft::new(d, &planner);
            let hl = rf.half_len();
            let rows: Vec<f32> = (0..4 * d).map(|_| rng.normal() as f32).collect();
            let mut batch = vec![C64::ZERO; 4 * hl];
            rf.rfft_batch(&rows, &mut batch, &mut scratch);
            for r in 0..4 {
                let mut one = vec![C64::ZERO; hl];
                rf.rfft(&rows[r * d..(r + 1) * d], &mut one, &mut scratch);
                for k in 0..hl {
                    // Bit-identical: the batch helper is the same code path.
                    assert_eq!(batch[r * hl + k], one[k], "d={d} row={r} k={k}");
                }
            }
        }
    }

    #[test]
    fn nyquist_only_signal_roundtrips() {
        // x = (+1, −1, +1, …) is pure Nyquist: all energy in bin h, which
        // must come out exactly real and invert exactly.
        let planner = Planner::new();
        let mut scratch = RealPackScratch::new();
        for d in [8usize, 32] {
            let rf = RealFft::new(d, &planner);
            let x: Vec<f32> = (0..d).map(|j| if j % 2 == 0 { 1.0 } else { -1.0 }).collect();
            let mut half = vec![C64::ZERO; rf.half_len()];
            rf.rfft(&x, &mut half, &mut scratch);
            assert_eq!(half[d / 2].im, 0.0);
            assert!((half[d / 2].re - d as f64).abs() < 1e-9, "d={d}");
            for k in 0..d / 2 {
                assert!(half[k].abs() < 1e-9, "d={d} bin {k} leaked {}", half[k].abs());
            }
            let mut back = vec![0f64; d];
            rf.irfft_f64(&half, &mut back, &mut scratch);
            for (a, b) in back.iter().zip(&x) {
                assert!((a - *b as f64).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn spectral_kernels_match_full_spectrum_accumulation() {
        // The half-spectrum kernels plus the solver's pairing rules must
        // reproduce the full-spectrum quantities: m' = m_l + m_{d−l} =
        // 2m_l, h' = h_l + h_{d−l} = 2h_l, g' = g_l − g_{d−l} = 2g_l.
        let planner = Planner::new();
        let mut rng = Pcg64::new(44);
        let mut scratch = RealPackScratch::new();
        for d in [16usize, 21] {
            let rf = RealFft::new(d, &planner);
            let hl = rf.half_len();
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..d)
                .map(|_| if rng.next_f64() < 0.5 { 1.0 } else { -1.0 })
                .collect();
            let mut xh = vec![C64::ZERO; hl];
            let mut bh = vec![C64::ZERO; hl];
            rf.rfft(&x, &mut xh, &mut scratch);
            rf.rfft(&b, &mut bh, &mut scratch);
            let xf = real::rfft_full(&planner, &x);
            let bf = real::rfft_full(&planner, &b);

            let mut m_half = vec![0f64; hl];
            spectral_energy_accum(&xh, &mut m_half);
            let mut h_half = vec![0f64; hl];
            let mut g_half = vec![0f64; hl];
            spectral_corr_accum(&xh, &bh, &mut h_half, &mut g_half);

            for l in 1..=(d - 1) / 2 {
                let m_full = xf[l].norm_sqr() + xf[d - l].norm_sqr();
                let h_full = -2.0
                    * (xf[l].re * bf[l].re + xf[l].im * bf[l].im
                        + xf[d - l].re * bf[d - l].re
                        + xf[d - l].im * bf[d - l].im);
                let g_full = 2.0 * (xf[l].im * bf[l].re - xf[l].re * bf[l].im)
                    - 2.0 * (xf[d - l].im * bf[d - l].re - xf[d - l].re * bf[d - l].im);
                assert!(
                    (2.0 * m_half[l] - m_full).abs() < 1e-6 * (1.0 + m_full.abs()),
                    "m d={d} l={l}"
                );
                assert!(
                    (2.0 * h_half[l] - h_full).abs() < 1e-6 * (1.0 + h_full.abs()),
                    "h d={d} l={l}"
                );
                assert!(
                    (2.0 * g_half[l] - g_full).abs() < 1e-6 * (1.0 + g_full.abs()),
                    "g d={d} l={l}"
                );
            }
            // Spectral product mirrors the full-spectrum product on the
            // shared bins.
            let mut prod = vec![C64::ZERO; hl];
            spectral_mul(&xh, &bh, &mut prod);
            for l in 0..hl {
                let full = xf[l] * bf[l];
                assert!((prod[l] - full).abs() < 1e-6 * (1.0 + full.abs()), "d={d} l={l}");
            }
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "must be real")]
    fn irfft_rejects_complex_nyquist_in_debug() {
        let planner = Planner::new();
        let d = 8;
        let plan = RealPackPlan::new(d, &planner);
        let mut spec = vec![C64::ZERO; d / 2 + 1];
        spec[d / 2] = C64::new(1.0, 0.5); // illegal: Nyquist must be real
        let mut out = vec![0f32; d];
        plan.irfft(&spec, &mut out, &mut RealPackScratch::new());
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "must be real")]
    fn odd_irfft_rejects_complex_dc_in_debug() {
        let planner = Planner::new();
        let d = 7;
        let rf = RealFft::new(d, &planner);
        let mut spec = vec![C64::ZERO; d / 2 + 1];
        spec[0] = C64::new(1.0, 0.5); // illegal: DC must be real
        let mut out = vec![0f32; d];
        rf.irfft(&spec, &mut out, &mut RealPackScratch::new());
    }
}
