//! Real-input FFT via half-size complex FFT (the "pack two reals into one
//! complex" trick, Numerical Recipes `realft` lineage).
//!
//! CBE's signals — data vectors, the circulant parameter r, and the
//! projections — are all real, so every transform in the encode hot path
//! can run at half size: a d-point real FFT costs one (d/2)-point complex
//! FFT plus O(d) untangling. Perf pass iteration 3 (EXPERIMENTS.md §Perf):
//! ~1.8× on the dominant cost.
//!
//! Conventions: `rfft` returns the half-spectrum X[0..=h] (h = d/2,
//! inclusive of the Nyquist bin; X[0] and X[h] are real). `irfft`
//! inverts it including the 1/d scale.
//!
//! [`RealPackPlan`] is immutable (`Send + Sync`, cheap to clone — the
//! half-size plan is `Arc`-shared); all per-transform state lives in the
//! caller-owned [`RealPackScratch`], one per thread.

use super::{C64, Dir, FftScratch, Plan, Planner};
use std::sync::Arc;

/// Precomputed tables for one even length d. Immutable and shareable
/// across threads; clones share the underlying half-size [`Plan`].
#[derive(Clone)]
pub struct RealPackPlan {
    pub d: usize,
    h: usize,
    /// W_d^k = e^{-2πik/d}, k = 0..h.
    w_fwd: Vec<C64>,
    /// W_d^{-k}, k = 0..h.
    w_inv: Vec<C64>,
    /// Shared half-size complex plan (resolved once, no planner lock on
    /// the hot path).
    half_plan: Arc<Plan>,
}

/// Caller-owned work space for [`RealPackPlan`]: the packed half-size
/// complex buffer plus the nested FFT scratch (h itself may be a
/// Bluestein size, e.g. d = 100 → h = 50).
#[derive(Default)]
pub struct RealPackScratch {
    z: Vec<C64>,
    fft: FftScratch,
}

impl RealPackScratch {
    pub fn new() -> RealPackScratch {
        RealPackScratch::default()
    }
}

impl RealPackPlan {
    /// d must be even (callers fall back to the full-complex path if not).
    pub fn new(d: usize, planner: &Planner) -> RealPackPlan {
        assert!(d >= 2 && d % 2 == 0, "RealPackPlan requires even d");
        let h = d / 2;
        let w_fwd: Vec<C64> = (0..=h)
            .map(|k| C64::cis(-2.0 * std::f64::consts::PI * k as f64 / d as f64))
            .collect();
        let w_inv: Vec<C64> = w_fwd.iter().map(|c| c.conj()).collect();
        RealPackPlan {
            d,
            h,
            w_fwd,
            w_inv,
            // Resolve the half-size plan now (not on the first hot call).
            half_plan: planner.plan(h),
        }
    }

    /// Forward real FFT: x (len d, real) → half spectrum (len h+1).
    /// `pre_scale` multiplies inputs on the fly (used for the D sign flips).
    pub fn rfft(
        &self,
        x: &[f32],
        pre_scale: Option<&[f32]>,
        out: &mut [C64],
        scratch: &mut RealPackScratch,
    ) {
        assert_eq!(x.len(), self.d);
        assert_eq!(out.len(), self.h + 1);
        let h = self.h;
        let RealPackScratch { z, fft } = scratch;
        z.resize(h, C64::ZERO);
        match pre_scale {
            Some(s) => {
                for (k, zk) in z.iter_mut().enumerate() {
                    *zk = C64::new(
                        (x[2 * k] * s[2 * k]) as f64,
                        (x[2 * k + 1] * s[2 * k + 1]) as f64,
                    );
                }
            }
            None => {
                for (k, zk) in z.iter_mut().enumerate() {
                    *zk = C64::new(x[2 * k] as f64, x[2 * k + 1] as f64);
                }
            }
        }
        self.half_plan.transform_with(z, Dir::Forward, fft);
        // Untangle: F_even[k] = (Z[k] + Z*[h-k])/2,
        //           F_odd[k]  = -i (Z[k] - Z*[h-k])/2,
        //           X[k] = F_even[k] + W_d^k F_odd[k].
        let zk0 = z[0];
        out[0] = C64::new(zk0.re + zk0.im, 0.0);
        out[h] = C64::new(zk0.re - zk0.im, 0.0);
        for k in 1..h {
            let a = z[k];
            let b = z[h - k].conj();
            let fe = (a + b).scale(0.5);
            let fo = (a - b).scale(0.5);
            let fo = C64::new(fo.im, -fo.re); // multiply by -i
            out[k] = fe + self.w_fwd[k] * fo;
        }
    }

    /// Inverse real FFT: half spectrum (len h+1) → real signal (len d),
    /// including the 1/d normalization.
    pub fn irfft(&self, spec: &[C64], out: &mut [f32], scratch: &mut RealPackScratch) {
        assert_eq!(spec.len(), self.h + 1);
        assert_eq!(out.len(), self.d);
        let h = self.h;
        let RealPackScratch { z, fft } = scratch;
        z.resize(h, C64::ZERO);
        // Retangle: F_even[k] = (X[k] + X*[h-k])/2,
        //           F_odd[k]  = W_d^{-k} (X[k] - X*[h-k])/2,
        //           Z[k] = F_even[k] + i F_odd[k].
        for (k, zk) in z.iter_mut().enumerate() {
            let a = spec[k];
            let b = spec[h - k].conj();
            let fe = (a + b).scale(0.5);
            let fo = (self.w_inv[k] * (a - b)).scale(0.5);
            let ifo = C64::new(-fo.im, fo.re); // multiply by i
            *zk = fe + ifo;
        }
        self.half_plan.transform_with(z, Dir::Inverse, fft);
        for k in 0..h {
            out[2 * k] = z[k].re as f32;
            out[2 * k + 1] = z[k].im as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::real;
    use crate::util::rng::Pcg64;

    #[test]
    fn half_spectrum_matches_full_fft() {
        let planner = Planner::new();
        let mut rng = Pcg64::new(31);
        let mut scratch = RealPackScratch::new();
        for d in [4usize, 16, 30, 64, 100] {
            let plan = RealPackPlan::new(d, &planner);
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let mut half = vec![C64::ZERO; d / 2 + 1];
            plan.rfft(&x, None, &mut half, &mut scratch);
            let full = real::rfft_full(&planner, &x);
            for k in 0..=d / 2 {
                let err = (half[k] - full[k]).abs();
                assert!(err < 1e-6 * (1.0 + full[k].abs()), "d={d} k={k} err={err}");
            }
        }
    }

    #[test]
    fn roundtrip_real_signal() {
        let planner = Planner::new();
        let mut rng = Pcg64::new(32);
        let mut scratch = RealPackScratch::new();
        for d in [8usize, 20, 64, 256] {
            let plan = RealPackPlan::new(d, &planner);
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let mut half = vec![C64::ZERO; d / 2 + 1];
            plan.rfft(&x, None, &mut half, &mut scratch);
            let mut back = vec![0f32; d];
            plan.irfft(&half, &mut back, &mut scratch);
            for (a, b) in back.iter().zip(&x) {
                assert!((a - b).abs() < 1e-4, "d={d}");
            }
        }
    }

    #[test]
    fn pre_scale_applies_sign_flips() {
        let planner = Planner::new();
        let mut rng = Pcg64::new(33);
        let mut scratch = RealPackScratch::new();
        let d = 32;
        let plan = RealPackPlan::new(d, &planner);
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let s = rng.sign_vec(d);
        let flipped: Vec<f32> = x.iter().zip(&s).map(|(a, b)| a * b).collect();
        let mut h1 = vec![C64::ZERO; d / 2 + 1];
        let mut h2 = vec![C64::ZERO; d / 2 + 1];
        plan.rfft(&x, Some(&s), &mut h1, &mut scratch);
        plan.rfft(&flipped, None, &mut h2, &mut scratch);
        for (a, b) in h1.iter().zip(&h2) {
            assert!((*a - *b).abs() < 1e-9);
        }
    }

    #[test]
    fn clones_share_the_half_plan() {
        let planner = Planner::new();
        let plan = RealPackPlan::new(64, &planner);
        let clone = plan.clone();
        assert!(Arc::ptr_eq(&plan.half_plan, &clone.half_plan));
    }
}
