//! Bluestein's chirp-z transform: FFT of arbitrary length n via a
//! convolution of length m ≥ 2n−1, m a power of two.
//!
//! Needed because the paper's feature dimensions (25,600 and 51,200) are not
//! powers of two; CBE must still run in O(d log d) for them.

use super::{radix2, C64, Dir};

/// Chirp table w_k = exp(-iπ k²/n), k in [0, n).
///
/// Built over half the range and mirrored: (n−k)² ≡ k² + n² (mod 2n),
/// and n² mod 2n is 0 for even n and n for odd n, so the upper half is
/// the lower half exactly (even n) or negated (odd n — the extra n in
/// the reduced square contributes exp(−iπ) = −1). That halves the
/// sin/cos calls, which dominate chirp construction at the paper's
/// non-power-of-two dims (25,600 / 51,200) where this table is rebuilt
/// per plan.
pub fn make_chirp(n: usize) -> Vec<C64> {
    let mut chirp = vec![C64::ZERO; n];
    for (k, w) in chirp.iter_mut().enumerate().take(n / 2 + 1) {
        // k² mod 2n avoids catastrophic angle growth for large k.
        let kk = (k * k) % (2 * n);
        *w = C64::cis(-std::f64::consts::PI * kk as f64 / n as f64);
    }
    for k in n / 2 + 1..n {
        let m = chirp[n - k];
        chirp[k] = if n % 2 == 0 { m } else { C64::new(-m.re, -m.im) };
    }
    chirp
}

/// FFT_m of the Bluestein filter b_k = conj(chirp)_|k| (wrapped support).
pub fn make_bfft(n: usize, m: usize, chirp: &[C64]) -> Vec<C64> {
    let mut b = vec![C64::ZERO; m];
    b[0] = chirp[0].conj();
    for k in 1..n {
        let c = chirp[k].conj();
        b[k] = c;
        b[m - k] = c;
    }
    let tw = radix2::make_twiddles(m);
    radix2::fft_inplace(&mut b, &tw, Dir::Forward);
    b
}

/// Full Bluestein transform of `buf` (len n). Forward or inverse (inverse
/// includes the 1/n scale). Uses the precomputed chirp, FFT(b) and radix-2
/// twiddle tables (both directions) plus a caller-provided length-m
/// scratch buffer — no allocation on the hot path (perf pass).
#[allow(clippy::too_many_arguments)]
pub fn transform_with_scratch(
    buf: &mut [C64],
    n: usize,
    m: usize,
    chirp: &[C64],
    bfft: &[C64],
    m_twiddles: &[C64],
    m_twiddles_inv: &[C64],
    a: &mut [C64],
    dir: Dir,
) {
    debug_assert_eq!(buf.len(), n);
    debug_assert_eq!(a.len(), m);
    // Inverse DFT via conj-forward-conj: IDFT(x) = conj(DFT(conj(x)))/n.
    if dir == Dir::Inverse {
        for v in buf.iter_mut() {
            *v = v.conj();
        }
    }
    // a_k = x_k * chirp_k, zero-padded to m.
    for k in 0..n {
        a[k] = buf[k] * chirp[k];
    }
    for v in a[n..].iter_mut() {
        *v = C64::ZERO;
    }
    radix2::fft_inplace_tw(a, m_twiddles);
    super::cmul_in_place(a, bfft);
    radix2::fft_inplace_tw(a, m_twiddles_inv);
    let scale = 1.0 / m as f64;
    for k in 0..n {
        buf[k] = a[k].scale(scale) * chirp[k];
    }
    if dir == Dir::Inverse {
        let s = 1.0 / n as f64;
        for v in buf.iter_mut() {
            *v = v.conj().scale(s);
        }
    }
}

/// Allocating convenience wrapper (tests / one-off callers).
pub fn transform(
    buf: &mut [C64],
    n: usize,
    m: usize,
    chirp: &[C64],
    bfft: &[C64],
    m_twiddles: &[C64],
    dir: Dir,
) {
    let inv: Vec<C64> = m_twiddles.iter().map(|c| c.conj()).collect();
    let mut a = vec![C64::ZERO; m];
    transform_with_scratch(buf, n, m, chirp, bfft, m_twiddles, &inv, &mut a, dir);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{dft_naive, Plan};

    #[test]
    fn odd_sizes_match_naive() {
        for n in [3usize, 5, 9, 17, 33, 101] {
            let x: Vec<C64> = (0..n)
                .map(|i| C64::new((i as f64 * 0.3).sin(), (i as f64 * 1.1).cos()))
                .collect();
            let want = dft_naive(&x, Dir::Forward);
            let plan = Plan::new(n);
            let mut got = x.clone();
            plan.transform(&mut got, Dir::Forward);
            let err = got
                .iter()
                .zip(&want)
                .map(|(a, b)| (*a - *b).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-9 * n as f64, "n={n} err={err}");
        }
    }

    #[test]
    fn chirp_symmetry() {
        let n = 12;
        let chirp = make_chirp(n);
        for k in 0..n {
            assert!((chirp[k].abs() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn mirrored_chirp_matches_the_per_k_formula() {
        // The mirrored build must agree with evaluating
        // exp(-iπ (k² mod 2n)/n) independently at every k — both
        // parities, including the degenerate n=1,2 (no mirrored tail)
        // and sizes the serving dims actually hit.
        for n in [1usize, 2, 3, 4, 5, 12, 13, 100, 101, 255, 256] {
            let got = make_chirp(n);
            for k in 0..n {
                let kk = (k * k) % (2 * n);
                let want = C64::cis(-std::f64::consts::PI * kk as f64 / n as f64);
                let err = (got[k] - want).abs();
                assert!(err < 1e-12, "n={n} k={k} err={err}");
            }
        }
    }
}
