//! Minimal complex-f64 arithmetic (no `num-complex` needed).

use std::ops::{Add, Mul, Neg, Sub};

/// A complex number with f64 parts.
///
/// `repr(C)` is load-bearing: the SIMD kernels reinterpret `&[C64]` as a
/// flat `[re, im, re, im, …]` f64 buffer (two complex lanes per
/// `__m256d`), which requires the guaranteed field order and no padding.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
#[repr(C)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    pub const ZERO: C64 = C64 { re: 0.0, im: 0.0 };
    pub const ONE: C64 = C64 { re: 1.0, im: 0.0 };

    #[inline]
    pub fn new(re: f64, im: f64) -> C64 {
        C64 { re, im }
    }

    /// e^{iθ}.
    #[inline]
    pub fn cis(theta: f64) -> C64 {
        C64::new(theta.cos(), theta.sin())
    }

    #[inline]
    pub fn conj(self) -> C64 {
        C64::new(self.re, -self.im)
    }

    #[inline]
    pub fn scale(self, s: f64) -> C64 {
        C64::new(self.re * s, self.im * s)
    }

    #[inline]
    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }

    #[inline]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }
}

impl Add for C64 {
    type Output = C64;
    #[inline]
    fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }
}
impl Sub for C64 {
    type Output = C64;
    #[inline]
    fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }
}
impl Mul for C64 {
    type Output = C64;
    #[inline]
    fn mul(self, o: C64) -> C64 {
        C64::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}
impl Neg for C64 {
    type Output = C64;
    #[inline]
    fn neg(self) -> C64 {
        C64::new(-self.re, -self.im)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = C64::new(1.0, 2.0);
        let b = C64::new(3.0, -1.0);
        assert_eq!(a + b, C64::new(4.0, 1.0));
        assert_eq!(a - b, C64::new(-2.0, 3.0));
        assert_eq!(a * b, C64::new(5.0, 5.0)); // (1+2i)(3-i) = 3 - i + 6i + 2 = 5 + 5i
        assert_eq!(-a, C64::new(-1.0, -2.0));
        assert_eq!(a.conj(), C64::new(1.0, -2.0));
    }

    #[test]
    fn cis_unit_circle() {
        let c = C64::cis(std::f64::consts::FRAC_PI_2);
        assert!((c.re).abs() < 1e-15);
        assert!((c.im - 1.0).abs() < 1e-15);
        assert!((c.abs() - 1.0).abs() < 1e-15);
    }
}
