//! From-scratch FFT substrate.
//!
//! The paper's entire speed claim rests on computing circulant projections
//! via FFT: `Rx = r ⊛ x = IFFT(FFT(r) ∘ FFT(x))` in O(d log d). The offline
//! vendor set has no FFT crate, so this module implements:
//!
//! * [`complex::C64`] — minimal complex arithmetic,
//! * [`radix2`] — iterative in-place Cooley–Tukey for power-of-two sizes,
//! * [`bluestein`] — Bluestein's chirp-z algorithm for arbitrary sizes
//!   (the paper's datasets are d = 25,600 / 51,200 — *not* powers of two),
//! * [`real`] — real-input forward/inverse wrappers (full spectra),
//! * [`realpack`] — the half-spectrum substrate: half-size real-FFT fast
//!   path for even lengths ([`realpack::RealPackPlan`]), the any-length
//!   [`RealFft`] facade the trainer stores its conjugate-symmetric
//!   half-spectra through, and the per-bin spectral kernels,
//! * [`Planner`] — caches twiddles/chirp tables per size.
//!
//! # Threading model
//!
//! The substrate is thread-safe by construction (the parallel batch-encode
//! engine fans one [`Plan`] out across scoped threads):
//!
//! * [`Plan`] is **immutable** — twiddle/chirp tables only, `Send + Sync`.
//!   Bluestein's length-m work buffer is *caller-owned* ([`FftScratch`]),
//!   passed to [`Plan::transform_with`]; nothing in a plan mutates.
//! * [`Planner`] is an `Arc<RwLock<…>>`-backed size-keyed cache handing out
//!   `Arc<Plan>`s. Cloning a planner shares the cache; hot paths resolve
//!   their `Arc<Plan>` once and never touch the lock again.
//! * Per-transform mutable state lives exclusively in [`FftScratch`] (and
//!   the higher-level scratch types built on it), owned by exactly one
//!   thread at a time.

pub mod complex;
pub mod radix2;
pub mod bluestein;
pub mod real;
pub mod realpack;
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub(crate) mod simd;

pub use complex::C64;
pub use realpack::RealFft;

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

/// Direction of a transform.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    Forward,
    Inverse,
}

/// Caller-owned work space for [`Plan::transform_with`]. Radix-2 plans
/// never touch it; Bluestein plans use it as the length-m convolution
/// buffer. Reuse one per thread to keep the hot path allocation-free —
/// the buffer grows to the largest size seen and stays there.
#[derive(Default)]
pub struct FftScratch {
    work: Vec<C64>,
}

impl FftScratch {
    pub fn new() -> FftScratch {
        FftScratch::default()
    }
}

/// A prepared FFT plan for one size (twiddle tables precomputed; forward
/// and inverse tables kept separately so the butterfly loop never branches
/// on direction — perf pass, see EXPERIMENTS.md §Perf). Immutable after
/// construction, so one plan is freely shared across threads.
pub struct Plan {
    pub n: usize,
    kind: PlanKind,
}

enum PlanKind {
    Radix2 {
        twiddles: Vec<C64>,
        twiddles_inv: Vec<C64>,
    },
    Bluestein {
        m: usize,
        chirp: Vec<C64>,          // w_k = exp(-i π k² / n)
        bfft: Vec<C64>,           // FFT_m of the chirp filter b
        m_twiddles: Vec<C64>,     // radix-2 twiddles for size m
        m_twiddles_inv: Vec<C64>, // conjugated table
    },
}

impl Plan {
    /// Build a plan for length-n transforms (any n ≥ 1).
    pub fn new(n: usize) -> Plan {
        assert!(n >= 1);
        if n.is_power_of_two() {
            Plan {
                n,
                kind: PlanKind::Radix2 {
                    twiddles: radix2::make_twiddles(n),
                    twiddles_inv: radix2::make_twiddles_inv(n),
                },
            }
        } else {
            let m = (2 * n - 1).next_power_of_two();
            let chirp = bluestein::make_chirp(n);
            let bfft = bluestein::make_bfft(n, m, &chirp);
            Plan {
                n,
                kind: PlanKind::Bluestein {
                    m,
                    chirp,
                    bfft,
                    m_twiddles: radix2::make_twiddles(m),
                    m_twiddles_inv: radix2::make_twiddles_inv(m),
                },
            }
        }
    }

    /// In-place transform of `buf` (len n) using caller-owned scratch.
    /// `Inverse` includes the 1/n scale, matching numpy's `ifft`
    /// convention. This is the hot-path entry point: with a reused
    /// [`FftScratch`] it performs no allocation.
    pub fn transform_with(&self, buf: &mut [C64], dir: Dir, scratch: &mut FftScratch) {
        assert_eq!(buf.len(), self.n);
        match &self.kind {
            PlanKind::Radix2 {
                twiddles,
                twiddles_inv,
            } => match dir {
                Dir::Forward => radix2::fft_inplace_tw(buf, twiddles),
                Dir::Inverse => {
                    radix2::fft_inplace_tw(buf, twiddles_inv);
                    let s = 1.0 / self.n as f64;
                    for v in buf.iter_mut() {
                        *v = v.scale(s);
                    }
                }
            },
            PlanKind::Bluestein {
                m,
                chirp,
                bfft,
                m_twiddles,
                m_twiddles_inv,
            } => {
                scratch.work.resize(*m, C64::ZERO);
                bluestein::transform_with_scratch(
                    buf,
                    self.n,
                    *m,
                    chirp,
                    bfft,
                    m_twiddles,
                    m_twiddles_inv,
                    &mut scratch.work[..*m],
                    dir,
                );
            }
        }
    }

    /// Convenience wrapper around [`Plan::transform_with`] for callers
    /// that don't thread a scratch (tests, `Planner::fft`/`ifft`, the
    /// CBE-opt trainer). Backed by a per-thread scratch so repeated
    /// Bluestein transforms don't reallocate the length-m buffer; the
    /// plan itself stays immutable and `Sync`.
    pub fn transform(&self, buf: &mut [C64], dir: Dir) {
        use std::cell::RefCell;
        thread_local! {
            static SCRATCH: RefCell<FftScratch> = RefCell::new(FftScratch::new());
        }
        SCRATCH.with(|s| self.transform_with(buf, dir, &mut s.borrow_mut()));
    }
}

/// Size-keyed plan cache. Cloning is cheap (`Arc`) and shares the cache;
/// the planner is `Send + Sync`, so one cache serves every thread.
#[derive(Clone, Default)]
pub struct Planner {
    plans: Arc<RwLock<HashMap<usize, Arc<Plan>>>>,
}

impl Planner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resolve (building on first use) the shared plan for length n. Hot
    /// paths should call this once and keep the `Arc<Plan>`; the lock is
    /// only for cache maintenance.
    pub fn plan(&self, n: usize) -> Arc<Plan> {
        if let Some(p) = self.plans.read().expect("planner lock poisoned").get(&n) {
            crate::obs::add(crate::obs::Counter::PlanHit, 1);
            return Arc::clone(p);
        }
        // Write-path entries count as misses; racers that lose the entry
        // race may double-count a miss, which is fine for a diagnostic —
        // the signal is "hot paths should hit the read path".
        crate::obs::add(crate::obs::Counter::PlanMiss, 1);
        let mut map = self.plans.write().expect("planner lock poisoned");
        Arc::clone(map.entry(n).or_insert_with(|| Arc::new(Plan::new(n))))
    }

    /// Forward FFT of a complex buffer (in place).
    pub fn fft(&self, buf: &mut [C64]) {
        self.plan(buf.len()).transform(buf, Dir::Forward);
    }

    /// Inverse FFT (with 1/n scale) of a complex buffer (in place).
    pub fn ifft(&self, buf: &mut [C64]) {
        self.plan(buf.len()).transform(buf, Dir::Inverse);
    }
}

/// Pointwise in-place complex product `a[i] ← a[i]·b[i]` — the spectral
/// multiply used by the Bluestein convolution and the circulant
/// projection. Dispatched through [`crate::simd`]: the AVX2 kernel is
/// bit-exact vs this scalar loop (element-wise mul/sub/add, no FMA).
pub fn cmul_in_place(a: &mut [C64], b: &[C64]) {
    assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if a.len() >= 2 && crate::simd::active() {
        // SAFETY: `active()` implies runtime AVX2 detection succeeded.
        unsafe { simd::cmul_in_place(a, b) };
        return;
    }
    for (av, bv) in a.iter_mut().zip(b) {
        *av = *av * *bv;
    }
}

/// Naive O(n²) DFT — the test oracle for every fast path in this module.
pub fn dft_naive(x: &[C64], dir: Dir) -> Vec<C64> {
    let n = x.len();
    let sign = match dir {
        Dir::Forward => -1.0,
        Dir::Inverse => 1.0,
    };
    let mut out = vec![C64::ZERO; n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = C64::ZERO;
        for (m, xm) in x.iter().enumerate() {
            let ang = sign * 2.0 * std::f64::consts::PI * (k * m % n) as f64 / n as f64;
            acc = acc + *xm * C64::new(ang.cos(), ang.sin());
        }
        *o = acc;
    }
    if dir == Dir::Inverse {
        for o in out.iter_mut() {
            *o = o.scale(1.0 / n as f64);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_signal(n: usize, seed: u64) -> Vec<C64> {
        let mut r = Pcg64::new(seed);
        (0..n).map(|_| C64::new(r.normal(), r.normal())).collect()
    }

    fn max_err(a: &[C64], b: &[C64]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (*x - *y).abs())
            .fold(0.0, f64::max)
    }

    #[test]
    fn fft_matches_naive_pow2() {
        let planner = Planner::new();
        for n in [1usize, 2, 4, 8, 64, 256] {
            let x = rand_signal(n, n as u64);
            let want = dft_naive(&x, Dir::Forward);
            let mut got = x.clone();
            planner.fft(&mut got);
            assert!(max_err(&got, &want) < 1e-9 * n as f64, "n={n}");
        }
    }

    #[test]
    fn fft_matches_naive_arbitrary() {
        let planner = Planner::new();
        for n in [3usize, 5, 6, 12, 100, 360, 1000] {
            let x = rand_signal(n, 100 + n as u64);
            let want = dft_naive(&x, Dir::Forward);
            let mut got = x.clone();
            planner.fft(&mut got);
            assert!(max_err(&got, &want) < 1e-8 * n as f64, "n={n}");
        }
    }

    #[test]
    fn ifft_inverts_fft() {
        let planner = Planner::new();
        for n in [4usize, 7, 25, 64, 100, 25_600 / 100] {
            let x = rand_signal(n, 7 + n as u64);
            let mut y = x.clone();
            planner.fft(&mut y);
            planner.ifft(&mut y);
            assert!(max_err(&y, &x) < 1e-9 * n as f64, "n={n}");
        }
    }

    #[test]
    fn parseval() {
        let planner = Planner::new();
        let n = 128;
        let x = rand_signal(n, 5);
        let e_time: f64 = x.iter().map(|c| c.abs() * c.abs()).sum();
        let mut y = x.clone();
        planner.fft(&mut y);
        let e_freq: f64 = y.iter().map(|c| c.abs() * c.abs()).sum::<f64>() / n as f64;
        assert!((e_time - e_freq).abs() < 1e-8 * e_time);
    }

    #[test]
    fn paper_sizes() {
        // d = 25,600 and 51,200 are not powers of two; Bluestein must handle
        // them (spot-check round-trip at reduced cost via 25600/10).
        let planner = Planner::new();
        let n = 2560;
        let x = rand_signal(n, 9);
        let mut y = x.clone();
        planner.fft(&mut y);
        planner.ifft(&mut y);
        assert!(max_err(&y, &x) < 1e-8);
    }

    #[test]
    fn plan_cache_reuses() {
        let planner = Planner::new();
        let p1 = planner.plan(64);
        let p2 = planner.plan(64);
        assert!(Arc::ptr_eq(&p1, &p2));
    }

    #[test]
    fn cloned_planner_shares_cache() {
        let planner = Planner::new();
        let p1 = planner.plan(48);
        let p2 = planner.clone().plan(48);
        assert!(Arc::ptr_eq(&p1, &p2));
    }

    #[test]
    fn shared_plan_transforms_concurrently() {
        // One Bluestein plan, many threads, caller-owned scratch each:
        // results must match the single-threaded transform exactly.
        let planner = Planner::new();
        let n = 100;
        let plan = planner.plan(n);
        let x = rand_signal(n, 77);
        let mut want = x.clone();
        plan.transform(&mut want, Dir::Forward);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    let mut scratch = FftScratch::new();
                    let mut got = x.clone();
                    plan.transform_with(&mut got, Dir::Forward, &mut scratch);
                    for (a, b) in got.iter().zip(&want) {
                        assert!((*a - *b).abs() == 0.0);
                    }
                });
            }
        });
    }
}
