//! From-scratch FFT substrate.
//!
//! The paper's entire speed claim rests on computing circulant projections
//! via FFT: `Rx = r ⊛ x = IFFT(FFT(r) ∘ FFT(x))` in O(d log d). The offline
//! vendor set has no FFT crate, so this module implements:
//!
//! * [`complex::C64`] — minimal complex arithmetic,
//! * [`radix2`] — iterative in-place Cooley–Tukey for power-of-two sizes,
//! * [`bluestein`] — Bluestein's chirp-z algorithm for arbitrary sizes
//!   (the paper's datasets are d = 25,600 / 51,200 — *not* powers of two),
//! * [`real`] — real-input forward/inverse wrappers (half-spectrum),
//! * [`Planner`] — caches twiddles/chirp tables per size.

pub mod complex;
pub mod radix2;
pub mod bluestein;
pub mod real;
pub mod realpack;

pub use complex::C64;

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// Direction of a transform.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    Forward,
    Inverse,
}

/// A prepared FFT plan for one size (twiddle tables precomputed; forward
/// and inverse tables kept separately so the butterfly loop never branches
/// on direction — perf pass, see EXPERIMENTS.md §Perf).
pub struct Plan {
    pub n: usize,
    kind: PlanKind,
}

enum PlanKind {
    Radix2 {
        twiddles: Vec<C64>,
        twiddles_inv: Vec<C64>,
    },
    Bluestein {
        m: usize,
        chirp: Vec<C64>,          // w_k = exp(-i π k² / n)
        bfft: Vec<C64>,           // FFT_m of the chirp filter b
        m_twiddles: Vec<C64>,     // radix-2 twiddles for size m
        m_twiddles_inv: Vec<C64>, // conjugated table
        scratch: RefCell<Vec<C64>>, // reusable length-m work buffer
    },
}

impl Plan {
    /// Build a plan for length-n transforms (any n ≥ 1).
    pub fn new(n: usize) -> Plan {
        assert!(n >= 1);
        if n.is_power_of_two() {
            Plan {
                n,
                kind: PlanKind::Radix2 {
                    twiddles: radix2::make_twiddles(n),
                    twiddles_inv: radix2::make_twiddles_inv(n),
                },
            }
        } else {
            let m = (2 * n - 1).next_power_of_two();
            let chirp = bluestein::make_chirp(n);
            let bfft = bluestein::make_bfft(n, m, &chirp);
            Plan {
                n,
                kind: PlanKind::Bluestein {
                    m,
                    chirp,
                    bfft,
                    m_twiddles: radix2::make_twiddles(m),
                    m_twiddles_inv: radix2::make_twiddles_inv(m),
                    scratch: RefCell::new(vec![C64::ZERO; m]),
                },
            }
        }
    }

    /// In-place transform of `buf` (len n). `Inverse` includes the 1/n scale,
    /// matching numpy's `ifft` convention.
    pub fn transform(&self, buf: &mut [C64], dir: Dir) {
        assert_eq!(buf.len(), self.n);
        match &self.kind {
            PlanKind::Radix2 {
                twiddles,
                twiddles_inv,
            } => match dir {
                Dir::Forward => radix2::fft_inplace_tw(buf, twiddles),
                Dir::Inverse => {
                    radix2::fft_inplace_tw(buf, twiddles_inv);
                    let s = 1.0 / self.n as f64;
                    for v in buf.iter_mut() {
                        *v = v.scale(s);
                    }
                }
            },
            PlanKind::Bluestein {
                m,
                chirp,
                bfft,
                m_twiddles,
                m_twiddles_inv,
                scratch,
            } => {
                let mut work = scratch.borrow_mut();
                bluestein::transform_with_scratch(
                    buf,
                    self.n,
                    *m,
                    chirp,
                    bfft,
                    m_twiddles,
                    m_twiddles_inv,
                    &mut work,
                    dir,
                );
            }
        }
    }
}

/// Size-keyed plan cache. Cloning is cheap (Rc).
#[derive(Clone, Default)]
pub struct Planner {
    plans: Rc<RefCell<HashMap<usize, Rc<Plan>>>>,
}

impl Planner {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn plan(&self, n: usize) -> Rc<Plan> {
        let mut map = self.plans.borrow_mut();
        map.entry(n).or_insert_with(|| Rc::new(Plan::new(n))).clone()
    }

    /// Forward FFT of a complex buffer (in place).
    pub fn fft(&self, buf: &mut [C64]) {
        self.plan(buf.len()).transform(buf, Dir::Forward);
    }

    /// Inverse FFT (with 1/n scale) of a complex buffer (in place).
    pub fn ifft(&self, buf: &mut [C64]) {
        self.plan(buf.len()).transform(buf, Dir::Inverse);
    }
}

/// Naive O(n²) DFT — the test oracle for every fast path in this module.
pub fn dft_naive(x: &[C64], dir: Dir) -> Vec<C64> {
    let n = x.len();
    let sign = match dir {
        Dir::Forward => -1.0,
        Dir::Inverse => 1.0,
    };
    let mut out = vec![C64::ZERO; n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = C64::ZERO;
        for (m, xm) in x.iter().enumerate() {
            let ang = sign * 2.0 * std::f64::consts::PI * (k * m % n) as f64 / n as f64;
            acc = acc + *xm * C64::new(ang.cos(), ang.sin());
        }
        *o = acc;
    }
    if dir == Dir::Inverse {
        for o in out.iter_mut() {
            *o = o.scale(1.0 / n as f64);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_signal(n: usize, seed: u64) -> Vec<C64> {
        let mut r = Pcg64::new(seed);
        (0..n).map(|_| C64::new(r.normal(), r.normal())).collect()
    }

    fn max_err(a: &[C64], b: &[C64]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (*x - *y).abs())
            .fold(0.0, f64::max)
    }

    #[test]
    fn fft_matches_naive_pow2() {
        let planner = Planner::new();
        for n in [1usize, 2, 4, 8, 64, 256] {
            let x = rand_signal(n, n as u64);
            let want = dft_naive(&x, Dir::Forward);
            let mut got = x.clone();
            planner.fft(&mut got);
            assert!(max_err(&got, &want) < 1e-9 * n as f64, "n={n}");
        }
    }

    #[test]
    fn fft_matches_naive_arbitrary() {
        let planner = Planner::new();
        for n in [3usize, 5, 6, 12, 100, 360, 1000] {
            let x = rand_signal(n, 100 + n as u64);
            let want = dft_naive(&x, Dir::Forward);
            let mut got = x.clone();
            planner.fft(&mut got);
            assert!(max_err(&got, &want) < 1e-8 * n as f64, "n={n}");
        }
    }

    #[test]
    fn ifft_inverts_fft() {
        let planner = Planner::new();
        for n in [4usize, 7, 25, 64, 100, 25_600 / 100] {
            let x = rand_signal(n, 7 + n as u64);
            let mut y = x.clone();
            planner.fft(&mut y);
            planner.ifft(&mut y);
            assert!(max_err(&y, &x) < 1e-9 * n as f64, "n={n}");
        }
    }

    #[test]
    fn parseval() {
        let planner = Planner::new();
        let n = 128;
        let x = rand_signal(n, 5);
        let e_time: f64 = x.iter().map(|c| c.abs() * c.abs()).sum();
        let mut y = x.clone();
        planner.fft(&mut y);
        let e_freq: f64 = y.iter().map(|c| c.abs() * c.abs()).sum::<f64>() / n as f64;
        assert!((e_time - e_freq).abs() < 1e-8 * e_time);
    }

    #[test]
    fn paper_sizes() {
        // d = 25,600 and 51,200 are not powers of two; Bluestein must handle
        // them (spot-check round-trip at reduced cost via 25600/10).
        let planner = Planner::new();
        let n = 2560;
        let x = rand_signal(n, 9);
        let mut y = x.clone();
        planner.fft(&mut y);
        planner.ifft(&mut y);
        assert!(max_err(&y, &x) < 1e-8);
    }

    #[test]
    fn plan_cache_reuses() {
        let planner = Planner::new();
        let p1 = planner.plan(64);
        let p2 = planner.plan(64);
        assert!(Rc::ptr_eq(&p1, &p2));
    }
}
