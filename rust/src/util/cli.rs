//! Declarative command-line flag parser (no `clap` in the offline vendor
//! set). `--flag value`, `--flag=value` and boolean `--flag` forms, with
//! typed accessors, defaults and auto-generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments: positionals + flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    spec: Vec<(String, String, String)>, // name, default, help
}

impl Args {
    /// Parse raw argv (excluding program name / subcommand).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut a = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.flags.insert(rest.to_string(), v);
                } else {
                    a.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    /// Register a flag for usage text; returns self for chaining.
    pub fn describe(mut self, name: &str, default: &str, help: &str) -> Self {
        self.spec
            .push((name.to_string(), default.to_string(), help.to_string()));
        self
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
    pub fn str(&self, name: &str, default: &str) -> String {
        self.flags
            .get(name)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }
    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
    pub fn f32(&self, name: &str, default: f32) -> f32 {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
    pub fn bool(&self, name: &str, default: bool) -> bool {
        match self.flags.get(name).map(|s| s.as_str()) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) | None => default,
        }
    }
    /// Comma-separated usize list.
    pub fn usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.flags.get(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .filter_map(|t| t.trim().parse().ok())
                .collect(),
        }
    }

    /// Render usage text from `describe` entries.
    pub fn usage(&self, cmd: &str) -> String {
        let mut s = format!("usage: cbe {cmd} [flags]\n");
        for (name, default, help) in &self.spec {
            s.push_str(&format!("  --{name:<18} {help} (default: {default})\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn flag_forms() {
        // NOTE: a bare `--flag` consumes the following token as its value
        // unless it is another flag, so positionals go first (or use `=`).
        let a = parse(&["pos1", "--dim", "512", "--bits=256", "--verbose"]);
        assert_eq!(a.usize("dim", 0), 512);
        assert_eq!(a.usize("bits", 0), 256);
        assert!(a.bool("verbose", false));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize("dim", 64), 64);
        assert_eq!(a.str("name", "x"), "x");
        assert!(!a.bool("verbose", false));
    }

    #[test]
    fn lists() {
        let a = parse(&["--bits", "64,128,256"]);
        assert_eq!(a.usize_list("bits", &[]), vec![64, 128, 256]);
        assert_eq!(a.usize_list("other", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse(&["--lr", "-0.5"]);
        assert_eq!(a.f32("lr", 0.0), -0.5);
    }
}
