//! ASCII table renderer for experiment reports (paper table/figure output).

/// A simple left-padded ASCII table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.chars().count());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        let sep: String = w
            .iter()
            .map(|wi| format!("+{}", "-".repeat(wi + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("| {:<width$} ", c, width = w[i]));
            }
            s.push('|');
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

/// Format a float with engineering-style precision matched to magnitude.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 1000.0 {
        format!("{:.2e}", ms)
    } else if ms >= 10.0 {
        format!("{:.1}", ms)
    } else {
        format!("{:.3}", ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["d", "time"]);
        t.row(vec!["32768".into(), "1.11".into()]);
        t.row(vec!["1048576".into(), "37.7".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("| 32768   |"));
        let widths: Vec<usize> = s.lines().map(|l| l.chars().count()).collect();
        // all body lines equal width
        assert!(widths[1..].iter().all(|w| *w == widths[1]));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
