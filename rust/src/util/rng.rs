//! PCG64 random number generator + gaussian sampling.
//!
//! Built from scratch (no `rand` crate in the offline vendor set). PCG-XSL-RR
//! 128/64 — the same generator family numpy uses by default — plus
//! Box–Muller normals, shuffles and subset sampling. Deterministic by seed so
//! every experiment in the repo is reproducible.

/// PCG-XSL-RR 128/64 pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream id (odd-ified internally).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (one value; pair partner discarded for
    /// simplicity — the generator is cheap).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a slice with i.i.d. N(0, sigma²) f32 values.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * sigma;
        }
    }

    /// Vector of n i.i.d. standard normal f32 values.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0f32; n];
        self.fill_normal(&mut v, 1.0);
        v
    }

    /// Random ±1 signs (the paper's diagonal matrix D).
    pub fn sign_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
            .collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// k distinct indices sampled without replacement from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher–Yates: only the first k positions matter.
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_range() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg64::new(3);
        for n in [1u64, 2, 7, 1000] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn sign_vec_balanced() {
        let mut r = Pcg64::new(13);
        let s = r.sign_vec(100_000);
        let pos = s.iter().filter(|v| **v > 0.0).count();
        assert!((pos as f64 / 1e5 - 0.5).abs() < 0.01);
        assert!(s.iter().all(|v| v.abs() == 1.0));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(17);
        let idx = r.sample_indices(100, 50);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 50);
        assert!(idx.iter().all(|i| *i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(19);
        let mut v: Vec<usize> = (0..64).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..64).collect::<Vec<_>>());
    }
}
