//! Minimal JSON parser/writer (no `serde` in the offline vendor set).
//!
//! Supports the full JSON grammar minus exotic escapes; enough for the AOT
//! artifact manifest, experiment reports and service configs. Numbers are
//! held as f64; integers round-trip exactly up to 2^53.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `None` on non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub at: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            at: self.i,
        }
    }
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn reject_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_structured() {
        let v = Json::obj(vec![
            ("name", Json::str("cbe_encode")),
            ("dims", Json::Arr(vec![Json::num(4.0), Json::num(64.0)])),
        ]);
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }
}
