//! Foundational utilities built from scratch (the offline vendor set has no
//! `rand`/`serde`/`clap`, so these are first-class substrates of the repo).

pub mod rng;
pub mod json;
pub mod cli;
pub mod table;
pub mod timer;

/// ℓ2-normalize a vector in place; returns the original norm.
pub fn l2_normalize(v: &mut [f32]) -> f32 {
    let n = (v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>()).sqrt() as f32;
    if n > 0.0 {
        let inv = 1.0 / n;
        for x in v.iter_mut() {
            *x *= inv;
        }
    }
    n
}

/// Dot product of two f32 slices (f64 accumulator for stability).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f64;
    for i in 0..a.len() {
        acc += a[i] as f64 * b[i] as f64;
    }
    acc as f32
}

/// Angle between two vectors, in radians.
pub fn angle(a: &[f32], b: &[f32]) -> f32 {
    let na = dot(a, a).sqrt();
    let nb = dot(b, b).sqrt();
    let c = (dot(a, b) / (na * nb)).clamp(-1.0, 1.0);
    c.acos()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l2_normalize_unit_norm() {
        let mut v = vec![3.0, 4.0];
        let n = l2_normalize(&mut v);
        assert!((n - 5.0).abs() < 1e-6);
        assert!((dot(&v, &v) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn l2_normalize_zero_vector_is_noop() {
        let mut v = vec![0.0; 8];
        let n = l2_normalize(&mut v);
        assert_eq!(n, 0.0);
        assert!(v.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn angle_orthogonal() {
        let a = vec![1.0, 0.0];
        let b = vec![0.0, 2.0];
        assert!((angle(&a, &b) - std::f32::consts::FRAC_PI_2).abs() < 1e-6);
    }

    #[test]
    fn angle_parallel() {
        let a = vec![1.0, 1.0, 0.5];
        assert!(angle(&a, &a).abs() < 1e-3);
    }
}
