//! Wall-clock timing helpers used across the bench harness and coordinator
//! metrics.

use std::time::Instant;

/// Time a closure, returning (result, elapsed milliseconds).
pub fn time_ms<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64() * 1e3)
}

/// Online mean/min/max/percentile accumulator over f64 samples.
#[derive(Debug, Default, Clone)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }
    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }
    pub fn std(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (self.xs.len() - 1) as f64)
            .sqrt()
    }
    pub fn min(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }
    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
    /// p in [0,100]; nearest-rank on the sorted samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut s = self.xs.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
        s[rank.min(s.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let mut s = Samples::default();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.percentile(50.0), 3.0);
        assert!((s.std() - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn time_ms_positive() {
        let (v, ms) = time_ms(|| (0..10_000).sum::<u64>());
        assert_eq!(v, 49_995_000);
        assert!(ms >= 0.0);
    }
}
