//! Dynamic batcher: accumulate encode requests up to the artifact batch
//! size or a deadline, whichever first — the same size-or-timeout policy
//! serving systems (vLLM, Triton) use for GPU batch formation.

use super::request::EncodeRequest;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Hard batch size (the compiled artifact's leading dimension).
    pub max_batch: usize,
    /// Max time the oldest request may wait before the batch launches.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Accumulates requests; `pop_ready` hands back a full or expired batch.
pub struct Batcher {
    cfg: BatcherConfig,
    pending: Vec<EncodeRequest>,
    oldest: Option<Instant>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher {
            cfg,
            pending: Vec::new(),
            oldest: None,
        }
    }

    pub fn push(&mut self, req: EncodeRequest) {
        if self.pending.is_empty() {
            self.oldest = Some(req.t_enqueue);
        }
        self.pending.push(req);
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// True when a batch should launch now.
    pub fn ready(&self, now: Instant) -> bool {
        if self.pending.is_empty() {
            return false;
        }
        if self.pending.len() >= self.cfg.max_batch {
            return true;
        }
        match self.oldest {
            Some(t) => now.duration_since(t) >= self.cfg.max_wait,
            None => false,
        }
    }

    /// Remove and return up to max_batch requests (oldest first) if ready.
    pub fn pop_ready(&mut self, now: Instant) -> Option<Vec<EncodeRequest>> {
        if !self.ready(now) {
            return None;
        }
        let take = self.pending.len().min(self.cfg.max_batch);
        let batch: Vec<EncodeRequest> = self.pending.drain(..take).collect();
        self.oldest = self.pending.first().map(|r| r.t_enqueue);
        Some(batch)
    }

    /// Remove and return every pending request (oldest first), ignoring
    /// deadline and batch-size policy — the explicit flush used on
    /// shutdown/disconnect instead of faking an expired deadline.
    pub fn drain_all(&mut self) -> Vec<EncodeRequest> {
        self.oldest = None;
        std::mem::take(&mut self.pending)
    }

    /// Time until the current oldest request expires (for sleep pacing).
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.oldest.map(|t| {
            let elapsed = now.duration_since(t);
            self.cfg.max_wait.saturating_sub(elapsed)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(d: usize) -> EncodeRequest {
        EncodeRequest::new(vec![0.0; d], d).0
    }

    #[test]
    fn fills_to_max_batch() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(60),
        });
        let now = Instant::now();
        for _ in 0..3 {
            b.push(req(8));
        }
        assert!(!b.ready(now));
        b.push(req(8));
        assert!(b.ready(now));
        let batch = b.pop_ready(now).unwrap();
        assert_eq!(batch.len(), 4);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_fires_partial_batch() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_millis(1),
        });
        b.push(req(8));
        let later = Instant::now() + Duration::from_millis(5);
        assert!(b.ready(later));
        assert_eq!(b.pop_ready(later).unwrap().len(), 1);
    }

    #[test]
    fn drain_all_ignores_policy() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(60),
        });
        for _ in 0..5 {
            b.push(req(4));
        }
        // Not ready by size-or-deadline policy beyond one full batch, but
        // drain_all flushes everything at once.
        assert_eq!(b.drain_all().len(), 5);
        assert!(b.is_empty());
        assert!(b.time_to_deadline(Instant::now()).is_none());
    }

    #[test]
    fn overflow_keeps_remainder() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(60),
        });
        for _ in 0..5 {
            b.push(req(4));
        }
        let now = Instant::now();
        assert_eq!(b.pop_ready(now).unwrap().len(), 2);
        assert_eq!(b.len(), 3);
    }
}
