//! Service metrics: throughput counters + a lock-free fixed-bucket
//! log-scale latency histogram ([`crate::obs::Histogram`]).
//!
//! Everything here is bounded and wait-free on the request path: the
//! histogram is ~15 KiB of atomic buckets however many requests the
//! service has served (the PR-6 bugfix — latencies used to pile up in an
//! unbounded `Mutex<Vec<u64>>` that was clone-and-sorted on every read),
//! and the counters are relaxed atomics. [`Metrics::snapshot`] merges
//! this per-service record with the process-global stage recorder into a
//! [`StatsSnapshot`] for the `ControlRequest::Stats` control plane.

use crate::obs::{self, Histogram, ProjectionInfo, StageStats, StatsSnapshot};
use std::sync::atomic::{AtomicU64, Ordering};

/// Per-service counters + end-to-end request-latency histogram (µs).
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Completed `Retrain` hot-swaps.
    pub retrains: AtomicU64,
    /// Searches refused with [`crate::error::CbeError::StaleIndex`].
    pub stale_rejections: AtomicU64,
    /// Requests rejected at admission with
    /// [`crate::error::CbeError::Overloaded`] (bounded queue full).
    pub overloads: AtomicU64,
    latency_us: Histogram,
}

impl Metrics {
    pub fn record_request(&self, latency_us: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.latency_us.record(latency_us);
    }

    pub fn record_batch(&self, size: usize, capacity: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        // A shutdown flush may exceed the nominal capacity; clamp rather
        // than underflow.
        self.padded_slots
            .fetch_add(capacity.saturating_sub(size) as u64, Ordering::Relaxed);
    }

    pub fn record_retrain(&self) {
        self.retrains.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_stale_rejection(&self) {
        self.stale_rejections.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_overload(&self) {
        self.overloads.fetch_add(1, Ordering::Relaxed);
    }

    pub fn request_count(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn batch_count(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn retrain_count(&self) -> u64 {
        self.retrains.load(Ordering::Relaxed)
    }

    pub fn stale_rejection_count(&self) -> u64 {
        self.stale_rejections.load(Ordering::Relaxed)
    }

    pub fn overload_count(&self) -> u64 {
        self.overloads.load(Ordering::Relaxed)
    }

    /// The full end-to-end request-latency histogram (µs buckets).
    pub fn latency_histogram(&self) -> &Histogram {
        &self.latency_us
    }

    /// (p50, p99, max) request latency in microseconds. p50/p99 carry the
    /// histogram's ≤3.125% bucket error; max is exact.
    pub fn latency_percentiles(&self) -> (u64, u64, u64) {
        (
            self.latency_us.p(0.50),
            self.latency_us.p(0.99),
            self.latency_us.max(),
        )
    }

    /// Mean occupancy of launched batches (1.0 = always full).
    pub fn batch_occupancy(&self, capacity: usize) -> f64 {
        let batches = self.batch_count();
        if batches == 0 {
            return 0.0;
        }
        let padded = self.padded_slots.load(Ordering::Relaxed) as f64;
        1.0 - padded / (batches as f64 * capacity as f64)
    }

    pub fn summary(&self, capacity: usize) -> String {
        let (p50, p99, max) = self.latency_percentiles();
        format!(
            "requests={} batches={} occupancy={:.2} latency_us p50={} p99={} max={}",
            self.request_count(),
            self.batch_count(),
            self.batch_occupancy(capacity),
            p50,
            p99,
            max
        )
    }

    /// Build a [`StatsSnapshot`]: this service's counters and latency
    /// histogram, plus the process-global per-stage recorder.
    /// `projection` identifies the live model (spec/variant/blocks/bits —
    /// the event loop resolves it from the registry per scrape, so a
    /// hot-swap shows up in the very next snapshot).
    pub fn snapshot(
        &self,
        capacity: usize,
        model_version: u64,
        projection: ProjectionInfo,
    ) -> StatsSnapshot {
        StatsSnapshot {
            model_version,
            projection,
            requests: self.request_count(),
            batches: self.batch_count(),
            batch_occupancy: self.batch_occupancy(capacity),
            retrains: self.retrain_count(),
            stale_rejections: self.stale_rejection_count(),
            overloads: self.overload_count(),
            latency: StageStats::from_histogram(&self.latency_us),
            ..Default::default()
        }
        .with_stages(obs::global())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_occupancy() {
        let m = Metrics::default();
        for us in [100u64, 200, 300, 400, 1000] {
            m.record_request(us);
        }
        m.record_batch(3, 4);
        m.record_batch(4, 4);
        let (p50, p99, max) = m.latency_percentiles();
        // p50 reports the bucket upper edge: within +3.125% of the true
        // median (the old Vec-backed path was exact but unbounded).
        assert!(p50 >= 300 && p50 as f64 <= 300.0 * 1.03125, "p50={p50}");
        assert_eq!(max, 1000, "max is exact via fetch_max");
        assert!(p99 >= 400);
        assert!((m.batch_occupancy(4) - 7.0 / 8.0).abs() < 1e-9);
        assert!(m.summary(4).contains("requests=5"));
    }

    #[test]
    fn retrain_and_stale_counters() {
        let m = Metrics::default();
        m.record_retrain();
        m.record_stale_rejection();
        m.record_stale_rejection();
        m.record_overload();
        m.record_overload();
        m.record_overload();
        assert_eq!(m.retrain_count(), 1);
        assert_eq!(m.stale_rejection_count(), 2);
        assert_eq!(m.overload_count(), 3);
        let info = ProjectionInfo {
            spec: "circ".to_string(),
            variant: "circ",
            blocks: 1,
            bits: 32,
        };
        let snap = m.snapshot(4, 3, info);
        assert_eq!(snap.retrains, 1);
        assert_eq!(snap.stale_rejections, 2);
        assert_eq!(snap.overloads, 3);
        assert_eq!(snap.model_version, 3);
        assert_eq!(snap.projection.spec, "circ");
        assert_eq!(snap.projection.bits, 32);
    }

    #[test]
    fn snapshot_carries_the_latency_histogram() {
        let m = Metrics::default();
        for us in [10u64, 20, 5000] {
            m.record_request(us);
        }
        let snap = m.snapshot(8, 0, ProjectionInfo::default());
        assert_eq!(snap.requests, 3);
        assert_eq!(snap.latency.count, 3);
        assert_eq!(snap.latency.max_us, 5000);
        assert!(snap.latency.p999_us >= snap.latency.p50_us);
    }
}
