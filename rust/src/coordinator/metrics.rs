//! Service metrics: counters + latency histogram (log-scale buckets).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Fixed log-scale latency histogram (µs buckets) + counters.
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
}

impl Metrics {
    pub fn record_request(&self, latency_us: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.latencies_us.lock().unwrap().push(latency_us);
    }

    pub fn record_batch(&self, size: usize, capacity: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        // A shutdown flush may exceed the nominal capacity; clamp rather
        // than underflow.
        self.padded_slots
            .fetch_add(capacity.saturating_sub(size) as u64, Ordering::Relaxed);
    }

    pub fn request_count(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn batch_count(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// (p50, p99, max) request latency in microseconds.
    pub fn latency_percentiles(&self) -> (u64, u64, u64) {
        let mut v = self.latencies_us.lock().unwrap().clone();
        if v.is_empty() {
            return (0, 0, 0);
        }
        v.sort_unstable();
        let pick = |p: f64| v[((p * (v.len() - 1) as f64).round() as usize).min(v.len() - 1)];
        (pick(0.50), pick(0.99), *v.last().unwrap())
    }

    /// Mean occupancy of launched batches (1.0 = always full).
    pub fn batch_occupancy(&self, capacity: usize) -> f64 {
        let batches = self.batch_count();
        if batches == 0 {
            return 0.0;
        }
        let padded = self.padded_slots.load(Ordering::Relaxed) as f64;
        1.0 - padded / (batches as f64 * capacity as f64)
    }

    pub fn summary(&self, capacity: usize) -> String {
        let (p50, p99, max) = self.latency_percentiles();
        format!(
            "requests={} batches={} occupancy={:.2} latency_us p50={} p99={} max={}",
            self.request_count(),
            self.batch_count(),
            self.batch_occupancy(capacity),
            p50,
            p99,
            max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_occupancy() {
        let m = Metrics::default();
        for us in [100u64, 200, 300, 400, 1000] {
            m.record_request(us);
        }
        m.record_batch(3, 4);
        m.record_batch(4, 4);
        let (p50, p99, max) = m.latency_percentiles();
        assert_eq!(p50, 300);
        assert_eq!(max, 1000);
        assert!(p99 >= 400);
        assert!((m.batch_occupancy(4) - 7.0 / 8.0).abs() < 1e-9);
        assert!(m.summary(4).contains("requests=5"));
    }
}
