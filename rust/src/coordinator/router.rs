//! Router: maps a request's (kind, feature dim) to a compiled artifact,
//! and routes retrieval to the right index backend for a corpus size.

use crate::index::IndexBackend;
use crate::runtime::Manifest;
use anyhow::{anyhow, Result};

/// Routing table built from the artifact manifest.
pub struct Router {
    routes: Vec<RouteEntry>,
}

#[derive(Clone, Debug)]
pub struct RouteEntry {
    pub kind: String,
    pub d: usize,
    pub batch: usize,
    pub artifact: String,
}

impl Router {
    pub fn from_manifest(m: &Manifest) -> Router {
        Router {
            routes: m
                .artifacts
                .iter()
                .map(|a| RouteEntry {
                    kind: a.kind.clone(),
                    d: a.d,
                    batch: a.batch,
                    artifact: a.name.clone(),
                })
                .collect(),
        }
    }

    /// Exact route for (kind, d).
    pub fn route(&self, kind: &str, d: usize) -> Result<&RouteEntry> {
        self.routes
            .iter()
            .find(|r| r.kind == kind && r.d == d)
            .ok_or_else(|| anyhow!("no artifact for kind={kind} d={d}; available dims: {:?}",
                self.dims(kind)))
    }

    /// Retrieval-side routing: pick the index backend for a corpus of `n`
    /// codes of `bits` bits. This is what `ServiceConfig::index = Auto`
    /// resolves through, so the serving path and the experiments agree on
    /// when a linear scan stops being the right answer.
    pub fn pick_index(n: usize, bits: usize) -> IndexBackend {
        IndexBackend::auto_for(n, bits)
    }

    /// Dims served for a kind.
    pub fn dims(&self, kind: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .routes
            .iter()
            .filter(|r| r.kind == kind)
            .map(|r| r.d)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ArtifactMeta;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        let mk = |kind: &str, d: usize| ArtifactMeta {
            name: format!("{kind}_d{d}"),
            kind: kind.into(),
            d,
            batch: 8,
            k: None,
            inputs: vec![],
            path: PathBuf::new(),
        };
        Manifest {
            artifacts: vec![mk("cbe_encode", 64), mk("cbe_encode", 128), mk("lsh_encode", 64)],
        }
    }

    #[test]
    fn routes_exact() {
        let r = Router::from_manifest(&manifest());
        assert_eq!(r.route("cbe_encode", 128).unwrap().artifact, "cbe_encode_d128");
        assert!(r.route("cbe_encode", 99).is_err());
        assert_eq!(r.dims("cbe_encode"), vec![64, 128]);
    }

    #[test]
    fn index_routing_scales_with_corpus() {
        assert_eq!(Router::pick_index(1_000, 256), IndexBackend::Linear);
        assert_eq!(
            Router::pick_index(50_000, 256),
            IndexBackend::Mih { m: None }
        );
        assert!(matches!(
            Router::pick_index(2_000_000, 256),
            IndexBackend::ShardedMih { .. }
        ));
    }
}
