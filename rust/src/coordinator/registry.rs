//! [`ModelRegistry`]: the hot-swappable model slot behind the serving
//! path.
//!
//! The event loop used to hold the `Arc<CirculantProjection>` directly,
//! which froze the model for the service's lifetime — swapping in a
//! freshly trained projection meant a restart. The registry decouples
//! model *identity* from model *lifetime* (and, since the projection
//! layer generalized, holds a [`CbeModel`] so stacked and downsampled
//! variants hot-swap exactly like the single-block circulant):
//!
//! * [`ModelRegistry::current`] hands out a clone of the active `Arc` —
//!   a read-lock held only for the refcount bump (no allocation, no
//!   waiting on trainers).
//! * [`ModelRegistry::swap`] atomically replaces the active `Arc` and
//!   bumps the version counter. Nothing in flight is touched: any batch
//!   that already resolved its `Arc` keeps encoding against the old
//!   model to completion and the old projection is freed when its last
//!   holder drops it. The event loop resolves [`ModelRegistry::current`]
//!   once per batch, so a swap lands between batches, never inside one.
//!
//! This is the hot-swap contract (see ARCHITECTURE.md "Training
//! pipeline"): **a batch is encoded by exactly one model version**, and
//! a `Retrain` can never fail or corrupt an in-flight request — the
//! worst case is a reply computed against the model that was active
//! when its batch formed.

use crate::projections::CbeModel;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// A versioned, atomically swappable slot holding the active projection
/// model. `Send + Sync`; share behind an `Arc`.
pub struct ModelRegistry {
    active: RwLock<Arc<CbeModel>>,
    version: AtomicU64,
}

impl ModelRegistry {
    /// Register the initial model as version 0.
    pub fn new(model: CbeModel) -> ModelRegistry {
        ModelRegistry {
            active: RwLock::new(Arc::new(model)),
            version: AtomicU64::new(0),
        }
    }

    /// The active model. Cheap (one refcount bump under a read lock);
    /// callers that encode a batch resolve this once and hold the `Arc`
    /// for the whole batch.
    pub fn current(&self) -> Arc<CbeModel> {
        Arc::clone(&self.active.read().expect("model registry poisoned"))
    }

    /// The active model *and* its version, read under one read lock so
    /// the pair is always consistent (swaps publish the version bump
    /// while still holding the write lock). This is what index builds
    /// stamp: resolving `current()` and `version()` separately could
    /// race a swap and stamp a new version onto codes encoded by the
    /// old model.
    pub fn current_versioned(&self) -> (Arc<CbeModel>, u64) {
        let slot = self.active.read().expect("model registry poisoned");
        (Arc::clone(&slot), self.version.load(Ordering::SeqCst))
    }

    /// Atomically install a new model and return its version. The model
    /// *shape* — variant, input dimension, code-length cap — is pinned at
    /// registration: a model of a different shape would silently break
    /// every queued request, so that's a panic, not a swap.
    pub fn swap(&self, model: CbeModel) -> u64 {
        let mut slot = self.active.write().expect("model registry poisoned");
        assert!(
            model.shape_matches(&slot),
            "hot-swap must preserve the model shape: {} d={} max_bits={} -> {} d={} max_bits={}",
            slot.variant(),
            slot.d(),
            slot.max_bits(),
            model.variant(),
            model.d(),
            model.max_bits(),
        );
        *slot = Arc::new(model);
        // Publish the bump while still holding the write lock so
        // version() can never run ahead of current().
        self.version.fetch_add(1, Ordering::SeqCst) + 1
    }

    /// Monotone swap counter (0 = the model the service started with).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::SeqCst)
    }
}

// The registry must stay shareable across the event loop, retrain
// threads and callers.
const _: () = {
    #[allow(dead_code)]
    fn assert_send_sync<T: Send + Sync>() {}
    #[allow(dead_code)]
    fn check() {
        assert_send_sync::<ModelRegistry>();
    }
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::Planner;
    use crate::projections::{CirculantProjection, ProjectionSpec};
    use crate::util::rng::Pcg64;

    fn proj(d: usize, seed: u64) -> CbeModel {
        let mut rng = Pcg64::new(seed);
        CbeModel::Circ(CirculantProjection::random(d, &mut rng, Planner::new()))
    }

    #[test]
    fn swap_bumps_version_and_replaces_model() {
        let reg = ModelRegistry::new(proj(16, 1));
        assert_eq!(reg.version(), 0);
        let before = reg.current();
        let (before2, v0) = reg.current_versioned();
        assert_eq!(v0, 0);
        assert!(Arc::ptr_eq(&before, &before2));
        let v = reg.swap(proj(16, 2));
        assert_eq!(v, 1);
        assert_eq!(reg.version(), 1);
        let (after2, v1) = reg.current_versioned();
        assert_eq!(v1, 1);
        let after = reg.current();
        assert!(Arc::ptr_eq(&after, &after2));
        assert!(!Arc::ptr_eq(&before, &after));
        // The old Arc is still alive and usable by in-flight holders.
        let x: Vec<f32> = (0..16).map(|i| i as f32 - 8.0).collect();
        let _ = before.encode(&x, 16);
    }

    #[test]
    #[should_panic]
    fn swap_rejects_dimension_change() {
        let reg = ModelRegistry::new(proj(16, 1));
        reg.swap(proj(32, 2));
    }

    #[test]
    #[should_panic]
    fn swap_rejects_variant_change() {
        let reg = ModelRegistry::new(proj(16, 1));
        let st = CbeModel::random(
            &ProjectionSpec::Stacked { blocks: Some(1) },
            16,
            16,
            2,
            Planner::new(),
        )
        .unwrap();
        reg.swap(st);
    }

    #[test]
    fn stacked_models_hot_swap_too() {
        let spec = ProjectionSpec::Stacked { blocks: Some(2) };
        let mk = |seed| CbeModel::random(&spec, 16, 32, seed, Planner::new()).unwrap();
        let reg = ModelRegistry::new(mk(1));
        let before = reg.current().fingerprint();
        assert_eq!(reg.swap(mk(2)), 1);
        assert_ne!(reg.current().fingerprint(), before);
    }

    #[test]
    fn concurrent_readers_see_a_full_model() {
        // Hammer current() while swapping: every resolved Arc must encode
        // self-consistently (no torn model state is even expressible —
        // the Arc swap is the only mutation — but the test pins the
        // lock discipline).
        let reg = Arc::new(ModelRegistry::new(proj(32, 3)));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        std::thread::scope(|scope| {
            for _ in 0..3 {
                let reg = Arc::clone(&reg);
                let stop = Arc::clone(&stop);
                scope.spawn(move || {
                    let x: Vec<f32> = (0..32).map(|i| (i as f32).sin()).collect();
                    while !stop.load(Ordering::Relaxed) {
                        let p = reg.current();
                        let code = p.encode(&x, 32);
                        assert_eq!(code.len(), 32);
                    }
                });
            }
            for s in 0..20u64 {
                reg.swap(proj(32, 100 + s));
            }
            stop.store(true, Ordering::Relaxed);
        });
        assert_eq!(reg.version(), 20);
    }
}
