//! [`EmbeddingService`]: the public serving facade.
//!
//! Owns the PJRT engine, the circulant model parameters (r, D), the
//! dynamic batcher and the retrieval index. A background worker thread
//! runs the event loop: drain requests → form batch → one PJRT execute →
//! scatter replies. The request path is pure Rust + compiled XLA.

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::request::{EncodeRequest, EncodeResponse};
use super::router::Router;
use crate::bits::index::Hit;
use crate::bits::BitCode;
use crate::index::{build_index, AnyIndex, IndexAny, IndexBackend};
use crate::runtime::Engine;
use anyhow::{anyhow, Result};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Feature dimension (must match a compiled artifact).
    pub d: usize,
    /// Bits returned per code (k ≤ d).
    pub bits: usize,
    /// Batching policy.
    pub batcher: BatcherConfig,
    /// Retrieval backend built by [`EmbeddingService::build_index`].
    /// `Auto` defers to [`Router::pick_index`] at corpus-build time.
    /// Parse from config with [`IndexBackend::from_spec`]
    /// (`auto | linear | mih[:m] | mih-sampled[:m] | sharded:<shards>[:m]`;
    /// the embedding_server example reads the spec from `CBE_INDEX`, the
    /// CLI from `--index`).
    pub index: IndexBackend,
}

/// The serving facade. Construct with [`EmbeddingService::start`], submit
/// with [`EmbeddingService::encode`] / [`EmbeddingService::encode_async`],
/// stop by dropping.
pub struct EmbeddingService {
    tx: mpsc::Sender<EncodeRequest>,
    pub metrics: Arc<Metrics>,
    cfg: ServiceConfig,
    stop: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl EmbeddingService {
    /// Start the service: load artifacts, spawn the batching event loop.
    /// `r` and `signs` are the circulant model parameters (e.g. from
    /// CBE-opt training or random for CBE-rand).
    pub fn start(
        artifacts_dir: &Path,
        cfg: ServiceConfig,
        r: Vec<f32>,
        signs: Vec<f32>,
    ) -> Result<EmbeddingService> {
        assert_eq!(r.len(), cfg.d);
        assert_eq!(signs.len(), cfg.d);
        assert!(cfg.bits <= cfg.d);

        let (tx, rx) = mpsc::channel::<EncodeRequest>();
        let metrics = Arc::new(Metrics::default());
        let stop = Arc::new(AtomicBool::new(false));

        // The PJRT client is not Send (Rc internals), so the engine is
        // constructed ON the worker thread; startup errors come back over
        // a one-shot channel.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<usize>>();
        let m2 = Arc::clone(&metrics);
        let stop2 = Arc::clone(&stop);
        let cfg2 = cfg.clone();
        let dir = artifacts_dir.to_path_buf();
        let worker = std::thread::spawn(move || {
            let setup = (|| -> Result<(Engine, String, usize)> {
                let mut engine = Engine::new(&dir)?;
                let router = Router::from_manifest(engine.manifest());
                let route = router.route("cbe_encode", cfg2.d)?.clone();
                engine.load(&route.artifact)?;
                Ok((engine, route.artifact, route.batch))
            })();
            match setup {
                Ok((engine, artifact, batch)) => {
                    let _ = ready_tx.send(Ok(batch));
                    event_loop(engine, artifact, batch, cfg2, r, signs, rx, m2, stop2);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            }
        });
        // Propagate startup failure.
        match ready_rx.recv() {
            Ok(Ok(_batch)) => {}
            Ok(Err(e)) => {
                let _ = worker.join();
                return Err(e);
            }
            Err(_) => return Err(anyhow!("service worker died during startup")),
        }

        Ok(EmbeddingService {
            tx,
            metrics,
            cfg,
            stop,
            worker: Some(worker),
        })
    }

    /// Fire-and-forget submit; returns the response receiver.
    pub fn encode_async(&self, features: Vec<f32>) -> Result<mpsc::Receiver<EncodeResponse>> {
        if features.len() != self.cfg.d {
            return Err(anyhow!(
                "feature dim {} != service dim {}",
                features.len(),
                self.cfg.d
            ));
        }
        let (req, rx) = EncodeRequest::new(features, self.cfg.bits);
        self.tx.send(req).map_err(|_| anyhow!("service stopped"))?;
        Ok(rx)
    }

    /// Blocking encode.
    pub fn encode(&self, features: Vec<f32>) -> Result<EncodeResponse> {
        let rx = self.encode_async(features)?;
        rx.recv().map_err(|_| anyhow!("service dropped reply"))
    }

    /// Encode a set of rows into a retrieval index (blocking, batched
    /// through the same pipeline). The backend comes from
    /// `ServiceConfig::index`; `Auto` routes by corpus size.
    pub fn build_index(&self, rows: &[Vec<f32>]) -> Result<IndexAny> {
        let mut codes = BitCode::new(rows.len(), self.cfg.bits);
        let handles: Vec<_> = rows
            .iter()
            .map(|r| self.encode_async(r.clone()))
            .collect::<Result<_>>()?;
        for (i, h) in handles.into_iter().enumerate() {
            let resp = h.recv().map_err(|_| anyhow!("reply lost"))?;
            codes.set_row_from_signs(i, &resp.signs);
        }
        let backend = match &self.cfg.index {
            IndexBackend::Auto => Router::pick_index(rows.len(), self.cfg.bits),
            explicit => explicit.clone(),
        };
        Ok(build_index(codes, &backend))
    }

    /// Encode a query and search an index — any backend that speaks
    /// [`AnyIndex`] (an [`IndexAny`] from [`EmbeddingService::build_index`],
    /// a bare `BinaryIndex`, `MihIndex`, `ShardedIndex`, …).
    pub fn search(&self, index: &dyn AnyIndex, query: Vec<f32>, topk: usize) -> Result<Vec<Hit>> {
        let resp = self.encode(query)?;
        let qc = BitCode::from_signs(&resp.signs, 1, self.cfg.bits);
        Ok(index.search(qc.code(0), topk))
    }
}

impl Drop for EmbeddingService {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// The batching event loop (runs on the worker thread).
#[allow(clippy::too_many_arguments)]
fn event_loop(
    mut engine: Engine,
    artifact: String,
    artifact_batch: usize,
    cfg: ServiceConfig,
    r: Vec<f32>,
    signs: Vec<f32>,
    rx: mpsc::Receiver<EncodeRequest>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    let d = cfg.d;
    let mut batcher = Batcher::new(BatcherConfig {
        max_batch: artifact_batch,
        ..cfg.batcher.clone()
    });
    loop {
        // Pull at least one request (with timeout so we can observe stop).
        let wait = batcher
            .time_to_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(20));
        match rx.recv_timeout(wait) {
            Ok(req) => {
                batcher.push(req);
                // Opportunistically drain whatever else is queued.
                while batcher.len() < artifact_batch {
                    match rx.try_recv() {
                        Ok(req) => batcher.push(req),
                        Err(_) => break,
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                if batcher.is_empty() {
                    return;
                }
            }
        }
        if stop.load(Ordering::SeqCst) && batcher.is_empty() {
            return;
        }
        let now = Instant::now();
        // Disconnected-but-pending: force the flush by pretending deadline.
        let force = stop.load(Ordering::SeqCst);
        let ready = batcher.ready(now) || (force && !batcher.is_empty());
        if !ready {
            continue;
        }
        let batch = match batcher.pop_ready(now) {
            Some(b) => b,
            None => {
                // force path: drain all
                let mut all = Vec::new();
                while let Some(mut b) = batcher.pop_ready(Instant::now() + Duration::from_secs(3600)) {
                    all.append(&mut b);
                }
                if all.is_empty() {
                    continue;
                }
                all
            }
        };

        // Assemble the padded input tensor [artifact_batch, d].
        let mut x = vec![0f32; artifact_batch * d];
        for (i, req) in batch.iter().enumerate() {
            x[i * d..(i + 1) * d].copy_from_slice(&req.features);
        }
        metrics.record_batch(batch.len(), artifact_batch);

        let t0 = Instant::now();
        let result = engine.execute(
            &artifact,
            &[
                (&x, &[artifact_batch, d]),
                (&r, &[d]),
                (&signs, &[d]),
            ],
        );
        let exec_ms = t0.elapsed().as_secs_f64() * 1e3;

        match result {
            Ok(outs) => {
                let codes = &outs[0]; // [artifact_batch, d] of ±1
                for (i, req) in batch.iter().enumerate() {
                    let queue_ms =
                        t0.duration_since(req.t_enqueue).as_secs_f64() * 1e3;
                    let signs_out = codes[i * d..i * d + req.bits].to_vec();
                    metrics.record_request(
                        (Instant::now().duration_since(req.t_enqueue).as_secs_f64() * 1e6)
                            as u64,
                    );
                    let _ = req.reply.send(EncodeResponse {
                        signs: signs_out,
                        queue_ms,
                        exec_ms,
                    });
                }
            }
            Err(e) => {
                eprintln!("batch execution failed: {e:#}");
                // Drop replies — senders see a closed channel.
            }
        }
    }
}
