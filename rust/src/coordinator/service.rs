//! [`EmbeddingService`]: the public serving facade.
//!
//! Owns the circulant model (one shared `Send + Sync`
//! [`CirculantProjection`]), the dynamic batcher and the retrieval index.
//! A background worker thread runs the event loop: drain requests → form
//! batch → one parallel batch-encode (scoped-thread fan-out across cores,
//! signs packed straight into `BitCode` words) → scatter replies. Bulk
//! indexing bypasses the request channel entirely via
//! [`EmbeddingService::encode_corpus`].
//!
//! The compiled-artifact manifest is advisory: when `artifacts_dir` holds
//! one, the routed artifact's batch dimension sizes the dynamic batches
//! (keeping native batches aligned with the shapes the AOT pipeline was
//! tuned for); without it the service runs fully native on
//! `cfg.batcher.max_batch`. The PJRT [`crate::runtime::Engine`] remains
//! the execution path for the `runtime_pjrt` integration suite.

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::request::{EncodeRequest, EncodeResponse};
use super::router::Router;
use crate::bits::index::Hit;
use crate::bits::BitCode;
use crate::fft::Planner;
use crate::index::{build_index, AnyIndex, IndexAny, IndexBackend};
use crate::projections::{CirculantProjection, ScratchPool};
use crate::runtime::Manifest;
use anyhow::{anyhow, Result};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Feature dimension.
    pub d: usize,
    /// Bits returned per code (k ≤ d).
    pub bits: usize,
    /// Batching policy.
    pub batcher: BatcherConfig,
    /// Retrieval backend built by [`EmbeddingService::build_index`].
    /// `Auto` defers to [`Router::pick_index`] at corpus-build time.
    /// Parse from config with [`IndexBackend::from_spec`]
    /// (`auto | linear | mih[:m] | mih-sampled[:m] | sharded:<shards>[:m]`;
    /// the embedding_server example reads the spec from `CBE_INDEX`, the
    /// CLI from `--index`).
    pub index: IndexBackend,
}

/// The serving facade. Construct with [`EmbeddingService::start`], submit
/// with [`EmbeddingService::encode`] / [`EmbeddingService::encode_async`],
/// bulk-index with [`EmbeddingService::build_index`], stop by dropping.
pub struct EmbeddingService {
    tx: mpsc::Sender<EncodeRequest>,
    pub metrics: Arc<Metrics>,
    cfg: ServiceConfig,
    /// The circulant model, shared with the worker thread (and with any
    /// caller that wants zero-copy bulk encoding).
    proj: Arc<CirculantProjection>,
    stop: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl EmbeddingService {
    /// Start the service: build the shared projection, spawn the batching
    /// event loop. `r` and `signs` are the circulant model parameters
    /// (e.g. from CBE-opt training or random for CBE-rand).
    pub fn start(
        artifacts_dir: &Path,
        cfg: ServiceConfig,
        r: Vec<f32>,
        signs: Vec<f32>,
    ) -> Result<EmbeddingService> {
        assert_eq!(r.len(), cfg.d);
        assert_eq!(signs.len(), cfg.d);
        assert!(cfg.bits <= cfg.d);

        let proj = Arc::new(CirculantProjection::new(r, signs, Planner::new()));

        // Adopt the routed artifact's batch dimension when a manifest is
        // present; otherwise the configured max_batch governs.
        let artifact_batch = Manifest::load(artifacts_dir)
            .ok()
            .and_then(|m| {
                Router::from_manifest(&m)
                    .route("cbe_encode", cfg.d)
                    .map(|route| route.batch)
                    .ok()
            })
            .unwrap_or(cfg.batcher.max_batch);

        let (tx, rx) = mpsc::channel::<EncodeRequest>();
        let metrics = Arc::new(Metrics::default());
        let stop = Arc::new(AtomicBool::new(false));
        let m2 = Arc::clone(&metrics);
        let stop2 = Arc::clone(&stop);
        let cfg2 = cfg.clone();
        let proj2 = Arc::clone(&proj);
        let worker = std::thread::spawn(move || {
            event_loop(artifact_batch, cfg2, proj2, rx, m2, stop2);
        });

        Ok(EmbeddingService {
            tx,
            metrics,
            cfg,
            proj,
            stop,
            worker: Some(worker),
        })
    }

    /// The shared circulant model (the same instance the worker encodes
    /// with — `Send + Sync`, clone the `Arc` freely).
    pub fn projection(&self) -> &Arc<CirculantProjection> {
        &self.proj
    }

    /// Fire-and-forget submit; returns the response receiver.
    pub fn encode_async(&self, features: Vec<f32>) -> Result<mpsc::Receiver<EncodeResponse>> {
        if features.len() != self.cfg.d {
            return Err(anyhow!(
                "feature dim {} != service dim {}",
                features.len(),
                self.cfg.d
            ));
        }
        let (req, rx) = EncodeRequest::new(features, self.cfg.bits);
        self.tx.send(req).map_err(|_| anyhow!("service stopped"))?;
        Ok(rx)
    }

    /// Blocking encode.
    pub fn encode(&self, features: Vec<f32>) -> Result<EncodeResponse> {
        let rx = self.encode_async(features)?;
        rx.recv().map_err(|_| anyhow!("service dropped reply"))
    }

    /// Bulk encode: run borrowed rows through the parallel batch engine,
    /// bypassing the per-request channel round-trip (and any per-row
    /// copies) entirely. Rows are packed straight into the returned
    /// [`BitCode`].
    pub fn encode_corpus(&self, rows: &[Vec<f32>]) -> Result<BitCode> {
        for (i, row) in rows.iter().enumerate() {
            if row.len() != self.cfg.d {
                return Err(anyhow!(
                    "row {i}: feature dim {} != service dim {}",
                    row.len(),
                    self.cfg.d
                ));
            }
        }
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut codes = BitCode::new(rows.len(), self.cfg.bits);
        let mut pool = ScratchPool::new();
        self.proj
            .encode_batch_into(&refs, self.cfg.bits, &mut codes, &mut pool);
        Ok(codes)
    }

    /// Encode a corpus into a retrieval index via
    /// [`EmbeddingService::encode_corpus`]. The backend comes from
    /// [`ServiceConfig::index`]; `Auto` routes by corpus size.
    pub fn build_index(&self, rows: &[Vec<f32>]) -> Result<IndexAny> {
        let codes = self.encode_corpus(rows)?;
        let backend = match &self.cfg.index {
            IndexBackend::Auto => Router::pick_index(rows.len(), self.cfg.bits),
            explicit => explicit.clone(),
        };
        Ok(build_index(codes, &backend))
    }

    /// Encode a query and search an index — any backend that speaks
    /// [`AnyIndex`] (an [`IndexAny`] from [`EmbeddingService::build_index`],
    /// a bare `BinaryIndex`, `MihIndex`, `ShardedIndex`, …).
    pub fn search(&self, index: &dyn AnyIndex, query: Vec<f32>, topk: usize) -> Result<Vec<Hit>> {
        let resp = self.encode(query)?;
        let qc = BitCode::from_signs(&resp.signs, 1, self.cfg.bits);
        Ok(index.search(qc.code(0), topk))
    }
}

impl Drop for EmbeddingService {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Encode one formed batch through the shared projection (parallel
/// fan-out, signs packed directly into the reused `codes` buffer) and
/// scatter the replies.
fn run_batch(
    proj: &CirculantProjection,
    bits: usize,
    artifact_batch: usize,
    batch: Vec<EncodeRequest>,
    codes: &mut BitCode,
    pool: &mut ScratchPool,
    metrics: &Metrics,
) {
    if batch.is_empty() {
        return;
    }
    metrics.record_batch(batch.len(), artifact_batch);
    let t0 = Instant::now();
    let rows: Vec<&[f32]> = batch.iter().map(|r| r.features.as_slice()).collect();
    codes.reset(batch.len());
    proj.encode_batch_into(&rows, bits, codes, pool);
    let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
    for (i, req) in batch.iter().enumerate() {
        let queue_ms = t0.duration_since(req.t_enqueue).as_secs_f64() * 1e3;
        let mut signs = codes.to_signs(i);
        signs.truncate(req.bits);
        let latency_us = (Instant::now().duration_since(req.t_enqueue).as_secs_f64() * 1e6) as u64;
        metrics.record_request(latency_us);
        let _ = req.reply.send(EncodeResponse {
            signs,
            queue_ms,
            exec_ms,
        });
    }
}

/// The batching event loop (runs on the worker thread). The projection,
/// scratch pool and packed-code buffer live for the whole loop — nothing
/// is allocated per request, and nothing bigger than a `Vec` of row
/// borrows per batch.
fn event_loop(
    artifact_batch: usize,
    cfg: ServiceConfig,
    proj: Arc<CirculantProjection>,
    rx: mpsc::Receiver<EncodeRequest>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    let mut batcher = Batcher::new(BatcherConfig {
        max_batch: artifact_batch,
        ..cfg.batcher.clone()
    });
    let mut pool = ScratchPool::new();
    let mut codes = BitCode::new(0, cfg.bits);
    loop {
        // Pull at least one request (with timeout so we can observe stop).
        let wait = batcher
            .time_to_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(20));
        match rx.recv_timeout(wait) {
            Ok(req) => {
                batcher.push(req);
                // Opportunistically drain whatever else is queued.
                while batcher.len() < artifact_batch {
                    match rx.try_recv() {
                        Ok(req) => batcher.push(req),
                        Err(_) => break,
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Senders gone: flush the stragglers and exit.
                let tail = batcher.drain_all();
                run_batch(
                    &proj,
                    cfg.bits,
                    artifact_batch,
                    tail,
                    &mut codes,
                    &mut pool,
                    &metrics,
                );
                return;
            }
        }
        if stop.load(Ordering::SeqCst) {
            // Graceful shutdown: absorb requests already queued in the
            // channel so in-flight encode_async callers still get their
            // replies, then flush everything in one final batch.
            while let Ok(req) = rx.try_recv() {
                batcher.push(req);
            }
            let tail = batcher.drain_all();
            run_batch(
                &proj,
                cfg.bits,
                artifact_batch,
                tail,
                &mut codes,
                &mut pool,
                &metrics,
            );
            return;
        }
        if let Some(batch) = batcher.pop_ready(Instant::now()) {
            run_batch(
                &proj,
                cfg.bits,
                artifact_batch,
                batch,
                &mut codes,
                &mut pool,
                &metrics,
            );
        }
    }
}
