//! [`EmbeddingService`]: the public serving facade.
//!
//! Owns the model slot (a hot-swappable
//! [`ModelRegistry`] of `Send + Sync` [`CbeModel`]s — any projection
//! variant of the `circ | stacked[:B] | downsampled` grammar), the
//! dynamic batcher and the retrieval index. A background worker thread
//! runs the event loop: drain requests → form batch → one parallel
//! batch-encode (scoped-thread fan-out across cores, signs packed
//! straight into `BitCode` words) → scatter replies. Bulk indexing
//! bypasses the request channel entirely via
//! [`EmbeddingService::encode_corpus`], which streams the corpus through
//! the fan-out in bounded slabs.
//!
//! The pipeline is instrumented end to end: each batch reports
//! queue-wait → model-resolve → encode → pack stage timings to the
//! [`crate::obs`] recorder (gated, near-zero overhead), and
//! [`EmbeddingService::stats`] returns a structured
//! [`StatsSnapshot`] over the control plane.
//!
//! Admission is bounded: the request channel holds at most
//! [`ServiceConfig::queue_depth`] waiting requests, and a submission
//! against a full queue fails fast with [`CbeError::Overloaded`]
//! (counted in `StatsSnapshot::overloads`) instead of growing the queue
//! without limit. Indexes persist crash-safely through
//! [`EmbeddingService::save_index`] / [`EmbeddingService::load_index`],
//! which stamp and verify model identity — see [`crate::index::persist`]
//! for the snapshot/WAL/recovery contract.
//!
//! # Online retraining
//!
//! The service can re-learn its circulant model without a restart:
//! [`EmbeddingService::encode_corpus`] keeps a seeded reservoir sample
//! of the rows it indexes (capacity [`RetrainConfig::sample`]), and a
//! [`ControlRequest::Retrain`] — issued via
//! [`EmbeddingService::retrain`] — trains CBE-opt on that sample in a
//! background thread while the event loop keeps serving, then
//! atomically swaps the new model into the registry. In-flight requests
//! are never dropped or re-encoded: each batch resolves the active
//! model once, so a swap lands between batches (see the hot-swap
//! contract on [`ModelRegistry`]).
//!
//! Indexes are part of the same contract: [`EmbeddingService::build_index`]
//! stamps the registry version its codes were encoded with onto the
//! returned [`IndexAny`], and [`EmbeddingService::search`] refuses an
//! index whose stamp mismatches the live model with
//! [`CbeError::StaleIndex`] — mixing codes from two models silently
//! returns garbage neighbors, so the rebuild-after-retrain rule is
//! enforced by code, not documentation. Unversioned indexes (built
//! directly over codes, outside the service) are not checked; their
//! staleness is the caller's contract.
//!
//! The compiled-artifact manifest is advisory: when `artifacts_dir` holds
//! one, the routed artifact's batch dimension sizes the dynamic batches
//! (keeping native batches aligned with the shapes the AOT pipeline was
//! tuned for); without it the service runs fully native on
//! `cfg.batcher.max_batch`. The PJRT [`crate::runtime::Engine`] remains
//! the execution path for the `runtime_pjrt` integration suite.

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::registry::ModelRegistry;
use super::request::{ControlRequest, EncodeRequest, EncodeResponse, RetrainOutcome, RetrainResult};
use super::router::Router;
use crate::bits::index::Hit;
use crate::bits::BitCode;
use crate::encoders::CbeTrainer;
use crate::error::CbeError;
use crate::fft::Planner;
use crate::index::persist::{self, LoadReport, SnapshotStamp};
use crate::index::{build_index, AnyIndex, IndexAny, IndexBackend};
use crate::linalg::Mat;
use crate::obs::{self, ProjectionInfo, Stage, StatsSnapshot};
use crate::opt::TimeFreqConfig;
use crate::projections::{CbeModel, ProjectionSpec, ScratchPool};
use crate::runtime::Manifest;
use crate::util::rng::Pcg64;
use anyhow::{anyhow, Result};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Knobs for online retraining (the `Retrain` control request).
#[derive(Clone, Debug)]
pub struct RetrainConfig {
    /// Reservoir capacity: how many corpus rows
    /// [`EmbeddingService::encode_corpus`] retains as training data.
    /// 0 disables sampling (and therefore retraining).
    pub sample: usize,
    /// Trainer iterations per retrain (paper: 5–10 suffice).
    pub iters: usize,
    /// λ of the near-orthogonality penalty.
    pub lambda: f64,
    /// Trainer fan-out threads (0 = auto, work-gated).
    pub threads: usize,
    /// Thread-count-invariant reductions in the trainer.
    pub deterministic: bool,
    /// Resident spectrum-cache budget for the trainer in bytes
    /// (0 = unlimited); oversized retrain samples stream in tiles. See
    /// [`crate::opt::TimeFreqConfig::cache_budget`].
    pub cache_budget: usize,
    /// Seed for the sign diagonal, r₀ init and the reservoir.
    pub seed: u64,
}

impl Default for RetrainConfig {
    fn default() -> RetrainConfig {
        RetrainConfig {
            sample: 512,
            iters: 5,
            lambda: 1.0,
            threads: 0,
            deterministic: true,
            cache_budget: 0,
            seed: 0x5eed,
        }
    }
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Feature dimension.
    pub d: usize,
    /// Bits returned per code. Capped by the projection: k ≤ d for
    /// `circ`/`downsampled`, k ≤ B·d for `stacked:B`; a request past the
    /// cap fails [`EmbeddingService::start`] with
    /// [`CbeError::BadCodeLength`].
    pub bits: usize,
    /// Projection variant serving the codes. Parse from config with
    /// [`ProjectionSpec::from_spec`] (`circ | stacked[:B] | downsampled`;
    /// the embedding_server example reads the spec from `CBE_PROJ`, the
    /// CLI from `--proj`). [`EmbeddingService::start`] only accepts
    /// `circ` (its `r`/`signs` arguments describe exactly one block) —
    /// other variants enter through
    /// [`EmbeddingService::start_with_model`].
    pub proj: ProjectionSpec,
    /// Batching policy.
    pub batcher: BatcherConfig,
    /// Retrieval backend built by [`EmbeddingService::build_index`].
    /// `Auto` defers to [`Router::pick_index`] at corpus-build time.
    /// Parse from config with [`IndexBackend::from_spec`]
    /// (`auto | linear | mih[:m] | mih-sampled[:m] | sharded:<shards>[:m]`;
    /// the embedding_server example reads the spec from `CBE_INDEX`, the
    /// CLI from `--index`).
    pub index: IndexBackend,
    /// Online-retraining knobs (the CLI exposes `--retrain*`, the
    /// embedding_server example `CBE_RETRAIN`).
    pub retrain: RetrainConfig,
    /// Admission-control bound on the request queue. When this many
    /// requests are already waiting, [`EmbeddingService::encode_async`]
    /// fails fast with [`CbeError::Overloaded`] instead of queueing
    /// without limit (unbounded queues turn overload into latency
    /// collapse and OOM). 0 = read `CBE_QUEUE_DEPTH`, defaulting to
    /// 1024.
    pub queue_depth: usize,
    /// Snapshot-load backing for [`EmbeddingService::load_index`]:
    /// zero-copy mmap vs portable heap copy. `Auto` (the default)
    /// consults `CBE_MMAP`, then maps wherever the platform supports it.
    pub load_mode: persist::LoadMode,
}

/// Resolve the configured queue depth: explicit config wins, then the
/// `CBE_QUEUE_DEPTH` environment variable, then the 1024 default.
fn resolve_queue_depth(configured: usize) -> usize {
    if configured > 0 {
        return configured;
    }
    std::env::var("CBE_QUEUE_DEPTH")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&v| v > 0)
        .unwrap_or(1024)
}

/// Seeded reservoir sample (Algorithm R) over the rows streamed through
/// [`EmbeddingService::encode_corpus`] — the training set for `Retrain`.
struct Reservoir {
    cap: usize,
    seen: u64,
    rng: Pcg64,
    rows: Vec<Vec<f32>>,
}

impl Reservoir {
    fn new(cap: usize, seed: u64) -> Reservoir {
        Reservoir {
            cap,
            seen: 0,
            rng: Pcg64::new(seed),
            rows: Vec::new(),
        }
    }

    fn add(&mut self, row: &[f32]) {
        if self.cap == 0 {
            return;
        }
        self.seen += 1;
        if self.rows.len() < self.cap {
            self.rows.push(row.to_vec());
            return;
        }
        let j = self.rng.below(self.seen);
        if (j as usize) < self.cap {
            self.rows[j as usize] = row.to_vec();
        }
    }
}

/// The serving facade. Construct with [`EmbeddingService::start`], submit
/// with [`EmbeddingService::encode`] / [`EmbeddingService::encode_async`],
/// bulk-index with [`EmbeddingService::build_index`], re-learn the model
/// with [`EmbeddingService::retrain`], stop by dropping.
pub struct EmbeddingService {
    tx: mpsc::SyncSender<EncodeRequest>,
    ctl: mpsc::Sender<ControlRequest>,
    pub metrics: Arc<Metrics>,
    cfg: ServiceConfig,
    /// Resolved admission bound (see [`ServiceConfig::queue_depth`]).
    queue_depth: usize,
    /// The hot-swappable model slot, shared with the worker thread, the
    /// retrain threads and any caller that wants zero-copy bulk encoding.
    registry: Arc<ModelRegistry>,
    /// Corpus reservoir feeding `Retrain`.
    sample: Arc<Mutex<Reservoir>>,
    artifact_batch: usize,
    stop: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl EmbeddingService {
    /// Start the service from bare single-block parameters: register the
    /// initial projection, spawn the batching event loop. `r` and `signs`
    /// are the circulant model parameters (e.g. from CBE-opt training or
    /// random for CBE-rand); accordingly [`ServiceConfig::proj`] must be
    /// `circ`. Stacked/downsampled services start through
    /// [`EmbeddingService::start_with_model`].
    pub fn start(
        artifacts_dir: &Path,
        cfg: ServiceConfig,
        r: Vec<f32>,
        signs: Vec<f32>,
    ) -> Result<EmbeddingService> {
        assert_eq!(r.len(), cfg.d);
        assert_eq!(signs.len(), cfg.d);
        if cfg.proj != ProjectionSpec::Circ {
            return Err(anyhow!(
                "EmbeddingService::start takes one circulant block (r, signs) and \
                 cannot build a '{}' model — use start_with_model",
                cfg.proj.spec()
            ));
        }
        let model = CbeModel::circulant(r, signs, Planner::new());
        EmbeddingService::start_with_model(artifacts_dir, cfg, model)
    }

    /// Start the service around an already-built model of any projection
    /// variant (the general entry point; [`EmbeddingService::start`] is
    /// the single-block convenience wrapper). The configured `bits` are
    /// validated against the model's cap — a typed
    /// [`CbeError::BadCodeLength`] instead of the old `assert!`.
    pub fn start_with_model(
        artifacts_dir: &Path,
        cfg: ServiceConfig,
        model: CbeModel,
    ) -> Result<EmbeddingService> {
        if model.d() != cfg.d {
            return Err(anyhow!(
                "model dimension {} != configured dimension {}",
                model.d(),
                cfg.d
            ));
        }
        model.check_code_length(cfg.bits)?;

        let planner = Planner::new();
        let registry = Arc::new(ModelRegistry::new(model));
        let sample = Arc::new(Mutex::new(Reservoir::new(
            cfg.retrain.sample,
            cfg.retrain.seed ^ 0x7e5e,
        )));

        // Adopt the routed artifact's batch dimension when a manifest is
        // present; otherwise the configured max_batch governs.
        let artifact_batch = Manifest::load(artifacts_dir)
            .ok()
            .and_then(|m| {
                Router::from_manifest(&m)
                    .route("cbe_encode", cfg.d)
                    .map(|route| route.batch)
                    .ok()
            })
            .unwrap_or(cfg.batcher.max_batch);

        // Bounded request channel: the queue (plus at most one forming
        // batch in the worker) is the entire in-flight set, so memory
        // under overload is `queue_depth` requests, not "whatever the
        // clients managed to pour in".
        let queue_depth = resolve_queue_depth(cfg.queue_depth);
        let (tx, rx) = mpsc::sync_channel::<EncodeRequest>(queue_depth);
        let (ctl, ctl_rx) = mpsc::channel::<ControlRequest>();
        let metrics = Arc::new(Metrics::default());
        let stop = Arc::new(AtomicBool::new(false));
        let m2 = Arc::clone(&metrics);
        let stop2 = Arc::clone(&stop);
        let cfg2 = cfg.clone();
        let registry2 = Arc::clone(&registry);
        let sample2 = Arc::clone(&sample);
        let planner2 = planner.clone();
        let worker = std::thread::spawn(move || {
            event_loop(
                artifact_batch,
                cfg2,
                planner2,
                registry2,
                sample2,
                rx,
                ctl_rx,
                m2,
                stop2,
            );
        });

        Ok(EmbeddingService {
            tx,
            ctl,
            metrics,
            cfg,
            queue_depth,
            registry,
            sample,
            artifact_batch,
            stop,
            worker: Some(worker),
        })
    }

    /// The currently active projection model (the same instance the
    /// worker will encode the *next* batch with — `Send + Sync`, hold
    /// the `Arc` as long as you like; a later hot-swap won't touch it).
    pub fn projection(&self) -> Arc<CbeModel> {
        self.registry.current()
    }

    /// The hot-swappable model slot itself.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Monotone model version (0 = the model the service started with;
    /// each completed `Retrain` bumps it).
    pub fn model_version(&self) -> u64 {
        self.registry.version()
    }

    /// Rows currently held in the retrain reservoir.
    pub fn corpus_sample_len(&self) -> usize {
        self.sample.lock().expect("sample lock poisoned").rows.len()
    }

    /// The configured admission bound (requests beyond it are rejected
    /// with [`CbeError::Overloaded`]).
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// Fire-and-forget submit; returns the response receiver. Fails
    /// typed: [`CbeError::Overloaded`] when the bounded request queue is
    /// full (back off and retry — the rejection is also counted in
    /// [`Metrics::record_overload`] / `StatsSnapshot::overloads`),
    /// [`CbeError::Service`] for dimension mismatches or a stopped
    /// service.
    pub fn encode_async(&self, features: Vec<f32>) -> Result<mpsc::Receiver<EncodeResponse>, CbeError> {
        if features.len() != self.cfg.d {
            return Err(CbeError::Service(format!(
                "feature dim {} != service dim {}",
                features.len(),
                self.cfg.d
            )));
        }
        let (req, rx) = EncodeRequest::new(features, self.cfg.bits);
        match self.tx.try_send(req) {
            Ok(()) => Ok(rx),
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.record_overload();
                Err(CbeError::Overloaded {
                    depth: self.queue_depth,
                })
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                Err(CbeError::Service("service stopped".to_string()))
            }
        }
    }

    /// Blocking encode. Same typed failures as
    /// [`EmbeddingService::encode_async`].
    pub fn encode(&self, features: Vec<f32>) -> Result<EncodeResponse, CbeError> {
        let rx = self.encode_async(features)?;
        rx.recv()
            .map_err(|_| CbeError::Service("service dropped reply".to_string()))
    }

    /// Request a retrain: train CBE-opt on the corpus reservoir in a
    /// background thread (the event loop keeps serving throughout) and
    /// hot-swap the result into the registry. Returns the receiver for
    /// the outcome; see [`EmbeddingService::retrain_blocking`] for the
    /// synchronous wrapper.
    pub fn retrain(&self) -> Result<mpsc::Receiver<RetrainResult>> {
        if self.cfg.retrain.sample == 0 {
            return Err(anyhow!(
                "retraining disabled: ServiceConfig::retrain.sample is 0"
            ));
        }
        let (reply, rx) = mpsc::channel();
        self.ctl
            .send(ControlRequest::Retrain { reply })
            .map_err(|_| anyhow!("service stopped"))?;
        Ok(rx)
    }

    /// [`EmbeddingService::retrain`], waited to completion.
    pub fn retrain_blocking(&self) -> Result<RetrainOutcome> {
        match self.retrain()?.recv() {
            Ok(Ok(outcome)) => Ok(outcome),
            Ok(Err(msg)) => Err(anyhow!("retrain failed: {msg}")),
            Err(_) => Err(anyhow!("service dropped retrain reply")),
        }
    }

    /// Snapshot the service's statistics over the control plane:
    /// counters (requests, retrains, `StaleIndex` rejections), the
    /// end-to-end latency histogram, index/plan-cache totals and the
    /// per-stage timing histograms. Serialize with
    /// [`StatsSnapshot::to_json`]; the CLI exposes it as `--stats` /
    /// `--stats-every`, the embedding_server example as `CBE_STATS=1`.
    pub fn stats(&self) -> Result<StatsSnapshot> {
        let (reply, rx) = mpsc::channel();
        self.ctl
            .send(ControlRequest::Stats { reply })
            .map_err(|_| anyhow!("service stopped"))?;
        rx.recv().map_err(|_| anyhow!("service dropped stats reply"))
    }

    /// Rows per `encode_corpus` slab: artifact-batch-sized, raised to
    /// the smallest count that still saturates the batch fan-out (every
    /// core gets work above the calibrated threshold), so streaming
    /// never costs throughput.
    fn corpus_slab(&self) -> usize {
        let cores = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        let min_rows = crate::tune::min_parallel_work().div_ceil(self.cfg.d.max(1));
        self.artifact_batch.max(min_rows).max(cores).max(1)
    }

    /// Bulk encode: stream borrowed rows through the parallel batch
    /// engine in artifact-batch-sized slabs, bypassing the per-request
    /// channel round-trip (and any per-row copies) entirely. Each slab
    /// is packed straight into its window of the returned [`BitCode`],
    /// so transient memory is bounded by one slab of row borrows plus
    /// the per-thread scratch — not by the corpus. The whole corpus is
    /// encoded by one model version (resolved once, up front), and the
    /// rows are folded into the retrain reservoir as they stream by.
    pub fn encode_corpus(&self, rows: &[Vec<f32>]) -> Result<BitCode> {
        Ok(self.encode_corpus_versioned(rows)?.0)
    }

    /// [`EmbeddingService::encode_corpus`] plus the registry version the
    /// codes were encoded with — model and version are resolved together
    /// under one registry read, which is what makes the version stamp on
    /// [`EmbeddingService::build_index`] trustworthy across a concurrent
    /// `Retrain` swap.
    fn encode_corpus_versioned(&self, rows: &[Vec<f32>]) -> Result<(BitCode, u64)> {
        // All-or-nothing: validate every row before encoding anything or
        // feeding a single row into the retrain reservoir, so a failed
        // call has no side effects.
        for (i, row) in rows.iter().enumerate() {
            if row.len() != self.cfg.d {
                return Err(anyhow!(
                    "row {i}: feature dim {} != service dim {}",
                    row.len(),
                    self.cfg.d
                ));
            }
        }
        let mut codes = BitCode::new(rows.len(), self.cfg.bits);
        let wpc = codes.words_per_code;
        let slab = self.corpus_slab();
        let (proj, version) = self.registry.current_versioned();
        let mut pool = ScratchPool::new();
        let mut refs: Vec<&[f32]> = Vec::with_capacity(slab.min(rows.len()));
        for (s, chunk) in rows.chunks(slab).enumerate() {
            let start = s * slab;
            refs.clear();
            refs.extend(chunk.iter().map(|r| r.as_slice()));
            let words = &mut codes.data[start * wpc..(start + chunk.len()) * wpc];
            proj.encode_batch_words(&refs, self.cfg.bits, words, wpc, &mut pool);
            if self.cfg.retrain.sample > 0 {
                let mut res = self.sample.lock().expect("sample lock poisoned");
                for row in chunk {
                    res.add(row);
                }
            }
        }
        Ok((codes, version))
    }

    /// Encode a corpus into a retrieval index via
    /// [`EmbeddingService::encode_corpus`]. The backend comes from
    /// [`ServiceConfig::index`]; `Auto` routes by corpus size. The
    /// returned index is stamped with the registry version its codes
    /// were encoded with, so a `search()` after a later `Retrain`
    /// hot-swap fails with [`CbeError::StaleIndex`] instead of silently
    /// mixing models — rebuild through this method after every retrain.
    pub fn build_index(&self, rows: &[Vec<f32>]) -> Result<IndexAny> {
        let (codes, version) = self.encode_corpus_versioned(rows)?;
        let backend = match &self.cfg.index {
            IndexBackend::Auto => Router::pick_index(rows.len(), self.cfg.bits),
            explicit => explicit.clone(),
        };
        Ok(build_index(codes, &backend).with_model_version(version))
    }

    /// Encode a query and search an index — any backend that speaks
    /// [`AnyIndex`] (an [`IndexAny`] from [`EmbeddingService::build_index`],
    /// a bare `BinaryIndex`, `MihIndex`, `ShardedIndex`, …).
    ///
    /// A versioned index (one built by [`EmbeddingService::build_index`])
    /// whose stamp differs from the live
    /// [`EmbeddingService::model_version`] is rejected with
    /// [`CbeError::StaleIndex`]: its codes come from a different model
    /// (usually one retired by a `Retrain`; a stamp *ahead* of this
    /// service means the index belongs to another instance), so its
    /// distances to the freshly encoded query are meaningless.
    /// Unversioned indexes skip the check (their staleness is the
    /// caller's contract).
    ///
    /// The guard runs twice: once before encoding (fast fail, no wasted
    /// batch slot) and once after the reply — a `Retrain` swap can land
    /// while the query is in flight, in which case the reply may already
    /// be new-model. The version bump is published before any batch can
    /// resolve the new model, so a query encoded by a newer model than
    /// the index can never slip past the second check; the only
    /// mid-flight outcome is a spurious (and safe) rejection of an
    /// old-model reply, and the caller was about to need a rebuild
    /// anyway.
    pub fn search(
        &self,
        index: &dyn AnyIndex,
        query: Vec<f32>,
        topk: usize,
    ) -> Result<Vec<Hit>, CbeError> {
        let guard = || -> Result<(), CbeError> {
            if let Some(built) = index.model_version() {
                let current = self.model_version();
                // Any mismatch is a cross-model search: trailing means a
                // retrain retired the index's model; *ahead* means the
                // index was built by a different service instance. Both
                // mix embeddings, so both are rejected.
                if built != current {
                    self.metrics.record_stale_rejection();
                    return Err(CbeError::StaleIndex { built, current });
                }
            }
            Ok(())
        };
        guard()?;
        // `encode` already fails typed (Overloaded propagates to the
        // caller as itself, not stringified).
        let resp = self.encode(query)?;
        guard()?;
        let qc = BitCode::from_signs(&resp.signs, 1, self.cfg.bits);
        Ok(index.search(qc.code(0), topk))
    }

    /// Content fingerprint of the live projection's parameters. Unlike
    /// [`EmbeddingService::model_version`] (a per-process counter), the
    /// fingerprint survives restarts: two processes that trained the same
    /// deterministic model agree on it, which is what lets
    /// [`EmbeddingService::load_index`] accept a snapshot from an earlier
    /// run of the same model and reject one from a different model. The
    /// hash covers **all** blocks plus any bit-selection plan (see
    /// [`CbeModel::fingerprint`]); a one-block stacked model fingerprints
    /// identically to the equivalent plain circulant.
    pub fn model_fingerprint(&self) -> u64 {
        self.registry.current().fingerprint()
    }

    /// Persist `index` into `dir` as a checksummed snapshot (plus a
    /// fresh, empty WAL), stamped with the live model's version and
    /// parameter fingerprint so a later load can verify model identity.
    /// Atomic: a crash mid-save leaves the directory's previous contents
    /// intact. A versioned index whose stamp trails the live model is
    /// refused with [`CbeError::StaleIndex`] — persisting it would pin
    /// retired codes under a current-model fingerprint.
    pub fn save_index(&self, dir: &Path, index: &IndexAny) -> Result<(), CbeError> {
        let current = self.model_version();
        let stamp = match index.model_version() {
            Some(built) if built != current => {
                return Err(CbeError::StaleIndex { built, current });
            }
            Some(built) => SnapshotStamp {
                model_version: Some(built),
                fingerprint: self.model_fingerprint(),
            },
            // Unversioned (built outside the service): persist without a
            // model stamp; staleness stays the caller's contract.
            None => SnapshotStamp::none(),
        };
        persist::save(dir, index, &stamp)
    }

    /// Load (and if necessary recover) the index persisted in `dir`,
    /// verifying its model stamp: a fingerprinted snapshot whose
    /// parameters differ from the live model is refused with
    /// [`CbeError::StaleIndex`] (counted like any stale rejection); a
    /// matching one is re-stamped at the live registry version so
    /// [`EmbeddingService::search`] accepts it even though version
    /// counters restart with the process. See
    /// [`crate::index::persist`] for the recovery classification in the
    /// returned [`LoadReport`].
    pub fn load_index(&self, dir: &Path) -> Result<(IndexAny, LoadReport), CbeError> {
        let (index, report) = persist::load_with_mode(dir, self.cfg.load_mode)?;
        if report.stamp.fingerprint == 0 {
            return Ok((index, report));
        }
        let current = self.model_version();
        if report.stamp.fingerprint != self.model_fingerprint() {
            self.metrics.record_stale_rejection();
            return Err(CbeError::StaleIndex {
                built: report.stamp.model_version.unwrap_or(0),
                current,
            });
        }
        Ok((index.with_model_version(current), report))
    }
}

impl Drop for EmbeddingService {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Train a replacement model on the reservoir snapshot and hot-swap it.
/// Runs on its own thread so the event loop keeps encoding; the handle
/// is joined at loop shutdown.
fn spawn_retrain(
    cfg: &ServiceConfig,
    planner: &Planner,
    registry: &Arc<ModelRegistry>,
    sample: &Arc<Mutex<Reservoir>>,
    metrics: &Arc<Metrics>,
    reply: mpsc::Sender<RetrainResult>,
) -> std::thread::JoinHandle<()> {
    let rc = cfg.retrain.clone();
    let d = cfg.d;
    let planner = planner.clone();
    let registry = Arc::clone(registry);
    let sample = Arc::clone(sample);
    let metrics = Arc::clone(metrics);
    // Retrain what is actually serving: the live model's canonical spec
    // (not the config's) decides the variant and block count, so a
    // stacked service retrains per-block and the swap keeps the shape.
    let spec = registry.current().spec();
    let bits = cfg.bits.clamp(1, registry.current().max_bits());
    std::thread::spawn(move || {
        let rows = {
            let res = sample.lock().expect("sample lock poisoned");
            res.rows.clone()
        };
        if rows.len() < 2 {
            let _ = reply.send(Err(format!(
                "corpus sample too small ({} rows) — index a corpus first",
                rows.len()
            )));
            return;
        }
        let mut x = Mat::zeros(rows.len(), d);
        for (i, row) in rows.iter().enumerate() {
            x.row_mut(i).copy_from_slice(row);
        }
        let mut tf = TimeFreqConfig::new(bits);
        tf.iters = rc.iters;
        tf.lambda = rc.lambda;
        tf.threads = rc.threads;
        tf.deterministic = rc.deterministic;
        tf.cache_budget = rc.cache_budget;
        let trainer = CbeTrainer::new(tf).seed(rc.seed).planner(planner);
        let enc = match trainer.train_model(&spec, &x, None) {
            Ok(enc) => enc,
            Err(e) => {
                let _ = reply.send(Err(format!("retrain failed: {e}")));
                return;
            }
        };
        let report = enc.report.clone();
        let version = registry.swap(enc.model);
        metrics.record_retrain();
        let _ = reply.send(Ok(RetrainOutcome {
            version,
            rows_used: rows.len(),
            report,
        }));
    })
}

/// Identity block for stats scrapes, resolved from the live model so a
/// hot-swap shows up in the very next snapshot (satellite of the
/// generalized projection layer: scrapes tell *what* is serving).
fn proj_info(model: &CbeModel, bits: usize) -> ProjectionInfo {
    ProjectionInfo {
        spec: model.spec_string(),
        variant: model.variant(),
        blocks: model.block_count(),
        bits,
    }
}

/// Encode one formed batch through the given projection (parallel
/// fan-out, signs packed directly into the reused `codes` buffer) and
/// scatter the replies.
fn run_batch(
    proj: &CbeModel,
    bits: usize,
    artifact_batch: usize,
    batch: Vec<EncodeRequest>,
    codes: &mut BitCode,
    pool: &mut ScratchPool,
    metrics: &Metrics,
) {
    if batch.is_empty() {
        return;
    }
    metrics.record_batch(batch.len(), artifact_batch);
    let on = obs::enabled();
    let t0 = Instant::now();
    if on {
        // Queue-wait ends when the batch launches; one sample per request.
        for req in &batch {
            obs::record(Stage::QueueWait, t0.duration_since(req.t_enqueue));
        }
    }
    let rows: Vec<&[f32]> = batch.iter().map(|r| r.features.as_slice()).collect();
    codes.reset(batch.len());
    {
        let _encode = on.then(|| obs::global().start(Stage::Encode));
        proj.encode_batch_into(&rows, bits, codes, pool);
    }
    let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
    let _pack = on.then(|| obs::global().start(Stage::Pack));
    for (i, req) in batch.iter().enumerate() {
        let queue_ms = t0.duration_since(req.t_enqueue).as_secs_f64() * 1e3;
        let mut signs = codes.to_signs(i);
        signs.truncate(req.bits);
        let latency_us = (Instant::now().duration_since(req.t_enqueue).as_secs_f64() * 1e6) as u64;
        metrics.record_request(latency_us);
        let _ = req.reply.send(EncodeResponse {
            signs,
            queue_ms,
            exec_ms,
        });
    }
}

/// The batching event loop (runs on the worker thread). The scratch pool
/// and packed-code buffer live for the whole loop — nothing is allocated
/// per request, and nothing bigger than a `Vec` of row borrows per
/// batch. Each batch resolves the active model from the registry once
/// (one refcount bump), which is what makes `Retrain` hot-swaps
/// batch-atomic; retrains themselves run on side threads spawned here
/// and joined at shutdown.
#[allow(clippy::too_many_arguments)]
fn event_loop(
    artifact_batch: usize,
    cfg: ServiceConfig,
    planner: Planner,
    registry: Arc<ModelRegistry>,
    sample: Arc<Mutex<Reservoir>>,
    rx: mpsc::Receiver<EncodeRequest>,
    ctl_rx: mpsc::Receiver<ControlRequest>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    let mut batcher = Batcher::new(BatcherConfig {
        max_batch: artifact_batch,
        ..cfg.batcher.clone()
    });
    let mut pool = ScratchPool::new();
    let mut codes = BitCode::new(0, cfg.bits);
    let mut trainers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        // Pull at least one request (with timeout so we can observe stop).
        let wait = batcher
            .time_to_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(20));
        match rx.recv_timeout(wait) {
            Ok(req) => {
                batcher.push(req);
                // Opportunistically drain whatever else is queued.
                while batcher.len() < artifact_batch {
                    match rx.try_recv() {
                        Ok(req) => batcher.push(req),
                        Err(_) => break,
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // Control plane: hand retrains to side threads so encoding
        // continues while the trainer runs; stats are answered inline
        // (snapshotting is a few hundred atomic loads).
        while let Ok(ctl) = ctl_rx.try_recv() {
            match ctl {
                ControlRequest::Retrain { reply } => {
                    trainers.push(spawn_retrain(
                        &cfg, &planner, &registry, &sample, &metrics, reply,
                    ));
                }
                ControlRequest::Stats { reply } => {
                    let (model, version) = registry.current_versioned();
                    let _ = reply.send(metrics.snapshot(
                        artifact_batch,
                        version,
                        proj_info(&model, cfg.bits),
                    ));
                }
            }
        }
        if let Some(batch) = batcher.pop_ready(Instant::now()) {
            let proj = {
                let _resolve = obs::span(Stage::ModelResolve);
                registry.current()
            };
            run_batch(
                &proj,
                cfg.bits,
                artifact_batch,
                batch,
                &mut codes,
                &mut pool,
                &metrics,
            );
        }
    }
    // Graceful shutdown (stop flag or senders gone): absorb requests
    // already queued in the channel so in-flight encode_async callers
    // still get their replies, flush everything in one final batch
    // against the current model, refuse late control requests, and wait
    // for any outstanding retrain to finish (its swap is then simply the
    // last one).
    while let Ok(req) = rx.try_recv() {
        batcher.push(req);
    }
    let tail = batcher.drain_all();
    let proj = registry.current();
    run_batch(
        &proj,
        cfg.bits,
        artifact_batch,
        tail,
        &mut codes,
        &mut pool,
        &metrics,
    );
    while let Ok(ctl) = ctl_rx.try_recv() {
        match ctl {
            ControlRequest::Retrain { reply } => {
                let _ = reply.send(Err("service stopping".to_string()));
            }
            // A final scrape is still answerable — the counters outlive
            // the loop; refusing would turn clean shutdowns into races.
            ControlRequest::Stats { reply } => {
                let (model, version) = registry.current_versioned();
                let _ = reply.send(metrics.snapshot(
                    artifact_batch,
                    version,
                    proj_info(&model, cfg.bits),
                ));
            }
        }
    }
    for t in trainers {
        let _ = t.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservoir_caps_and_is_uniformish() {
        let mut res = Reservoir::new(32, 7);
        for i in 0..1000 {
            res.add(&[i as f32]);
        }
        assert_eq!(res.rows.len(), 32);
        assert_eq!(res.seen, 1000);
        // Uniform over the stream: the kept indices should span it, not
        // cluster at the head (prefix-keep would have max < 32).
        let max = res
            .rows
            .iter()
            .map(|r| r[0] as u64)
            .max()
            .unwrap();
        assert!(max > 500, "reservoir stuck on the stream head: max={max}");
    }

    #[test]
    fn reservoir_zero_capacity_is_inert() {
        let mut res = Reservoir::new(0, 7);
        for i in 0..10 {
            res.add(&[i as f32]);
        }
        assert!(res.rows.is_empty());
    }
}
