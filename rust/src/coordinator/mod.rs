//! L3 coordinator: the serving layer around the compiled artifacts.
//!
//! Architecture (vLLM-router-style, scaled to this paper's workload):
//!
//! * [`request`] — typed encode/search requests with completion handles.
//! * [`batcher`] — dynamic batching: requests accumulate until the batch
//!   size is full or a deadline expires, then encode as one parallel
//!   batch (`Batcher::drain_all` is the explicit shutdown flush).
//! * [`router`] — picks the artifact for a request's (kind, d), and the
//!   retrieval backend for a corpus size (`Router::pick_index`, the
//!   resolution behind `IndexBackend::Auto`).
//! * [`metrics`] — throughput counters + a lock-free log-scale latency
//!   histogram; `Metrics::snapshot` merges them with the process-global
//!   [`crate::obs`] stage recorder into a `StatsSnapshot`, served over
//!   the control plane as `ControlRequest::Stats`.
//! * [`registry`] — [`ModelRegistry`]: the hot-swappable model slot.
//!   A `Retrain` control request re-learns the circulant model from the
//!   service's corpus reservoir on a background thread and swaps it in
//!   atomically; each batch resolves the active model exactly once, so
//!   in-flight requests are never dropped or re-encoded.
//! * [`service`] — [`EmbeddingService`]: the public facade wiring the
//!   model registry, batcher and the binary retrieval index together.
//!   `build_index` stamps the registry version its codes were encoded
//!   with, and `search()` rejects an index whose stamp mismatches the live
//!   model ([`crate::error::CbeError::StaleIndex`]) instead of mixing
//!   codes from two models.
//!   Batches are encoded by the parallel batch-encode engine
//!   ([`crate::projections::CirculantProjection::encode_batch_into`]:
//!   scoped-thread fan-out, signs packed directly into `BitCode` words);
//!   bulk corpus encoding takes [`EmbeddingService::encode_corpus`],
//!   which borrows rows, streams them in bounded slabs, and skips the
//!   request channel entirely.
//!
//! Retrieval is configuration, not code: [`ServiceConfig::index`] takes
//! any [`crate::index::IndexBackend`] spec (`auto | linear | mih[:m] |
//! mih-sampled[:m] | sharded:<shards>[:m]`), the CLI exposes it as
//! `--index`, and the embedding_server example reads `CBE_INDEX`. All
//! backends are exact, so flipping the spec never changes results — only
//! throughput.

pub mod request;
pub mod batcher;
pub mod router;
pub mod metrics;
pub mod registry;
pub mod service;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use registry::ModelRegistry;
pub use request::{ControlRequest, EncodeRequest, EncodeResponse, RetrainOutcome, RetrainResult};
pub use router::Router;
pub use service::{EmbeddingService, RetrainConfig, ServiceConfig};
