//! L3 coordinator: the serving layer around the compiled artifacts.
//!
//! Architecture (vLLM-router-style, scaled to this paper's workload):
//!
//! * [`request`] — typed encode/search requests with completion handles.
//! * [`batcher`] — dynamic batching: requests accumulate until the
//!   artifact's batch size is full or a deadline expires, then execute as
//!   one PJRT call (padding the tail).
//! * [`router`] — picks the artifact for a request's (kind, d), and the
//!   retrieval backend for a corpus size (`Router::pick_index`, the
//!   resolution behind `IndexBackend::Auto`).
//! * [`metrics`] — latency histograms + throughput counters.
//! * [`service`] — [`EmbeddingService`]: the public facade wiring encoder
//!   state, batcher, PJRT engine and the binary retrieval index together.
//!
//! Retrieval is configuration, not code: [`ServiceConfig::index`] takes
//! any [`crate::index::IndexBackend`] spec (`auto | linear | mih[:m] |
//! mih-sampled[:m] | sharded:<shards>[:m]`), the CLI exposes it as
//! `--index`, and the embedding_server example reads `CBE_INDEX`. All
//! backends are exact, so flipping the spec never changes results — only
//! throughput.

pub mod request;
pub mod batcher;
pub mod router;
pub mod metrics;
pub mod service;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use request::{EncodeRequest, EncodeResponse};
pub use router::Router;
pub use service::{EmbeddingService, ServiceConfig};
