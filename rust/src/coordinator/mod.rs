//! L3 coordinator: the serving layer around the compiled artifacts.
//!
//! Architecture (vLLM-router-style, scaled to this paper's workload):
//!
//! * [`request`] — typed encode/search requests with completion handles.
//! * [`batcher`] — dynamic batching: requests accumulate until the
//!   artifact's batch size is full or a deadline expires, then execute as
//!   one PJRT call (padding the tail).
//! * [`router`] — picks the artifact for a request's (kind, d).
//! * [`metrics`] — latency histograms + throughput counters.
//! * [`service`] — [`EmbeddingService`]: the public facade wiring encoder
//!   state, batcher, PJRT engine and the binary retrieval index together.

pub mod request;
pub mod batcher;
pub mod router;
pub mod metrics;
pub mod service;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use request::{EncodeRequest, EncodeResponse};
pub use router::Router;
pub use service::{EmbeddingService, ServiceConfig};
