//! Request/response types flowing through the coordinator.
//!
//! Two channels feed the event loop: the data plane
//! ([`EncodeRequest`] → [`EncodeResponse`]) and the control plane
//! ([`ControlRequest`]), which carries operations on the service itself:
//! [`ControlRequest::Retrain`] re-learns the circulant model from the
//! service's corpus sample and hot-swaps it into the
//! [`super::registry::ModelRegistry`] without touching in-flight encodes,
//! and [`ControlRequest::Stats`] answers with a structured
//! [`StatsSnapshot`] of counters + per-stage latency histograms.

use crate::obs::StatsSnapshot;
use crate::opt::TrainReport;
use std::sync::mpsc;
use std::time::Instant;

/// A request to encode one vector into a k-bit binary code.
pub struct EncodeRequest {
    /// Feature vector (len must match a routed model's d).
    pub features: Vec<f32>,
    /// Bits to keep (k ≤ d).
    pub bits: usize,
    /// Enqueue timestamp (latency accounting).
    pub t_enqueue: Instant,
    /// Completion channel.
    pub reply: mpsc::Sender<EncodeResponse>,
}

/// The reply: packed sign bits plus timing breakdown.
#[derive(Clone, Debug)]
pub struct EncodeResponse {
    /// ±1 signs, length = bits requested.
    pub signs: Vec<f32>,
    /// Milliseconds spent queued before the batch launched.
    pub queue_ms: f64,
    /// Milliseconds of batch encode execution (shared across the batch).
    pub exec_ms: f64,
}

/// A control-plane operation on the service.
pub enum ControlRequest {
    /// Re-train the circulant model on the current corpus sample (in a
    /// background thread — the event loop keeps serving) and hot-swap
    /// it into the registry. The reply reports the outcome; an `Err`
    /// (e.g. no corpus sampled yet) leaves the active model untouched.
    Retrain {
        reply: mpsc::Sender<RetrainResult>,
    },
    /// Snapshot the service's statistics (counters, latency histogram,
    /// per-stage timings). Answered inline by the event loop — and also
    /// during shutdown drain, so a final scrape never races teardown.
    Stats {
        reply: mpsc::Sender<StatsSnapshot>,
    },
}

/// Reply to [`ControlRequest::Retrain`]. The error arm is a message, not
/// an `anyhow::Error`, so it crosses the channel cheaply.
pub type RetrainResult = Result<RetrainOutcome, String>;

/// A completed, installed retrain.
#[derive(Clone, Debug)]
pub struct RetrainOutcome {
    /// Registry version of the swapped-in model.
    pub version: u64,
    /// Corpus-sample rows the trainer saw.
    pub rows_used: usize,
    /// The trainer's convergence + performance record.
    pub report: TrainReport,
}

impl EncodeRequest {
    /// Build a request + its receiving handle.
    pub fn new(features: Vec<f32>, bits: usize) -> (EncodeRequest, mpsc::Receiver<EncodeResponse>) {
        let (tx, rx) = mpsc::channel();
        (
            EncodeRequest {
                features,
                bits,
                t_enqueue: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reply_roundtrip() {
        let (req, rx) = EncodeRequest::new(vec![1.0, 2.0], 2);
        req.reply
            .send(EncodeResponse {
                signs: vec![1.0, -1.0],
                queue_ms: 0.1,
                exec_ms: 0.2,
            })
            .unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.signs, vec![1.0, -1.0]);
    }
}
