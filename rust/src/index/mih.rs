//! [`MihIndex`]: exact k-NN in Hamming space via multi-index hashing.
//!
//! Split every b-bit code into m substrings and bucket each substring in
//! its own [`SubstringTable`]. A query probes buckets in increasing
//! substring-radius order and re-ranks candidates with exact full-code
//! Hamming distance, so results are identical to a linear scan — but only
//! a vanishing fraction of the corpus is ever touched when codes carry
//! neighbor structure. See the `crate::index` module docs for the probe
//! schedule and its termination bound.

use super::substring::{for_each_key_at_radius, substring_spans, BuildFastHash, SubstringTable};
use crate::bits::bitcode::BitCode;
use crate::bits::hamming::hamming_words;
use crate::bits::index::Hit;
use std::collections::{BinaryHeap, HashMap};

/// C(n, k), saturating in f64 — used only for probe-vs-sweep cost
/// estimates, never for exact counting.
fn binomial_approx(n: usize, k: usize) -> f64 {
    if k > n {
        return 0.0;
    }
    let k = k.min(n - k);
    let mut acc = 1f64;
    for i in 0..k {
        acc = acc * (n - i) as f64 / (i + 1) as f64;
        if acc > 1e18 {
            return 1e18;
        }
    }
    acc
}

/// The m that minimizes probe work for a uniform corpus: one substring per
/// log2(n) bits (Norouzi et al., "Multi-Index Hashing"), clamped so every
/// substring key fits a u64 and every substring has at least one bit.
pub fn auto_m(bits: usize, n: usize) -> usize {
    let min_m = bits.div_ceil(64).max(1);
    let target = (bits as f64 / (n.max(2) as f64).log2()).round() as usize;
    target.clamp(min_m, bits.max(min_m))
}

/// Multi-index hashing over packed CBE codes. Exact (same contract as
/// [`crate::bits::BinaryIndex`]), with incremental `insert` / `remove` for
/// live corpora. Removed rows are tombstoned in code storage but dropped
/// from every bucket, so probe cost never pays for dead entries.
pub struct MihIndex {
    codes: BitCode,
    ids: Vec<u32>,
    alive: Vec<bool>,
    live: usize,
    slot_of: HashMap<u32, u32, BuildFastHash>,
    tables: Vec<SubstringTable>,
}

impl MihIndex {
    /// Build over a packed corpus with ids `0..n`. `m` = substring count
    /// (None → [`auto_m`]).
    pub fn build(codes: BitCode, m: Option<usize>) -> MihIndex {
        let ids = (0..codes.n as u32).collect();
        MihIndex::build_with_ids(codes, ids, m)
    }

    /// Build with explicit external ids (must be unique).
    pub fn build_with_ids(codes: BitCode, ids: Vec<u32>, m: Option<usize>) -> MihIndex {
        assert_eq!(codes.n, ids.len());
        assert!(codes.bits >= 1, "zero-width codes cannot be indexed");
        let min_m = codes.bits.div_ceil(64).max(1);
        let m = m
            .unwrap_or_else(|| auto_m(codes.bits, codes.n))
            .clamp(min_m, codes.bits);
        let spans = substring_spans(codes.bits, m);
        let mut tables: Vec<SubstringTable> = spans
            .iter()
            .map(|&(start, len)| SubstringTable::new(start, len))
            .collect();
        let mut slot_of =
            HashMap::with_capacity_and_hasher(codes.n, BuildFastHash::default());
        for slot in 0..codes.n {
            let code = codes.code(slot);
            for t in tables.iter_mut() {
                t.insert(t.key_of(code), slot as u32);
            }
            let prev = slot_of.insert(ids[slot], slot as u32);
            assert!(prev.is_none(), "duplicate id {}", ids[slot]);
        }
        let live = codes.n;
        let alive = vec![true; codes.n];
        MihIndex {
            codes,
            ids,
            alive,
            live,
            slot_of,
            tables,
        }
    }

    /// Live (non-removed) code count.
    pub fn len(&self) -> usize {
        self.live
    }
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }
    /// Code length in bits.
    pub fn bits(&self) -> usize {
        self.codes.bits
    }
    /// Substring count m.
    pub fn m(&self) -> usize {
        self.tables.len()
    }
    /// Whether an external id is currently indexed.
    pub fn contains(&self, id: u32) -> bool {
        self.slot_of.contains_key(&id)
    }

    /// Add one packed code under a fresh external id. O(m) bucket appends.
    pub fn insert(&mut self, id: u32, code: &[u64]) {
        assert_eq!(
            code.len(),
            self.codes.words_per_code,
            "code word count mismatch"
        );
        let pad = self.codes.words_per_code * 64 - self.codes.bits;
        if pad > 0 {
            assert_eq!(
                code[code.len() - 1] >> (64 - pad),
                0,
                "padding bits beyond `bits` must be zero"
            );
        }
        assert!(!self.slot_of.contains_key(&id), "duplicate id {id}");
        let slot = self.codes.n as u32;
        self.codes.data.extend_from_slice(code);
        self.codes.n += 1;
        self.ids.push(id);
        self.alive.push(true);
        self.live += 1;
        self.slot_of.insert(id, slot);
        for t in self.tables.iter_mut() {
            t.insert(t.key_of(code), slot);
        }
    }

    /// Add one ±1 sign row (len == bits) under a fresh external id.
    pub fn insert_signs(&mut self, id: u32, signs: &[f32]) {
        let packed = BitCode::from_signs(signs, 1, self.codes.bits);
        self.insert(id, packed.code(0));
    }

    /// Remove by external id; false if absent. O(m · bucket length),
    /// amortized: when tombstones outnumber live rows the storage is
    /// compacted, so churn cannot grow memory (or per-query sweep/bitmap
    /// cost) without bound.
    pub fn remove(&mut self, id: u32) -> bool {
        let Some(slot) = self.slot_of.remove(&id) else {
            return false;
        };
        let code: Vec<u64> = self.codes.code(slot as usize).to_vec();
        for t in self.tables.iter_mut() {
            let removed = t.remove(t.key_of(&code), slot);
            debug_assert!(removed, "bucket entry missing for live slot");
        }
        self.alive[slot as usize] = false;
        self.live -= 1;
        if self.codes.n > 64 && self.live * 2 < self.codes.n {
            self.compact();
        }
        true
    }

    /// Physical storage slots, tombstones included (diagnostics/tests).
    pub fn storage_slots(&self) -> usize {
        self.codes.n
    }

    /// Rebuild storage and tables over the live rows only.
    fn compact(&mut self) {
        let wpc = self.codes.words_per_code;
        let mut codes = BitCode::new(0, self.codes.bits);
        codes.data.reserve(self.live * wpc);
        let mut ids = Vec::with_capacity(self.live);
        for slot in 0..self.codes.n {
            if self.alive[slot] {
                codes.data.extend_from_slice(self.codes.code(slot));
                codes.n += 1;
                ids.push(self.ids[slot]);
            }
        }
        *self = MihIndex::build_with_ids(codes, ids, Some(self.tables.len()));
    }

    /// Exact top-k by Hamming distance; ties broken by ascending id, hits
    /// sorted by `(dist, id)` — the same contract as
    /// [`crate::bits::BinaryIndex::search`].
    ///
    /// Probes buckets in rounds of increasing substring radius and stops
    /// at the pigeonhole bound (see the `crate::index` module docs). When
    /// a round's key enumeration would cost more than finishing with a
    /// direct sweep of the not-yet-seen slots — tiny corpora, adversarial
    /// `m`, or neighbor-free uniform codes — it sweeps instead, so the
    /// worst case is bounded by the linear scan it replaces.
    pub fn search(&self, q: &[u64], k: usize) -> Vec<Hit> {
        assert_eq!(q.len(), self.codes.words_per_code, "query word count");
        let k = k.min(self.live);
        if k == 0 {
            return Vec::new();
        }
        let m = self.tables.len() as u32;
        let mut visited = vec![0u64; self.codes.n.div_ceil(64)];
        // Bounded max-heap of (dist, id): holds the k lexicographically
        // smallest pairs seen so far.
        let mut heap: BinaryHeap<(u32, u32)> = BinaryHeap::with_capacity(k + 1);
        let push = |heap: &mut BinaryHeap<(u32, u32)>, cand: (u32, u32)| {
            if heap.len() < k {
                heap.push(cand);
            } else if let Some(&top) = heap.peek() {
                if cand < top {
                    heap.pop();
                    heap.push(cand);
                }
            }
        };
        // Live slots not yet re-ranked; the sweep-cutover budget.
        let mut unseen = self.live;
        let max_radius = self.tables.iter().map(|t| t.len).max().unwrap_or(0);
        for s in 0..=max_radius {
            let round_keys: f64 = self
                .tables
                .iter()
                .map(|t| binomial_approx(t.len, s))
                .sum();
            if round_keys > unseen as f64 {
                // Cheaper to finish exhaustively than to enumerate keys.
                for si in 0..self.codes.n {
                    let (w, b) = (si / 64, si % 64);
                    if visited[w] >> b & 1 == 1 || !self.alive[si] {
                        continue;
                    }
                    push(
                        &mut heap,
                        (hamming_words(q, self.codes.code(si)), self.ids[si]),
                    );
                }
                break;
            }
            for t in &self.tables {
                let qkey = t.key_of(q);
                for_each_key_at_radius(qkey, t.len, s, &mut |key| {
                    let Some(bucket) = t.bucket(key) else { return };
                    for &slot in bucket {
                        let (w, b) = ((slot / 64) as usize, slot % 64);
                        if visited[w] >> b & 1 == 1 {
                            continue;
                        }
                        visited[w] |= 1u64 << b;
                        let si = slot as usize;
                        if !self.alive[si] {
                            continue;
                        }
                        unseen -= 1;
                        push(
                            &mut heap,
                            (hamming_words(q, self.codes.code(si)), self.ids[si]),
                        );
                    }
                });
            }
            // Pigeonhole bound: after probing every table at all substring
            // radii ≤ s, any unseen code differs by ≥ m·(s+1) overall. Once
            // the current k-th best is strictly inside that bound no unseen
            // code can displace it (ids only break ties at equal distance).
            if heap.len() == k {
                if let Some(&(worst, _)) = heap.peek() {
                    if worst < m * (s as u32 + 1) {
                        break;
                    }
                }
            }
        }
        let mut hits: Vec<Hit> = heap
            .into_iter()
            .map(|(dist, id)| Hit { id, dist })
            .collect();
        hits.sort_by_key(|h| (h.dist, h.id));
        hits
    }

    /// Batch search, query order preserved.
    pub fn search_batch(&self, queries: &BitCode, k: usize) -> Vec<Vec<Hit>> {
        (0..queries.n)
            .map(|i| self.search(queries.code(i), k))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::BinaryIndex;
    use crate::util::rng::Pcg64;

    fn random_codes(rng: &mut Pcg64, n: usize, bits: usize) -> BitCode {
        BitCode::from_signs(&rng.sign_vec(n * bits), n, bits)
    }

    #[test]
    fn matches_linear_scan_small() {
        let mut rng = Pcg64::new(201);
        for (n, bits, m) in [(60, 32, Some(4)), (120, 96, None), (40, 256, Some(8))] {
            let db = random_codes(&mut rng, n, bits);
            let mih = MihIndex::build(db.clone(), m);
            let linear = BinaryIndex::new(db);
            let queries = random_codes(&mut rng, 6, bits);
            for qi in 0..queries.n {
                let a = mih.search(queries.code(qi), 9);
                let b = linear.search(queries.code(qi), 9);
                assert_eq!(a, b, "n={n} bits={bits} m={m:?} qi={qi}");
            }
        }
    }

    #[test]
    fn self_query_returns_self() {
        let mut rng = Pcg64::new(202);
        let db = random_codes(&mut rng, 50, 128);
        let mih = MihIndex::build(db.clone(), Some(4));
        for i in [0usize, 21, 49] {
            let hits = mih.search(db.code(i), 1);
            assert_eq!(hits[0].dist, 0);
        }
    }

    #[test]
    fn k_exceeding_live_truncates() {
        let mut rng = Pcg64::new(203);
        let db = random_codes(&mut rng, 5, 64);
        let mih = MihIndex::build(db, None);
        assert_eq!(mih.search(&[0u64], 100).len(), 5);
        assert!(mih.search(&[0u64], 0).is_empty());
    }

    #[test]
    fn insert_then_remove_roundtrip() {
        let mut rng = Pcg64::new(204);
        let db = random_codes(&mut rng, 30, 96);
        let mut mih = MihIndex::build(db.clone(), Some(6));
        let extra = random_codes(&mut rng, 1, 96);
        mih.insert(1000, extra.code(0));
        assert_eq!(mih.len(), 31);
        assert!(mih.contains(1000));
        let hits = mih.search(extra.code(0), 1);
        assert_eq!(hits[0].dist, 0);
        assert_eq!(hits[0].id, 1000);

        assert!(mih.remove(1000));
        assert!(!mih.remove(1000));
        assert_eq!(mih.len(), 30);
        let hits = mih.search(extra.code(0), 30);
        assert!(hits.iter().all(|h| h.id != 1000), "removed id must not surface");
    }

    #[test]
    fn churn_compacts_tombstones_and_stays_exact() {
        let mut rng = Pcg64::new(205);
        let bits = 64;
        let db = random_codes(&mut rng, 100, bits);
        let mut mih = MihIndex::build(db.clone(), Some(4));
        for id in 0..80u32 {
            assert!(mih.remove(id));
        }
        assert_eq!(mih.len(), 20);
        assert!(
            mih.storage_slots() < 100,
            "tombstones must be compacted; slots={}",
            mih.storage_slots()
        );
        // Survivors are rows 80..100 with their original ids; the index
        // must still agree with a fresh linear scan over exactly those.
        let mut survivors = BitCode::new(20, bits);
        for (i, slot) in (80..100).enumerate() {
            let wpc = survivors.words_per_code;
            survivors.data[i * wpc..(i + 1) * wpc].copy_from_slice(db.code(slot));
        }
        let linear = BinaryIndex::with_ids(survivors, (80u32..100).collect());
        let q = random_codes(&mut rng, 1, bits);
        assert_eq!(mih.search(q.code(0), 7), linear.search(q.code(0), 7));
    }

    #[test]
    fn empty_index_returns_nothing() {
        let mih = MihIndex::build(BitCode::new(0, 64), None);
        assert!(mih.is_empty());
        assert!(mih.search(&[0u64], 5).is_empty());
    }

    #[test]
    fn auto_m_sane() {
        assert_eq!(auto_m(256, 1_000_000), 13); // 256 / ~19.9 rounds to 13
        assert!(auto_m(64, 1 << 16) >= 1);
        // long codes: never below the u64-key floor
        assert!(auto_m(1 << 17, 1000) >= (1 << 17) / 64);
        // tiny corpora: never above bits
        assert!(auto_m(4, 2) <= 4);
    }
}
