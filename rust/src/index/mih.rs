//! [`MihIndex`]: exact k-NN in Hamming space via multi-index hashing.
//!
//! Split every b-bit code into m substrings and bucket each substring in
//! its own [`SubstringTable`]. A query probes buckets in increasing
//! substring-radius order and re-ranks candidates with exact full-code
//! Hamming distance, so results are identical to a linear scan — but only
//! a vanishing fraction of the corpus is ever touched when codes carry
//! neighbor structure. See the `crate::index` module docs for the probe
//! schedule and its termination bound.
//!
//! Two serving-scale mechanisms live here rather than in the tables:
//!
//! * **Substring scheme** ([`SubstringScheme`]): substrings are either
//!   contiguous bit spans (the classic MIH layout) or seeded-permutation
//!   **bit samples** ([`super::substring::sampled_positions`]) that
//!   decorrelate adjacent circulant-embedding bits before bucketing.
//! * **Generation-stamped visited scratch**: deduplicating candidates used
//!   to allocate (and O(n)-zero) a fresh bitmap per query; the index now
//!   pools `u32` stamp buffers behind a mutex and bumps a generation
//!   counter instead, so the per-query dedup cost is O(candidates), not
//!   O(n) — while `search(&self)` stays `Sync` for the sharded fan-out.
//!
//! Re-ranking rides the [`crate::bits::hamming::hamming_words`] dispatch:
//! per-candidate distances take the AVX2 popcount kernel at ≥ 8 words per
//! code (512-bit and up), while the ≤ 4-word windows the paper's serving
//! shapes mostly probe stay on the scalar unroll, where the in-register
//! table setup would dominate a single short window. Either way the
//! distances are bit-identical (strict tier of the SIMD contract), so the
//! exactness guarantee above is unaffected by the gate.

use super::substring::{
    for_each_key_at_radius, sampled_positions, substring_spans, BuildFastHash, KeySource,
    SubstringTable,
};
use crate::bits::bitcode::BitCode;
use crate::bits::hamming::hamming_words;
use crate::bits::index::Hit;
use crate::obs::{self, Counter, Stage};
use std::collections::{BinaryHeap, HashMap};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Seed of the bit-sampling permutation. A fixed constant: the permutation
/// must be reproducible so a compacted/rebuilt index buckets exactly like
/// the original.
const SAMPLE_SEED: u64 = 0x53_4145_4d50_4c44;

/// How substring keys are drawn from the full code.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubstringScheme {
    /// Contiguous bit spans (Norouzi et al.'s layout). Optimal when code
    /// bits are independent.
    Contiguous,
    /// Seeded-permutation bit sampling: each table keys on a random
    /// (deterministic) subset of bit positions. Adjacent CBE bits are
    /// correlated (Yu et al., 2015), which skews contiguous-span bucket
    /// occupancy; sampling restores the near-uniform bucket distribution
    /// the probe-cost model assumes. Exactness is unaffected — the groups
    /// still partition all bits, so the pigeonhole bound holds.
    Sampled,
}

/// C(n, k), saturating in f64 — used only for probe-vs-sweep cost
/// estimates, never for exact counting.
fn binomial_approx(n: usize, k: usize) -> f64 {
    if k > n {
        return 0.0;
    }
    let k = k.min(n - k);
    let mut acc = 1f64;
    for i in 0..k {
        acc = acc * (n - i) as f64 / (i + 1) as f64;
        if acc > 1e18 {
            return 1e18;
        }
    }
    acc
}

/// The m that minimizes probe work for a uniform corpus: one substring per
/// log2(n) bits (Norouzi et al., "Multi-Index Hashing"), clamped so every
/// substring key fits a u64 and every substring has at least one bit.
pub fn auto_m(bits: usize, n: usize) -> usize {
    let min_m = bits.div_ceil(64).max(1);
    let target = (bits as f64 / (n.max(2) as f64).log2()).round() as usize;
    target.clamp(min_m, bits.max(min_m))
}

/// One reusable visited-stamp buffer (`stamps[slot] == gen` ⇔ the slot was
/// already re-ranked by the query currently holding the buffer) plus the
/// raw-candidate gather list each probe round fills before dedup.
struct Scratch {
    gen: u32,
    stamps: Vec<u32>,
    cands: Vec<u32>,
}

/// Pool of stamp buffers. The mutex is held only to take/return a buffer
/// (two lock ops per query, never per candidate), which keeps `MihIndex`
/// `Sync` so `ShardedIndex` can fan a single query out across shards on
/// scoped threads.
#[derive(Default)]
struct ScratchPool(Mutex<Vec<Scratch>>);

impl ScratchPool {
    /// Borrow a buffer covering `n` slots with a fresh generation. New or
    /// grown regions are zeroed; the generation starts at 1, so a zeroed
    /// stamp can never read as visited. On u32 wrap-around the buffer is
    /// re-zeroed — once every 2³² queries instead of every query.
    fn take(&self, n: usize) -> Scratch {
        let mut s = self
            .0
            .lock()
            .expect("scratch pool poisoned")
            .pop()
            .unwrap_or(Scratch {
                gen: 0,
                stamps: Vec::new(),
                cands: Vec::new(),
            });
        if s.stamps.len() < n {
            s.stamps.resize(n, 0);
        }
        s.gen = s.gen.wrapping_add(1);
        if s.gen == 0 {
            s.stamps.fill(0);
            s.gen = 1;
        }
        s
    }

    /// Return a buffer to the pool. The pool is capped at roughly the
    /// core count: buffers beyond that only exist during oversubscribed
    /// bursts, and retaining them would pin `4·n` bytes each forever —
    /// excess buffers are dropped instead. The cap is computed once
    /// (`available_parallelism` is a syscall; this is the per-query path).
    fn put(&self, s: Scratch) {
        static POOL_CAP: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
        let cap = *POOL_CAP.get_or_init(|| {
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(8)
        });
        let mut pool = self.0.lock().expect("scratch pool poisoned");
        if pool.len() < cap {
            pool.push(s);
        }
    }
}

/// Multi-index hashing over packed CBE codes. Exact (same contract as
/// [`crate::bits::BinaryIndex`]), with incremental `insert` / `remove` for
/// live corpora. Removed rows are tombstoned in code storage but dropped
/// from every bucket, so probe cost never pays for dead entries.
pub struct MihIndex {
    codes: BitCode,
    ids: Vec<u32>,
    alive: Vec<bool>,
    live: usize,
    slot_of: HashMap<u32, u32, BuildFastHash>,
    tables: Vec<SubstringTable>,
    scheme: SubstringScheme,
    scratch: ScratchPool,
}

impl MihIndex {
    /// Build over a packed corpus with ids `0..n`, contiguous substrings.
    /// `m` = substring count (None → [`auto_m`]).
    pub fn build(codes: BitCode, m: Option<usize>) -> MihIndex {
        let ids = (0..codes.n as u32).collect();
        MihIndex::build_with_ids(codes, ids, m)
    }

    /// Build with explicit external ids (must be unique), contiguous
    /// substrings.
    pub fn build_with_ids(codes: BitCode, ids: Vec<u32>, m: Option<usize>) -> MihIndex {
        MihIndex::build_inner(codes, ids, m, SubstringScheme::Contiguous)
    }

    /// Build over a packed corpus with ids `0..n`, **bit-sampled**
    /// substrings (see [`SubstringScheme::Sampled`]).
    pub fn build_sampled(codes: BitCode, m: Option<usize>) -> MihIndex {
        let ids = (0..codes.n as u32).collect();
        MihIndex::build_sampled_with_ids(codes, ids, m)
    }

    /// Build with explicit external ids, bit-sampled substrings.
    pub fn build_sampled_with_ids(codes: BitCode, ids: Vec<u32>, m: Option<usize>) -> MihIndex {
        MihIndex::build_inner(codes, ids, m, SubstringScheme::Sampled)
    }

    fn build_inner(
        codes: BitCode,
        ids: Vec<u32>,
        m: Option<usize>,
        scheme: SubstringScheme,
    ) -> MihIndex {
        assert_eq!(codes.n, ids.len());
        assert!(codes.bits >= 1, "zero-width codes cannot be indexed");
        let min_m = codes.bits.div_ceil(64).max(1);
        let m = m
            .unwrap_or_else(|| auto_m(codes.bits, codes.n))
            .clamp(min_m, codes.bits);
        let sources: Vec<KeySource> = match scheme {
            SubstringScheme::Contiguous => substring_spans(codes.bits, m)
                .into_iter()
                .map(|(start, len)| KeySource::Span { start, len })
                .collect(),
            SubstringScheme::Sampled => sampled_positions(codes.bits, m, SAMPLE_SEED)
                .into_iter()
                .map(|positions| KeySource::Sampled {
                    positions: positions.into_boxed_slice(),
                })
                .collect(),
        };
        // Two-pass bulk build per table: one exactly-sized postings arena
        // each, zero per-bucket allocations.
        let tables: Vec<SubstringTable> = sources
            .into_iter()
            .map(|source| SubstringTable::build(source, &codes))
            .collect();
        let mut slot_of = HashMap::with_capacity_and_hasher(codes.n, BuildFastHash::default());
        for (slot, &id) in ids.iter().enumerate() {
            let prev = slot_of.insert(id, slot as u32);
            assert!(prev.is_none(), "duplicate id {id}");
        }
        let live = codes.n;
        let alive = vec![true; codes.n];
        MihIndex {
            codes,
            ids,
            alive,
            live,
            slot_of,
            tables,
            scheme,
            scratch: ScratchPool::default(),
        }
    }

    /// Live (non-removed) code count.
    pub fn len(&self) -> usize {
        self.live
    }
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }
    /// Code length in bits.
    pub fn bits(&self) -> usize {
        self.codes.bits
    }
    /// Substring count m.
    pub fn m(&self) -> usize {
        self.tables.len()
    }
    /// The substring scheme this index buckets with.
    pub fn scheme(&self) -> SubstringScheme {
        self.scheme
    }
    /// Whether an external id is currently indexed.
    pub fn contains(&self, id: u32) -> bool {
        self.slot_of.contains_key(&id)
    }

    /// Add one packed code under a fresh external id. O(m) bucket appends.
    pub fn insert(&mut self, id: u32, code: &[u64]) {
        assert_eq!(
            code.len(),
            self.codes.words_per_code,
            "code word count mismatch"
        );
        let pad = self.codes.words_per_code * 64 - self.codes.bits;
        if pad > 0 {
            assert_eq!(
                code[code.len() - 1] >> (64 - pad),
                0,
                "padding bits beyond `bits` must be zero"
            );
        }
        assert!(!self.slot_of.contains_key(&id), "duplicate id {id}");
        let slot = self.codes.n as u32;
        self.codes.data.to_mut().extend_from_slice(code);
        self.codes.n += 1;
        self.ids.push(id);
        self.alive.push(true);
        self.live += 1;
        self.slot_of.insert(id, slot);
        for t in self.tables.iter_mut() {
            t.insert(t.key_of(code), slot);
        }
    }

    /// Add one ±1 sign row (len == bits) under a fresh external id.
    pub fn insert_signs(&mut self, id: u32, signs: &[f32]) {
        let packed = BitCode::from_signs(signs, 1, self.codes.bits);
        self.insert(id, packed.code(0));
    }

    /// Remove by external id; false if absent. O(m · bucket length),
    /// amortized: when tombstones outnumber live rows the storage is
    /// compacted, so churn cannot grow memory (or per-query sweep/stamp
    /// cost) without bound. (Each table's postings arena additionally
    /// self-compacts; see [`SubstringTable`].)
    pub fn remove(&mut self, id: u32) -> bool {
        let Some(slot) = self.slot_of.remove(&id) else {
            return false;
        };
        let code: Vec<u64> = self.codes.code(slot as usize).to_vec();
        for t in self.tables.iter_mut() {
            let removed = t.remove(t.key_of(&code), slot);
            debug_assert!(removed, "bucket entry missing for live slot");
        }
        self.alive[slot as usize] = false;
        self.live -= 1;
        if self.codes.n > 64 && self.live * 2 < self.codes.n {
            self.compact();
        }
        true
    }

    /// Physical storage slots, tombstones included (diagnostics/tests).
    pub fn storage_slots(&self) -> usize {
        self.codes.n
    }

    /// Raw storage views for the snapshot writer: packed codes, external
    /// ids and the alive mask (all indexed by storage slot, tombstones
    /// included), plus the live tables. The writer compacts tombstones
    /// out on its way to disk, so dead slots never reach a snapshot.
    pub(crate) fn storage_parts(&self) -> (&BitCode, &[u32], &[bool], &[SubstringTable]) {
        (&self.codes, &self.ids, &self.alive, &self.tables)
    }

    /// Reassemble an index from snapshot parts. Every row is live (the
    /// writer compacted tombstones out), ids are unique and tables were
    /// rebuilt over the same slot numbering — all pre-validated by the
    /// snapshot loader, which is the only caller.
    pub(crate) fn from_parts(
        codes: BitCode,
        ids: Vec<u32>,
        tables: Vec<SubstringTable>,
        scheme: SubstringScheme,
    ) -> MihIndex {
        debug_assert_eq!(codes.n, ids.len());
        let mut slot_of = HashMap::with_capacity_and_hasher(codes.n, BuildFastHash::default());
        for (slot, &id) in ids.iter().enumerate() {
            let prev = slot_of.insert(id, slot as u32);
            debug_assert!(prev.is_none(), "duplicate id {id}");
        }
        let live = codes.n;
        let alive = vec![true; codes.n];
        MihIndex {
            codes,
            ids,
            alive,
            live,
            slot_of,
            tables,
            scheme,
            scratch: ScratchPool::default(),
        }
    }

    /// Rebuild storage and tables over the live rows only, preserving the
    /// substring scheme (the sampling permutation is seed-deterministic,
    /// so a rebuilt index buckets exactly like the original).
    fn compact(&mut self) {
        let wpc = self.codes.words_per_code;
        let mut codes = BitCode::new(0, self.codes.bits);
        codes.data.to_mut().reserve(self.live * wpc);
        let mut ids = Vec::with_capacity(self.live);
        for slot in 0..self.codes.n {
            if self.alive[slot] {
                codes.data.to_mut().extend_from_slice(self.codes.code(slot));
                codes.n += 1;
                ids.push(self.ids[slot]);
            }
        }
        *self = MihIndex::build_inner(codes, ids, Some(self.tables.len()), self.scheme);
    }

    /// Exact top-k by Hamming distance; ties broken by ascending id, hits
    /// sorted by `(dist, id)` — the same contract as
    /// [`crate::bits::BinaryIndex::search`].
    ///
    /// Probes buckets in rounds of increasing substring radius and stops
    /// at the pigeonhole bound (see the `crate::index` module docs). When
    /// a round's key enumeration would cost more than finishing with a
    /// direct sweep of the not-yet-seen slots — tiny corpora, adversarial
    /// `m`, or neighbor-free uniform codes — it sweeps instead, so the
    /// worst case is bounded by the linear scan it replaces.
    ///
    /// Candidate dedup uses a pooled generation-stamped scratch buffer, so
    /// a query pays for the candidates it touches, not an O(n) bitmap
    /// memset.
    ///
    /// Each round runs as three explicit phases — **probe** (key
    /// enumeration + bucket gather), **candidate-dedup** (generation-stamp
    /// filter), **re-rank** (exact Hamming + heap) — reported per query to
    /// the [`crate::obs`] recorder as stage timings and probe/candidate/
    /// re-rank totals. The bounded min-k heap is push-order-invariant, so
    /// batching pushes after the gather returns exactly the results the
    /// old interleaved loop did.
    pub fn search(&self, q: &[u64], k: usize) -> Vec<Hit> {
        assert_eq!(q.len(), self.codes.words_per_code, "query word count");
        let k = k.min(self.live);
        if k == 0 {
            return Vec::new();
        }
        let m = self.tables.len() as u32;
        let mut scratch = self.scratch.take(self.codes.n);
        let gen = scratch.gen;
        let Scratch { stamps, cands, .. } = &mut scratch;
        // Bounded max-heap of (dist, id): holds the k lexicographically
        // smallest pairs seen so far.
        let mut heap: BinaryHeap<(u32, u32)> = BinaryHeap::with_capacity(k + 1);
        let push = |heap: &mut BinaryHeap<(u32, u32)>, cand: (u32, u32)| {
            if heap.len() < k {
                heap.push(cand);
            } else if let Some(&top) = heap.peek() {
                if cand < top {
                    heap.pop();
                    heap.push(cand);
                }
            }
        };
        // Live slots not yet re-ranked; the sweep-cutover budget.
        let mut unseen = self.live;
        // Per-table query keys are invariant across rounds; hoisted because
        // sampled-scheme extraction is an O(key_bits) gather, not O(1).
        let qkeys: Vec<u64> = self.tables.iter().map(|t| t.key_of(q)).collect();
        let max_radius = self.tables.iter().map(|t| t.key_bits()).max().unwrap_or(0);
        // Per-query accounting, flushed to the global recorder once at the
        // end; `on == false` costs one branch per phase and nothing else.
        let on = obs::enabled();
        let (mut n_probes, mut n_cands, mut n_reranked) = (0u64, 0u64, 0u64);
        let mut probe_dur = Duration::ZERO;
        let mut dedup_dur = Duration::ZERO;
        let mut rerank_dur = Duration::ZERO;
        for s in 0..=max_radius {
            let round_keys: f64 = self
                .tables
                .iter()
                .map(|t| binomial_approx(t.key_bits(), s))
                .sum();
            if round_keys > unseen as f64 {
                // Cheaper to finish exhaustively than to enumerate keys.
                // The sweep is re-rank work: exact distances on every
                // not-yet-seen row.
                let t0 = on.then(Instant::now);
                for si in 0..self.codes.n {
                    if stamps[si] == gen || !self.alive[si] {
                        continue;
                    }
                    n_reranked += 1;
                    push(
                        &mut heap,
                        (hamming_words(q, self.codes.code(si)), self.ids[si]),
                    );
                }
                if let Some(t0) = t0 {
                    rerank_dur += t0.elapsed();
                }
                break;
            }
            // Probe: enumerate candidate keys at substring radius s and
            // gather raw postings (duplicates included — one slot can land
            // in several tables' buckets).
            let t_probe = on.then(Instant::now);
            cands.clear();
            for (t, &qkey) in self.tables.iter().zip(&qkeys) {
                for_each_key_at_radius(qkey, t.key_bits(), s, &mut |key| {
                    n_probes += 1;
                    if let Some(bucket) = t.bucket(key) {
                        cands.extend_from_slice(bucket);
                    }
                });
            }
            n_cands += cands.len() as u64;
            // Candidate-dedup: generation-stamp filter, in place. Dead
            // slots are stamped too (so a later round skips them cheaply)
            // but only live first-sightings spend the re-rank budget.
            let t_dedup = on.then(Instant::now);
            if let (Some(a), Some(b)) = (t_probe, t_dedup) {
                probe_dur += b.duration_since(a);
            }
            cands.retain(|&slot| {
                let si = slot as usize;
                if stamps[si] == gen {
                    return false;
                }
                stamps[si] = gen;
                if !self.alive[si] {
                    return false;
                }
                unseen -= 1;
                true
            });
            // Re-rank: exact full-code Hamming on the deduped survivors.
            let t_rerank = on.then(Instant::now);
            if let (Some(a), Some(b)) = (t_dedup, t_rerank) {
                dedup_dur += b.duration_since(a);
            }
            n_reranked += cands.len() as u64;
            for &slot in cands.iter() {
                let si = slot as usize;
                push(
                    &mut heap,
                    (hamming_words(q, self.codes.code(si)), self.ids[si]),
                );
            }
            if let Some(t0) = t_rerank {
                rerank_dur += t0.elapsed();
            }
            // Pigeonhole bound: after probing every table at all substring
            // radii ≤ s, any unseen code differs by ≥ m·(s+1) overall. Once
            // the current k-th best is strictly inside that bound no unseen
            // code can displace it (ids only break ties at equal distance).
            if heap.len() == k {
                if let Some(&(worst, _)) = heap.peek() {
                    if worst < m * (s as u32 + 1) {
                        break;
                    }
                }
            }
        }
        self.scratch.put(scratch);
        if on {
            let rec = obs::global();
            rec.record(Stage::Probe, probe_dur);
            rec.record(Stage::CandidateDedup, dedup_dur);
            rec.record(Stage::ReRank, rerank_dur);
            rec.add(Counter::Probes, n_probes);
            rec.add(Counter::Candidates, n_cands);
            rec.add(Counter::Reranked, n_reranked);
        }
        let mut hits: Vec<Hit> = heap
            .into_iter()
            .map(|(dist, id)| Hit { id, dist })
            .collect();
        hits.sort_by_key(|h| (h.dist, h.id));
        hits
    }

    /// Batch search, query order preserved.
    pub fn search_batch(&self, queries: &BitCode, k: usize) -> Vec<Vec<Hit>> {
        (0..queries.n)
            .map(|i| self.search(queries.code(i), k))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::BinaryIndex;
    use crate::util::rng::Pcg64;

    fn random_codes(rng: &mut Pcg64, n: usize, bits: usize) -> BitCode {
        BitCode::from_signs(&rng.sign_vec(n * bits), n, bits)
    }

    #[test]
    fn matches_linear_scan_small() {
        let mut rng = Pcg64::new(201);
        for (n, bits, m) in [(60, 32, Some(4)), (120, 96, None), (40, 256, Some(8))] {
            let db = random_codes(&mut rng, n, bits);
            let mih = MihIndex::build(db.clone(), m);
            let sampled = MihIndex::build_sampled(db.clone(), m);
            let linear = BinaryIndex::new(db);
            let queries = random_codes(&mut rng, 6, bits);
            for qi in 0..queries.n {
                let b = linear.search(queries.code(qi), 9);
                assert_eq!(
                    mih.search(queries.code(qi), 9),
                    b,
                    "contiguous n={n} bits={bits} m={m:?} qi={qi}"
                );
                assert_eq!(
                    sampled.search(queries.code(qi), 9),
                    b,
                    "sampled n={n} bits={bits} m={m:?} qi={qi}"
                );
            }
        }
    }

    #[test]
    fn self_query_returns_self() {
        let mut rng = Pcg64::new(202);
        let db = random_codes(&mut rng, 50, 128);
        let mih = MihIndex::build(db.clone(), Some(4));
        for i in [0usize, 21, 49] {
            let hits = mih.search(db.code(i), 1);
            assert_eq!(hits[0].dist, 0);
        }
    }

    #[test]
    fn k_exceeding_live_truncates() {
        let mut rng = Pcg64::new(203);
        let db = random_codes(&mut rng, 5, 64);
        let mih = MihIndex::build(db, None);
        assert_eq!(mih.search(&[0u64], 100).len(), 5);
        assert!(mih.search(&[0u64], 0).is_empty());
    }

    #[test]
    fn insert_then_remove_roundtrip() {
        let mut rng = Pcg64::new(204);
        let db = random_codes(&mut rng, 30, 96);
        for build in [MihIndex::build, MihIndex::build_sampled] {
            let mut mih = build(db.clone(), Some(6));
            let extra = random_codes(&mut rng, 1, 96);
            mih.insert(1000, extra.code(0));
            assert_eq!(mih.len(), 31);
            assert!(mih.contains(1000));
            let hits = mih.search(extra.code(0), 1);
            assert_eq!(hits[0].dist, 0);
            assert_eq!(hits[0].id, 1000);

            assert!(mih.remove(1000));
            assert!(!mih.remove(1000));
            assert_eq!(mih.len(), 30);
            let hits = mih.search(extra.code(0), 30);
            assert!(
                hits.iter().all(|h| h.id != 1000),
                "removed id must not surface"
            );
        }
    }

    #[test]
    fn churn_compacts_tombstones_and_stays_exact() {
        let mut rng = Pcg64::new(205);
        let bits = 64;
        let db = random_codes(&mut rng, 100, bits);
        let mut mih = MihIndex::build(db.clone(), Some(4));
        for id in 0..80u32 {
            assert!(mih.remove(id));
        }
        assert_eq!(mih.len(), 20);
        assert!(
            mih.storage_slots() < 100,
            "tombstones must be compacted; slots={}",
            mih.storage_slots()
        );
        // Survivors are rows 80..100 with their original ids; the index
        // must still agree with a fresh linear scan over exactly those.
        let mut survivors = BitCode::new(20, bits);
        for (i, slot) in (80..100).enumerate() {
            let wpc = survivors.words_per_code;
            survivors.data[i * wpc..(i + 1) * wpc].copy_from_slice(db.code(slot));
        }
        let linear = BinaryIndex::with_ids(survivors, (80u32..100).collect());
        let q = random_codes(&mut rng, 1, bits);
        assert_eq!(mih.search(q.code(0), 7), linear.search(q.code(0), 7));
    }

    #[test]
    fn compact_preserves_sampled_scheme() {
        let mut rng = Pcg64::new(206);
        let bits = 96;
        let db = random_codes(&mut rng, 100, bits);
        let mut mih = MihIndex::build_sampled(db.clone(), Some(6));
        for id in 0..80u32 {
            assert!(mih.remove(id));
        }
        assert_eq!(mih.scheme(), SubstringScheme::Sampled);
        assert!(mih.storage_slots() < 100, "compaction must have run");
        // Post-compaction searches stay exact.
        let mut survivors = BitCode::new(20, bits);
        for (i, slot) in (80..100).enumerate() {
            let wpc = survivors.words_per_code;
            survivors.data[i * wpc..(i + 1) * wpc].copy_from_slice(db.code(slot));
        }
        let linear = BinaryIndex::with_ids(survivors, (80u32..100).collect());
        let q = random_codes(&mut rng, 1, bits);
        assert_eq!(mih.search(q.code(0), 9), linear.search(q.code(0), 9));
    }

    #[test]
    fn stamped_scratch_is_reused_across_queries() {
        // Back-to-back queries must stay exact while the pool recycles one
        // buffer (the second query's generation invalidates the first's
        // stamps without any re-zeroing).
        let mut rng = Pcg64::new(207);
        let db = random_codes(&mut rng, 120, 64);
        let mih = MihIndex::build(db.clone(), Some(4));
        let linear = BinaryIndex::new(db);
        let queries = random_codes(&mut rng, 30, 64);
        for qi in 0..queries.n {
            assert_eq!(
                mih.search(queries.code(qi), 5),
                linear.search(queries.code(qi), 5),
                "qi={qi}"
            );
        }
        // The sequential batch path reuses a single pooled buffer.
        assert_eq!(mih.scratch.0.lock().unwrap().len(), 1);
    }

    #[test]
    fn empty_index_returns_nothing() {
        let mih = MihIndex::build(BitCode::new(0, 64), None);
        assert!(mih.is_empty());
        assert!(mih.search(&[0u64], 5).is_empty());
    }

    #[test]
    fn auto_m_sane() {
        assert_eq!(auto_m(256, 1_000_000), 13); // 256 / ~19.9 rounds to 13
        assert!(auto_m(64, 1 << 16) >= 1);
        // long codes: never below the u64-key floor
        assert!(auto_m(1 << 17, 1000) >= (1 << 17) / 64);
        // tiny corpora: never above bits
        assert!(auto_m(4, 2) <= 4);
    }
}
