//! [`ShardedIndex`]: corpus-partitioned multi-index hashing.
//!
//! The corpus is split round-robin across worker shards, each an
//! independent [`MihIndex`]. A single query fans out across shards on
//! scoped threads and merges the per-shard top-k; batch queries instead
//! parallelize across queries (better cache behavior, same exactness).
//! Because every shard is exact and the merge keeps the k smallest
//! `(dist, id)` pairs, the result is identical to one big linear scan.
//!
//! The fan-out calls `MihIndex::search(&self, ..)` concurrently from
//! several threads, which is only legal because `MihIndex` is `Sync`:
//! its per-query visited scratch is a pooled, generation-stamped buffer
//! behind a mutex rather than interior state mutated in place — see the
//! [`super::mih`] module docs.

use super::mih::MihIndex;
use super::substring::BuildFastHash;
use crate::bits::bitcode::BitCode;
use crate::bits::index::{par_map_queries, Hit};
use std::collections::HashSet;

/// Below this corpus size the thread fan-out costs more than it saves and
/// single-query search degrades to a sequential shard sweep.
const PARALLEL_CUTOVER: usize = 16_384;

/// Keep the k lexicographically smallest `(dist, id)` hits of several
/// already-sorted per-shard result lists.
fn merge_topk(per_shard: Vec<Vec<Hit>>, k: usize) -> Vec<Hit> {
    let mut all: Vec<Hit> = per_shard.into_iter().flatten().collect();
    all.sort_by_key(|h| (h.dist, h.id));
    all.truncate(k);
    all
}

/// Sharded exact Hamming k-NN with incremental updates. Same `Hit`
/// contract as [`crate::bits::BinaryIndex`].
pub struct ShardedIndex {
    shards: Vec<MihIndex>,
    bits: usize,
    words_per_code: usize,
}

impl ShardedIndex {
    /// Partition a packed corpus (ids `0..n`) round-robin across `shards`
    /// MIH shards. `m` is the per-shard substring count (None → auto).
    pub fn build(codes: BitCode, shards: usize, m: Option<usize>) -> ShardedIndex {
        let ids = (0..codes.n as u32).collect();
        ShardedIndex::build_with_ids(codes, ids, shards, m)
    }

    /// Partition with explicit external ids (must be unique).
    pub fn build_with_ids(
        codes: BitCode,
        ids: Vec<u32>,
        shards: usize,
        m: Option<usize>,
    ) -> ShardedIndex {
        assert_eq!(codes.n, ids.len());
        // Per-shard MihIndex builds only catch duplicates landing in the
        // same shard; check globally up front.
        let mut seen: HashSet<u32, BuildFastHash> =
            HashSet::with_capacity_and_hasher(ids.len(), BuildFastHash::default());
        for &id in &ids {
            assert!(seen.insert(id), "duplicate id {id}");
        }
        let s_count = shards.max(1);
        let bits = codes.bits;
        let wpc = codes.words_per_code;
        let mut parts: Vec<(BitCode, Vec<u32>)> = (0..s_count)
            .map(|_| (BitCode::new(0, bits), Vec::new()))
            .collect();
        for slot in 0..codes.n {
            let (part_codes, part_ids) = &mut parts[slot % s_count];
            part_codes.data.to_mut().extend_from_slice(codes.code(slot));
            part_codes.n += 1;
            part_ids.push(ids[slot]);
        }
        ShardedIndex {
            shards: parts
                .into_iter()
                .map(|(part_codes, part_ids)| MihIndex::build_with_ids(part_codes, part_ids, m))
                .collect(),
            bits,
            words_per_code: wpc,
        }
    }

    /// Total live codes across shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }
    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.is_empty())
    }
    pub fn bits(&self) -> usize {
        self.bits
    }
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }
    /// Live size of every shard (for balance inspection).
    pub fn shard_sizes(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.len()).collect()
    }
    pub fn contains(&self, id: u32) -> bool {
        self.shards.iter().any(|s| s.contains(id))
    }

    /// Insert into the currently smallest shard (keeps shards balanced
    /// under arbitrary insert/remove interleavings).
    pub fn insert(&mut self, id: u32, code: &[u64]) {
        assert_eq!(code.len(), self.words_per_code, "code word count mismatch");
        assert!(!self.contains(id), "duplicate id {id}");
        let target = self
            .shards
            .iter()
            .enumerate()
            .min_by_key(|(i, s)| (s.len(), *i))
            .map(|(i, _)| i)
            .expect("at least one shard");
        self.shards[target].insert(id, code);
    }

    /// Insert one ±1 sign row (len == bits).
    pub fn insert_signs(&mut self, id: u32, signs: &[f32]) {
        let packed = BitCode::from_signs(signs, 1, self.bits);
        self.insert(id, packed.code(0));
    }

    /// Remove by external id from whichever shard holds it.
    pub fn remove(&mut self, id: u32) -> bool {
        self.shards.iter_mut().any(|s| s.remove(id))
    }

    /// Exact top-k: parallel fan-out across shards (capped at core count;
    /// each thread sweeps a group of shards), merged by `(dist, id)`.
    pub fn search(&self, q: &[u64], k: usize) -> Vec<Hit> {
        if k == 0 {
            return Vec::new();
        }
        let busy: Vec<&MihIndex> = self.shards.iter().filter(|s| !s.is_empty()).collect();
        let threads = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(busy.len());
        if threads <= 1 || self.len() < PARALLEL_CUTOVER {
            return merge_topk(busy.iter().map(|s| s.search(q, k)).collect(), k);
        }
        let chunk = busy.len().div_ceil(threads);
        let mut per_group: Vec<Vec<Hit>> = Vec::with_capacity(threads);
        std::thread::scope(|scope| {
            let handles: Vec<_> = busy
                .chunks(chunk)
                .map(|group| {
                    scope.spawn(move || {
                        merge_topk(group.iter().map(|s| s.search(q, k)).collect(), k)
                    })
                })
                .collect();
            for h in handles {
                per_group.push(h.join().expect("shard search panicked"));
            }
        });
        merge_topk(per_group, k)
    }

    /// One query, all shards swept on the calling thread (the batch path
    /// gets its parallelism from query-level fan-out instead).
    fn search_sequential(&self, q: &[u64], k: usize) -> Vec<Hit> {
        if k == 0 {
            return Vec::new();
        }
        merge_topk(
            self.shards
                .iter()
                .filter(|s| !s.is_empty())
                .map(|s| s.search(q, k))
                .collect(),
            k,
        )
    }

    /// Batch search parallelized across queries; order preserved.
    pub fn search_batch(&self, queries: &BitCode, k: usize) -> Vec<Vec<Hit>> {
        par_map_queries(queries.n, |i| self.search_sequential(queries.code(i), k))
    }

    /// The per-shard indexes, for the snapshot writer (each shard is
    /// serialized as an independent MIH body; shard membership is part of
    /// the snapshot, so a reload reproduces the exact same partition).
    pub(crate) fn shards(&self) -> &[MihIndex] {
        &self.shards
    }

    /// Reassemble from per-shard indexes (snapshot loader only; the
    /// loader has validated a uniform `bits` across shards and globally
    /// unique ids).
    pub(crate) fn from_shards(shards: Vec<MihIndex>, bits: usize) -> ShardedIndex {
        debug_assert!(!shards.is_empty());
        ShardedIndex {
            shards,
            bits,
            words_per_code: bits.div_ceil(64),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::BinaryIndex;
    use crate::util::rng::Pcg64;

    fn random_codes(rng: &mut Pcg64, n: usize, bits: usize) -> BitCode {
        BitCode::from_signs(&rng.sign_vec(n * bits), n, bits)
    }

    #[test]
    fn matches_linear_scan() {
        let mut rng = Pcg64::new(301);
        for shards in [1usize, 2, 3, 7] {
            let db = random_codes(&mut rng, 150, 128);
            let sharded = ShardedIndex::build(db.clone(), shards, Some(4));
            let linear = BinaryIndex::new(db);
            let queries = random_codes(&mut rng, 5, 128);
            for qi in 0..queries.n {
                assert_eq!(
                    sharded.search(queries.code(qi), 11),
                    linear.search(queries.code(qi), 11),
                    "shards={shards} qi={qi}"
                );
            }
        }
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Pcg64::new(302);
        let db = random_codes(&mut rng, 200, 64);
        let sharded = ShardedIndex::build(db, 4, None);
        let queries = random_codes(&mut rng, 20, 64);
        let batch = sharded.search_batch(&queries, 5);
        for qi in 0..queries.n {
            assert_eq!(batch[qi], sharded.search(queries.code(qi), 5));
        }
    }

    #[test]
    fn insert_balances_and_remove_finds_shard() {
        let mut rng = Pcg64::new(303);
        let db = random_codes(&mut rng, 20, 64);
        let mut sharded = ShardedIndex::build(db, 4, None);
        let extra = random_codes(&mut rng, 40, 64);
        for i in 0..extra.n {
            sharded.insert(1000 + i as u32, extra.code(i));
        }
        assert_eq!(sharded.len(), 60);
        let sizes = sharded.shard_sizes();
        let (lo, hi) = (
            *sizes.iter().min().unwrap(),
            *sizes.iter().max().unwrap(),
        );
        assert!(hi - lo <= 1, "shards must stay balanced: {sizes:?}");
        for i in 0..extra.n {
            assert!(sharded.remove(1000 + i as u32));
        }
        assert_eq!(sharded.len(), 20);
        assert!(!sharded.remove(9999));
    }

    #[test]
    fn more_shards_than_codes() {
        let mut rng = Pcg64::new(304);
        let db = random_codes(&mut rng, 3, 32);
        let sharded = ShardedIndex::build(db.clone(), 8, None);
        let linear = BinaryIndex::new(db.clone());
        assert_eq!(
            sharded.search(db.code(0), 10),
            linear.search(db.code(0), 10)
        );
    }
}
