//! Substring bucket store: the per-table layer of multi-index hashing.
//!
//! A b-bit code is partitioned into m contiguous substrings; each
//! [`SubstringTable`] owns one span and maps the span's (≤ 64-bit) value to
//! the list of storage slots whose code carries that value. Probing a table
//! at substring radius r means enumerating the C(len, r) keys at Hamming
//! distance exactly r from the query's key — [`for_each_key_at_radius`].

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Avalanche hasher for the u64 bucket keys (and u32 id keys). std's
/// SipHash is DoS-hardened, which is wasted work on keys we control; this
/// is a splitmix64 finalizer for integer writes with an FNV-1a fallback
/// for byte streams.
#[derive(Default)]
pub struct FastHash(u64);

impl Hasher for FastHash {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ u64::from(b)).wrapping_mul(0x100_0000_01b3);
        }
    }
    #[inline]
    fn write_u64(&mut self, x: u64) {
        let mut z = self.0 ^ x;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        self.0 = z ^ (z >> 31);
    }
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
}

/// `HashMap` hasher state for all index-internal tables.
pub type BuildFastHash = BuildHasherDefault<FastHash>;

/// Partition `bits` into `m` contiguous spans `(start, len)`, as even as
/// possible: the first `bits % m` spans get one extra bit. Every span must
/// fit a u64 key, so callers need `m ≥ ceil(bits / 64)`.
pub fn substring_spans(bits: usize, m: usize) -> Vec<(usize, usize)> {
    assert!(
        (1..=bits).contains(&m),
        "need 1 <= m <= bits (m={m}, bits={bits})"
    );
    let base = bits / m;
    let extra = bits % m;
    let mut spans = Vec::with_capacity(m);
    let mut start = 0;
    for i in 0..m {
        let len = base + usize::from(i < extra);
        assert!(
            len <= 64,
            "substring of {len} bits exceeds a u64 key; use m >= ceil(bits/64)"
        );
        spans.push((start, len));
        start += len;
    }
    debug_assert_eq!(start, bits);
    spans
}

/// Extract `len` (1..=64) bits starting at absolute bit `start` from a
/// packed little-endian-bit code row.
#[inline]
pub fn extract_bits(code: &[u64], start: usize, len: usize) -> u64 {
    debug_assert!((1..=64).contains(&len));
    let w = start / 64;
    let off = start % 64;
    let mut v = code[w] >> off;
    if off + len > 64 {
        v |= code[w + 1] << (64 - off);
    }
    if len < 64 {
        v &= (1u64 << len) - 1;
    }
    v
}

/// Visit every key at Hamming distance exactly `r` from `key` within a
/// `len`-bit keyspace — C(len, r) keys, in deterministic (lexicographic
/// flip-set) order. No-op when `r > len`.
pub fn for_each_key_at_radius(key: u64, len: usize, r: usize, visit: &mut impl FnMut(u64)) {
    if r == 0 {
        visit(key);
        return;
    }
    if r > len {
        return;
    }
    // `flip` walks the r-combinations of bit positions {0, .., len-1}.
    let mut flip: Vec<usize> = (0..r).collect();
    loop {
        let mut k = key;
        for &b in &flip {
            k ^= 1u64 << b;
        }
        visit(k);
        let mut j = r;
        while j > 0 && flip[j - 1] == len - r + (j - 1) {
            j -= 1;
        }
        if j == 0 {
            return;
        }
        flip[j - 1] += 1;
        for l in j..r {
            flip[l] = flip[l - 1] + 1;
        }
    }
}

/// One hash table of the multi-index: bucket store for a single substring
/// span. Values are *storage slots* (row indices of the owning index's
/// `BitCode`), not external ids — the owner translates after re-ranking.
pub struct SubstringTable {
    /// Absolute start bit of this table's span.
    pub start: usize,
    /// Span length in bits (1..=64).
    pub len: usize,
    buckets: HashMap<u64, Vec<u32>, BuildFastHash>,
}

impl SubstringTable {
    pub fn new(start: usize, len: usize) -> SubstringTable {
        assert!((1..=64).contains(&len));
        SubstringTable {
            start,
            len,
            buckets: HashMap::default(),
        }
    }

    /// This table's key for a full packed code row.
    #[inline]
    pub fn key_of(&self, code: &[u64]) -> u64 {
        extract_bits(code, self.start, self.len)
    }

    /// Append a slot to a bucket.
    pub fn insert(&mut self, key: u64, slot: u32) {
        self.buckets.entry(key).or_default().push(slot);
    }

    /// Remove a slot from a bucket; true if it was present.
    pub fn remove(&mut self, key: u64, slot: u32) -> bool {
        if let Some(bucket) = self.buckets.get_mut(&key) {
            if let Some(pos) = bucket.iter().position(|s| *s == slot) {
                bucket.swap_remove(pos);
                if bucket.is_empty() {
                    self.buckets.remove(&key);
                }
                return true;
            }
        }
        false
    }

    /// The slots bucketed under `key`, if any.
    #[inline]
    pub fn bucket(&self, key: u64) -> Option<&[u32]> {
        self.buckets.get(&key).map(|v| v.as_slice())
    }

    /// Number of non-empty buckets.
    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_partition_exactly() {
        for (bits, m) in [(256, 8), (256, 13), (100, 7), (64, 1), (5, 5), (65, 2)] {
            let spans = substring_spans(bits, m);
            assert_eq!(spans.len(), m);
            let mut next = 0;
            for &(start, len) in &spans {
                assert_eq!(start, next);
                assert!(len >= 1 && len <= 64);
                next += len;
            }
            assert_eq!(next, bits);
            // even-as-possible: lens differ by at most one
            let lens: Vec<usize> = spans.iter().map(|s| s.1).collect();
            let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(hi - lo <= 1);
        }
    }

    #[test]
    fn extract_matches_naive() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(41);
        let words: Vec<u64> = (0..4).map(|_| rng.next_u64()).collect();
        let bit = |i: usize| words[i / 64] >> (i % 64) & 1;
        for start in [0usize, 1, 31, 63, 64, 100, 127, 190] {
            for len in [1usize, 2, 17, 33, 64] {
                if start + len > 256 {
                    continue;
                }
                let v = extract_bits(&words, start, len);
                for j in 0..len {
                    assert_eq!(v >> j & 1, bit(start + j), "start={start} len={len} j={j}");
                }
                if len < 64 {
                    assert_eq!(v >> len, 0, "high bits must be masked");
                }
            }
        }
    }

    #[test]
    fn radius_enumeration_exact() {
        let binom = |n: u64, k: u64| -> u64 {
            (0..k).fold(1u64, |acc, i| acc * (n - i) / (i + 1))
        };
        for len in [1usize, 3, 8, 12] {
            for r in 0..=len.min(4) {
                let key = 0b1010_1010 & ((1u64 << len) - 1).max(1);
                let mut seen = Vec::new();
                for_each_key_at_radius(key, len, r, &mut |k| seen.push(k));
                assert_eq!(seen.len() as u64, binom(len as u64, r as u64), "len={len} r={r}");
                for k in &seen {
                    assert_eq!((k ^ key).count_ones() as usize, r);
                    assert_eq!(k >> len, 0, "keys stay inside the keyspace");
                }
                let mut dedup = seen.clone();
                dedup.sort_unstable();
                dedup.dedup();
                assert_eq!(dedup.len(), seen.len(), "no key visited twice");
            }
        }
    }

    #[test]
    fn radius_beyond_len_is_empty() {
        let mut count = 0;
        for_each_key_at_radius(0, 3, 4, &mut |_| count += 1);
        assert_eq!(count, 0);
    }

    #[test]
    fn table_insert_remove_roundtrip() {
        let mut t = SubstringTable::new(0, 16);
        t.insert(7, 0);
        t.insert(7, 1);
        t.insert(9, 2);
        assert_eq!(t.bucket(7), Some(&[0u32, 1][..]));
        assert_eq!(t.bucket_count(), 2);
        assert!(t.remove(7, 0));
        assert!(!t.remove(7, 0), "double remove is a no-op");
        assert_eq!(t.bucket(7), Some(&[1u32][..]));
        assert!(t.remove(7, 1));
        assert!(t.bucket(7).is_none(), "empty buckets are dropped");
        assert_eq!(t.bucket_count(), 1);
    }
}
