//! Substring bucket store: the per-table storage engine of multi-index
//! hashing.
//!
//! A b-bit code is partitioned into m substrings; each [`SubstringTable`]
//! owns one substring (a contiguous span *or* a sampled bit set, see
//! [`KeySource`]) and maps the substring's (≤ 64-bit) value to the list of
//! storage slots whose code carries that value. Probing a table at
//! substring radius r means enumerating the C(len, r) keys at Hamming
//! distance exactly r from the query's key — [`for_each_key_at_radius`].
//!
//! # Storage layout
//!
//! The table is a **flat open-addressing hash table** (linear probing,
//! power-of-two capacity, splitmix64-finalized keys) whose postings live in
//! **one contiguous `u32` arena** — zero per-bucket allocations, unlike the
//! `HashMap<u64, Vec<u32>>` it replaced (which paid one heap allocation per
//! non-empty bucket, ruinous at the 10⁶+ scale).
//!
//! * **Bulk build** ([`SubstringTable::build`]) is two-pass: count keys →
//!   prefix-sum bucket offsets → fill. The arena is sized exactly and each
//!   posting is written once.
//! * **Incremental insert** appends into the bucket's reserved capacity;
//!   on overflow the bucket relocates to the arena tail with doubled
//!   capacity, abandoning its old range. Abandoned capacity is tracked and
//!   the arena is rewritten in place once more than half of it is dead, so
//!   insert/remove churn cannot grow memory without bound.
//! * **Remove** swap-removes within the bucket slice; a bucket that empties
//!   tombstones its key slot (reclaimed by later inserts or the next
//!   rehash).

use crate::bits::bitcode::BitCode;
use crate::index::persist::mmap::Postings;
use std::hash::{BuildHasherDefault, Hasher};

/// splitmix64 finalizer: the avalanche permutation behind both [`FastHash`]
/// and the open-addressing probe start.
#[inline]
pub(crate) fn splitmix64(x: u64) -> u64 {
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Avalanche hasher for the u64 bucket keys (and u32 id keys). std's
/// SipHash is DoS-hardened, which is wasted work on keys we control; this
/// is a splitmix64 finalizer for integer writes with an FNV-1a fallback
/// for byte streams.
#[derive(Default)]
pub struct FastHash(u64);

impl Hasher for FastHash {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ u64::from(b)).wrapping_mul(0x100_0000_01b3);
        }
    }
    #[inline]
    fn write_u64(&mut self, x: u64) {
        self.0 = splitmix64(self.0 ^ x);
    }
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
}

/// `HashMap` hasher state for all index-internal tables.
pub type BuildFastHash = BuildHasherDefault<FastHash>;

/// Partition `bits` into `m` contiguous spans `(start, len)`, as even as
/// possible: the first `bits % m` spans get one extra bit. Every span must
/// fit a u64 key, so callers need `m ≥ ceil(bits / 64)`.
pub fn substring_spans(bits: usize, m: usize) -> Vec<(usize, usize)> {
    assert!(
        (1..=bits).contains(&m),
        "need 1 <= m <= bits (m={m}, bits={bits})"
    );
    let base = bits / m;
    let extra = bits % m;
    let mut spans = Vec::with_capacity(m);
    let mut start = 0;
    for i in 0..m {
        let len = base + usize::from(i < extra);
        assert!(
            len <= 64,
            "substring of {len} bits exceeds a u64 key; use m >= ceil(bits/64)"
        );
        spans.push((start, len));
        start += len;
    }
    debug_assert_eq!(start, bits);
    spans
}

/// Partition `bits` bit positions into `m` **sampled** (non-contiguous)
/// groups via a seeded Fisher–Yates permutation, with the same
/// even-as-possible group sizes as [`substring_spans`]. Every bit position
/// lands in exactly one group, so the pigeonhole bound of the probe
/// schedule holds unchanged; what changes is *which* bits share a bucket
/// key. Adjacent circulant-embedding bits are correlated (Yu et al., 2015),
/// which skews contiguous-span bucket occupancy; sampling decorrelates the
/// bits behind each key and restores the near-uniform bucket distribution
/// multi-index hashing assumes. Deterministic in `(bits, m, seed)`.
pub fn sampled_positions(bits: usize, m: usize, seed: u64) -> Vec<Vec<u32>> {
    use crate::util::rng::Pcg64;
    let spans = substring_spans(bits, m);
    let mut perm: Vec<u32> = (0..bits as u32).collect();
    Pcg64::new(seed ^ ((bits as u64) << 20) ^ m as u64).shuffle(&mut perm);
    let mut groups = Vec::with_capacity(m);
    let mut at = 0usize;
    for &(_, len) in &spans {
        let mut g = perm[at..at + len].to_vec();
        // Sorted within the group: key bit j is the j-th smallest sampled
        // position, so extraction walks the code in address order.
        g.sort_unstable();
        groups.push(g);
        at += len;
    }
    groups
}

/// Extract `len` (1..=64) bits starting at absolute bit `start` from a
/// packed little-endian-bit code row.
#[inline]
pub fn extract_bits(code: &[u64], start: usize, len: usize) -> u64 {
    debug_assert!((1..=64).contains(&len));
    let w = start / 64;
    let off = start % 64;
    let mut v = code[w] >> off;
    if off + len > 64 {
        v |= code[w + 1] << (64 - off);
    }
    if len < 64 {
        v &= (1u64 << len) - 1;
    }
    v
}

/// Gather the bits at `positions` (each an absolute bit index, ≤ 64 of
/// them) into a packed key: key bit j = code bit `positions[j]`.
#[inline]
pub fn gather_bits(code: &[u64], positions: &[u32]) -> u64 {
    debug_assert!((1..=64).contains(&positions.len()));
    let mut key = 0u64;
    for (j, &p) in positions.iter().enumerate() {
        let p = p as usize;
        key |= (code[p / 64] >> (p % 64) & 1) << j;
    }
    key
}

/// Visit every key at Hamming distance exactly `r` from `key` within a
/// `len`-bit keyspace — C(len, r) keys, in deterministic (lexicographic
/// flip-set) order. No-op when `r > len`.
pub fn for_each_key_at_radius(key: u64, len: usize, r: usize, visit: &mut impl FnMut(u64)) {
    if r == 0 {
        visit(key);
        return;
    }
    if r > len {
        return;
    }
    // `flip` walks the r-combinations of bit positions {0, .., len-1}.
    let mut flip: Vec<usize> = (0..r).collect();
    loop {
        let mut k = key;
        for &b in &flip {
            k ^= 1u64 << b;
        }
        visit(k);
        let mut j = r;
        while j > 0 && flip[j - 1] == len - r + (j - 1) {
            j -= 1;
        }
        if j == 0 {
            return;
        }
        flip[j - 1] += 1;
        for l in j..r {
            flip[l] = flip[l - 1] + 1;
        }
    }
}

/// How a [`SubstringTable`] derives its key bits from a full packed code.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KeySource {
    /// `len` contiguous bits starting at absolute bit `start`.
    Span { start: usize, len: usize },
    /// Explicit (sorted, distinct) absolute bit positions, ≤ 64 of them —
    /// the bit-sampled scheme of [`sampled_positions`].
    Sampled { positions: Box<[u32]> },
}

impl KeySource {
    /// Number of key bits this source produces.
    #[inline]
    pub fn key_bits(&self) -> usize {
        match self {
            KeySource::Span { len, .. } => *len,
            KeySource::Sampled { positions } => positions.len(),
        }
    }
}

/// Slot states of the open-addressing key table.
const EMPTY: u8 = 0;
const FULL: u8 = 1;
const TOMB: u8 = 2;

/// Bucket metadata: a half-open range of reserved arena capacity, of which
/// the first `len` entries are live postings.
#[derive(Clone, Copy, Default)]
struct Bucket {
    key: u64,
    off: u32,
    len: u32,
    cap: u32,
}

/// One hash table of the multi-index: flat-arena bucket store for a single
/// substring. Values are *storage slots* (row indices of the owning index's
/// `BitCode`), not external ids — the owner translates after re-ranking.
/// See the module docs for the memory layout.
pub struct SubstringTable {
    source: KeySource,
    /// Open-addressing control bytes ([`EMPTY`]/[`FULL`]/[`TOMB`]),
    /// power-of-two length, parallel to `buckets`.
    ctrl: Vec<u8>,
    buckets: Vec<Bucket>,
    n_full: usize,
    n_tomb: usize,
    /// All postings, one contiguous run: an owned allocation, or — after
    /// a zero-copy snapshot load — a window into the mapped snapshot
    /// (promoted to owned on first mutation; see
    /// [`crate::index::persist::mmap`]).
    arena: Postings,
    /// Arena capacity abandoned by bucket relocation / emptied buckets;
    /// compacted away once it exceeds half the arena.
    dead: usize,
}

const INITIAL_SLOTS: usize = 16;

impl SubstringTable {
    /// Empty table over a contiguous span (see [`SubstringTable::with_source`]
    /// for sampled keys).
    pub fn new(start: usize, len: usize) -> SubstringTable {
        SubstringTable::with_source(KeySource::Span { start, len })
    }

    /// Empty table over an arbitrary key source.
    pub fn with_source(source: KeySource) -> SubstringTable {
        assert!(
            (1..=64).contains(&source.key_bits()),
            "substring keys must be 1..=64 bits"
        );
        SubstringTable {
            source,
            ctrl: vec![EMPTY; INITIAL_SLOTS],
            buckets: vec![Bucket::default(); INITIAL_SLOTS],
            n_full: 0,
            n_tomb: 0,
            arena: Postings::default(),
            dead: 0,
        }
    }

    /// Two-pass bulk build over a packed corpus: count keys → prefix-sum
    /// offsets → fill. The arena is sized exactly (no dead capacity, no
    /// per-bucket headroom) and every posting is written exactly once.
    pub fn build(source: KeySource, codes: &BitCode) -> SubstringTable {
        assert!(codes.n <= u32::MAX as usize, "storage slots must fit u32");
        let mut t = SubstringTable::with_source(source);
        // Pass 1: count occupancy per key (len doubles as the counter).
        for row in 0..codes.n {
            let key = t.key_of(codes.code(row));
            let bi = t.slot_for_insert(key);
            t.buckets[bi].len += 1;
        }
        // Prefix-sum the counts into exact arena offsets.
        let mut total = 0usize;
        for i in 0..t.ctrl.len() {
            if t.ctrl[i] == FULL {
                let count = t.buckets[i].len;
                t.buckets[i].off = total as u32;
                t.buckets[i].cap = count;
                t.buckets[i].len = 0;
                total += count as usize;
            }
        }
        t.arena = Postings::owned(vec![0u32; total]);
        // Pass 2: fill postings in slot order.
        for row in 0..codes.n {
            let key = t.key_of(codes.code(row));
            let bi = t.find(key).expect("key present after counting pass");
            let Bucket { off, len, .. } = t.buckets[bi];
            t.arena[(off + len) as usize] = row as u32;
            t.buckets[bi].len = len + 1;
        }
        t
    }

    /// The key source this table extracts with.
    pub fn source(&self) -> &KeySource {
        &self.source
    }

    /// Key width in bits (the radius-enumeration keyspace).
    #[inline]
    pub fn key_bits(&self) -> usize {
        self.source.key_bits()
    }

    /// This table's key for a full packed code row.
    #[inline]
    pub fn key_of(&self, code: &[u64]) -> u64 {
        match &self.source {
            KeySource::Span { start, len } => extract_bits(code, *start, *len),
            KeySource::Sampled { positions } => gather_bits(code, positions),
        }
    }

    /// Append a slot to a bucket. Amortized O(1): within reserved capacity
    /// it is a single arena write; on overflow the bucket relocates to the
    /// arena tail with doubled capacity.
    pub fn insert(&mut self, key: u64, slot: u32) {
        let bi = self.slot_for_insert(key);
        let Bucket { off, len, cap, .. } = self.buckets[bi];
        if len < cap {
            self.arena[(off + len) as usize] = slot;
            self.buckets[bi].len = len + 1;
            return;
        }
        // saturating: a pathological single-bucket table near u32::MAX
        // postings must hit the arena-addressing assert below, not wrap
        // cap to a small value and corrupt the bucket range.
        let new_cap = cap.saturating_mul(2).max(4);
        let new_off = self.arena.len();
        assert!(
            new_off + new_cap as usize <= u32::MAX as usize,
            "postings arena exceeds u32 addressing"
        );
        {
            let arena = self.arena.to_mut();
            arena.extend_from_within(off as usize..(off + len) as usize);
            arena.push(slot);
            arena.resize(new_off + new_cap as usize, 0);
        }
        self.dead += cap as usize;
        let b = &mut self.buckets[bi];
        b.off = new_off as u32;
        b.len = len + 1;
        b.cap = new_cap;
        self.maybe_compact();
    }

    /// Remove a slot from a bucket; true if it was present. Swap-removes
    /// within the bucket slice; an emptied bucket tombstones its key slot
    /// and abandons its arena capacity (reclaimed by the next compaction).
    pub fn remove(&mut self, key: u64, slot: u32) -> bool {
        let Some(bi) = self.find(key) else {
            return false;
        };
        let Bucket { off, len, cap, .. } = self.buckets[bi];
        let (s, e) = (off as usize, (off + len) as usize);
        let Some(pos) = self.arena[s..e].iter().position(|&x| x == slot) else {
            return false;
        };
        self.arena.swap(s + pos, e - 1);
        self.buckets[bi].len = len - 1;
        if len == 1 {
            self.ctrl[bi] = TOMB;
            self.n_full -= 1;
            self.n_tomb += 1;
            self.dead += cap as usize;
            self.maybe_compact();
        }
        true
    }

    /// The slots bucketed under `key`, if any.
    #[inline]
    pub fn bucket(&self, key: u64) -> Option<&[u32]> {
        self.find(key).map(|bi| {
            let Bucket { off, len, .. } = self.buckets[bi];
            &self.arena[off as usize..(off + len) as usize]
        })
    }

    /// Number of non-empty buckets.
    pub fn bucket_count(&self) -> usize {
        self.n_full
    }

    /// Total arena capacity in postings, dead ranges included
    /// (diagnostics/tests).
    pub fn arena_capacity(&self) -> usize {
        self.arena.len()
    }

    /// Arena capacity currently abandoned (relocated or emptied buckets).
    /// Bounded: compaction keeps `dead ≤ arena_capacity / 2`.
    pub fn arena_dead(&self) -> usize {
        self.dead
    }

    /// Visit every live bucket as `(key, postings)`, in table-slot order.
    /// The snapshot writer serializes tables through this seam; the order
    /// is deterministic for a fixed build/churn history but is not part
    /// of the on-disk contract (buckets are keyed, not positional).
    pub(crate) fn for_each_bucket(&self, mut f: impl FnMut(u64, &[u32])) {
        for i in 0..self.ctrl.len() {
            if self.ctrl[i] != FULL {
                continue;
            }
            let Bucket { key, off, len, .. } = self.buckets[i];
            f(key, &self.arena[off as usize..(off + len) as usize]);
        }
    }

    /// Live postings across all buckets (arena entries minus dead ranges).
    pub(crate) fn postings_len(&self) -> usize {
        self.arena.len() - self.dead
    }

    /// Reassemble a table from snapshot parts: `buckets` is `(key, len)`
    /// per bucket and `arena` holds their postings concatenated in the
    /// same order (exactly [`SubstringTable::for_each_bucket`]'s output).
    /// The arena is adopted whole — one contiguous allocation, zero dead
    /// capacity, same footprint as a fresh bulk build. The caller (the
    /// snapshot loader) has already validated distinct in-range keys and
    /// that the bucket lengths sum to `arena.len()`; those invariants are
    /// re-checked here as debug assertions only.
    pub(crate) fn from_buckets(
        source: KeySource,
        buckets: &[(u64, u32)],
        arena: impl Into<Postings>,
    ) -> SubstringTable {
        let mut t = SubstringTable::with_source(source);
        let mut off = 0u32;
        for &(key, len) in buckets {
            let bi = t.slot_for_insert(key);
            debug_assert_eq!(t.buckets[bi].len, 0, "duplicate bucket key {key}");
            t.buckets[bi] = Bucket { key, off, len, cap: len };
            off += len;
        }
        let arena = arena.into();
        debug_assert_eq!(off as usize, arena.len());
        t.arena = arena;
        t
    }

    /// Is the postings arena still a zero-copy window into a mapped
    /// snapshot (i.e. has no churn promoted it to owned yet)?
    pub(crate) fn arena_is_mapped(&self) -> bool {
        self.arena.is_mapped()
    }

    /// Find the table slot holding `key`, skipping tombstones.
    fn find(&self, key: u64) -> Option<usize> {
        let mask = self.ctrl.len() - 1;
        let mut i = splitmix64(key) as usize & mask;
        loop {
            match self.ctrl[i] {
                EMPTY => return None,
                FULL if self.buckets[i].key == key => return Some(i),
                _ => {}
            }
            i = (i + 1) & mask;
        }
    }

    /// Find the slot for `key`, claiming a fresh one (reusing the first
    /// tombstone on the probe path) if absent. Grows the table first when
    /// occupancy (FULL + TOMB) would exceed 7/8, so a probe always
    /// terminates at an EMPTY slot.
    fn slot_for_insert(&mut self, key: u64) -> usize {
        if (self.n_full + self.n_tomb + 1) * 8 > self.ctrl.len() * 7 {
            self.rehash();
        }
        let mask = self.ctrl.len() - 1;
        let mut i = splitmix64(key) as usize & mask;
        let mut first_tomb: Option<usize> = None;
        loop {
            match self.ctrl[i] {
                EMPTY => {
                    let at = match first_tomb {
                        Some(t) => {
                            self.n_tomb -= 1;
                            t
                        }
                        None => i,
                    };
                    self.ctrl[at] = FULL;
                    self.n_full += 1;
                    self.buckets[at] = Bucket {
                        key,
                        ..Bucket::default()
                    };
                    return at;
                }
                FULL if self.buckets[i].key == key => return i,
                TOMB if first_tomb.is_none() => first_tomb = Some(i),
                _ => {}
            }
            i = (i + 1) & mask;
        }
    }

    /// Rebuild the key table at a capacity sized for the live keys,
    /// dropping tombstones. Arena and bucket ranges are untouched.
    fn rehash(&mut self) {
        let new_len = (self.n_full * 2).max(INITIAL_SLOTS).next_power_of_two();
        let old_ctrl = std::mem::replace(&mut self.ctrl, vec![EMPTY; new_len]);
        let old_buckets = std::mem::replace(&mut self.buckets, vec![Bucket::default(); new_len]);
        self.n_tomb = 0;
        let mask = new_len - 1;
        for (c, b) in old_ctrl.into_iter().zip(old_buckets) {
            if c != FULL {
                continue;
            }
            let mut i = splitmix64(b.key) as usize & mask;
            while self.ctrl[i] == FULL {
                i = (i + 1) & mask;
            }
            self.ctrl[i] = FULL;
            self.buckets[i] = b;
        }
    }

    /// Rewrite the arena over live postings once more than half of it is
    /// dead. Bucket capacities shrink to their live lengths, so churn-heavy
    /// tables converge to the same footprint a fresh bulk build would have.
    fn maybe_compact(&mut self) {
        if self.dead * 2 <= self.arena.len() || self.arena.len() < 64 {
            return;
        }
        let mut packed = Vec::with_capacity(self.arena.len() - self.dead);
        for i in 0..self.ctrl.len() {
            if self.ctrl[i] != FULL {
                continue;
            }
            let Bucket { off, len, .. } = self.buckets[i];
            let new_off = packed.len() as u32;
            packed.extend_from_slice(&self.arena[off as usize..(off + len) as usize]);
            self.buckets[i].off = new_off;
            self.buckets[i].cap = len;
        }
        self.arena = Postings::owned(packed);
        self.dead = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use std::collections::HashMap;

    #[test]
    fn spans_partition_exactly() {
        for (bits, m) in [(256, 8), (256, 13), (100, 7), (64, 1), (5, 5), (65, 2)] {
            let spans = substring_spans(bits, m);
            assert_eq!(spans.len(), m);
            let mut next = 0;
            for &(start, len) in &spans {
                assert_eq!(start, next);
                assert!(len >= 1 && len <= 64);
                next += len;
            }
            assert_eq!(next, bits);
            // even-as-possible: lens differ by at most one
            let lens: Vec<usize> = spans.iter().map(|s| s.1).collect();
            let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(hi - lo <= 1);
        }
    }

    #[test]
    fn sampled_positions_partition_all_bits() {
        for (bits, m) in [(256usize, 8usize), (100, 7), (64, 1), (5, 5), (130, 3)] {
            let groups = sampled_positions(bits, m, 0xcbe);
            assert_eq!(groups.len(), m);
            let mut all: Vec<u32> = groups.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..bits as u32).collect::<Vec<_>>(), "bits={bits} m={m}");
            // group sizes match the contiguous partition's
            let spans = substring_spans(bits, m);
            for (g, &(_, len)) in groups.iter().zip(&spans) {
                assert_eq!(g.len(), len);
                assert!(g.windows(2).all(|w| w[0] < w[1]), "sorted, distinct");
            }
            // deterministic in the seed
            assert_eq!(groups, sampled_positions(bits, m, 0xcbe));
            // m == 1 sorts the whole permutation back to 0..bits, so only
            // multi-group partitions can differ across seeds.
            if m > 1 && bits > m {
                assert_ne!(
                    groups,
                    sampled_positions(bits, m, 0xcbe + 1),
                    "different seed should permute differently (bits={bits} m={m})"
                );
            }
        }
    }

    #[test]
    fn extract_matches_naive() {
        let mut rng = Pcg64::new(41);
        let words: Vec<u64> = (0..4).map(|_| rng.next_u64()).collect();
        let bit = |i: usize| words[i / 64] >> (i % 64) & 1;
        for start in [0usize, 1, 31, 63, 64, 100, 127, 190] {
            for len in [1usize, 2, 17, 33, 64] {
                if start + len > 256 {
                    continue;
                }
                let v = extract_bits(&words, start, len);
                for j in 0..len {
                    assert_eq!(v >> j & 1, bit(start + j), "start={start} len={len} j={j}");
                }
                if len < 64 {
                    assert_eq!(v >> len, 0, "high bits must be masked");
                }
            }
        }
    }

    #[test]
    fn gather_matches_extract_on_spans_and_naive_on_samples() {
        let mut rng = Pcg64::new(43);
        let words: Vec<u64> = (0..4).map(|_| rng.next_u64()).collect();
        // A contiguous position set must agree with extract_bits.
        for (start, len) in [(0usize, 16usize), (60, 8), (100, 64), (255, 1)] {
            let positions: Vec<u32> = (start as u32..(start + len) as u32).collect();
            assert_eq!(
                gather_bits(&words, &positions),
                extract_bits(&words, start, len)
            );
        }
        // Arbitrary sample vs per-bit reads.
        let positions = [3u32, 64, 65, 130, 200, 255];
        let key = gather_bits(&words, &positions);
        for (j, &p) in positions.iter().enumerate() {
            let p = p as usize;
            assert_eq!(key >> j & 1, words[p / 64] >> (p % 64) & 1);
        }
        assert_eq!(key >> positions.len(), 0);
    }

    #[test]
    fn radius_enumeration_exact() {
        let binom = |n: u64, k: u64| -> u64 {
            (0..k).fold(1u64, |acc, i| acc * (n - i) / (i + 1))
        };
        for len in [1usize, 3, 8, 12] {
            for r in 0..=len.min(4) {
                let key = 0b1010_1010 & ((1u64 << len) - 1).max(1);
                let mut seen = Vec::new();
                for_each_key_at_radius(key, len, r, &mut |k| seen.push(k));
                assert_eq!(seen.len() as u64, binom(len as u64, r as u64), "len={len} r={r}");
                for k in &seen {
                    assert_eq!((k ^ key).count_ones() as usize, r);
                    assert_eq!(k >> len, 0, "keys stay inside the keyspace");
                }
                let mut dedup = seen.clone();
                dedup.sort_unstable();
                dedup.dedup();
                assert_eq!(dedup.len(), seen.len(), "no key visited twice");
            }
        }
    }

    #[test]
    fn radius_beyond_len_is_empty() {
        let mut count = 0;
        for_each_key_at_radius(0, 3, 4, &mut |_| count += 1);
        assert_eq!(count, 0);
    }

    #[test]
    fn table_insert_remove_roundtrip() {
        let mut t = SubstringTable::new(0, 16);
        t.insert(7, 0);
        t.insert(7, 1);
        t.insert(9, 2);
        assert_eq!(t.bucket(7), Some(&[0u32, 1][..]));
        assert_eq!(t.bucket_count(), 2);
        assert!(t.remove(7, 0));
        assert!(!t.remove(7, 0), "double remove is a no-op");
        assert_eq!(t.bucket(7), Some(&[1u32][..]));
        assert!(t.remove(7, 1));
        assert!(t.bucket(7).is_none(), "empty buckets are dropped");
        assert_eq!(t.bucket_count(), 1);
    }

    #[test]
    fn bulk_build_matches_incremental_inserts() {
        let mut rng = Pcg64::new(47);
        for (n, bits) in [(0usize, 64usize), (1, 32), (300, 96), (500, 17)] {
            let codes = BitCode::from_signs(&rng.sign_vec(n * bits), n, bits);
            let len = bits.min(16);
            let bulk = SubstringTable::build(KeySource::Span { start: 0, len }, &codes);
            let mut inc = SubstringTable::new(0, len);
            for row in 0..n {
                inc.insert(inc.key_of(codes.code(row)), row as u32);
            }
            assert_eq!(bulk.bucket_count(), inc.bucket_count(), "n={n} bits={bits}");
            assert_eq!(bulk.arena_capacity(), n, "bulk build sizes the arena exactly");
            assert_eq!(bulk.arena_dead(), 0);
            for key in 0..1u64 << len.min(10) {
                let a = bulk.bucket(key).map(|s| {
                    let mut v = s.to_vec();
                    v.sort_unstable();
                    v
                });
                let b = inc.bucket(key).map(|s| {
                    let mut v = s.to_vec();
                    v.sort_unstable();
                    v
                });
                assert_eq!(a, b, "key={key}");
            }
        }
    }

    /// Mirror model: drive the flat table and a plain HashMap-of-vecs with
    /// the same random churn; bucket contents must stay identical and the
    /// arena's dead capacity must stay within the compaction bound.
    #[test]
    fn churn_matches_hashmap_mirror_and_compacts() {
        let mut rng = Pcg64::new(53);
        let mut t = SubstringTable::new(0, 8);
        let mut mirror: HashMap<u64, Vec<u32>> = HashMap::new();
        let mut next_slot = 0u32;
        for step in 0..4000 {
            let key = rng.below(32); // dense keyspace → deep buckets
            let remove = rng.below(100) < 45 && !mirror.is_empty();
            if remove {
                // remove a random live (key, slot)
                let keys: Vec<u64> = mirror.keys().copied().collect();
                let k = keys[rng.below(keys.len() as u64) as usize];
                let bucket = mirror.get_mut(&k).unwrap();
                let victim = bucket[rng.below(bucket.len() as u64) as usize];
                bucket.retain(|&s| s != victim);
                if bucket.is_empty() {
                    mirror.remove(&k);
                }
                assert!(t.remove(k, victim), "step={step}");
                assert!(!t.remove(k, victim), "double remove");
            } else {
                mirror.entry(key).or_default().push(next_slot);
                t.insert(key, next_slot);
                next_slot += 1;
            }
            assert!(
                t.arena_dead() * 2 <= t.arena_capacity() || t.arena_capacity() < 64,
                "step={step}: dead={} cap={}",
                t.arena_dead(),
                t.arena_capacity()
            );
        }
        assert_eq!(t.bucket_count(), mirror.len());
        for key in 0..256u64 {
            let mut a = t.bucket(key).map(<[u32]>::to_vec).unwrap_or_default();
            let mut b = mirror.get(&key).cloned().unwrap_or_default();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "key={key}");
        }
    }

    #[test]
    fn emptying_the_table_reclaims_the_arena() {
        let mut t = SubstringTable::new(0, 12);
        for slot in 0..2000u32 {
            t.insert(u64::from(slot % 37), slot);
        }
        let peak = t.arena_capacity();
        assert!(peak >= 2000);
        for slot in 0..2000u32 {
            assert!(t.remove(u64::from(slot % 37), slot));
        }
        assert_eq!(t.bucket_count(), 0);
        assert!(
            t.arena_capacity() < peak / 2,
            "arena must compact once everything is dead: {} vs peak {peak}",
            t.arena_capacity()
        );
    }

    /// from_buckets(for_each_bucket(t)) must reproduce every bucket — on
    /// a fresh bulk build and on a churned, tombstone-carrying table
    /// (the snapshot writer walks live buckets only, so dead arena
    /// ranges and key-slot tombstones never reach disk).
    #[test]
    fn bucket_roundtrip_survives_churn_and_drops_dead_capacity() {
        let mut rng = Pcg64::new(61);
        let mut t = SubstringTable::new(0, 8);
        for slot in 0..500u32 {
            t.insert(rng.below(64), slot);
        }
        // Churn: remove ~half (some buckets empty out → tombstones).
        for slot in 0..500u32 {
            if slot % 2 == 0 {
                for key in 0..64u64 {
                    if t.remove(key, slot) {
                        break;
                    }
                }
            }
        }
        let mut buckets = Vec::new();
        let mut arena = Vec::new();
        t.for_each_bucket(|key, postings| {
            buckets.push((key, postings.len() as u32));
            arena.extend_from_slice(postings);
        });
        assert_eq!(arena.len(), t.postings_len());
        let r = SubstringTable::from_buckets(t.source().clone(), &buckets, arena);
        assert_eq!(r.bucket_count(), t.bucket_count());
        assert_eq!(r.arena_dead(), 0, "reassembled arena starts fully live");
        assert_eq!(r.arena_capacity(), t.postings_len());
        for key in 0..64u64 {
            let mut a = t.bucket(key).map(<[u32]>::to_vec).unwrap_or_default();
            let mut b = r.bucket(key).map(<[u32]>::to_vec).unwrap_or_default();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "key={key}");
        }
        // The reassembled table keeps serving churn.
        let mut r = r;
        r.insert(5, 9000);
        assert!(r.bucket(5).unwrap().contains(&9000));
    }

    #[test]
    fn sampled_table_buckets_by_gathered_key() {
        let mut rng = Pcg64::new(59);
        let bits = 96;
        let n = 200;
        let codes = BitCode::from_signs(&rng.sign_vec(n * bits), n, bits);
        let positions: Box<[u32]> = vec![1u32, 17, 40, 64, 65, 90].into_boxed_slice();
        let t = SubstringTable::build(
            KeySource::Sampled {
                positions: positions.clone(),
            },
            &codes,
        );
        assert_eq!(t.key_bits(), 6);
        for row in 0..n {
            let key = gather_bits(codes.code(row), &positions);
            let bucket = t.bucket(key).expect("own key must be bucketed");
            assert!(bucket.contains(&(row as u32)), "row={row}");
        }
    }
}
