//! Sectioned, checksummed snapshot encoding for every index backend.
//!
//! File grammar (all integers little-endian, every section 8-aligned):
//!
//! ```text
//! prelude (24 B):
//!   magic          8 B  = "CBEIDX01"
//!   format_version u32  = 2
//!   section_count  u32
//!   crc            u32    CRC-32 of bytes [0, 16)
//!   reserved       u32  = 0
//! section (repeated section_count times):
//!   id        u32    1 = META, 2 = CODES, 3 = IDS, 4 = TABLES
//!   reserved  u32  = 0
//!   len       u64    payload bytes (pre-padding)
//!   crc       u32    CRC-32 of the payload (pre-padding)
//!   pad       u32  = 0
//!   payload   len bytes, zero-padded to a multiple of 8
//! ```
//!
//! Format v2 (over v1): inside each TABLES payload the postings array of
//! every table is preceded by 0–3 zero bytes so it starts 4-aligned
//! within the payload — and, because payloads start 8-aligned in the
//! file, 4-aligned absolutely. The pad is covered by the section CRC and
//! the decoder requires it to be zero. Together with CODES word arrays
//! (which start at payload offset 8, hence 8-aligned absolutely) this
//! makes both big flat structures adoptable in place by the mmap loader.
//!
//! META is always first; then per backend: linear → one CODES + IDS
//! pair; MIH → CODES + IDS + TABLES; sharded → one CODES + IDS + TABLES
//! group *per shard*, in shard order (shard membership is part of the
//! snapshot, so a reload reproduces the exact partition and therefore
//! the exact WAL-replay insert routing).
//!
//! The writer **compacts on the way out**: tombstoned storage slots are
//! skipped and table postings are remapped through an old→new slot map,
//! so dead rows never reach disk and a loaded index is always in
//! canonical compacted form. The payload layout is fixed-width LE with
//! 8-byte-aligned sections, and the decoder adopts the two big flat
//! arrays — CODES words and TABLES postings — **in place** when handed a
//! snapshot mapping (see [`super::mmap`]): the returned index's stores
//! are zero-copy windows into the map. Without a mapping (the portable
//! heap path) the same decode does one copy per array instead; every
//! validation below runs identically on both paths.
//!
//! Decoding trusts nothing: beyond the per-section CRCs, every
//! structural invariant the in-memory types assume (unique ids, zero
//! padding bits, postings in range and distinct, bucket keys within the
//! key width, tables partitioning the code bits) is re-verified so a
//! CRC-valid-but-wrong file from a future format drift turns into a
//! typed error instead of a panic or a silently wrong search.

use super::format::{crc32, put_u32, put_u64, Reader};
use super::mmap::{Mmap, Postings, Words};
use super::SnapshotStamp;
use crate::bits::bitcode::BitCode;
use crate::bits::BinaryIndex;
use crate::index::mih::{MihIndex, SubstringScheme};
use crate::index::sharded::ShardedIndex;
use crate::index::substring::{BuildFastHash, KeySource, SubstringTable};
use crate::index::{IndexAny, IndexKind};
use std::collections::HashSet;
use std::sync::Arc;

pub(crate) const SNAP_MAGIC: [u8; 8] = *b"CBEIDX01";
pub(crate) const SNAP_FORMAT: u32 = 2;
pub(crate) const SNAP_FILE: &str = "current.snap";
pub(crate) const SNAP_TMP: &str = "snap.tmp";

const SEC_META: u32 = 1;
const SEC_CODES: u32 = 2;
const SEC_IDS: u32 = 3;
const SEC_TABLES: u32 = 4;

const BACKEND_LINEAR: u8 = 0;
const BACKEND_MIH: u8 = 1;
const BACKEND_SHARDED: u8 = 2;

/// Largest code width / shard count / section count we will believe
/// from a header. A snapshot this size cannot be produced by this
/// writer, so larger values are corruption, and rejecting them early
/// keeps allocation sizes sane while decoding hostile bytes.
const MAX_BITS: u64 = 1 << 24;
const MAX_SHARDS: u32 = 1 << 16;

/// Identity facts decoded from the META section.
pub(crate) struct SnapshotMeta {
    pub generation: u64,
    pub model_version: Option<u64>,
    pub fingerprint: u64,
}

// ---------------------------------------------------------------- encode

fn encode_codes_rows(codes: &BitCode, alive: Option<&[bool]>, n_live: usize) -> Vec<u8> {
    let wpc = codes.words_per_code;
    let mut p = Vec::with_capacity(8 + n_live * wpc * 8);
    put_u64(&mut p, n_live as u64);
    for slot in 0..codes.n {
        if alive.map_or(true, |a| a[slot]) {
            for &w in codes.code(slot) {
                put_u64(&mut p, w);
            }
        }
    }
    p
}

fn encode_ids_rows(ids: &[u32], alive: Option<&[bool]>, n_live: usize) -> Vec<u8> {
    let mut p = Vec::with_capacity(8 + n_live * 4);
    put_u64(&mut p, n_live as u64);
    for (slot, &id) in ids.iter().enumerate() {
        if alive.map_or(true, |a| a[slot]) {
            put_u32(&mut p, id);
        }
    }
    p
}

/// One MIH body (CODES + IDS + TABLES), tombstones compacted out.
fn mih_sections(mih: &MihIndex, sections: &mut Vec<(u32, Vec<u8>)>) {
    let (codes, ids, alive, tables) = mih.storage_parts();
    let n_live = mih.len();
    let identity = n_live == codes.n;
    // Old→new slot map over live rows (only built when tombstones exist).
    let remap: Vec<u32> = if identity {
        Vec::new()
    } else {
        let mut map = vec![u32::MAX; codes.n];
        let mut next = 0u32;
        for (slot, &a) in alive.iter().enumerate() {
            if a {
                map[slot] = next;
                next += 1;
            }
        }
        map
    };
    let live_mask = (!identity).then_some(alive);
    sections.push((SEC_CODES, encode_codes_rows(codes, live_mask, n_live)));
    sections.push((SEC_IDS, encode_ids_rows(ids, live_mask, n_live)));

    let mut tp = Vec::new();
    put_u32(&mut tp, tables.len() as u32);
    for t in tables {
        match t.source() {
            KeySource::Span { start, len } => {
                tp.push(0u8);
                put_u64(&mut tp, *start as u64);
                put_u64(&mut tp, *len as u64);
            }
            KeySource::Sampled { positions } => {
                tp.push(1u8);
                put_u32(&mut tp, positions.len() as u32);
                for &p in positions.iter() {
                    put_u32(&mut tp, p);
                }
            }
        }
        // Tables only ever hold live slots (removal drops postings
        // eagerly), so remapping never hits a dead slot.
        let mut dir: Vec<(u64, u32)> = Vec::with_capacity(t.bucket_count());
        let mut postings: Vec<u32> = Vec::with_capacity(t.postings_len());
        t.for_each_bucket(|key, slots| {
            if slots.is_empty() {
                return;
            }
            dir.push((key, slots.len() as u32));
            for &s in slots {
                postings.push(if identity { s } else { remap[s as usize] });
            }
        });
        put_u64(&mut tp, dir.len() as u64);
        put_u64(&mut tp, postings.len() as u64);
        for &(key, len) in &dir {
            put_u64(&mut tp, key);
            put_u32(&mut tp, len);
        }
        // Format v2: 4-align the postings array within the payload
        // (payloads start 8-aligned in the file), so a mapped load can
        // adopt it in place. The pad is inside the section CRC and the
        // decoder requires it to be zero.
        while tp.len() % 4 != 0 {
            tp.push(0);
        }
        for &p in &postings {
            put_u32(&mut tp, p);
        }
    }
    sections.push((SEC_TABLES, tp));
}

/// Encode a full snapshot as the ordered list of write-op buffers
/// (prelude, then header/payload per section). Keeping each buffer a
/// separate op gives the fault injector a crash point at every syscall
/// boundary of the writer.
pub(crate) fn encode_snapshot(
    index: &IndexAny,
    stamp: &SnapshotStamp,
    generation: u64,
) -> Vec<Vec<u8>> {
    let (backend, scheme, shard_count) = match index.kind() {
        IndexKind::Linear(_) => (BACKEND_LINEAR, SubstringScheme::Contiguous, 1u32),
        IndexKind::Mih(ix) => (BACKEND_MIH, ix.scheme(), 1u32),
        IndexKind::Sharded(ix) => {
            let scheme = ix
                .shards()
                .first()
                .map(|s| s.scheme())
                .unwrap_or(SubstringScheme::Contiguous);
            (BACKEND_SHARDED, scheme, ix.shard_count() as u32)
        }
    };
    let mut meta = Vec::with_capacity(46);
    meta.push(backend);
    meta.push(match scheme {
        SubstringScheme::Contiguous => 0u8,
        SubstringScheme::Sampled => 1u8,
    });
    put_u64(&mut meta, index.bits() as u64);
    put_u64(&mut meta, index.len() as u64);
    put_u32(&mut meta, shard_count);
    put_u64(&mut meta, generation);
    // u64::MAX is the "no model stamp" sentinel (registry versions are
    // small integers, so the collision is theoretical).
    put_u64(&mut meta, stamp.model_version.unwrap_or(u64::MAX));
    put_u64(&mut meta, stamp.fingerprint);

    let mut sections: Vec<(u32, Vec<u8>)> = vec![(SEC_META, meta)];
    match index.kind() {
        IndexKind::Linear(ix) => {
            sections.push((SEC_CODES, encode_codes_rows(&ix.codes, None, ix.codes.n)));
            sections.push((SEC_IDS, encode_ids_rows(&ix.ids, None, ix.ids.len())));
        }
        IndexKind::Mih(ix) => mih_sections(ix, &mut sections),
        IndexKind::Sharded(ix) => {
            for shard in ix.shards() {
                mih_sections(shard, &mut sections);
            }
        }
    }

    let mut ops = Vec::with_capacity(1 + sections.len() * 2);
    let mut prelude = Vec::with_capacity(24);
    prelude.extend_from_slice(&SNAP_MAGIC);
    put_u32(&mut prelude, SNAP_FORMAT);
    put_u32(&mut prelude, sections.len() as u32);
    let crc = crc32(&prelude);
    put_u32(&mut prelude, crc);
    put_u32(&mut prelude, 0);
    ops.push(prelude);
    for (id, mut payload) in sections {
        let mut header = Vec::with_capacity(24);
        put_u32(&mut header, id);
        put_u32(&mut header, 0);
        put_u64(&mut header, payload.len() as u64);
        put_u32(&mut header, crc32(&payload));
        put_u32(&mut header, 0);
        ops.push(header);
        let pad = (8 - payload.len() % 8) % 8;
        payload.resize(payload.len() + pad, 0);
        ops.push(payload);
    }
    ops
}

// ---------------------------------------------------------------- decode

/// Byte offset of `slice` within the mapping — defined only when the
/// decoder is actually reading off `map.as_slice()`, which is how every
/// mapped decode is invoked.
fn offset_in(map: &Arc<Mmap>, slice: &[u8]) -> Option<usize> {
    let base = map.as_slice().as_ptr() as usize;
    (slice.as_ptr() as usize)
        .checked_sub(base)
        .filter(|off| off + slice.len() <= map.len())
}

/// Adopt a CRC-verified LE u64 array in place when a mapping is
/// available (and the window is in bounds + aligned); copy otherwise.
/// On the little-endian targets that can map, the two are bit-identical.
fn adopt_u64s(bytes: &[u8], len: usize, map: Option<&Arc<Mmap>>) -> Words {
    debug_assert_eq!(bytes.len(), len * 8);
    if let Some(m) = map {
        if let Some(store) = offset_in(m, bytes).and_then(|off| Words::mapped(m, off, len)) {
            return store;
        }
    }
    Words::owned(
        bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().expect("8 bytes")))
            .collect(),
    )
}

/// Adopt a CRC-verified LE u32 array in place; copy otherwise.
fn adopt_u32s(bytes: &[u8], len: usize, map: Option<&Arc<Mmap>>) -> Postings {
    debug_assert_eq!(bytes.len(), len * 4);
    if let Some(m) = map {
        if let Some(store) = offset_in(m, bytes).and_then(|off| Postings::mapped(m, off, len)) {
            return store;
        }
    }
    Postings::owned(
        bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().expect("4 bytes")))
            .collect(),
    )
}

fn decode_codes(payload: &[u8], bits: usize, map: Option<&Arc<Mmap>>) -> Result<BitCode, String> {
    let mut r = Reader::new(payload);
    let n = r.take_u64("codes row count")?;
    if n > u32::MAX as u64 {
        return Err(format!("codes row count {n} exceeds u32 id space"));
    }
    let n = n as usize;
    let wpc = bits.div_ceil(64);
    let need = n
        .checked_mul(wpc)
        .and_then(|w| w.checked_mul(8))
        .ok_or_else(|| "codes section size overflows".to_string())?;
    if r.remaining() != need {
        return Err(format!(
            "codes payload is {} bytes, expected {need} for {n} rows of {wpc} words",
            r.remaining()
        ));
    }
    let data = adopt_u64s(r.take(need, "code words")?, n * wpc, map);
    let codes = BitCode {
        n,
        bits,
        words_per_code: wpc,
        data,
    };
    if !codes.padding_is_zero() {
        return Err("nonzero padding bits in stored codes".to_string());
    }
    Ok(codes)
}

fn decode_ids(payload: &[u8]) -> Result<Vec<u32>, String> {
    let mut r = Reader::new(payload);
    let n = r.take_u64("id count")?;
    if n > u32::MAX as u64 {
        return Err(format!("id count {n} exceeds u32 id space"));
    }
    let n = n as usize;
    if r.remaining() != n * 4 {
        return Err(format!(
            "ids payload is {} bytes, expected {} for {n} ids",
            r.remaining(),
            n * 4
        ));
    }
    Ok(r.take(n * 4, "ids")?
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().expect("4 bytes")))
        .collect())
}

fn decode_tables(
    payload: &[u8],
    bits: usize,
    n_rows: usize,
    map: Option<&Arc<Mmap>>,
) -> Result<Vec<SubstringTable>, String> {
    let mut r = Reader::new(payload);
    let count = r.take_u32("table count")? as usize;
    if count == 0 || count > bits {
        return Err(format!("table count {count} out of range for {bits} bits"));
    }
    // Exactness (the pigeonhole probe bound) requires the tables to
    // partition the code bits: every bit in exactly one table.
    let mut coverage = vec![false; bits];
    let mut cover = |bit: usize| -> Result<(), String> {
        if bit >= bits {
            return Err(format!("table bit {bit} out of range for {bits} bits"));
        }
        if coverage[bit] {
            return Err(format!("code bit {bit} claimed by two tables"));
        }
        coverage[bit] = true;
        Ok(())
    };
    let mut tables = Vec::with_capacity(count);
    for ti in 0..count {
        let source = match r.take_u8("table source tag")? {
            0 => {
                let start = r.take_u64("span start")?;
                let len = r.take_u64("span len")?;
                if len == 0 || len > 64 || start.checked_add(len).map_or(true, |e| e > bits as u64) {
                    return Err(format!("table {ti}: span {start}+{len} invalid for {bits} bits"));
                }
                for b in start..start + len {
                    cover(b as usize)?;
                }
                KeySource::Span {
                    start: start as usize,
                    len: len as usize,
                }
            }
            1 => {
                let cnt = r.take_u32("sampled position count")? as usize;
                if cnt == 0 || cnt > 64 {
                    return Err(format!("table {ti}: {cnt} sampled positions out of range"));
                }
                let mut positions = Vec::with_capacity(cnt);
                let mut prev: i64 = -1;
                for _ in 0..cnt {
                    let p = r.take_u32("sampled position")?;
                    if i64::from(p) <= prev {
                        return Err(format!(
                            "table {ti}: sampled positions not strictly increasing"
                        ));
                    }
                    prev = i64::from(p);
                    cover(p as usize)?;
                    positions.push(p);
                }
                KeySource::Sampled {
                    positions: positions.into_boxed_slice(),
                }
            }
            tag => return Err(format!("table {ti}: unknown source tag {tag}")),
        };
        let key_bits = source.key_bits();
        let bucket_count = r.take_u64("bucket count")? as usize;
        let postings_total = r.take_u64("postings total")? as usize;
        // Every live row keys into exactly one bucket per table.
        if postings_total != n_rows {
            return Err(format!(
                "table {ti}: {postings_total} postings for {n_rows} rows"
            ));
        }
        if bucket_count > n_rows {
            return Err(format!(
                "table {ti}: {bucket_count} buckets exceed {n_rows} rows"
            ));
        }
        let mut dir: Vec<(u64, u32)> = Vec::with_capacity(bucket_count);
        let mut keys: HashSet<u64, BuildFastHash> =
            HashSet::with_capacity_and_hasher(bucket_count, BuildFastHash::default());
        let mut sum = 0usize;
        for _ in 0..bucket_count {
            let key = r.take_u64("bucket key")?;
            let len = r.take_u32("bucket len")?;
            if key_bits < 64 && key >> key_bits != 0 {
                return Err(format!("table {ti}: key {key:#x} wider than {key_bits} bits"));
            }
            if len == 0 {
                return Err(format!("table {ti}: empty bucket"));
            }
            if !keys.insert(key) {
                return Err(format!("table {ti}: duplicate bucket key {key:#x}"));
            }
            sum += len as usize;
            dir.push((key, len));
        }
        if sum != postings_total {
            return Err(format!(
                "table {ti}: bucket lengths sum to {sum}, postings total says {postings_total}"
            ));
        }
        // Format v2 alignment pad before the postings array (see the
        // module grammar): 0–3 bytes, required zero.
        let pad = (4 - r.pos() % 4) % 4;
        if r.take(pad, "postings alignment pad")?.iter().any(|&b| b != 0) {
            return Err(format!("table {ti}: nonzero postings alignment pad"));
        }
        let need = postings_total
            .checked_mul(4)
            .ok_or_else(|| format!("table {ti}: postings size overflows"))?;
        let arena = adopt_u32s(r.take(need, "postings")?, postings_total, map);
        let mut seen = vec![false; n_rows];
        for &p in arena.iter() {
            if p as usize >= n_rows || seen[p as usize] {
                return Err(format!("table {ti}: posting {p} out of range or repeated"));
            }
            seen[p as usize] = true;
        }
        tables.push(SubstringTable::from_buckets(source, &dir, arena));
    }
    if !r.is_done() {
        return Err("trailing bytes in tables section".to_string());
    }
    if let Some(bit) = coverage.iter().position(|c| !*c) {
        return Err(format!("code bit {bit} not covered by any table"));
    }
    Ok(tables)
}

fn expect_section<'a>(
    secs: &[(u32, &'a [u8])],
    at: usize,
    want: u32,
    what: &str,
) -> Result<&'a [u8], String> {
    match secs.get(at) {
        Some(&(id, payload)) if id == want => Ok(payload),
        Some(&(id, _)) => Err(format!("section {at} is id {id}, expected {what}")),
        None => Err(format!("missing section {at} ({what})")),
    }
}

fn decode_mih_body(
    secs: &[(u32, &[u8])],
    at: usize,
    bits: usize,
    scheme: SubstringScheme,
    id_set: &mut HashSet<u32, BuildFastHash>,
    map: Option<&Arc<Mmap>>,
) -> Result<MihIndex, String> {
    let codes = decode_codes(expect_section(secs, at, SEC_CODES, "CODES")?, bits, map)?;
    let ids = decode_ids(expect_section(secs, at + 1, SEC_IDS, "IDS")?)?;
    if codes.n != ids.len() {
        return Err(format!("{} codes but {} ids", codes.n, ids.len()));
    }
    for &id in &ids {
        if !id_set.insert(id) {
            return Err(format!("duplicate id {id}"));
        }
    }
    let tables = decode_tables(
        expect_section(secs, at + 2, SEC_TABLES, "TABLES")?,
        bits,
        codes.n,
        map,
    )?;
    Ok(MihIndex::from_parts(codes, ids, tables, scheme))
}

/// Decode and fully validate a snapshot image. When `map` is given,
/// `bytes` must be `map.as_slice()`: every validation still runs over
/// the bytes (a single streaming pass, CRC first), but the big flat
/// arrays are adopted as zero-copy windows into the map instead of
/// copied to the heap.
pub(crate) fn decode_snapshot(
    bytes: &[u8],
    map: Option<&Arc<Mmap>>,
) -> Result<(IndexAny, SnapshotMeta), String> {
    if bytes.len() < 24 {
        return Err(format!("snapshot is {} bytes, shorter than the prelude", bytes.len()));
    }
    if bytes[..8] != SNAP_MAGIC {
        return Err("snapshot magic mismatch".to_string());
    }
    let mut r = Reader::new(&bytes[8..24]);
    let format = r.take_u32("format version")?;
    if format != SNAP_FORMAT {
        return Err(format!("unsupported snapshot format version {format}"));
    }
    let section_count = r.take_u32("section count")?;
    let crc = r.take_u32("prelude crc")?;
    if crc != crc32(&bytes[..16]) {
        return Err("prelude crc mismatch".to_string());
    }
    if section_count == 0 || section_count > 3 * MAX_SHARDS + 1 {
        return Err(format!("implausible section count {section_count}"));
    }

    let mut secs: Vec<(u32, &[u8])> = Vec::with_capacity(section_count as usize);
    let mut at = 24usize;
    for si in 0..section_count {
        if bytes.len() - at < 24 {
            return Err(format!("truncated header of section {si}"));
        }
        let h = &bytes[at..at + 24];
        let id = u32::from_le_bytes(h[0..4].try_into().expect("4 bytes"));
        let len = u64::from_le_bytes(h[8..16].try_into().expect("8 bytes"));
        let sec_crc = u32::from_le_bytes(h[16..20].try_into().expect("4 bytes"));
        at += 24;
        if len > (bytes.len() - at) as u64 {
            return Err(format!("truncated payload of section {si} (id {id})"));
        }
        let len = len as usize;
        let payload = &bytes[at..at + len];
        if crc32(payload) != sec_crc {
            return Err(format!("crc mismatch in section {si} (id {id})"));
        }
        let padded = len + (8 - len % 8) % 8;
        if padded > bytes.len() - at {
            return Err(format!("truncated padding of section {si}"));
        }
        at += padded;
        secs.push((id, payload));
    }
    if at != bytes.len() {
        return Err(format!("{} trailing bytes after the last section", bytes.len() - at));
    }

    let mut m = Reader::new(expect_section(&secs, 0, SEC_META, "META")?);
    let backend = m.take_u8("backend tag")?;
    let scheme = match m.take_u8("scheme tag")? {
        0 => SubstringScheme::Contiguous,
        1 => SubstringScheme::Sampled,
        tag => return Err(format!("unknown substring scheme tag {tag}")),
    };
    let bits = m.take_u64("code bits")?;
    if bits == 0 || bits > MAX_BITS {
        return Err(format!("implausible code width {bits}"));
    }
    let bits = bits as usize;
    let n_live = m.take_u64("live row count")?;
    if n_live > u32::MAX as u64 {
        return Err(format!("live row count {n_live} exceeds u32 id space"));
    }
    let shard_count = m.take_u32("shard count")?;
    let generation = m.take_u64("generation")?;
    let model_version = match m.take_u64("model version")? {
        u64::MAX => None,
        v => Some(v),
    };
    let fingerprint = m.take_u64("model fingerprint")?;
    if !m.is_done() {
        return Err("trailing bytes in META".to_string());
    }
    let meta = SnapshotMeta {
        generation,
        model_version,
        fingerprint,
    };

    let mut id_set: HashSet<u32, BuildFastHash> =
        HashSet::with_capacity_and_hasher(n_live as usize, BuildFastHash::default());
    let kind = match backend {
        BACKEND_LINEAR => {
            if shard_count != 1 || secs.len() != 3 {
                return Err("linear snapshot must be exactly META+CODES+IDS".to_string());
            }
            let codes = decode_codes(expect_section(&secs, 1, SEC_CODES, "CODES")?, bits, map)?;
            let ids = decode_ids(expect_section(&secs, 2, SEC_IDS, "IDS")?)?;
            if codes.n != ids.len() || codes.n as u64 != n_live {
                return Err(format!(
                    "linear row counts disagree: {} codes, {} ids, META says {n_live}",
                    codes.n,
                    ids.len()
                ));
            }
            IndexKind::Linear(BinaryIndex::with_ids(codes, ids))
        }
        BACKEND_MIH => {
            if shard_count != 1 || secs.len() != 4 {
                return Err("mih snapshot must be exactly META+CODES+IDS+TABLES".to_string());
            }
            let ix = decode_mih_body(&secs, 1, bits, scheme, &mut id_set, map)?;
            if ix.len() as u64 != n_live {
                return Err(format!("mih has {} rows, META says {n_live}", ix.len()));
            }
            IndexKind::Mih(ix)
        }
        BACKEND_SHARDED => {
            if shard_count == 0 || shard_count > MAX_SHARDS {
                return Err(format!("implausible shard count {shard_count}"));
            }
            if secs.len() != 1 + 3 * shard_count as usize {
                return Err(format!(
                    "sharded snapshot has {} sections, expected {} for {shard_count} shards",
                    secs.len(),
                    1 + 3 * shard_count as usize
                ));
            }
            let mut shards = Vec::with_capacity(shard_count as usize);
            for s in 0..shard_count as usize {
                shards.push(
                    decode_mih_body(&secs, 1 + 3 * s, bits, scheme, &mut id_set, map)
                        .map_err(|e| format!("shard {s}: {e}"))?,
                );
            }
            let total: usize = shards.iter().map(|s| s.len()).sum();
            if total as u64 != n_live {
                return Err(format!("shards hold {total} rows, META says {n_live}"));
            }
            IndexKind::Sharded(ShardedIndex::from_shards(shards, bits))
        }
        tag => return Err(format!("unknown backend tag {tag}")),
    };
    Ok((IndexAny::from(kind), meta))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::{build_index_with_ids, IndexBackend};
    use crate::util::rng::Pcg64;

    fn image(index: &IndexAny, generation: u64) -> Vec<u8> {
        encode_snapshot(
            index,
            &SnapshotStamp {
                model_version: Some(7),
                fingerprint: 0x5EED,
            },
            generation,
        )
        .concat()
    }

    fn random_index(n: usize, bits: usize, backend: &IndexBackend, seed: u64) -> IndexAny {
        let mut rng = Pcg64::new(seed);
        let codes = BitCode::from_signs(&rng.sign_vec(n * bits), n, bits);
        let ids = (0..n as u32).map(|i| i * 3 + 1).collect();
        build_index_with_ids(codes, ids, backend)
    }

    fn assert_same_results(a: &IndexAny, b: &IndexAny, bits: usize, seed: u64) {
        let mut rng = Pcg64::new(seed);
        let queries = BitCode::from_signs(&rng.sign_vec(8 * bits), 8, bits);
        for qi in 0..queries.n {
            assert_eq!(
                a.search(queries.code(qi), 10),
                b.search(queries.code(qi), 10),
                "query {qi} diverged after a snapshot roundtrip"
            );
        }
    }

    #[test]
    fn roundtrips_every_backend_including_odd_word_counts() {
        // bits=160 → words_per_code=3 (odd, with 32 padding bits);
        // bits=64 → exactly one word, no padding.
        for (backend, bits, n) in [
            (IndexBackend::Linear, 160, 50),
            (IndexBackend::Mih { m: Some(4) }, 160, 120),
            (IndexBackend::MihSampled { m: Some(4) }, 96, 80),
            (
                IndexBackend::ShardedMih {
                    shards: 3,
                    m: Some(2),
                },
                64,
                90,
            ),
        ] {
            let index = random_index(n, bits, &backend, 42 + bits as u64);
            let img = image(&index, 9);
            let (loaded, meta) = decode_snapshot(&img, None).unwrap();
            assert_eq!(meta.generation, 9);
            assert_eq!(meta.model_version, Some(7));
            assert_eq!(meta.fingerprint, 0x5EED);
            assert_eq!(loaded.len(), index.len());
            assert_eq!(loaded.backend_name(), index.backend_name());
            assert_same_results(&index, &loaded, bits, 1000 + bits as u64);
        }
    }

    #[test]
    fn roundtrips_an_empty_index() {
        let index = random_index(0, 128, &IndexBackend::Mih { m: Some(2) }, 5);
        let (loaded, _) = decode_snapshot(&image(&index, 1), None).unwrap();
        assert_eq!(loaded.len(), 0);
        assert!(loaded.search(&[0u64, 0], 3).is_empty());
    }

    #[test]
    fn save_compacts_tombstones_out() {
        // 60 storage slots ≤ the auto-compaction floor (64), so removals
        // leave tombstones in memory — the writer must drop them.
        let mut index = random_index(60, 128, &IndexBackend::Mih { m: Some(4) }, 11);
        for id in (0..60u32).map(|i| i * 3 + 1).take(35) {
            assert_eq!(index.remove(id), Ok(true));
        }
        let storage = match index.kind() {
            IndexKind::Mih(ix) => ix.storage_slots(),
            _ => unreachable!(),
        };
        assert_eq!(storage, 60, "tombstones still occupy storage in memory");
        let (loaded, _) = decode_snapshot(&image(&index, 2), None).unwrap();
        assert_eq!(loaded.len(), 25);
        match loaded.kind() {
            IndexKind::Mih(ix) => assert_eq!(
                ix.storage_slots(),
                25,
                "a loaded snapshot is in canonical compacted form"
            ),
            _ => unreachable!(),
        }
        assert_same_results(&index, &loaded, 128, 12);
    }

    #[test]
    fn every_single_byte_is_load_bearing_or_ignored_safely() {
        // Flip one bit in each byte of a small snapshot: the result must
        // be a typed error or a bit-identical index — never a panic and
        // never different search results.
        let index = random_index(30, 96, &IndexBackend::Mih { m: Some(3) }, 21);
        let img = image(&index, 1);
        for byte in 0..img.len() {
            let mut bad = img.clone();
            bad[byte] ^= 0x04;
            match decode_snapshot(&bad, None) {
                Err(_) => {}
                Ok((loaded, _)) => {
                    // Only section padding escapes a CRC; results must
                    // still be exact.
                    assert_same_results(&index, &loaded, 96, 22);
                }
            }
        }
    }

    #[test]
    fn truncation_at_any_length_is_a_typed_error() {
        let index = random_index(20, 64, &IndexBackend::Mih { m: Some(2) }, 31);
        let img = image(&index, 1);
        for cut in 0..img.len() {
            assert!(
                decode_snapshot(&img[..cut], None).is_err(),
                "truncation to {cut} bytes must not decode"
            );
        }
    }

    #[test]
    fn mapped_decode_is_zero_copy_and_exact() {
        if !Mmap::supported() {
            return;
        }
        for (backend, bits, n) in [
            (IndexBackend::Mih { m: Some(4) }, 160, 120),
            (
                IndexBackend::ShardedMih {
                    shards: 3,
                    m: Some(2),
                },
                64,
                90,
            ),
        ] {
            let index = random_index(n, bits, &backend, 77 + bits as u64);
            let img = image(&index, 3);
            let path = std::env::temp_dir().join(format!(
                "cbe_snap_mapped_{}_{bits}",
                std::process::id()
            ));
            std::fs::write(&path, &img).unwrap();
            let map = Arc::new(Mmap::map(&std::fs::File::open(&path).unwrap()).unwrap());
            let (loaded, meta) = decode_snapshot(map.as_slice(), Some(&map)).unwrap();
            assert_eq!(meta.generation, 3);
            assert_eq!(loaded.len(), index.len());
            // The big flat arrays must actually be windows into the map,
            // not copies — for every shard of the loaded index.
            let shards: Vec<&MihIndex> = match loaded.kind() {
                IndexKind::Mih(ix) => vec![ix],
                IndexKind::Sharded(ix) => ix.shards().iter().collect(),
                IndexKind::Linear(_) => unreachable!(),
            };
            for mih in shards {
                let (codes, _, _, tables) = mih.storage_parts();
                assert!(codes.data.is_mapped(), "codes adopted in place");
                for t in tables {
                    assert!(t.arena_is_mapped(), "postings adopted in place");
                }
            }
            assert_same_results(&index, &loaded, bits, 78 + bits as u64);
            drop(loaded);
            let _ = std::fs::remove_file(path);
        }
    }
}
