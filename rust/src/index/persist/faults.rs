//! Deterministic fault injection for the persistence tier.
//!
//! Every durability-relevant syscall the snapshot and WAL writers make —
//! each `write_all`, each `fsync`, each `rename`, each directory fsync —
//! is one *op* on a shared [`FaultClock`]. A [`FaultPlan`] names an op
//! index at which the world ends: the op either fails with an injected
//! `io::Error` (optionally after landing a torn prefix of the write), or
//! aborts the whole process (`kill -9` semantics for the CI smoke test).
//! Because the op sequence of a given save/append is deterministic,
//! tests can dry-run once to count ops, then replay the exact same
//! workload crashing at every boundary `0..n` — the recovery matrix.
//!
//! The clock is plumbed by `&mut` through the writers rather than
//! stored in a thread-local so concurrent indexes don't interleave op
//! counts, and so the zero-fault fast path is one branch per syscall.
//!
//! A plan can also come from the environment (`CBE_FAULT=crash:<n>` or
//! `CBE_FAULT=abort:<n>`), which is how the CI recovery smoke kills a
//! real `cbe save-index` process mid-snapshot from outside.

use std::fs::{self, File};
use std::io::{self, Write};
use std::path::Path;

/// What to do to the write stream, and when. The default plan does
/// nothing and costs one branch + one increment per syscall.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Op index at which to inject the failure (None = never).
    pub crash_at: Option<u64>,
    /// If the crashing op is a write, how many bytes still reach the
    /// file before the failure — models a torn sector.
    pub torn_bytes: usize,
    /// `(op, bit)`: flip one bit of that op's write buffer (bit index
    /// taken modulo the buffer length). The op itself succeeds — this
    /// models silent media corruption that checksums must catch.
    pub flip: Option<(u64, u64)>,
    /// Crash via `std::process::abort()` instead of an `io::Error` —
    /// nothing unwinds, no `Drop` runs; the real `kill -9`.
    pub abort: bool,
}

impl FaultPlan {
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Fail op `op` cleanly (no bytes of it land).
    pub fn crash_at(op: u64) -> FaultPlan {
        FaultPlan {
            crash_at: Some(op),
            ..FaultPlan::default()
        }
    }

    /// Fail op `op` after writing only its first `bytes` bytes.
    pub fn torn_at(op: u64, bytes: usize) -> FaultPlan {
        FaultPlan {
            crash_at: Some(op),
            torn_bytes: bytes,
            ..FaultPlan::default()
        }
    }

    /// Flip bit `bit` of op `op`'s buffer and keep going.
    pub fn flip_at(op: u64, bit: u64) -> FaultPlan {
        FaultPlan {
            flip: Some((op, bit)),
            ..FaultPlan::default()
        }
    }

    /// Parse `CBE_FAULT` (`crash:<n>` | `abort:<n>` | `torn:<n>:<bytes>`).
    /// Unset or unparsable → no faults; a typo must not brick a writer.
    pub fn from_env() -> FaultPlan {
        match std::env::var("CBE_FAULT") {
            Ok(spec) => FaultPlan::parse(&spec).unwrap_or_default(),
            Err(_) => FaultPlan::default(),
        }
    }

    fn parse(spec: &str) -> Option<FaultPlan> {
        let mut parts = spec.split(':');
        let kind = parts.next()?;
        let op: u64 = parts.next()?.parse().ok()?;
        match kind {
            "crash" => Some(FaultPlan::crash_at(op)),
            "abort" => Some(FaultPlan {
                abort: true,
                ..FaultPlan::crash_at(op)
            }),
            "torn" => {
                let bytes: usize = parts.next()?.parse().ok()?;
                Some(FaultPlan::torn_at(op, bytes))
            }
            _ => None,
        }
    }

    pub fn is_none(&self) -> bool {
        self.crash_at.is_none() && self.flip.is_none()
    }
}

/// What the current op should do, as decided by the clock.
pub(crate) enum Step {
    Proceed,
    /// Proceed, but flip this bit of the write buffer first.
    Flip(u64),
    /// Fail; if a write, land only `torn` bytes first.
    Crash { torn: usize },
}

/// Op counter + plan. One clock per logical writer (a `PersistentIndex`
/// owns one for its whole life, so op indices span snapshot writes, WAL
/// appends, and checkpoints in order).
#[derive(Debug)]
pub struct FaultClock {
    plan: FaultPlan,
    ops: u64,
    /// Once a fault has fired the writer is dead: every later op fails
    /// too, so a `Drop`-time flush can't resurrect a crashed file.
    dead: bool,
}

impl FaultClock {
    pub fn new(plan: FaultPlan) -> FaultClock {
        FaultClock {
            plan,
            ops: 0,
            dead: false,
        }
    }

    pub fn none() -> FaultClock {
        FaultClock::new(FaultPlan::none())
    }

    pub fn from_env() -> FaultClock {
        FaultClock::new(FaultPlan::from_env())
    }

    /// Ops consumed so far (a completed dry run's count bounds the crash
    /// points the recovery-matrix test needs to cover).
    pub fn ops(&self) -> u64 {
        self.ops
    }

    pub(crate) fn step(&mut self) -> Step {
        if self.dead {
            return Step::Crash { torn: 0 };
        }
        let op = self.ops;
        self.ops += 1;
        if self.plan.crash_at == Some(op) {
            if self.plan.abort {
                eprintln!("CBE_FAULT: aborting at persistence op {op}");
                std::process::abort();
            }
            self.dead = true;
            return Step::Crash {
                torn: self.plan.torn_bytes,
            };
        }
        if let Some((fop, bit)) = self.plan.flip {
            if fop == op {
                return Step::Flip(bit);
            }
        }
        Step::Proceed
    }
}

pub(crate) fn injected_err(what: &str) -> io::Error {
    io::Error::new(io::ErrorKind::Other, format!("injected fault during {what}"))
}

/// Fault-aware file writer: each `write_all`/`sync` is one clock op.
pub(crate) struct Sink<'a> {
    pub file: &'a mut File,
    pub clock: &'a mut FaultClock,
}

impl Sink<'_> {
    pub fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        match self.clock.step() {
            Step::Proceed => self.file.write_all(buf),
            Step::Flip(bit) => {
                let mut flipped = buf.to_vec();
                if !flipped.is_empty() {
                    let b = (bit as usize) % (flipped.len() * 8);
                    flipped[b / 8] ^= 1 << (b % 8);
                }
                self.file.write_all(&flipped)
            }
            Step::Crash { torn } => {
                let torn = torn.min(buf.len());
                if torn > 0 {
                    self.file.write_all(&buf[..torn])?;
                    // The torn prefix must be *durable* to model the
                    // worst case: sector hit the platter, then power cut.
                    let _ = self.file.sync_all();
                }
                Err(injected_err("write"))
            }
        }
    }

    pub fn sync(&mut self) -> io::Result<()> {
        match self.clock.step() {
            Step::Crash { .. } => Err(injected_err("fsync")),
            _ => self.file.sync_all(),
        }
    }
}

/// Fault-aware atomic rename (one op).
pub(crate) fn rename(clock: &mut FaultClock, from: &Path, to: &Path) -> io::Result<()> {
    match clock.step() {
        Step::Crash { .. } => Err(injected_err("rename")),
        _ => fs::rename(from, to),
    }
}

/// Fault-aware directory fsync (one op) — makes the rename itself
/// durable. Best-effort on filesystems that refuse to open a directory;
/// the injected crash is still honored so op counts stay deterministic.
pub(crate) fn sync_dir(clock: &mut FaultClock, dir: &Path) -> io::Result<()> {
    match clock.step() {
        Step::Crash { .. } => Err(injected_err("directory fsync")),
        _ => {
            if let Ok(d) = File::open(dir) {
                let _ = d.sync_all();
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parses_env_grammar() {
        assert_eq!(FaultPlan::parse("crash:7"), Some(FaultPlan::crash_at(7)));
        assert_eq!(
            FaultPlan::parse("torn:3:12"),
            Some(FaultPlan::torn_at(3, 12))
        );
        let abort = FaultPlan::parse("abort:2").unwrap();
        assert!(abort.abort);
        assert_eq!(abort.crash_at, Some(2));
        assert_eq!(FaultPlan::parse("nonsense"), None);
        assert_eq!(FaultPlan::parse("crash:x"), None);
    }

    #[test]
    fn clock_crashes_exactly_once_then_stays_dead() {
        let mut clock = FaultClock::new(FaultPlan::crash_at(2));
        assert!(matches!(clock.step(), Step::Proceed));
        assert!(matches!(clock.step(), Step::Proceed));
        assert!(matches!(clock.step(), Step::Crash { torn: 0 }));
        // Dead forever after — Drop-time flushes can't write post-crash.
        assert!(matches!(clock.step(), Step::Crash { torn: 0 }));
        assert!(matches!(clock.step(), Step::Crash { torn: 0 }));
    }

    #[test]
    fn sink_lands_the_torn_prefix() {
        let dir = std::env::temp_dir().join(format!("cbe_faults_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("torn.bin");
        let mut f = File::create(&path).unwrap();
        let mut clock = FaultClock::new(FaultPlan::torn_at(0, 3));
        let mut sink = Sink {
            file: &mut f,
            clock: &mut clock,
        };
        let err = sink.write_all(b"abcdef").unwrap_err();
        assert!(err.to_string().contains("injected"));
        drop(f);
        assert_eq!(std::fs::read(&path).unwrap(), b"abc");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
