//! Crash-safe persistence for the index tier: checksummed snapshots, a
//! write-ahead log for churn, and recovery that classifies every load.
//!
//! An index directory holds two files:
//!
//! ```text
//! <dir>/current.snap   sectioned snapshot (see [`snapshot`] grammar)
//! <dir>/wal.log        churn since that snapshot (see [`wal`] grammar)
//! ```
//!
//! Both are replaced atomically (write to `snap.tmp`/`wal.tmp`, fsync,
//! rename, fsync the directory) and paired by a *generation* number: a
//! checkpoint writes snapshot generation `g+1`, then a fresh WAL stamped
//! `g+1`. Whatever instant a crash lands on, the directory decodes to
//! exactly one of:
//!
//! * [`RecoveryState::Loaded`] — snapshot plus a cleanly-ending WAL
//!   (a WAL generation *behind* the snapshot is a checkpoint that died
//!   between the two renames; its records are already folded into the
//!   snapshot, so it is ignored and reset);
//! * [`RecoveryState::LoadedWithTruncatedWalTail`] — the WAL's last
//!   record was torn mid-write; the tail is dropped, *reported*, and
//!   physically truncated so the log is clean again;
//! * a typed [`CbeError::CorruptSnapshot`] — anything that cannot be
//!   explained by tearing the tail of an append-only file (bad magic or
//!   CRC, structural invariant failures, a WAL generation *ahead* of its
//!   snapshot). Never a panic, never silently wrong neighbors.
//!
//! Durability contract: [`PersistentIndex::insert`]/[`remove`] append to
//! the WAL (fsync'd by default) *before* touching the in-memory index,
//! so an acknowledged operation survives any later crash, and a crashed
//! operation is at worst a reported torn tail. After
//! [`PersistOptions::compact_threshold`] appends the log is folded into
//! a fresh snapshot automatically.
//!
//! Every syscall in the write paths is a crash point on a deterministic
//! [`faults::FaultClock`], which is how the recovery-matrix tests (and
//! the CI smoke's `CBE_FAULT=abort:<n>`) prove the claims above by
//! dying at every single boundary.

pub mod faults;
mod format;
pub mod mmap;
mod snapshot;
mod wal;

use crate::error::CbeError;
use crate::index::IndexAny;
use crate::index::substring::splitmix64;
use crate::obs::{self, Counter, Stage};
use faults::{FaultClock, FaultPlan, Sink};
use mmap::Mmap;
use snapshot::{SNAP_FILE, SNAP_TMP};
use std::fs::{self, File};
use std::io;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;
use wal::{Replay, WalOp, WalWriter};

/// Model-identity stamp carried inside a snapshot so a load can refuse
/// codes that were encoded by a different projection.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SnapshotStamp {
    /// Registry version the index was built at (None = unversioned).
    pub model_version: Option<u64>,
    /// Content fingerprint of the projection parameters, from
    /// [`model_fingerprint`] (0 = not stamped). Unlike the version
    /// counter, this survives process restarts: two runs that train the
    /// same deterministic model agree on it.
    pub fingerprint: u64,
}

impl SnapshotStamp {
    pub fn none() -> SnapshotStamp {
        SnapshotStamp {
            model_version: None,
            fingerprint: 0,
        }
    }
}

/// How a load should back the index's big flat arrays.
///
/// Resolution order is explicit config > `CBE_MMAP` env > platform
/// default: `Auto` consults `CBE_MMAP` (`1`/`true`/`on` forces the
/// mapped path, `0`/`false`/`off` the heap path) and otherwise maps
/// wherever [`Mmap::supported`] (unix + little-endian). Either way a
/// failed `mmap` syscall silently falls back to the heap loader — the
/// mode picks a fast path, never a new failure mode.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LoadMode {
    /// `CBE_MMAP` if set, else mapped wherever supported.
    #[default]
    Auto,
    /// Always the portable read + copy path.
    Heap,
    /// The zero-copy mapped path (still heap on unsupported targets).
    Mmap,
}

impl LoadMode {
    /// Should this load attempt the mapped path?
    fn try_mmap(self) -> bool {
        match self {
            LoadMode::Heap => false,
            LoadMode::Mmap => Mmap::supported(),
            LoadMode::Auto => match std::env::var("CBE_MMAP") {
                Ok(v) => match v.trim().to_ascii_lowercase().as_str() {
                    "0" | "false" | "off" => false,
                    "1" | "true" | "on" => Mmap::supported(),
                    _ => Mmap::supported(),
                },
                Err(_) => Mmap::supported(),
            },
        }
    }
}

/// Which path a load actually took (post-fallback).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoadPath {
    Mmap,
    Heap,
}

impl LoadPath {
    /// Stable name — the `load.mode` value in the stats snapshot JSON.
    pub fn name(self) -> &'static str {
        match self {
            LoadPath::Mmap => "mmap",
            LoadPath::Heap => "heap",
        }
    }
}

/// Knobs for a [`PersistentIndex`].
#[derive(Clone, Debug)]
pub struct PersistOptions {
    /// Fsync the WAL after every append (default). Turning this off
    /// trades the durability of the last few acknowledged operations
    /// for append throughput; crash consistency is unaffected.
    pub sync_on_append: bool,
    /// Fold the WAL into a fresh snapshot once it holds this many
    /// records (0 = never checkpoint automatically).
    pub compact_threshold: u64,
    /// Deterministic fault plan for the writers (tests/CI; the default
    /// comes from `CBE_FAULT`, which is empty in production).
    pub faults: FaultPlan,
    /// Snapshot-load backing: zero-copy mmap vs portable heap copy.
    pub load_mode: LoadMode,
}

impl Default for PersistOptions {
    fn default() -> PersistOptions {
        PersistOptions {
            sync_on_append: true,
            compact_threshold: 8192,
            faults: FaultPlan::from_env(),
            load_mode: LoadMode::Auto,
        }
    }
}

/// How a successful load classified the directory it found.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RecoveryState {
    /// Snapshot (plus a cleanly-ending or absent WAL) loaded verbatim.
    Loaded,
    /// The WAL's last record was torn by a crash mid-append; `dropped_bytes`
    /// of tail were discarded and the file truncated back to its last
    /// valid record. Everything before the tear was replayed.
    LoadedWithTruncatedWalTail { dropped_bytes: u64 },
}

/// What a load found and did.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub state: RecoveryState,
    /// Snapshot generation the directory was at.
    pub generation: u64,
    /// WAL records folded into the loaded index.
    pub wal_records_replayed: u64,
    /// Model identity the snapshot was saved under.
    pub stamp: SnapshotStamp,
    /// Which backing the load actually used (post-fallback).
    pub path: LoadPath,
    /// Snapshot bytes served straight from the mapping (0 on the heap
    /// path).
    pub mapped_bytes: u64,
}

/// Content fingerprint of a circulant projection's parameters (`r` and
/// the sign flips), for cross-process staleness detection: a snapshot
/// stamped with one fingerprint must only serve queries encoded by a
/// projection with the same one. Never returns 0 (0 = "not stamped").
pub fn model_fingerprint(r: &[f32], signs: &[f32]) -> u64 {
    let mut h = 0x5bd1_e995_0000_0001_u64 ^ ((r.len() as u64) << 32) ^ signs.len() as u64;
    for &v in r.iter().chain(signs.iter()) {
        h = splitmix64(h ^ u64::from(v.to_bits()));
    }
    h | 1
}

/// Fold one more component (another block's [`model_fingerprint`], a
/// bit-selection index, a variant tag) into a fingerprint chain. Chaining
/// is how multi-block models stay collision-distinct from single-block
/// ones without changing the single-block value: a one-block stacked
/// model never calls this, so its fingerprint equals the plain circulant
/// fingerprint of the same parameters — while any extra block or
/// selection plan perturbs the hash. Never returns 0 (0 = "not stamped").
pub fn fingerprint_chain(h: u64, component: u64) -> u64 {
    splitmix64(h ^ component.rotate_left(17)) | 1
}

fn io_cbe(ctx: &str, e: &io::Error) -> CbeError {
    CbeError::Service(format!("{ctx}: {e}"))
}

fn corrupt(reason: String) -> CbeError {
    CbeError::CorruptSnapshot { reason }
}

/// Write `index` as `<dir>/current.snap` atomically: every byte goes to
/// `snap.tmp`, which is fsync'd and renamed over the live file, then the
/// directory is fsync'd so the rename itself is durable.
fn write_snapshot(
    dir: &Path,
    index: &IndexAny,
    stamp: &SnapshotStamp,
    generation: u64,
    clock: &mut FaultClock,
) -> Result<(), CbeError> {
    fs::create_dir_all(dir).map_err(|e| io_cbe("create index dir", &e))?;
    let tmp = dir.join(SNAP_TMP);
    let ops = snapshot::encode_snapshot(index, stamp, generation);
    let mut f = File::create(&tmp).map_err(|e| io_cbe("create snap.tmp", &e))?;
    {
        let mut sink = Sink {
            file: &mut f,
            clock,
        };
        for buf in &ops {
            sink.write_all(buf)
                .map_err(|e| io_cbe("write snapshot", &e))?;
        }
        sink.sync().map_err(|e| io_cbe("fsync snapshot", &e))?;
    }
    drop(f);
    faults::rename(clock, &tmp, &dir.join(SNAP_FILE))
        .map_err(|e| io_cbe("rename snapshot into place", &e))?;
    faults::sync_dir(clock, dir).map_err(|e| io_cbe("fsync index dir", &e))?;
    Ok(())
}

/// Save `index` to `dir` at generation 1 with a fresh, empty WAL,
/// honoring any `CBE_FAULT` plan in the environment. Overwrites whatever
/// the directory held (atomically — a crash leaves the old state).
pub fn save(dir: &Path, index: &IndexAny, stamp: &SnapshotStamp) -> Result<(), CbeError> {
    let mut clock = FaultClock::from_env();
    write_snapshot(dir, index, stamp, 1, &mut clock)?;
    WalWriter::create(dir, 1, &mut clock).map_err(|e| io_cbe("create wal", &e))?;
    Ok(())
}

/// Whether the WAL should be continued or replaced after a load.
enum WalDisposition {
    /// Current-generation log, tail already repaired: append to it.
    Continue { records: u64 },
    /// Absent or stale (pre-checkpoint) log: write a fresh one.
    Reset,
}

fn apply_replay(index: &mut IndexAny, rec: Replay, wpc: usize, bits: usize) -> Result<(), CbeError> {
    match rec {
        Replay::Insert { id, code } => {
            if index.contains(id) {
                return Err(corrupt(format!("wal inserts id {id} already in the snapshot")));
            }
            debug_assert_eq!(code.len(), wpc, "scan_wal sized the record");
            let pad = wpc * 64 - bits;
            if pad > 0 && code[wpc - 1] >> (64 - pad) != 0 {
                return Err(corrupt(format!("wal insert of id {id} has nonzero padding bits")));
            }
            index
                .insert(id, &code)
                .map_err(|e| corrupt(format!("wal insert rejected: {e}")))?;
        }
        Replay::Remove { id } => {
            let removed = index
                .remove(id)
                .map_err(|e| corrupt(format!("wal remove rejected: {e}")))?;
            if !removed {
                return Err(corrupt(format!("wal removes id {id} absent from the snapshot")));
            }
        }
    }
    Ok(())
}

/// Decode the snapshot file, preferring the zero-copy mapped path when
/// `mode` allows it. The verify pass (CRCs + structural re-validation)
/// is one streaming front-to-back read either way — on the mapped path
/// it runs under `madvise(SEQUENTIAL)` and the map is flipped to
/// `WILLNEED` once verified, so first-query latency overlaps page-in.
fn decode_snapshot_file(
    snap_path: &Path,
    mode: LoadMode,
) -> Result<(IndexAny, snapshot::SnapshotMeta, LoadPath, u64), CbeError> {
    let cannot = |e: &dyn std::fmt::Display| corrupt(format!("cannot read {}: {e}", snap_path.display()));
    let t0 = Instant::now();
    if mode.try_mmap() {
        let file = File::open(snap_path).map_err(|e| cannot(&e))?;
        if let Ok(map) = Mmap::map(&file) {
            let map = Arc::new(map);
            map.advise_sequential();
            let (index, meta) =
                snapshot::decode_snapshot(map.as_slice(), Some(&map)).map_err(corrupt)?;
            map.advise_willneed();
            let mapped_bytes = map.len() as u64;
            obs::add(Counter::MmapLoad, 1);
            obs::add(Counter::MappedBytes, mapped_bytes);
            obs::add(Counter::LoadVerifyUs, t0.elapsed().as_micros() as u64);
            return Ok((index, meta, LoadPath::Mmap, mapped_bytes));
        }
        // Map failed (unsupported target, exotic filesystem): fall
        // through to the portable path with the file already open.
    }
    let bytes = fs::read(snap_path).map_err(|e| cannot(&e))?;
    let (index, meta) = snapshot::decode_snapshot(&bytes, None).map_err(corrupt)?;
    obs::add(Counter::HeapLoad, 1);
    obs::add(Counter::LoadVerifyUs, t0.elapsed().as_micros() as u64);
    Ok((index, meta, LoadPath::Heap, 0))
}

fn load_inner(dir: &Path, mode: LoadMode) -> Result<(IndexAny, LoadReport, WalDisposition), CbeError> {
    let snap_path = dir.join(SNAP_FILE);
    let (mut index, meta, path, mapped_bytes) = decode_snapshot_file(&snap_path, mode)?;
    let bits = index.bits();
    let wpc = bits.div_ceil(64);

    let mut state = RecoveryState::Loaded;
    let mut replayed = 0u64;
    let mut disposition = WalDisposition::Reset;
    match fs::read(dir.join(wal::WAL_FILE)) {
        Err(e) if e.kind() == io::ErrorKind::NotFound => {}
        Err(e) => return Err(corrupt(format!("cannot read wal.log: {e}"))),
        Ok(wal_bytes) => {
            let scan = wal::scan_wal(&wal_bytes, wpc).map_err(corrupt)?;
            if scan.generation > meta.generation {
                return Err(corrupt(format!(
                    "wal generation {} is ahead of snapshot generation {}",
                    scan.generation, meta.generation
                )));
            }
            if scan.generation == meta.generation {
                for rec in scan.records {
                    apply_replay(&mut index, rec, wpc, bits)?;
                    replayed += 1;
                }
                obs::add(Counter::WalReplay, replayed);
                if scan.truncated_bytes > 0 {
                    wal::repair_tail(dir, scan.good_end)
                        .map_err(|e| io_cbe("truncate torn wal tail", &e))?;
                    state = RecoveryState::LoadedWithTruncatedWalTail {
                        dropped_bytes: scan.truncated_bytes,
                    };
                }
                disposition = WalDisposition::Continue { records: replayed };
            }
            // generation < snapshot: a checkpoint died between the
            // snapshot rename and the wal rename. Those records are
            // already folded into the snapshot — reset the log.
        }
    }
    if let Some(v) = meta.model_version {
        index = index.with_model_version(v);
    }
    let report = LoadReport {
        state,
        generation: meta.generation,
        wal_records_replayed: replayed,
        stamp: SnapshotStamp {
            model_version: meta.model_version,
            fingerprint: meta.fingerprint,
        },
        path,
        mapped_bytes,
    };
    Ok((index, report, disposition))
}

/// Load the index saved in `dir`, replaying (and if need be repairing)
/// its WAL. Every outcome is classified: see the module docs. Uses
/// [`LoadMode::Auto`] backing (`CBE_MMAP`, else mapped where
/// supported).
pub fn load(dir: &Path) -> Result<(IndexAny, LoadReport), CbeError> {
    load_with_mode(dir, LoadMode::Auto)
}

/// [`load`] with an explicit [`LoadMode`] (service config beats the
/// environment).
pub fn load_with_mode(dir: &Path, mode: LoadMode) -> Result<(IndexAny, LoadReport), CbeError> {
    let _span = obs::span(Stage::SnapshotLoad);
    let out = load_inner(dir, mode);
    obs::add(Counter::Recovery, 1);
    out.map(|(index, report, _)| (index, report))
}

/// The slicing-by-8 CRC-32 every snapshot section and WAL record is
/// checksummed with. Public so the persist bench can A/B it against
/// [`crc32_bytewise`] on real snapshot bytes.
pub fn crc32_sliced(bytes: &[u8]) -> u32 {
    format::crc32(bytes)
}

/// The classic byte-at-a-time CRC-32 reference kernel (bit-identical to
/// [`crc32_sliced`], roughly 4–6x slower on long buffers).
pub fn crc32_bytewise(bytes: &[u8]) -> u32 {
    format::crc32_bytewise(bytes)
}

/// An [`IndexAny`] bound to an on-disk directory: every mutation is
/// write-ahead logged before it is applied, and the log is folded into
/// a fresh checksummed snapshot past a churn threshold.
pub struct PersistentIndex {
    dir: PathBuf,
    index: IndexAny,
    stamp: SnapshotStamp,
    generation: u64,
    wal: WalWriter,
    opts: PersistOptions,
    clock: FaultClock,
    /// Set when a WAL append failed mid-write: the tail may be torn, so
    /// further appends would bury records behind garbage. A checkpoint
    /// (fresh snapshot + fresh log) clears it.
    poisoned: bool,
}

impl PersistentIndex {
    /// Persist `index` into `dir` (generation 1, empty WAL) and return
    /// the bound handle.
    pub fn create(
        dir: &Path,
        index: IndexAny,
        stamp: SnapshotStamp,
        opts: PersistOptions,
    ) -> Result<PersistentIndex, CbeError> {
        let mut clock = FaultClock::new(opts.faults.clone());
        write_snapshot(dir, &index, &stamp, 1, &mut clock)?;
        let wal = WalWriter::create(dir, 1, &mut clock).map_err(|e| io_cbe("create wal", &e))?;
        Ok(PersistentIndex {
            dir: dir.to_path_buf(),
            index,
            stamp,
            generation: 1,
            wal,
            opts,
            clock,
            poisoned: false,
        })
    }

    /// Load (and recover) the index in `dir` and bind to it for further
    /// churn.
    pub fn open(dir: &Path, opts: PersistOptions) -> Result<(PersistentIndex, LoadReport), CbeError> {
        let _span = obs::span(Stage::SnapshotLoad);
        let loaded = load_inner(dir, opts.load_mode);
        obs::add(Counter::Recovery, 1);
        let (index, report, disposition) = loaded?;
        let mut clock = FaultClock::new(opts.faults.clone());
        let wal = match disposition {
            WalDisposition::Continue { records } => {
                WalWriter::open(dir, records).map_err(|e| io_cbe("reopen wal", &e))?
            }
            WalDisposition::Reset => WalWriter::create(dir, report.generation, &mut clock)
                .map_err(|e| io_cbe("reset stale wal", &e))?,
        };
        Ok((
            PersistentIndex {
                dir: dir.to_path_buf(),
                index,
                stamp: report.stamp.clone(),
                generation: report.generation,
                wal,
                opts,
                clock,
                poisoned: false,
            },
            report,
        ))
    }

    pub fn index(&self) -> &IndexAny {
        &self.index
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Records currently in the WAL (replayed + appended since open).
    pub fn wal_records(&self) -> u64 {
        self.wal.records
    }

    /// Fault-injection ops consumed so far (the recovery-matrix tests
    /// dry-run a workload with no faults to enumerate its crash points).
    pub fn fault_ops(&self) -> u64 {
        self.clock.ops()
    }

    pub fn search(&self, q: &[u64], k: usize) -> Vec<crate::bits::index::Hit> {
        self.index.search(q, k)
    }

    fn guard_poisoned(&self) -> Result<(), CbeError> {
        if self.poisoned {
            return Err(CbeError::Service(
                "wal tail may be torn after a failed append; checkpoint() to recover".to_string(),
            ));
        }
        Ok(())
    }

    /// Durably log, then apply, one insert. The operation is fully
    /// validated *before* it is logged, so a logged record can always be
    /// replayed.
    pub fn insert(&mut self, id: u32, code: &[u64]) -> Result<(), CbeError> {
        self.guard_poisoned()?;
        let bits = self.index.bits();
        let wpc = bits.div_ceil(64);
        if code.len() != wpc {
            return Err(CbeError::Service(format!(
                "insert of id {id}: {} code words, index uses {wpc}",
                code.len()
            )));
        }
        let pad = wpc * 64 - bits;
        if pad > 0 && code[wpc - 1] >> (64 - pad) != 0 {
            return Err(CbeError::Service(format!(
                "insert of id {id}: padding bits beyond {bits} must be zero"
            )));
        }
        if self.index.contains(id) {
            return Err(CbeError::Service(format!("insert of duplicate id {id}")));
        }
        if matches!(self.index.kind(), crate::index::IndexKind::Linear(_)) {
            return Err(CbeError::Service(
                "linear index is immutable; use mih or sharded for live corpora".to_string(),
            ));
        }
        if let Err(e) = self.wal.append(
            &WalOp::Insert { id, code },
            self.opts.sync_on_append,
            &mut self.clock,
        ) {
            self.poisoned = true;
            return Err(io_cbe("wal append", &e));
        }
        self.index.insert(id, code).expect("pre-validated insert");
        self.maybe_checkpoint()
    }

    /// Durably log, then apply, one removal. Removing an absent id is a
    /// no-op `Ok(false)` and is not logged.
    pub fn remove(&mut self, id: u32) -> Result<bool, CbeError> {
        self.guard_poisoned()?;
        if matches!(self.index.kind(), crate::index::IndexKind::Linear(_)) {
            return Err(CbeError::Service(
                "linear index is immutable; use mih or sharded for live corpora".to_string(),
            ));
        }
        if !self.index.contains(id) {
            return Ok(false);
        }
        if let Err(e) = self.wal.append(
            &WalOp::Remove { id },
            self.opts.sync_on_append,
            &mut self.clock,
        ) {
            self.poisoned = true;
            return Err(io_cbe("wal append", &e));
        }
        let removed = self.index.remove(id).expect("mutable backend");
        debug_assert!(removed, "contains() said the id was present");
        self.maybe_checkpoint()?;
        Ok(true)
    }

    fn maybe_checkpoint(&mut self) -> Result<(), CbeError> {
        if self.opts.compact_threshold > 0 && self.wal.records >= self.opts.compact_threshold {
            self.checkpoint()?;
            obs::add(Counter::WalCompaction, 1);
        }
        Ok(())
    }

    /// Fold the WAL into a fresh snapshot at generation + 1. Crash-safe
    /// at every instant: until the snapshot rename lands, the old
    /// snapshot + full WAL are intact; between the two renames, the new
    /// snapshot already contains every logged record and the stale-
    /// generation WAL is ignored on load.
    pub fn checkpoint(&mut self) -> Result<(), CbeError> {
        let next = self.generation + 1;
        write_snapshot(&self.dir, &self.index, &self.stamp, next, &mut self.clock)?;
        self.wal = WalWriter::create(&self.dir, next, &mut self.clock)
            .map_err(|e| io_cbe("create wal", &e))?;
        self.generation = next;
        self.poisoned = false;
        Ok(())
    }

    /// Fsync the WAL tail (shutdown drain).
    pub fn flush(&mut self) -> Result<(), CbeError> {
        self.wal.flush().map_err(|e| io_cbe("fsync wal", &e))
    }
}

impl Drop for PersistentIndex {
    fn drop(&mut self) {
        // Best-effort: with sync_on_append off, push the tail to disk.
        let _ = self.wal.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::bitcode::BitCode;
    use crate::index::{build_index_with_ids, IndexBackend};
    use crate::util::rng::Pcg64;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "cbe_persist_{tag}_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn small_index(n: usize, bits: usize, seed: u64) -> IndexAny {
        let mut rng = Pcg64::new(seed);
        let codes = BitCode::from_signs(&rng.sign_vec(n * bits), n, bits);
        build_index_with_ids(
            codes,
            (0..n as u32).collect(),
            &IndexBackend::Mih { m: Some(2) },
        )
    }

    #[test]
    fn save_load_roundtrip_with_stamp() {
        let dir = temp_dir("roundtrip");
        let index = small_index(40, 64, 1).with_model_version(3);
        let stamp = SnapshotStamp {
            model_version: Some(3),
            fingerprint: 0xF00D,
        };
        save(&dir, &index, &stamp).unwrap();
        let (loaded, report) = load(&dir).unwrap();
        assert_eq!(report.state, RecoveryState::Loaded);
        assert_eq!(report.generation, 1);
        assert_eq!(report.wal_records_replayed, 0);
        assert_eq!(report.stamp, stamp);
        assert_eq!(loaded.model_version(), Some(3));
        assert_eq!(loaded.len(), 40);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn churn_survives_a_reopen_via_the_wal() {
        let dir = temp_dir("churn");
        let index = small_index(10, 64, 2);
        let opts = PersistOptions {
            compact_threshold: 0,
            ..PersistOptions::default()
        };
        let mut p =
            PersistentIndex::create(&dir, index, SnapshotStamp::none(), opts.clone()).unwrap();
        p.insert(100, &[0xAA55]).unwrap();
        p.insert(101, &[0x1234]).unwrap();
        assert!(p.remove(3).unwrap());
        assert!(!p.remove(999).unwrap(), "absent id is Ok(false), not logged");
        assert_eq!(p.wal_records(), 3);
        drop(p);
        let (p2, report) = PersistentIndex::open(&dir, opts).unwrap();
        assert_eq!(report.wal_records_replayed, 3);
        assert_eq!(report.state, RecoveryState::Loaded);
        assert_eq!(p2.len(), 11);
        assert!(p2.index().contains(100) && p2.index().contains(101));
        assert!(!p2.index().contains(3));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoint_bumps_generation_and_empties_the_wal() {
        let dir = temp_dir("checkpoint");
        let opts = PersistOptions {
            compact_threshold: 4,
            ..PersistOptions::default()
        };
        let mut p =
            PersistentIndex::create(&dir, small_index(8, 64, 3), SnapshotStamp::none(), opts.clone())
                .unwrap();
        for id in 100..104u32 {
            p.insert(id, &[u64::from(id)]).unwrap();
        }
        // The 4th append crossed the threshold: auto-checkpoint.
        assert_eq!(p.generation(), 2);
        assert_eq!(p.wal_records(), 0);
        drop(p);
        let (p2, report) = PersistentIndex::open(&dir, opts).unwrap();
        assert_eq!(report.generation, 2);
        assert_eq!(report.wal_records_replayed, 0);
        assert_eq!(p2.len(), 12);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_is_deterministic_nonzero_and_sensitive() {
        let r = [0.5f32, -1.25, 3.0];
        let signs = [1.0f32, -1.0, 1.0];
        let a = model_fingerprint(&r, &signs);
        assert_eq!(a, model_fingerprint(&r, &signs));
        assert_ne!(a, 0);
        let mut r2 = r;
        r2[1] += 1e-6;
        assert_ne!(a, model_fingerprint(&r2, &signs));
        assert_ne!(a, model_fingerprint(&signs, &r));
    }

    #[test]
    fn fingerprint_chain_is_deterministic_nonzero_and_order_sensitive() {
        let r = [0.5f32, -1.25, 3.0];
        let signs = [1.0f32, -1.0, 1.0];
        let a = model_fingerprint(&r, &signs);
        let b = model_fingerprint(&signs, &r);
        let ab = fingerprint_chain(a, b);
        assert_eq!(ab, fingerprint_chain(a, b));
        assert_ne!(ab, 0);
        // Chaining must distinguish block order and chain length, or a
        // stacked model could collide with a permutation of itself.
        assert_ne!(ab, fingerprint_chain(b, a));
        assert_ne!(ab, a);
        assert_ne!(fingerprint_chain(ab, a), ab);
    }

    #[test]
    fn load_mode_forces_the_backing_path() {
        let dir = temp_dir("loadmode");
        let index = small_index(20, 64, 9);
        save(&dir, &index, &SnapshotStamp::none()).unwrap();
        let (a, ra) = load_with_mode(&dir, LoadMode::Heap).unwrap();
        assert_eq!(ra.path, LoadPath::Heap);
        assert_eq!(ra.mapped_bytes, 0);
        let (b, rb) = load_with_mode(&dir, LoadMode::Mmap).unwrap();
        if Mmap::supported() {
            assert_eq!(rb.path, LoadPath::Mmap);
            assert!(rb.mapped_bytes > 0, "whole snapshot should be mapped");
        } else {
            assert_eq!(rb.path, LoadPath::Heap);
        }
        assert_eq!(a.len(), b.len());
        let q = [0x0Fu64];
        assert_eq!(a.search(&q, 5), b.search(&q, 5));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn loading_an_empty_dir_is_a_typed_error() {
        let dir = temp_dir("empty");
        fs::create_dir_all(&dir).unwrap();
        match load(&dir) {
            Err(CbeError::CorruptSnapshot { reason }) => {
                assert!(reason.contains("current.snap"), "reason: {reason}")
            }
            other => panic!("expected CorruptSnapshot, got {other:?}"),
        }
        let _ = fs::remove_dir_all(&dir);
    }
}
