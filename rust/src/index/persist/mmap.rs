//! Zero-copy snapshot storage: a minimal [`Mmap`] over the libc that
//! std already links, and the [`Store`] seam that lets the index's two
//! big flat structures — the packed `BitCode` word store and
//! `SubstringTable`'s postings arena — read straight out of a mapped
//! snapshot instead of a heap copy.
//!
//! The design rule is that *storage representation is invisible at
//! every call site*: `Store<T>` derefs to `[T]`, so reads and in-place
//! slice mutation (`store[i] = x`, `store.swap(a, b)`) compile
//! unchanged whether the words live in an owned `Vec` or a shared
//! [`Arc<Mmap>`] window. The first mutation of a mapped store promotes
//! it to an owned copy (copy-on-write — counted in
//! `Counter::PromoteOwned`), so a pure-read load copies nothing and a
//! churned index pays exactly one copy, at first churn. `Vec`-only
//! growth methods go through [`Store::to_mut`], which performs the same
//! promotion explicitly.
//!
//! Platform gating: the mapped representation needs `unix` (for
//! `mmap`/`munmap`/`madvise`) and a little-endian target (the snapshot
//! bytes are LE words reinterpreted in place). Everywhere else
//! [`Mmap::map`] returns `ErrorKind::Unsupported` and the loader falls
//! back to the portable heap path — same bytes, same typed-corruption
//! guarantees, one extra copy.

use std::ffi::c_void;
use std::io;
use std::sync::Arc;

#[cfg(all(unix, target_endian = "little"))]
mod sys {
    use std::ffi::c_void;
    use std::os::raw::c_int;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
    }

    // Identical values on Linux and macOS, the two unix targets this
    // repo builds on.
    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
    pub const MADV_SEQUENTIAL: c_int = 2;
    pub const MADV_WILLNEED: c_int = 3;
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;
}

/// A read-only, private memory mapping of a whole file.
///
/// Read-only and `MAP_PRIVATE`, so concurrent readers are safe
/// (`Send + Sync` below) and a later snapshot checkpoint — which
/// replaces the file by atomic rename, never in-place writes — cannot
/// change the bytes under a live map: the old inode stays alive until
/// the last map drops.
pub struct Mmap {
    /// Null iff `len == 0` (mapping an empty file is `EINVAL`, so empty
    /// snapshot sections get an empty slice without a syscall).
    ptr: *mut c_void,
    len: usize,
}

// Safety: the mapping is PROT_READ and never handed out mutably; the
// pointer is owned by this struct and unmapped exactly once, on drop.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Does this target support the mapped representation at all?
    pub fn supported() -> bool {
        cfg!(all(unix, target_endian = "little"))
    }

    /// Map `file` read-only in its entirety. On unsupported targets
    /// (non-unix or big-endian) fails with `ErrorKind::Unsupported`;
    /// callers fall back to the heap loader.
    pub fn map(file: &std::fs::File) -> io::Result<Mmap> {
        let len = file.metadata()?.len();
        if len > usize::MAX as u64 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "file too large to map",
            ));
        }
        let len = len as usize;
        if len == 0 {
            return Ok(Mmap {
                ptr: std::ptr::null_mut(),
                len: 0,
            });
        }
        #[cfg(all(unix, target_endian = "little"))]
        {
            use std::os::unix::io::AsRawFd;
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr == sys::MAP_FAILED {
                return Err(io::Error::last_os_error());
            }
            Ok(Mmap { ptr, len })
        }
        #[cfg(not(all(unix, target_endian = "little")))]
        {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "mmap is unix + little-endian only; use the heap loader",
            ))
        }
    }

    /// The mapped bytes (empty slice for an empty file).
    pub fn as_slice(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // Safety: ptr is a live PROT_READ mapping of exactly `len`
        // bytes, unmapped only in Drop.
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }

    /// Total mapped bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Hint the kernel that the map is about to be read front to back
    /// (the CRC + structural verify pass): prefetch aggressively,
    /// recycle pages behind the cursor.
    pub fn advise_sequential(&self) {
        #[cfg(all(unix, target_endian = "little"))]
        if self.len > 0 {
            // Advice is best-effort; a failure changes nothing but speed.
            unsafe { sys::madvise(self.ptr, self.len, sys::MADV_SEQUENTIAL) };
        }
    }

    /// Hint the kernel the map will be randomly accessed soon (the
    /// serving phase after verification): keep/bring pages resident.
    pub fn advise_willneed(&self) {
        #[cfg(all(unix, target_endian = "little"))]
        if self.len > 0 {
            unsafe { sys::madvise(self.ptr, self.len, sys::MADV_WILLNEED) };
        }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(all(unix, target_endian = "little"))]
        if self.len > 0 {
            // Safety: ptr/len came from a successful mmap; this is the
            // sole owner and the only munmap.
            unsafe { sys::munmap(self.ptr, self.len) };
        }
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mmap").field("len", &self.len).finish()
    }
}

/// The packed `BitCode` word store.
pub type Words = Store<u64>;
/// `SubstringTable`'s postings arena.
pub type Postings = Store<u32>;

/// A flat `[T]` that is either owned (a `Vec`, the portable default and
/// the representation of anything built in memory) or a typed window
/// into a shared snapshot mapping. See the module docs for the
/// copy-on-write contract.
pub struct Store<T> {
    repr: Repr<T>,
}

enum Repr<T> {
    Owned(Vec<T>),
    Mapped {
        map: Arc<Mmap>,
        /// Byte offset of element 0 within the mapping. Validated
        /// aligned for `T` at construction.
        off: usize,
        /// Length in elements.
        len: usize,
    },
}

impl<T> Store<T> {
    /// An owned store (the representation every builder produces).
    pub fn owned(v: Vec<T>) -> Store<T> {
        Store {
            repr: Repr::Owned(v),
        }
    }

    /// A zero-copy window of `len` elements at byte offset `off` into
    /// `map`. Returns `None` when the window is out of bounds or
    /// misaligned for `T` — callers fall back to copying.
    pub(crate) fn mapped(map: &Arc<Mmap>, off: usize, len: usize) -> Option<Store<T>> {
        let bytes = map.as_slice();
        let nbytes = len.checked_mul(std::mem::size_of::<T>())?;
        let end = off.checked_add(nbytes)?;
        if end > bytes.len() {
            return None;
        }
        if (bytes.as_ptr() as usize + off) % std::mem::align_of::<T>() != 0 {
            return None;
        }
        Some(Store {
            repr: Repr::Mapped {
                map: Arc::clone(map),
                off,
                len,
            },
        })
    }

    /// Is this store still backed by the snapshot mapping (i.e. has no
    /// mutation promoted it yet)?
    pub fn is_mapped(&self) -> bool {
        matches!(self.repr, Repr::Mapped { .. })
    }
}

impl<T: Clone> Store<T> {
    /// The owned `Vec`, promoting a mapped store by copying first (the
    /// copy-on-write step; counted in `Counter::PromoteOwned`). All
    /// growth/shrink mutation funnels through here — slice-shaped
    /// mutation goes through `DerefMut`, which calls this too.
    pub fn to_mut(&mut self) -> &mut Vec<T> {
        if self.is_mapped() {
            let copied: Vec<T> = (**self).to_vec();
            crate::obs::add(crate::obs::Counter::PromoteOwned, 1);
            self.repr = Repr::Owned(copied);
        }
        match &mut self.repr {
            Repr::Owned(v) => v,
            Repr::Mapped { .. } => unreachable!("just promoted"),
        }
    }
}

impl<T> std::ops::Deref for Store<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        match &self.repr {
            Repr::Owned(v) => v,
            Repr::Mapped { map, off, len } => {
                // Safety: bounds and alignment were validated in
                // `mapped()`; the mapping is immutable and outlives the
                // borrow via the Arc.
                unsafe {
                    std::slice::from_raw_parts(
                        map.as_slice().as_ptr().add(*off) as *const T,
                        *len,
                    )
                }
            }
        }
    }
}

impl<T: Clone> std::ops::DerefMut for Store<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        self.to_mut().as_mut_slice()
    }
}

impl<T> From<Vec<T>> for Store<T> {
    fn from(v: Vec<T>) -> Store<T> {
        Store::owned(v)
    }
}

impl<T> Default for Store<T> {
    fn default() -> Store<T> {
        Store::owned(Vec::new())
    }
}

impl<T: Clone> Clone for Store<T> {
    fn clone(&self) -> Store<T> {
        match &self.repr {
            Repr::Owned(v) => Store::owned(v.clone()),
            // Cloning a mapped store clones the window, not the pages.
            Repr::Mapped { map, off, len } => Store {
                repr: Repr::Mapped {
                    map: Arc::clone(map),
                    off: *off,
                    len: *len,
                },
            },
        }
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for Store<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_list().entries(self.iter()).finish()
    }
}

impl<T: PartialEq> PartialEq for Store<T> {
    fn eq(&self, other: &Store<T>) -> bool {
        **self == **other
    }
}

// Lets tests keep writing `store == vec![...]`.
impl<T: PartialEq> PartialEq<Vec<T>> for Store<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        **self == other[..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn temp_file(bytes: &[u8]) -> (std::path::PathBuf, std::fs::File) {
        let path = std::env::temp_dir().join(format!(
            "cbe_mmap_test_{}_{}",
            std::process::id(),
            bytes.len()
        ));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(bytes).unwrap();
        f.sync_all().unwrap();
        (path.clone(), std::fs::File::open(&path).unwrap())
    }

    #[test]
    fn map_reads_back_exact_bytes() {
        if !Mmap::supported() {
            return;
        }
        let bytes: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let (path, f) = temp_file(&bytes);
        let map = Mmap::map(&f).unwrap();
        assert_eq!(map.as_slice(), &bytes[..]);
        map.advise_sequential();
        map.advise_willneed();
        drop(map);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn empty_file_maps_to_empty_slice() {
        let (path, f) = temp_file(&[]);
        let map = Mmap::map(&f).unwrap();
        assert!(map.is_empty());
        assert_eq!(map.as_slice(), &[] as &[u8]);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn store_cow_promotes_on_first_write_only() {
        if !Mmap::supported() {
            return;
        }
        let words: Vec<u64> = (0..64u64).collect();
        let mut bytes = Vec::new();
        for w in &words {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        let (path, f) = temp_file(&bytes);
        let map = Arc::new(Mmap::map(&f).unwrap());
        let mut store: Store<u64> = Store::mapped(&map, 0, 64).unwrap();
        assert!(store.is_mapped());
        assert_eq!(store, words); // reads never promote
        assert_eq!(store[17], 17);
        assert!(store.is_mapped());
        store[17] = 999; // first write promotes…
        assert!(!store.is_mapped());
        assert_eq!(store[17], 999);
        assert_eq!(store[16], 16); // …and carried the old contents over
        store.to_mut().push(1000); // growth works post-promotion
        assert_eq!(store.len(), 65);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn mapped_rejects_misaligned_and_oob_windows() {
        if !Mmap::supported() {
            return;
        }
        let (path, f) = temp_file(&[0u8; 64]);
        let map = Arc::new(Mmap::map(&f).unwrap());
        // Offset 3 cannot be 8-aligned (mmap base is page-aligned).
        assert!(Store::<u64>::mapped(&map, 3, 4).is_none());
        // Window past the end of the file.
        assert!(Store::<u64>::mapped(&map, 0, 9).is_none());
        assert!(Store::<u64>::mapped(&map, 0, 8).is_some());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn snapshot_rename_keeps_live_map_valid() {
        if !Mmap::supported() {
            return;
        }
        let (path, f) = temp_file(b"generation-one");
        let map = Mmap::map(&f).unwrap();
        // Replace the file the way a checkpoint does: write a temp,
        // rename over the live name. The old inode must stay readable
        // through the existing map.
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, b"generation-two").unwrap();
        std::fs::rename(&tmp, &path).unwrap();
        assert_eq!(map.as_slice(), b"generation-one");
        let _ = std::fs::remove_file(path);
    }
}
