//! On-disk encoding primitives shared by the snapshot and WAL writers:
//! a table-driven CRC-32 (IEEE, reflected — the zlib/PNG polynomial) and
//! little-endian put/take helpers with typed bounds errors.
//!
//! Everything persisted by this tier goes through these helpers so the
//! byte layout has exactly one definition: fixed-width little-endian
//! integers, no varints, no alignment-dependent structs. A reader error
//! is a `String` reason; callers wrap it in
//! [`crate::error::CbeError::CorruptSnapshot`] so a damaged file can
//! never surface as a panic or an index silently missing rows.

/// CRC-32 lookup table for the reflected IEEE polynomial `0xEDB88320`,
/// built at compile time.
static CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// Standard CRC-32 (matches zlib's `crc32`): init `!0`, reflected
/// table updates, final xor `!0`.
pub(crate) fn crc32(bytes: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked little-endian reader over a byte slice. Every `take_*`
/// names what it was reading so corruption reports say *which* field was
/// truncated, not just "unexpected EOF".
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, at: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }

    pub fn is_done(&self) -> bool {
        self.at == self.buf.len()
    }

    pub fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated reading {what}: need {n} bytes at offset {}, have {}",
                self.at,
                self.remaining()
            ));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    pub fn take_u8(&mut self, what: &str) -> Result<u8, String> {
        Ok(self.take(1, what)?[0])
    }

    pub fn take_u32(&mut self, what: &str) -> Result<u32, String> {
        let s = self.take(4, what)?;
        Ok(u32::from_le_bytes(s.try_into().expect("4-byte slice")))
    }

    pub fn take_u64(&mut self, what: &str) -> Result<u64, String> {
        let s = self.take(8, what)?;
        Ok(u64::from_le_bytes(s.try_into().expect("8-byte slice")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_the_standard_check_value() {
        // The universal CRC-32 test vector.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn crc32_detects_a_single_bit_flip() {
        let mut buf: Vec<u8> = (0u8..=255).collect();
        let clean = crc32(&buf);
        buf[100] ^= 0x10;
        assert_ne!(crc32(&buf), clean);
    }

    #[test]
    fn reader_roundtrips_and_names_truncated_fields() {
        let mut buf = Vec::new();
        buf.push(7u8);
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, u64::MAX - 1);
        let mut r = Reader::new(&buf);
        assert_eq!(r.take_u8("tag").unwrap(), 7);
        assert_eq!(r.take_u32("len").unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.take_u64("gen").unwrap(), u64::MAX - 1);
        assert!(r.is_done());
        let err = r.take_u32("trailer").unwrap_err();
        assert!(err.contains("trailer"), "error names the field: {err}");
    }
}
