//! On-disk encoding primitives shared by the snapshot and WAL writers:
//! a table-driven CRC-32 (IEEE, reflected — the zlib/PNG polynomial) and
//! little-endian put/take helpers with typed bounds errors.
//!
//! Everything persisted by this tier goes through these helpers so the
//! byte layout has exactly one definition: fixed-width little-endian
//! integers, no varints, no alignment-dependent structs. A reader error
//! is a `String` reason; callers wrap it in
//! [`crate::error::CbeError::CorruptSnapshot`] so a damaged file can
//! never surface as a panic or an index silently missing rows.
//!
//! The CRC runs **slicing-by-8**: eight 256-entry tables let the hot
//! loop fold 8 input bytes per iteration with independent lookups
//! instead of a serial one-byte-at-a-time dependency chain. On a
//! zero-copy (mmap) load the streaming verify pass is the dominant cost
//! of reaching the first query, so this kernel is on the cold-start
//! critical path. It is scalar, table-driven, and bit-identical to the
//! classic byte-wise form (the tables are built from the same
//! polynomial; the equivalence test below runs both).

/// Eight CRC-32 lookup tables for the reflected IEEE polynomial
/// `0xEDB88320`, built at compile time. `CRC_TABLES[0]` is the classic
/// byte-wise table; `CRC_TABLES[k][b]` advances byte `b` through `k`
/// extra zero bytes, which is what lets 8 lookups combine into one
/// 8-byte step.
static CRC_TABLES: [[u32; 256]; 8] = crc_tables();

const fn crc_tables() -> [[u32; 256]; 8] {
    let mut t = [[0u32; 256]; 8];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        t[0][i] = c;
        i += 1;
    }
    let mut j = 1;
    while j < 8 {
        let mut i = 0;
        while i < 256 {
            t[j][i] = t[0][(t[j - 1][i] & 0xFF) as usize] ^ (t[j - 1][i] >> 8);
            i += 1;
        }
        j += 1;
    }
    t
}

/// One classic byte-wise CRC step.
#[inline]
fn crc_byte(c: u32, b: u8) -> u32 {
    CRC_TABLES[0][((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8)
}

/// The classic one-byte-at-a-time CRC-32 — the reference kernel the
/// sliced implementation is proven against, kept for the differential
/// test and the persist bench's A/B arm.
pub(crate) fn crc32_bytewise(bytes: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in bytes {
        c = crc_byte(c, b);
    }
    !c
}

/// Standard CRC-32 (matches zlib's `crc32`): init `!0`, reflected
/// table updates, final xor `!0`. Slicing-by-8 on the body, byte-wise
/// on the unaligned tail.
pub(crate) fn crc32(bytes: &[u8]) -> u32 {
    let mut c = !0u32;
    let mut chunks = bytes.chunks_exact(8);
    for chunk in &mut chunks {
        // Fold the running CRC into the first 4 bytes, then look all 8
        // bytes up in their distance-matched tables. The xor of the 8
        // lookups is exactly 8 serial byte steps, but with no
        // loop-carried dependency between the lookups themselves.
        let lo = u32::from_le_bytes(chunk[0..4].try_into().expect("4 bytes")) ^ c;
        let hi = u32::from_le_bytes(chunk[4..8].try_into().expect("4 bytes"));
        c = CRC_TABLES[7][(lo & 0xFF) as usize]
            ^ CRC_TABLES[6][((lo >> 8) & 0xFF) as usize]
            ^ CRC_TABLES[5][((lo >> 16) & 0xFF) as usize]
            ^ CRC_TABLES[4][(lo >> 24) as usize]
            ^ CRC_TABLES[3][(hi & 0xFF) as usize]
            ^ CRC_TABLES[2][((hi >> 8) & 0xFF) as usize]
            ^ CRC_TABLES[1][((hi >> 16) & 0xFF) as usize]
            ^ CRC_TABLES[0][(hi >> 24) as usize];
    }
    for &b in chunks.remainder() {
        c = crc_byte(c, b);
    }
    !c
}

pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked little-endian reader over a byte slice. Every `take_*`
/// names what it was reading so corruption reports say *which* field was
/// truncated, not just "unexpected EOF".
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, at: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }

    /// Current cursor position (bytes consumed) — alignment-sensitive
    /// decoders use this to locate format-v2 padding.
    pub fn pos(&self) -> usize {
        self.at
    }

    pub fn is_done(&self) -> bool {
        self.at == self.buf.len()
    }

    pub fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated reading {what}: need {n} bytes at offset {}, have {}",
                self.at,
                self.remaining()
            ));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    pub fn take_u8(&mut self, what: &str) -> Result<u8, String> {
        Ok(self.take(1, what)?[0])
    }

    pub fn take_u32(&mut self, what: &str) -> Result<u32, String> {
        let s = self.take(4, what)?;
        Ok(u32::from_le_bytes(s.try_into().expect("4-byte slice")))
    }

    pub fn take_u64(&mut self, what: &str) -> Result<u64, String> {
        let s = self.take(8, what)?;
        Ok(u64::from_le_bytes(s.try_into().expect("8-byte slice")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_the_standard_check_value() {
        // The universal CRC-32 test vector.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn crc32_detects_a_single_bit_flip() {
        let mut buf: Vec<u8> = (0u8..=255).collect();
        let clean = crc32(&buf);
        buf[100] ^= 0x10;
        assert_ne!(crc32(&buf), clean);
    }

    #[test]
    fn sliced_crc_matches_bytewise_at_every_length() {
        // Lengths 0..=64 cover every body/tail split of the 8-byte
        // slicing loop; the pseudo-random fill makes table mix-ups
        // visible. Reference: the classic one-byte-at-a-time update.
        let data: Vec<u8> = (0..64u32)
            .map(|i| (i.wrapping_mul(0x9E37_79B9) >> 24) as u8)
            .collect();
        for len in 0..=data.len() {
            assert_eq!(crc32(&data[..len]), crc32_bytewise(&data[..len]), "len={len}");
        }
    }

    #[test]
    fn reader_roundtrips_and_names_truncated_fields() {
        let mut buf = Vec::new();
        buf.push(7u8);
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, u64::MAX - 1);
        let mut r = Reader::new(&buf);
        assert_eq!(r.take_u8("tag").unwrap(), 7);
        assert_eq!(r.take_u32("len").unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.take_u64("gen").unwrap(), u64::MAX - 1);
        assert!(r.is_done());
        let err = r.take_u32("trailer").unwrap_err();
        assert!(err.contains("trailer"), "error names the field: {err}");
    }
}
