//! Write-ahead log for index churn between snapshots.
//!
//! File grammar (all integers little-endian):
//!
//! ```text
//! header (32 B, written atomically via wal.tmp + rename, never torn):
//!   magic      8 B  = "CBEWAL01"
//!   format     u32  = 1
//!   reserved   u32  = 0
//!   generation u64    pairs the log with current.snap
//!   crc        u32    CRC-32 of bytes [0, 24)
//!   pad        u32  = 0
//! record (appended, fsync'd per append when sync_on_append):
//!   len        u32    payload length in bytes
//!   crc        u32    CRC-32 of the payload
//!   payload:
//!     op  u8          1 = insert, 2 = remove
//!     id  u32
//!     code  wpc × u64   (insert only)
//! ```
//!
//! A crash can only tear the *tail*: the header is renamed into place
//! whole, and records are appended in order. The scanner therefore stops
//! at the first short, missized, or CRC-failing record and reports how
//! many bytes follow it; the loader physically truncates that tail and
//! classifies the load as `LoadedWithTruncatedWalTail`. A generation
//! *behind* the snapshot is a checkpoint that died after the snapshot
//! rename — its records are already folded in, so it is ignored and
//! reset. A generation *ahead* of the snapshot cannot come from any
//! crash of this writer and is reported as corruption.

use super::faults::{self, FaultClock, Sink};
use super::format::{crc32, put_u32, put_u64, Reader};
use crate::obs::{self, Counter};
use std::fs::{File, OpenOptions};
use std::io;
use std::path::Path;

pub(crate) const WAL_MAGIC: [u8; 8] = *b"CBEWAL01";
pub(crate) const WAL_FORMAT: u32 = 1;
pub(crate) const WAL_HEADER_LEN: usize = 32;

pub(crate) const WAL_FILE: &str = "wal.log";
const WAL_TMP: &str = "wal.tmp";

const OP_INSERT: u8 = 1;
const OP_REMOVE: u8 = 2;

pub(crate) fn encode_wal_header(generation: u64) -> Vec<u8> {
    let mut b = Vec::with_capacity(WAL_HEADER_LEN);
    b.extend_from_slice(&WAL_MAGIC);
    put_u32(&mut b, WAL_FORMAT);
    put_u32(&mut b, 0);
    put_u64(&mut b, generation);
    let crc = crc32(&b);
    put_u32(&mut b, crc);
    put_u32(&mut b, 0);
    b
}

/// A churn operation to be logged.
pub(crate) enum WalOp<'a> {
    Insert { id: u32, code: &'a [u64] },
    Remove { id: u32 },
}

pub(crate) fn encode_record(op: &WalOp) -> Vec<u8> {
    let mut payload = Vec::new();
    match op {
        WalOp::Insert { id, code } => {
            payload.push(OP_INSERT);
            put_u32(&mut payload, *id);
            for &w in *code {
                put_u64(&mut payload, w);
            }
        }
        WalOp::Remove { id } => {
            payload.push(OP_REMOVE);
            put_u32(&mut payload, *id);
        }
    }
    let mut rec = Vec::with_capacity(8 + payload.len());
    put_u32(&mut rec, payload.len() as u32);
    put_u32(&mut rec, crc32(&payload));
    rec.extend_from_slice(&payload);
    rec
}

/// A decoded, CRC-verified record.
pub(crate) enum Replay {
    Insert { id: u32, code: Vec<u64> },
    Remove { id: u32 },
}

pub(crate) struct WalScan {
    pub generation: u64,
    pub records: Vec<Replay>,
    /// Byte offset just past the last valid record.
    pub good_end: u64,
    /// Bytes past `good_end` — a torn tail to be truncated (0 = clean).
    pub truncated_bytes: u64,
}

/// Parse a WAL image. Header damage is an error (the header is written
/// atomically, so a bad one means corruption, not a crash); record
/// damage past the header is a torn tail and ends the scan.
pub(crate) fn scan_wal(bytes: &[u8], words_per_code: usize) -> Result<WalScan, String> {
    if bytes.len() < WAL_HEADER_LEN {
        return Err(format!(
            "wal header truncated: {} bytes, need {WAL_HEADER_LEN}",
            bytes.len()
        ));
    }
    if bytes[..8] != WAL_MAGIC {
        return Err("wal magic mismatch".to_string());
    }
    let mut r = Reader::new(&bytes[8..WAL_HEADER_LEN]);
    let format = r.take_u32("wal format")?;
    if format != WAL_FORMAT {
        return Err(format!("unsupported wal format {format}"));
    }
    let _reserved = r.take_u32("wal reserved")?;
    let generation = r.take_u64("wal generation")?;
    let crc = r.take_u32("wal header crc")?;
    if crc != crc32(&bytes[..24]) {
        return Err("wal header crc mismatch".to_string());
    }

    let insert_len = 5 + words_per_code * 8;
    let mut records = Vec::new();
    let mut at = WAL_HEADER_LEN;
    loop {
        let rest = &bytes[at..];
        if rest.is_empty() {
            return Ok(WalScan {
                generation,
                records,
                good_end: at as u64,
                truncated_bytes: 0,
            });
        }
        // Anything that follows fails one of these checks only if the
        // record's write was torn (or its bytes rotted, which we cannot
        // distinguish) — stop and report the tail.
        if rest.len() < 8 {
            break;
        }
        let len = u32::from_le_bytes(rest[..4].try_into().expect("4 bytes")) as usize;
        let crc = u32::from_le_bytes(rest[4..8].try_into().expect("4 bytes"));
        if len != 5 && len != insert_len {
            break;
        }
        if rest.len() < 8 + len {
            break;
        }
        let payload = &rest[8..8 + len];
        if crc32(payload) != crc {
            break;
        }
        let tag = payload[0];
        let id = u32::from_le_bytes(payload[1..5].try_into().expect("4 bytes"));
        match (tag, len) {
            (OP_INSERT, l) if l == insert_len => {
                let code = payload[5..]
                    .chunks_exact(8)
                    .map(|c| u64::from_le_bytes(c.try_into().expect("8 bytes")))
                    .collect();
                records.push(Replay::Insert { id, code });
            }
            (OP_REMOVE, 5) => records.push(Replay::Remove { id }),
            _ => break,
        }
        at += 8 + len;
    }
    Ok(WalScan {
        generation,
        records,
        good_end: at as u64,
        truncated_bytes: (bytes.len() - at) as u64,
    })
}

/// Append handle over an open `wal.log`.
pub(crate) struct WalWriter {
    file: File,
    /// Records in the log (replayed + appended since open).
    pub records: u64,
}

impl WalWriter {
    /// Create a fresh, empty log for `generation` atomically (write the
    /// header to `wal.tmp`, fsync, rename over `wal.log`, fsync the
    /// directory) and open it for append.
    pub fn create(dir: &Path, generation: u64, clock: &mut FaultClock) -> io::Result<WalWriter> {
        let tmp = dir.join(WAL_TMP);
        let path = dir.join(WAL_FILE);
        let mut f = File::create(&tmp)?;
        {
            let mut sink = Sink {
                file: &mut f,
                clock,
            };
            sink.write_all(&encode_wal_header(generation))?;
            sink.sync()?;
        }
        drop(f);
        faults::rename(clock, &tmp, &path)?;
        faults::sync_dir(clock, dir)?;
        let file = OpenOptions::new().append(true).open(&path)?;
        Ok(WalWriter { file, records: 0 })
    }

    /// Reopen an existing (already tail-repaired) log for append.
    pub fn open(dir: &Path, records: u64) -> io::Result<WalWriter> {
        let file = OpenOptions::new().append(true).open(dir.join(WAL_FILE))?;
        Ok(WalWriter { file, records })
    }

    /// Append one record (one write op, plus one fsync op when `sync`).
    pub fn append(&mut self, op: &WalOp, sync: bool, clock: &mut FaultClock) -> io::Result<()> {
        let rec = encode_record(op);
        let mut sink = Sink {
            file: &mut self.file,
            clock,
        };
        sink.write_all(&rec)?;
        if sync {
            sink.sync()?;
        }
        self.records += 1;
        obs::add(Counter::WalAppend, 1);
        Ok(())
    }

    /// Fsync the tail (shutdown drain / explicit flush).
    pub fn flush(&mut self) -> io::Result<()> {
        self.file.sync_all()
    }
}

/// Truncate a damaged tail off `wal.log` so future appends extend a
/// clean prefix instead of burying records behind garbage.
pub(crate) fn repair_tail(dir: &Path, good_end: u64) -> io::Result<()> {
    let f = OpenOptions::new().write(true).open(dir.join(WAL_FILE))?;
    f.set_len(good_end)?;
    f.sync_all()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn image(generation: u64, ops: &[WalOp]) -> Vec<u8> {
        let mut b = encode_wal_header(generation);
        for op in ops {
            b.extend_from_slice(&encode_record(op));
        }
        b
    }

    #[test]
    fn scan_roundtrips_inserts_and_removes() {
        let code = [0xDEAD_BEEF_u64, 0x1234];
        let img = image(
            3,
            &[
                WalOp::Insert { id: 7, code: &code },
                WalOp::Remove { id: 7 },
            ],
        );
        let scan = scan_wal(&img, 2).unwrap();
        assert_eq!(scan.generation, 3);
        assert_eq!(scan.truncated_bytes, 0);
        assert_eq!(scan.good_end as usize, img.len());
        assert_eq!(scan.records.len(), 2);
        match &scan.records[0] {
            Replay::Insert { id, code: c } => {
                assert_eq!(*id, 7);
                assert_eq!(c, &code);
            }
            _ => panic!("expected insert"),
        }
        assert!(matches!(scan.records[1], Replay::Remove { id: 7 }));
    }

    #[test]
    fn torn_tail_is_reported_not_fatal() {
        let code = [1u64];
        let full = image(
            1,
            &[
                WalOp::Insert { id: 1, code: &code },
                WalOp::Insert { id: 2, code: &code },
            ],
        );
        // Cut the second record mid-payload.
        let torn = &full[..full.len() - 4];
        let scan = scan_wal(torn, 1).unwrap();
        assert_eq!(scan.records.len(), 1);
        assert!(scan.truncated_bytes > 0);
        assert_eq!(
            scan.good_end as usize + scan.truncated_bytes as usize,
            torn.len()
        );
    }

    #[test]
    fn flipped_record_bit_ends_the_scan_at_that_record() {
        let code = [1u64];
        let mut img = image(
            1,
            &[
                WalOp::Insert { id: 1, code: &code },
                WalOp::Insert { id: 2, code: &code },
            ],
        );
        // Flip a payload bit of the *first* record: both it and the
        // record after it are dropped — a reported tail, never a
        // silently wrong replay.
        img[WAL_HEADER_LEN + 9] ^= 0x40;
        let scan = scan_wal(&img, 1).unwrap();
        assert_eq!(scan.records.len(), 0);
        assert!(scan.truncated_bytes > 0);
    }

    #[test]
    fn header_damage_is_an_error() {
        let img = image(1, &[]);
        let mut bad_magic = img.clone();
        bad_magic[0] = b'X';
        assert!(scan_wal(&bad_magic, 1).unwrap_err().contains("magic"));
        let mut bad_crc = img.clone();
        bad_crc[16] ^= 1; // generation byte — breaks the header CRC
        assert!(scan_wal(&bad_crc, 1).unwrap_err().contains("crc"));
        assert!(scan_wal(&img[..10], 1).unwrap_err().contains("truncated"));
    }
}
