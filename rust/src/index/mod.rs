//! Sub-linear Hamming ANN: sharded multi-index hashing over packed codes.
//!
//! CBE makes *encoding* cheap — O(d log d) against O(d²) for a dense
//! projection — but the seed retrieval path was still an O(n·d) linear
//! scan per query ([`crate::bits::BinaryIndex`]). This module adds the
//! serving-side counterpart: **multi-index hashing** (MIH, Norouzi,
//! Punjani & Fleet), which answers exact k-NN-by-Hamming queries while
//! touching only a vanishing fraction of the corpus.
//!
//! # How the probe schedule works
//!
//! Split every b-bit code into m substrings and bucket each substring
//! value in its own [`substring::SubstringTable`]. Substrings are either
//! contiguous spans ([`substring::substring_spans`]) or seeded-permutation
//! bit samples ([`substring::sampled_positions`]; see
//! [`mih::SubstringScheme`]) — either way they partition the b bits, so
//! the pigeonhole argument holds: if two codes differ by at most r bits
//! overall, some substring pair differs by at most ⌊r/m⌋ bits — a far
//! smaller radius in a far smaller keyspace.
//!
//! A query therefore proceeds in rounds of increasing substring radius
//! s = 0, 1, 2, …: in round s, every table enumerates the C(len, s) keys
//! at distance exactly s from the query's substring and pulls the matching
//! buckets. Every candidate is deduplicated (generation-stamped scratch,
//! pooled across queries), re-ranked with the exact full-code Hamming
//! kernel ([`crate::bits::hamming`]), and pushed into a bounded max-heap
//! of the k smallest `(dist, id)` pairs. After finishing round s, any code
//! *not yet seen* has all m substring distances ≥ s+1, hence full distance
//! ≥ m·(s+1); the loop stops as soon as the current k-th best distance is
//! strictly below that bound. This makes [`MihIndex`] **exact**: equal
//! hit-for-hit (including ties, which break by ascending id) with a full
//! linear scan.
//!
//! The schedule also self-bounds: before each round it compares the
//! round's key-enumeration cost (Σ C(lenᵢ, s)) against the number of
//! still-unseen live codes, and when enumeration is the more expensive
//! side it finishes with a direct sweep of the stragglers. Worst-case
//! work is therefore never more than a constant factor over the linear
//! scan, while structured (real-embedding) corpora terminate after a few
//! tiny rounds.
//!
//! # Storage engine
//!
//! Each [`substring::SubstringTable`] is a flat open-addressing key table
//! whose postings live in one contiguous arena — zero allocations per
//! bucket, two-pass (count → prefix-sum → fill) bulk builds, and
//! tombstone-aware incremental churn with self-compaction. See the
//! `substring` module docs for the layout and `ARCHITECTURE.md` for the
//! design rationale.
//!
//! [`ShardedIndex`] layers horizontal scale on top: the corpus is
//! partitioned round-robin across independent MIH shards, single queries
//! fan out across shards on scoped threads, batches parallelize across
//! queries, and `insert`/`remove` keep shards balanced for live corpora —
//! query throughput scales with cores instead of corpus size.
//!
//! Backend choice is config, not code: [`IndexBackend`] (parsed from specs
//! like `"mih:8"`, `"mih-sampled"` or `"sharded:16"`) + [`build_index`]
//! produce an [`IndexAny`], and everything downstream —
//! `EmbeddingService::search`, the recall experiments, the benches —
//! talks [`AnyIndex`].

pub mod mih;
pub mod persist;
pub mod sharded;
pub mod substring;

pub use mih::{MihIndex, SubstringScheme};
pub use persist::{
    LoadMode, LoadPath, LoadReport, PersistOptions, PersistentIndex, RecoveryState, SnapshotStamp,
};
pub use sharded::ShardedIndex;

use crate::bits::bitcode::BitCode;
use crate::bits::index::Hit;
use crate::bits::BinaryIndex;

/// Object-safe facade over every retrieval backend. All implementations
/// are exact: same hits, same `(dist, id)` ordering, same tie-breaks.
pub trait AnyIndex: Send + Sync {
    /// Live code count.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Code length in bits.
    fn bits(&self) -> usize;
    /// Exact top-k by Hamming distance, sorted by `(dist, id)`.
    fn search(&self, q: &[u64], k: usize) -> Vec<Hit>;
    /// Batch search, query order preserved.
    fn search_batch(&self, queries: &BitCode, k: usize) -> Vec<Vec<Hit>> {
        (0..queries.n)
            .map(|i| self.search(queries.code(i), k))
            .collect()
    }
    /// Short backend tag for logs/metrics.
    fn backend_name(&self) -> &'static str;
    /// The encoder-model version the codes were produced with, when
    /// known. `EmbeddingService::build_index` stamps its registry
    /// version here so a `search()` against an index that predates a
    /// `Retrain` hot-swap is rejected (`CbeError::StaleIndex`) instead
    /// of silently mixing codes from two models. `None` (the default,
    /// and what bare backends report) means unversioned: the caller
    /// owns staleness.
    fn model_version(&self) -> Option<u64> {
        None
    }
}

impl AnyIndex for BinaryIndex {
    fn len(&self) -> usize {
        BinaryIndex::len(self)
    }
    fn bits(&self) -> usize {
        self.codes.bits
    }
    fn search(&self, q: &[u64], k: usize) -> Vec<Hit> {
        BinaryIndex::search(self, q, k)
    }
    fn search_batch(&self, queries: &BitCode, k: usize) -> Vec<Vec<Hit>> {
        BinaryIndex::search_batch(self, queries, k)
    }
    fn backend_name(&self) -> &'static str {
        "linear"
    }
}

impl AnyIndex for MihIndex {
    fn len(&self) -> usize {
        MihIndex::len(self)
    }
    fn bits(&self) -> usize {
        MihIndex::bits(self)
    }
    fn search(&self, q: &[u64], k: usize) -> Vec<Hit> {
        MihIndex::search(self, q, k)
    }
    fn search_batch(&self, queries: &BitCode, k: usize) -> Vec<Vec<Hit>> {
        MihIndex::search_batch(self, queries, k)
    }
    fn backend_name(&self) -> &'static str {
        match self.scheme() {
            SubstringScheme::Contiguous => "mih",
            SubstringScheme::Sampled => "mih-sampled",
        }
    }
}

impl AnyIndex for ShardedIndex {
    fn len(&self) -> usize {
        ShardedIndex::len(self)
    }
    fn bits(&self) -> usize {
        ShardedIndex::bits(self)
    }
    fn search(&self, q: &[u64], k: usize) -> Vec<Hit> {
        ShardedIndex::search(self, q, k)
    }
    fn search_batch(&self, queries: &BitCode, k: usize) -> Vec<Vec<Hit>> {
        ShardedIndex::search_batch(self, queries, k)
    }
    fn backend_name(&self) -> &'static str {
        "sharded-mih"
    }
}

/// Which retrieval backend to build — selected by config (service config,
/// CLI flag, `CBE_INDEX` env var), not by code.
///
/// # Spec strings
///
/// [`IndexBackend::from_spec`] accepts exactly these forms (and
/// [`IndexBackend::spec`] prints the canonical one back):
///
/// * `auto` — pick by corpus size via [`IndexBackend::auto_for`]: linear
///   below ~8k codes, one MIH to ~256k, a shard per core beyond that.
/// * `linear` (alias `scan`) — exact linear scan
///   ([`crate::bits::BinaryIndex`]), the O(n·d) baseline. Immutable.
/// * `mih` or `mih:<m>` — single [`MihIndex`] over contiguous substrings;
///   `m` = substring count, ≥ 1 (omitted → [`mih::auto_m`]; explicit
///   values are clamped at build time to `[ceil(bits/64), bits]` so
///   substring keys fit a u64).
/// * `mih-sampled` or `mih-sampled:<m>` — [`MihIndex`] over **bit-sampled**
///   substrings ([`SubstringScheme::Sampled`]): a seeded permutation
///   scatters the key bits so correlated adjacent CBE bits don't skew
///   bucket occupancy. Same exactness, same `m` rules as `mih`.
/// * `sharded:<shards>` or `sharded:<shards>:<m>` (alias `sharded-mih`) —
///   [`ShardedIndex`]: corpus partitioned round-robin over `shards` ≥ 1
///   MIH shards with parallel fan-out; `m` as for `mih`.
///
/// Anything else — unknown names, zero counts, non-numeric or empty
/// fields, extra `:` segments — is rejected with a descriptive error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum IndexBackend {
    /// Pick by corpus size: linear below ~8k codes, MIH to ~256k, one MIH
    /// shard per core beyond that.
    Auto,
    /// Exact linear scan ([`BinaryIndex`]) — the O(n·d) baseline.
    Linear,
    /// Single multi-index hash table set over contiguous substrings;
    /// `m` = substring count (None → [`mih::auto_m`]).
    Mih { m: Option<usize> },
    /// Single multi-index hash table set over bit-sampled substrings
    /// ([`SubstringScheme::Sampled`]); `m` as in [`IndexBackend::Mih`].
    MihSampled { m: Option<usize> },
    /// Corpus-partitioned MIH with parallel shard fan-out.
    ShardedMih { shards: usize, m: Option<usize> },
}

impl IndexBackend {
    /// Parse a backend spec: `auto` | `linear` | `mih[:m]` |
    /// `mih-sampled[:m]` | `sharded:<shards>[:m]`. See the type-level docs
    /// for the exact grammar.
    pub fn from_spec(spec: &str) -> Result<IndexBackend, String> {
        let parts: Vec<&str> = spec.trim().split(':').collect();
        let num = |s: &str| {
            s.parse::<usize>()
                .map_err(|_| format!("bad number '{s}' in index spec '{spec}'"))
        };
        let arity = |want: std::ops::RangeInclusive<usize>| {
            if want.contains(&parts.len()) {
                Ok(())
            } else {
                Err(format!("wrong arity in index spec '{spec}'"))
            }
        };
        let opt_m = |idx: usize| -> Result<Option<usize>, String> {
            if parts.len() > idx {
                let m = num(parts[idx])?;
                if m == 0 {
                    return Err(format!("substring count must be >= 1 in '{spec}'"));
                }
                Ok(Some(m))
            } else {
                Ok(None)
            }
        };
        match parts[0] {
            "auto" => {
                arity(1..=1)?;
                Ok(IndexBackend::Auto)
            }
            "linear" | "scan" => {
                arity(1..=1)?;
                Ok(IndexBackend::Linear)
            }
            "mih" => {
                arity(1..=2)?;
                Ok(IndexBackend::Mih { m: opt_m(1)? })
            }
            "mih-sampled" => {
                arity(1..=2)?;
                Ok(IndexBackend::MihSampled { m: opt_m(1)? })
            }
            "sharded" | "sharded-mih" => {
                arity(2..=3)?;
                let shards = num(parts[1])?;
                if shards == 0 {
                    return Err(format!("shard count must be >= 1 in '{spec}'"));
                }
                Ok(IndexBackend::ShardedMih {
                    shards,
                    m: opt_m(2)?,
                })
            }
            other => Err(format!(
                "unknown index backend '{other}' (want auto | linear | mih[:m] | \
                 mih-sampled[:m] | sharded:<shards>[:m])"
            )),
        }
    }

    /// Canonical spec string (round-trips through [`IndexBackend::from_spec`]).
    pub fn spec(&self) -> String {
        match self {
            IndexBackend::Auto => "auto".to_string(),
            IndexBackend::Linear => "linear".to_string(),
            IndexBackend::Mih { m: None } => "mih".to_string(),
            IndexBackend::Mih { m: Some(m) } => format!("mih:{m}"),
            IndexBackend::MihSampled { m: None } => "mih-sampled".to_string(),
            IndexBackend::MihSampled { m: Some(m) } => format!("mih-sampled:{m}"),
            IndexBackend::ShardedMih { shards, m: None } => format!("sharded:{shards}"),
            IndexBackend::ShardedMih { shards, m: Some(m) } => format!("sharded:{shards}:{m}"),
        }
    }

    /// The serving heuristic behind [`IndexBackend::Auto`]: linear scan
    /// while the scan is cheap, one MIH beyond that, and a shard per core
    /// once the corpus dwarfs the probe cost. (Bit sampling stays opt-in:
    /// it pays an O(len) gather per key extraction, which only buys QPS
    /// back when the code bits are correlated enough to skew buckets.)
    pub fn auto_for(n: usize, _bits: usize) -> IndexBackend {
        if n < 8_192 {
            IndexBackend::Linear
        } else if n < 262_144 {
            IndexBackend::Mih { m: None }
        } else {
            let shards = std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4)
                .max(2);
            IndexBackend::ShardedMih { shards, m: None }
        }
    }
}

/// The backend variants behind [`IndexAny`].
pub enum IndexKind {
    Linear(BinaryIndex),
    /// Both substring schemes land here; [`MihIndex::scheme`] tells them
    /// apart (as does [`IndexAny::backend_name`]).
    Mih(MihIndex),
    Sharded(ShardedIndex),
}

/// A concrete backend instance plus the serving metadata stamped at
/// build time (today: the encoder-model version behind the codes).
/// Inherent methods mirror [`AnyIndex`] so callers can use an
/// `IndexAny` without importing the trait.
pub struct IndexAny {
    kind: IndexKind,
    /// Registry version of the model that encoded the codes, stamped by
    /// `EmbeddingService::build_index` ([`IndexAny::with_model_version`]);
    /// `None` for indexes built directly over codes.
    model_version: Option<u64>,
}

impl From<IndexKind> for IndexAny {
    fn from(kind: IndexKind) -> IndexAny {
        IndexAny {
            kind,
            model_version: None,
        }
    }
}

impl IndexAny {
    /// The concrete backend.
    pub fn kind(&self) -> &IndexKind {
        &self.kind
    }

    /// Stamp the encoder-model version the codes were produced with
    /// (builder style; used by `EmbeddingService::build_index`).
    pub fn with_model_version(mut self, version: u64) -> IndexAny {
        self.model_version = Some(version);
        self
    }

    /// The stamped model version, if any (see
    /// [`AnyIndex::model_version`]).
    pub fn model_version(&self) -> Option<u64> {
        self.model_version
    }

    pub fn len(&self) -> usize {
        match &self.kind {
            IndexKind::Linear(i) => i.len(),
            IndexKind::Mih(i) => i.len(),
            IndexKind::Sharded(i) => i.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn bits(&self) -> usize {
        match &self.kind {
            IndexKind::Linear(i) => i.codes.bits,
            IndexKind::Mih(i) => i.bits(),
            IndexKind::Sharded(i) => i.bits(),
        }
    }
    pub fn search(&self, q: &[u64], k: usize) -> Vec<Hit> {
        match &self.kind {
            IndexKind::Linear(i) => i.search(q, k),
            IndexKind::Mih(i) => i.search(q, k),
            IndexKind::Sharded(i) => i.search(q, k),
        }
    }
    pub fn search_batch(&self, queries: &BitCode, k: usize) -> Vec<Vec<Hit>> {
        match &self.kind {
            IndexKind::Linear(i) => i.search_batch(queries, k),
            IndexKind::Mih(i) => i.search_batch(queries, k),
            IndexKind::Sharded(i) => i.search_batch(queries, k),
        }
    }
    pub fn backend_name(&self) -> &'static str {
        match &self.kind {
            IndexKind::Linear(_) => "linear",
            IndexKind::Mih(i) => AnyIndex::backend_name(i),
            IndexKind::Sharded(_) => "sharded-mih",
        }
    }

    /// Whether an external id is currently indexed. O(1)-ish on the MIH
    /// backends; an O(n) id scan on the linear backend (used by WAL
    /// replay validation, never on the query path).
    pub fn contains(&self, id: u32) -> bool {
        match &self.kind {
            IndexKind::Linear(i) => i.ids.contains(&id),
            IndexKind::Mih(i) => i.contains(id),
            IndexKind::Sharded(i) => i.contains(id),
        }
    }

    /// Incremental insert; `Err` on the immutable linear backend.
    pub fn insert(&mut self, id: u32, code: &[u64]) -> Result<(), String> {
        match &mut self.kind {
            IndexKind::Linear(_) => {
                Err("linear index is immutable; use mih or sharded for live corpora".to_string())
            }
            IndexKind::Mih(i) => {
                i.insert(id, code);
                Ok(())
            }
            IndexKind::Sharded(i) => {
                i.insert(id, code);
                Ok(())
            }
        }
    }

    /// Incremental remove; `Ok(false)` when the id is absent, `Err` on the
    /// immutable linear backend.
    pub fn remove(&mut self, id: u32) -> Result<bool, String> {
        match &mut self.kind {
            IndexKind::Linear(_) => {
                Err("linear index is immutable; use mih or sharded for live corpora".to_string())
            }
            IndexKind::Mih(i) => Ok(i.remove(id)),
            IndexKind::Sharded(i) => Ok(i.remove(id)),
        }
    }
}

impl AnyIndex for IndexAny {
    fn len(&self) -> usize {
        IndexAny::len(self)
    }
    fn bits(&self) -> usize {
        IndexAny::bits(self)
    }
    fn search(&self, q: &[u64], k: usize) -> Vec<Hit> {
        IndexAny::search(self, q, k)
    }
    fn search_batch(&self, queries: &BitCode, k: usize) -> Vec<Vec<Hit>> {
        IndexAny::search_batch(self, queries, k)
    }
    fn backend_name(&self) -> &'static str {
        IndexAny::backend_name(self)
    }
    fn model_version(&self) -> Option<u64> {
        IndexAny::model_version(self)
    }
}

/// Build the configured backend over a packed corpus with ids `0..n`.
/// `Auto` resolves via [`IndexBackend::auto_for`].
pub fn build_index(codes: BitCode, backend: &IndexBackend) -> IndexAny {
    let ids = (0..codes.n as u32).collect();
    build_index_with_ids(codes, ids, backend)
}

/// Build the configured backend with explicit external ids. Ids must be
/// unique — the MIH backends assert this; the linear backend does not
/// check (duplicates would surface as repeated hits there).
pub fn build_index_with_ids(codes: BitCode, ids: Vec<u32>, backend: &IndexBackend) -> IndexAny {
    let backend = match backend {
        IndexBackend::Auto => IndexBackend::auto_for(codes.n, codes.bits),
        b => b.clone(),
    };
    let kind = match backend {
        IndexBackend::Auto => unreachable!("auto resolved above"),
        IndexBackend::Linear => IndexKind::Linear(BinaryIndex::with_ids(codes, ids)),
        IndexBackend::Mih { m } => IndexKind::Mih(MihIndex::build_with_ids(codes, ids, m)),
        IndexBackend::MihSampled { m } => {
            IndexKind::Mih(MihIndex::build_sampled_with_ids(codes, ids, m))
        }
        IndexBackend::ShardedMih { shards, m } => {
            IndexKind::Sharded(ShardedIndex::build_with_ids(codes, ids, shards, m))
        }
    };
    IndexAny::from(kind)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn spec_roundtrip() {
        for spec in [
            "auto",
            "linear",
            "mih",
            "mih:8",
            "mih-sampled",
            "mih-sampled:8",
            "sharded:4",
            "sharded:4:8",
        ] {
            let b = IndexBackend::from_spec(spec).unwrap();
            assert_eq!(b.spec(), spec);
            assert_eq!(IndexBackend::from_spec(&b.spec()).unwrap(), b);
        }
        assert_eq!(
            IndexBackend::from_spec("scan").unwrap(),
            IndexBackend::Linear
        );
        assert_eq!(
            IndexBackend::from_spec("sharded-mih:4").unwrap(),
            IndexBackend::ShardedMih {
                shards: 4,
                m: None
            }
        );
        // Leading/trailing whitespace is tolerated; the interior is not.
        assert_eq!(
            IndexBackend::from_spec(" mih-sampled:3 ").unwrap(),
            IndexBackend::MihSampled { m: Some(3) }
        );
    }

    #[test]
    fn spec_rejects_malformed() {
        for bad in [
            "",
            "mih:",           // empty m field
            "mih:x",          // non-numeric m
            "mih:0",          // zero substrings
            "mih:1:2",        // trailing garbage
            "mih-sampled:",   // empty m field
            "mih-sampled:0",  // zero substrings
            "mih-sampled:2:3",// trailing garbage
            "sampled",        // not a backend name
            "sharded",        // missing shard count
            "sharded:",       // empty shard count
            "sharded:0",      // zero shards
            "sharded:2:0",    // zero substrings
            "sharded:2:8:1",  // trailing garbage
            "linear:1",       // arity
            "auto:2",         // arity
            "hnsw",           // unknown backend
            "mih extra",      // embedded whitespace
        ] {
            assert!(IndexBackend::from_spec(bad).is_err(), "'{bad}' should fail");
        }
    }

    #[test]
    fn auto_scales_with_n() {
        assert_eq!(IndexBackend::auto_for(100, 64), IndexBackend::Linear);
        assert_eq!(
            IndexBackend::auto_for(100_000, 256),
            IndexBackend::Mih { m: None }
        );
        match IndexBackend::auto_for(1_000_000, 256) {
            IndexBackend::ShardedMih { shards, m: None } => assert!(shards >= 2),
            other => panic!("expected sharded backend, got {other:?}"),
        }
    }

    #[test]
    fn build_index_dispatches_every_backend() {
        let mut rng = Pcg64::new(401);
        let bits = 64;
        let n = 30;
        let db = BitCode::from_signs(&rng.sign_vec(n * bits), n, bits);
        let q = db.code(4).to_vec();
        let mut expected: Option<Vec<Hit>> = None;
        for backend in [
            IndexBackend::Auto,
            IndexBackend::Linear,
            IndexBackend::Mih { m: Some(4) },
            IndexBackend::MihSampled { m: Some(4) },
            IndexBackend::ShardedMih {
                shards: 3,
                m: None,
            },
        ] {
            let idx = build_index(db.clone(), &backend);
            assert_eq!(idx.len(), n);
            assert_eq!(idx.bits(), bits);
            let hits = idx.search(&q, 7);
            assert_eq!(hits[0].id, 4);
            assert_eq!(hits[0].dist, 0);
            match &expected {
                None => expected = Some(hits),
                Some(e) => assert_eq!(&hits, e, "backend {backend:?} diverged"),
            }
        }
    }

    #[test]
    fn backend_names_distinguish_schemes() {
        let mut rng = Pcg64::new(403);
        let db = BitCode::from_signs(&rng.sign_vec(20 * 32), 20, 32);
        let plain = build_index(db.clone(), &IndexBackend::Mih { m: None });
        let sampled = build_index(db, &IndexBackend::MihSampled { m: None });
        assert_eq!(plain.backend_name(), "mih");
        assert_eq!(sampled.backend_name(), "mih-sampled");
    }

    #[test]
    fn index_any_mutation_gating() {
        let mut rng = Pcg64::new(402);
        let bits = 32;
        let db = BitCode::from_signs(&rng.sign_vec(10 * bits), 10, bits);
        let extra = BitCode::from_signs(&rng.sign_vec(bits), 1, bits);

        let mut linear = build_index(db.clone(), &IndexBackend::Linear);
        assert!(linear.insert(99, extra.code(0)).is_err());
        assert!(linear.remove(0).is_err());

        for backend in [
            IndexBackend::Mih { m: None },
            IndexBackend::MihSampled { m: None },
            IndexBackend::ShardedMih { shards: 2, m: None },
        ] {
            let mut idx = build_index(db.clone(), &backend);
            idx.insert(99, extra.code(0)).unwrap();
            assert_eq!(idx.len(), 11);
            assert_eq!(idx.remove(99), Ok(true));
            assert_eq!(idx.remove(99), Ok(false));
            assert_eq!(idx.len(), 10);
        }
    }

    #[test]
    fn model_version_stamping() {
        let mut rng = Pcg64::new(404);
        let db = BitCode::from_signs(&rng.sign_vec(10 * 32), 10, 32);
        let idx = build_index(db, &IndexBackend::Linear);
        // Indexes built directly over codes are unversioned …
        assert_eq!(idx.model_version(), None);
        assert_eq!(AnyIndex::model_version(&idx), None);
        // … and the service's build path stamps its registry version.
        let stamped = idx.with_model_version(3);
        assert_eq!(stamped.model_version(), Some(3));
        assert_eq!(AnyIndex::model_version(&stamped), Some(3));
    }
}
