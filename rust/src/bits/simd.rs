//! AVX2 popcount kernels for the Hamming-distance hot loops.
//!
//! Integer XOR + popcount has one result whatever the lane width, so
//! these kernels sit in the strict **bit-exact** tier of the contract
//! trivially: the differential suite (`rust/tests/simd_kernels.rs`)
//! asserts equality against the scalar paths for every `words_per_code`,
//! including ragged tails.
//!
//! The vector body is the Muła–Kurz–Lemire positional-popcount idiom:
//! XOR four words at a time, split each byte into nibbles, look both up
//! in an in-register 16-entry table (`vpshufb`), and horizontally sum
//! the per-byte counts with `vpsadbw`. The SAD runs once per 4-word
//! chunk into a 64-bit accumulator, so no byte/short counter can
//! saturate for any code width. Word tails (`len % 4`) finish with
//! scalar `count_ones` inside the kernel.
//!
//! # Safety
//!
//! `#[target_feature(enable = "avx2")]` throughout — call only when
//! [`crate::simd::active`] returned true. Unaligned loads; all pointer
//! arithmetic stays inside the passed slices.

use super::BitCode;
use std::arch::x86_64::*;

/// Popcount of `a[..len] ^ b[..len]` (raw-pointer windows into two code
/// rows). Bit-exact with the scalar word loop.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn xor_popcnt_words(a: *const u64, b: *const u64, len: usize) -> u32 {
    // Per-nibble popcount table, replicated across both 128-bit halves.
    let table = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3,
        3, 4,
    );
    let low_mask = _mm256_set1_epi8(0x0f);
    let zero = _mm256_setzero_si256();
    let mut acc = _mm256_setzero_si256();
    let mut k = 0usize;
    while k + 4 <= len {
        let va = _mm256_loadu_si256(a.add(k) as *const __m256i);
        let vb = _mm256_loadu_si256(b.add(k) as *const __m256i);
        let v = _mm256_xor_si256(va, vb);
        let lo = _mm256_and_si256(v, low_mask);
        let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), low_mask);
        let cnt = _mm256_add_epi8(
            _mm256_shuffle_epi8(table, lo),
            _mm256_shuffle_epi8(table, hi),
        );
        // Widen per-byte counts to four u64 partial sums immediately:
        // nothing narrower than 64 bits ever accumulates across chunks.
        acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, zero));
        k += 4;
    }
    let mut lanes = [0u64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
    let mut total = (lanes[0] + lanes[1] + lanes[2] + lanes[3]) as u32;
    while k < len {
        total += (*a.add(k) ^ *b.add(k)).count_ones();
        k += 1;
    }
    total
}

/// Hamming distance between two equal-length word slices.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn hamming_words(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    xor_popcnt_words(a.as_ptr(), b.as_ptr(), a.len())
}

/// Distances from query `q` to every code in `db`, written into `out`.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn hamming_to_all(q: &[u64], db: &BitCode, out: &mut [u32]) {
    let wpc = db.words_per_code;
    debug_assert_eq!(q.len(), wpc);
    debug_assert_eq!(out.len(), db.n);
    let qp = q.as_ptr();
    let dp = db.data.as_ptr();
    for (i, o) in out.iter_mut().enumerate() {
        *o = xor_popcnt_words(qp, dp.add(i * wpc), wpc);
    }
}
