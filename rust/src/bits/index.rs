//! Linear-scan binary index with top-k selection.
//!
//! The retrieval engine behind the recall experiments (Figs. 2–5) and the
//! serving path: stores packed codes, answers k-NN-by-Hamming queries with a
//! bounded max-heap so selection is O(n log k).

use super::bitcode::BitCode;
use super::hamming::hamming_to_all;
use std::collections::BinaryHeap;

/// Immutable binary index over n packed codes.
pub struct BinaryIndex {
    pub codes: BitCode,
    /// Optional external ids (defaults to 0..n).
    pub ids: Vec<u32>,
}

/// One retrieval hit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hit {
    pub id: u32,
    pub dist: u32,
}

impl BinaryIndex {
    pub fn new(codes: BitCode) -> BinaryIndex {
        let ids = (0..codes.n as u32).collect();
        BinaryIndex { codes, ids }
    }

    pub fn with_ids(codes: BitCode, ids: Vec<u32>) -> BinaryIndex {
        assert_eq!(codes.n, ids.len());
        BinaryIndex { codes, ids }
    }

    pub fn len(&self) -> usize {
        self.codes.n
    }
    pub fn is_empty(&self) -> bool {
        self.codes.n == 0
    }

    /// Top-k nearest codes by Hamming distance. Ties broken by insertion
    /// order (stable for reproducibility). Returns hits sorted by distance.
    pub fn search(&self, query: &[u64], k: usize) -> Vec<Hit> {
        let n = self.len();
        let k = k.min(n);
        if k == 0 {
            return Vec::new();
        }
        let mut dists = vec![0u32; n];
        hamming_to_all(query, &self.codes, &mut dists);
        // Bounded max-heap of (dist, insertion idx).
        let mut heap: BinaryHeap<(u32, u32)> = BinaryHeap::with_capacity(k + 1);
        for (i, &d) in dists.iter().enumerate() {
            if heap.len() < k {
                heap.push((d, i as u32));
            } else if let Some(&(top, _)) = heap.peek() {
                if d < top {
                    heap.pop();
                    heap.push((d, i as u32));
                }
            }
        }
        let mut hits: Vec<Hit> = heap
            .into_iter()
            .map(|(d, i)| Hit {
                id: self.ids[i as usize],
                dist: d,
            })
            .collect();
        hits.sort_by_key(|h| (h.dist, h.id));
        hits
    }

    /// Batch search over a BitCode of queries.
    pub fn search_batch(&self, queries: &BitCode, k: usize) -> Vec<Vec<Hit>> {
        (0..queries.n)
            .map(|i| self.search(queries.code(i), k))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn search_exact_self() {
        let mut rng = Pcg64::new(91);
        let bits = 128;
        let n = 50;
        let signs = rng.sign_vec(n * bits);
        let db = BitCode::from_signs(&signs, n, bits);
        let idx = BinaryIndex::new(db.clone());
        for i in [0usize, 17, 49] {
            let hits = idx.search(db.code(i), 1);
            assert_eq!(hits[0].id, i as u32);
            assert_eq!(hits[0].dist, 0);
        }
    }

    #[test]
    fn search_matches_brute_force() {
        let mut rng = Pcg64::new(93);
        let bits = 96;
        let n = 200;
        let signs = rng.sign_vec(n * bits);
        let db = BitCode::from_signs(&signs, n, bits);
        let idx = BinaryIndex::new(db.clone());
        let q = BitCode::from_signs(&rng.sign_vec(bits), 1, bits);
        let k = 10;
        let hits = idx.search(q.code(0), k);
        // brute force
        let mut all: Vec<(u32, u32)> = (0..n)
            .map(|i| {
                (
                    super::super::hamming::hamming(&q, 0, &db, i),
                    i as u32,
                )
            })
            .collect();
        all.sort();
        for (h, (d, i)) in hits.iter().zip(all.iter().take(k)) {
            assert_eq!(h.dist, *d);
            assert_eq!(h.id, *i);
        }
    }

    #[test]
    fn k_larger_than_n() {
        let db = BitCode::from_signs(&[1.0, -1.0, 1.0, 1.0], 2, 2);
        let idx = BinaryIndex::new(db.clone());
        let hits = idx.search(db.code(0), 10);
        assert_eq!(hits.len(), 2);
    }
}
