//! Linear-scan binary index with top-k selection.
//!
//! The retrieval engine behind the recall experiments (Figs. 2–5) and the
//! serving path: stores packed codes, answers k-NN-by-Hamming queries with a
//! bounded max-heap so selection is O(n log k).

use super::bitcode::BitCode;
use super::hamming::hamming_to_all;
use std::collections::BinaryHeap;

/// Immutable binary index over n packed codes.
pub struct BinaryIndex {
    pub codes: BitCode,
    /// Optional external ids (defaults to 0..n).
    pub ids: Vec<u32>,
}

/// One retrieval hit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hit {
    pub id: u32,
    pub dist: u32,
}

impl BinaryIndex {
    pub fn new(codes: BitCode) -> BinaryIndex {
        let ids = (0..codes.n as u32).collect();
        BinaryIndex { codes, ids }
    }

    pub fn with_ids(codes: BitCode, ids: Vec<u32>) -> BinaryIndex {
        assert_eq!(codes.n, ids.len());
        BinaryIndex { codes, ids }
    }

    pub fn len(&self) -> usize {
        self.codes.n
    }
    pub fn is_empty(&self) -> bool {
        self.codes.n == 0
    }

    /// Top-k nearest codes by Hamming distance: the k lexicographically
    /// smallest `(dist, id)` pairs, sorted. Ties break by ascending id —
    /// the shared contract of every backend in `crate::index`, so exact
    /// backends agree hit-for-hit even with custom external ids.
    pub fn search(&self, query: &[u64], k: usize) -> Vec<Hit> {
        let n = self.len();
        let k = k.min(n);
        if k == 0 {
            return Vec::new();
        }
        // A linear scan is all re-rank: every row gets an exact distance.
        let _rerank = crate::obs::span(crate::obs::Stage::ReRank);
        crate::obs::add(crate::obs::Counter::Reranked, n as u64);
        let mut dists = vec![0u32; n];
        hamming_to_all(query, &self.codes, &mut dists);
        // Bounded max-heap of (dist, id).
        let mut heap: BinaryHeap<(u32, u32)> = BinaryHeap::with_capacity(k + 1);
        for (i, &d) in dists.iter().enumerate() {
            let cand = (d, self.ids[i]);
            if heap.len() < k {
                heap.push(cand);
            } else if let Some(&top) = heap.peek() {
                if cand < top {
                    heap.pop();
                    heap.push(cand);
                }
            }
        }
        let mut hits: Vec<Hit> = heap
            .into_iter()
            .map(|(dist, id)| Hit { id, dist })
            .collect();
        hits.sort_by_key(|h| (h.dist, h.id));
        hits
    }

    /// Batch search over a BitCode of queries, fanned out across cores.
    ///
    /// Queries are chunked over `available_parallelism` scoped threads, so
    /// the linear-scan baseline saturates the machine the same way the
    /// sharded MIH backend does — `cargo bench coordinator_throughput`
    /// compares like with like. Results are in query order, identical to a
    /// sequential map over [`BinaryIndex::search`].
    pub fn search_batch(&self, queries: &BitCode, k: usize) -> Vec<Vec<Hit>> {
        par_map_queries(queries.n, |i| self.search(queries.code(i), k))
    }
}

/// Run `f(query_index)` for `0..nq`, chunked across scoped threads (at most
/// `available_parallelism`, sequential for tiny batches). Shared by every
/// backend's batch path so chunking policy lives in one place.
pub(crate) fn par_map_queries<F>(nq: usize, f: F) -> Vec<Vec<Hit>>
where
    F: Fn(usize) -> Vec<Hit> + Sync,
{
    let threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(nq);
    if threads <= 1 || nq < 8 {
        return (0..nq).map(f).collect();
    }
    let mut out: Vec<Vec<Hit>> = vec![Vec::new(); nq];
    let chunk = nq.div_ceil(threads);
    let f = &f;
    std::thread::scope(|scope| {
        for (t, slots) in out.chunks_mut(chunk).enumerate() {
            let start = t * chunk;
            scope.spawn(move || {
                for (j, slot) in slots.iter_mut().enumerate() {
                    *slot = f(start + j);
                }
            });
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn search_exact_self() {
        let mut rng = Pcg64::new(91);
        let bits = 128;
        let n = 50;
        let signs = rng.sign_vec(n * bits);
        let db = BitCode::from_signs(&signs, n, bits);
        let idx = BinaryIndex::new(db.clone());
        for i in [0usize, 17, 49] {
            let hits = idx.search(db.code(i), 1);
            assert_eq!(hits[0].id, i as u32);
            assert_eq!(hits[0].dist, 0);
        }
    }

    #[test]
    fn search_matches_brute_force() {
        let mut rng = Pcg64::new(93);
        let bits = 96;
        let n = 200;
        let signs = rng.sign_vec(n * bits);
        let db = BitCode::from_signs(&signs, n, bits);
        let idx = BinaryIndex::new(db.clone());
        let q = BitCode::from_signs(&rng.sign_vec(bits), 1, bits);
        let k = 10;
        let hits = idx.search(q.code(0), k);
        // brute force
        let mut all: Vec<(u32, u32)> = (0..n)
            .map(|i| {
                (
                    super::super::hamming::hamming(&q, 0, &db, i),
                    i as u32,
                )
            })
            .collect();
        all.sort();
        for (h, (d, i)) in hits.iter().zip(all.iter().take(k)) {
            assert_eq!(h.dist, *d);
            assert_eq!(h.id, *i);
        }
    }

    #[test]
    fn search_batch_matches_sequential() {
        let mut rng = Pcg64::new(97);
        let bits = 256;
        let n = 300;
        let db = BitCode::from_signs(&rng.sign_vec(n * bits), n, bits);
        let idx = BinaryIndex::new(db);
        let queries = BitCode::from_signs(&rng.sign_vec(40 * bits), 40, bits);
        let batch = idx.search_batch(&queries, 7);
        assert_eq!(batch.len(), 40);
        for (i, hits) in batch.iter().enumerate() {
            assert_eq!(*hits, idx.search(queries.code(i), 7));
        }
    }

    #[test]
    fn k_larger_than_n() {
        let db = BitCode::from_signs(&[1.0, -1.0, 1.0, 1.0], 2, 2);
        let idx = BinaryIndex::new(db.clone());
        let hits = idx.search(db.code(0), 10);
        assert_eq!(hits.len(), 2);
    }
}
