//! Binary-code substrate: bit packing, Hamming distance, top-k retrieval.
//!
//! Once codes are generated (by any encoder), retrieval happens entirely in
//! this module: ±1 codes are packed 64-per-u64 and compared with XOR +
//! popcount — the operational payoff the paper's embedding exists for.

pub mod bitcode;
pub mod hamming;
pub mod index;

pub use bitcode::BitCode;
pub use index::BinaryIndex;
