//! Binary-code substrate: bit packing, Hamming distance, top-k retrieval.
//!
//! Once codes are generated (by any encoder), retrieval happens entirely
//! on this substrate: ±1 codes are packed 64-per-u64 ([`BitCode`], sign ≥ 0
//! → bit set, row-major, padding bits zero) and compared with XOR +
//! popcount ([`hamming`], unrolled for the common 4/8 words-per-code
//! shapes, with an AVX2 bulk kernel behind the [`crate::simd`] gate for
//! wide scans) — the operational payoff the paper's embedding exists for.
//!
//! * [`bitcode`] — the packed code container and sign↔bit conversions.
//! * [`hamming`] — the XOR+popcount distance kernels.
//! * [`index`] — [`BinaryIndex`]: the exact O(n·d) linear-scan baseline
//!   with bounded-heap top-k selection and a core-parallel batch path.
//!
//! Every retrieval backend in the repo — this linear scan and the
//! sub-linear structures in [`crate::index`] — shares one result
//! contract: hits are the k lexicographically smallest `(dist, id)`
//! pairs, sorted, with distance ties broken by ascending id. The
//! `index_equivalence` property tests hold all backends to it
//! hit-for-hit.

pub mod bitcode;
pub mod hamming;
pub mod index;
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub(crate) mod simd;

pub use bitcode::BitCode;
pub use index::BinaryIndex;
