//! XOR + popcount Hamming distance over packed codes.
//!
//! Integer paths are the strict tier of the SIMD exactness contract:
//! the AVX2 kernels ([`super::simd`], gated by [`crate::simd::active`])
//! produce bit-identical distances to the scalar loops here, which stay
//! public as the differential-test oracles. Dispatch thresholds: the
//! pairwise [`hamming_words`] takes the vector kernel from 8 words
//! (512-bit codes) where the 4-word XOR+`vpshufb` chunks amortize; the
//! bulk [`hamming_to_all`] from 4 words, where the per-row setup is
//! hoisted out of the scan.

use super::BitCode;

/// Hamming distance between two packed codes (same word count).
/// SIMD-dispatched at ≥ 8 words; narrower codes keep the scalar loop
/// (the MIH re-rank hammers 4-word windows where `count_ones` already
/// pipelines and the in-register table setup would dominate).
#[inline]
pub fn hamming_words(a: &[u64], b: &[u64]) -> u32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if a.len() >= 8 && crate::simd::active() {
        // SAFETY: `active()` implies runtime AVX2 detection succeeded.
        return unsafe { super::simd::hamming_words(a, b) };
    }
    hamming_words_scalar(a, b)
}

/// The scalar word loop — the oracle the SIMD path is compared against,
/// and the only path on non-AVX2 hosts / scalar builds.
#[inline]
pub fn hamming_words_scalar(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0u32;
    for i in 0..a.len() {
        acc += (a[i] ^ b[i]).count_ones();
    }
    acc
}

/// Hamming distance between code i of `a` and code j of `b`.
#[inline]
pub fn hamming(a: &BitCode, i: usize, b: &BitCode, j: usize) -> u32 {
    hamming_words(a.code(i), b.code(j))
}

/// Distances from query code `q` (packed words) to every code in `db`,
/// written into `out` (len db.n). SIMD-dispatched at ≥ 4 words per code;
/// results are bit-identical to [`hamming_to_all_scalar`] either way.
pub fn hamming_to_all(q: &[u64], db: &BitCode, out: &mut [u32]) {
    assert_eq!(out.len(), db.n);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if db.words_per_code >= 4 && crate::simd::active() {
        // SAFETY: `active()` implies runtime AVX2 detection succeeded.
        unsafe { super::simd::hamming_to_all(q, db, out) };
        return;
    }
    hamming_to_all_scalar(q, db, out);
}

/// The scalar scan (unrolled at the common 4/8-word shapes) — the oracle
/// the SIMD path is compared against, and the only path on non-AVX2
/// hosts / scalar builds.
pub fn hamming_to_all_scalar(q: &[u64], db: &BitCode, out: &mut [u32]) {
    assert_eq!(out.len(), db.n);
    let wpc = db.words_per_code;
    match wpc {
        1 => {
            let qw = q[0];
            for (i, o) in out.iter_mut().enumerate() {
                *o = (qw ^ db.data[i]).count_ones();
            }
        }
        2 => {
            let (q0, q1) = (q[0], q[1]);
            for (i, o) in out.iter_mut().enumerate() {
                let base = i * 2;
                *o = (q0 ^ db.data[base]).count_ones() + (q1 ^ db.data[base + 1]).count_ones();
            }
        }
        // 256- and 512-bit codes are the serving sweet spots (and what MIH
        // re-ranking hammers); fully unrolled so the popcounts pipeline
        // without the generic loop's per-word bookkeeping.
        4 => {
            let qw: [u64; 4] = [q[0], q[1], q[2], q[3]];
            for (i, o) in out.iter_mut().enumerate() {
                let c = &db.data[i * 4..i * 4 + 4];
                *o = (qw[0] ^ c[0]).count_ones()
                    + (qw[1] ^ c[1]).count_ones()
                    + (qw[2] ^ c[2]).count_ones()
                    + (qw[3] ^ c[3]).count_ones();
            }
        }
        8 => {
            let qw: [u64; 8] = [q[0], q[1], q[2], q[3], q[4], q[5], q[6], q[7]];
            for (i, o) in out.iter_mut().enumerate() {
                let c = &db.data[i * 8..i * 8 + 8];
                *o = (qw[0] ^ c[0]).count_ones()
                    + (qw[1] ^ c[1]).count_ones()
                    + (qw[2] ^ c[2]).count_ones()
                    + (qw[3] ^ c[3]).count_ones()
                    + (qw[4] ^ c[4]).count_ones()
                    + (qw[5] ^ c[5]).count_ones()
                    + (qw[6] ^ c[6]).count_ones()
                    + (qw[7] ^ c[7]).count_ones();
            }
        }
        _ => {
            for (i, o) in out.iter_mut().enumerate() {
                *o = hamming_words_scalar(q, db.code(i));
            }
        }
    }
}

/// Normalized Hamming distance (eq. 11 of the paper) between sign rows.
pub fn normalized_hamming(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let diff = a
        .iter()
        .zip(b)
        .filter(|(x, y)| (**x >= 0.0) != (**y >= 0.0))
        .count();
    diff as f64 / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn hamming_known() {
        let a = BitCode::from_signs(&[1.0, 1.0, -1.0, -1.0], 1, 4);
        let b = BitCode::from_signs(&[1.0, -1.0, -1.0, 1.0], 1, 4);
        assert_eq!(hamming(&a, 0, &b, 0), 2);
    }

    #[test]
    fn packed_matches_unpacked() {
        let mut rng = Pcg64::new(81);
        for bits in [32usize, 64, 128, 200] {
            let s1: Vec<f32> = rng.sign_vec(bits);
            let s2: Vec<f32> = rng.sign_vec(bits);
            let a = BitCode::from_signs(&s1, 1, bits);
            let b = BitCode::from_signs(&s2, 1, bits);
            let packed = hamming(&a, 0, &b, 0) as f64 / bits as f64;
            let unpacked = normalized_hamming(&s1, &s2);
            assert!((packed - unpacked).abs() < 1e-12);
        }
    }

    #[test]
    fn hamming_to_all_consistent() {
        let mut rng = Pcg64::new(83);
        // 256 and 512 exercise the unrolled 4- and 8-word kernels; 200 and
        // 450 exercise them with padding bits in the last word.
        for bits in [64usize, 128, 200, 256, 320, 450, 512] {
            let n = 20;
            let signs: Vec<f32> = rng.sign_vec(n * bits);
            let db = BitCode::from_signs(&signs, n, bits);
            let q: Vec<f32> = rng.sign_vec(bits);
            let qc = BitCode::from_signs(&q, 1, bits);
            let mut out = vec![0u32; n];
            hamming_to_all(qc.code(0), &db, &mut out);
            for i in 0..n {
                assert_eq!(out[i], hamming(&qc, 0, &db, i));
            }
        }
    }
}
