//! Packed binary codes: sign(+) → 1-bit, 64 bits per u64 word.

use crate::index::persist::mmap::Words;

/// A set of n fixed-length binary codes, bit-packed row-major.
///
/// The word store is a [`Words`] (`Store<u64>`): owned for anything
/// built in memory, or a zero-copy window into a mapped snapshot after
/// an mmap load. It derefs to `[u64]`, so indexing and slicing read it
/// either way; the first mutation of a mapped store promotes it to an
/// owned copy (see [`crate::index::persist::mmap`]).
#[derive(Clone, Debug, PartialEq)]
pub struct BitCode {
    pub n: usize,
    pub bits: usize,
    pub words_per_code: usize,
    pub data: Words,
}

impl BitCode {
    pub fn new(n: usize, bits: usize) -> BitCode {
        let wpc = bits.div_ceil(64);
        BitCode {
            n,
            bits,
            words_per_code: wpc,
            data: Words::owned(vec![0u64; n * wpc]),
        }
    }

    /// Re-shape to `n` rows in place (same bit width), reusing the
    /// allocation where possible; all words are reset to zero. The
    /// batch-encode loop recycles one `BitCode` across batches with this.
    pub fn reset(&mut self, n: usize) {
        self.n = n;
        let data = self.data.to_mut();
        data.clear();
        data.resize(n * self.words_per_code, 0);
    }

    /// Pack rows of ±1 (or arbitrary-sign f32) values; v ≥ 0 → bit set.
    pub fn from_signs(rows: &[f32], n: usize, bits: usize) -> BitCode {
        assert_eq!(rows.len(), n * bits);
        let mut bc = BitCode::new(n, bits);
        for i in 0..n {
            let row = &rows[i * bits..(i + 1) * bits];
            bc.set_row_from_signs(i, row);
        }
        bc
    }

    /// Overwrite code i from a slice of sign values (len == bits).
    pub fn set_row_from_signs(&mut self, i: usize, signs: &[f32]) {
        assert_eq!(signs.len(), self.bits);
        let base = i * self.words_per_code;
        for w in 0..self.words_per_code {
            let mut word = 0u64;
            let lo = w * 64;
            let hi = (lo + 64).min(self.bits);
            for (b, &s) in signs[lo..hi].iter().enumerate() {
                if s >= 0.0 {
                    word |= 1u64 << b;
                }
            }
            self.data[base + w] = word;
        }
    }

    #[inline]
    pub fn code(&self, i: usize) -> &[u64] {
        &self.data[i * self.words_per_code..(i + 1) * self.words_per_code]
    }

    /// Are all tail-word padding bits (bit positions ≥ `bits` in the last
    /// word of each row) zero? Every writer in this module keeps them
    /// zero; the popcount kernels (scalar and SIMD alike) count whole
    /// words, so a stray padding bit would silently inflate distances.
    /// The padding regression tests churn codes and assert this.
    pub fn padding_is_zero(&self) -> bool {
        let tail = self.bits % 64;
        if tail == 0 || self.words_per_code == 0 {
            return true;
        }
        let mask = !0u64 << tail;
        (0..self.n).all(|i| self.code(i)[self.words_per_code - 1] & mask == 0)
    }

    /// Unpack code i back to ±1 f32 values.
    pub fn to_signs(&self, i: usize) -> Vec<f32> {
        let code = self.code(i);
        (0..self.bits)
            .map(|b| {
                if code[b / 64] >> (b % 64) & 1 == 1 {
                    1.0
                } else {
                    -1.0
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_signs() {
        let mut rng = Pcg64::new(71);
        for bits in [1usize, 63, 64, 65, 100, 256] {
            let n = 5;
            let signs: Vec<f32> = (0..n * bits)
                .map(|_| if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
                .collect();
            let bc = BitCode::from_signs(&signs, n, bits);
            for i in 0..n {
                assert_eq!(bc.to_signs(i), signs[i * bits..(i + 1) * bits].to_vec());
            }
        }
    }

    #[test]
    fn zero_maps_to_positive() {
        let bc = BitCode::from_signs(&[0.0, -0.0, 1.0, -1.0], 1, 4);
        // IEEE -0.0 >= 0.0 is true, so both zeros set the bit.
        assert_eq!(bc.to_signs(0), vec![1.0, 1.0, 1.0, -1.0]);
    }

    #[test]
    fn reset_reshapes_and_zeroes() {
        let mut bc = BitCode::from_signs(&vec![1.0; 3 * 65], 3, 65);
        bc.reset(2);
        assert_eq!(bc.n, 2);
        assert_eq!(bc.bits, 65);
        assert_eq!(bc.data, vec![0u64; 2 * bc.words_per_code]);
        bc.reset(4);
        assert_eq!(bc.data.len(), 4 * bc.words_per_code);
    }

    #[test]
    fn padding_bits_zero() {
        let bc = BitCode::from_signs(&vec![1.0; 65], 1, 65);
        // word 1 must only have bit 0 set.
        assert_eq!(bc.code(0)[1], 1);
    }
}
