//! `cbe` — the coordinator binary.
//!
//! Subcommands:
//!   serve       run the embedding service demo (parallel batch encode)
//!   train       train CBE-opt on synthetic data, report objective trace
//!   encode      encode random vectors through the serving pipeline
//!   exp <id>    reproduce a paper table/figure: fig1 table2 fig2 fig3
//!               fig4 fig5 table3 sec6 | all
//!   artifacts   list compiled artifacts

use cbe::bits::BitCode;
use cbe::coordinator::{BatcherConfig, EmbeddingService, RetrainConfig, ServiceConfig};
use cbe::data::{generate, SynthConfig};
use cbe::encoders::CbeTrainer;
use cbe::experiments as exp;
use cbe::index::persist::{LoadMode, LoadReport, PersistOptions, PersistentIndex};
use cbe::index::{IndexBackend, IndexKind, RecoveryState};
use cbe::fft::Planner;
use cbe::opt::TimeFreqConfig;
use cbe::projections::{CbeModel, ProjectionSpec};
use cbe::runtime::Manifest;
use cbe::util::cli::Args;
use cbe::util::rng::Pcg64;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str("artifacts", "artifacts"))
}

/// Projection variant: `--proj SPEC` wins, then the `CBE_PROJ` env var,
/// then the paper's single-block `circ`. Grammar:
/// `circ | stacked[:B] | downsampled`.
fn proj_spec_arg(args: &Args) -> anyhow::Result<ProjectionSpec> {
    let raw = if args.has("proj") {
        args.str("proj", "circ")
    } else {
        std::env::var("CBE_PROJ").unwrap_or_else(|_| "circ".to_string())
    };
    ProjectionSpec::from_spec(&raw).map_err(|e| anyhow::anyhow!("--proj: {e}"))
}

/// Trainer spectrum-cache budget in bytes: `--cache-budget` wins, then the
/// `CBE_CACHE_BUDGET` env var, then 0 (unlimited — no tiling).
fn cache_budget_arg(args: &Args) -> usize {
    if args.has("cache-budget") {
        return args.usize("cache-budget", 0);
    }
    std::env::var("CBE_CACHE_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

fn main() -> anyhow::Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        return Ok(());
    }
    let cmd = argv.remove(0);
    let args = Args::parse(argv);
    match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "train" => cmd_train(&args),
        "encode" => cmd_encode(&args),
        "save-index" => cmd_save_index(&args),
        "load-index" => cmd_load_index(&args),
        "exp" => cmd_exp(&args),
        "artifacts" => cmd_artifacts(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            print_usage();
            std::process::exit(2);
        }
    }
}

fn print_usage() {
    println!(
        "cbe — Circulant Binary Embedding (ICML 2014) coordinator\n\
         \n\
         usage: cbe <command> [flags]\n\
         \n\
         commands:\n\
         \x20 serve      run the embedding service demo (parallel batch encode)\n\
         \x20 train      train CBE-opt on synthetic data (native optimizer)\n\
         \x20 encode     batch-encode random vectors through the service\n\
         \x20 save-index build an index over a seeded corpus and persist it\n\
         \x20            (checksummed snapshot + write-ahead log)\n\
         \x20 load-index load/recover a persisted index and verify it serves\n\
         \x20 exp <id>   reproduce a paper artifact: fig1 table2 fig2 fig3\n\
         \x20            fig4 fig5 table3 sec6 all\n\
         \x20 artifacts  list compiled artifacts\n\
         \n\
         common flags: --artifacts DIR --d N --bits K --seed S\n\
         \x20             --index SPEC (auto | linear | mih[:m] | mih-sampled[:m] |\n\
         \x20                           sharded:<shards>[:m])\n\
         \x20             --proj SPEC (circ | stacked[:B] | downsampled; also env\n\
         \x20                          CBE_PROJ. stacked serves k > d bits,\n\
         \x20                          downsampled decorrelates k < d bits)\n\
         \x20             --queue-depth N (admission bound; 0 = CBE_QUEUE_DEPTH\n\
         \x20                              env, default 1024)\n\
         serve flags:  --retrain (train from the corpus reservoir and hot-swap\n\
         \x20             the model live) --retrain-sample N --retrain-iters N\n\
         \x20             --index-path DIR (load the index from a persisted\n\
         \x20             snapshot+wal, or build+save it, and demo wal churn)\n\
         \x20             --stats (print the stats snapshot as JSON on exit)\n\
         \x20             --stats-every SECS (stream snapshots to stderr)\n\
         persist flags: --index-path DIR (for save-index / load-index; the\n\
         \x20             fault plan env CBE_FAULT=crash:<n>|abort:<n> kills the\n\
         \x20             writer at persistence op <n> for recovery drills)\n\
         \x20             --mmap auto|1|0 (snapshot-load backing: zero-copy\n\
         \x20             mmap vs heap copy; auto reads CBE_MMAP, then maps\n\
         \x20             wherever the platform supports it)\n\
         train flags:  --threads N (0 = auto) --deterministic BOOL\n\
         \x20             --cache-budget BYTES (trainer spectrum-cache budget,\n\
         \x20             also env CBE_CACHE_BUDGET; 0 = unlimited)\n\
         scale flags:  --full (paper-scale dims; slow), default is CI scale"
    );
}

fn cmd_artifacts(args: &Args) -> anyhow::Result<()> {
    let m = Manifest::load(&artifacts_dir(args))?;
    println!("{} artifacts:", m.artifacts.len());
    for a in &m.artifacts {
        println!(
            "  {:<32} kind={:<16} d={:<6} batch={} inputs={:?}",
            a.name, a.kind, a.d, a.batch, a.inputs
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let d = args.usize("d", 512);
    let k = args.usize("bits", d);
    let n = args.usize("n", 1000);
    let iters = args.usize("iters", 8);
    let seed = args.u64("seed", 1);
    println!("training CBE-opt: d={d} k={k} n={n} iters={iters}");
    let ds = generate(&SynthConfig::imagenet(n, d, seed));
    let mut tf = TimeFreqConfig::new(k);
    tf.iters = iters;
    tf.lambda = args.f32("lambda", 1.0) as f64;
    tf.threads = args.usize("threads", 0);
    tf.deterministic = args.bool("deterministic", true);
    tf.cache_budget = cache_budget_arg(args);
    let enc = CbeTrainer::new(tf).seed(seed + 1).planner(Planner::new()).train(&ds.x);
    let rep = &enc.report;
    println!(
        "trained in {:.1} ms ({} threads, spectrum cache {:.1} MiB); objective trace:",
        rep.total_ms,
        rep.threads,
        rep.cache_bytes as f64 / (1 << 20) as f64
    );
    println!(
        "phases: cache-build {:.1} ms, sweep {:.1} ms, bin-solve {:.1} ms",
        rep.cache_build_ms, rep.sweep_ms, rep.bin_solve_ms
    );
    for (i, (o, ms)) in rep.objective_trace.iter().zip(&rep.iter_ms).enumerate() {
        println!("  iter {i}: {o:.3} ({ms:.1} ms)");
    }
    Ok(())
}

fn cmd_encode(args: &Args) -> anyhow::Result<()> {
    let d = args.usize("d", 512);
    let count = args.usize("count", 256);
    let bits = args.usize("bits", d.min(256));
    let seed = args.u64("seed", 3);
    let proj = proj_spec_arg(args)?;
    let mut rng = Pcg64::new(seed);
    let model = CbeModel::random_with(&proj, d, bits, &mut rng, Planner::new())?;
    let service = EmbeddingService::start_with_model(
        &artifacts_dir(args),
        ServiceConfig {
            d,
            bits,
            batcher: BatcherConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(2),
            },
            index: IndexBackend::Auto,
            retrain: RetrainConfig::default(),
            queue_depth: args.usize("queue-depth", 0),
            load_mode: load_mode_arg(args),
            proj,
        },
        model,
    )?;
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..count)
        .map(|_| service.encode_async(rng.normal_vec(d)).unwrap())
        .collect();
    let mut ones = 0usize;
    for h in handles {
        let resp = h.recv()?;
        ones += resp.signs.iter().filter(|s| **s > 0.0).count();
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "encoded {count}×{d}→{bits} bits in {:.1} ms ({:.0} vec/s); bit balance {:.3}",
        dt * 1e3,
        count as f64 / dt,
        ones as f64 / (count * bits) as f64
    );
    println!("metrics: {}", service.metrics.summary(32));
    Ok(())
}

fn index_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str("index-path", "index_dir"))
}

/// Start a service over the *seeded* random projection (no training):
/// `save-index` and `load-index` runs in separate processes derive the
/// same parameters from the same `--seed` (and the same `--proj` spec —
/// the fingerprint covers all blocks and any selection plan), so the
/// snapshot's model fingerprint verifies across them.
fn seeded_service(
    args: &Args,
    d: usize,
    bits: usize,
    seed: u64,
    backend: IndexBackend,
) -> anyhow::Result<EmbeddingService> {
    let proj = proj_spec_arg(args)?;
    let model = CbeModel::random(&proj, d, bits, seed, Planner::new())?;
    EmbeddingService::start_with_model(
        &artifacts_dir(args),
        ServiceConfig {
            d,
            bits,
            batcher: BatcherConfig::default(),
            index: backend,
            retrain: RetrainConfig::default(),
            queue_depth: args.usize("queue-depth", 0),
            load_mode: load_mode_arg(args),
            proj,
        },
        model,
    )
}

fn dir_bytes(dir: &std::path::Path) -> anyhow::Result<u64> {
    let mut total = 0;
    for entry in std::fs::read_dir(dir)? {
        total += entry?.metadata()?.len();
    }
    Ok(total)
}

/// `--mmap auto|1|0` → snapshot-load backing (explicit flag beats the
/// `CBE_MMAP` env, which `auto` consults).
fn load_mode_arg(args: &Args) -> LoadMode {
    match args.str("mmap", "auto").as_str() {
        "0" | "heap" | "off" | "false" => LoadMode::Heap,
        "1" | "mmap" | "on" | "true" => LoadMode::Mmap,
        _ => LoadMode::Auto,
    }
}

fn print_load_report(report: &LoadReport) {
    match &report.state {
        RecoveryState::Loaded => println!(
            "recovery: clean load (generation {}, {} wal records replayed)",
            report.generation, report.wal_records_replayed
        ),
        RecoveryState::LoadedWithTruncatedWalTail { dropped_bytes } => println!(
            "recovery: dropped {dropped_bytes} torn wal tail bytes \
             (generation {}, {} wal records replayed)",
            report.generation, report.wal_records_replayed
        ),
    }
    println!(
        "load path: {} ({} snapshot bytes mapped)",
        report.path.name(),
        report.mapped_bytes
    );
}

fn cmd_save_index(args: &Args) -> anyhow::Result<()> {
    let d = args.usize("d", 256);
    let bits = args.usize("bits", d.min(128));
    let n_db = args.usize("db", 2000);
    let seed = args.u64("seed", 5);
    let dir = index_dir(args);
    let backend = IndexBackend::from_spec(&args.str("index", "mih"))
        .map_err(|e| anyhow::anyhow!("--index: {e}"))?;
    let service = seeded_service(args, d, bits, seed, backend)?;
    let ds = generate(&SynthConfig::flickr(n_db, d, seed ^ 0xC0FFEE));
    let rows: Vec<Vec<f32>> = (0..n_db).map(|i| ds.x.row(i).to_vec()).collect();
    let (index, build_ms) = cbe::util::timer::time_ms(|| service.build_index(&rows).unwrap());
    let (saved, save_ms) = cbe::util::timer::time_ms(|| service.save_index(&dir, &index));
    saved.map_err(|e| anyhow::anyhow!("save-index: {e}"))?;
    println!(
        "saved {} rows ({} bits, backend: {}) to {}: {} bytes in {save_ms:.1} ms \
         (index built in {build_ms:.1} ms); model fingerprint {:#018x}",
        index.len(),
        bits,
        index.backend_name(),
        dir.display(),
        dir_bytes(&dir)?,
        service.model_fingerprint()
    );
    Ok(())
}

fn cmd_load_index(args: &Args) -> anyhow::Result<()> {
    let d = args.usize("d", 256);
    let bits = args.usize("bits", d.min(128));
    let n_db = args.usize("db", 2000);
    let topk = args.usize("topk", 10);
    let seed = args.u64("seed", 5);
    let dir = index_dir(args);
    let service = seeded_service(args, d, bits, seed, IndexBackend::Auto)?;
    let (loaded, load_ms) = cbe::util::timer::time_ms(|| service.load_index(&dir));
    let (index, report) = loaded.map_err(|e| anyhow::anyhow!("load-index: {e}"))?;
    print_load_report(&report);
    println!(
        "loaded {} rows (backend: {}) from {} in {load_ms:.1} ms",
        index.len(),
        index.backend_name(),
        dir.display()
    );
    // Verify the recovered index actually serves: with the same --d,
    // --bits, --db, and --seed as the save, every corpus row must find
    // itself at Hamming distance 0.
    let ds = generate(&SynthConfig::flickr(n_db, d, seed ^ 0xC0FFEE));
    let checks = 20.min(index.len()).min(n_db);
    let mut hits_self = 0usize;
    for qi in 0..checks {
        let hits = service
            .search(&index, ds.x.row(qi).to_vec(), topk)
            .map_err(|e| anyhow::anyhow!("search: {e}"))?;
        if hits.iter().any(|h| h.id == qi as u32) {
            hits_self += 1;
        }
    }
    anyhow::ensure!(
        hits_self == checks,
        "recovered index lost rows: {hits_self}/{checks} self-queries hit \
         (were --d/--bits/--db/--seed the same as at save time?)"
    );
    println!("verified: {checks}/{checks} self-queries hit their own id");
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let d = args.usize("d", 512);
    let bits = args.usize("bits", d.min(256));
    let n_db = args.usize("db", 2000);
    let topk = args.usize("topk", 10);
    let seed = args.u64("seed", 5);
    let backend = IndexBackend::from_spec(&args.str("index", "auto"))
        .map_err(|e| anyhow::anyhow!("--index: {e}"))?;
    let proj = proj_spec_arg(args)?;
    println!(
        "embedding server demo: d={d} bits={bits} db={n_db} index={} proj={}",
        backend.spec(),
        proj.spec()
    );

    // Train CBE-opt natively (per block for stacked; the downsampled
    // variant is training-free), then serve through the parallel batch
    // path.
    let ds = generate(&SynthConfig::flickr(n_db + 100, d, seed));
    let mut tf = TimeFreqConfig::new(bits);
    tf.iters = 5;
    let train = cbe::data::gather(&ds.x, &(0..500.min(n_db)).collect::<Vec<_>>());
    let enc = CbeTrainer::new(tf)
        .seed(seed)
        .train_model(&proj, &train, None)
        .map_err(|e| anyhow::anyhow!("train: {e}"))?;

    let defaults = RetrainConfig::default();
    let retrain = RetrainConfig {
        sample: args.usize("retrain-sample", defaults.sample),
        iters: args.usize("retrain-iters", defaults.iters),
        cache_budget: cache_budget_arg(args),
        ..defaults
    };
    let service = EmbeddingService::start_with_model(
        &artifacts_dir(args),
        ServiceConfig {
            d,
            bits,
            batcher: BatcherConfig::default(),
            index: backend,
            retrain,
            queue_depth: args.usize("queue-depth", 0),
            load_mode: load_mode_arg(args),
            proj,
        },
        enc.model,
    )?;

    // --stats-every N: a scoped ticker thread streams stats snapshots to
    // stderr every N seconds while the demo runs (stdout stays reserved
    // for the demo output and the final --stats JSON line).
    let stats_every = args.usize("stats-every", 0);
    let ticker_stop = AtomicBool::new(false);
    std::thread::scope(|scope| -> anyhow::Result<()> {
        if stats_every > 0 {
            let (svc, stop) = (&service, &ticker_stop);
            scope.spawn(move || {
                let period = Duration::from_secs(stats_every as u64);
                let mut next = std::time::Instant::now() + period;
                // Poll the stop flag at 200 ms so demo exit never waits
                // out a whole period.
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(200));
                    if std::time::Instant::now() >= next {
                        if let Ok(snap) = svc.stats() {
                            eprintln!("{}", snap.to_json());
                        }
                        next += period;
                    }
                }
            });
        }
        let result = serve_demo(args, &service, &ds, n_db, topk);
        ticker_stop.store(true, Ordering::Relaxed);
        result
    })?;
    println!("metrics: {}", service.metrics.summary(32));
    // --stats: the machine-readable snapshot, as the last stdout line (CI
    // smoke pipes it straight into a JSON parser).
    if args.bool("stats", false) {
        let snap = service.stats().map_err(|e| anyhow::anyhow!("stats: {e}"))?;
        println!("{}", snap.to_json());
    }
    Ok(())
}

/// The serve-demo workload proper: index the corpus, serve queries, and
/// optionally retrain + rebuild ( `--retrain`). Split out of [`cmd_serve`]
/// so the stats ticker can scope around it.
fn serve_demo(
    args: &Args,
    service: &EmbeddingService,
    ds: &cbe::data::Dataset,
    n_db: usize,
    topk: usize,
) -> anyhow::Result<()> {
    let rows: Vec<Vec<f32>> = (0..n_db).map(|i| ds.x.row(i).to_vec()).collect();
    let build = || {
        let (index, ms) = cbe::util::timer::time_ms(|| service.build_index(&rows).unwrap());
        println!(
            "indexed {n_db} vectors in {ms:.1} ms (backend: {})",
            index.backend_name()
        );
        index
    };
    // --index-path: load (and recover) the persisted index if the
    // directory holds a usable one for the live model; otherwise build
    // fresh and save it for the next run.
    let index_path = args.has("index-path").then(|| index_dir(args));
    let index = match &index_path {
        Some(dir) => match service.load_index(dir) {
            Ok((index, report)) => {
                print_load_report(&report);
                println!(
                    "loaded {} vectors from {} (backend: {})",
                    index.len(),
                    dir.display(),
                    index.backend_name()
                );
                index
            }
            Err(e) => {
                println!("no usable index at {} ({e}); building fresh", dir.display());
                let index = build();
                service
                    .save_index(dir, &index)
                    .map_err(|e| anyhow::anyhow!("save index: {e}"))?;
                println!("saved snapshot to {}", dir.display());
                index
            }
        },
        None => build(),
    };

    let mut hits_self = 0usize;
    let queries = 50usize;
    let (_, qms) = cbe::util::timer::time_ms(|| {
        for qi in 0..queries {
            let hits = service
                .search(&index, ds.x.row(qi).to_vec(), topk)
                .unwrap();
            if hits.iter().any(|h| h.id == qi as u32) {
                hits_self += 1;
            }
        }
    });
    println!(
        "served {queries} queries in {qms:.1} ms ({:.2} ms/query); self-recall@{topk}: {:.2}",
        qms / queries as f64,
        hits_self as f64 / queries as f64
    );

    // --index-path churn demo: run live insert/remove traffic through
    // the write-ahead log (linear indexes are immutable, so skip them).
    if let Some(dir) = &index_path {
        if !matches!(index.kind(), IndexKind::Linear(_)) {
            churn_demo(service, dir, ds, n_db)?;
        }
    }

    // --retrain: re-learn the model from the corpus reservoir and
    // hot-swap it in with the service still running, then serve again.
    if args.bool("retrain", false) {
        let outcome = service
            .retrain_blocking()
            .map_err(|e| anyhow::anyhow!("retrain: {e}"))?;
        println!(
            "retrained: model v{} on {} sampled rows in {:.1} ms ({} threads), \
             final objective {:.3}",
            outcome.version,
            outcome.rows_used,
            outcome.report.total_ms,
            outcome.report.threads,
            outcome.report.objective_trace.last().copied().unwrap_or(f64::NAN)
        );
        // The old index was built with the old model — the service now
        // refuses it (CbeError::StaleIndex) instead of serving
        // cross-model garbage. Rebuild under the new model and serve.
        let stale = service
            .search(&index, ds.x.row(0).to_vec(), topk)
            .expect_err("stale index must be rejected after a retrain");
        println!("stale index rejected: {stale}");
        let (index, ms) = cbe::util::timer::time_ms(|| service.build_index(&rows).unwrap());
        let mut hits_self = 0usize;
        for qi in 0..queries {
            let hits = service.search(&index, ds.x.row(qi).to_vec(), topk).unwrap();
            if hits.iter().any(|h| h.id == qi as u32) {
                hits_self += 1;
            }
        }
        println!(
            "post-swap: reindexed in {ms:.1} ms; self-recall@{topk}: {:.2}",
            hits_self as f64 / queries as f64
        );
    }
    Ok(())
}

/// WAL churn demo: log inserts for corpus rows past the indexed cut,
/// prove they serve, then log their removal — the directory ends in the
/// same logical state it began in, so repeated `serve --index-path`
/// runs are idempotent while the wal genuinely grows and replays.
fn churn_demo(
    service: &EmbeddingService,
    dir: &std::path::Path,
    ds: &cbe::data::Dataset,
    n_db: usize,
) -> anyhow::Result<()> {
    let (mut pidx, _) = PersistentIndex::open(dir, PersistOptions::default())
        .map_err(|e| anyhow::anyhow!("reopen index for churn: {e}"))?;
    let bits = pidx.index().bits();
    let extra = 8usize;
    let encode_row = |i: usize| -> anyhow::Result<BitCode> {
        let resp = service
            .encode(ds.x.row(i).to_vec())
            .map_err(|e| anyhow::anyhow!("encode: {e}"))?;
        Ok(BitCode::from_signs(&resp.signs, 1, bits))
    };
    for i in 0..extra {
        let id = (n_db + i) as u32;
        // A prior crashed run may have logged this insert without its
        // matching remove; clear it so the insert cannot collide.
        pidx.remove(id).map_err(|e| anyhow::anyhow!("wal remove: {e}"))?;
        let code = encode_row(n_db + i)?;
        pidx.insert(id, code.code(0))
            .map_err(|e| anyhow::anyhow!("wal insert: {e}"))?;
    }
    // The logged rows must be live: the first insert finds itself.
    let probe = encode_row(n_db)?;
    let top = pidx.search(probe.code(0), 1).first().map(|h| h.id);
    anyhow::ensure!(
        top == Some(n_db as u32),
        "wal-inserted row {n_db} not searchable (top hit: {top:?})"
    );
    for i in 0..extra {
        pidx.remove((n_db + i) as u32)
            .map_err(|e| anyhow::anyhow!("wal remove: {e}"))?;
    }
    pidx.flush().map_err(|e| anyhow::anyhow!("wal flush: {e}"))?;
    println!(
        "wal churn: {extra} inserts + {extra} removes logged and fsync'd \
         (generation {}, {} wal records)",
        pidx.generation(),
        pidx.wal_records()
    );
    Ok(())
}

fn cmd_exp(args: &Args) -> anyhow::Result<()> {
    let which = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let full = args.bool("full", false);
    let run_one = |id: &str| -> anyhow::Result<()> {
        println!("{}", run_experiment(id, full, args)?);
        Ok(())
    };
    if which == "all" {
        for id in ["fig1", "table2", "fig2", "fig3", "fig4", "fig5", "table3", "sec6"] {
            run_one(id)?;
        }
        Ok(())
    } else {
        run_one(&which)
    }
}

fn run_experiment(id: &str, full: bool, args: &Args) -> anyhow::Result<String> {
    use exp::recall_sweep::{Corpus, SweepConfig};
    Ok(match id {
        "fig1" => {
            let d = args.usize("d", if full { 256 } else { 128 });
            let pairs = args.usize("pairs", if full { 40 } else { 10 });
            let reps = args.usize("reps", if full { 200 } else { 60 });
            exp::fig1_variance::run(
                d,
                &args.usize_list("bits", &[8, 16, 32, 64, d.min(128)]),
                &[0.2, 0.5, 0.9, 1.2, std::f64::consts::FRAC_PI_2],
                pairs,
                reps,
                args.u64("seed", 42),
            )
            .report
        }
        "table2" => {
            let dims: Vec<usize> = if full {
                vec![1 << 13, 1 << 15, 1 << 17, 1 << 20]
            } else {
                vec![1 << 10, 1 << 12, 1 << 14, 1 << 16]
            };
            let dims = args.usize_list("dims", &dims);
            exp::table2_timing::run(
                &dims,
                exp::table2_timing::DEFAULT_MEM_BUDGET,
                args.u64("seed", 7),
            )
            .report
        }
        "fig2" | "fig3" | "fig4" => {
            let (corpus, d_default) = match id {
                "fig2" => (Corpus::Flickr, if full { 25600 } else { 2560 }),
                "fig3" => (Corpus::ImageNet, if full { 25600 } else { 2560 }),
                _ => (Corpus::ImageNet, if full { 51200 } else { 5120 }),
            };
            let d = args.usize("d", d_default);
            let mut cfg = SweepConfig::quick(corpus, d);
            if full {
                cfg.n = 20_000;
                cfg.n_train = 2_000;
                cfg.n_queries = 500;
            }
            if args.has("bits") {
                cfg.bits = args.usize_list("bits", &cfg.bits);
            }
            cfg.index = IndexBackend::from_spec(&args.str("index", "auto"))
                .map_err(|e| anyhow::anyhow!("--index: {e}"))?;
            exp::recall_sweep::run(&cfg).report
        }
        "fig5" => {
            let d = args.usize("d", if full { 2048 } else { 512 });
            let mut cfg = exp::fig5_lowdim::Fig5Config::quick(d);
            if full {
                cfg.n = 10_000;
                cfg.n_train = 1_000;
                cfg.n_queries = 200;
                cfg.bits = vec![64, 128, 256, 512];
            }
            exp::fig5_lowdim::run(&cfg).report
        }
        "table3" => {
            let d = args.usize("d", if full { 2560 } else { 256 });
            let mut cfg = exp::table3_classify::Table3Config::quick(d);
            if full {
                cfg.classes = 50;
                cfg.per_class_train = 100;
                cfg.per_class_test = 50;
            }
            exp::table3_classify::run(&cfg).report
        }
        "ablate" => {
            let d = args.usize("d", if full { 2048 } else { 256 });
            exp::ablations::run(d, args.u64("seed", 5)).report
        }
        "sec6" => {
            let d = args.usize("d", if full { 2560 } else { 256 });
            let mut cfg = exp::semi_supervised::Sec6Config::quick(d);
            if full {
                cfg.n = 10_000;
                cfg.n_train = 1_000;
                cfg.n_pairs = 2_000;
            }
            cfg.mu = args.f32("mu", cfg.mu as f32) as f64;
            cfg.n_pairs = args.usize("pairs", cfg.n_pairs);
            cfg.k = args.usize("bits", cfg.k);
            exp::semi_supervised::run(&cfg).report
        }
        other => anyhow::bail!("unknown experiment '{other}'"),
    })
}
