//! Synthetic dataset substrate.
//!
//! The paper evaluates on proprietary image features (Flickr-25600,
//! ImageNet-25600/51200: VLAD/Fisher-vector style descriptors, 100K
//! instances, ℓ2-normalized). Those files are not distributable, so this
//! module generates the closest synthetic equivalent exercising the same
//! code paths (see DESIGN.md §Substitutions):
//!
//! * clustered gaussian mixture with power-law cluster weights (image
//!   collections are long-tailed),
//! * heavy-tailed per-dimension scales (descriptor blocks have uneven
//!   energy, which is what makes learned rotations beat random ones),
//! * ℓ2 normalization (the paper's footnote 5 assumes unit-norm data).

use crate::linalg::Mat;
use crate::util::rng::Pcg64;
use crate::util::l2_normalize;

/// Parameters of the synthetic feature generator.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    pub n: usize,
    pub d: usize,
    pub clusters: usize,
    /// Within-cluster spread relative to between-cluster distance.
    pub noise: f32,
    /// Power-law exponent for cluster weights (0 = uniform).
    pub zipf: f32,
    pub seed: u64,
}

impl SynthConfig {
    /// "Flickr-like": noisier internet-photo collection.
    pub fn flickr(n: usize, d: usize, seed: u64) -> SynthConfig {
        SynthConfig {
            n,
            d,
            clusters: 64,
            noise: 0.55,
            zipf: 0.8,
            seed,
        }
    }
    /// "ImageNet-like": 100 classes, tighter clusters.
    pub fn imagenet(n: usize, d: usize, seed: u64) -> SynthConfig {
        SynthConfig {
            n,
            d,
            clusters: 100,
            noise: 0.35,
            zipf: 0.3,
            seed,
        }
    }
}

/// A generated dataset: rows are ℓ2-normalized features.
pub struct Dataset {
    pub x: Mat,
    /// Cluster id per row (class labels for Table 3).
    pub labels: Vec<usize>,
    pub cfg: SynthConfig,
}

/// Generate the synthetic dataset.
pub fn generate(cfg: &SynthConfig) -> Dataset {
    let mut rng = Pcg64::new(cfg.seed);
    let d = cfg.d;

    // Cluster centers: sparse-ish heavy-tailed directions — mimics
    // descriptor blocks lighting up for specific visual words.
    let mut scales = vec![0f32; d];
    for (j, s) in scales.iter_mut().enumerate() {
        // block-structured energy decay
        let block = (j * 16 / d.max(1)) as f32;
        *s = (1.0 / (1.0 + block)).powf(0.7);
    }
    let mut centers = Mat::zeros(cfg.clusters, d);
    for c in 0..cfg.clusters {
        for j in 0..d {
            centers[(c, j)] = rng.normal() as f32 * scales[j];
        }
        l2_normalize(centers.row_mut(c));
    }

    // Power-law cluster weights.
    let weights: Vec<f64> = (0..cfg.clusters)
        .map(|c| 1.0 / ((c + 1) as f64).powf(cfg.zipf as f64))
        .collect();
    let total: f64 = weights.iter().sum();
    let cum: Vec<f64> = weights
        .iter()
        .scan(0.0, |acc, w| {
            *acc += w / total;
            Some(*acc)
        })
        .collect();

    let mut x = Mat::zeros(cfg.n, d);
    let mut labels = Vec::with_capacity(cfg.n);
    for i in 0..cfg.n {
        let u = rng.next_f64();
        let c = cum.partition_point(|p| *p < u).min(cfg.clusters - 1);
        labels.push(c);
        for j in 0..d {
            x[(i, j)] = centers[(c, j)] + cfg.noise * rng.normal() as f32 * scales[j];
        }
        l2_normalize(x.row_mut(i));
    }
    Dataset {
        x,
        labels,
        cfg: cfg.clone(),
    }
}

/// Split rows into (train, queries): queries are sampled without
/// replacement and removed from the training pool indices.
pub fn train_query_split(
    n: usize,
    n_queries: usize,
    seed: u64,
) -> (Vec<usize>, Vec<usize>) {
    let mut rng = Pcg64::new(seed);
    let idx = rng.sample_indices(n, n_queries);
    let is_query: std::collections::HashSet<usize> = idx.iter().cloned().collect();
    let train: Vec<usize> = (0..n).filter(|i| !is_query.contains(i)).collect();
    (train, idx)
}

/// Gather rows of a matrix by index.
pub fn gather(x: &Mat, idx: &[usize]) -> Mat {
    let mut out = Mat::zeros(idx.len(), x.cols);
    for (i, &src) in idx.iter().enumerate() {
        out.row_mut(i).copy_from_slice(x.row(src));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::dot;

    #[test]
    fn rows_unit_norm() {
        let ds = generate(&SynthConfig::flickr(100, 64, 1));
        for i in 0..100 {
            let n = dot(ds.x.row(i), ds.x.row(i));
            assert!((n - 1.0).abs() < 1e-4);
        }
        assert_eq!(ds.labels.len(), 100);
    }

    #[test]
    fn clusters_are_tighter_than_background() {
        let ds = generate(&SynthConfig::imagenet(400, 32, 2));
        // mean intra-cluster dot > mean inter-cluster dot
        let (mut intra, mut inter) = (0f64, 0f64);
        let (mut ni, mut nx) = (0u64, 0u64);
        for i in 0..200 {
            for j in (i + 1)..200 {
                let s = dot(ds.x.row(i), ds.x.row(j)) as f64;
                if ds.labels[i] == ds.labels[j] {
                    intra += s;
                    ni += 1;
                } else {
                    inter += s;
                    nx += 1;
                }
            }
        }
        assert!(ni > 0 && nx > 0);
        assert!(intra / ni as f64 > inter / nx as f64 + 0.1);
    }

    #[test]
    fn split_disjoint_and_complete() {
        let (train, query) = train_query_split(100, 10, 3);
        assert_eq!(train.len(), 90);
        assert_eq!(query.len(), 10);
        let mut all: Vec<usize> = train.iter().chain(query.iter()).cloned().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_by_seed() {
        let a = generate(&SynthConfig::flickr(10, 16, 42));
        let b = generate(&SynthConfig::flickr(10, 16, 42));
        assert_eq!(a.x.data, b.x.data);
        assert_eq!(a.labels, b.labels);
    }
}
