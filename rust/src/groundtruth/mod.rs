//! Exact k-NN ground truth by ℓ2 distance (the paper's protocol: the true
//! 10 nearest neighbors of each query among the database rows).

use crate::linalg::Mat;

/// For each query row, the indices of its k nearest database rows by ℓ2
/// distance (equivalently cosine, for unit-norm rows — footnote 5).
pub fn exact_knn(db: &Mat, queries: &Mat, k: usize) -> Vec<Vec<u32>> {
    assert_eq!(db.cols, queries.cols);
    let k = k.min(db.rows);
    let mut out = Vec::with_capacity(queries.rows);
    for qi in 0..queries.rows {
        let q = queries.row(qi);
        // max-heap of (dist, idx) keeping the k smallest
        let mut heap: std::collections::BinaryHeap<(ordered, u32)> =
            std::collections::BinaryHeap::with_capacity(k + 1);
        for di in 0..db.rows {
            let row = db.row(di);
            let mut dist = 0f32;
            for j in 0..db.cols {
                let t = q[j] - row[j];
                dist += t * t;
            }
            if heap.len() < k {
                heap.push((ordered_of(dist), di as u32));
            } else if let Some(&(top, _)) = heap.peek() {
                if dist < top.0 {
                    heap.pop();
                    heap.push((ordered_of(dist), di as u32));
                }
            }
        }
        let mut hits: Vec<(f32, u32)> = heap.into_iter().map(|(d, i)| (d.0, i)).collect();
        hits.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        out.push(hits.into_iter().map(|(_, i)| i).collect());
    }
    out
}

/// Total-ordered f32 wrapper for the heap.
#[allow(non_camel_case_types)]
#[derive(PartialEq, Copy, Clone)]
struct ordered(f32);
impl Eq for ordered {}
impl PartialOrd for ordered {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for ordered {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&o.0).unwrap_or(std::cmp::Ordering::Equal)
    }
}
fn ordered_of(x: f32) -> ordered {
    ordered(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn knn_matches_bruteforce_sort() {
        let mut rng = Pcg64::new(5);
        let db = Mat::randn(50, 8, &mut rng);
        let q = Mat::randn(3, 8, &mut rng);
        let got = exact_knn(&db, &q, 5);
        for qi in 0..3 {
            let mut all: Vec<(f32, u32)> = (0..50)
                .map(|di| {
                    let mut d2 = 0f32;
                    for j in 0..8 {
                        let t = q[(qi, j)] - db[(di, j)];
                        d2 += t * t;
                    }
                    (d2, di as u32)
                })
                .collect();
            all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            let want: Vec<u32> = all.iter().take(5).map(|(_, i)| *i).collect();
            assert_eq!(got[qi], want);
        }
    }

    #[test]
    fn self_is_nearest() {
        let mut rng = Pcg64::new(6);
        let db = Mat::randn(20, 4, &mut rng);
        let got = exact_knn(&db, &db, 1);
        for (i, hits) in got.iter().enumerate() {
            assert_eq!(hits[0], i as u32);
        }
    }
}
