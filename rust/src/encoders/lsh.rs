//! LSH baseline: sign(Wx) with dense gaussian W (Charikar 2002).
//! O(kd) time, O(kd) space — the cost column the paper's Table 1 beats.

use super::BinaryEncoder;
use crate::projections::FullProjection;
use crate::util::rng::Pcg64;

pub struct Lsh {
    pub proj: FullProjection,
}

impl Lsh {
    pub fn new(d: usize, k: usize, seed: u64) -> Lsh {
        let mut rng = Pcg64::new(seed);
        Lsh {
            proj: FullProjection::random(k, d, &mut rng),
        }
    }
}

impl BinaryEncoder for Lsh {
    fn name(&self) -> &'static str {
        "LSH"
    }
    fn bits(&self) -> usize {
        self.proj.k
    }
    fn encode_signs(&self, x: &[f32]) -> Vec<f32> {
        self.proj.encode(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::hamming::normalized_hamming;
    use crate::util::{angle, l2_normalize};

    #[test]
    fn lsh_angle_preservation() {
        let d = 64;
        let k = 512;
        let enc = Lsh::new(d, k, 11);
        let mut rng = Pcg64::new(12);
        let mut a = rng.normal_vec(d);
        let mut b: Vec<f32> = a.iter().map(|v| v + 0.5 * rng.normal() as f32).collect();
        l2_normalize(&mut a);
        l2_normalize(&mut b);
        let theta = angle(&a, &b) as f64;
        let nh = normalized_hamming(&enc.encode_signs(&a), &enc.encode_signs(&b));
        assert!((nh - theta / std::f64::consts::PI).abs() < 0.08);
    }
}
