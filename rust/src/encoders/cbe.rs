//! CBE-rand and CBE-opt — the paper's methods.
//!
//! Both are thin configs over a [`CbeModel`]: the [`ProjectionSpec`]
//! grammar (`circ | stacked[:B] | downsampled`) decides whether the
//! model is the paper's single circulant block, a stack of B blocks for
//! k > d codes, or a sparsely row-selected block for k ≪ d. Both
//! override [`BinaryEncoder::encode_batch`] with the parallel
//! batch-encode engine (scoped-thread fan-out, direct sign→bit packing),
//! which is bit-exactly equivalent to the serial per-vector default.
//!
//! Training goes through [`CbeTrainer`]: it owns the run configuration
//! (λ, iterations, thread count, determinism, spectrum-memory budget),
//! drives the half-spectrum-cached parallel [`TimeFreqOptimizer`], and
//! hands back a [`CbeOpt`] carrying both the learned model and the
//! [`TrainReport`] of the run (per-iteration objective, wall time,
//! thread count, resident cache bytes / tile size). For stacked models
//! each block trains independently on its own slice of the bit budget
//! ([`CbeTrainer::train_model`]); the downsampled variant is
//! data-independent and needs no trainer at all.

use super::BinaryEncoder;
use crate::bits::BitCode;
use crate::fft::Planner;
use crate::linalg::Mat;
use crate::opt::{PairSet, TimeFreqConfig, TimeFreqOptimizer, TrainReport};
use crate::projections::{CbeModel, CirculantProjection, ProjectionSpec, ScratchPool, StackedCirculant};
use crate::util::rng::Pcg64;
use crate::CbeError;

/// Shared batch-path override: fan the rows of `x` out across cores and
/// pack the k-bit codes directly.
fn batch_encode(model: &CbeModel, k: usize, x: &Mat) -> BitCode {
    let rows: Vec<&[f32]> = (0..x.rows).map(|i| x.row(i)).collect();
    let mut bc = BitCode::new(x.rows, k);
    model.encode_batch_into(&rows, k, &mut bc, &mut ScratchPool::new());
    bc
}

/// Encoder display name for a variant — kept `CBE`-prefixed so harness
/// logic keying on the family (e.g. the fixed-time recall sweep) still
/// groups all variants together.
fn variant_name(model: &CbeModel, opt: bool) -> &'static str {
    match (model, opt) {
        (CbeModel::Circ(_), false) => "CBE-rand",
        (CbeModel::Circ(_), true) => "CBE-opt",
        (CbeModel::Stacked(_), false) => "CBE-rand-stacked",
        (CbeModel::Stacked(_), true) => "CBE-opt-stacked",
        (CbeModel::Downsampled(_), false) => "CBE-rand-ds",
        (CbeModel::Downsampled(_), true) => "CBE-opt-ds",
    }
}

/// Randomized CBE (§3): r ~ N(0,1), D random ±1 diagonal — generalized
/// over the projection variants via [`ProjectionSpec`].
pub struct CbeRand {
    pub model: CbeModel,
    pub k: usize,
}

impl CbeRand {
    /// The paper's single-block encoder (`circ` spec). k > d is a typed
    /// [`CbeError::BadCodeLength`], not a panic — use
    /// [`CbeRand::with_spec`] and `stacked[:B]` for longer codes.
    pub fn new(d: usize, k: usize, seed: u64, planner: Planner) -> Result<CbeRand, CbeError> {
        CbeRand::with_spec(&ProjectionSpec::Circ, d, k, seed, planner)
    }

    /// Seeded random encoder for any projection spec.
    pub fn with_spec(
        spec: &ProjectionSpec,
        d: usize,
        k: usize,
        seed: u64,
        planner: Planner,
    ) -> Result<CbeRand, CbeError> {
        Ok(CbeRand {
            model: CbeModel::random(spec, d, k, seed, planner)?,
            k,
        })
    }
}

impl BinaryEncoder for CbeRand {
    fn name(&self) -> &'static str {
        variant_name(&self.model, false)
    }
    fn bits(&self) -> usize {
        self.k
    }
    fn encode_signs(&self, x: &[f32]) -> Vec<f32> {
        self.model.encode(x, self.k)
    }
    fn encode_batch(&self, x: &Mat) -> BitCode {
        batch_encode(&self.model, self.k, x)
    }
}

/// The CBE-opt training harness: configuration in, trained [`CbeOpt`]
/// (+ [`TrainReport`]) out.
///
/// ```no_run
/// # use cbe::encoders::CbeTrainer;
/// # use cbe::opt::TimeFreqConfig;
/// # use cbe::linalg::Mat;
/// # let x = Mat::zeros(8, 16);
/// let mut cfg = TimeFreqConfig::new(16);
/// cfg.iters = 5;
/// let enc = CbeTrainer::new(cfg).seed(7).train(&x);
/// println!("trained in {:.1} ms on {} threads",
///          enc.report.total_ms, enc.report.threads);
/// ```
#[derive(Clone)]
pub struct CbeTrainer {
    pub cfg: TimeFreqConfig,
    pub seed: u64,
    pub planner: Planner,
}

impl CbeTrainer {
    pub fn new(cfg: TimeFreqConfig) -> CbeTrainer {
        CbeTrainer {
            cfg,
            seed: 1,
            planner: Planner::new(),
        }
    }

    /// Seed for the sign diagonal D and the r₀ init (default 1).
    pub fn seed(mut self, seed: u64) -> CbeTrainer {
        self.seed = seed;
        self
    }

    /// Share an existing plan cache instead of building a fresh one.
    pub fn planner(mut self, planner: Planner) -> CbeTrainer {
        self.planner = planner;
        self
    }

    /// Cap the trainer's resident spectrum memory (bytes). Training sets
    /// whose half-spectrum cache would exceed the budget stream through
    /// block-aligned tiles instead — bit-identical results, bounded
    /// memory (0 = never tile). See
    /// [`TimeFreqConfig::cache_budget`](crate::opt::TimeFreqConfig::cache_budget).
    pub fn cache_budget(mut self, bytes: usize) -> CbeTrainer {
        self.cfg.cache_budget = bytes;
        self
    }

    /// Train on the rows of `x` (unsupervised).
    pub fn train(&self, x: &Mat) -> CbeOpt {
        self.train_with_pairs(x, None)
    }

    /// Train with optional §6 similar/dissimilar pair supervision.
    pub fn train_with_pairs(&self, x: &Mat, pairs: Option<&PairSet>) -> CbeOpt {
        let (proj, trace, report) = self.train_block(x, pairs, self.cfg.clone(), self.seed);
        CbeOpt {
            model: CbeModel::Circ(proj),
            k: self.cfg.k,
            objective_trace: trace,
            block_reports: vec![report.clone()],
            report,
        }
    }

    /// Train a model for any projection spec, with `self.cfg.k` as the
    /// *total* code length:
    ///
    /// * `circ` — the classic path, identical to
    ///   [`CbeTrainer::train_with_pairs`].
    /// * `stacked[:B]` — each block trains independently on its own bit
    ///   window (block b owns `min(d, k − b·d)` bits); block 0 uses
    ///   `self.seed` so a trained `stacked:1` is bit-identical to a
    ///   trained `circ`, later blocks derive their seeds
    ///   deterministically from it.
    /// * `downsampled` — data-independent (arXiv:1601.06342): returns
    ///   the seeded random model with an empty objective trace.
    pub fn train_model(
        &self,
        spec: &ProjectionSpec,
        x: &Mat,
        pairs: Option<&PairSet>,
    ) -> Result<CbeOpt, CbeError> {
        let d = x.cols;
        let k = self.cfg.k;
        spec.validate(k, d)?;
        match spec {
            ProjectionSpec::Circ => Ok(self.train_with_pairs(x, pairs)),
            ProjectionSpec::Downsampled => {
                let model =
                    CbeModel::random(spec, d, k, self.seed, self.planner.clone())?;
                Ok(CbeOpt {
                    model,
                    k,
                    objective_trace: Vec::new(),
                    block_reports: Vec::new(),
                    report: TrainReport::default(),
                })
            }
            ProjectionSpec::Stacked { .. } => {
                let blocks = spec.blocks_for(k, d);
                let mut trained = Vec::with_capacity(blocks);
                let mut reports = Vec::with_capacity(blocks);
                for b in 0..blocks {
                    let mut cfg = self.cfg.clone();
                    cfg.k = d.min(k - b * d);
                    // Block 0 trains exactly like the plain circulant run
                    // (same cfg.k, same seed); extra blocks get distinct
                    // deterministic seed offsets so their D diagonals and
                    // r₀ inits are independent draws.
                    let seed = self
                        .seed
                        .wrapping_add((b as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                    let (proj, _trace, report) = self.train_block(x, pairs, cfg, seed);
                    reports.push(report);
                    trained.push(proj);
                }
                let model = CbeModel::Stacked(StackedCirculant::new(trained)?);
                let report = reports[0].clone();
                Ok(CbeOpt {
                    model,
                    k,
                    objective_trace: report.objective_trace.clone(),
                    block_reports: reports,
                    report,
                })
            }
        }
    }

    /// One circulant block's training run — the shared core of the
    /// single-block and stacked paths.
    fn train_block(
        &self,
        x: &Mat,
        pairs: Option<&PairSet>,
        cfg: TimeFreqConfig,
        seed: u64,
    ) -> (CirculantProjection, Vec<f64>, TrainReport) {
        let d = x.cols;
        let mut rng = Pcg64::new(seed);
        let signs = rng.sign_vec(d);
        let r0 = rng.normal_vec(d);

        // Apply D to the training data once (sign flips), as §2 prescribes.
        let mut xflip = x.clone();
        for i in 0..xflip.rows {
            for (v, s) in xflip.row_mut(i).iter_mut().zip(&signs) {
                *v *= *s;
            }
        }

        let mut opt = TimeFreqOptimizer::new(d, cfg, self.planner.clone());
        let r = opt.run(&xflip, &r0, pairs);
        let trace = opt.objective_trace.clone();
        (
            CirculantProjection::new(r, signs, self.planner.clone()),
            trace,
            opt.report,
        )
    }
}

/// Learned CBE (§4): r optimized by the time–frequency alternating
/// optimization on training data.
pub struct CbeOpt {
    pub model: CbeModel,
    pub k: usize,
    /// Objective trace of the training run (diagnostics; same values as
    /// `report.objective_trace`). For stacked models this is block 0's
    /// trace — see [`CbeOpt::block_reports`] for the rest.
    pub objective_trace: Vec<f64>,
    /// Full convergence + performance record of the training run. For
    /// stacked models, block 0's report (the others ride in
    /// [`CbeOpt::block_reports`]); empty-default for the training-free
    /// downsampled variant.
    pub report: TrainReport,
    /// Per-block reports, one per trained circulant block (empty for
    /// downsampled).
    pub block_reports: Vec<TrainReport>,
}

impl CbeOpt {
    /// Train on rows of `x`. λ and iteration count come from `cfg`.
    /// Thin wrapper over [`CbeTrainer`] for callers that don't need the
    /// builder.
    pub fn train(
        x: &Mat,
        cfg: TimeFreqConfig,
        seed: u64,
        planner: Planner,
        pairs: Option<&PairSet>,
    ) -> CbeOpt {
        CbeTrainer::new(cfg)
            .seed(seed)
            .planner(planner)
            .train_with_pairs(x, pairs)
    }
}

impl BinaryEncoder for CbeOpt {
    fn name(&self) -> &'static str {
        variant_name(&self.model, true)
    }
    fn bits(&self) -> usize {
        self.k
    }
    fn encode_signs(&self, x: &[f32]) -> Vec<f32> {
        self.model.encode(x, self.k)
    }
    fn encode_batch(&self, x: &Mat) -> BitCode {
        batch_encode(&self.model, self.k, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::hamming::normalized_hamming;
    use crate::util::{angle, l2_normalize};

    #[test]
    fn cbe_rand_angle_preservation() {
        // E[normalized hamming] = θ/π (eq. 13) — statistical check.
        let d = 256;
        let planner = Planner::new();
        let mut rng = Pcg64::new(1234);
        let trials = 60;
        let mut errs = 0f64;
        for t in 0..trials {
            let enc = CbeRand::new(d, d, 5000 + t, planner.clone()).unwrap();
            let mut a = rng.normal_vec(d);
            let mut b: Vec<f32> = a
                .iter()
                .enumerate()
                .map(|(i, v)| v + if i % 2 == 0 { 0.8 } else { -0.8 } * rng.normal() as f32)
                .collect();
            l2_normalize(&mut a);
            l2_normalize(&mut b);
            let theta = angle(&a, &b) as f64;
            let ha = enc.encode_signs(&a);
            let hb = enc.encode_signs(&b);
            let nh = normalized_hamming(&ha, &hb);
            errs += (nh - theta / std::f64::consts::PI).abs();
        }
        let mean_err = errs / trials as f64;
        assert!(mean_err < 0.06, "mean |H - θ/π| = {mean_err}");
    }

    #[test]
    fn cbe_opt_beats_rand_on_objective() {
        let d = 32;
        let n = 60;
        let mut rng = Pcg64::new(99);
        let mut x = Mat::randn(n, d, &mut rng);
        for i in 0..n {
            l2_normalize(x.row_mut(i));
        }
        let cfg = TimeFreqConfig::new(d);
        let enc = CbeTrainer::new(cfg).seed(7).train(&x);
        assert_eq!(enc.bits(), d);
        let tr = &enc.objective_trace;
        assert!(!tr.is_empty());
        // trace[0] reflects the random init (see timefreq tests); descent
        // holds from iteration 1 onward.
        assert!(tr.last().unwrap() <= &tr[1]);
        // The report mirrors the trace and records the run shape.
        assert_eq!(enc.report.objective_trace, *tr);
        assert_eq!(enc.report.n, n);
        assert_eq!(enc.report.d, d);
    }

    #[test]
    fn trainer_builder_matches_legacy_entry_point() {
        // CbeOpt::train is a thin wrapper over CbeTrainer — identical
        // model out (same seed → same signs, same r bits).
        let d = 24;
        let n = 40;
        let mut rng = Pcg64::new(55);
        let x = Mat::randn(n, d, &mut rng);
        let mut cfg = TimeFreqConfig::new(d);
        cfg.iters = 3;
        let planner = Planner::new();
        let a = CbeOpt::train(&x, cfg.clone(), 9, planner.clone(), None);
        let b = CbeTrainer::new(cfg).seed(9).planner(planner).train(&x);
        let (pa, pb) = (
            a.model.as_circulant().unwrap(),
            b.model.as_circulant().unwrap(),
        );
        assert_eq!(pa.signs, pb.signs);
        for (x, y) in pa.r.iter().zip(&pb.r) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn trained_stacked_1_is_the_trained_circulant() {
        // The k == d compatibility contract holds through training, not
        // just random draws: one stacked block learns the exact same
        // model as the classic path (same seed stream, same cfg.k).
        let d = 20;
        let n = 30;
        let mut rng = Pcg64::new(11);
        let x = Mat::randn(n, d, &mut rng);
        let mut cfg = TimeFreqConfig::new(d);
        cfg.iters = 3;
        let trainer = CbeTrainer::new(cfg).seed(6);
        let circ = trainer.train(&x);
        let st1 = trainer
            .train_model(&ProjectionSpec::Stacked { blocks: Some(1) }, &x, None)
            .unwrap();
        let pc = circ.model.as_circulant().unwrap();
        let CbeModel::Stacked(ref s) = st1.model else {
            panic!("expected a stacked model");
        };
        let ps = &s.blocks()[0];
        assert_eq!(pc.signs, ps.signs);
        for (a, b) in pc.r.iter().zip(&ps.r) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(circ.model.fingerprint(), st1.model.fingerprint());
        assert_eq!(circ.objective_trace, st1.objective_trace);
    }

    #[test]
    fn stacked_training_partitions_the_bit_budget() {
        let d = 16;
        let n = 25;
        let k = 2 * d + 5; // 3 blocks: 16 + 16 + 5 bits
        let mut rng = Pcg64::new(21);
        let x = Mat::randn(n, d, &mut rng);
        let mut cfg = TimeFreqConfig::new(k);
        cfg.iters = 2;
        let enc = CbeTrainer::new(cfg)
            .seed(3)
            .train_model(&ProjectionSpec::Stacked { blocks: None }, &x, None)
            .unwrap();
        assert_eq!(enc.bits(), k);
        assert_eq!(enc.model.block_count(), 3);
        assert_eq!(enc.block_reports.len(), 3);
        for r in &enc.block_reports {
            assert!(!r.objective_trace.is_empty());
        }
        // Blocks are independent draws: their D diagonals differ.
        let CbeModel::Stacked(ref s) = enc.model else {
            panic!("expected a stacked model");
        };
        assert_ne!(s.blocks()[0].signs, s.blocks()[1].signs);
        // Serving shape: a full-length encode really yields k bits.
        let q = Pcg64::new(1).normal_vec(d);
        assert_eq!(enc.encode_signs(&q).len(), k);
        assert_eq!(enc.name(), "CBE-opt-stacked");
    }

    #[test]
    fn downsampled_training_is_free_and_deterministic() {
        let d = 32;
        let k = 8;
        let n = 20;
        let mut rng = Pcg64::new(41);
        let x = Mat::randn(n, d, &mut rng);
        let mut cfg = TimeFreqConfig::new(k);
        cfg.iters = 2;
        let trainer = CbeTrainer::new(cfg).seed(13);
        let a = trainer
            .train_model(&ProjectionSpec::Downsampled, &x, None)
            .unwrap();
        let b = trainer
            .train_model(&ProjectionSpec::Downsampled, &x, None)
            .unwrap();
        assert!(a.objective_trace.is_empty(), "downsampled has no trainer");
        assert!(a.block_reports.is_empty());
        assert_eq!(a.model.fingerprint(), b.model.fingerprint());
        // ...and equals the pure random draw from the same seed: the
        // "trained" downsampled model IS the seeded model.
        let r = CbeRand::with_spec(&ProjectionSpec::Downsampled, d, k, 13, Planner::new())
            .unwrap();
        assert_eq!(a.model.fingerprint(), r.model.fingerprint());
        assert_eq!(a.name(), "CBE-opt-ds");
    }

    #[test]
    fn cache_budget_does_not_change_the_model() {
        // The memory budget tiles the cache build; the learned model
        // must not move by a single bit.
        let d = 24;
        let n = 150;
        let mut rng = Pcg64::new(77);
        let x = Mat::randn(n, d, &mut rng);
        let mut cfg = TimeFreqConfig::new(d);
        cfg.iters = 3;
        let full = CbeTrainer::new(cfg.clone()).seed(5).train(&x);
        let tiled = CbeTrainer::new(cfg)
            .seed(5)
            .cache_budget(70 * (d / 2 + 1) * 16)
            .train(&x);
        assert!(tiled.report.tile_rows > 0, "budget did not trigger tiling");
        assert!(tiled.report.cache_bytes < full.report.cache_bytes);
        let (pf, pt) = (
            full.model.as_circulant().unwrap(),
            tiled.model.as_circulant().unwrap(),
        );
        assert_eq!(pf.signs, pt.signs);
        for (a, b) in pf.r.iter().zip(&pt.r) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn batch_override_matches_default_path() {
        let d = 48;
        let n = 33;
        let planner = Planner::new();
        for spec in [
            ProjectionSpec::Circ,
            ProjectionSpec::Stacked { blocks: Some(2) },
            ProjectionSpec::Downsampled,
        ] {
            let k = if matches!(spec, ProjectionSpec::Stacked { .. }) {
                2 * d - 7
            } else {
                20
            };
            let enc = CbeRand::with_spec(&spec, d, k, 8, planner.clone()).unwrap();
            let mut rng = Pcg64::new(9);
            let x = Mat::randn(n, d, &mut rng);
            let batch = enc.encode_batch(&x);
            let mut serial = BitCode::new(n, enc.bits());
            for i in 0..n {
                serial.set_row_from_signs(i, &enc.encode_signs(x.row(i)));
            }
            assert_eq!(batch, serial, "spec={}", spec.spec());
        }
    }

    #[test]
    fn k_bits_are_prefix() {
        let d = 64;
        let planner = Planner::new();
        let full = CbeRand::new(d, d, 3, planner.clone()).unwrap();
        let fp = full.model.as_circulant().unwrap();
        let part = CbeRand {
            model: CbeModel::circulant(fp.r.clone(), fp.signs.clone(), planner),
            k: 16,
        };
        let mut rng = Pcg64::new(4);
        let x = rng.normal_vec(d);
        assert_eq!(part.encode_signs(&x), full.encode_signs(&x)[..16].to_vec());
    }

    #[test]
    fn bad_code_lengths_are_typed_errors() {
        let planner = Planner::new();
        assert_eq!(
            CbeRand::new(16, 17, 1, planner.clone()).unwrap_err(),
            CbeError::BadCodeLength { k: 17, d: 16, max: 16 }
        );
        assert_eq!(
            CbeRand::with_spec(
                &ProjectionSpec::Stacked { blocks: Some(2) },
                16,
                33,
                1,
                planner
            )
            .unwrap_err(),
            CbeError::BadCodeLength { k: 33, d: 16, max: 32 }
        );
    }
}
