//! CBE-rand and CBE-opt — the paper's methods.
//!
//! Both override [`BinaryEncoder::encode_batch`] with the parallel
//! batch-encode engine (scoped-thread fan-out, direct sign→bit packing),
//! which is bit-exactly equivalent to the serial per-vector default.
//!
//! Training goes through [`CbeTrainer`]: it owns the run configuration
//! (λ, iterations, thread count, determinism, spectrum-memory budget),
//! drives the half-spectrum-cached parallel [`TimeFreqOptimizer`], and
//! hands back a [`CbeOpt`] carrying both the learned projection and the
//! [`TrainReport`] of the run (per-iteration objective, wall time,
//! thread count, resident cache bytes / tile size).

use super::BinaryEncoder;
use crate::bits::BitCode;
use crate::fft::Planner;
use crate::linalg::Mat;
use crate::opt::{PairSet, TimeFreqConfig, TimeFreqOptimizer, TrainReport};
use crate::projections::{CirculantProjection, ScratchPool};
use crate::util::rng::Pcg64;

/// Shared batch-path override: fan the rows of `x` out across cores and
/// pack the k-bit codes directly.
fn batch_encode(proj: &CirculantProjection, k: usize, x: &Mat) -> BitCode {
    let rows: Vec<&[f32]> = (0..x.rows).map(|i| x.row(i)).collect();
    let mut bc = BitCode::new(x.rows, k);
    proj.encode_batch_into(&rows, k, &mut bc, &mut ScratchPool::new());
    bc
}

/// Randomized CBE (§3): r ~ N(0,1), D random ±1 diagonal.
pub struct CbeRand {
    pub proj: CirculantProjection,
    pub k: usize,
}

impl CbeRand {
    pub fn new(d: usize, k: usize, seed: u64, planner: Planner) -> CbeRand {
        assert!(k <= d, "CBE produces at most d bits");
        let mut rng = Pcg64::new(seed);
        CbeRand {
            proj: CirculantProjection::random(d, &mut rng, planner),
            k,
        }
    }
}

impl BinaryEncoder for CbeRand {
    fn name(&self) -> &'static str {
        "CBE-rand"
    }
    fn bits(&self) -> usize {
        self.k
    }
    fn encode_signs(&self, x: &[f32]) -> Vec<f32> {
        self.proj.encode(x, self.k)
    }
    fn encode_batch(&self, x: &Mat) -> BitCode {
        batch_encode(&self.proj, self.k, x)
    }
}

/// The CBE-opt training harness: configuration in, trained [`CbeOpt`]
/// (+ [`TrainReport`]) out.
///
/// ```no_run
/// # use cbe::encoders::CbeTrainer;
/// # use cbe::opt::TimeFreqConfig;
/// # use cbe::linalg::Mat;
/// # let x = Mat::zeros(8, 16);
/// let mut cfg = TimeFreqConfig::new(16);
/// cfg.iters = 5;
/// let enc = CbeTrainer::new(cfg).seed(7).train(&x);
/// println!("trained in {:.1} ms on {} threads",
///          enc.report.total_ms, enc.report.threads);
/// ```
#[derive(Clone)]
pub struct CbeTrainer {
    pub cfg: TimeFreqConfig,
    pub seed: u64,
    pub planner: Planner,
}

impl CbeTrainer {
    pub fn new(cfg: TimeFreqConfig) -> CbeTrainer {
        CbeTrainer {
            cfg,
            seed: 1,
            planner: Planner::new(),
        }
    }

    /// Seed for the sign diagonal D and the r₀ init (default 1).
    pub fn seed(mut self, seed: u64) -> CbeTrainer {
        self.seed = seed;
        self
    }

    /// Share an existing plan cache instead of building a fresh one.
    pub fn planner(mut self, planner: Planner) -> CbeTrainer {
        self.planner = planner;
        self
    }

    /// Cap the trainer's resident spectrum memory (bytes). Training sets
    /// whose half-spectrum cache would exceed the budget stream through
    /// block-aligned tiles instead — bit-identical results, bounded
    /// memory (0 = never tile). See
    /// [`TimeFreqConfig::cache_budget`](crate::opt::TimeFreqConfig::cache_budget).
    pub fn cache_budget(mut self, bytes: usize) -> CbeTrainer {
        self.cfg.cache_budget = bytes;
        self
    }

    /// Train on the rows of `x` (unsupervised).
    pub fn train(&self, x: &Mat) -> CbeOpt {
        self.train_with_pairs(x, None)
    }

    /// Train with optional §6 similar/dissimilar pair supervision.
    pub fn train_with_pairs(&self, x: &Mat, pairs: Option<&PairSet>) -> CbeOpt {
        let d = x.cols;
        let k = self.cfg.k;
        let mut rng = Pcg64::new(self.seed);
        let signs = rng.sign_vec(d);
        let r0 = rng.normal_vec(d);

        // Apply D to the training data once (sign flips), as §2 prescribes.
        let mut xflip = x.clone();
        for i in 0..xflip.rows {
            for (v, s) in xflip.row_mut(i).iter_mut().zip(&signs) {
                *v *= *s;
            }
        }

        let mut opt = TimeFreqOptimizer::new(d, self.cfg.clone(), self.planner.clone());
        let r = opt.run(&xflip, &r0, pairs);
        CbeOpt {
            proj: CirculantProjection::new(r, signs, self.planner.clone()),
            k,
            objective_trace: opt.objective_trace.clone(),
            report: opt.report,
        }
    }
}

/// Learned CBE (§4): r optimized by the time–frequency alternating
/// optimization on training data.
pub struct CbeOpt {
    pub proj: CirculantProjection,
    pub k: usize,
    /// Objective trace of the training run (diagnostics; same values as
    /// `report.objective_trace`).
    pub objective_trace: Vec<f64>,
    /// Full convergence + performance record of the training run.
    pub report: TrainReport,
}

impl CbeOpt {
    /// Train on rows of `x`. λ and iteration count come from `cfg`.
    /// Thin wrapper over [`CbeTrainer`] for callers that don't need the
    /// builder.
    pub fn train(
        x: &Mat,
        cfg: TimeFreqConfig,
        seed: u64,
        planner: Planner,
        pairs: Option<&PairSet>,
    ) -> CbeOpt {
        CbeTrainer::new(cfg)
            .seed(seed)
            .planner(planner)
            .train_with_pairs(x, pairs)
    }
}

impl BinaryEncoder for CbeOpt {
    fn name(&self) -> &'static str {
        "CBE-opt"
    }
    fn bits(&self) -> usize {
        self.k
    }
    fn encode_signs(&self, x: &[f32]) -> Vec<f32> {
        self.proj.encode(x, self.k)
    }
    fn encode_batch(&self, x: &Mat) -> BitCode {
        batch_encode(&self.proj, self.k, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::hamming::normalized_hamming;
    use crate::util::{angle, l2_normalize};

    #[test]
    fn cbe_rand_angle_preservation() {
        // E[normalized hamming] = θ/π (eq. 13) — statistical check.
        let d = 256;
        let planner = Planner::new();
        let mut rng = Pcg64::new(1234);
        let trials = 60;
        let mut errs = 0f64;
        for t in 0..trials {
            let enc = CbeRand::new(d, d, 5000 + t, planner.clone());
            let mut a = rng.normal_vec(d);
            let mut b: Vec<f32> = a
                .iter()
                .enumerate()
                .map(|(i, v)| v + if i % 2 == 0 { 0.8 } else { -0.8 } * rng.normal() as f32)
                .collect();
            l2_normalize(&mut a);
            l2_normalize(&mut b);
            let theta = angle(&a, &b) as f64;
            let ha = enc.encode_signs(&a);
            let hb = enc.encode_signs(&b);
            let nh = normalized_hamming(&ha, &hb);
            errs += (nh - theta / std::f64::consts::PI).abs();
        }
        let mean_err = errs / trials as f64;
        assert!(mean_err < 0.06, "mean |H - θ/π| = {mean_err}");
    }

    #[test]
    fn cbe_opt_beats_rand_on_objective() {
        let d = 32;
        let n = 60;
        let mut rng = Pcg64::new(99);
        let mut x = Mat::randn(n, d, &mut rng);
        for i in 0..n {
            l2_normalize(x.row_mut(i));
        }
        let cfg = TimeFreqConfig::new(d);
        let enc = CbeTrainer::new(cfg).seed(7).train(&x);
        assert_eq!(enc.bits(), d);
        let tr = &enc.objective_trace;
        assert!(!tr.is_empty());
        // trace[0] reflects the random init (see timefreq tests); descent
        // holds from iteration 1 onward.
        assert!(tr.last().unwrap() <= &tr[1]);
        // The report mirrors the trace and records the run shape.
        assert_eq!(enc.report.objective_trace, *tr);
        assert_eq!(enc.report.n, n);
        assert_eq!(enc.report.d, d);
    }

    #[test]
    fn trainer_builder_matches_legacy_entry_point() {
        // CbeOpt::train is a thin wrapper over CbeTrainer — identical
        // model out (same seed → same signs, same r bits).
        let d = 24;
        let n = 40;
        let mut rng = Pcg64::new(55);
        let x = Mat::randn(n, d, &mut rng);
        let mut cfg = TimeFreqConfig::new(d);
        cfg.iters = 3;
        let planner = Planner::new();
        let a = CbeOpt::train(&x, cfg.clone(), 9, planner.clone(), None);
        let b = CbeTrainer::new(cfg).seed(9).planner(planner).train(&x);
        assert_eq!(a.proj.signs, b.proj.signs);
        for (x, y) in a.proj.r.iter().zip(&b.proj.r) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn cache_budget_does_not_change_the_model() {
        // The memory budget tiles the cache build; the learned model
        // must not move by a single bit.
        let d = 24;
        let n = 150;
        let mut rng = Pcg64::new(77);
        let x = Mat::randn(n, d, &mut rng);
        let mut cfg = TimeFreqConfig::new(d);
        cfg.iters = 3;
        let full = CbeTrainer::new(cfg.clone()).seed(5).train(&x);
        let tiled = CbeTrainer::new(cfg)
            .seed(5)
            .cache_budget(70 * (d / 2 + 1) * 16)
            .train(&x);
        assert!(tiled.report.tile_rows > 0, "budget did not trigger tiling");
        assert!(tiled.report.cache_bytes < full.report.cache_bytes);
        assert_eq!(full.proj.signs, tiled.proj.signs);
        for (a, b) in full.proj.r.iter().zip(&tiled.proj.r) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn batch_override_matches_default_path() {
        let d = 48;
        let n = 33;
        let planner = Planner::new();
        let enc = CbeRand::new(d, 20, 8, planner);
        let mut rng = Pcg64::new(9);
        let x = Mat::randn(n, d, &mut rng);
        let batch = enc.encode_batch(&x);
        let mut serial = BitCode::new(n, enc.bits());
        for i in 0..n {
            serial.set_row_from_signs(i, &enc.encode_signs(x.row(i)));
        }
        assert_eq!(batch, serial);
    }

    #[test]
    fn k_bits_are_prefix() {
        let d = 64;
        let planner = Planner::new();
        let full = CbeRand::new(d, d, 3, planner.clone());
        let part = CbeRand {
            proj: CirculantProjection::new(
                full.proj.r.clone(),
                full.proj.signs.clone(),
                planner,
            ),
            k: 16,
        };
        let mut rng = Pcg64::new(4);
        let x = rng.normal_vec(d);
        assert_eq!(part.encode_signs(&x), full.encode_signs(&x)[..16].to_vec());
    }
}
