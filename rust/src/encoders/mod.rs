//! Every binary-embedding method the paper evaluates, behind one trait.
//!
//! * [`CbeRand`] / [`CbeOpt`] — the paper's contribution (§2–4).
//! * [`Lsh`] — full gaussian projection (Charikar 2002), the classic
//!   baseline ("LSH" in the paper's figures).
//! * [`BilinearRand`] / [`BilinearOpt`] — Gong et al. 2013a, the prior
//!   state of the art for long codes.
//! * [`Itq`], [`Sh`], [`Sklsh`], [`Aqbc`] — low-dimensional baselines of
//!   Figure 5.

pub mod traits;
pub mod cbe;
pub mod lsh;
pub mod bilinear;
pub mod itq;
pub mod sh;
pub mod sklsh;
pub mod aqbc;

pub use aqbc::Aqbc;
pub use bilinear::{BilinearOpt, BilinearRand};
pub use cbe::{CbeOpt, CbeRand};
pub use itq::Itq;
pub use lsh::Lsh;
pub use sh::Sh;
pub use sklsh::Sklsh;
pub use traits::BinaryEncoder;
