//! Every binary-embedding method the paper evaluates, behind one trait.
//!
//! All encoders implement [`BinaryEncoder`]: train (where applicable) on a
//! sample matrix, then map d-dim float rows to k-bit sign vectors, packed
//! downstream via [`crate::bits::BitCode`]. The experiment drivers
//! ([`crate::experiments`]) treat them uniformly through `&dyn
//! BinaryEncoder`.
//!
//! * [`CbeRand`] / [`CbeOpt`] — the paper's contribution (§2–4): a
//!   circulant projection applied via FFT, O(d log d) per vector instead
//!   of the O(d²) dense multiply; `Opt` learns the circulant in the
//!   frequency domain ([`crate::opt`]).
//! * [`Lsh`] — full gaussian projection (Charikar 2002), the classic
//!   baseline ("LSH" in the paper's figures).
//! * [`BilinearRand`] / [`BilinearOpt`] — Gong et al. 2013a, the prior
//!   state of the art for long codes.
//! * [`Itq`], [`Sh`], [`Sklsh`], [`Aqbc`] — low-dimensional baselines of
//!   Figure 5.
//!
//! One property of CBE matters downstream in [`crate::index`]: adjacent
//! circulant bits are *correlated* (Yu et al., 2015), so an index that
//! buckets on contiguous bit ranges sees skewed bucket occupancy — the
//! `mih-sampled` backend exists to undo exactly that.

pub mod traits;
pub mod cbe;
pub mod lsh;
pub mod bilinear;
pub mod itq;
pub mod sh;
pub mod sklsh;
pub mod aqbc;

pub use aqbc::Aqbc;
pub use bilinear::{BilinearOpt, BilinearRand};
pub use cbe::{CbeOpt, CbeRand, CbeTrainer};
pub use itq::Itq;
pub use lsh::Lsh;
pub use sh::Sh;
pub use sklsh::Sklsh;
pub use traits::BinaryEncoder;
