//! SH — Spectral Hashing (Weiss et al. 2008).
//!
//! PCA, then per-direction eigenfunctions of the 1-D Laplacian on the data
//! range: bits are sign(sin(π/2 + jπ/range · proj)) for the k smallest
//! analytical eigenvalues across directions/frequencies.

use super::BinaryEncoder;
use crate::linalg::pca::Pca;
use crate::linalg::Mat;

pub struct Sh {
    pca: Pca,
    /// Per-bit (pca_dir, mode_j, omega) — sin(omega·(v−lo) + π/2·mode parity)
    modes: Vec<(usize, f64)>, // (direction, omega_j = jπ/range)
    los: Vec<f32>,
    k: usize,
}

impl Sh {
    pub fn train(x: &Mat, k: usize, seed: u64) -> Sh {
        let _ = seed; // deterministic given data
        let npca = k.min(x.cols);
        let pca = Pca::fit(x, npca);
        let v = pca.transform(x);
        // Per-direction ranges.
        let mut lo = vec![f32::INFINITY; npca];
        let mut hi = vec![f32::NEG_INFINITY; npca];
        for i in 0..v.rows {
            for j in 0..npca {
                lo[j] = lo[j].min(v[(i, j)]);
                hi[j] = hi[j].max(v[(i, j)]);
            }
        }
        // Candidate modes: eigenvalue ∝ (j·π/range)², j = 1..k per direction.
        let mut cands: Vec<(f64, usize, f64)> = Vec::new(); // (eig, dir, omega)
        for dir in 0..npca {
            let range = (hi[dir] - lo[dir]).max(1e-6) as f64;
            for j in 1..=k {
                let omega = j as f64 * std::f64::consts::PI / range;
                cands.push((omega * omega, dir, omega));
            }
        }
        cands.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let modes: Vec<(usize, f64)> = cands.iter().take(k).map(|c| (c.1, c.2)).collect();
        Sh {
            pca,
            modes,
            los: lo,
            k,
        }
    }
}

impl BinaryEncoder for Sh {
    fn name(&self) -> &'static str {
        "SH"
    }
    fn bits(&self) -> usize {
        self.k
    }
    fn encode_signs(&self, x: &[f32]) -> Vec<f32> {
        let row = Mat::from_vec(1, x.len(), x.to_vec());
        let v = self.pca.transform(&row);
        self.modes
            .iter()
            .map(|&(dir, omega)| {
                let t = (v[(0, dir)] - self.los[dir]) as f64;
                let val = (omega * t + std::f64::consts::FRAC_PI_2).sin();
                if val >= 0.0 {
                    1.0
                } else {
                    -1.0
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn produces_k_sign_bits() {
        let mut rng = Pcg64::new(41);
        let x = Mat::randn(100, 32, &mut rng);
        let enc = Sh::train(&x, 12, 0);
        let code = enc.encode_signs(x.row(3));
        assert_eq!(code.len(), 12);
        assert!(code.iter().all(|c| c.abs() == 1.0));
    }

    #[test]
    fn low_frequency_modes_first() {
        let mut rng = Pcg64::new(42);
        let x = Mat::randn(200, 16, &mut rng);
        let enc = Sh::train(&x, 8, 0);
        // First mode should be the slowest oscillation (j=1 on the widest
        // direction); nearby points then agree on early bits more often.
        let a = enc.encode_signs(x.row(0));
        let mut xb = x.row(0).to_vec();
        for v in xb.iter_mut() {
            *v += 1e-4;
        }
        let b = enc.encode_signs(&xb);
        assert_eq!(a[0], b[0]);
    }
}
