//! Bilinear binary codes (Gong et al. 2013a) — randomized and learned.
//!
//! The learned variant alternates three closed-form updates (mirroring the
//! original paper's ITQ-style procedure, adapted to two factors):
//!   B  = sign(R1ᵀ Z_i R2)                (binary codes)
//!   R1 = Procrustes(Σ_i Z_i R2 Bᵢᵀ-ish)  (orthogonal factor 1)
//!   R2 = Procrustes(Σ_i Z_iᵀ R1 Bᵢ)      (orthogonal factor 2)
//! with B_i the k1×k2 code matrix of sample i.

use super::BinaryEncoder;
use crate::linalg::Mat;
use crate::projections::{bilinear::near_square_factors, BilinearProjection};
use crate::util::rng::Pcg64;

/// Randomized bilinear codes.
pub struct BilinearRand {
    pub proj: BilinearProjection,
}

impl BilinearRand {
    pub fn new(d: usize, k: usize, seed: u64) -> BilinearRand {
        let mut rng = Pcg64::new(seed);
        BilinearRand {
            proj: BilinearProjection::random(d, k, &mut rng),
        }
    }
}

impl BinaryEncoder for BilinearRand {
    fn name(&self) -> &'static str {
        "Bilinear-rand"
    }
    fn bits(&self) -> usize {
        self.proj.bits()
    }
    fn encode_signs(&self, x: &[f32]) -> Vec<f32> {
        self.proj.encode(x)
    }
}

/// Learned bilinear codes.
pub struct BilinearOpt {
    pub proj: BilinearProjection,
}

impl BilinearOpt {
    /// Train on rows of `x` (d = x.cols), producing k = k1·k2 bits.
    pub fn train(x: &Mat, k: usize, iters: usize, seed: u64) -> BilinearOpt {
        let d = x.cols;
        let (d1, d2) = near_square_factors(d);
        let (k1, k2) = near_square_factors(k);
        // Each factor needs orthonormal columns (QR/Procrustes), so clamp
        // k1 ≤ d1 and k2 ≤ d2; actual bits = self.bits().
        let (k1, k2) = (k1.min(d1), k2.min(d2));
        let mut rng = Pcg64::new(seed);

        // Random orthonormal-ish init (QR of gaussian, columns only).
        let mut r1 = crate::linalg::qr::qr(&Mat::randn(d1, k1, &mut rng)).0;
        let mut r2 = crate::linalg::qr::qr(&Mat::randn(d2, k2, &mut rng)).0;

        let n = x.rows;
        for _ in 0..iters {
            // Accumulate Procrustes targets over samples.
            let mut m1 = Mat::zeros(d1, k1); // Σ Z_i R2 B_iᵀ → for R1
            let mut m2 = Mat::zeros(d2, k2); // Σ Z_iᵀ R1 B_i → for R2
            for i in 0..n {
                let z = Mat::from_vec(d1, d2, x.row(i).to_vec());
                let zr2 = z.matmul(&r2); // d1×k2
                let t = r1.transpose().matmul(&zr2); // k1×k2
                let b = t.sign();
                // R1 target: Z R2 Bᵀ (d1×k1)
                let zb = zr2.matmul(&b.transpose());
                for idx in 0..m1.data.len() {
                    m1.data[idx] += zb.data[idx];
                }
                // R2 target: Zᵀ R1 B (d2×k2)
                let ztr1 = z.transpose().matmul(&r1); // d2×k1
                let zb2 = ztr1.matmul(&b);
                for idx in 0..m2.data.len() {
                    m2.data[idx] += zb2.data[idx];
                }
            }
            r1 = orthonormal_factor(&m1);
            r2 = orthonormal_factor(&m2);
        }

        BilinearOpt {
            proj: BilinearProjection {
                d1,
                d2,
                k1,
                k2,
                r1,
                r2,
            },
        }
    }
}

/// Procrustes solution for a (possibly rectangular) target T (d×k, d ≥ k):
/// the orthonormal-columns W maximizing tr(WᵀT). Computed via the k×k SVD
/// of TᵀT: W = T·V·diag(1/s)·Vᵀ (polar factor), falling back to QR when T
/// is rank-deficient.
fn orthonormal_factor(t: &Mat) -> Mat {
    let k = t.cols;
    let tt = t.transpose().matmul(t); // k×k
    let (u, s, _v) = crate::linalg::svd::svd_square(&tt);
    // tt = U diag(s) Uᵀ (symmetric psd) → T^{-1/2}-style polar factor.
    let mut ok = true;
    for i in 0..k {
        if s[i] < 1e-6 {
            ok = false;
        }
    }
    if !ok {
        return crate::linalg::qr::qr(t).0;
    }
    // inv_sqrt = U diag(1/√s) Uᵀ
    let mut inv_sqrt = Mat::zeros(k, k);
    for i in 0..k {
        for j in 0..k {
            let mut acc = 0f64;
            for l in 0..k {
                acc += u[(i, l)] as f64 / (s[l] as f64).sqrt() * u[(j, l)] as f64;
            }
            inv_sqrt[(i, j)] = acc as f32;
        }
    }
    t.matmul(&inv_sqrt)
}

impl BinaryEncoder for BilinearOpt {
    fn name(&self) -> &'static str {
        "Bilinear-opt"
    }
    fn bits(&self) -> usize {
        self.proj.bits()
    }
    fn encode_signs(&self, x: &[f32]) -> Vec<f32> {
        self.proj.encode(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::orthonormality_error;
    use crate::util::l2_normalize;

    #[test]
    fn trained_factors_orthonormal() {
        let mut rng = Pcg64::new(21);
        let n = 50;
        let d = 36;
        let mut x = Mat::randn(n, d, &mut rng);
        for i in 0..n {
            l2_normalize(x.row_mut(i));
        }
        let enc = BilinearOpt::train(&x, 16, 3, 5);
        assert!(orthonormality_error(&enc.proj.r1) < 1e-3);
        assert!(orthonormality_error(&enc.proj.r2) < 1e-3);
        assert_eq!(enc.bits(), 16);
        let code = enc.encode_signs(x.row(0));
        assert_eq!(code.len(), 16);
        assert!(code.iter().all(|c| c.abs() == 1.0));
    }

    #[test]
    fn quantization_error_decreases_with_training() {
        let mut rng = Pcg64::new(22);
        let n = 80;
        let d = 64;
        let mut x = Mat::randn(n, d, &mut rng);
        for i in 0..n {
            l2_normalize(x.row_mut(i));
        }
        let qerr = |enc: &BilinearProjection| -> f64 {
            let mut e = 0f64;
            for i in 0..n {
                let y = enc.project(x.row(i));
                for v in y {
                    let s: f32 = if v >= 0.0 { 1.0 } else { -1.0 };
                    e += ((s - v) as f64).powi(2);
                }
            }
            e
        };
        let rand = BilinearRand::new(d, 16, 9);
        // Scale-free comparison: normalize rand's projection rows? Instead
        // compare trained iters=1 vs iters=6 (same pipeline, more descent).
        let e1 = qerr(&BilinearOpt::train(&x, 16, 1, 9).proj);
        let e6 = qerr(&BilinearOpt::train(&x, 16, 6, 9).proj);
        assert!(e6 <= e1 * 1.05, "e6={e6} e1={e1}");
        let _ = rand; // rand used for API smoke only
    }
}
