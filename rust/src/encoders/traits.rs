//! The common interface every embedding method implements.

use crate::bits::BitCode;
use crate::linalg::Mat;

/// A trained binary encoder: maps f32 vectors to k-bit codes.
pub trait BinaryEncoder {
    /// Human-readable method name (matches the paper's figure legends).
    fn name(&self) -> &'static str;

    /// Number of output bits.
    fn bits(&self) -> usize;

    /// Encode one vector to ±1 signs (len == bits()).
    fn encode_signs(&self, x: &[f32]) -> Vec<f32>;

    /// Encode a batch of rows into a packed BitCode.
    ///
    /// The default is the serial per-vector reference path
    /// (`encode_signs` + `set_row_from_signs`); throughput-critical
    /// encoders (CBE) override it with the parallel batch engine, which
    /// must stay bit-exactly equal to this default — the equivalence
    /// property tests in `rust/tests/encode_batch.rs` enforce that.
    fn encode_batch(&self, x: &Mat) -> BitCode {
        let k = self.bits();
        let mut bc = BitCode::new(x.rows, k);
        for i in 0..x.rows {
            let signs = self.encode_signs(x.row(i));
            bc.set_row_from_signs(i, &signs);
        }
        bc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Neg;
    impl BinaryEncoder for Neg {
        fn name(&self) -> &'static str {
            "neg"
        }
        fn bits(&self) -> usize {
            2
        }
        fn encode_signs(&self, x: &[f32]) -> Vec<f32> {
            vec![
                if x[0] >= 0.0 { 1.0 } else { -1.0 },
                if x[0] >= 0.0 { -1.0 } else { 1.0 },
            ]
        }
    }

    #[test]
    fn batch_matches_single() {
        let e = Neg;
        let x = Mat::from_vec(2, 1, vec![3.0, -2.0]);
        let bc = e.encode_batch(&x);
        assert_eq!(bc.to_signs(0), vec![1.0, -1.0]);
        assert_eq!(bc.to_signs(1), vec![-1.0, 1.0]);
    }
}
