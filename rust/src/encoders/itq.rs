//! ITQ — Iterative Quantization (Gong et al. 2013b).
//!
//! PCA to k dims, then alternate B = sign(VR) and R = Procrustes(BᵀV) to
//! minimize quantization error. O(d³)-ish due to PCA — the paper's Figure 5
//! shows it strong at low d but unable to scale.

use super::BinaryEncoder;
use crate::linalg::pca::Pca;
use crate::linalg::svd::procrustes_rotation;
use crate::linalg::Mat;
use crate::linalg::qr::random_orthonormal;
use crate::util::rng::Pcg64;

pub struct Itq {
    pca: Pca,
    rot: Mat, // k×k rotation
    k: usize,
}

impl Itq {
    pub fn train(x: &Mat, k: usize, iters: usize, seed: u64) -> Itq {
        assert!(k <= x.cols);
        let pca = Pca::fit(x, k);
        let v = pca.transform(x); // n×k
        let mut rng = Pcg64::new(seed);
        let mut rot = random_orthonormal(k, &mut rng);
        for _ in 0..iters {
            let vr = v.matmul(&rot);
            let b = vr.sign();
            // R = argmin ‖B − VR‖ = Procrustes of VᵀB.
            let m = v.transpose().matmul(&b); // k×k
            rot = procrustes_rotation(&m);
        }
        Itq { pca, rot, k }
    }
}

impl BinaryEncoder for Itq {
    fn name(&self) -> &'static str {
        "ITQ"
    }
    fn bits(&self) -> usize {
        self.k
    }
    fn encode_signs(&self, x: &[f32]) -> Vec<f32> {
        let row = Mat::from_vec(1, x.len(), x.to_vec());
        let v = self.pca.transform(&row);
        let vr = v.matmul(&self.rot);
        vr.sign().data
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::l2_normalize;

    #[test]
    fn itq_reduces_quantization_error() {
        let mut rng = Pcg64::new(31);
        let n = 120;
        let d = 24;
        let k = 8;
        let mut x = Mat::randn(n, d, &mut rng);
        for i in 0..n {
            l2_normalize(x.row_mut(i));
        }
        let qerr = |enc: &Itq| -> f64 {
            let v = enc.pca.transform(&x).matmul(&enc.rot);
            let b = v.sign();
            v.data
                .iter()
                .zip(&b.data)
                .map(|(a, s)| ((a - s) as f64).powi(2))
                .sum()
        };
        let e0 = qerr(&Itq::train(&x, k, 0, 7));
        let e10 = qerr(&Itq::train(&x, k, 10, 7));
        assert!(e10 < e0, "e10={e10} e0={e0}");
    }

    #[test]
    fn codes_are_signs() {
        let mut rng = Pcg64::new(32);
        let x = Mat::randn(60, 16, &mut rng);
        let enc = Itq::train(&x, 8, 5, 3);
        let code = enc.encode_signs(x.row(0));
        assert_eq!(code.len(), 8);
        assert!(code.iter().all(|c| c.abs() == 1.0));
    }
}
