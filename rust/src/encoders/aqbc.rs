//! AQBC — Angular Quantization-based Binary Codes (Gong et al. 2012).
//!
//! For non-negative data, codes quantize the direction of x onto binary
//! vertices {0,1}^k maximizing cosine similarity; the bit pattern is found
//! greedily by sorting coordinates (exact for the unconstrained landmark
//! problem). A learned rotation (Procrustes, ITQ-style) aligns the data
//! first. General data is shifted to the non-negative orthant by the
//! training minimum.

use super::BinaryEncoder;
use crate::linalg::pca::Pca;
use crate::linalg::svd::procrustes_rotation;
use crate::linalg::Mat;
use crate::linalg::qr::random_orthonormal;
use crate::util::rng::Pcg64;

pub struct Aqbc {
    pca: Pca,
    rot: Mat,
    shift: Vec<f32>,
    k: usize,
}

/// Best binary vertex b ∈ {0,1}^k maximizing cos(v, b): take top-m
/// coordinates for the m maximizing vᵀb/√m (scan m = 1..k).
fn best_vertex(v: &[f32]) -> Vec<f32> {
    let k = v.len();
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| v[b].partial_cmp(&v[a]).unwrap());
    let mut best_m = 1;
    let mut best_score = f64::NEG_INFINITY;
    let mut prefix = 0f64;
    for m in 1..=k {
        prefix += v[order[m - 1]] as f64;
        let score = prefix / (m as f64).sqrt();
        if score > best_score {
            best_score = score;
            best_m = m;
        }
    }
    let mut b = vec![-1.0f32; k]; // report as ±1 for the common BitCode path
    for &i in order.iter().take(best_m) {
        b[i] = 1.0;
    }
    b
}

impl Aqbc {
    pub fn train(x: &Mat, k: usize, iters: usize, seed: u64) -> Aqbc {
        let pca = Pca::fit(x, k.min(x.cols));
        let v = pca.transform(x);
        // Shift to non-negative orthant.
        let mut shift = vec![0f32; v.cols];
        for i in 0..v.rows {
            for j in 0..v.cols {
                shift[j] = shift[j].min(v[(i, j)]);
            }
        }
        let mut vp = v.clone();
        for i in 0..vp.rows {
            for j in 0..vp.cols {
                vp[(i, j)] -= shift[j];
            }
        }
        let mut rng = Pcg64::new(seed);
        let mut rot = random_orthonormal(v.cols, &mut rng);
        for _ in 0..iters {
            let vr = vp.matmul(&rot);
            // Quantize each row to its best vertex (in 0/1 space).
            let mut b = Mat::zeros(vr.rows, vr.cols);
            for i in 0..vr.rows {
                let verts = best_vertex(vr.row(i));
                for j in 0..vr.cols {
                    b[(i, j)] = if verts[j] > 0.0 { 1.0 } else { 0.0 };
                }
            }
            let m = vp.transpose().matmul(&b);
            rot = procrustes_rotation(&m);
        }
        Aqbc {
            pca,
            rot,
            shift,
            k,
        }
    }
}

impl BinaryEncoder for Aqbc {
    fn name(&self) -> &'static str {
        "AQBC"
    }
    fn bits(&self) -> usize {
        self.k
    }
    fn encode_signs(&self, x: &[f32]) -> Vec<f32> {
        let row = Mat::from_vec(1, x.len(), x.to_vec());
        let v = self.pca.transform(&row);
        let mut vp = v.clone();
        for j in 0..vp.cols {
            vp[(0, j)] -= self.shift[j];
        }
        let vr = vp.matmul(&self.rot);
        let mut out = best_vertex(vr.row(0));
        out.truncate(self.k);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_vertex_maximizes_cosine() {
        let v = vec![3.0f32, 0.1, 2.0, -1.0];
        let b = best_vertex(&v);
        // brute force over all 2^4 - 1 vertices
        let cos = |mask: usize| -> f64 {
            let mut dot = 0f64;
            let mut cnt = 0f64;
            for j in 0..4 {
                if mask >> j & 1 == 1 {
                    dot += v[j] as f64;
                    cnt += 1.0;
                }
            }
            dot / cnt.sqrt()
        };
        let got_mask = (0..4).fold(0usize, |m, j| m | ((b[j] > 0.0) as usize) << j);
        let got = cos(got_mask);
        for mask in 1..16 {
            assert!(cos(mask) <= got + 1e-9, "mask={mask}");
        }
    }

    #[test]
    fn encode_emits_k_bits() {
        let mut rng = Pcg64::new(61);
        let x = Mat::randn(80, 20, &mut rng);
        let enc = Aqbc::train(&x, 10, 4, 3);
        let c = enc.encode_signs(x.row(5));
        assert_eq!(c.len(), 10);
        assert!(c.iter().all(|v| v.abs() == 1.0));
        assert!(c.iter().any(|v| *v > 0.0), "at least one bit set");
    }
}
