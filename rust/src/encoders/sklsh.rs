//! SKLSH — locality-sensitive binary codes from shift-invariant kernels
//! (Raginsky & Lazebnik 2009): random Fourier features + random phase,
//! binarized by sign(cos(wᵀx + b)).

use super::BinaryEncoder;
use crate::linalg::Mat;
use crate::util::rng::Pcg64;

pub struct Sklsh {
    /// k×d gaussian directions scaled by 1/σ (RBF bandwidth).
    w: Mat,
    /// Random phases in [0, 2π).
    phase: Vec<f32>,
    k: usize,
}

impl Sklsh {
    /// `sigma` is the RBF kernel bandwidth (paper tunes per dataset; for
    /// ℓ2-normalized data sigma ≈ 0.3–1 works well).
    pub fn new(d: usize, k: usize, sigma: f32, seed: u64) -> Sklsh {
        let mut rng = Pcg64::new(seed);
        let mut w = Mat::randn(k, d, &mut rng);
        let inv_sigma = 1.0 / sigma;
        for v in w.data.iter_mut() {
            *v *= inv_sigma;
        }
        let phase: Vec<f32> = (0..k)
            .map(|_| rng.next_f32() * 2.0 * std::f32::consts::PI)
            .collect();
        Sklsh { w, phase, k }
    }
}

impl BinaryEncoder for Sklsh {
    fn name(&self) -> &'static str {
        "SKLSH"
    }
    fn bits(&self) -> usize {
        self.k
    }
    fn encode_signs(&self, x: &[f32]) -> Vec<f32> {
        (0..self.k)
            .map(|i| {
                let row = self.w.row(i);
                let mut acc = 0f32;
                for j in 0..x.len() {
                    acc += row[j] * x[j];
                }
                if (acc + self.phase[i]).cos() >= 0.0 {
                    1.0
                } else {
                    -1.0
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::hamming::normalized_hamming;
    use crate::util::l2_normalize;

    #[test]
    fn near_points_closer_than_far_points() {
        let d = 32;
        let enc = Sklsh::new(d, 256, 0.7, 51);
        let mut rng = Pcg64::new(52);
        let mut a = rng.normal_vec(d);
        l2_normalize(&mut a);
        let mut near: Vec<f32> = a.iter().map(|v| v + 0.05 * rng.normal() as f32).collect();
        l2_normalize(&mut near);
        let mut far = rng.normal_vec(d);
        l2_normalize(&mut far);
        let ca = enc.encode_signs(&a);
        let h_near = normalized_hamming(&ca, &enc.encode_signs(&near));
        let h_far = normalized_hamming(&ca, &enc.encode_signs(&far));
        assert!(h_near < h_far, "near={h_near} far={h_far}");
    }
}
